//! Full reproduction of the paper's §4 comparison on the 16k-task Montage
//! workflow: job model, job model + clustering (several configs), and the
//! hybrid worker-pools model, on the 17-node / 68-core cluster.
//!
//!   cargo run --release --example model_comparison [--tasks 16000]

use hyperflow_k8s::engine::clustering::ClusteringConfig;
use hyperflow_k8s::models::{driver, ExecModel};
use hyperflow_k8s::util::cli::Args;
use hyperflow_k8s::workflow::montage::{generate, MontageConfig};

fn main() {
    let args = Args::from_env();
    let wf = MontageConfig::with_total_tasks(args.get_usize("tasks", 16_000), 42);
    let n = MontageConfig::total_tasks_for_grid(wf.grid_w, wf.grid_h, wf.diagonals);
    println!("Montage {}x{} = {n} tasks, 17 nodes (68 cores)\n", wf.grid_w, wf.grid_h);
    println!(
        "{:>26} {:>10} {:>8} {:>10} {:>10} {:>9}",
        "model", "makespan", "pods", "api reqs", "backoffs", "cpu util"
    );

    let mut rows: Vec<(String, f64)> = Vec::new();
    let configs: Vec<(String, ExecModel)> = vec![
        ("job-based".into(), ExecModel::JobBased),
        (
            "clustered (paper cfg)".into(),
            ExecModel::Clustered(ClusteringConfig::paper_default()),
        ),
        (
            "clustered (uniform 10)".into(),
            ExecModel::Clustered(ClusteringConfig::uniform(10, 3000)),
        ),
        (
            "clustered (uniform 40)".into(),
            ExecModel::Clustered(ClusteringConfig::uniform(40, 3000)),
        ),
        ("worker-pools (hybrid)".into(), ExecModel::paper_hybrid_pools()),
    ];
    for (label, model) in configs {
        let res = driver::run(generate(&wf), model, driver::SimConfig::default());
        println!(
            "{label:>26} {:>9.0}s {:>8} {:>10} {:>10} {:>8.1}%",
            res.makespan.as_secs_f64(),
            res.pods_created,
            res.api_requests,
            res.sched_backoffs,
            res.avg_cpu_utilization * 100.0
        );
        rows.push((label, res.makespan.as_secs_f64()));
    }

    let best_job = rows
        .iter()
        .filter(|(l, _)| l.starts_with("clustered") || l.starts_with("job"))
        .map(|(_, m)| *m)
        .fold(f64::INFINITY, f64::min);
    let pools = rows.last().unwrap().1;
    println!(
        "\nworker pools vs best job-based: {:.0}s vs {:.0}s  ->  {:.1}% makespan improvement",
        pools,
        best_job,
        (best_job - pools) / best_job * 100.0
    );
    println!("(paper §4.4: ~1420s vs ~1700s, \"nearly 20%\")");
}
