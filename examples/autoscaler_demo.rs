//! Autoscaler behaviour under intertwined parallel stages (Table 1's
//! "proportional resource allocation" challenge): watch the per-pool
//! replica counts and queue depths while mProject and mDiffFit compete for
//! the cluster.
//!
//!   cargo run --release --example autoscaler_demo

use hyperflow_k8s::models::{driver, ExecModel};
use hyperflow_k8s::util::ascii_plot;
use hyperflow_k8s::workflow::montage::{generate, MontageConfig};

fn main() {
    let wf = MontageConfig {
        grid_w: 24,
        grid_h: 24,
        diagonals: true,
        seed: 7,
    };
    println!(
        "montage {}x{} ({} tasks), worker-pools model, 17 nodes\n",
        wf.grid_w,
        wf.grid_h,
        MontageConfig::total_tasks_for_grid(wf.grid_w, wf.grid_h, true)
    );
    let res = driver::run(
        generate(&wf),
        ExecModel::paper_hybrid_pools(),
        driver::SimConfig::default(),
    );
    println!(
        "makespan {:.0}s, avg cpu utilization {:.1}%\n",
        res.makespan.as_secs_f64(),
        res.avg_cpu_utilization * 100.0
    );

    for pool in ["mProject", "mDiffFit", "mBackground"] {
        if let Some(q) = res.metrics.gauge(&format!("queue::{pool}")) {
            println!(
                "{}",
                ascii_plot::area_chart(
                    &format!("queue depth – {pool}"),
                    q.points(),
                    90,
                    6
                )
            );
        }
        if let Some(r) = res.metrics.gauge(&format!("replicas::{pool}")) {
            println!(
                "{}",
                ascii_plot::area_chart(
                    &format!("replicas – {pool} (proportional allocation)"),
                    r.points(),
                    90,
                    5
                )
            );
        }
    }

    // proportional-allocation check during the intertwined phase:
    // while both pools have backlog, cpu shares should track workloads
    println!("scale events: {}", res.metrics.counter("pods_created"));
    println!("note: pools scale to ZERO between stages (KEDA, §3.5)");
}
