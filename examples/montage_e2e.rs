//! End-to-end driver: run a real Montage mosaic through the full
//! three-layer stack — Rust coordinator (worker pools + autoscaler + job
//! pods) executing the AOT-compiled JAX/Pallas numerics via PJRT — and
//! verify the mosaic against the analytic sky.
//!
//!   make artifacts && cargo run --release --example montage_e2e
//!
//! Flags: --grid N (default 4)  --workers N  --model pools|jobs
//!        --pod-start-ms MS     --seed S     --no-warp

use hyperflow_k8s::realtime::{run, RealModel, RealtimeConfig};
use hyperflow_k8s::util::cli::Args;
use hyperflow_k8s::util::logger;

fn main() -> anyhow::Result<()> {
    logger::init();
    let args = Args::from_env();
    let model = match args.get_or("model", "pools") {
        "jobs" | "job" => RealModel::Jobs,
        _ => RealModel::WorkerPools,
    };
    let cfg = RealtimeConfig {
        grid: args.get_usize("grid", 4),
        model,
        max_workers: args.get_usize(
            "workers",
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
        ),
        pod_start_ms: args.get_u64("pod-start-ms", 250),
        seed: args.get_u64("seed", 42),
        warp: !args.has("no-warp"),
        ..Default::default()
    };
    println!(
        "montage_e2e: grid {gx}x{gx} ({} tasks), model {:?}, {} worker quota, pod start {} ms",
        hyperflow_k8s::workflow::montage::MontageConfig::total_tasks_for_grid(
            cfg.grid, cfg.grid, false
        ),
        cfg.model,
        cfg.max_workers,
        cfg.pod_start_ms,
        gx = cfg.grid,
    );

    let report = run(cfg)?;

    println!("\n== run ==");
    println!("makespan:    {:.2} s", report.makespan_ms as f64 / 1000.0);
    println!("tasks:       {}", report.tasks);
    println!("pods:        {}", report.pods);
    println!("throughput:  {:.1} tasks/s", report.throughput_tasks_per_s());

    println!("\n== per-type latency (ms) ==");
    println!(
        "{:>12} {:>6} {:>10} {:>10} {:>10} {:>10}",
        "type", "n", "wait p50", "wait p95", "exec p50", "exec p95"
    );
    for (ty, (wait, exec)) in report.latency_by_type() {
        println!(
            "{:>12} {:>6} {:>10.0} {:>10.0} {:>10.0} {:>10.0}",
            ty,
            wait.len(),
            wait.percentile(50.0),
            wait.percentile(95.0),
            exec.percentile(50.0),
            exec.percentile(95.0)
        );
    }

    println!("\n== verification ==");
    let v = &report.verify;
    println!(
        "mosaic residual (max, DC-free): {:.4}   offset error (max): {:.4}",
        v.max_mosaic_residual, v.max_offset_error
    );
    println!(
        "coverage: {}/{} canvas pixels",
        v.covered_pixels, v.canvas_pixels
    );
    // tolerance: exact-grid runs are tight; warped runs absorb the bilinear
    // interpolation error of the synthetic sky (~2e-2 per overlap fit)
    let tol = if args.has("no-warp") { 0.02 } else { 0.15 };
    if v.ok(tol) {
        println!("RESULT: OK — mosaic matches the analytic sky");
        Ok(())
    } else {
        anyhow::bail!("verification FAILED: residual too large")
    }
}
