//! Quickstart: simulate a Montage workflow under each execution model and
//! compare makespan/utilization — the paper's core experiment in miniature.
//!
//!   cargo run --release --example quickstart

use hyperflow_k8s::engine::clustering::ClusteringConfig;
use hyperflow_k8s::models::{driver, ExecModel};
use hyperflow_k8s::util::ascii_plot;
use hyperflow_k8s::workflow::montage::{generate, MontageConfig};

fn main() {
    // a ~1.3k-task Montage on a 17-node cluster (fast to simulate)
    let wf = MontageConfig {
        grid_w: 16,
        grid_h: 16,
        diagonals: true,
        seed: 42,
    };
    println!(
        "workflow: montage {}x{} = {} tasks\n",
        wf.grid_w,
        wf.grid_h,
        MontageConfig::total_tasks_for_grid(wf.grid_w, wf.grid_h, true)
    );

    for model in [
        ExecModel::JobBased,
        ExecModel::Clustered(ClusteringConfig::paper_default()),
        ExecModel::paper_hybrid_pools(),
    ] {
        let name = model.name();
        let res = driver::run(generate(&wf), model, driver::SimConfig::default());
        println!(
            "{name:>14}: makespan {:>6.0} s   pods {:>5}   avg parallel tasks {:>5.1}   cpu util {:>4.1}%",
            res.makespan.as_secs_f64(),
            res.pods_created,
            res.avg_running_tasks,
            res.avg_cpu_utilization * 100.0
        );
        println!(
            "{}",
            ascii_plot::area_chart(
                &format!("  {name} – tasks running"),
                &res.running_series(),
                90,
                7
            )
        );
    }
    println!("(see examples/montage_e2e.rs for the real-compute PJRT run)");
}
