//! Multiple workflow instances sharing one cluster ("multiple instances of
//! different workflows can intertwine", §3.4): two Montage instances are
//! merged with [`Dag::disjoint_union`] and executed under each model.
//! Worker pools handle the type-level aggregation naturally — both
//! instances feed the same queues. For an *open-loop* arrival process with
//! tenancy and fair-share scheduling, see `hyperflow serve` and the
//! `fleet` module.
//!
//!   cargo run --release --example multi_workflow

use hyperflow_k8s::engine::clustering::ClusteringConfig;
use hyperflow_k8s::models::{driver, ExecModel};
use hyperflow_k8s::workflow::dag::Dag;
use hyperflow_k8s::workflow::montage::{generate, MontageConfig};

fn instances() -> Vec<Dag> {
    vec![
        generate(&MontageConfig {
            grid_w: 14,
            grid_h: 14,
            diagonals: true,
            seed: 1,
        }),
        generate(&MontageConfig {
            grid_w: 10,
            grid_h: 10,
            diagonals: true,
            seed: 2,
        }),
    ]
}

fn main() {
    let parts = instances();
    println!(
        "two Montage instances: {} + {} tasks, shared 17-node cluster\n",
        parts[0].len(),
        parts[1].len()
    );
    let merged = Dag::disjoint_union(&parts);
    assert!(merged.validate().is_ok());
    assert_eq!(merged.len(), parts[0].len() + parts[1].len());

    for model in [
        ExecModel::JobBased,
        ExecModel::Clustered(ClusteringConfig::paper_default()),
        ExecModel::paper_hybrid_pools(),
    ] {
        let name = model.name();
        let dag = Dag::disjoint_union(&instances());
        let res = driver::run(dag, model, driver::SimConfig::default());
        println!(
            "{name:>14}: joint makespan {:>6.0}s   pods {:>6}   cpu util {:>5.1}%",
            res.makespan.as_secs_f64(),
            res.pods_created,
            res.avg_cpu_utilization * 100.0
        );
    }
    // sanity: merged DAG structure is two disjoint montages
    let c = merged.count_by_type();
    println!(
        "\nmerged stage sizes: mProject {}  mDiffFit {}  mBackground {}",
        c["mProject"], c["mDiffFit"], c["mBackground"]
    );
}
