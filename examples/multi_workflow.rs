//! Multiple workflow instances sharing one cluster ("multiple instances of
//! different workflows can intertwine", §3.4): two Montage instances are
//! merged into one DAG and executed under each model. Worker pools handle
//! the type-level aggregation naturally — both instances feed the same
//! queues.
//!
//!   cargo run --release --example multi_workflow

use hyperflow_k8s::engine::clustering::ClusteringConfig;
use hyperflow_k8s::models::{driver, ExecModel};
use hyperflow_k8s::sim::SimTime;
use hyperflow_k8s::workflow::dag::Dag;
use hyperflow_k8s::workflow::montage::{default_types, generate, MontageConfig};
use hyperflow_k8s::workflow::task::TaskId;

/// Merge independent workflow instances into one DAG (disjoint union).
fn merge(instances: &[Dag]) -> Dag {
    let mut out = Dag::new("multi-montage");
    let type_ids: Vec<_> = default_types().into_iter().map(|t| out.add_type(t)).collect();
    for inst in instances {
        let base = out.len() as u32;
        // invert successor lists into dependency lists in one pass
        let mut deps: Vec<Vec<TaskId>> = vec![Vec::new(); inst.len()];
        for p in 0..inst.len() as u32 {
            for s in inst.successors(TaskId(p)) {
                deps[s.0 as usize].push(TaskId(p + base));
            }
        }
        for t in &inst.tasks {
            let name = &inst.types[t.ttype.0 as usize].name;
            let ty = type_ids
                .iter()
                .find(|ti| out.types[ti.0 as usize].name == *name)
                .copied()
                .unwrap();
            out.add_task(ty, t.duration, &deps[t.id.0 as usize]);
        }
    }
    out
}

fn main() {
    let a = generate(&MontageConfig {
        grid_w: 14,
        grid_h: 14,
        diagonals: true,
        seed: 1,
    });
    let b = generate(&MontageConfig {
        grid_w: 10,
        grid_h: 10,
        diagonals: true,
        seed: 2,
    });
    println!(
        "two Montage instances: {} + {} tasks, shared 17-node cluster\n",
        a.len(),
        b.len()
    );
    let merged = merge(&[a, b]);
    assert!(merged.validate().is_ok());

    for model in [
        ExecModel::JobBased,
        ExecModel::Clustered(ClusteringConfig::paper_default()),
        ExecModel::paper_hybrid_pools(),
    ] {
        let name = model.name();
        let dag = merge(&[
            generate(&MontageConfig {
                grid_w: 14,
                grid_h: 14,
                diagonals: true,
                seed: 1,
            }),
            generate(&MontageConfig {
                grid_w: 10,
                grid_h: 10,
                diagonals: true,
                seed: 2,
            }),
        ]);
        let res = driver::run(dag, model, driver::SimConfig::default());
        // per-instance makespans: first instance tasks end where?
        println!(
            "{name:>14}: joint makespan {:>6.0}s   pods {:>6}   cpu util {:>5.1}%",
            res.makespan.as_secs_f64(),
            res.pods_created,
            res.avg_cpu_utilization * 100.0
        );
    }
    // sanity: merged DAG structure is two disjoint montages
    let c = merged.count_by_type();
    println!(
        "\nmerged stage sizes: mProject {}  mDiffFit {}  mBackground {}",
        c["mProject"], c["mDiffFit"], c["mBackground"]
    );
    let _ = SimTime::ZERO;
}
