//! The **strategy** layer: pluggable execution-model policy on top of the
//! [`Kernel`].
//!
//! * [`ExecModel`] — the user-facing model description (paper §3), parsed
//!   by the CLI / config layer.
//! * [`ExecStrategy`] — the lifecycle-hook trait the kernel event loop
//!   dispatches into: `on_ready`, `on_pod_started`, `on_pod_idle`,
//!   `on_task_done`, `on_scale`, `on_retry_task` / `on_retry_batch`,
//!   `on_speculate`, `on_node_down`, `on_fault`. One module per model
//!   implements it: [`crate::exec::job`], [`crate::exec::clustered`],
//!   [`crate::exec::pools`], [`crate::exec::generic`].
//! * [`Strategy`] — the enum-backed dispatcher ([`Strategy::build`] holds
//!   the *single* `ExecModel` match in the execution layer). Enum
//!   dispatch keeps the hot path static — no boxed trait objects, no
//!   per-event closures (EXPERIMENTS.md §Perf).
//! * [`StrategyState`] — the shared machinery every strategy composes: a
//!   [`JobPath`] (batching + throttling) and a [`PoolPath`] (queues +
//!   deployments + autoscaler), plus the cross-cutting operations that
//!   touch both (scheduling passes, ready-task routing, pod
//!   termination). A model is a *configuration* of these paths — e.g.
//!   the hybrid pools model routes pooled types to queues and everything
//!   else to singleton jobs — which is what lets one event loop execute
//!   all four paper models bit-reproducibly.

use crate::chaos::RecoveryPolicy;
use crate::engine::clustering::{BatchAction, ClusteringConfig};
use crate::engine::{Engine, TaskState};
use crate::exec::clustered::ClusteredStrategy;
use crate::exec::config::{ConfigError, SimConfig};
use crate::exec::generic::GenericStrategy;
use crate::exec::job::{JobPath, JobStrategy};
use crate::exec::kernel::{Ev, IoPhase, Kernel};
use crate::exec::pools::{PoolPath, PoolsStrategy};
use crate::k8s::pod::{Payload, PodId, PodPhase};
use crate::k8s::scheduler::DataLocality;
use crate::metrics::Registry;
use crate::obs::Actor;
use crate::sim::SimTime;
use crate::workflow::dag::Dag;
use crate::workflow::task::TaskId;

/// Which execution model a run uses (paper §3).
#[derive(Debug, Clone)]
pub enum ExecModel {
    /// §3.2: one task -> one Kubernetes Job -> one Pod.
    JobBased,
    /// §3.2 + clustering: batches of same-type tasks per pod.
    Clustered(ClusteringConfig),
    /// §3.3: worker pools for `pooled_types`; other types run as jobs
    /// (the paper's hybrid setup). Set `pooled_types` to all types for the
    /// pure pool model.
    WorkerPools { pooled_types: Vec<String> },
    /// §3.3's rejected alternative: a single generic worker pool for ALL
    /// task types. "Inferior both conceptually and technically": the pod
    /// template must request the max resources over every type (degrading
    /// scheduling quality) and implies one universal container image.
    /// Implemented to quantify exactly that degradation.
    GenericPool,
}

impl ExecModel {
    pub fn name(&self) -> &'static str {
        match self {
            ExecModel::JobBased => "job-based",
            ExecModel::Clustered(_) => "job-clustered",
            ExecModel::WorkerPools { .. } => "worker-pools",
            ExecModel::GenericPool => "generic-pool",
        }
    }

    /// The hybrid worker-pools setup used in §4.4: pools for the three
    /// parallel stages, jobs for everything else.
    pub fn paper_hybrid_pools() -> Self {
        ExecModel::WorkerPools {
            pooled_types: vec![
                "mProject".to_string(),
                "mDiffFit".to_string(),
                "mBackground".to_string(),
            ],
        }
    }

    /// Structural validation (no workflow needed): empty pool sets,
    /// duplicate pool declarations and zero-size clustering rules become
    /// named errors instead of mid-run panics.
    pub fn validate(&self) -> Result<(), ConfigError> {
        match self {
            ExecModel::WorkerPools { pooled_types } => {
                if pooled_types.is_empty() {
                    return Err(ConfigError::EmptyPoolSet);
                }
                for (i, t) in pooled_types.iter().enumerate() {
                    if pooled_types[..i].contains(t) {
                        return Err(ConfigError::DuplicatePooledType(t.clone()));
                    }
                }
                Ok(())
            }
            ExecModel::Clustered(c) => {
                if c.rules.iter().any(|r| r.size == 0) {
                    return Err(ConfigError::ZeroClusterSize);
                }
                Ok(())
            }
            _ => Ok(()),
        }
    }

    /// Validate the model against a concrete workflow (pooled types must
    /// exist in the DAG).
    pub fn validate_against(&self, dag: &Dag) -> Result<(), ConfigError> {
        if let ExecModel::WorkerPools { pooled_types } = self {
            for t in pooled_types {
                if dag.type_id(t).is_none() {
                    return Err(ConfigError::UnknownPooledType(t.clone()));
                }
            }
        }
        Ok(())
    }
}

/// What a pod will do next, extracted from its payload without cloning it
/// (the owned `Vec<TaskId>` is *moved* out of job payloads).
pub enum PodWork {
    Batch(Vec<TaskId>),
    Pool(crate::broker::PoolId),
}

/// The machinery every strategy composes: the job path and the pool path.
/// Cross-cutting operations (routing, scheduling passes, termination, the
/// subsystem-hook glue in [`crate::exec::hooks`]) are methods here so any
/// strategy can reach both paths without borrow gymnastics.
pub struct StrategyState {
    pub jobs: JobPath,
    pub pools: PoolPath,
}

impl StrategyState {
    // ---------------------------------------------------------------
    // routing + scheduling
    // ---------------------------------------------------------------

    /// Route newly-ready tasks: pooled types publish to their queue,
    /// everything else goes through the job path's batcher.
    pub fn dispatch_ready(&mut self, k: &mut Kernel, ready: &[TaskId]) {
        let now = k.now();
        for &t in ready {
            let ttype = k.engine.dag().tasks[t.0 as usize].ttype;
            k.trace.ready(t, ttype, now);
            if let Some(o) = k.obs.as_mut() {
                o.ready(t, now);
            }
            match self.pools.pool_of_type[ttype.0 as usize] {
                Some(pool) => {
                    let tenant = k.tenant_of(t);
                    self.pools.publish(k, pool, t, tenant);
                }
                None => {
                    // job path (with or without clustering)
                    let action = self.jobs.batcher.push(
                        now,
                        ttype,
                        &k.engine.dag().types[ttype.0 as usize].name,
                        t,
                    );
                    match action {
                        BatchAction::Flush(batch) => self.jobs.create_job(k, batch),
                        BatchAction::ArmTimer(deadline) => k.q.schedule_at(
                            deadline,
                            Ev::FlushTimer {
                                type_idx: ttype.0,
                                deadline,
                            },
                        ),
                        BatchAction::Buffered => {}
                    }
                }
            }
        }
    }

    /// One scheduler pass: bind what fits, back off what doesn't. Bound
    /// job pods leave the pending pipeline (throttle accounting); the
    /// locality oracle is consulted only when the data plane asks for it.
    pub fn run_scheduler(&mut self, k: &mut Kernel) {
        let now = k.now();
        let mut pass = std::mem::take(&mut k.pass_buf);
        // locality-aware placement only when the data plane asks for it;
        // otherwise the oracle-free path is taken (bit-identical to the
        // pre-data scheduler)
        let data = k.data.take();
        let locality: Option<&dyn DataLocality> = match &data {
            Some(d) if d.cfg().locality => Some(d),
            _ => None,
        };
        // isolation oracle: quota admission + node-pool placement filter
        // (None — the default — is bit-identical to the pre-tenancy pass)
        let mut iso = k.isolation.take();
        k.sched
            .pass_into(now, &mut k.pods, &mut k.nodes, &mut pass, locality, iso.as_mut());
        k.data = data;
        k.isolation = iso;
        if !pass.bound.is_empty() {
            k.record_cpu();
        }
        // a sandboxed runtime class (gVisor/Kata-style) boots extra
        // machinery per pod: constant start-latency tax on every bind
        let start_ms = k.cfg.pod_start_ms
            + k.isolation
                .as_ref()
                .map_or(0, |i| i.cfg.policy.start_overhead_ms());
        for &(pid, node, bind_done) in &pass.bound {
            k.pending_count -= 1;
            k.pod_bound_inc[pid.0 as usize] = k.node_incarnation[node.0];
            if matches!(k.pods.payload[pid.0 as usize], Payload::JobBatch { .. }) {
                self.jobs.job_unblocked(k);
            }
            k.q.schedule_at(
                bind_done + SimTime::from_millis(start_ms),
                Ev::PodStarted { pod: pid },
            );
        }
        for &(pid, until) in &pass.backed_off {
            k.q.schedule_at(until, Ev::BackoffExpire { pod: pid });
        }
        if let Some(o) = k.obs.as_mut() {
            for &(pid, node, _) in &pass.bound {
                o.event(
                    now,
                    Actor::Scheduler,
                    "bind",
                    format!("pod {} -> node {}", pid.0, node.0),
                    1.0,
                );
            }
            for (i, &(pid, until)) in pass.backed_off.iter().enumerate() {
                let why = pass
                    .backoff_reasons
                    .get(i)
                    .map(|r| r.name())
                    .unwrap_or("nofit");
                o.event(
                    now,
                    Actor::Scheduler,
                    "backoff",
                    format!("pod {} ({why})", pid.0),
                    until.saturating_sub(now).as_secs_f64(),
                );
            }
        }
        k.pass_buf = pass;
        k.metrics.set_id(k.g_pending, now, k.pending_count as f64);
    }

    /// Terminate a pod, drop it from its deployment, and re-run the
    /// scheduler: freed resources mean pods in the *active* queue can
    /// retry now; pods in back-off keep sleeping (the paper's §4.2/4.3
    /// pathology).
    pub fn terminate_pod(&mut self, k: &mut Kernel, pid: PodId, phase: PodPhase) {
        k.release_pod(pid, phase);
        if let Some(pool) = k.pods.pool_id(pid.0 as usize) {
            self.pools.forget_worker(pool, pid);
        }
        k.sched.forget(pid);
        // pod deletion is an API request too
        k.api.admit(k.now());
        self.run_scheduler(k);
    }

    // ---------------------------------------------------------------
    // kernel-event entry points (the trait hooks delegate here)
    // ---------------------------------------------------------------

    /// Container started: maybe crash (chaos), then begin the payload —
    /// a batch starts its first task, a worker fetches or goes idle.
    pub fn pod_started(&mut self, k: &mut Kernel, pod: PodId) {
        let now = k.now();
        if k.pods.is_terminal(pod.0 as usize) {
            return; // deleted while starting
        }
        if k.stale_node_event(pod) {
            return; // bound to a node incarnation that no longer exists
        }
        // chaos: crash at container start (PodFailure injector — the
        // migrated sim.pod_failure_prob knob included)
        let crash = match &mut k.chaos {
            Some(ch) if ch.pod_fail_prob > 0.0 => ch.pod_rng.f64() < ch.pod_fail_prob,
            _ => false,
        };
        if crash {
            self.pod_start_failure(k, pod);
            return;
        }
        let work = {
            let i = pod.0 as usize;
            k.pods.phase[i] = PodPhase::Running;
            k.pods.running_at[i] = Some(now);
            match &mut k.pods.payload[i] {
                // move the batch into the execution queue — the
                // remainder lives in `batch_queue` from here on
                Payload::JobBatch { tasks } => PodWork::Batch(std::mem::take(tasks)),
                Payload::Worker { pool } => PodWork::Pool(*pool),
            }
        };
        match work {
            PodWork::Batch(tasks) => {
                k.batch_queue[pod.0 as usize] = tasks.into();
                let first = k.batch_queue[pod.0 as usize]
                    .front()
                    .copied()
                    .expect("non-empty batch");
                self.begin_task(k, pod, first);
            }
            PodWork::Pool(pool) => self.pools.fetch_or_idle(k, pod, pool),
        }
    }

    /// A worker's queue fetch completed: drop stale deliveries, requeue if
    /// the worker died in the meantime, otherwise begin the task.
    pub fn worker_fetched(&mut self, k: &mut Kernel, pod: PodId, task: TaskId) {
        if k.pods.is_terminal(pod.0 as usize) {
            // worker deleted between fetch and start: requeue on the
            // pod's own pool (its payload outlives deletion)
            if let Some(pool) = k.pods.pool_id(pod.0 as usize) {
                self.pools.broker.nack_requeue(pool, task, k.tenant_of(task));
                self.pools.wake_idle_worker(k, pool);
            }
            return;
        }
        // chaos/speculation: the task already completed elsewhere (its
        // other copy won, or it was requeued after a fault and then
        // finished) — drop the stale delivery
        if k.engine.state(task) == TaskState::Done {
            if let Some(pool) = k.pods.pool_id(pod.0 as usize) {
                self.advance_worker(k, pod, pool);
            }
            return;
        }
        self.begin_task(k, pod, task);
    }

    /// The current task's compute finished: account it, propagate
    /// readiness (or hand off to the stage-out cycle), and advance the
    /// pod to its next unit of work.
    pub fn task_done(&mut self, k: &mut Kernel, pod: PodId, task: TaskId) {
        if k.pods.is_terminal(pod.0 as usize) || k.current_task[pod.0 as usize] != Some(task) {
            return; // pod was killed; the task was requeued/recreated
        }
        if k.stale_node_event(pod) {
            return; // completion from a node incarnation that is gone
        }
        let now = k.now();
        let ttype = k.engine.dag().tasks[task.0 as usize].ttype;
        // execution time of this run, net of the fixed executor overhead
        // (same definition as the waste accounting, so goodput's numerator
        // and denominator are commensurate)
        let exec_ms = k.run_exec_ms(pod);
        // speculative duplicate that lost the race: the task already
        // completed in its other copy (or, with the data plane, its twin's
        // stage-out is already in flight) — the whole run is wasted work,
        // and the worker simply moves on
        if k.engine.state(task) == TaskState::Done
            || (k.data.is_some() && k.task_out_pending[task.0 as usize])
        {
            k.current_task[pod.0 as usize] = None;
            k.pod_io[pod.0 as usize] = IoPhase::Idle;
            k.record_running(ttype, -1);
            k.task_running[task.0 as usize] -= 1;
            k.chaos_stats.add_waste(k.tenant_of(task).idx(), exec_ms);
            k.metrics.inc_id(k.c.speculative_losses, 1);
            if let Some(o) = k.obs.as_mut() {
                o.attempt_lost(pod, now);
                o.event(
                    now,
                    Actor::Chaos,
                    "spec_loss",
                    format!("task {} pod {}", task.0, pod.0),
                    exec_ms as f64 / 1000.0,
                );
            }
            if let Some(pool) = k.pods.pool_id(pod.0 as usize) {
                self.advance_worker(k, pod, pool);
            }
            return;
        }
        if k.data.is_some() {
            // the execution is done but the output write is not:
            // successors wait for the stage-out (write-through shared
            // storage). `current_task` stays set so a kill during the
            // write re-runs the task — and ALL success accounting (useful
            // work, completed-by-type, compute time) waits for the write
            // to land in finish_task, or the re-run would be counted
            // twice.
            k.record_running(ttype, -1);
            k.task_running[task.0 as usize] -= 1;
            k.pod_exec_ms[pod.0 as usize] = exec_ms;
            // compute is over; `finished` is stamped when the write lands
            k.obs_task_complete(pod, task, now);
            self.begin_stage_out_for(k, pod, task);
            return;
        }
        if k.chaos.is_some() {
            k.chaos_stats.useful_ms += exec_ms;
        }
        k.current_task[pod.0 as usize] = None;
        k.pod_io[pod.0 as usize] = IoPhase::Idle;
        k.trace.finished(task, now);
        k.obs_task_complete(pod, task, now);
        if let Some(o) = k.obs.as_mut() {
            o.finished(task, now);
        }
        k.record_running(ttype, -1);
        k.task_running[task.0 as usize] -= 1;
        k.completed_by_type[ttype.0 as usize] += 1;
        // readiness propagation through the reusable scratch buffer
        let mut ready = std::mem::take(&mut k.ready_buf);
        ready.clear();
        k.engine.complete_into(task, &mut ready);
        self.dispatch_ready(k, &ready);
        k.ready_buf = ready;
        // fleet: per-instance completion + admission-slot release
        if k.fleet.is_some() {
            self.instance_task_done(k, task);
        }
        // advance the pod
        match k.pods.pool_id(pod.0 as usize) {
            None => {
                k.batch_queue[pod.0 as usize].pop_front();
                if let Some(&next) = k.batch_queue[pod.0 as usize].front() {
                    k.start_task(pod, next);
                } else {
                    self.terminate_pod(k, pod, PodPhase::Succeeded);
                }
            }
            Some(pool) => self.advance_worker(k, pod, pool),
        }
    }

    /// A failed task's retry back-off expired: re-enter it, unless a
    /// speculative copy landed it (or started) in the meantime.
    pub fn retry_task(&mut self, k: &mut Kernel, task: TaskId) {
        if k.engine.state(task) == TaskState::Done {
            return; // a speculative copy landed it in the meantime
        }
        if k.task_running[task.0 as usize] > 0 {
            return; // a copy started while the back-off ran; it owns the work
        }
        let ttype = k.engine.dag().tasks[task.0 as usize].ttype;
        match self.pools.pool_of_type[ttype.0 as usize] {
            Some(pool) => {
                let tenant = k.tenant_of(task);
                self.pools.publish(k, pool, task, tenant);
            }
            // defensive: a task of an unpooled type re-enters as a
            // single-task job
            None => self.jobs.create_job(k, vec![task]),
        }
    }

    /// Straggler watch fired: if the task is still running in this pod,
    /// launch its speculative copy (at most one per task).
    pub fn speculate(&mut self, k: &mut Kernel, pod: PodId, task: TaskId) {
        if k.pods.is_terminal(pod.0 as usize)
            || k.current_task[pod.0 as usize] != Some(task)
            || k.engine.state(task) == TaskState::Done
            || k.spec_launched[task.0 as usize]
        {
            return;
        }
        k.spec_launched[task.0 as usize] = true;
        k.chaos_stats.speculations += 1;
        k.metrics.inc_id(k.c.speculative_copies, 1);
        let now = k.now();
        if let Some(o) = k.obs.as_mut() {
            o.event(
                now,
                Actor::Chaos,
                "speculate",
                format!("task {} straggling in pod {}", task.0, pod.0),
                0.0,
            );
        }
        let ttype = k.engine.dag().tasks[task.0 as usize].ttype;
        if let Some(pool) = self.pools.pool_of_type[ttype.0 as usize] {
            let tenant = k.tenant_of(task);
            self.pools.publish(k, pool, task, tenant);
        }
    }
}

/// Lifecycle hooks the kernel event loop dispatches into. One module per
/// execution model implements this trait; the default bodies encode the
/// shared semantics over [`StrategyState`], so a model only overrides
/// what it actually changes (its name, its construction, its recovery
/// default).
///
/// Scope: these hooks are the **kernel -> strategy** boundary — they fire
/// once per calendar event. Strategy-internal chains (e.g. readiness
/// propagation inside `task_done`, instance admission) call the
/// [`StrategyState`] mechanics directly, so a model that wants to change
/// *routing itself* should do it in its pool tables / batcher
/// configuration (the single routing point is
/// [`StrategyState::dispatch_ready`]), not by overriding `on_ready`
/// alone.
pub trait ExecStrategy {
    /// Model name as reported in results (matches [`ExecModel::name`]).
    fn name(&self) -> &'static str;
    fn state(&mut self) -> &mut StrategyState;
    fn state_ref(&self) -> &StrategyState;
    /// The recovery policy used when the chaos spec does not pin one.
    fn default_recovery(&self) -> RecoveryPolicy;

    /// Newly-ready tasks (readiness propagation, instance admission, the
    /// t=0 roots).
    fn on_ready(&mut self, k: &mut Kernel, ready: &[TaskId]) {
        self.state().dispatch_ready(k, ready);
    }
    /// A pod's container started.
    fn on_pod_started(&mut self, k: &mut Kernel, pod: PodId) {
        self.state().pod_started(k, pod);
    }
    /// A running worker holds no task (just started, or completed one):
    /// fetch the next message or park it idle.
    fn on_pod_idle(&mut self, k: &mut Kernel, pod: PodId, pool: crate::broker::PoolId) {
        let st = self.state();
        st.pools.fetch_or_idle(k, pod, pool);
    }
    /// A worker's queue fetch completed.
    fn on_worker_fetched(&mut self, k: &mut Kernel, pod: PodId, task: TaskId) {
        self.state().worker_fetched(k, pod, task);
    }
    /// A task's compute finished.
    fn on_task_done(&mut self, k: &mut Kernel, pod: PodId, task: TaskId) {
        self.state().task_done(k, pod, task);
    }
    /// A clustering flush timer fired.
    fn on_flush_timer(&mut self, k: &mut Kernel, type_idx: u16, deadline: SimTime) {
        self.state().jobs.flush_timer(k, type_idx, deadline);
    }
    /// Autoscaler poll.
    fn on_scale(&mut self, k: &mut Kernel) {
        self.state().autoscale(k);
    }
    /// A failed pool task's retry back-off expired.
    fn on_retry_task(&mut self, k: &mut Kernel, task: TaskId) {
        self.state().retry_task(k, task);
    }
    /// A failed job batch's retry back-off expired.
    fn on_retry_batch(&mut self, k: &mut Kernel, tasks: Vec<TaskId>) {
        self.state().jobs.create_job(k, tasks);
    }
    /// Straggler watch fired.
    fn on_speculate(&mut self, k: &mut Kernel, pod: PodId, task: TaskId) {
        self.state().speculate(k, pod, task);
    }
    /// A node went down (scheduled event or chaos fault); recover every
    /// pod that was on it.
    fn on_node_down(&mut self, k: &mut Kernel, node: usize, chaos: bool) {
        self.state().fail_node_inner(k, node, chaos);
    }
    /// A timed chaos injector struck.
    fn on_fault(&mut self, k: &mut Kernel, proc_idx: usize, node: usize) {
        self.state().apply_fault(k, proc_idx, node);
    }
    /// Capacity or cordon state changed: give waiting pods another pass.
    fn on_capacity_changed(&mut self, k: &mut Kernel) {
        self.state().run_scheduler(k);
    }
}

/// Enum-backed strategy dispatch: static, allocation-free, and the single
/// place the execution layer matches on [`ExecModel`].
pub enum Strategy {
    Job(JobStrategy),
    Clustered(ClusteredStrategy),
    Pools(PoolsStrategy),
    Generic(GenericStrategy),
}

impl Strategy {
    /// Instantiate the strategy for a model: declare its pools, configure
    /// its batcher, and register its per-pool gauges.
    pub fn build(
        model: &ExecModel,
        engine: &Engine,
        cfg: &SimConfig,
        metrics: &mut Registry,
    ) -> Strategy {
        match model {
            ExecModel::JobBased => Strategy::Job(JobStrategy::build(engine)),
            ExecModel::Clustered(c) => {
                Strategy::Clustered(ClusteredStrategy::build(c.clone(), engine))
            }
            ExecModel::WorkerPools { pooled_types } => {
                Strategy::Pools(PoolsStrategy::build(pooled_types, engine, cfg, metrics))
            }
            ExecModel::GenericPool => Strategy::Generic(GenericStrategy::build(engine, cfg, metrics)),
        }
    }
}

macro_rules! delegate {
    ($self:ident, $inner:ident => $body:expr) => {
        match $self {
            Strategy::Job($inner) => $body,
            Strategy::Clustered($inner) => $body,
            Strategy::Pools($inner) => $body,
            Strategy::Generic($inner) => $body,
        }
    };
}

impl ExecStrategy for Strategy {
    fn name(&self) -> &'static str {
        delegate!(self, s => s.name())
    }
    fn state(&mut self) -> &mut StrategyState {
        delegate!(self, s => s.state())
    }
    fn state_ref(&self) -> &StrategyState {
        delegate!(self, s => s.state_ref())
    }
    fn default_recovery(&self) -> RecoveryPolicy {
        delegate!(self, s => s.default_recovery())
    }
    fn on_ready(&mut self, k: &mut Kernel, ready: &[TaskId]) {
        delegate!(self, s => s.on_ready(k, ready))
    }
    fn on_pod_started(&mut self, k: &mut Kernel, pod: PodId) {
        delegate!(self, s => s.on_pod_started(k, pod))
    }
    fn on_pod_idle(&mut self, k: &mut Kernel, pod: PodId, pool: crate::broker::PoolId) {
        delegate!(self, s => s.on_pod_idle(k, pod, pool))
    }
    fn on_worker_fetched(&mut self, k: &mut Kernel, pod: PodId, task: TaskId) {
        delegate!(self, s => s.on_worker_fetched(k, pod, task))
    }
    fn on_task_done(&mut self, k: &mut Kernel, pod: PodId, task: TaskId) {
        delegate!(self, s => s.on_task_done(k, pod, task))
    }
    fn on_flush_timer(&mut self, k: &mut Kernel, type_idx: u16, deadline: SimTime) {
        delegate!(self, s => s.on_flush_timer(k, type_idx, deadline))
    }
    fn on_scale(&mut self, k: &mut Kernel) {
        delegate!(self, s => s.on_scale(k))
    }
    fn on_retry_task(&mut self, k: &mut Kernel, task: TaskId) {
        delegate!(self, s => s.on_retry_task(k, task))
    }
    fn on_retry_batch(&mut self, k: &mut Kernel, tasks: Vec<TaskId>) {
        delegate!(self, s => s.on_retry_batch(k, tasks))
    }
    fn on_speculate(&mut self, k: &mut Kernel, pod: PodId, task: TaskId) {
        delegate!(self, s => s.on_speculate(k, pod, task))
    }
    fn on_node_down(&mut self, k: &mut Kernel, node: usize, chaos: bool) {
        delegate!(self, s => s.on_node_down(k, node, chaos))
    }
    fn on_fault(&mut self, k: &mut Kernel, proc_idx: usize, node: usize) {
        delegate!(self, s => s.on_fault(k, proc_idx, node))
    }
    fn on_capacity_changed(&mut self, k: &mut Kernel) {
        delegate!(self, s => s.on_capacity_changed(k))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn model_names() {
        assert_eq!(ExecModel::JobBased.name(), "job-based");
        assert_eq!(
            ExecModel::Clustered(ClusteringConfig::paper_default()).name(),
            "job-clustered"
        );
        assert_eq!(ExecModel::paper_hybrid_pools().name(), "worker-pools");
        assert_eq!(ExecModel::GenericPool.name(), "generic-pool");
    }

    #[test]
    fn validate_rejects_empty_and_duplicate_pool_sets() {
        assert_eq!(
            ExecModel::WorkerPools {
                pooled_types: vec![]
            }
            .validate(),
            Err(ConfigError::EmptyPoolSet)
        );
        assert_eq!(
            ExecModel::WorkerPools {
                pooled_types: vec!["a".into(), "a".into()]
            }
            .validate(),
            Err(ConfigError::DuplicatePooledType("a".into()))
        );
        assert!(ExecModel::paper_hybrid_pools().validate().is_ok());
        assert!(ExecModel::JobBased.validate().is_ok());
    }

    #[test]
    fn validate_rejects_zero_cluster_size() {
        let mut c = ClusteringConfig::paper_default();
        c.rules[0].size = 0;
        assert_eq!(
            ExecModel::Clustered(c).validate(),
            Err(ConfigError::ZeroClusterSize)
        );
    }

    #[test]
    fn strategy_recovery_defaults_differ_on_speculation_only() {
        use crate::workflow::montage::{generate, MontageConfig};
        let dag = generate(&MontageConfig {
            grid_w: 3,
            grid_h: 3,
            diagonals: true,
            seed: 1,
        });
        let cfg = SimConfig::with_nodes(3);
        let mut metrics = Registry::new();
        let (engine, _) = Engine::new(dag);
        let job = Strategy::build(&ExecModel::JobBased, &engine, &cfg, &mut metrics);
        let pools = Strategy::build(
            &ExecModel::paper_hybrid_pools(),
            &engine,
            &cfg,
            &mut metrics,
        );
        let generic = Strategy::build(&ExecModel::GenericPool, &engine, &cfg, &mut metrics);
        assert!(!job.default_recovery().speculative);
        assert!(pools.default_recovery().speculative);
        assert!(generic.default_recovery().speculative);
        assert_eq!(
            job.default_recovery().retry_initial_ms,
            pools.default_recovery().retry_initial_ms
        );
        assert_eq!(
            job.default_recovery().checkpoint_frac,
            pools.default_recovery().checkpoint_frac
        );
        assert!(job.default_recovery().blacklist_after > 0);
    }
}
