//! **Subsystem hooks**: how chaos, the data plane and the fleet service
//! attach to kernel events — instead of being inlined branches of the
//! event loop.
//!
//! Each subsystem follows the same pattern: an `Option<State>` slot on
//! the [`Kernel`] (`None` = subsystem off, zero events scheduled,
//! bit-identical to a build without it), plus a set of attachment points
//! implemented here:
//!
//! * **chaos** ([`ChaosRuntime`]) — fault *injection* rides dedicated
//!   calendar events (`ChaosFault` / `ChaosReclaim` / `ChaosRestore` /
//!   `ChaosUncordon`); fault *recovery* re-enters work through the
//!   strategy's `on_retry_task` / `on_retry_batch` hooks after a policy
//!   back-off. The kill paths ([`StrategyState::fail_node_inner`],
//!   [`StrategyState::spot_warning`], [`StrategyState::pod_start_failure`])
//!   charge wasted work and route every orphaned payload to its
//!   strategy-owned recovery. Tenant takeovers (`ChaosTakeover`) measure
//!   the compromised tenant's blast radius against the isolation model
//!   ([`StrategyState::apply_takeover`]) and remediate by cordon-and-drain
//!   ([`StrategyState::drain_node`]) or contained pod kills.
//! * **data plane** — every task expands into a stage-in -> compute ->
//!   stage-out cycle ([`StrategyState::begin_task`] /
//!   [`StrategyState::finish_task`]); transfer completions arrive as
//!   `FlowDone` / `FlowActivate` events and readiness propagation is
//!   gated on the write-through stage-out.
//! * **fleet** ([`FleetState`]) — open-loop `InstanceArrive` events feed
//!   admission control; instance roots dispatch through the shared
//!   [`StrategyState::dispatch_ready`] routing at admission, and per-task
//!   completion releases admission slots.
//!
//! Note on layering: the `on_*` trait hooks are the *kernel-event*
//! surface. Work that becomes ready *inside* a strategy operation
//! (readiness propagation after a completion, fleet admission, retries)
//! routes through [`StrategyState::dispatch_ready`] directly — it is the
//! single routing point either way.

use crate::chaos::inject::FaultProcess;
use crate::chaos::{ChaosConfig, Injector, RecoveryPolicy};
use crate::data::StageStart;
use crate::engine::TaskState;
use crate::exec::kernel::{Ev, IoPhase, Kernel};
use crate::exec::strategy::{PodWork, StrategyState};
use crate::k8s::pod::{Payload, PodId, PodPhase};
use crate::obs::Actor;
use crate::sim::SimTime;
use crate::util::rng::Rng;
use crate::workflow::task::TaskId;
use std::collections::VecDeque;

/// Runtime state of the chaos engine for one run (`None` on the kernel =
/// disabled: no chaos events are ever scheduled and the hot path is
/// untouched).
pub struct ChaosRuntime {
    /// Timed injectors (spot reclaim, node crash), each with its own
    /// forked RNG stream.
    pub processes: Vec<FaultProcess>,
    /// Combined per-start crash probability over all PodFailure injectors
    /// (includes the migrated legacy `pod_failure_prob`).
    pub pod_fail_prob: f64,
    /// Stream for pod-start crash sampling.
    pub pod_rng: Rng,
    /// Stream for straggler (re)sampling on node replacement.
    pub node_rng: Rng,
    /// Straggler injector params: (fraction of slow nodes, slow factor).
    pub straggler: Option<(f64, f64)>,
    /// Recovery policy in force (explicit or the strategy's default).
    pub policy: RecoveryPolicy,
    /// Quota the autoscaler was configured with at build (re-scaled to
    /// surviving capacity on node churn).
    pub base_quota: u64,
}

impl ChaosRuntime {
    /// Build the runtime from a config, folding the deprecated
    /// `pod_failure_prob` knob in as one more PodFailure injector.
    /// `default_policy` is the strategy's recovery default, used when the
    /// spec does not pin a policy. Returns `None` when no fault source is
    /// configured.
    pub fn build(
        cfg: &ChaosConfig,
        legacy_pod_failure_prob: f64,
        default_policy: RecoveryPolicy,
        seed: u64,
        base_quota: u64,
    ) -> Option<ChaosRuntime> {
        let mut spec = cfg.clone();
        if legacy_pod_failure_prob > 0.0 {
            log::warn!(
                "sim.pod_failure_prob is deprecated: folding it into the chaos \
                 subsystem as a PodFailure injector (use chaos spec 'pod:{legacy_pod_failure_prob}')"
            );
            spec.injectors.push(Injector::PodFailure {
                prob: legacy_pod_failure_prob,
            });
        }
        if !spec.is_enabled() {
            return None;
        }
        let policy = spec.recovery.clone().unwrap_or(default_policy);
        // Fixed fork order => the fault timeline is a pure function of
        // (seed, chaos spec), independent of everything else in the run.
        // The pod-failure stream keeps the legacy `seed ^ 0xFA11` seeding
        // of the old inline pod_failure_prob branch, so configs that only
        // set the deprecated knob reproduce their historical failure
        // pattern (one draw per pod start, same order until the first
        // fault diverges the timeline).
        let mut master = Rng::new(seed ^ 0xC4A0_5EED);
        let pod_rng = Rng::new(seed ^ 0xFA11);
        let node_rng = master.fork(2);
        let processes: Vec<FaultProcess> = spec
            .injectors
            .iter()
            .filter(|i| i.is_timed())
            .enumerate()
            .map(|(k, i)| FaultProcess::new(i.clone(), master.fork(16 + k as u64)))
            .collect();
        assert!(processes.len() <= u8::MAX as usize, "too many timed injectors");
        Some(ChaosRuntime {
            processes,
            pod_fail_prob: spec.pod_failure_prob(),
            pod_rng,
            node_rng,
            straggler: spec.straggler(),
            policy,
            base_quota,
        })
    }
}

/// Runtime state of a fleet run: per-instance admission and completion
/// tracking over the disjoint-union task space.
pub struct FleetState {
    /// Unfinished task count per instance; 0 = the instance completed.
    pub outstanding: Vec<u32>,
    /// Each instance's initially-ready tasks, dispatched at admission
    /// (taken out once — an instance is admitted exactly once).
    pub roots: Vec<Vec<TaskId>>,
    pub admitted_at: Vec<Option<SimTime>>,
    pub finished_at: Vec<Option<SimTime>>,
    /// Arrived instances waiting for an admission slot (FIFO).
    pub waiting: VecDeque<u32>,
    /// Instances admitted but not yet finished.
    pub in_flight: usize,
    /// Admission-control cap on concurrently running instances.
    pub max_in_flight: Option<usize>,
}

impl FleetState {
    /// An instance arrived (open-loop): `true` if a slot is free and it
    /// should be admitted now; otherwise it joins the FIFO queue.
    pub fn try_admit(&mut self, inst: usize) -> bool {
        match self.max_in_flight {
            Some(cap) if self.in_flight >= cap => {
                self.waiting.push_back(inst as u32);
                false
            }
            _ => true,
        }
    }

    /// Admit an instance: stamp it and hand back its root tasks for the
    /// strategy to dispatch.
    pub fn admit(&mut self, inst: usize, now: SimTime) -> Vec<TaskId> {
        self.in_flight += 1;
        debug_assert!(self.admitted_at[inst].is_none(), "double admission");
        self.admitted_at[inst] = Some(now);
        std::mem::take(&mut self.roots[inst])
    }

    /// A task of `inst` completed. Returns `None` while the instance is
    /// still running; on instance completion, returns the next waiting
    /// instance (if any) whose admission slot just freed.
    pub fn task_done(&mut self, inst: usize, now: SimTime) -> Option<Option<u32>> {
        debug_assert!(self.outstanding[inst] > 0);
        self.outstanding[inst] -= 1;
        if self.outstanding[inst] > 0 {
            return None;
        }
        self.finished_at[inst] = Some(now);
        self.in_flight -= 1;
        Some(self.waiting.pop_front())
    }
}

// ---------------------------------------------------------------
// data plane: the stage-in -> compute -> stage-out task cycle
// ---------------------------------------------------------------
impl StrategyState {
    /// Hand `task` to `pod`: with the data plane on, stage its inputs
    /// first (execution starts when the transfer completes); without it,
    /// execution starts immediately — the exact pre-data path.
    pub fn begin_task(&mut self, k: &mut Kernel, pod: PodId, task: TaskId) {
        let now = k.now();
        if let Some(o) = k.obs.as_mut() {
            o.dispatch(pod, task, now);
        }
        if k.data.is_none() {
            k.start_task(pod, task);
            return;
        }
        let node = k.pods.node[pod.0 as usize].expect("running pod is bound").0;
        let tenant = k.tenant_of(task).idx();
        k.current_task[pod.0 as usize] = Some(task);
        k.pod_io[pod.0 as usize] = IoPhase::StageIn;
        let mut buf = std::mem::take(&mut k.flow_buf);
        let start = k
            .data
            .as_mut()
            .expect("data plane")
            .begin_stage_in(now, pod, node, task, tenant, &mut buf);
        k.schedule_flow_events(buf);
        if start == StageStart::Ready {
            // every input byte is already node-local (warm cache)
            k.start_task(pod, task);
        }
    }

    /// The task's compute finished: write its output back to the backend.
    /// Successors become ready only when the write lands (write-through
    /// shared storage, like the paper's NFS volume).
    pub fn begin_stage_out_for(&mut self, k: &mut Kernel, pod: PodId, task: TaskId) {
        let now = k.now();
        let node = k.pods.node[pod.0 as usize].expect("running pod is bound").0;
        let tenant = k.tenant_of(task).idx();
        k.pod_io[pod.0 as usize] = IoPhase::StageOut;
        k.task_out_pending[task.0 as usize] = true;
        let mut buf = std::mem::take(&mut k.flow_buf);
        let start = k
            .data
            .as_mut()
            .expect("data plane")
            .begin_stage_out(now, pod, node, task, tenant, &mut buf);
        k.schedule_flow_events(buf);
        if start == StageStart::Ready {
            self.finish_task(k, pod, task);
        }
    }

    /// Stage-out landed (or the task had no output bytes): the task's
    /// completion becomes visible — trace it, propagate readiness, and
    /// advance the pod to its next unit of work. Data-plane runs only.
    pub fn finish_task(&mut self, k: &mut Kernel, pod: PodId, task: TaskId) {
        let now = k.now();
        k.current_task[pod.0 as usize] = None;
        k.pod_io[pod.0 as usize] = IoPhase::Idle;
        k.task_out_pending[task.0 as usize] = false;
        // a speculative twin cannot have completed it (the loser is caught
        // at TaskDone), but guard anyway: completing twice would corrupt
        // the engine's outstanding count
        if k.engine.state(task) != TaskState::Done {
            // success accounting deferred from TaskDone: only an execution
            // whose output landed counts as useful/completed
            let ttype = k.engine.dag().tasks[task.0 as usize].ttype;
            let exec_ms = k.pod_exec_ms[pod.0 as usize];
            k.completed_by_type[ttype.0 as usize] += 1;
            if k.chaos.is_some() {
                k.chaos_stats.useful_ms += exec_ms;
            }
            k.data.as_mut().expect("data plane").stats.compute_ms += exec_ms;
            k.trace.finished(task, now);
            if let Some(o) = k.obs.as_mut() {
                o.finished(task, now);
            }
            let mut ready = std::mem::take(&mut k.ready_buf);
            ready.clear();
            k.engine.complete_into(task, &mut ready);
            self.dispatch_ready(k, &ready);
            k.ready_buf = ready;
            if k.fleet.is_some() {
                self.instance_task_done(k, task);
            }
        }
        match k.pods.pool_id(pod.0 as usize) {
            None => {
                k.batch_queue[pod.0 as usize].pop_front();
                if let Some(&next) = k.batch_queue[pod.0 as usize].front() {
                    self.begin_task(k, pod, next);
                } else {
                    self.terminate_pod(k, pod, PodPhase::Succeeded);
                }
            }
            Some(pool) => self.advance_worker(k, pod, pool),
        }
    }

    /// A transfer's completion check fired: let the data plane resolve it
    /// (stale generations drop out), then resume the owning pod's cycle.
    pub fn flow_done(&mut self, k: &mut Kernel, flow: u32, gen: u32) {
        let now = k.now();
        let mut buf = std::mem::take(&mut k.flow_buf);
        let done = k
            .data
            .as_mut()
            .and_then(|dp| dp.flow_done(now, flow, gen, &mut buf));
        k.schedule_flow_events(buf);
        let Some(d) = done else { return };
        if let Some(o) = k.obs.as_mut() {
            // achieved bandwidth over the whole transfer, Gbit/s
            let gbps = if d.dur > SimTime::ZERO {
                d.bytes as f64 * 8.0 / 1e9 / d.dur.as_secs_f64()
            } else {
                0.0
            };
            o.event(
                now,
                Actor::Data,
                if d.inbound { "stage_in_done" } else { "stage_out_done" },
                format!("pod {} task {}", d.pod.0, d.task.0),
                gbps,
            );
        }
        // a completing flow implies a live pod (kills cancel their flows
        // synchronously) — but stay defensive
        if k.pods.is_terminal(d.pod.0 as usize)
            || k.current_task[d.pod.0 as usize] != Some(d.task)
        {
            return;
        }
        if d.inbound {
            k.start_task(d.pod, d.task);
        } else {
            self.finish_task(k, d.pod, d.task);
        }
    }
}

// ---------------------------------------------------------------
// chaos engine: fault application and payload recovery
// ---------------------------------------------------------------
impl StrategyState {
    /// A timed fault strikes `node`.
    pub fn apply_fault(&mut self, k: &mut Kernel, proc_idx: usize, node: usize) {
        let injector = match &k.chaos {
            Some(ch) => ch.processes[proc_idx].injector.clone(),
            None => return,
        };
        match injector {
            Injector::SpotReclaim {
                warning_ms,
                replace_ms,
                ..
            } => self.spot_warning(k, node, warning_ms, replace_ms),
            Injector::NodeCrash { repair_ms, .. } => {
                if k.nodes[node].failed {
                    return; // already down
                }
                k.chaos_stats.node_crashes += 1;
                k.metrics.inc_id(k.c.node_crashes, 1);
                if let Some(o) = k.obs.as_mut() {
                    let now = k.q.now();
                    o.event(
                        now,
                        Actor::Chaos,
                        "node_crash",
                        format!("node {node}"),
                        repair_ms as f64 / 1000.0,
                    );
                }
                self.fail_node_inner(k, node, true);
                k.q
                    .schedule_in(SimTime::from_millis(repair_ms), Ev::ChaosRestore { node });
            }
            _ => unreachable!("only timed injectors emit ChaosFault"),
        }
    }

    /// Spot reclaim, phase 1: the provider's warning. See
    /// [`StrategyState::drain_node`] for the shared cordon-and-drain
    /// mechanics; this wrapper only owns the spot-reclaim counters.
    pub fn spot_warning(&mut self, k: &mut Kernel, node: usize, warning_ms: u64, replace_ms: u64) {
        if self.drain_node(k, node, warning_ms, replace_ms) {
            k.chaos_stats.spot_warnings += 1;
            k.metrics.inc_id(k.c.spot_warnings, 1);
            if let Some(o) = k.obs.as_mut() {
                let now = k.q.now();
                o.event(
                    now,
                    Actor::Chaos,
                    "spot_warning",
                    format!("node {node}"),
                    warning_ms as f64 / 1000.0,
                );
            }
        }
    }

    /// Cordon-and-drain a node ahead of losing it: no new placements, and
    /// — under a graceful policy — its workers drain: idle workers
    /// terminate immediately (the autoscaler replaces them on surviving
    /// nodes), busy workers finish their current task and exit. Job pods
    /// run on; whatever is still alive when the warning expires dies with
    /// the node (`ChaosReclaim`), and replacement capacity arrives
    /// `replace_ms` later. Shared by the spot-reclaim warning and the
    /// takeover blast-radius remediation. Returns `false` when the node
    /// is already dying (no drain started).
    pub fn drain_node(&mut self, k: &mut Kernel, node: usize, warning_ms: u64, replace_ms: u64) -> bool {
        if k.nodes[node].failed || k.drain_pending[node] {
            return false; // already dying
        }
        k.drain_pending[node] = true;
        k.nodes[node].cordoned = true;
        let drain = k
            .chaos
            .as_ref()
            .map(|c| c.policy.drain_on_warning)
            .unwrap_or(false);
        if drain {
            let victims = k.take_node_victims(node, true);
            for &pid in &victims {
                match k.pods.phase[pid.0 as usize] {
                    PodPhase::Running if k.current_task[pid.0 as usize].is_none() => {
                        // idle worker: release it now so the deployment
                        // re-creates it on a surviving node
                        self.terminate_pod(k, pid, PodPhase::Succeeded);
                    }
                    PodPhase::Running => {
                        k.pods.phase[pid.0 as usize] = PodPhase::Draining;
                    }
                    // Starting workers are abandoned before doing work
                    PodPhase::Starting => self.terminate_pod(k, pid, PodPhase::Deleted),
                    _ => {}
                }
            }
            k.put_members_buf(victims);
        }
        k.q.schedule_in(
            SimTime::from_millis(warning_ms),
            Ev::ChaosReclaim { node, replace_ms },
        );
        true
    }

    /// A tenant is compromised (`takeover:<tenant>@<t>` injector): compute
    /// the blast radius its privilege level can reach, record the exposure
    /// every innocent tenant suffered on those nodes, then remediate.
    /// Escaping policies (shared/dedicated) cordon-and-drain every
    /// reachable node — innocent work drains, lingering pods die with the
    /// node and the capacity returns after a re-image. The sandboxed
    /// policy contains the escape, so only the victim's own pods are
    /// killed and recovered through the normal retry machinery.
    pub fn apply_takeover(&mut self, k: &mut Kernel, tenant: u16) {
        use crate::chaos::takeover::{
            compute_blast_radius, PrivilegeModel, TAKEOVER_DRAIN_MS, TAKEOVER_REIMAGE_MS,
        };
        let Some(mut iso) = k.isolation.take() else {
            return; // takeover without an isolation model: nothing to measure
        };
        let now = k.now();
        let privilege = PrivilegeModel::for_policy(iso.cfg.policy);
        let br = {
            let current_task = &k.current_task;
            let task_tenant = &k.task_tenant;
            let pods = &k.pods;
            let eff = |i: usize| {
                let tt =
                    current_task[i].map(|t| task_tenant.get(t.0 as usize).copied().unwrap_or(0));
                iso.effective_tenant(PodId(i as u64), &pods.payload[i], tt)
            };
            compute_blast_radius(
                tenant,
                &privilege,
                pods,
                k.nodes.len(),
                |n| k.nodes[n.0].failed,
                eff,
                k.data.is_some(),
            )
        };
        iso.stats.takeovers += 1;
        iso.stats.blast_nodes_total += br.nodes.len() as u64;
        iso.stats.blast_pods_total += br.pods;
        iso.stats.blast_innocent_pods_total += br.innocent_pods;
        iso.stats.blast_storage_surfaces_total += br.storage_surfaces;
        // innocent SLO impact: compute time innocent tenants had in flight
        // on blast nodes at takeover time (it drains or dies below)
        for &nid in &br.nodes {
            for i in 0..k.pods.len() {
                if k.pods.node[i] != Some(nid) || k.pods.is_terminal(i) {
                    continue;
                }
                if let Some(t) = k.current_task[i] {
                    let tt = k.task_tenant.get(t.0 as usize).copied().unwrap_or(0);
                    if tt != tenant {
                        let exposed = now.saturating_sub(k.pod_task_started_at[i]).as_millis();
                        iso.stats.add_exposure(tt, exposed);
                    }
                }
            }
        }
        let can_reach_node = privilege.can_reach_node;
        // restore before remediation: drain/kill paths re-enter the
        // scheduler and release_pod, which charge and refund the quota
        k.isolation = Some(iso);
        k.metrics.inc_id(k.c.tenant_takeovers, 1);
        if let Some(o) = k.obs.as_mut() {
            o.event(
                now,
                Actor::Chaos,
                "takeover",
                format!(
                    "tenant {tenant}: {} nodes, {} pods in blast radius",
                    br.nodes.len(),
                    br.pods
                ),
                br.nodes.len() as f64,
            );
        }
        if can_reach_node {
            for &nid in &br.nodes {
                self.drain_node(k, nid.0, TAKEOVER_DRAIN_MS, TAKEOVER_REIMAGE_MS);
            }
        } else {
            // contained: kill only the compromised tenant's own pods
            let victims: Vec<PodId> = (0..k.pods.len())
                .filter(|&i| !k.pods.is_terminal(i))
                .filter(|&i| {
                    let tt = k.current_task[i]
                        .map(|t| k.task_tenant.get(t.0 as usize).copied().unwrap_or(0));
                    k.isolation
                        .as_ref()
                        .and_then(|is| is.effective_tenant(PodId(i as u64), &k.pods.payload[i], tt))
                        == Some(tenant)
                })
                .map(|i| PodId(i as u64))
                .collect();
            for pid in victims {
                self.takeover_kill_pod(k, pid);
            }
        }
    }

    /// Kill a single pod during takeover remediation, recovering its
    /// payload through the chaos machinery (waste accounting, retry
    /// back-off) — the per-pod slice of [`StrategyState::fail_node_inner`]
    /// without the node going down.
    fn takeover_kill_pod(&mut self, k: &mut Kernel, pid: PodId) {
        if k.pods.is_terminal(pid.0 as usize) {
            return;
        }
        if let Some(o) = k.obs.as_mut() {
            let now = k.q.now();
            o.attempt_lost(pid, now);
        }
        let node = k.pods.node[pid.0 as usize];
        let in_flight = k.current_task[pid.0 as usize].take();
        let phase = k.pod_io[pid.0 as usize];
        if let Some(task) = in_flight {
            if phase != IoPhase::Compute {
                if phase == IoPhase::StageOut {
                    k.task_out_pending[task.0 as usize] = false;
                    let wasted = k.run_exec_ms(pid);
                    k.chaos_stats.add_waste(k.tenant_of(task).idx(), wasted);
                    k.fault_stamp(task);
                }
            } else {
                let ttype = k.engine.dag().tasks[task.0 as usize].ttype;
                k.record_running(ttype, -1);
                k.task_running[task.0 as usize] -= 1;
                if k.engine.state(task) == TaskState::Done {
                    let exec_ms = k.run_exec_ms(pid);
                    k.chaos_stats.add_waste(k.tenant_of(task).idx(), exec_ms);
                    k.metrics.inc_id(k.c.speculative_losses, 1);
                } else if let Some(n) = node {
                    k.account_lost_work(pid, task, n.0);
                }
            }
        }
        let work = match &k.pods.payload[pid.0 as usize] {
            Payload::JobBatch { tasks } => {
                let remaining: Vec<TaskId> = if k.batch_queue[pid.0 as usize].is_empty() {
                    tasks.clone()
                } else {
                    k.batch_queue[pid.0 as usize].iter().copied().collect()
                };
                PodWork::Batch(remaining)
            }
            Payload::Worker { pool } => PodWork::Pool(*pool),
        };
        self.terminate_pod(k, pid, PodPhase::Deleted);
        match work {
            PodWork::Batch(remaining) => {
                if !remaining.is_empty() {
                    k.schedule_batch_retry(remaining);
                }
            }
            PodWork::Pool(pool) => {
                if let Some(task) = in_flight {
                    self.pools.broker.nack_drop(pool);
                    self.pools.record_queue_depth(k, pool);
                    if k.engine.state(task) != TaskState::Done {
                        k.schedule_task_retry(task);
                    }
                }
            }
        }
    }

    /// Node failure: kill every pod on the node; recover their work.
    /// Job batches are recreated by the job controller; a worker's
    /// in-flight task is redelivered to its queue (the broker's unacked
    /// window, like a RabbitMQ consumer dying).
    ///
    /// Shared kill path for scheduled `node_events` (`chaos = false`:
    /// instant redelivery, the pre-chaos semantics) and the chaos engine
    /// (`chaos = true`: wasted-work accounting, checkpoint-restart credit,
    /// and policy-driven retry back-off instead of instant redelivery).
    pub fn fail_node_inner(&mut self, k: &mut Kernel, node: usize, chaos: bool) {
        k.nodes[node].failed = true;
        k.metrics.inc_id(k.c.node_failures, 1);
        let victims = k.take_node_victims(node, false);
        if let Some(o) = k.obs.as_mut() {
            let now = k.q.now();
            o.event(
                now,
                Actor::Chaos,
                "node_down",
                format!("node {node}"),
                victims.len() as f64,
            );
        }
        for &pid in &victims {
            // every attempt on the node dies with it: its compute so far
            // is recovery waste on the owning task's span
            if let Some(o) = k.obs.as_mut() {
                let now = k.q.now();
                o.attempt_lost(pid, now);
            }
            // roll back the running-task accounting for the in-flight task
            let in_flight = k.current_task[pid.0 as usize].take();
            let phase = k.pod_io[pid.0 as usize];
            if let Some(task) = in_flight {
                if phase != IoPhase::Compute {
                    // killed while staging data: nothing executed yet
                    // (stage-in) or the output write was lost (stage-out —
                    // the task must re-run, its completion never became
                    // visible). The requeue below handles both; only the
                    // running-task accounting is skipped.
                    if phase == IoPhase::StageOut {
                        k.task_out_pending[task.0 as usize] = false;
                        if chaos {
                            // the finished execution died with its output:
                            // its compute (plus the partial write) never
                            // counted as useful — charge it as waste and
                            // stamp the fault for recovery latency
                            let wasted = k.run_exec_ms(pid);
                            k.chaos_stats
                                .add_waste(k.tenant_of(task).idx(), wasted);
                            k.fault_stamp(task);
                        }
                    }
                } else {
                    let ttype = k.engine.dag().tasks[task.0 as usize].ttype;
                    k.record_running(ttype, -1);
                    k.task_running[task.0 as usize] -= 1;
                    if chaos {
                        if k.engine.state(task) == TaskState::Done {
                            // losing speculative copy killed after its twin
                            // already won: the whole run is waste, there is
                            // nothing to checkpoint or recover
                            let exec_ms = k.run_exec_ms(pid);
                            k.chaos_stats
                                .add_waste(k.tenant_of(task).idx(), exec_ms);
                            k.metrics.inc_id(k.c.speculative_losses, 1);
                        } else {
                            k.account_lost_work(pid, task, node);
                        }
                    }
                }
            }
            let work = match &k.pods.payload[pid.0 as usize] {
                Payload::JobBatch { tasks } => {
                    // job controller recreates the pod with the unfinished
                    // remainder of the batch (current task included)
                    let remaining: Vec<TaskId> = if k.batch_queue[pid.0 as usize].is_empty() {
                        tasks.clone() // killed while Pending/Starting
                    } else {
                        k.batch_queue[pid.0 as usize].iter().copied().collect()
                    };
                    PodWork::Batch(remaining)
                }
                Payload::Worker { pool } => PodWork::Pool(*pool),
            };
            self.terminate_pod(k, pid, PodPhase::Deleted);
            match work {
                PodWork::Batch(remaining) => {
                    if !remaining.is_empty() {
                        if chaos {
                            k.schedule_batch_retry(remaining);
                        } else {
                            self.jobs.create_job(k, remaining);
                        }
                    }
                }
                PodWork::Pool(pool) => {
                    if let Some(task) = in_flight {
                        if chaos {
                            // the recovery policy owns the message now: it
                            // re-enters the queue after its retry back-off
                            // (unless the task already completed elsewhere)
                            self.pools.broker.nack_drop(pool);
                            self.pools.record_queue_depth(k, pool);
                            if k.engine.state(task) != TaskState::Done {
                                k.schedule_task_retry(task);
                            }
                        } else {
                            // the unacked delivery is redelivered at once
                            self.pools
                                .broker
                                .nack_requeue(pool, task, k.tenant_of(task));
                            self.pools.wake_idle_worker(k, pool);
                        }
                    }
                }
            }
        }
        k.put_members_buf(victims);
        if chaos {
            self.pools.update_chaos_quota(k);
        }
    }

    /// A pod crashed at container start (PodFailure injector, successor of
    /// the legacy inline `pod_failure_prob` branch): the startup time is
    /// wasted, the node collects blacklisting evidence, and the payload is
    /// recovered by policy — batches after a retry back-off, workers by
    /// the deployment controller on the next autoscale tick.
    pub fn pod_start_failure(&mut self, k: &mut Kernel, pod: PodId) {
        k.metrics.inc_id(k.c.pod_failures, 1);
        k.chaos_stats.pod_failures += 1;
        if let Some(o) = k.obs.as_mut() {
            let now = k.q.now();
            o.event(
                now,
                Actor::Chaos,
                "pod_start_failure",
                format!("pod {}", pod.0),
                0.0,
            );
        }
        // the container-start latency was burned for nothing; a batch pod
        // charges its owning tenant, a shared pool worker charges no lane
        // (it serves every tenant)
        match &k.pods.payload[pod.0 as usize] {
            Payload::JobBatch { tasks } => {
                let tenant = k.tenant_of(tasks[0]).idx();
                k.chaos_stats.add_waste(tenant, k.cfg.pod_start_ms);
            }
            Payload::Worker { .. } => {
                k.chaos_stats.add_waste_shared(k.cfg.pod_start_ms);
            }
        }
        if let Some(nid) = k.pods.node[pod.0 as usize] {
            k.note_node_fault(nid.0);
        }
        let retry = match &mut k.pods.payload[pod.0 as usize] {
            Payload::JobBatch { tasks } => Some(std::mem::take(tasks)),
            Payload::Worker { .. } => None,
        };
        self.terminate_pod(k, pod, PodPhase::Deleted);
        if let Some(tasks) = retry {
            k.schedule_batch_retry(tasks);
        }
    }
}

// ---------------------------------------------------------------
// fleet service: instance arrival / admission / completion
// ---------------------------------------------------------------
impl StrategyState {
    /// An instance arrives (open-loop): admit immediately if a slot is
    /// free, otherwise it joined the admission queue (FIFO).
    pub fn instance_arrive(&mut self, k: &mut Kernel, inst: usize) {
        let admit = k.fleet.as_mut().expect("fleet mode").try_admit(inst);
        if admit {
            self.admit_instance(k, inst);
        }
    }

    /// Admit an instance: dispatch its root tasks into the shared cluster.
    pub fn admit_instance(&mut self, k: &mut Kernel, inst: usize) {
        let now = k.now();
        let roots = k.fleet.as_mut().expect("fleet mode").admit(inst, now);
        k.metrics.inc_id(k.c.instances_admitted, 1);
        if let Some(o) = k.obs.as_mut() {
            let in_flight = k.fleet.as_ref().map_or(0, |f| f.in_flight);
            o.event(
                now,
                Actor::Fleet,
                "admit",
                format!("instance {inst}"),
                in_flight as f64,
            );
        }
        self.dispatch_ready(k, &roots);
    }

    /// Per-instance completion bookkeeping after a task finished; frees an
    /// admission slot (and admits the next waiting instance) when the
    /// task was its instance's last.
    pub fn instance_task_done(&mut self, k: &mut Kernel, task: TaskId) {
        let now = k.now();
        let inst = k.task_instance[task.0 as usize] as usize;
        let Some(next) = k
            .fleet
            .as_mut()
            .expect("fleet mode")
            .task_done(inst, now)
        else {
            return;
        };
        k.metrics.inc_id(k.c.instances_completed, 1);
        if let Some(o) = k.obs.as_mut() {
            let in_flight = k.fleet.as_ref().map_or(0, |f| f.in_flight);
            o.event(
                now,
                Actor::Fleet,
                "instance_done",
                format!("instance {inst}"),
                in_flight as f64,
            );
        }
        if let Some(next) = next {
            self.admit_instance(k, next as usize);
        }
    }

    /// The node-event entry point (`node_events` config + tests): the
    /// pre-chaos instant-redelivery semantics.
    pub fn fail_node(&mut self, k: &mut Kernel, node: usize) {
        self.fail_node_inner(k, node, false);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn two_instance_state(cap: Option<usize>) -> FleetState {
        FleetState {
            outstanding: vec![2, 1],
            roots: vec![vec![TaskId(0)], vec![TaskId(2)]],
            admitted_at: vec![None; 2],
            finished_at: vec![None; 2],
            waiting: VecDeque::new(),
            in_flight: 0,
            max_in_flight: cap,
        }
    }

    #[test]
    fn admission_cap_queues_and_releases_in_fifo_order() {
        let mut fs = two_instance_state(Some(1));
        assert!(fs.try_admit(0));
        let roots = fs.admit(0, SimTime(10));
        assert_eq!(roots, vec![TaskId(0)]);
        assert_eq!(fs.in_flight, 1);
        // second instance must wait
        assert!(!fs.try_admit(1));
        assert_eq!(fs.waiting.len(), 1);
        // first task done: instance 0 still running
        assert_eq!(fs.task_done(0, SimTime(20)), None);
        // last task done: slot frees, instance 1 pops
        assert_eq!(fs.task_done(0, SimTime(30)), Some(Some(1)));
        assert_eq!(fs.finished_at[0], Some(SimTime(30)));
        assert_eq!(fs.in_flight, 0);
    }

    #[test]
    fn uncapped_admission_is_immediate() {
        let mut fs = two_instance_state(None);
        assert!(fs.try_admit(0));
        fs.admit(0, SimTime::ZERO);
        assert!(fs.try_admit(1));
        fs.admit(1, SimTime::ZERO);
        assert_eq!(fs.in_flight, 2);
        assert!(fs.waiting.is_empty());
        // completing the single-task instance pops nobody
        assert_eq!(fs.task_done(1, SimTime(5)), Some(None));
    }

    #[test]
    fn chaos_runtime_disabled_without_fault_sources() {
        let rt = ChaosRuntime::build(
            &ChaosConfig::default(),
            0.0,
            RecoveryPolicy::default(),
            42,
            1_000,
        );
        assert!(rt.is_none(), "no injectors => subsystem off");
    }

    #[test]
    fn chaos_runtime_folds_legacy_pod_failure_knob() {
        let rt = ChaosRuntime::build(
            &ChaosConfig::default(),
            0.25,
            RecoveryPolicy::default(),
            42,
            1_000,
        )
        .expect("legacy knob enables the subsystem");
        assert!((rt.pod_fail_prob - 0.25).abs() < 1e-12);
        assert!(rt.processes.is_empty(), "pod failure is not a timed process");
    }
}
