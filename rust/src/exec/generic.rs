//! The **generic-pool** model (paper §3.3's rejected alternative): one
//! untyped worker pool serving every task type.
//!
//! The single pool's pod template must request the *maximum* resources
//! over all task types (the resource-side of §3.3's "universal image"
//! problem), which degrades packing quality — implemented precisely to
//! quantify that degradation against the typed pools of
//! [`crate::exec::pools`]. Routing-wise this is the typed strategy with
//! the whole `pool_of_type` table pointed at one [`crate::broker::PoolId`],
//! so the job path never engages on a healthy run.

use crate::autoscale::PoolSpec;
use crate::chaos::RecoveryPolicy;
use crate::engine::clustering::ClusteringConfig;
use crate::engine::Engine;
use crate::exec::config::SimConfig;
use crate::exec::job::JobPath;
use crate::exec::pools::PoolPath;
use crate::exec::strategy::{ExecStrategy, StrategyState};
use crate::k8s::resources::Resources;
use crate::metrics::Registry;

/// Queue name of the single pool in the generic-pool model.
pub const GENERIC_POOL: &str = "__generic__";

/// §3.3's single generic worker pool for ALL task types.
pub struct GenericStrategy {
    state: StrategyState,
}

impl GenericStrategy {
    pub fn build(engine: &Engine, cfg: &SimConfig, metrics: &mut Registry) -> GenericStrategy {
        let n_types = engine.dag().types.len();
        // generic-pool pod template: max requests over every task type
        // (§3.3's "universal image" problem, resource-wise)
        let generic_requests = engine
            .dag()
            .types
            .iter()
            .fold(Resources::ZERO, |acc, t| Resources {
                cpu_m: acc.cpu_m.max(t.requests.cpu_m),
                mem_mb: acc.mem_mb.max(t.requests.mem_mb),
            });
        let mut pools = PoolPath::none(n_types);
        let id = pools.broker.declare(GENERIC_POOL);
        pools.pool_type.push(None);
        for slot in pools.pool_of_type.iter_mut() {
            *slot = Some(id);
        }
        pools.generic_requests = generic_requests;
        let specs = vec![PoolSpec {
            name: GENERIC_POOL.to_string(),
            requests: generic_requests,
        }];
        pools.finalize(cfg, specs, metrics);
        GenericStrategy {
            state: StrategyState {
                jobs: JobPath::new(ClusteringConfig::none()),
                pools,
            },
        }
    }
}

impl ExecStrategy for GenericStrategy {
    fn name(&self) -> &'static str {
        "generic-pool"
    }

    fn state(&mut self) -> &mut StrategyState {
        &mut self.state
    }

    fn state_ref(&self) -> &StrategyState {
        &self.state
    }

    /// Queue consumers can be duplicated, so stragglers are speculatively
    /// re-executed like the typed pools.
    fn default_recovery(&self) -> RecoveryPolicy {
        RecoveryPolicy {
            speculative: true,
            ..RecoveryPolicy::default()
        }
    }
}
