//! The layered **execution subsystem**: a discrete-event kernel with
//! pluggable execution-model strategies and subsystem hooks.
//!
//! # Architecture map
//!
//! ```text
//!              run() / run_fleet()           (this module: thin binding)
//!                      |
//!   +------------------v-------------------+
//!   |  World = Kernel + Strategy           |  event router (handle())
//!   +---------+--------------------+-------+
//!             |                    |
//!   +---------v---------+  +------v--------------------------------+
//!   | kernel (kernel.rs)|  | strategy (strategy.rs)                |
//!   | calendar queue,   |  | ExecStrategy trait + enum dispatch    |
//!   | pods/nodes, sched,|  |   job.rs       §3.2 one task = 1 Job  |
//!   | API server, trace,|  |   clustered.rs §3.5 batched jobs      |
//!   | engine, metrics,  |  |   pools.rs     §3.3 typed worker pools|
//!   | per-task tables   |  |   generic.rs   §3.3 one generic pool  |
//!   +---------^---------+  +------^--------------------------------+
//!             |                   |
//!   +---------+-------------------+--------+
//!   | hooks (hooks.rs)                     |
//!   |  chaos: ChaosRuntime + kill paths    |
//!   |  data plane: stage-in/out cycle      |
//!   |  fleet: FleetState admission control |
//!   |  isolation: namespaces/quotas/pools  |
//!   |    + tenant-takeover blast radius    |
//!   +--------------------------------------+
//! ```
//!
//! * The **kernel** ([`kernel`]) owns the substrate: the calendar
//!   [`crate::sim::EventQueue`], pod/node lifecycle tables, the
//!   scheduler/API control plane, accounting, and the zero-alloc scratch
//!   buffers (EXPERIMENTS.md §Perf).
//! * A **strategy** ([`strategy::ExecStrategy`], one module per paper
//!   model) decides *routing policy*: where a ready task goes, how a pod
//!   advances, and how deployments scale. Strategies are enum-dispatched
//!   ([`strategy::Strategy`]) — static calls, no boxed closures.
//! * **Subsystem hooks** ([`hooks`]) attach chaos, the data plane and the
//!   fleet service to kernel events; each is an `Option<_>` slot that
//!   stays `None` (zero events, bit-identical runs) unless configured.
//!
//! Two entry points share the event machinery:
//!
//! * [`run`] — the paper's experiment harness: one workflow, dispatched
//!   at t=0, simulated to completion.
//! * [`run_fleet`] — the fleet service: many workflow *instances* (one
//!   [`Dag::disjoint_union`] task space, each instance a contiguous id
//!   range) arriving over simulated time, tagged with tenants, admitted
//!   under an optional concurrency cap, and executed concurrently on the
//!   shared cluster. Instance roots are held back until admission;
//!   readiness propagation, pools, autoscaling and scheduling are exactly
//!   the single-run code paths — the autoscaler simply sees the aggregate
//!   backlog of all in-flight instances, and the broker's per-tenant
//!   lanes enforce weighted fair-share at dequeue time.
//!
//! Determinism contract: identical `(workflow, model, SimConfig)` inputs
//! reproduce makespans, counters and event totals bit-identically
//! (`tests/determinism.rs`, `tests/golden_trace.rs`).

pub mod clustered;
pub mod config;
pub mod generic;
pub mod hooks;
pub mod job;
pub mod kernel;
pub mod pools;
pub mod strategy;

#[cfg(test)]
mod tests;

pub use config::{ConfigError, SimConfig, SimConfigBuilder};
pub use strategy::ExecModel;

use crate::chaos::inject::sample_node_slowdowns;
use crate::chaos::ChaosStats;
use crate::data::DataPlane;
use crate::engine::Engine;
use crate::fleet::{FleetPlan, InstanceOutcome};
use crate::k8s::api_server::ApiServer;
use crate::k8s::isolation::{IsolationConfig, IsolationPolicy, IsolationState};
use crate::k8s::node::paper_cluster;
use crate::k8s::pod::{PodPhase, PodTable};
use crate::k8s::scheduler::{SchedulePass, Scheduler};
use crate::metrics::{GaugeId, Registry};
use crate::obs::monitor::MonitorState;
use crate::obs::{critpath, Actor, FlightRecorder, ObsReport, PodRow};
use crate::report::{SimResult, Trace};
use crate::sim::{EventQueue, SimTime};
use crate::workflow::dag::Dag;
use crate::workflow::task::TaskId;
use hooks::{ChaosRuntime, FleetState};
use kernel::{Counters, Ev, Kernel, NO_FAULT};
use std::collections::VecDeque;
use strategy::{ExecStrategy, Strategy};

/// The bound simulation: the kernel substrate plus the execution-model
/// strategy layered on it. `handle` routes each calendar event either to
/// a kernel primitive or through the strategy's lifecycle hooks.
struct World {
    k: Kernel,
    strat: Strategy,
}

impl World {
    fn handle(&mut self, ev: Ev) {
        match ev {
            Ev::JobAdmitted { pod } => {
                // job controller creates the pod object after its reconcile
                let done = self.k.api.admit(self.k.now())
                    + SimTime::from_millis(self.k.cfg.job_controller_ms);
                self.k.q.schedule_at(done, Ev::PodCreated { pod });
            }
            Ev::PodCreated { pod } => {
                if self.k.pods.phase[pod.0 as usize] == PodPhase::Pending {
                    self.k.sched.enqueue(pod);
                    self.strat.on_capacity_changed(&mut self.k);
                }
            }
            Ev::BackoffExpire { pod } => {
                if self.k.pods.phase[pod.0 as usize] == PodPhase::Pending
                    && self.k.sched.is_sleeping(pod)
                {
                    self.k.sched.enqueue(pod);
                    self.strat.on_capacity_changed(&mut self.k);
                }
            }
            Ev::PodStarted { pod } => self.strat.on_pod_started(&mut self.k, pod),
            Ev::WorkerFetched { pod, task } => {
                self.strat.on_worker_fetched(&mut self.k, pod, task)
            }
            Ev::TaskDone { pod, task } => self.strat.on_task_done(&mut self.k, pod, task),
            Ev::FlushTimer { type_idx, deadline } => {
                self.strat.on_flush_timer(&mut self.k, type_idx, deadline)
            }
            Ev::NodeEvent { node, up } => {
                if up {
                    self.k.nodes[node].failed = false;
                    self.strat.on_capacity_changed(&mut self.k); // capacity restored
                } else {
                    self.strat.on_node_down(&mut self.k, node, false);
                }
            }
            Ev::InstanceArrive { inst } => {
                self.strat
                    .state()
                    .instance_arrive(&mut self.k, inst as usize);
            }
            Ev::ChaosFault { proc_idx, node } => {
                self.strat.on_fault(&mut self.k, proc_idx as usize, node);
                // lazy Poisson process: draw + schedule the next strike
                self.k.schedule_next_fault(proc_idx as usize);
            }
            Ev::ChaosReclaim { node, replace_ms } => {
                self.k.drain_pending[node] = false;
                if !self.k.nodes[node].failed {
                    self.k.chaos_stats.spot_reclaims += 1;
                    self.k.metrics.inc_id(self.k.c.spot_reclaims, 1);
                    if let Some(o) = self.k.obs.as_mut() {
                        let at = self.k.q.now();
                        o.event(
                            at,
                            Actor::Chaos,
                            "spot_reclaim",
                            format!("node {node}"),
                            replace_ms as f64 / 1000.0,
                        );
                    }
                    self.strat.on_node_down(&mut self.k, node, true);
                    self.k.q.schedule_in(
                        SimTime::from_millis(replace_ms),
                        Ev::ChaosRestore { node },
                    );
                }
                // if a crash beat the warning to it, the crash's own
                // restore will bring the replacement up
            }
            Ev::ChaosRestore { node } => {
                // replacement capacity: same slot, fresh incarnation
                self.k.node_replaced(node);
                // replacement hardware rolls the straggler dice again
                let resample = self.k.chaos.as_mut().and_then(|ch| {
                    ch.straggler
                        .map(|(frac, factor)| if ch.node_rng.f64() < frac { factor } else { 1.0 })
                });
                if let Some(slow) = resample {
                    self.k.node_slow[node] = slow;
                }
                self.strat
                    .state()
                    .pools
                    .update_chaos_quota(&mut self.k);
                self.k.metrics.inc_id(self.k.c.nodes_restored, 1);
                if let Some(o) = self.k.obs.as_mut() {
                    let at = self.k.q.now();
                    o.event(at, Actor::Chaos, "node_restored", format!("node {node}"), 0.0);
                }
                self.strat.on_capacity_changed(&mut self.k);
            }
            Ev::ChaosUncordon { node } => {
                let now = self.k.now();
                if !self.k.nodes[node].failed
                    && !self.k.drain_pending[node]
                    && self.k.blacklist_until[node] <= now
                    && self.k.nodes[node].cordoned
                {
                    self.k.nodes[node].cordoned = false;
                    self.strat.on_capacity_changed(&mut self.k);
                }
            }
            Ev::ChaosTakeover { tenant } => {
                self.strat.state().apply_takeover(&mut self.k, tenant)
            }
            Ev::ChaosRetryTask { task } => self.strat.on_retry_task(&mut self.k, task),
            Ev::ChaosRetryBatch { tasks } => self.strat.on_retry_batch(&mut self.k, tasks),
            Ev::SpecCheck { pod, task } => self.strat.on_speculate(&mut self.k, pod, task),
            Ev::FlowActivate { flow, gen } => {
                let now = self.k.now();
                let mut buf = std::mem::take(&mut self.k.flow_buf);
                if let Some(dp) = &mut self.k.data {
                    dp.activate(now, flow, gen, &mut buf);
                }
                self.k.schedule_flow_events(buf);
            }
            Ev::FlowDone { flow, gen } => {
                self.strat.state().flow_done(&mut self.k, flow, gen)
            }
            Ev::AutoscaleTick => {
                self.strat.on_scale(&mut self.k);
                if !self.k.engine.is_done() {
                    let poll = self
                        .strat
                        .state_ref()
                        .pools
                        .scaler
                        .as_ref()
                        .map(|s| s.cfg.poll_ms)
                        .unwrap_or(15_000);
                    self.k
                        .q
                        .schedule_in(SimTime::from_millis(poll), Ev::AutoscaleTick);
                }
            }
            Ev::MonitorTick => {
                // take/put-back so the scrape can borrow the whole kernel
                // read-only; it draws no RNG and mutates nothing but its
                // own ring buffers and alert lifecycles
                if let Some(mut m) = self.k.monitor.take() {
                    let now = self.k.now();
                    m.scrape(now, &self.k);
                    let interval = m.interval_ms();
                    self.k.monitor = Some(m);
                    if !self.k.engine.is_done() {
                        self.k
                            .q
                            .schedule_in(SimTime::from_millis(interval), Ev::MonitorTick);
                    }
                }
            }
        }
    }
}

/// Construct the simulated world (cluster, control plane, strategy,
/// gauges) for a workflow + execution model, returning the
/// initially-ready tasks for the caller to dispatch — at t=0 ([`run`]) or
/// per instance arrival ([`run_fleet`]).
fn build(dag: Dag, model: &ExecModel, cfg: SimConfig) -> (World, Vec<TaskId>) {
    let (engine, initial_ready) = Engine::new(dag);
    let n_types = engine.dag().types.len();
    // type names cloned once here; trace records carry only the TypeId
    let type_names: Vec<String> = engine.dag().types.iter().map(|t| t.name.clone()).collect();

    // pre-resolve the hot gauges and counters (see §Perf)
    let mut metrics = Registry::new();
    let c = Counters::resolve(&mut metrics);
    let g_running = metrics.gauge_id("running_tasks");
    let g_cpu = metrics.gauge_id("cpu_allocated_m");
    let g_pending = metrics.gauge_id("pending_pods");
    let g_by_type: Vec<GaugeId> = engine
        .dag()
        .types
        .iter()
        .map(|t| metrics.gauge_id(&format!("running::{}", t.name)))
        .collect();

    // the single ExecModel match in the execution layer: instantiate the
    // model's strategy (declares pools + per-pool gauges)
    let strat = Strategy::build(model, &engine, &cfg, &mut metrics);

    let n_tasks = engine.dag().len();
    let chaos = ChaosRuntime::build(
        &cfg.chaos,
        cfg.pod_failure_prob,
        strat.default_recovery(),
        cfg.seed,
        cfg.autoscale.quota_cpu_m,
    );
    let chaos_enabled = chaos.is_some();
    // data plane: file tables + caches derived from the DAG's annotations
    let data = cfg
        .data
        .as_ref()
        .map(|dc| DataPlane::new(dc.clone(), engine.dag(), cfg.nodes));
    let task_out_pending = if data.is_some() {
        vec![false; n_tasks]
    } else {
        Vec::new()
    };
    // per-task chaos tables (healthy runs read work_left in start_task too,
    // so it always mirrors the DAG durations)
    let task_work_left: Vec<SimTime> = engine.dag().tasks.iter().map(|t| t.duration).collect();
    // isolation: namespaces/quotas/node pools. A scheduled takeover forces
    // the subsystem on (default shared policy) so the blast-radius
    // machinery has tenancy state to work with; otherwise `None` keeps
    // every pre-tenancy run bit-identical.
    let isolation = if cfg.isolation.is_some() || cfg.chaos.takeovers().next().is_some() {
        let ic = cfg
            .isolation
            .clone()
            .unwrap_or_else(|| IsolationConfig::new(IsolationPolicy::Shared));
        Some(IsolationState::new(ic, cfg.nodes))
    } else {
        None
    };

    let mut k = Kernel {
        chaos,
        chaos_stats: ChaosStats {
            enabled: chaos_enabled,
            ..Default::default()
        },
        node_slow: vec![1.0; cfg.nodes],
        node_incarnation: vec![0; cfg.nodes],
        node_fault_counts: vec![0; cfg.nodes],
        drain_pending: vec![false; cfg.nodes],
        blacklist_until: vec![SimTime::ZERO; cfg.nodes],
        task_work_left,
        task_attempts: vec![0; n_tasks],
        task_fault_at: vec![NO_FAULT; n_tasks],
        spec_launched: vec![false; n_tasks],
        task_running: vec![0; n_tasks],
        nodes: paper_cluster(cfg.nodes),
        sched: Scheduler::new(cfg.sched.clone()),
        api: ApiServer::new(cfg.api.clone()),
        engine,
        metrics,
        c,
        trace: Trace::with_type_names(type_names),
        obs: cfg.obs.then(|| FlightRecorder::new(n_tasks)),
        monitor: None,
        running_tasks: 0,
        pending_count: 0,
        completed_by_type: vec![0; n_types],
        data,
        task_out_pending,
        flow_buf: Vec::new(),
        isolation,
        fleet: None,
        task_instance: Vec::new(),
        task_tenant: Vec::new(),
        g_running,
        g_cpu,
        g_pending,
        g_by_type,
        q: EventQueue::new(),
        pods: PodTable::new(),
        batch_queue: Vec::new(),
        current_task: Vec::new(),
        pod_bound_inc: Vec::new(),
        pod_task_started_at: Vec::new(),
        pod_io: Vec::new(),
        pod_exec_ms: Vec::new(),
        ready_buf: Vec::new(),
        pass_buf: SchedulePass::default(),
        members_buf: Vec::new(),
        cfg,
    };

    k.metrics.set_id(k.g_running, SimTime::ZERO, 0.0);
    // schedule the configured node failures (moved out and back rather
    // than cloning the whole Vec per run)
    let node_events = std::mem::take(&mut k.cfg.node_events);
    for &(at_ms, node, up) in &node_events {
        assert!(node < k.nodes.len(), "node event for unknown node {node}");
        k.q
            .schedule_at(SimTime::from_millis(at_ms), Ev::NodeEvent { node, up });
    }
    k.cfg.node_events = node_events;
    // chaos: sample the straggler table and arm every timed injector
    let straggler = k.chaos.as_ref().and_then(|c| c.straggler);
    if let Some((frac, factor)) = straggler {
        let n = k.nodes.len();
        let slow = {
            let ch = k.chaos.as_mut().expect("chaos runtime");
            sample_node_slowdowns(n, frac, factor, &mut ch.node_rng)
        };
        k.node_slow = slow;
    }
    let n_processes = k.chaos.as_ref().map(|c| c.processes.len()).unwrap_or(0);
    for i in 0..n_processes {
        k.schedule_next_fault(i);
    }
    // takeovers are RNG-free fixed calendar events — placed last so they
    // cannot perturb the injector fork order above
    let takeovers: Vec<(u16, u64)> = k.cfg.chaos.takeovers().collect();
    for (tenant, at_ms) in takeovers {
        k.q
            .schedule_at(SimTime::from_millis(at_ms), Ev::ChaosTakeover { tenant });
    }
    // monitor scrape loop: same RNG-free fixed-event pattern as the
    // takeovers, armed after every injector for the same reason
    if let Some(mc) = k.cfg.monitor.clone() {
        let m = MonitorState::from_config(&mc, k.data.is_some(), k.isolation.is_some())
            .expect("monitor rules validated by SimConfig::validate");
        let interval = m.interval_ms();
        k.monitor = Some(m);
        k.q
            .schedule_in(SimTime::from_millis(interval), Ev::MonitorTick);
    }
    (World { k, strat }, initial_ready)
}

/// Pump the event loop until every workflow task completed (or the wall
/// cap fires); returns the makespan and the processed event count.
fn drive(world: &mut World) -> (SimTime, u64) {
    let max_ms = (world.k.cfg.max_sim_s * 1000.0) as u64;
    let mut makespan = SimTime::ZERO;
    let mut sim_events: u64 = 0;
    while let Some((t, ev)) = world.k.q.pop() {
        if t.as_millis() > max_ms {
            log::warn!(
                "simulation wall cap hit at {t} with {} tasks outstanding",
                world.k.engine.n_outstanding()
            );
            break;
        }
        sim_events += 1;
        world.handle(ev);
        if world.k.engine.is_done() {
            makespan = world.k.q.now();
            break;
        }
    }
    assert!(
        world.k.engine.is_done(),
        "simulation ended with {} of {} tasks incomplete (deadlock?)",
        world.k.engine.n_outstanding(),
        world.k.engine.dag().len()
    );
    (makespan, sim_events)
}

/// Fold the finished kernel into a [`SimResult`]. The strategy is only
/// consulted to resolve broker pool names for the pod lanes of the
/// flight-recorder report.
fn summarize(
    mut k: Kernel,
    strat: &Strategy,
    model_name: String,
    makespan: SimTime,
    sim_events: u64,
) -> SimResult {
    // distill the flight recorder (when attached): whole-run attribution
    // over the critical path, control-plane events, pod lanes
    let obs = k.obs.take().map(|rec| {
        let preds = critpath::predecessors(k.engine.dag());
        let n = k.engine.dag().len() as u32;
        let (attribution, critical_path) =
            match critpath::attribute(&rec, &preds, 0, n, SimTime::ZERO) {
                Some((a, p)) => (Some(a), p),
                None => (None, Vec::new()),
            };
        let broker = &strat.state_ref().pools.broker;
        let pods = (0..k.pods.len())
            .map(|i| PodRow {
                pod: i as u64,
                node: k.pods.node[i].map(|n| n.0 as u32),
                pool: k.pods.pool_id(i).map(|pid| broker.name(pid).to_string()),
                created: k.pods.created_at[i],
                scheduled: k.pods.scheduled_at[i],
                running: k.pods.running_at[i],
                finished: k.pods.finished_at[i],
            })
            .collect();
        let phase_rows = crate::obs::phase_rows(rec.spans());
        ObsReport {
            attribution,
            critical_path,
            events: rec.events,
            pods,
            instance_attr: Vec::new(),
            phase_rows,
        }
    });

    // harvest the monitor before the registry moves into the result:
    // finalize open alert episodes and freeze the report
    let monitor = k.monitor.take().map(|m| m.into_report(makespan));

    let t_end = makespan.as_secs_f64();
    let avg_running = k
        .metrics
        .gauge("running_tasks")
        .map(|s| s.time_average(0.0, t_end))
        .unwrap_or(0.0);
    let total_cpu = k.cfg.nodes as f64 * 4_000.0;
    let avg_cpu = k
        .metrics
        .gauge("cpu_allocated_m")
        .map(|s| s.time_average(0.0, t_end) / total_cpu)
        .unwrap_or(0.0);

    SimResult {
        model_name,
        makespan,
        data: k.data.as_ref().map(|d| d.report()).unwrap_or_default(),
        pods_created: k.metrics.counter("pods_created"),
        api_requests: k.api.requests_total,
        sched_backoffs: k.sched.backoffs_total,
        sched_binds: k.sched.binds_total,
        sim_events,
        event_arena: k.q.arena_stats(),
        avg_running_tasks: avg_running,
        avg_cpu_utilization: avg_cpu,
        isolation: k
            .isolation
            .as_ref()
            .map(|i| i.report())
            .unwrap_or_default(),
        chaos: k.chaos_stats.report(),
        obs,
        monitor,
        trace: k.trace,
        metrics: k.metrics,
    }
}

/// Run a workflow under an execution model on the simulated cluster.
pub fn run(dag: Dag, model: ExecModel, cfg: SimConfig) -> SimResult {
    let model_name = model.name().to_string();
    let (mut world, initial_ready) = build(dag, &model, cfg);
    world.strat.on_ready(&mut world.k, &initial_ready);
    if world.strat.state_ref().pools.scaler.is_some() {
        // first poll fires quickly so pools can start warming up
        world
            .k
            .q
            .schedule_in(SimTime::from_millis(1_000), Ev::AutoscaleTick);
    }
    let (makespan, sim_events) = drive(&mut world);
    let World { k, strat } = world;
    summarize(k, &strat, model_name, makespan, sim_events)
}

/// Run an open-loop fleet of workflow instances on one shared cluster.
///
/// `dag` is the [`Dag::disjoint_union`] of every instance; `plan` maps
/// each instance to its contiguous task range, tenant, and arrival time,
/// and carries the tenant fair-share weights plus the admission cap. Each
/// instance's root tasks are dispatched when the instance is *admitted*
/// (at arrival, or when a slot frees under the cap); everything downstream
/// — readiness, batching, pools, autoscaling — is the single-run
/// machinery operating on the aggregate workload. Returns the overall
/// [`SimResult`] plus one [`InstanceOutcome`] per instance (same order as
/// `plan.instances`), from which per-tenant SLO statistics are derived by
/// [`crate::fleet::report`].
///
/// Panics on a structurally invalid plan (the panic message carries the
/// named [`ConfigError`]); callers that want a `Result` should check
/// [`FleetPlan::validate`] themselves before invoking — the CLI and the
/// config loader do.
pub fn run_fleet(
    dag: Dag,
    model: ExecModel,
    cfg: SimConfig,
    plan: &FleetPlan,
) -> (SimResult, Vec<InstanceOutcome>) {
    let model_name = format!("fleet/{}", model.name());
    let n_tasks = dag.len();
    // validate the plan: contiguous instance ranges covering the union
    // DAG, every tenant weighted, a usable admission cap
    if let Err(e) = plan.validate(n_tasks as u32) {
        panic!("invalid fleet plan: {e}");
    }

    let (mut world, initial_ready) = build(dag, &model, cfg);
    world
        .strat
        .state()
        .pools
        .broker
        .set_tenant_weights(&plan.tenant_weights);
    // per-tenant resilience accounting (wasted work / retries per lane)
    world.k.chaos_stats.set_tenants(plan.tenant_weights.len());
    // per-tenant namespaces + fair-share-weighted node-pool partition
    if let Some(iso) = &mut world.k.isolation {
        iso.set_tenants(&plan.tenant_weights);
    }
    // per-tenant bytes-moved lanes for the data plane, when enabled
    if let Some(dp) = &mut world.k.data {
        dp.stats.set_tenants(plan.tenant_weights.len());
    }

    // per-task instance/tenant tables (the disjoint-union offset scheme)
    let mut task_instance = vec![0u32; n_tasks];
    let mut task_tenant = vec![0u16; n_tasks];
    for (i, s) in plan.instances.iter().enumerate() {
        let range = s.first_task as usize..(s.first_task + s.n_tasks) as usize;
        task_instance[range.clone()].fill(i as u32);
        task_tenant[range].fill(s.tenant);
    }
    // hold each instance's roots back until it is admitted
    let mut roots: Vec<Vec<TaskId>> = vec![Vec::new(); plan.instances.len()];
    for &t in &initial_ready {
        roots[task_instance[t.0 as usize] as usize].push(t);
    }
    world.k.task_instance = task_instance;
    world.k.task_tenant = task_tenant;
    world.k.fleet = Some(FleetState {
        outstanding: plan.instances.iter().map(|s| s.n_tasks).collect(),
        roots,
        admitted_at: vec![None; plan.instances.len()],
        finished_at: vec![None; plan.instances.len()],
        waiting: VecDeque::new(),
        in_flight: 0,
        max_in_flight: plan.max_in_flight,
    });
    for (i, s) in plan.instances.iter().enumerate() {
        world.k.q.schedule_at(
            SimTime::from_millis(s.arrival_ms),
            Ev::InstanceArrive { inst: i as u32 },
        );
    }
    // per-tenant SLO rules (slowdown age + burn-rate budgets) only make
    // sense on fleet runs; tell the monitor who the tenants are
    if let Some(m) = world.k.monitor.as_mut() {
        m.set_fleet(plan.instances.iter().map(|s| s.tenant).collect());
    }
    if world.strat.state_ref().pools.scaler.is_some() {
        world
            .k
            .q
            .schedule_in(SimTime::from_millis(1_000), Ev::AutoscaleTick);
    }

    let (makespan, sim_events) = drive(&mut world);

    let fs = world.k.fleet.take().expect("fleet state");
    debug_assert!(fs.waiting.is_empty() && fs.in_flight == 0);
    // per-instance attribution: each instance's contiguous sub-DAG,
    // based at its admission time so the first segment's queueing covers
    // admission -> first dispatch
    let instance_attr: Vec<Option<critpath::Attribution>> = match world.k.obs.as_ref() {
        Some(rec) => {
            let preds = critpath::predecessors(world.k.engine.dag());
            plan.instances
                .iter()
                .enumerate()
                .map(|(i, s)| {
                    let base = fs.admitted_at[i].unwrap_or(SimTime::ZERO);
                    critpath::attribute(rec, &preds, s.first_task, s.first_task + s.n_tasks, base)
                        .map(|(a, _)| a)
                })
                .collect()
        }
        None => Vec::new(),
    };
    let outcomes = plan
        .instances
        .iter()
        .enumerate()
        .map(|(i, s)| InstanceOutcome {
            tenant: s.tenant,
            arrival: SimTime::from_millis(s.arrival_ms),
            admitted: fs.admitted_at[i].expect("instance never admitted"),
            finished: fs.finished_at[i].expect("instance never finished"),
            n_tasks: s.n_tasks,
        })
        .collect();
    let World { k, strat } = world;
    let mut res = summarize(k, &strat, model_name, makespan, sim_events);
    if let Some(o) = res.obs.as_mut() {
        o.instance_attr = instance_attr;
    }
    (res, outcomes)
}
