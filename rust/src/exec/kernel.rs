//! The execution **kernel**: the simulation substrate every strategy and
//! subsystem hook runs on.
//!
//! The kernel owns the calendar [`EventQueue`], the pod/node tables and
//! their lifecycle bookkeeping, the HyperFlow [`Engine`], the control
//! plane ([`Scheduler`] + [`ApiServer`]), metrics/trace accounting, and
//! the per-task fault/data tables the subsystem hooks ([`crate::exec::hooks`])
//! write through. It deliberately knows nothing about execution *models*:
//! routing a ready task to a queue or a Job, advancing a worker, and
//! scaling deployments are [`crate::exec::strategy::ExecStrategy`]
//! decisions layered on top.
//!
//! Hot-path contract (EXPERIMENTS.md §Perf): every per-pod / per-task
//! attribute is a dense `Vec` indexed by the interned id, gauge handles
//! are pre-resolved, and the reusable scratch buffers (`ready_buf`,
//! `pass_buf`, `members_buf`) keep the steady-state event loop free of
//! heap allocation.

use crate::chaos::ChaosStats;
use crate::data::{DataPlane, FlowEvent};
use crate::engine::Engine;
use crate::exec::config::SimConfig;
use crate::exec::hooks::{ChaosRuntime, FleetState};
use crate::k8s::api_server::ApiServer;
use crate::k8s::isolation::{IsolationState, SHARED_TENANT};
use crate::k8s::node::{Node, NodeId};
use crate::k8s::pod::{Payload, Pod, PodId, PodPhase, PodTable};
use crate::k8s::resources::Resources;
use crate::k8s::scheduler::{SchedulePass, Scheduler};
use crate::metrics::{CounterId, GaugeId, Registry};
use crate::obs::monitor::MonitorState;
use crate::obs::FlightRecorder;
use crate::report::Trace;
use crate::sim::{EventQueue, SimTime};
use crate::workflow::task::{TaskId, TypeId};
use std::collections::VecDeque;

/// Simulation events.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Ev {
    /// API processed the Job creation; the Job controller will now create
    /// the pod object.
    JobAdmitted { pod: PodId },
    /// Pod object exists; enters the scheduler.
    PodCreated { pod: PodId },
    /// Container started; payload begins.
    PodStarted { pod: PodId },
    /// Current task inside the pod finished.
    TaskDone { pod: PodId, task: TaskId },
    /// A pod's scheduling back-off expired; retry.
    BackoffExpire { pod: PodId },
    /// Clustering partial-batch timeout.
    FlushTimer { type_idx: u16, deadline: SimTime },
    /// Autoscaler poll.
    AutoscaleTick,
    /// A worker finished fetching a message from its queue.
    WorkerFetched { pod: PodId, task: TaskId },
    /// Failure injection: a node goes down (kills its pods) or comes back.
    NodeEvent { node: usize, up: bool },
    /// Fleet service: workflow instance `inst` arrives (open-loop).
    InstanceArrive { inst: u32 },
    /// Chaos: timed injector `proc_idx` strikes `node` (spot warning or
    /// crash); the handler samples and schedules the process's next fault.
    ChaosFault { proc_idx: u8, node: usize },
    /// Chaos: a spot-reclaim warning expired — the node goes down now;
    /// replacement capacity arrives `replace_ms` later.
    ChaosReclaim { node: usize, replace_ms: u64 },
    /// Chaos: a reclaimed/crashed node's replacement capacity arrives
    /// (fresh incarnation).
    ChaosRestore { node: usize },
    /// Chaos: a blacklisted node's cordon expires.
    ChaosUncordon { node: usize },
    /// Chaos recovery: a failed pool task's retry back-off expired.
    ChaosRetryTask { task: TaskId },
    /// Chaos recovery: a failed job batch's retry back-off expired.
    ChaosRetryBatch { tasks: Vec<TaskId> },
    /// Chaos recovery: straggler watch — if `task` is still running in
    /// `pod`, launch a speculative copy.
    SpecCheck { pod: PodId, task: TaskId },
    /// Data plane: a transfer's scheduled completion check (stale
    /// generations are dropped by [`DataPlane::flow_done`]).
    FlowDone { flow: u32, gen: u32 },
    /// Data plane: an object-store request's latency elapsed — the flow
    /// joins fair bandwidth sharing.
    FlowActivate { flow: u32, gen: u32 },
    /// Chaos: tenant `tenant` is fully compromised at this instant — its
    /// blast radius is computed and remediated (RNG-free; placed on the
    /// calendar at build time).
    ChaosTakeover { tenant: u16 },
    /// Monitoring scrape: sample the registry into the monitor's ring
    /// buffers and evaluate recording/alert rules. RNG-free and
    /// self-rescheduling at a fixed interval; only exists with
    /// `--monitor` attached.
    MonitorTick,
}

/// Where a pod is in the stage-in -> compute -> stage-out cycle of its
/// current task (always `Idle` between tasks; stage phases only occur
/// with the data plane enabled).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum IoPhase {
    Idle,
    StageIn,
    Compute,
    StageOut,
}

/// Sentinel for "no pending fault" in the per-task fault-time table.
pub(crate) const NO_FAULT: u64 = u64::MAX;

/// Pre-resolved [`CounterId`] handles for every counter the kernel,
/// strategies and hooks increment on hot paths — the counter-side mirror
/// of the pre-resolved gauge handles (`inc(&str)` did a string-keyed
/// BTreeMap lookup, allocating on first touch, once per pod/fault/retry).
/// Resolved once at build; counters therefore exist (value 0) from the
/// start of the run, which also gives the Prometheus exposition a
/// complete metric set.
#[derive(Debug, Clone, Copy)]
pub struct Counters {
    pub pods_created: CounterId,
    pub tasks_lost_to_faults: CounterId,
    pub stale_node_events_dropped: CounterId,
    pub node_blacklists: CounterId,
    pub chaos_retries: CounterId,
    pub node_crashes: CounterId,
    pub node_failures: CounterId,
    pub spot_warnings: CounterId,
    pub spot_reclaims: CounterId,
    pub nodes_restored: CounterId,
    pub pod_failures: CounterId,
    pub speculative_copies: CounterId,
    pub speculative_losses: CounterId,
    pub instances_admitted: CounterId,
    pub instances_completed: CounterId,
    pub tenant_takeovers: CounterId,
}

impl Counters {
    pub fn resolve(reg: &mut Registry) -> Self {
        Counters {
            pods_created: reg.counter_id("pods_created"),
            tasks_lost_to_faults: reg.counter_id("tasks_lost_to_faults"),
            stale_node_events_dropped: reg.counter_id("stale_node_events_dropped"),
            node_blacklists: reg.counter_id("node_blacklists"),
            chaos_retries: reg.counter_id("chaos_retries"),
            node_crashes: reg.counter_id("node_crashes"),
            node_failures: reg.counter_id("node_failures"),
            spot_warnings: reg.counter_id("spot_warnings"),
            spot_reclaims: reg.counter_id("spot_reclaims"),
            nodes_restored: reg.counter_id("nodes_restored"),
            pod_failures: reg.counter_id("pod_failures"),
            speculative_copies: reg.counter_id("speculative_copies"),
            speculative_losses: reg.counter_id("speculative_losses"),
            instances_admitted: reg.counter_id("instances_admitted"),
            instances_completed: reg.counter_id("instances_completed"),
            tenant_takeovers: reg.counter_id("tenant_takeovers"),
        }
    }
}

/// The simulation substrate: everything that is *not* an execution-model
/// decision. See the module docs for the layering contract.
pub struct Kernel {
    pub cfg: SimConfig,
    pub q: EventQueue<Ev>,
    /// Pod lifecycle state, SoA: one dense column per field, indexed by
    /// `PodId` (see [`PodTable`]). The event loop touches one or two
    /// columns of many pods per event; `Pod` rows only exist transiently
    /// at creation.
    pub pods: PodTable,
    pub nodes: Vec<Node>,
    pub sched: Scheduler,
    pub api: ApiServer,
    pub engine: Engine,
    pub metrics: Registry,
    /// Pre-resolved counter handles (hot-path increments, see [`Counters`]).
    pub c: Counters,
    pub trace: Trace,
    /// Flight recorder (`--obs`): structured span/event recording. `None`
    /// — the default — records nothing; recording never draws RNG and
    /// never schedules events, so the simulated trace is bit-identical
    /// either way.
    pub obs: Option<FlightRecorder>,
    /// Monitoring stack (`--monitor`): deterministic scrape loop with
    /// recording rules and SLO burn-rate alerting. `None` — the default —
    /// schedules no ticks; scrapes only read kernel state, so the
    /// simulated trace is unchanged apart from the tick events.
    pub monitor: Option<MonitorState>,
    pub running_tasks: i64,
    /// Incremental count of pods in the Pending phase (perf: a full scan
    /// here was 70% of the 16k job-model sim, see EXPERIMENTS.md §Perf).
    pub pending_count: usize,
    /// Completed tasks per TypeId (feeds the VPA usage estimator).
    pub completed_by_type: Vec<u64>,
    // pre-resolved gauge handles (string-keyed lookups were hot; §Perf)
    pub g_running: GaugeId,
    pub g_cpu: GaugeId,
    pub g_pending: GaugeId,
    /// running::<type> gauge per TypeId.
    pub g_by_type: Vec<GaugeId>,
    // -- per-pod tables (pushed by `new_pod`, indexed by PodId) ----------
    /// Remaining batch tasks per pod (job path), front = current.
    pub batch_queue: Vec<VecDeque<TaskId>>,
    /// Task currently executing in each pod (for node-failure recovery).
    pub current_task: Vec<Option<TaskId>>,
    /// Incarnation of the node each pod was bound to (stale-event guard).
    pub pod_bound_inc: Vec<u32>,
    /// When the task currently in each pod started (waste accounting).
    pub pod_task_started_at: Vec<SimTime>,
    /// Stage cycle position per pod (all `Idle`/`Compute` without data).
    pub pod_io: Vec<IoPhase>,
    /// Execution ms of the task a pod is currently staging out — success
    /// accounting (useful work, completed-by-type, compute time) is
    /// deferred until the write lands, so a kill mid-write re-runs the
    /// task without double counting.
    pub pod_exec_ms: Vec<u64>,
    // -- chaos hook (None for healthy runs; see crate::exec::hooks) ------
    pub chaos: Option<ChaosRuntime>,
    /// Resilience accounting (always present; all-zero without chaos).
    pub chaos_stats: ChaosStats,
    /// Per-node task-duration multiplier (straggler injector; all 1.0
    /// otherwise). Resampled when a node's replacement arrives.
    pub node_slow: Vec<f64>,
    /// Node incarnation counters: bumped when replacement capacity for a
    /// reclaimed/crashed node arrives, so events bound to the previous
    /// hardware are recognizably stale.
    pub node_incarnation: Vec<u32>,
    /// Pod-start failures charged to each node (blacklisting evidence).
    pub node_fault_counts: Vec<u32>,
    /// Spot warning in progress for the node (drain pending).
    pub drain_pending: Vec<bool>,
    /// Blacklist expiry per node (ZERO = not blacklisted).
    pub blacklist_until: Vec<SimTime>,
    /// Remaining work per task (checkpoint-restart shrinks it on re-runs;
    /// initialized to the DAG durations).
    pub task_work_left: Vec<SimTime>,
    /// Fault-driven re-dispatch count per task (retry back-off input).
    pub task_attempts: Vec<u32>,
    /// When the task was last lost to a fault (`NO_FAULT` = none pending);
    /// cleared into the recovery-latency summary when it re-starts.
    pub task_fault_at: Vec<u64>,
    /// A speculative copy was already launched for the task (at most one).
    pub spec_launched: Vec<bool>,
    /// Live executions per task (1 normally; 2 while a speculative copy
    /// races the original). Gates retries — a task with a copy still
    /// running must not be re-dispatched — and keeps the trace record on
    /// the first copy's timestamps.
    pub task_running: Vec<u8>,
    // -- data hook (None = pure-compute tasks, the pre-data behavior) ----
    pub data: Option<DataPlane>,
    /// Task has a stage-out in flight (its completion is not yet visible
    /// to successors); sized only when the data plane is on.
    pub task_out_pending: Vec<bool>,
    /// Scratch buffer for transfer (re)schedules.
    pub flow_buf: Vec<FlowEvent>,
    // -- isolation hook (None = no namespaces/quotas, pre-tenancy paths) -
    pub isolation: Option<IsolationState>,
    // -- fleet hook (None for classic single-workflow runs) --------------
    pub fleet: Option<FleetState>,
    /// Instance index of each task (fleet runs; empty otherwise).
    pub task_instance: Vec<u32>,
    /// Tenant lane of each task (fleet runs; empty = all tenant 0).
    pub task_tenant: Vec<u16>,
    // -- reusable scratch buffers (zero steady-state allocation, §Perf) --
    /// Newly-ready tasks from `Engine::complete_into`.
    pub ready_buf: Vec<TaskId>,
    /// Scheduler pass output.
    pub pass_buf: SchedulePass,
    /// Pod-id snapshots (node-failure victims, scale-down members).
    pub members_buf: Vec<PodId>,
}

impl Kernel {
    pub fn now(&self) -> SimTime {
        self.q.now()
    }

    // ---------------------------------------------------------------
    // pod lifecycle primitives
    // ---------------------------------------------------------------

    /// Register a new pod with precomputed resource requests (the caller
    /// — job path or pool path — owns the template-sizing policy) and
    /// grow every per-pod table alongside it. With isolation on, the pod
    /// is stamped into its tenant's namespace (job batches inherit their
    /// first task's tenant; pool workers are shared infrastructure) and
    /// the namespace LimitRange defaults/floors the requests.
    pub fn new_pod(&mut self, payload: Payload, requests: Resources) -> PodId {
        let id = PodId(self.pods.len() as u64);
        let requests = if let Some(iso) = &mut self.isolation {
            let tenant = match &payload {
                Payload::JobBatch { tasks } => tasks
                    .first()
                    .map(|t| self.task_tenant.get(t.0 as usize).copied().unwrap_or(0))
                    .unwrap_or(0),
                Payload::Worker { .. } => SHARED_TENANT,
            };
            iso.on_pod_created(id, tenant, requests)
        } else {
            requests
        };
        let pod = Pod::new(id, payload, requests, self.now());
        self.pods.push(pod);
        self.batch_queue.push(VecDeque::new());
        self.current_task.push(None);
        self.pod_bound_inc.push(0);
        self.pod_task_started_at.push(SimTime::ZERO);
        self.pod_io.push(IoPhase::Idle);
        self.pod_exec_ms.push(0);
        self.pending_count += 1;
        self.metrics.inc_id(self.c.pods_created, 1);
        id
    }

    /// Mark a pod terminal and free its node resources. The strategy
    /// layer wraps this with deployment-membership cleanup and the
    /// post-release scheduler pass ([`crate::exec::strategy::StrategyState::terminate_pod`]).
    pub fn release_pod(&mut self, pid: PodId, phase: PodPhase) {
        let now = self.now();
        let i = pid.0 as usize;
        if self.pods.phase[i] == PodPhase::Pending {
            self.pending_count -= 1;
        }
        // data plane: the pod's in-flight transfer is torn down and its
        // ephemeral cache entries die with it (crash-loses-cache)
        if self.data.is_some() {
            let node = self.pods.node[i].map(|n| n.0);
            let mut buf = std::mem::take(&mut self.flow_buf);
            self.data
                .as_mut()
                .expect("data plane")
                .cancel_pod(now, pid, node, &mut buf);
            self.schedule_flow_events(buf);
            self.pod_io[i] = IoPhase::Idle;
        }
        // namespace quota frees with the pod (idempotent: only ever
        // charged once, at bind)
        if let Some(iso) = &mut self.isolation {
            iso.release(pid);
        }
        debug_assert!(!self.pods.is_terminal(i));
        let had_node = self.pods.node[i];
        self.pods.phase[i] = phase;
        self.pods.finished_at[i] = Some(now);
        if let Some(nid) = had_node {
            let req = self.pods.requests[i];
            self.nodes[nid.0].release(req);
            self.record_cpu();
        }
    }

    // ---------------------------------------------------------------
    // accounting
    // ---------------------------------------------------------------

    pub fn record_cpu(&mut self) {
        let now = self.now();
        let alloc: u64 = self.nodes.iter().map(|n| n.allocated.cpu_m).sum();
        self.metrics.set_id(self.g_cpu, now, alloc as f64);
    }

    pub fn record_running(&mut self, ttype: TypeId, delta: i64) {
        let now = self.now();
        self.running_tasks += delta;
        self.metrics
            .set_id(self.g_running, now, self.running_tasks as f64);
        self.metrics
            .add_id(self.g_by_type[ttype.0 as usize], now, delta as f64);
    }

    /// Tenant lane of a task: its instance's tenant in fleet runs, the
    /// default lane otherwise.
    pub fn tenant_of(&self, t: TaskId) -> crate::broker::TenantId {
        crate::broker::TenantId(self.task_tenant.get(t.0 as usize).copied().unwrap_or(0))
    }

    /// Wall-clock execution ms the pod's current run has burned, net of
    /// the fixed executor overhead. One definition shared by success
    /// accounting (`TaskDone`), wasted-work charging on kills, and
    /// checkpoint-restart credit — so goodput's numerator and denominator
    /// stay commensurate (previously hand-copied at four sites).
    pub fn run_exec_ms(&self, pod: PodId) -> u64 {
        let elapsed = self
            .now()
            .saturating_sub(self.pod_task_started_at[pod.0 as usize])
            .as_millis();
        elapsed.saturating_sub(self.cfg.exec_overhead_ms.min(elapsed))
    }

    /// Flight recorder: stamp the winning attempt's lifecycle chain when
    /// a task's compute finishes in `pod`. Job pods carry real
    /// created/bound/running timestamps; a pool worker long predates the
    /// task, so all three collapse to the broker dispatch time (the
    /// asymmetry the attribution report is built to show). No-op without
    /// the recorder.
    pub fn obs_task_complete(&mut self, pod: PodId, task: TaskId, now: SimTime) {
        if self.obs.is_none() {
            return;
        }
        let i = pod.0 as usize;
        let (a, b, c) = if self.pods.pool_id(i).is_some() {
            let d = self
                .obs
                .as_ref()
                .expect("recorder checked above")
                .dispatch_of(pod, now);
            (d, d, d)
        } else {
            (
                self.pods.created_at[i],
                self.pods.scheduled_at[i].unwrap_or(self.pods.created_at[i]),
                self.pods.running_at[i].unwrap_or(now),
            )
        };
        if let Some(o) = self.obs.as_mut() {
            o.complete(pod, task, now, a, b, c);
        }
    }

    /// Stamp a task as lost to a fault: the recovery-latency clock starts
    /// now and stops when the task executes again (`start_task`).
    pub fn fault_stamp(&mut self, task: TaskId) {
        self.task_fault_at[task.0 as usize] = self.now().as_millis();
        self.metrics.inc_id(self.c.tasks_lost_to_faults, 1);
    }

    // ---------------------------------------------------------------
    // node-fault bookkeeping (one copy; previously hand-rolled by the
    // spot-warning, node-failure and pod-start-failure paths)
    // ---------------------------------------------------------------

    /// Snapshot the live pods on `node` into the reusable members buffer
    /// (`workers_only` restricts to pool workers, the spot-drain case).
    /// Return the buffer with [`Kernel::put_members_buf`] when done.
    pub fn take_node_victims(&mut self, node: usize, workers_only: bool) -> Vec<PodId> {
        let mut victims = std::mem::take(&mut self.members_buf);
        victims.clear();
        victims.extend(
            (0..self.pods.len())
                .filter(|&i| {
                    self.pods.node[i] == Some(NodeId(node))
                        && !self.pods.is_terminal(i)
                        && (!workers_only || self.pods.pool_id(i).is_some())
                })
                .map(|i| PodId(i as u64)),
        );
        victims
    }

    pub fn put_members_buf(&mut self, buf: Vec<PodId>) {
        self.members_buf = buf;
    }

    /// A scheduled pod event is stale when the pod's node was reclaimed
    /// and its replacement (same index, new incarnation) arrived in the
    /// meantime. Defense-in-depth: chaos kills are synchronous, so pods
    /// die with their node — but any completion that slips through must
    /// not be credited against the new hardware.
    pub fn stale_node_event(&mut self, pod: PodId) -> bool {
        let Some(nid) = self.pods.node[pod.0 as usize] else {
            return false;
        };
        if self.pod_bound_inc[pod.0 as usize] != self.node_incarnation[nid.0] {
            self.chaos_stats.stale_drops += 1;
            self.metrics.inc_id(self.c.stale_node_events_dropped, 1);
            return true;
        }
        false
    }

    /// Replacement capacity arrived for a reclaimed/crashed node: bump the
    /// incarnation counter (so events bound to the old hardware read as
    /// stale) and reset every per-node fault flag.
    pub fn node_replaced(&mut self, node: usize) {
        self.node_incarnation[node] += 1;
        self.nodes[node].failed = false;
        self.nodes[node].cordoned = false;
        self.drain_pending[node] = false;
        self.blacklist_until[node] = SimTime::ZERO;
        self.node_fault_counts[node] = 0;
    }

    /// Blacklisting: a node that keeps failing pod starts is cordoned for
    /// the policy's blacklist window.
    pub fn note_node_fault(&mut self, node: usize) {
        self.node_fault_counts[node] += 1;
        let Some(ch) = &self.chaos else { return };
        let k = ch.policy.blacklist_after;
        let window = ch.policy.blacklist_ms;
        if k == 0 || self.node_fault_counts[node] < k {
            return;
        }
        if self.nodes[node].failed || self.nodes[node].cordoned {
            return; // already out of rotation
        }
        let now = self.now();
        self.nodes[node].cordoned = true;
        self.blacklist_until[node] = now + SimTime::from_millis(window);
        self.node_fault_counts[node] = 0;
        self.chaos_stats.blacklists += 1;
        self.metrics.inc_id(self.c.node_blacklists, 1);
        self.q
            .schedule_in(SimTime::from_millis(window), Ev::ChaosUncordon { node });
    }

    // ---------------------------------------------------------------
    // task execution
    // ---------------------------------------------------------------

    /// Start executing `task` inside `pod` at the current time.
    ///
    /// Chaos hooks (all inert on healthy runs): the remaining work may be
    /// less than the DAG duration (checkpoint-restart), a straggler node
    /// stretches it by its slowdown factor, a pending fault timestamp is
    /// folded into the recovery-latency summary, and straggling pool
    /// tasks get a speculation watch.
    pub fn start_task(&mut self, pod: PodId, task: TaskId) {
        let now = self.now();
        let nominal = self.task_work_left[task.0 as usize];
        let ttype = self.engine.dag().tasks[task.0 as usize].ttype;
        let slow = match self.pods.node[pod.0 as usize] {
            Some(nid) => self.node_slow[nid.0],
            None => 1.0,
        };
        let dur = if slow != 1.0 {
            SimTime::from_millis((nominal.as_millis() as f64 * slow).round() as u64)
        } else {
            nominal
        };
        // a speculative copy racing the original must not overwrite the
        // task's trace record — queueing delay is ready -> *first* start
        if self.task_running[task.0 as usize] == 0 {
            self.trace.started(task, pod.0, now);
        }
        if let Some(o) = self.obs.as_mut() {
            o.exec_start(pod, task, now);
        }
        self.task_running[task.0 as usize] += 1;
        self.record_running(ttype, 1);
        self.pods.executed[pod.0 as usize] += 1;
        self.current_task[pod.0 as usize] = Some(task);
        self.pod_io[pod.0 as usize] = IoPhase::Compute;
        self.pod_task_started_at[pod.0 as usize] = now;
        // isolation audit: a task starting on capacity owned by another
        // tenant is a pool-isolation violation (e.g. a mixed clustered
        // batch riding a foreign namespace's pod)
        if let (Some(iso), Some(nid)) = (&mut self.isolation, self.pods.node[pod.0 as usize]) {
            let tt = self.task_tenant.get(task.0 as usize).copied().unwrap_or(0);
            iso.note_task_start(tt, nid);
        }
        if self.chaos.is_some() {
            let fault_at = self.task_fault_at[task.0 as usize];
            if fault_at != NO_FAULT {
                self.task_fault_at[task.0 as usize] = NO_FAULT;
                self.chaos_stats
                    .recovery_latency
                    .add((now - SimTime::from_millis(fault_at)).as_secs_f64());
            }
        }
        self.q.schedule_at(
            now + SimTime::from_millis(self.cfg.exec_overhead_ms) + dur,
            Ev::TaskDone { pod, task },
        );
        // straggler watch: if the task is still running after spec_factor
        // x its nominal time, a speculative copy is launched (pools only)
        if let Some(ch) = &self.chaos {
            if ch.policy.speculative
                && ch.straggler.is_some()
                && !self.spec_launched[task.0 as usize]
                && self.pods.pool_id(pod.0 as usize).is_some()
            {
                let watch = SimTime::from_millis(
                    self.cfg.exec_overhead_ms
                        + (nominal.as_millis() as f64 * ch.policy.spec_factor).round() as u64,
                );
                self.q.schedule_at(now + watch, Ev::SpecCheck { pod, task });
            }
        }
    }

    /// Charge the compute a killed in-flight task burned, minus the
    /// checkpoint-restored fraction, and shrink the task's remaining work
    /// accordingly. `node` is where it ran (for de-slowing straggler time
    /// into work units).
    pub fn account_lost_work(&mut self, pod: PodId, task: TaskId, node: usize) {
        let exec_ms = self.run_exec_ms(pod);
        let frac = self
            .chaos
            .as_ref()
            .map(|c| c.policy.checkpoint_frac)
            .unwrap_or(0.0);
        // progress in work units (a straggler burns `slow` wall-ms per
        // work-ms), of which `frac` survives in the checkpoint
        let slow = self.node_slow[node].max(1.0);
        let work_done = (exec_ms as f64 / slow) as u64;
        let left = self.task_work_left[task.0 as usize].as_millis();
        let credit = ((work_done as f64 * frac) as u64).min(left.saturating_sub(1));
        self.task_work_left[task.0 as usize] = SimTime::from_millis(left - credit);
        let wasted = exec_ms.saturating_sub(credit);
        self.chaos_stats
            .add_waste(self.tenant_of(task).idx(), wasted);
        self.fault_stamp(task);
    }

    // ---------------------------------------------------------------
    // chaos recovery scheduling
    // ---------------------------------------------------------------

    /// Schedule a pool task's policy-driven re-dispatch — unless another
    /// copy of it is still executing (speculation): the live copy carries
    /// the work, and if that copy dies too, *its* kill path schedules the
    /// retry. Keeps the at-most-one-extra-copy contract.
    pub fn schedule_task_retry(&mut self, task: TaskId) {
        if self.task_running[task.0 as usize] > 0 {
            return;
        }
        let attempt = self.task_attempts[task.0 as usize];
        self.task_attempts[task.0 as usize] = attempt.saturating_add(1);
        let delay = self
            .chaos
            .as_ref()
            .map(|c| c.policy.backoff(attempt))
            .unwrap_or(SimTime::ZERO);
        self.chaos_stats.add_retry(self.tenant_of(task).idx());
        self.metrics.inc_id(self.c.chaos_retries, 1);
        self.q.schedule_in(delay, Ev::ChaosRetryTask { task });
    }

    /// Schedule a job batch's policy-driven re-creation (attempt count
    /// keyed on the batch's first task).
    pub fn schedule_batch_retry(&mut self, tasks: Vec<TaskId>) {
        debug_assert!(!tasks.is_empty());
        let key = tasks[0];
        let attempt = self.task_attempts[key.0 as usize];
        self.task_attempts[key.0 as usize] = attempt.saturating_add(1);
        let delay = self
            .chaos
            .as_ref()
            .map(|c| c.policy.backoff(attempt))
            .unwrap_or(SimTime::ZERO);
        self.chaos_stats.add_retry(self.tenant_of(key).idx());
        self.metrics.inc_id(self.c.chaos_retries, 1);
        self.q.schedule_in(delay, Ev::ChaosRetryBatch { tasks });
    }

    /// Sample + schedule the next fault of timed injector `i` (no-op for
    /// inert processes).
    pub fn schedule_next_fault(&mut self, i: usize) {
        let n = self.nodes.len();
        let Some(ch) = &mut self.chaos else { return };
        if let Some((delay, victim)) = ch.processes[i].next_fault(n) {
            self.q.schedule_in(
                delay,
                Ev::ChaosFault {
                    proc_idx: i as u8,
                    node: victim,
                },
            );
        }
    }

    // ---------------------------------------------------------------
    // data-plane plumbing
    // ---------------------------------------------------------------

    /// Drain the data plane's (re)schedules into the event queue.
    pub fn schedule_flow_events(&mut self, mut buf: Vec<FlowEvent>) {
        for ev in buf.drain(..) {
            let e = if ev.activate {
                Ev::FlowActivate {
                    flow: ev.flow,
                    gen: ev.gen,
                }
            } else {
                Ev::FlowDone {
                    flow: ev.flow,
                    gen: ev.gen,
                }
            };
            self.q.schedule_at(ev.at, e);
        }
        self.flow_buf = buf;
    }
}
