//! The **pool path**: auto-scalable worker-pool deployments fed by
//! broker queues (paper §3.3), plus the hybrid [`PoolsStrategy`] used in
//! the paper's experiments (§4.4).
//!
//! Event flow:
//! ```text
//!   task ready -> publish to type queue -> wake idle worker / autoscaler
//!   autoscale tick: desired replicas -> API: create/delete worker pods
//!   -> scheduler -> pod start -> worker loop: fetch/execute/ack
//! ```
//!
//! [`PoolPath`] is shared machinery: the hybrid strategy declares one
//! pool per pooled type, the generic strategy declares a single untyped
//! pool covering every type, and the job strategies carry an empty pool
//! set (so the routing table sends everything to the job path). Pools
//! are interned to dense [`PoolId`] indices at startup, so deployments,
//! idle-worker queues, queue-depth gauges and per-type routing are all
//! `Vec` lookups (EXPERIMENTS.md §Perf).

use crate::autoscale::{Autoscaler, PoolSpec};
use crate::broker::{Broker, PoolId, TenantId};
use crate::chaos::RecoveryPolicy;
use crate::engine::clustering::ClusteringConfig;
use crate::engine::Engine;
use crate::exec::config::SimConfig;
use crate::exec::job::JobPath;
use crate::exec::kernel::{Ev, Kernel};
use crate::exec::strategy::{ExecStrategy, StrategyState};
use crate::k8s::pod::{Payload, PodId, PodPhase};
use crate::k8s::resources::Resources;
use crate::metrics::{GaugeId, Registry};
use crate::obs::Actor;
use crate::sim::SimTime;
use crate::workflow::task::{TaskId, TypeId};
use std::collections::VecDeque;

/// Worker-pool machinery: the broker, per-pool deployment state, the
/// autoscaler, and the type -> pool routing table. Empty (zero pools)
/// for the pure job strategies.
pub struct PoolPath {
    pub broker: Broker,
    pub scaler: Option<Autoscaler>,
    /// Worker deployment state per pool: live pod set, kept sorted by
    /// `PodId` (ids are assigned monotonically, so insertion is a push;
    /// this preserves the old `BTreeSet` iteration order for scale-down).
    pub deployments: Vec<Vec<PodId>>,
    /// Idle running workers per pool (FIFO).
    pub idle_workers: Vec<VecDeque<PodId>>,
    /// The task type backing each pool (`None` for the generic pool).
    pub pool_type: Vec<Option<TypeId>>,
    /// Routing table: which pool (if any) a ready task of each type goes
    /// to. Replaces per-task string compares/clones in dispatch.
    pub pool_of_type: Vec<Option<PoolId>>,
    /// Pools in name order — the autoscale reconciliation applies desired
    /// counts in this order to stay bit-identical with the pre-interning
    /// code, which iterated a `BTreeMap<String, usize>`.
    pub pools_by_name: Vec<PoolId>,
    /// Pod template for the generic-pool model (max over all types).
    pub generic_requests: Resources,
    /// queue::<pool> gauge per PoolId.
    pub g_queue: Vec<GaugeId>,
    /// replicas::<pool> gauge per PoolId.
    pub g_replicas: Vec<GaugeId>,
    // reusable scratch buffers (§Perf)
    /// Idle-worker snapshot for scale-down.
    idle_buf: Vec<PodId>,
    /// Autoscale tick: backlog / current / desired per pool.
    backlog_buf: Vec<usize>,
    current_buf: Vec<usize>,
    desired_buf: Vec<usize>,
}

impl PoolPath {
    /// No pools at all: every type routes to the job path.
    pub fn none(n_types: usize) -> PoolPath {
        PoolPath {
            broker: Broker::new(),
            scaler: None,
            deployments: Vec::new(),
            idle_workers: Vec::new(),
            pool_type: Vec::new(),
            pool_of_type: vec![None; n_types],
            pools_by_name: Vec::new(),
            generic_requests: Resources::ZERO,
            g_queue: Vec::new(),
            g_replicas: Vec::new(),
            idle_buf: Vec::new(),
            backlog_buf: Vec::new(),
            current_buf: Vec::new(),
            desired_buf: Vec::new(),
        }
    }

    /// Finish construction once every pool is declared on the broker:
    /// size the per-pool tables, build the autoscaler, resolve the
    /// name-ordered reconciliation sequence and the per-pool gauges.
    pub fn finalize(&mut self, cfg: &SimConfig, specs: Vec<PoolSpec>, metrics: &mut Registry) {
        let n_pools = self.pool_type.len();
        self.scaler = (n_pools > 0).then(|| Autoscaler::new(cfg.autoscale.clone(), specs));
        self.deployments = vec![Vec::new(); n_pools];
        self.idle_workers = vec![VecDeque::new(); n_pools];
        let mut pools_by_name: Vec<PoolId> = (0..n_pools).map(|i| PoolId(i as u16)).collect();
        pools_by_name.sort_by(|a, b| self.broker.name(*a).cmp(self.broker.name(*b)));
        self.pools_by_name = pools_by_name;
        self.g_queue = (0..n_pools)
            .map(|i| {
                let name = self.broker.name(PoolId(i as u16));
                metrics.gauge_id(&format!("queue::{name}"))
            })
            .collect();
        self.g_replicas = (0..n_pools)
            .map(|i| {
                let name = self.broker.name(PoolId(i as u16));
                metrics.gauge_id(&format!("replicas::{name}"))
            })
            .collect();
    }

    pub fn n_pools(&self) -> usize {
        self.pool_type.len()
    }

    /// Flight recorder: a message left `pool`'s queue for `pod` (the
    /// remaining depth rides along as the event value).
    fn record_dequeue(&self, k: &mut Kernel, pool: PoolId, pod: PodId, now: SimTime) {
        if let Some(o) = k.obs.as_mut() {
            o.event(
                now,
                Actor::Broker,
                "dequeue",
                format!("{} -> pod {}", self.broker.name(pool), pod.0),
                self.broker.queue(pool).depth() as f64,
            );
        }
    }

    /// Record the current depth of a pool's queue.
    pub fn record_queue_depth(&mut self, k: &mut Kernel, pool: PoolId) {
        let now = k.now();
        let depth = self.broker.queue(pool).depth();
        k.metrics
            .set_id(self.g_queue[pool.idx()], now, depth as f64);
    }

    /// Publish a ready task to its pool queue and try to hand it to an
    /// idle worker.
    pub fn publish(&mut self, k: &mut Kernel, pool: PoolId, task: TaskId, tenant: TenantId) {
        self.broker.publish_for(pool, task, tenant);
        self.record_queue_depth(k, pool);
        self.wake_idle_worker(k, pool);
    }

    /// Fetch the next message a given worker is allowed to serve. Under a
    /// node-partitioning isolation policy a worker on a tenant-owned node
    /// draws only from that tenant's lane (pods on owned nodes must not
    /// execute foreign work); workers on shared nodes — and every worker
    /// when isolation is off or `shared` — use the plain stride-fair
    /// fetch, bit-identical to the pre-isolation path.
    fn fetch_for_worker(&mut self, k: &Kernel, pod: PodId, pool: PoolId) -> Option<TaskId> {
        let constrained = k.isolation.as_ref().filter(|i| i.constrains_fetch());
        match (constrained, k.pods.node[pod.0 as usize]) {
            (Some(iso), Some(node)) => match iso.node_owner(node) {
                Some(t) => self.broker.fetch_from(pool, TenantId(t)),
                None => self.broker.fetch(pool),
            },
            _ => self.broker.fetch(pool),
        }
    }

    /// Give an idle worker of `pool` a task, if any is queued.
    pub fn wake_idle_worker(&mut self, k: &mut Kernel, pool: PoolId) {
        if k.isolation.as_ref().is_some_and(|i| i.constrains_fetch()) {
            self.wake_idle_worker_constrained(k, pool);
            return;
        }
        while let Some(&pid) = self.idle_workers[pool.idx()].front() {
            // skip workers that were deleted while idle
            if k.pods.phase[pid.0 as usize] != PodPhase::Running {
                self.idle_workers[pool.idx()].pop_front();
                continue;
            }
            if let Some(task) = self.broker.fetch(pool) {
                self.idle_workers[pool.idx()].pop_front();
                let now = k.now();
                self.record_dequeue(k, pool, pid, now);
                k.q.schedule_at(
                    now + SimTime::from_millis(k.cfg.fetch_ms),
                    Ev::WorkerFetched { pod: pid, task },
                );
            }
            return;
        }
    }

    /// Isolation-partitioned variant of [`PoolPath::wake_idle_worker`]:
    /// different idle workers can reach different lanes (their nodes have
    /// different owners), so scan the FIFO for the first live worker whose
    /// lane has work instead of only probing the front.
    fn wake_idle_worker_constrained(&mut self, k: &mut Kernel, pool: PoolId) {
        // same lazy cleanup as the unconstrained path: deleted workers at
        // the front are dropped for good
        while let Some(&pid) = self.idle_workers[pool.idx()].front() {
            if k.pods.phase[pid.0 as usize] != PodPhase::Running {
                self.idle_workers[pool.idx()].pop_front();
            } else {
                break;
            }
        }
        for i in 0..self.idle_workers[pool.idx()].len() {
            let pid = self.idle_workers[pool.idx()][i];
            if k.pods.phase[pid.0 as usize] != PodPhase::Running {
                continue;
            }
            if let Some(task) = self.fetch_for_worker(k, pid, pool) {
                self.idle_workers[pool.idx()].remove(i);
                let now = k.now();
                self.record_dequeue(k, pool, pid, now);
                k.q.schedule_at(
                    now + SimTime::from_millis(k.cfg.fetch_ms),
                    Ev::WorkerFetched { pod: pid, task },
                );
                return;
            }
        }
    }

    /// A running worker has no task in hand: fetch the next message or
    /// park in the idle queue. Shared by pod start and post-completion
    /// advance (previously two hand-copied branches).
    pub fn fetch_or_idle(&mut self, k: &mut Kernel, pod: PodId, pool: PoolId) {
        let now = k.now();
        if let Some(task) = self.fetch_for_worker(k, pod, pool) {
            self.record_dequeue(k, pool, pod, now);
            k.q.schedule_at(
                now + SimTime::from_millis(k.cfg.fetch_ms),
                Ev::WorkerFetched { pod, task },
            );
        } else {
            self.idle_workers[pool.idx()].push_back(pod);
        }
    }

    /// Pool path: create a worker pod for a deployment scale-up. The pod
    /// template is the pool's (VPA right-sizes it once enough samples of
    /// the backing type completed, §5).
    pub fn create_worker(&mut self, k: &mut Kernel, pool: PoolId) {
        let requests = match self.pool_type[pool.idx()] {
            None => self.generic_requests,
            Some(ty) => {
                let t = &k.engine.dag().types[ty.0 as usize];
                // §5 VPA: once enough of this type has run, right-size
                // new workers to the observed CPU usage
                if k.cfg.autoscale.vpa
                    && k.completed_by_type[ty.0 as usize] >= k.cfg.autoscale.vpa_min_samples
                {
                    Resources::new(t.cpu_used_m, t.requests.mem_mb)
                } else {
                    t.requests
                }
            }
        };
        let pid = k.new_pod(Payload::Worker { pool }, requests);
        let dep = &mut self.deployments[pool.idx()];
        if let Some(&last) = dep.last() {
            debug_assert!(last < pid, "pod ids must be monotone");
        }
        dep.push(pid);
        let done = k.api.admit(k.now());
        k.q.schedule_at(done, Ev::PodCreated { pod: pid });
    }

    /// Drop a terminated worker from its deployment's live set.
    pub fn forget_worker(&mut self, pool: PoolId, pid: PodId) {
        let dep = &mut self.deployments[pool.idx()];
        if let Ok(i) = dep.binary_search(&pid) {
            dep.remove(i);
        }
    }

    /// Rescale the pool quota to the surviving node capacity (chaos runs
    /// only — legacy `node_events` keep the original quota semantics).
    pub fn update_chaos_quota(&mut self, k: &mut Kernel) {
        let Some(ch) = &k.chaos else { return };
        let base = ch.base_quota;
        if self.scaler.is_none() {
            return;
        }
        let total: u64 = k.nodes.iter().map(|n| n.capacity.cpu_m).sum();
        let live: u64 = k
            .nodes
            .iter()
            .filter(|n| !n.failed)
            .map(|n| n.capacity.cpu_m)
            .sum();
        let quota = ((base as u128 * live as u128) / total.max(1) as u128) as u64;
        self.scaler.as_mut().unwrap().set_quota(quota);
    }
}

// ---------------------------------------------------------------
// pool-side strategy mechanics that terminate pods / re-enter the
// scheduler, and therefore need the whole strategy state
// ---------------------------------------------------------------
impl StrategyState {
    /// Post-completion advance of a pool worker: ack the delivery, then
    /// drain, fetch the next message, or go idle. Shared by the normal
    /// completion path and the speculative-loser path.
    pub fn advance_worker(&mut self, k: &mut Kernel, pod: PodId, pool: PoolId) {
        self.pools.broker.ack(pool);
        self.pools.record_queue_depth(k, pool);
        if k.pods.phase[pod.0 as usize] == PodPhase::Draining {
            self.terminate_pod(k, pod, PodPhase::Succeeded);
        } else {
            self.pools.fetch_or_idle(k, pod, pool);
        }
    }

    /// Autoscaler reconciliation: publish VPA templates, poll desired
    /// replica counts from the aggregate backlog, and apply them in pool
    /// name order.
    pub fn autoscale(&mut self, k: &mut Kernel) {
        let now = k.now();
        // VPA: publish right-sized pod templates to the scaler once a
        // type's usage estimate is trustworthy
        if k.cfg.autoscale.vpa {
            if let Some(s) = &mut self.pools.scaler {
                for pool in 0..self.pools.pool_type.len() {
                    let Some(ty) = self.pools.pool_type[pool] else { continue };
                    let t = &k.engine.dag().types[ty.0 as usize];
                    if k.completed_by_type[ty.0 as usize] >= k.cfg.autoscale.vpa_min_samples
                        && t.cpu_used_m != t.requests.cpu_m
                    {
                        s.set_pool_requests(pool, Resources::new(t.cpu_used_m, t.requests.mem_mb));
                    }
                }
            }
        }
        if self.pools.scaler.is_none() {
            return;
        }
        let n_pools = self.pools.deployments.len();
        let mut backlogs = std::mem::take(&mut self.pools.backlog_buf);
        let mut current = std::mem::take(&mut self.pools.current_buf);
        let mut desired = std::mem::take(&mut self.pools.desired_buf);
        backlogs.clear();
        current.clear();
        for pool in 0..n_pools {
            backlogs.push(self.pools.broker.queue(PoolId(pool as u16)).backlog());
            let have = self.pools.deployments[pool].len();
            current.push(have);
            k.metrics
                .set_id(self.pools.g_replicas[pool], now, have as f64);
        }
        self.pools
            .scaler
            .as_mut()
            .unwrap()
            .poll_into(now, &backlogs, &current, &mut desired);
        let pools_by_name = std::mem::take(&mut self.pools.pools_by_name);
        for &pool in &pools_by_name {
            let want = desired[pool.idx()];
            let have = self.pools.deployments[pool.idx()].len();
            if want != have {
                if let Some(o) = k.obs.as_mut() {
                    o.event(
                        now,
                        Actor::Autoscaler,
                        if want > have { "scale_up" } else { "scale_down" },
                        format!(
                            "{}: {} -> {} (backlog {})",
                            self.pools.broker.name(pool),
                            have,
                            want,
                            backlogs[pool.idx()]
                        ),
                        want as f64,
                    );
                }
            }
            if want > have {
                for _ in 0..(want - have) {
                    self.pools.create_worker(k, pool);
                }
            } else if want < have {
                self.scale_down(k, pool, have - want);
            }
        }
        self.pools.pools_by_name = pools_by_name;
        self.pools.backlog_buf = backlogs;
        self.pools.current_buf = current;
        self.pools.desired_buf = desired;
        self.run_scheduler(k);
    }

    /// Remove `n` workers from a pool: pending pods first, then idle
    /// running workers, then mark busy workers Draining.
    fn scale_down(&mut self, k: &mut Kernel, pool: PoolId, n: usize) {
        let mut members = std::mem::take(&mut k.members_buf);
        members.clear();
        members.extend_from_slice(&self.pools.deployments[pool.idx()]);
        let mut idle = std::mem::take(&mut self.pools.idle_buf);
        idle.clear();
        idle.extend(self.pools.idle_workers[pool.idx()].iter().copied());
        self.scale_down_phases(k, pool, n, &members, &idle);
        k.members_buf = members;
        self.pools.idle_buf = idle;
    }

    fn scale_down_phases(
        &mut self,
        k: &mut Kernel,
        pool: PoolId,
        n: usize,
        members: &[PodId],
        idle: &[PodId],
    ) {
        let mut remaining = n;
        // 1. pending (never scheduled) pods
        for &pid in members {
            if remaining == 0 {
                return;
            }
            if k.pods.phase[pid.0 as usize] == PodPhase::Pending {
                self.terminate_pod(k, pid, PodPhase::Deleted);
                remaining -= 1;
            }
        }
        // also starting pods that haven't begun work
        for &pid in members {
            if remaining == 0 {
                return;
            }
            if k.pods.phase[pid.0 as usize] == PodPhase::Starting {
                self.terminate_pod(k, pid, PodPhase::Deleted);
                remaining -= 1;
            }
        }
        // 2. idle running workers
        for &pid in idle {
            if remaining == 0 {
                return;
            }
            if k.pods.phase[pid.0 as usize] == PodPhase::Running {
                self.pools.idle_workers[pool.idx()].retain(|&p| p != pid);
                self.terminate_pod(k, pid, PodPhase::Deleted);
                remaining -= 1;
            }
        }
        // 3. drain busy workers (terminate after current task)
        for &pid in members {
            if remaining == 0 {
                return;
            }
            let phase = &mut k.pods.phase[pid.0 as usize];
            if *phase == PodPhase::Running {
                *phase = PodPhase::Draining;
                remaining -= 1;
            }
        }
    }
}

/// §3.3: worker pools for `pooled_types`; other types run as jobs (the
/// paper's hybrid setup — pools for the three parallel stages, jobs for
/// the serial tail).
pub struct PoolsStrategy {
    state: StrategyState,
}

impl PoolsStrategy {
    pub fn build(
        pooled_types: &[String],
        engine: &Engine,
        cfg: &SimConfig,
        metrics: &mut Registry,
    ) -> PoolsStrategy {
        let n_types = engine.dag().types.len();
        let mut pools = PoolPath::none(n_types);
        let mut specs: Vec<PoolSpec> = Vec::new();
        for t in pooled_types {
            let ty = engine
                .dag()
                .type_id(t)
                .unwrap_or_else(|| panic!("pooled type '{t}' not in workflow"));
            let id = pools.broker.declare(t);
            assert_eq!(id.idx(), pools.pool_type.len(), "duplicate pooled type '{t}'");
            pools.pool_type.push(Some(ty));
            pools.pool_of_type[ty.0 as usize] = Some(id);
            specs.push(PoolSpec {
                name: t.clone(),
                requests: engine.dag().types[ty.0 as usize].requests,
            });
        }
        pools.finalize(cfg, specs, metrics);
        PoolsStrategy {
            state: StrategyState {
                jobs: JobPath::new(ClusteringConfig::none()),
                pools,
            },
        }
    }
}

impl ExecStrategy for PoolsStrategy {
    fn name(&self) -> &'static str {
        "worker-pools"
    }

    fn state(&mut self) -> &mut StrategyState {
        &mut self.state
    }

    fn state_ref(&self) -> &StrategyState {
        &self.state
    }

    /// Pool tasks are queue deliveries, so a straggling task can be
    /// speculatively duplicated (first completion wins).
    fn default_recovery(&self) -> RecoveryPolicy {
        RecoveryPolicy {
            speculative: true,
            ..RecoveryPolicy::default()
        }
    }
}
