//! The **job path**: one Kubernetes Job (-> one Pod) per task batch
//! (paper §3.2), plus the [`JobStrategy`] that runs a workflow purely on
//! it.
//!
//! Event flow:
//! ```text
//!   task ready -> batcher (maybe buffer) -> API: create Job
//!   -> job controller reconcile -> API: create Pod
//!   -> scheduler (may back off!) -> pod start (~2 s)
//!   -> execute batch sequentially -> pod terminates, free node
//! ```
//!
//! [`JobPath`] is shared machinery: the clustered strategy drives it with
//! real batching rules, the hybrid worker-pools strategy uses it for the
//! serial (non-pooled) stages, and the plain job strategy drives it with
//! [`ClusteringConfig::none`] so every task is a singleton batch. The
//! §5 pending-pod throttle (`max_pending_pods`) also lives here.

use crate::chaos::RecoveryPolicy;
use crate::engine::clustering::{Batcher, ClusteringConfig};
use crate::engine::Engine;
use crate::exec::kernel::{Ev, Kernel};
use crate::exec::pools::PoolPath;
use crate::exec::strategy::{ExecStrategy, StrategyState};
use crate::k8s::pod::Payload;
use crate::sim::SimTime;
use crate::workflow::task::{TaskId, TypeId};
use std::collections::VecDeque;

/// Job-submission machinery: clustering buffers and the pending-pod
/// throttle. Every strategy owns one (pool strategies use it for their
/// non-pooled types).
pub struct JobPath {
    pub batcher: Batcher,
    /// Job batches deferred by the pending-pod throttle (§5 future work).
    pub throttle_wait: VecDeque<Vec<TaskId>>,
    /// Pods created but not yet bound (throttle accounting).
    pub jobs_in_flight: usize,
}

impl JobPath {
    pub fn new(cfg: ClusteringConfig) -> JobPath {
        JobPath {
            batcher: Batcher::new(cfg),
            throttle_wait: VecDeque::new(),
            jobs_in_flight: 0,
        }
    }

    /// Job path: create a Job for a batch of same-type tasks, honouring the
    /// pending-pod throttle (§5 future work) when configured.
    pub fn create_job(&mut self, k: &mut Kernel, tasks: Vec<TaskId>) {
        debug_assert!(!tasks.is_empty());
        if let Some(cap) = k.cfg.max_pending_pods {
            if self.jobs_in_flight >= cap {
                self.throttle_wait.push_back(tasks);
                k.metrics.inc("throttled_batches", 1);
                return;
            }
        }
        self.create_job_now(k, tasks);
    }

    fn create_job_now(&mut self, k: &mut Kernel, tasks: Vec<TaskId>) {
        let requests = k.engine.dag().type_of(tasks[0]).requests;
        let pid = k.new_pod(Payload::JobBatch { tasks }, requests);
        self.jobs_in_flight += 1;
        k.metrics.inc("jobs_created", 1);
        // API round-trip for the Job object
        let done = k.api.admit(k.now());
        k.q.schedule_at(done, Ev::JobAdmitted { pod: pid });
    }

    /// A job pod left the pending pipeline: admit deferred batches.
    pub fn job_unblocked(&mut self, k: &mut Kernel) {
        debug_assert!(self.jobs_in_flight > 0);
        self.jobs_in_flight -= 1;
        if let Some(cap) = k.cfg.max_pending_pods {
            while self.jobs_in_flight < cap {
                match self.throttle_wait.pop_front() {
                    Some(batch) => self.create_job_now(k, batch),
                    None => break,
                }
            }
        }
    }

    /// A clustering partial-batch timeout fired: flush the partial batch
    /// if the deadline is still current.
    pub fn flush_timer(&mut self, k: &mut Kernel, type_idx: u16, deadline: SimTime) {
        let batch = self.batcher.timer_fired(TypeId(type_idx), deadline);
        if let Some(batch) = batch {
            self.create_job(k, batch);
        }
    }
}

/// §3.2: one task -> one Kubernetes Job -> one Pod. No queues, no pools:
/// the [`JobPath`] with [`ClusteringConfig::none`] flushes every ready
/// task as a singleton batch.
pub struct JobStrategy {
    state: StrategyState,
}

impl JobStrategy {
    pub fn build(engine: &Engine) -> JobStrategy {
        JobStrategy {
            state: StrategyState {
                jobs: JobPath::new(ClusteringConfig::none()),
                pools: PoolPath::none(engine.dag().types.len()),
            },
        }
    }
}

impl ExecStrategy for JobStrategy {
    fn name(&self) -> &'static str {
        "job-based"
    }

    fn state(&mut self) -> &mut StrategyState {
        &mut self.state
    }

    fn state_ref(&self) -> &StrategyState {
        &self.state
    }

    /// Job pods cannot be speculatively duplicated (the unit of execution
    /// is the whole pod), so the default policy leans on retry back-off,
    /// blacklisting and checkpoint-restart alone.
    fn default_recovery(&self) -> RecoveryPolicy {
        RecoveryPolicy::default()
    }
}
