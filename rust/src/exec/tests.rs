//! Unit tests for the execution subsystem (moved intact from the old
//! `models/driver.rs` god-object — same configurations, same assertions,
//! so the decomposition is checked against the pre-refactor behavior).

use super::{run, run_fleet, ExecModel, SimConfig};
use crate::engine::clustering::ClusteringConfig;
use crate::fleet::FleetPlan;
use crate::sim::SimTime;
use crate::workflow::dag::Dag;
use crate::workflow::montage::{generate, MontageConfig};
use crate::workflow::task::TaskId;

fn small_dag() -> Dag {
    generate(&MontageConfig {
        grid_w: 3,
        grid_h: 3,
        diagonals: true,
        seed: 1,
    })
}

#[test]
fn job_based_completes_small_workflow() {
    let res = run(small_dag(), ExecModel::JobBased, SimConfig::with_nodes(4));
    assert!(res.makespan > SimTime::ZERO);
    // every task got its own pod
    assert_eq!(res.pods_created as usize, small_dag().len());
    assert!(res.avg_running_tasks > 0.0);
    assert!(res.sim_events > 0);
}

#[test]
fn clustered_uses_fewer_pods() {
    let dag = small_dag();
    let n = dag.len();
    let res = run(
        dag,
        ExecModel::Clustered(ClusteringConfig::paper_default()),
        SimConfig::with_nodes(4),
    );
    assert!(
        (res.pods_created as usize) < n,
        "clustering must reduce pod count: {} vs {n}",
        res.pods_created
    );
}

#[test]
fn worker_pools_completes() {
    let res = run(
        small_dag(),
        ExecModel::paper_hybrid_pools(),
        SimConfig::with_nodes(4),
    );
    assert!(res.makespan > SimTime::ZERO);
    assert!(res.avg_running_tasks > 0.0);
}

#[test]
fn all_tasks_traced_exactly_once() {
    for model in [
        ExecModel::JobBased,
        ExecModel::Clustered(ClusteringConfig::paper_default()),
        ExecModel::paper_hybrid_pools(),
    ] {
        let dag = small_dag();
        let n = dag.len();
        let res = run(dag, model, SimConfig::with_nodes(4));
        assert_eq!(res.trace.records.len(), n);
        for r in &res.trace.records {
            assert!(r.started_at.is_some(), "{:?} never started", r.task);
            assert!(r.finished_at.is_some(), "{:?} never finished", r.task);
            assert!(r.started_at.unwrap() >= r.ready_at);
            assert!(r.finished_at.unwrap() > r.started_at.unwrap());
        }
    }
}

#[test]
fn dependencies_respected_in_trace() {
    let dag = small_dag();
    let succs: Vec<(TaskId, Vec<TaskId>)> = (0..dag.len())
        .map(|i| {
            let t = TaskId(i as u32);
            (t, dag.successors(t).to_vec())
        })
        .collect();
    let res = run(dag, ExecModel::JobBased, SimConfig::with_nodes(4));
    for (t, ss) in succs {
        let t_fin = res.trace.record(t).unwrap().finished_at.unwrap();
        for s in ss {
            let s_start = res.trace.record(s).unwrap().started_at.unwrap();
            assert!(
                s_start >= t_fin,
                "dependency violated: {s:?} started before {t:?} finished"
            );
        }
    }
}

#[test]
fn pools_beat_plain_jobs_on_parallel_stage_heavy_workflow() {
    let mk = || {
        generate(&MontageConfig {
            grid_w: 6,
            grid_h: 6,
            diagonals: true,
            seed: 2,
        })
    };
    let jobs = run(mk(), ExecModel::JobBased, SimConfig::with_nodes(4));
    let pools = run(mk(), ExecModel::paper_hybrid_pools(), SimConfig::with_nodes(4));
    assert!(
        pools.makespan < jobs.makespan,
        "pools {} vs jobs {}",
        pools.makespan,
        jobs.makespan
    );
}

#[test]
fn deterministic_given_seed() {
    let a = run(small_dag(), ExecModel::JobBased, SimConfig::with_nodes(4));
    let b = run(small_dag(), ExecModel::JobBased, SimConfig::with_nodes(4));
    assert_eq!(a.makespan, b.makespan);
    assert_eq!(a.pods_created, b.pods_created);
    assert_eq!(a.api_requests, b.api_requests);
}

#[test]
fn generic_pool_completes_but_wastes_resources() {
    // wide parallel stages: the generic pod template (max requests over
    // all types = mAdd's 2000m) halves the worker slots (§3.3)
    let mk = || {
        generate(&MontageConfig {
            grid_w: 10,
            grid_h: 10,
            diagonals: true,
            seed: 4,
        })
    };
    let dag = mk();
    let n = dag.len();
    let generic = run(dag, ExecModel::GenericPool, SimConfig::with_nodes(4));
    assert_eq!(generic.trace.records.len(), n);
    let typed = run(
        mk(),
        ExecModel::WorkerPools {
            pooled_types: crate::workflow::montage::TYPE_NAMES
                .iter()
                .map(|s| s.to_string())
                .collect(),
        },
        SimConfig::with_nodes(4),
    );
    assert!(
        typed.makespan < generic.makespan,
        "typed {} vs generic {}",
        typed.makespan,
        generic.makespan
    );
}

#[test]
fn job_throttle_cuts_backoffs_and_makespan() {
    // §5 future work: "improvement of the job queuing mechanism in the
    // job-based model to reduce the number of requested Pods, thus
    // mitigating the main flaw of the model" — confirmed.
    let mk = || {
        generate(&MontageConfig {
            grid_w: 8,
            grid_h: 8,
            diagonals: true,
            seed: 4,
        })
    };
    let mut throttled_cfg = SimConfig::with_nodes(4);
    throttled_cfg.max_pending_pods = Some(8);
    let throttled = run(mk(), ExecModel::JobBased, throttled_cfg);
    let unthrottled = run(mk(), ExecModel::JobBased, SimConfig::with_nodes(4));
    assert_eq!(throttled.trace.records.len(), mk().len());
    assert!(
        throttled.sched_backoffs < unthrottled.sched_backoffs / 2,
        "throttle should slash back-offs: {} vs {}",
        throttled.sched_backoffs,
        unthrottled.sched_backoffs
    );
    assert!(
        throttled.makespan <= unthrottled.makespan,
        "throttle should not slow the run: {} vs {}",
        throttled.makespan,
        unthrottled.makespan
    );
    assert!(throttled.metrics.counter("throttled_batches") > 0);
}

#[test]
fn vpa_rightsizing_speeds_up_pools() {
    // §5 future work: with VPA, workers request observed usage
    // (mDiffFit 300m vs 500m requested) -> more fit per node
    let mk = || {
        generate(&MontageConfig {
            grid_w: 14,
            grid_h: 14,
            diagonals: true,
            seed: 6,
        })
    };
    let mut vpa_cfg = SimConfig::with_nodes(4);
    vpa_cfg.autoscale.vpa = true;
    let with_vpa = run(mk(), ExecModel::paper_hybrid_pools(), vpa_cfg);
    let without = run(mk(), ExecModel::paper_hybrid_pools(), SimConfig::with_nodes(4));
    assert_eq!(with_vpa.trace.records.len(), mk().len());
    assert!(
        with_vpa.makespan < without.makespan,
        "VPA {} vs {}",
        with_vpa.makespan,
        without.makespan
    );
    // capacity still never exceeded
    let cap = 4.0 * 4000.0;
    for &(_, v) in with_vpa.metrics.gauge("cpu_allocated_m").unwrap().points() {
        assert!(v <= cap + 1e-9);
    }
}

#[test]
fn node_failure_recovers_all_tasks() {
    for model in [
        ExecModel::JobBased,
        ExecModel::Clustered(ClusteringConfig::paper_default()),
        ExecModel::paper_hybrid_pools(),
    ] {
        let dag = small_dag();
        let n = dag.len();
        let mut cfg = SimConfig::with_nodes(4);
        // node 0 dies mid-run, comes back much later
        cfg.node_events = vec![(30_000, 0, false), (200_000, 0, true)];
        let res = run(dag, model.clone(), cfg);
        assert_eq!(res.trace.records.len(), n, "{}", model.name());
        assert!(res.metrics.counter("node_failures") == 1);
        for r in &res.trace.records {
            assert!(r.finished_at.is_some(), "{:?} lost", r.task);
        }
    }
}

fn two_instance_plan(n_a: u32, n_b: u32, arrival_b_ms: u64, cap: Option<usize>) -> FleetPlan {
    FleetPlan {
        instances: vec![
            crate::fleet::InstanceSpec {
                tenant: 0,
                arrival_ms: 0,
                first_task: 0,
                n_tasks: n_a,
            },
            crate::fleet::InstanceSpec {
                tenant: 1,
                arrival_ms: arrival_b_ms,
                first_task: n_a,
                n_tasks: n_b,
            },
        ],
        tenant_weights: vec![1, 1],
        max_in_flight: cap,
    }
}

#[test]
fn fleet_two_instances_complete_concurrently() {
    let (a, b) = (small_dag(), small_dag());
    let (n_a, n_b) = (a.len() as u32, b.len() as u32);
    let union = Dag::disjoint_union(&[a, b]);
    let plan = two_instance_plan(n_a, n_b, 30_000, None);
    let (res, outcomes) = run_fleet(
        union,
        ExecModel::paper_hybrid_pools(),
        SimConfig::with_nodes(4),
        &plan,
    );
    assert_eq!(res.trace.records.len(), (n_a + n_b) as usize);
    assert_eq!(outcomes.len(), 2);
    for o in &outcomes {
        assert!(o.admitted >= o.arrival, "admitted before arrival");
        assert!(o.finished > o.admitted, "finished before admitted");
    }
    // no cap: admission is immediate at arrival
    assert_eq!(outcomes[0].admitted, SimTime::ZERO);
    assert_eq!(outcomes[1].admitted, SimTime::from_millis(30_000));
    // the second instance overlaps the first (shared cluster, not serial)
    assert!(outcomes[1].admitted < outcomes[0].finished);
}

#[test]
fn fleet_admission_cap_serializes_instances() {
    let (a, b) = (small_dag(), small_dag());
    let (n_a, n_b) = (a.len() as u32, b.len() as u32);
    let union = Dag::disjoint_union(&[a, b]);
    let plan = two_instance_plan(n_a, n_b, 30_000, Some(1));
    let (res, outcomes) = run_fleet(
        union,
        ExecModel::paper_hybrid_pools(),
        SimConfig::with_nodes(4),
        &plan,
    );
    assert_eq!(res.trace.records.len(), (n_a + n_b) as usize);
    // cap 1: the second instance waits for the first to finish
    assert!(outcomes[1].admitted >= outcomes[0].finished);
    assert!(outcomes[1].admitted > outcomes[1].arrival, "queued at the cap");
    assert_eq!(res.metrics.counter("instances_admitted"), 2);
    assert_eq!(res.metrics.counter("instances_completed"), 2);
}

#[test]
fn fleet_works_under_every_model() {
    for model in [
        ExecModel::JobBased,
        ExecModel::Clustered(ClusteringConfig::paper_default()),
        ExecModel::paper_hybrid_pools(),
        ExecModel::GenericPool,
    ] {
        let (a, b) = (small_dag(), small_dag());
        let (n_a, n_b) = (a.len() as u32, b.len() as u32);
        let union = Dag::disjoint_union(&[a, b]);
        let plan = two_instance_plan(n_a, n_b, 10_000, None);
        let (res, outcomes) = run_fleet(union, model.clone(), SimConfig::with_nodes(4), &plan);
        assert_eq!(
            res.trace.records.len(),
            (n_a + n_b) as usize,
            "{}",
            model.name()
        );
        assert!(outcomes.iter().all(|o| o.finished > o.admitted));
    }
}

#[test]
fn chaos_every_model_completes_under_heavy_churn() {
    // spot reclaims, crashes, flaky pod starts and stragglers all at
    // once: every model must still finish every task exactly once,
    // and the accounting must show the faults actually happened.
    for model in [
        ExecModel::JobBased,
        ExecModel::Clustered(ClusteringConfig::paper_default()),
        ExecModel::paper_hybrid_pools(),
        ExecModel::GenericPool,
    ] {
        let dag = generate(&MontageConfig {
            grid_w: 5,
            grid_h: 5,
            diagonals: true,
            seed: 3,
        });
        let n = dag.len();
        let mut cfg = SimConfig::with_nodes(4);
        cfg.seed = 9;
        cfg.chaos =
            crate::chaos::ChaosConfig::parse_spec("spot:4,crash:2,pod:0.25,straggler:0.3")
                .unwrap();
        let res = run(dag, model.clone(), cfg);
        let name = model.name();
        assert_eq!(res.trace.records.len(), n, "{name}: records");
        for r in &res.trace.records {
            assert!(r.finished_at.is_some(), "{name}: {:?} lost", r.task);
        }
        assert!(res.chaos.enabled, "{name}");
        assert!(res.chaos.faults_total() > 0, "{name}: no faults injected");
        assert!(res.chaos.wasted_ms > 0, "{name}: no waste accounted");
        assert!(res.chaos.goodput() < 1.0, "{name}: goodput must dip");
        assert!(res.chaos.goodput() > 0.0, "{name}");
    }
}

#[test]
fn chaos_spot_churn_inflates_makespan() {
    let mk = || {
        generate(&MontageConfig {
            grid_w: 6,
            grid_h: 6,
            diagonals: true,
            seed: 2,
        })
    };
    let healthy = run(mk(), ExecModel::paper_hybrid_pools(), SimConfig::with_nodes(4));
    let mut cfg = SimConfig::with_nodes(4);
    cfg.seed = 5;
    cfg.chaos = crate::chaos::ChaosConfig::parse_spec("spot:6,crash:3").unwrap();
    let churned = run(mk(), ExecModel::paper_hybrid_pools(), cfg);
    assert!(
        churned.makespan > healthy.makespan,
        "churn {} vs healthy {}",
        churned.makespan,
        healthy.makespan
    );
    assert!(healthy.chaos.wasted_ms == 0 && !healthy.chaos.enabled);
}

#[test]
fn legacy_pod_failure_prob_is_migrated_onto_the_chaos_engine() {
    // the deprecated knob must keep injecting failures — now routed
    // through the PodFailure injector with waste + retry accounting
    let dag = small_dag();
    let n = dag.len();
    let mut cfg = SimConfig::with_nodes(4);
    cfg.pod_failure_prob = 0.3;
    cfg.seed = 13;
    let res = run(dag, ExecModel::JobBased, cfg);
    assert_eq!(res.trace.records.len(), n);
    assert!(res.metrics.counter("pod_failures") > 0);
    assert!(res.chaos.enabled, "legacy knob must enable the subsystem");
    assert_eq!(
        res.chaos.pod_failures,
        res.metrics.counter("pod_failures"),
        "chaos accounting mirrors the metric"
    );
    assert!(res.chaos.retries > 0, "failed batches are retried");
    assert!(res.chaos.wasted_ms > 0, "burned pod starts are waste");
}

#[test]
fn fleet_under_chaos_drains_and_stamps_every_instance() {
    // regression (fleet accounting under retries): per-instance
    // outstanding counters must not drift when tasks fail and re-enter
    // the queue — a faulty fleet run still drains, and every instance
    // gets admission + completion stamps. (run_fleet panics on any
    // unstamped instance.)
    let (a, b) = (small_dag(), small_dag());
    let (n_a, n_b) = (a.len() as u32, b.len() as u32);
    let union = Dag::disjoint_union(&[a, b]);
    let plan = two_instance_plan(n_a, n_b, 20_000, None);
    let mut cfg = SimConfig::with_nodes(4);
    cfg.seed = 21;
    cfg.chaos =
        crate::chaos::ChaosConfig::parse_spec("pod:0.25,crash:6,straggler:0.5").unwrap();
    let (res, outcomes) = run_fleet(union, ExecModel::paper_hybrid_pools(), cfg, &plan);
    assert_eq!(outcomes.len(), 2);
    for o in &outcomes {
        assert!(o.finished > o.admitted);
    }
    assert_eq!(res.metrics.counter("instances_completed"), 2);
    assert_eq!(res.trace.records.len(), (n_a + n_b) as usize);
    assert!(res.chaos.faults_total() > 0, "churn must actually occur");
    // per-tenant resilience lanes are sized; task-attributable waste
    // lands in them, shared worker-crash waste only in the total
    assert_eq!(res.chaos.wasted_ms_by_tenant.len(), 2);
    assert!(
        res.chaos.wasted_ms_by_tenant.iter().sum::<u64>() <= res.chaos.wasted_ms,
        "lanes cannot exceed the total"
    );
}

fn data_cfg(nodes: usize, spec: &str) -> SimConfig {
    let mut cfg = SimConfig::with_nodes(nodes);
    cfg.data = Some(crate::data::DataConfig::parse_spec(spec).unwrap());
    cfg
}

#[test]
fn data_plane_every_model_completes_and_accounts_bytes() {
    for model in [
        ExecModel::JobBased,
        ExecModel::Clustered(ClusteringConfig::paper_default()),
        ExecModel::paper_hybrid_pools(),
        ExecModel::GenericPool,
    ] {
        let dag = small_dag();
        let n = dag.len();
        let res = run(dag, model.clone(), data_cfg(4, "nfs:1,cache:4"));
        let name = model.name();
        assert_eq!(res.trace.records.len(), n, "{name}: records");
        for r in &res.trace.records {
            assert!(r.finished_at.is_some(), "{name}: {:?} lost", r.task);
            assert!(r.started_at.unwrap() >= r.ready_at, "{name}");
            assert!(r.finished_at.unwrap() > r.started_at.unwrap(), "{name}");
        }
        assert!(res.data.enabled, "{name}");
        assert!(res.data.bytes_in > 0, "{name}: no stage-in traffic");
        assert!(res.data.bytes_out > 0, "{name}: no stage-out traffic");
        assert!(res.data.transfers > 0, "{name}");
        assert!(res.data.compute_ms > 0, "{name}");
        assert!(res.data.io_ms > 0, "{name}: transfers must take time");
        // every task stages in exactly once on a healthy run
        assert_eq!(res.data.stage_ins, n, "{name}");
    }
}

#[test]
fn data_plane_slows_the_run_and_the_default_stays_inert() {
    let base = SimConfig::with_nodes(4);
    assert!(base.data.is_none(), "data plane must be opt-in");
    let plain = run(small_dag(), ExecModel::paper_hybrid_pools(), base);
    assert!(!plain.data.enabled);
    assert_eq!(plain.data.bytes_in, 0);
    // a constrained shared link must cost wall-clock time
    let with_data = run(
        small_dag(),
        ExecModel::paper_hybrid_pools(),
        data_cfg(4, "nfs:0.5,cache:4"),
    );
    assert!(
        with_data.makespan > plain.makespan,
        "I/O pressure must show up: {} vs {}",
        with_data.makespan,
        plain.makespan
    );
}

#[test]
fn warm_pool_caches_beat_cold_job_pods_on_bytes_and_stage_in() {
    // the ISSUE's acceptance asymmetry: long-lived workers keep their
    // node-local caches across tasks, job pods always start cold — at
    // constrained NFS bandwidth pools move fewer bytes and collapse
    // the stage-in tail.
    let mk = || {
        generate(&MontageConfig {
            grid_w: 6,
            grid_h: 6,
            diagonals: true,
            seed: 2,
        })
    };
    let jobs = run(mk(), ExecModel::JobBased, data_cfg(4, "nfs:0.5,cache:8"));
    let pools = run(
        mk(),
        ExecModel::paper_hybrid_pools(),
        data_cfg(4, "nfs:0.5,cache:8"),
    );
    assert!(
        pools.data.bytes_in < jobs.data.bytes_in,
        "pools {} vs jobs {} bytes in",
        pools.data.bytes_in,
        jobs.data.bytes_in
    );
    assert!(
        pools.data.cache_hit_ratio() > jobs.data.cache_hit_ratio(),
        "pools {:.3} vs jobs {:.3} hit ratio",
        pools.data.cache_hit_ratio(),
        jobs.data.cache_hit_ratio()
    );
    assert!(
        pools.data.stage_in_p95_s <= jobs.data.stage_in_p95_s,
        "pools {:.2}s vs jobs {:.2}s stage-in p95",
        pools.data.stage_in_p95_s,
        jobs.data.stage_in_p95_s
    );
}

#[test]
fn locality_scheduling_completes_and_reproduces() {
    // clustered batches are the placement-sensitive case: producers
    // may still be alive when consumers schedule
    let mk = || {
        let mut cfg = data_cfg(4, "nfs:1,cache:8,locality:on");
        cfg.seed = 3;
        run(
            generate(&MontageConfig {
                grid_w: 5,
                grid_h: 5,
                diagonals: true,
                seed: 3,
            }),
            ExecModel::Clustered(ClusteringConfig::paper_default()),
            cfg,
        )
    };
    let (a, b) = (mk(), mk());
    assert_eq!(a.trace.records.len(), b.trace.records.len());
    assert_eq!(a.makespan, b.makespan, "locality run must reproduce");
    assert_eq!(a.data.bytes_in, b.data.bytes_in);
    assert_eq!(a.sched_binds, b.sched_binds);
    for r in &a.trace.records {
        assert!(r.finished_at.is_some(), "{:?} lost under locality", r.task);
    }
}

#[test]
fn data_plane_survives_chaos_churn() {
    // node crashes kill in-flight transfers and wipe node caches
    // (crash-loses-cache); every task must still complete exactly once
    for model in [ExecModel::paper_hybrid_pools(), ExecModel::JobBased] {
        let dag = generate(&MontageConfig {
            grid_w: 5,
            grid_h: 5,
            diagonals: true,
            seed: 4,
        });
        let n = dag.len();
        let mut cfg = data_cfg(4, "nfs:1,cache:4");
        cfg.seed = 9;
        cfg.chaos = crate::chaos::ChaosConfig::parse_spec("crash:4,pod:0.15").unwrap();
        let res = run(dag, model.clone(), cfg);
        let name = model.name();
        assert_eq!(res.trace.records.len(), n, "{name}");
        for r in &res.trace.records {
            assert!(r.finished_at.is_some(), "{name}: {:?} lost", r.task);
        }
        assert!(res.chaos.faults_total() > 0, "{name}: churn must occur");
        assert!(res.data.bytes_in > 0, "{name}");
        // interrupted stage-ins re-run, so there can be more stage-in
        // samples than tasks — never fewer
        assert!(res.data.stage_ins >= n, "{name}");
    }
}

#[test]
fn fleet_with_data_fills_tenant_byte_lanes() {
    let (a, b) = (small_dag(), small_dag());
    let (n_a, n_b) = (a.len() as u32, b.len() as u32);
    let union = Dag::disjoint_union(&[a, b]);
    let plan = two_instance_plan(n_a, n_b, 20_000, None);
    let (res, outcomes) = run_fleet(
        union,
        ExecModel::paper_hybrid_pools(),
        data_cfg(4, "nfs:1,cache:4"),
        &plan,
    );
    assert_eq!(outcomes.len(), 2);
    for o in &outcomes {
        assert!(o.finished > o.admitted);
    }
    assert_eq!(res.data.bytes_by_tenant.len(), 2);
    assert!(res.data.bytes_by_tenant.iter().all(|&b| b > 0));
    // every moved byte belongs to some tenant's instance
    assert_eq!(
        res.data.bytes_by_tenant.iter().sum::<u64>(),
        res.data.bytes_in + res.data.bytes_out
    );
}

#[test]
fn nodes_never_overcommitted() {
    // run and assert the cpu_allocated series never exceeds capacity
    let res = run(
        small_dag(),
        ExecModel::paper_hybrid_pools(),
        SimConfig::with_nodes(3),
    );
    let cap = 3.0 * 4000.0;
    let s = res.metrics.gauge("cpu_allocated_m").unwrap();
    for &(_, v) in s.points() {
        assert!(v <= cap + 1e-9, "allocated {v} exceeds capacity {cap}");
    }
}
