//! Simulation configuration: [`SimConfig`] (cluster / runtime parameters)
//! and the validating [`SimConfigBuilder`].
//!
//! The builder exists so misconfigurations surface as *named* errors
//! ([`ConfigError`]) at construction time — a zero-node cluster, an empty
//! worker-pool set, or a tenant/weight arity mismatch used to panic
//! mid-run deep inside the driver. `SimConfig` itself stays a plain
//! struct (every field public) so existing call sites and config-file
//! loading keep working unchanged.

use crate::autoscale::AutoscalerConfig;
use crate::chaos::ChaosConfig;
use crate::data::DataConfig;
use crate::k8s::api_server::ApiServerConfig;
use crate::k8s::isolation::IsolationConfig;
use crate::k8s::scheduler::SchedulerConfig;
use crate::obs::monitor::{MonitorConfig, RulesSource};

/// A named configuration error, reported before any event is simulated.
#[derive(Debug, Clone, PartialEq)]
pub enum ConfigError {
    /// The cluster has zero worker nodes.
    ZeroNodes,
    /// A scheduled node event references a node outside the cluster.
    NodeEventOutOfRange { node: usize, nodes: usize },
    /// The deprecated `pod_failure_prob` knob is outside [0, 1].
    PodFailureProbOutOfRange(f64),
    /// `max_sim_s` is not a positive finite wall cap.
    NonPositiveWallCap(f64),
    /// A worker-pools model was configured with no pooled types.
    EmptyPoolSet,
    /// The same type appears twice in the pooled-type list.
    DuplicatePooledType(String),
    /// A pooled type does not exist in the workflow.
    UnknownPooledType(String),
    /// A clustering rule has batch size zero.
    ZeroClusterSize,
    /// Fleet plan: no tenants (the weight vector is empty).
    NoTenants,
    /// Fleet plan: an instance references a tenant with no weight entry.
    TenantWeightArity { tenant: u16, weights: usize },
    /// Fleet plan: an admission cap of zero would never admit anything.
    ZeroAdmissionCap,
    /// Fleet plan: instance task ranges must be contiguous and cover the
    /// union DAG. `expected` is the next task offset (mid-plan gap or
    /// overlap) or the DAG's task count (coverage shortfall at the end);
    /// `found` is what the plan supplied instead.
    BadInstanceRanges { expected: u32, found: u32 },
    /// Fleet plan: an instance with zero tasks.
    EmptyInstance,
    /// Isolation: a zero resource quota can never admit a pod — every
    /// tenant pod would back off forever until the wall cap trips.
    ZeroIsolationQuota,
    /// Isolation: a LimitRange with a zero default/floor is a no-op that
    /// almost certainly meant something else.
    ZeroLimitRange,
    /// Monitor: a zero scrape interval would loop forever on one tick.
    ZeroScrapeInterval,
    /// Monitor: the supplied rule file failed to parse (message carries
    /// the line-numbered parser error).
    BadMonitorRules(String),
}

impl std::fmt::Display for ConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ConfigError::ZeroNodes => write!(f, "cluster must have at least one node"),
            ConfigError::NodeEventOutOfRange { node, nodes } => write!(
                f,
                "node event references node {node} but the cluster has {nodes} nodes"
            ),
            ConfigError::PodFailureProbOutOfRange(p) => {
                write!(f, "pod_failure_prob must be in [0, 1], got {p}")
            }
            ConfigError::NonPositiveWallCap(s) => {
                write!(f, "max_sim_s must be a positive number, got {s}")
            }
            ConfigError::EmptyPoolSet => {
                write!(f, "worker-pools model needs at least one pooled type")
            }
            ConfigError::DuplicatePooledType(t) => {
                write!(f, "pooled type '{t}' is listed more than once")
            }
            ConfigError::UnknownPooledType(t) => {
                write!(f, "pooled type '{t}' is not present in the workflow")
            }
            ConfigError::ZeroClusterSize => write!(f, "clustering size must be >= 1"),
            ConfigError::NoTenants => write!(f, "fleet plan needs at least one tenant"),
            ConfigError::TenantWeightArity { tenant, weights } => write!(
                f,
                "instance tenant {tenant} has no weight entry (weights cover {weights} tenants)"
            ),
            ConfigError::ZeroAdmissionCap => {
                write!(f, "admission cap of 0 would never admit an instance")
            }
            ConfigError::BadInstanceRanges { expected, found } => write!(
                f,
                "instance task ranges must be contiguous and cover the DAG \
                 (expected {expected}, got {found})"
            ),
            ConfigError::EmptyInstance => write!(f, "empty workflow instance"),
            ConfigError::ZeroIsolationQuota => write!(
                f,
                "isolation quota must be non-zero in every capped dimension \
                 (a zero quota can never admit a pod)"
            ),
            ConfigError::ZeroLimitRange => {
                write!(f, "isolation limit range must have a non-zero default")
            }
            ConfigError::ZeroScrapeInterval => {
                write!(f, "monitor scrape interval must be non-zero")
            }
            ConfigError::BadMonitorRules(e) => write!(f, "monitor rules: {e}"),
        }
    }
}

impl std::error::Error for ConfigError {}

/// Cluster / runtime parameters (defaults follow DESIGN.md §5).
#[derive(Debug, Clone)]
pub struct SimConfig {
    /// Number of worker nodes (paper: up to 17).
    pub nodes: usize,
    /// Pod container startup latency (paper: "typically about 2s").
    pub pod_start_ms: u64,
    /// Per-task executor overhead inside a pod (HyperFlow job-executor
    /// fetch + spawn).
    pub exec_overhead_ms: u64,
    /// Job-controller reconcile delay (Job object -> Pod object).
    pub job_controller_ms: u64,
    /// Message fetch latency from a pool queue.
    pub fetch_ms: u64,
    pub sched: SchedulerConfig,
    pub api: ApiServerConfig,
    pub autoscale: AutoscalerConfig,
    /// Hard wall-clock cap on the simulation (guards against livelock in
    /// pathological configurations). Simulated seconds.
    pub max_sim_s: f64,
    /// **Deprecated** — legacy knob, kept working for old configs: at
    /// build time a non-zero value is folded into the chaos subsystem as
    /// a `PodFailure` injector. Prefer `chaos` with a `pod:<p>` spec.
    pub pod_failure_prob: f64,
    /// Seed for the chaos/failure-injection RNG streams.
    pub seed: u64,
    /// Chaos engine: fault injectors + recovery policy (see
    /// [`crate::chaos`]). Empty = disabled, zero overhead, bit-identical
    /// behavior to pre-chaos builds.
    pub chaos: ChaosConfig,
    /// Future-work (§5): throttled job submission — cap on pods that may
    /// sit in the Pending/creation pipeline at once; further batches wait
    /// in the engine. `None` reproduces the paper's unthrottled behaviour.
    pub max_pending_pods: Option<usize>,
    /// Failure injection: scheduled node up/down events (ms, node index,
    /// up?). Down kills all pods on the node (jobs recreated, worker tasks
    /// requeued); up restores capacity.
    pub node_events: Vec<(u64, usize, bool)>,
    /// Data plane: shared-storage/transfer modeling (see [`crate::data`]).
    /// `None` (the default) disables it entirely — no stage events are
    /// ever scheduled and runs are bit-identical to pre-data builds.
    pub data: Option<DataConfig>,
    /// Tenant isolation: namespaces/quotas/node pools (see
    /// [`crate::k8s::isolation`]). `None` (the default) disables it
    /// entirely and runs are bit-identical to pre-isolation builds —
    /// unless the chaos spec schedules a takeover, which builds a
    /// default shared-policy state so the blast radius can be computed.
    pub isolation: Option<IsolationConfig>,
    /// Observability: attach the flight recorder ([`crate::obs`]) and
    /// produce span/event traces plus critical-path attribution in the
    /// result. Off by default; recording never perturbs the simulation
    /// (no RNG draws, no calendar events), it only fills side tables.
    pub obs: bool,
    /// Monitoring stack ([`crate::obs::monitor`]): deterministic scrape
    /// loop with recording rules and SLO burn-rate alerting. `None` (the
    /// default) schedules no ticks and runs stay bit-identical to
    /// pre-monitor builds; the scrape itself is read-only and RNG-free.
    pub monitor: Option<MonitorConfig>,
}

impl Default for SimConfig {
    fn default() -> Self {
        let nodes = 17;
        SimConfig {
            nodes,
            pod_start_ms: 2_000,
            exec_overhead_ms: 100,
            job_controller_ms: 500,
            fetch_ms: 10,
            sched: SchedulerConfig::default(),
            api: ApiServerConfig::default(),
            autoscale: AutoscalerConfig {
                quota_cpu_m: nodes as u64 * 4_000,
                ..Default::default()
            },
            max_sim_s: 6.0 * 3600.0,
            pod_failure_prob: 0.0,
            seed: 42,
            chaos: ChaosConfig::default(),
            max_pending_pods: None,
            node_events: Vec::new(),
            data: None,
            isolation: None,
            obs: false,
            monitor: None,
        }
    }
}

impl SimConfig {
    pub fn with_nodes(nodes: usize) -> Self {
        SimConfig {
            nodes,
            autoscale: AutoscalerConfig {
                quota_cpu_m: nodes as u64 * 4_000,
                ..Default::default()
            },
            ..Default::default()
        }
    }

    /// Attach the flight recorder (builder-style, for tests and callers
    /// that assemble a config by hand).
    pub fn obs(mut self, on: bool) -> Self {
        self.obs = on;
        self
    }

    /// Deterministic fingerprint of the simulation-relevant knobs, as a
    /// 16-hex-digit FNV-1a hash. Observation attachments (`obs`,
    /// `monitor`) are normalized out before hashing: by the determinism
    /// contract they never perturb the simulated trace, so two runs that
    /// differ only in observation carry the *same* fingerprint and
    /// `hyperflow diff` will not flag them as differently configured.
    pub fn fingerprint(&self) -> String {
        let mut canon = self.clone();
        canon.obs = false;
        canon.monitor = None;
        format!("{:016x}", crate::util::meta::fnv1a64(format!("{canon:?}").as_bytes()))
    }

    /// Start a validating builder (CLI entry points use this so bad flag
    /// combinations exit with a named [`ConfigError`] instead of a panic
    /// halfway through a run).
    pub fn builder() -> SimConfigBuilder {
        SimConfigBuilder {
            cfg: SimConfig::default(),
        }
    }

    /// Validate an already-assembled config (the builder calls this; the
    /// JSON experiment loader reuses it for its own error reporting).
    pub fn validate(&self) -> Result<(), ConfigError> {
        if self.nodes == 0 {
            return Err(ConfigError::ZeroNodes);
        }
        if !(0.0..=1.0).contains(&self.pod_failure_prob) {
            return Err(ConfigError::PodFailureProbOutOfRange(self.pod_failure_prob));
        }
        if !self.max_sim_s.is_finite() || self.max_sim_s <= 0.0 {
            return Err(ConfigError::NonPositiveWallCap(self.max_sim_s));
        }
        for &(_, node, _) in &self.node_events {
            if node >= self.nodes {
                return Err(ConfigError::NodeEventOutOfRange {
                    node,
                    nodes: self.nodes,
                });
            }
        }
        if let Some(iso) = &self.isolation {
            if let Some(q) = &iso.quota {
                if q.cpu_m == 0 || q.mem_mb == 0 || q.pods == Some(0) {
                    return Err(ConfigError::ZeroIsolationQuota);
                }
            }
            if let Some(lr) = &iso.limit {
                if lr.default == crate::k8s::Resources::ZERO {
                    return Err(ConfigError::ZeroLimitRange);
                }
            }
        }
        if let Some(m) = &self.monitor {
            if m.interval_ms == 0 {
                return Err(ConfigError::ZeroScrapeInterval);
            }
            // parse user-supplied rules now so build() can unwrap later;
            // builtin variants are covered by unit tests in obs::monitor
            if let RulesSource::Inline(text) = &m.rules {
                if let Err(e) = crate::obs::rules::RuleSet::parse(text) {
                    return Err(ConfigError::BadMonitorRules(e));
                }
            }
        }
        Ok(())
    }
}

/// Builder for [`SimConfig`] whose `build()` rejects invalid setups with
/// named errors.
#[derive(Debug, Clone)]
pub struct SimConfigBuilder {
    cfg: SimConfig,
}

impl SimConfigBuilder {
    /// Cluster size; also re-derives the autoscaler CPU quota like
    /// [`SimConfig::with_nodes`].
    pub fn nodes(mut self, nodes: usize) -> Self {
        self.cfg.nodes = nodes;
        self.cfg.autoscale.quota_cpu_m = nodes as u64 * 4_000;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.cfg.seed = seed;
        self
    }

    pub fn chaos(mut self, chaos: ChaosConfig) -> Self {
        self.cfg.chaos = chaos;
        self
    }

    pub fn data(mut self, data: Option<DataConfig>) -> Self {
        self.cfg.data = data;
        self
    }

    pub fn isolation(mut self, isolation: Option<IsolationConfig>) -> Self {
        self.cfg.isolation = isolation;
        self
    }

    pub fn max_pending_pods(mut self, cap: Option<usize>) -> Self {
        self.cfg.max_pending_pods = cap;
        self
    }

    pub fn obs(mut self, on: bool) -> Self {
        self.cfg.obs = on;
        self
    }

    pub fn monitor(mut self, monitor: Option<MonitorConfig>) -> Self {
        self.cfg.monitor = monitor;
        self
    }

    pub fn node_events(mut self, events: Vec<(u64, usize, bool)>) -> Self {
        self.cfg.node_events = events;
        self
    }

    pub fn pod_failure_prob(mut self, p: f64) -> Self {
        self.cfg.pod_failure_prob = p;
        self
    }

    pub fn max_sim_s(mut self, s: f64) -> Self {
        self.cfg.max_sim_s = s;
        self
    }

    pub fn build(self) -> Result<SimConfig, ConfigError> {
        self.cfg.validate()?;
        Ok(self.cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_happy_path_matches_with_nodes() {
        let built = SimConfig::builder().nodes(4).seed(7).build().unwrap();
        let direct = SimConfig::with_nodes(4);
        assert_eq!(built.nodes, direct.nodes);
        assert_eq!(built.autoscale.quota_cpu_m, direct.autoscale.quota_cpu_m);
        assert_eq!(built.seed, 7);
    }

    #[test]
    fn zero_nodes_is_a_named_error() {
        let err = SimConfig::builder().nodes(0).build().unwrap_err();
        assert_eq!(err, ConfigError::ZeroNodes);
        assert!(err.to_string().contains("at least one node"));
    }

    #[test]
    fn out_of_range_node_event_is_rejected() {
        let err = SimConfig::builder()
            .nodes(2)
            .node_events(vec![(1_000, 5, false)])
            .build()
            .unwrap_err();
        assert_eq!(
            err,
            ConfigError::NodeEventOutOfRange { node: 5, nodes: 2 }
        );
    }

    #[test]
    fn zero_isolation_quota_and_limit_are_rejected() {
        let iso = |spec: &str| {
            Some(crate::k8s::isolation::IsolationConfig::parse_spec(spec).unwrap())
        };
        assert!(matches!(
            SimConfig::builder().isolation(iso("shared,quota:0x1024")).build(),
            Err(ConfigError::ZeroIsolationQuota)
        ));
        assert!(matches!(
            SimConfig::builder().isolation(iso("shared,pods:0")).build(),
            Err(ConfigError::ZeroIsolationQuota)
        ));
        assert!(matches!(
            SimConfig::builder().isolation(iso("shared,limit:0x0")).build(),
            Err(ConfigError::ZeroLimitRange)
        ));
        // a sane spec passes and lands in the config
        let cfg = SimConfig::builder()
            .nodes(4)
            .isolation(iso("dedicated,quota:8000x32768"))
            .build()
            .unwrap();
        assert_eq!(
            cfg.isolation.unwrap().policy,
            crate::k8s::isolation::IsolationPolicy::Dedicated
        );
    }

    #[test]
    fn monitor_misconfigurations_are_named_errors() {
        let zero = MonitorConfig {
            interval_ms: 0,
            ..Default::default()
        };
        assert!(matches!(
            SimConfig::builder().monitor(Some(zero)).build(),
            Err(ConfigError::ZeroScrapeInterval)
        ));
        let bad = MonitorConfig {
            rules: RulesSource::Inline("alert Broken if".into()),
            ..Default::default()
        };
        let err = SimConfig::builder().monitor(Some(bad)).build().unwrap_err();
        match &err {
            ConfigError::BadMonitorRules(msg) => {
                assert!(msg.contains("line 1"), "parser error is line-numbered: {msg}")
            }
            other => panic!("expected BadMonitorRules, got {other:?}"),
        }
        // builtin rules always validate
        SimConfig::builder()
            .monitor(Some(MonitorConfig::default()))
            .build()
            .unwrap();
    }

    #[test]
    fn bad_legacy_probability_and_wall_cap_are_rejected() {
        assert!(matches!(
            SimConfig::builder().pod_failure_prob(2.0).build(),
            Err(ConfigError::PodFailureProbOutOfRange(_))
        ));
        assert!(matches!(
            SimConfig::builder().max_sim_s(0.0).build(),
            Err(ConfigError::NonPositiveWallCap(_))
        ));
    }
}
