//! The **clustered** model (paper §3.2 + §3.5): jobs with HyperFlow task
//! clustering.
//!
//! Identical machinery to [`crate::exec::job`] — the difference is pure
//! policy: the [`JobPath`]'s batcher runs with real
//! [`ClusteringConfig`] rules, so same-type tasks agglomerate into
//! batches of `size` (flushed early by the partial-batch timer,
//! [`crate::exec::kernel::Ev::FlushTimer`]) and execute sequentially
//! inside one pod. This slashes pod/API pressure on the 16k-task Montage
//! runs at the cost of intra-batch serialization (Fig. 4/5).

use crate::chaos::RecoveryPolicy;
use crate::engine::clustering::ClusteringConfig;
use crate::engine::Engine;
use crate::exec::job::JobPath;
use crate::exec::pools::PoolPath;
use crate::exec::strategy::{ExecStrategy, StrategyState};

/// §3.2 + clustering: batches of same-type tasks per pod.
pub struct ClusteredStrategy {
    state: StrategyState,
}

impl ClusteredStrategy {
    pub fn build(rules: ClusteringConfig, engine: &Engine) -> ClusteredStrategy {
        ClusteredStrategy {
            state: StrategyState {
                jobs: JobPath::new(rules),
                pools: PoolPath::none(engine.dag().types.len()),
            },
        }
    }
}

impl ExecStrategy for ClusteredStrategy {
    fn name(&self) -> &'static str {
        "job-clustered"
    }

    fn state(&mut self) -> &mut StrategyState {
        &mut self.state
    }

    fn state_ref(&self) -> &StrategyState {
        &self.state
    }

    /// Like the plain job model: a batch executes inside a single pod and
    /// cannot be speculatively split, so recovery is retry + blacklist +
    /// checkpoint-restart.
    fn default_recovery(&self) -> RecoveryPolicy {
        RecoveryPolicy::default()
    }
}
