//! Generic workflow-pattern generators beyond Montage: the structural
//! archetypes of scientific workflows (chains, fan-out/fan-in, ensembles,
//! multi-stage pipelines). Used to check that the execution models are not
//! over-fitted to Montage's shape, and by the property tests.

use super::dag::Dag;
use super::task::{TaskId, TaskType};
use crate::k8s::resources::Resources;
use crate::sim::SimTime;
use crate::util::rng::Rng;

fn dur(rng: &mut Rng, median: f64, sigma: f64) -> SimTime {
    SimTime::from_secs_f64(rng.lognormal(median, sigma))
}

/// A linear chain of `n` tasks (no parallelism at all).
pub fn chain(n: usize, seed: u64) -> Dag {
    let mut dag = Dag::new(&format!("chain-{n}"));
    let mut rng = Rng::new(seed);
    let ty = dag.add_type(TaskType::new("stage", Resources::new(1000, 1024), 5.0, 0.3));
    let mut prev: Option<TaskId> = None;
    for _ in 0..n {
        let deps: Vec<TaskId> = prev.into_iter().collect();
        prev = Some(dag.add_task(ty, dur(&mut rng, 5.0, 0.3), &deps));
    }
    dag
}

/// Fan-out/fan-in ("bag of tasks" with a reduce): 1 -> n -> 1.
pub fn fan(n: usize, seed: u64) -> Dag {
    let mut dag = Dag::new(&format!("fan-{n}"));
    let mut rng = Rng::new(seed);
    let prep = dag.add_type(TaskType::new("prepare", Resources::new(1000, 2048), 10.0, 0.1));
    let work = dag.add_type(TaskType::new("work", Resources::new(500, 512), 3.0, 0.4));
    let reduce = dag.add_type(TaskType::new("reduce", Resources::new(2000, 4096), 30.0, 0.1));
    let p = dag.add_task(prep, dur(&mut rng, 10.0, 0.1), &[]);
    let workers: Vec<TaskId> = (0..n)
        .map(|_| dag.add_task(work, dur(&mut rng, 3.0, 0.4), &[p]))
        .collect();
    dag.add_task(reduce, dur(&mut rng, 30.0, 0.1), &workers);
    dag
}

/// An ensemble of `m` independent chains of length `k` (e.g. parameter
/// sweeps); stresses fairness across identical sub-workflows.
pub fn ensemble(m: usize, k: usize, seed: u64) -> Dag {
    let mut dag = Dag::new(&format!("ensemble-{m}x{k}"));
    let mut rng = Rng::new(seed);
    let ty = dag.add_type(TaskType::new("member", Resources::new(500, 1024), 4.0, 0.3));
    for _ in 0..m {
        let mut prev: Option<TaskId> = None;
        for _ in 0..k {
            let deps: Vec<TaskId> = prev.into_iter().collect();
            prev = Some(dag.add_task(ty, dur(&mut rng, 4.0, 0.3), &deps));
        }
    }
    dag
}

/// An Epigenomics-like multi-lane pipeline: `lanes` parallel chains of the
/// same staged types, merging into a final global stage — a second
/// real-workflow archetype with *typed* stages (unlike [`ensemble`]).
pub fn pipeline(lanes: usize, seed: u64) -> Dag {
    let mut dag = Dag::new(&format!("pipeline-{lanes}"));
    let mut rng = Rng::new(seed);
    let stages = [
        ("fastqSplit", 1000, 8.0),
        ("filterContams", 500, 3.0),
        ("sol2sanger", 500, 2.0),
        ("fastq2bfq", 500, 2.0),
        ("map", 2000, 20.0),
    ];
    let tys: Vec<_> = stages
        .iter()
        .map(|(n, cpu, med)| {
            dag.add_type(TaskType::new(n, Resources::new(*cpu, 1024), *med, 0.3))
        })
        .collect();
    let merge = dag.add_type(TaskType::new("mapMerge", Resources::new(2000, 8192), 60.0, 0.1));
    let mut lane_ends = Vec::new();
    for _ in 0..lanes {
        let mut prev: Option<TaskId> = None;
        for (i, ty) in tys.iter().enumerate() {
            let deps: Vec<TaskId> = prev.into_iter().collect();
            prev = Some(dag.add_task(*ty, dur(&mut rng, stages[i].2, 0.3), &deps));
        }
        lane_ends.push(prev.unwrap());
    }
    dag.add_task(merge, dur(&mut rng, 60.0, 0.1), &lane_ends);
    dag
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::clustering::ClusteringConfig;
    use crate::models::{driver, ExecModel};

    #[test]
    fn shapes() {
        assert_eq!(chain(10, 1).len(), 10);
        assert_eq!(fan(50, 1).len(), 52);
        assert_eq!(ensemble(5, 4, 1).len(), 20);
        assert_eq!(pipeline(8, 1).len(), 8 * 5 + 1);
        for d in [chain(10, 1), fan(50, 1), ensemble(5, 4, 1), pipeline(8, 1)] {
            assert!(d.validate().is_ok());
        }
    }

    #[test]
    fn chain_critical_path_equals_total_work() {
        let d = chain(6, 2);
        let total: f64 = d.work_by_type().values().sum();
        assert!((d.critical_path_secs() - total).abs() < 1e-9);
    }

    #[test]
    fn all_models_run_all_patterns() {
        let mk: Vec<fn() -> Dag> = vec![
            || chain(8, 3),
            || fan(40, 3),
            || ensemble(6, 3, 3),
            || pipeline(6, 3),
        ];
        for f in &mk {
            for model in [
                ExecModel::JobBased,
                ExecModel::GenericPool,
                ExecModel::Clustered(ClusteringConfig::uniform(5, 2000)),
            ] {
                let dag = f();
                let n = dag.len();
                let res = driver::run(dag, model, driver::SimConfig::with_nodes(4));
                assert_eq!(res.trace.records.len(), n);
            }
        }
    }

    #[test]
    fn fan_parallelism_bounded_by_cluster() {
        let res = driver::run(
            fan(200, 4),
            ExecModel::GenericPool,
            driver::SimConfig::with_nodes(2),
        );
        // generic workers request max(cpu)=2000m -> 4 fit on 2 nodes
        let peak = res
            .running_series()
            .iter()
            .map(|&(_, v)| v)
            .fold(0.0f64, f64::max);
        assert!(peak <= 4.0 + 1e-9, "peak {peak}");
    }

    #[test]
    fn typed_pools_work_on_pipeline() {
        let res = driver::run(
            pipeline(10, 5),
            ExecModel::WorkerPools {
                pooled_types: vec!["map".into(), "filterContams".into()],
            },
            driver::SimConfig::with_nodes(4),
        );
        assert_eq!(res.trace.records.len(), 51);
    }
}
