//! Workflow tasks and task types.

use crate::k8s::resources::Resources;
use crate::sim::SimTime;

/// Index of a task in its workflow DAG.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct TaskId(pub u32);

/// Index into the workflow's task-type table.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct TypeId(pub u16);

/// Per-type metadata: the pod template for this task type.
///
/// Separate worker pools per task type exist precisely because types differ
/// in resource requests and container image (§3.3).
#[derive(Debug, Clone)]
pub struct TaskType {
    pub name: String,
    /// CPU/memory requests of a pod executing this type. Users typically
    /// over-provision these (the safety margin VPA reclaims, §5).
    pub requests: Resources,
    /// CPU this type *actually* uses (millicores). Defaults to the
    /// request; the vertical-pod-autoscaler ablation sets it lower.
    pub cpu_used_m: u64,
    /// Median duration (seconds) of the type's tasks.
    pub median_secs: f64,
    /// Lognormal sigma of the duration distribution.
    pub sigma: f64,
}

impl TaskType {
    pub fn new(name: &str, requests: Resources, median_secs: f64, sigma: f64) -> Self {
        TaskType {
            name: name.to_string(),
            requests,
            cpu_used_m: requests.cpu_m,
            median_secs,
            sigma,
        }
    }

    /// Declare the type's true CPU usage (for the VPA ablation).
    pub fn with_cpu_used(mut self, cpu_used_m: u64) -> Self {
        self.cpu_used_m = cpu_used_m;
        self
    }
}

/// One workflow task: a type, a sampled duration, and its dependencies
/// (stored in the DAG).
#[derive(Debug, Clone)]
pub struct Task {
    pub id: TaskId,
    pub ttype: TypeId,
    pub duration: SimTime,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn task_type_carries_pod_template() {
        let t = TaskType::new("mProject", Resources::new(1000, 1024), 15.0, 0.3);
        assert_eq!(t.name, "mProject");
        assert_eq!(t.requests.cpu_m, 1000);
    }

    #[test]
    fn ids_are_ordered() {
        assert!(TaskId(1) < TaskId(2));
        assert!(TypeId(0) < TypeId(3));
    }
}
