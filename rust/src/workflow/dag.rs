//! The workflow DAG: tasks, dependencies, and structural queries.
//!
//! Construction is append-only (dependencies must reference existing tasks),
//! which makes the graph acyclic by construction. The HyperFlow engine
//! (crate::engine) consumes the DAG through `preds_count` / `successors`.

use super::task::{Task, TaskId, TaskType, TypeId};
use crate::sim::SimTime;
use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Dag {
    pub types: Vec<TaskType>,
    pub tasks: Vec<Task>,
    /// Forward edges: successors of each task.
    succs: Vec<Vec<TaskId>>,
    /// Number of predecessors of each task.
    preds: Vec<u32>,
    /// Data plane: bytes each task stages in from *external* storage
    /// (initial inputs; dependency bytes are the predecessors' outputs).
    in_bytes: Vec<u64>,
    /// Data plane: bytes of the single output file each task produces.
    out_bytes: Vec<u64>,
    name: String,
}

impl Dag {
    pub fn new(name: &str) -> Self {
        Dag {
            name: name.to_string(),
            ..Default::default()
        }
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    /// Register a task type; returns its id. Reuses an existing entry with
    /// the same name.
    pub fn add_type(&mut self, t: TaskType) -> TypeId {
        if let Some(i) = self.types.iter().position(|x| x.name == t.name) {
            return TypeId(i as u16);
        }
        self.types.push(t);
        TypeId((self.types.len() - 1) as u16)
    }

    pub fn type_id(&self, name: &str) -> Option<TypeId> {
        self.types
            .iter()
            .position(|t| t.name == name)
            .map(|i| TypeId(i as u16))
    }

    pub fn type_of(&self, t: TaskId) -> &TaskType {
        &self.types[self.tasks[t.0 as usize].ttype.0 as usize]
    }

    pub fn type_name(&self, t: TaskId) -> &str {
        &self.type_of(t).name
    }

    /// Append a task with the given dependencies. Panics if a dependency
    /// does not exist yet (enforcing acyclicity by construction).
    pub fn add_task(&mut self, ttype: TypeId, duration: SimTime, deps: &[TaskId]) -> TaskId {
        let id = TaskId(self.tasks.len() as u32);
        for &d in deps {
            assert!(
                (d.0 as usize) < self.tasks.len(),
                "dependency {:?} of task {:?} does not exist",
                d,
                id
            );
            self.succs[d.0 as usize].push(id);
        }
        self.tasks.push(Task {
            id,
            ttype,
            duration,
        });
        self.succs.push(Vec::new());
        self.preds.push(deps.len() as u32);
        self.in_bytes.push(0);
        self.out_bytes.push(0);
        id
    }

    /// Annotate a task's data-plane I/O: bytes staged in from external
    /// storage (beyond its predecessors' outputs) and bytes of the output
    /// file it produces. Tasks default to (0, 0) — pure compute.
    pub fn set_io(&mut self, t: TaskId, in_bytes: u64, out_bytes: u64) {
        self.in_bytes[t.0 as usize] = in_bytes;
        self.out_bytes[t.0 as usize] = out_bytes;
    }

    /// External stage-in bytes of a task (0 = inputs come only from
    /// predecessors).
    pub fn task_in_bytes(&self, t: TaskId) -> u64 {
        self.in_bytes[t.0 as usize]
    }

    /// Output-file bytes of a task.
    pub fn task_out_bytes(&self, t: TaskId) -> u64 {
        self.out_bytes[t.0 as usize]
    }

    /// Sum of all output-file bytes (sanity metric for the data plane).
    pub fn total_out_bytes(&self) -> u64 {
        self.out_bytes.iter().sum()
    }

    pub fn len(&self) -> usize {
        self.tasks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tasks.is_empty()
    }

    pub fn successors(&self, t: TaskId) -> &[TaskId] {
        &self.succs[t.0 as usize]
    }

    pub fn preds_count(&self, t: TaskId) -> u32 {
        self.preds[t.0 as usize]
    }

    /// Tasks with no dependencies (the workflow's entry tasks).
    pub fn roots(&self) -> Vec<TaskId> {
        (0..self.tasks.len())
            .filter(|&i| self.preds[i] == 0)
            .map(|i| TaskId(i as u32))
            .collect()
    }

    /// Count of tasks per type name (the paper quotes stage sizes this way).
    ///
    /// Accumulates into a dense per-type table first so each name is
    /// cloned once per type, not once per task (the per-task clone showed
    /// up in the 16k-sim profile, EXPERIMENTS.md §Perf).
    pub fn count_by_type(&self) -> BTreeMap<String, usize> {
        let mut per_type = vec![0usize; self.types.len()];
        for t in &self.tasks {
            per_type[t.ttype.0 as usize] += 1;
        }
        per_type
            .into_iter()
            .enumerate()
            .filter(|&(_, n)| n > 0)
            .map(|(i, n)| (self.types[i].name.clone(), n))
            .collect()
    }

    /// Total work (sum of durations) per type, in seconds. Same dense
    /// accumulation as [`Dag::count_by_type`]: one name clone per type.
    pub fn work_by_type(&self) -> BTreeMap<String, f64> {
        let mut per_type = vec![(0.0f64, 0usize); self.types.len()];
        for t in &self.tasks {
            let e = &mut per_type[t.ttype.0 as usize];
            e.0 += t.duration.as_secs_f64();
            e.1 += 1;
        }
        per_type
            .into_iter()
            .enumerate()
            .filter(|&(_, (_, n))| n > 0)
            .map(|(i, (w, _))| (self.types[i].name.clone(), w))
            .collect()
    }

    /// Critical-path length in seconds (longest dependency chain by
    /// duration) — the theoretical lower bound on makespan with infinite
    /// resources.
    pub fn critical_path_secs(&self) -> f64 {
        let mut finish = vec![0.0f64; self.tasks.len()];
        // tasks are topologically ordered by construction
        for (i, t) in self.tasks.iter().enumerate() {
            finish[i] += t.duration.as_secs_f64();
        }
        let mut best: f64 = 0.0;
        let mut start = vec![0.0f64; self.tasks.len()];
        for i in 0..self.tasks.len() {
            let f = start[i] + self.tasks[i].duration.as_secs_f64();
            best = best.max(f);
            for s in &self.succs[i] {
                let j = s.0 as usize;
                if f > start[j] {
                    start[j] = f;
                }
            }
        }
        best
    }

    /// Disjoint union of independent workflow instances: tasks of instance
    /// `i` are appended after all tasks of instances `0..i`, with edges
    /// offset accordingly, so the result is one DAG whose connected
    /// components are the inputs ("multiple instances of different
    /// workflows can intertwine", §3.4). Task types are merged **by name**
    /// through a map built once per instance — not the per-task linear
    /// scan over `types` that [`Dag::add_type`] would repeat — so unioning
    /// a fleet of hundreds of instances stays linear in total task count.
    ///
    /// The instance occupying tasks `[base, base + inst.len())` keeps its
    /// internal ids shifted by `base` (= sum of earlier instance lengths),
    /// which is the offset scheme the fleet service uses to map a task
    /// back to its workflow instance and tenant.
    pub fn disjoint_union(instances: &[Dag]) -> Dag {
        let mut out = Dag::new(&format!("union-{}", instances.len()));
        let mut by_name: BTreeMap<String, TypeId> = BTreeMap::new();
        let mut deps: Vec<Vec<TaskId>> = Vec::new();
        for inst in instances {
            // local type index -> TypeId in the union, resolved by name;
            // a name collision must carry the same definition, or the
            // simulation would silently run later instances with the first
            // instance's resources/durations
            let tmap: Vec<TypeId> = inst
                .types
                .iter()
                .map(|t| match by_name.get(&t.name) {
                    Some(&id) => {
                        let seen = &out.types[id.0 as usize];
                        assert!(
                            seen.requests == t.requests
                                && seen.cpu_used_m == t.cpu_used_m
                                && seen.median_secs == t.median_secs
                                && seen.sigma == t.sigma,
                            "disjoint_union: conflicting definitions of task type '{}'",
                            t.name
                        );
                        id
                    }
                    None => {
                        let id = out.add_type(t.clone());
                        by_name.insert(t.name.clone(), id);
                        id
                    }
                })
                .collect();
            let base = out.len() as u32;
            // invert successor lists into (offset) dependency lists
            deps.clear();
            deps.resize(inst.len(), Vec::new());
            for p in 0..inst.len() as u32 {
                for s in inst.successors(TaskId(p)) {
                    deps[s.0 as usize].push(TaskId(p + base));
                }
            }
            for t in &inst.tasks {
                let id = out.add_task(tmap[t.ttype.0 as usize], t.duration, &deps[t.id.0 as usize]);
                // files stay instance-scoped: task-indexed byte tables
                // shift with the ids, so no instance can see another's data
                out.set_io(id, inst.in_bytes[t.id.0 as usize], inst.out_bytes[t.id.0 as usize]);
            }
        }
        out
    }

    /// Validate structural invariants (used by property tests).
    pub fn validate(&self) -> Result<(), String> {
        if self.succs.len() != self.tasks.len()
            || self.preds.len() != self.tasks.len()
            || self.in_bytes.len() != self.tasks.len()
            || self.out_bytes.len() != self.tasks.len()
        {
            return Err("internal arrays out of sync".into());
        }
        let mut pred_check = vec![0u32; self.tasks.len()];
        for (i, ss) in self.succs.iter().enumerate() {
            for s in ss {
                if s.0 as usize <= i {
                    return Err(format!("edge {i} -> {} not forward", s.0));
                }
                pred_check[s.0 as usize] += 1;
            }
        }
        if pred_check != self.preds {
            return Err("preds count mismatch".into());
        }
        for t in &self.tasks {
            if t.ttype.0 as usize >= self.types.len() {
                return Err("task references unknown type".into());
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::k8s::resources::Resources;

    fn tiny() -> Dag {
        let mut d = Dag::new("t");
        let a = d.add_type(TaskType::new("A", Resources::new(500, 512), 1.0, 0.0));
        let b = d.add_type(TaskType::new("B", Resources::new(500, 512), 2.0, 0.0));
        let t0 = d.add_task(a, SimTime(1000), &[]);
        let t1 = d.add_task(a, SimTime(1000), &[]);
        let t2 = d.add_task(b, SimTime(2000), &[t0, t1]);
        let _t3 = d.add_task(b, SimTime(2000), &[t2]);
        d
    }

    #[test]
    fn roots_and_successors() {
        let d = tiny();
        assert_eq!(d.roots(), vec![TaskId(0), TaskId(1)]);
        assert_eq!(d.successors(TaskId(0)), &[TaskId(2)]);
        assert_eq!(d.preds_count(TaskId(2)), 2);
        assert_eq!(d.preds_count(TaskId(0)), 0);
    }

    #[test]
    fn type_reuse() {
        let mut d = Dag::new("t");
        let a1 = d.add_type(TaskType::new("A", Resources::ZERO, 1.0, 0.0));
        let a2 = d.add_type(TaskType::new("A", Resources::ZERO, 9.0, 0.0));
        assert_eq!(a1, a2);
        assert_eq!(d.types.len(), 1);
    }

    #[test]
    fn counts_and_work() {
        let d = tiny();
        let c = d.count_by_type();
        assert_eq!(c["A"], 2);
        assert_eq!(c["B"], 2);
        let w = d.work_by_type();
        assert!((w["B"] - 4.0).abs() < 1e-9);
    }

    #[test]
    fn critical_path() {
        let d = tiny();
        // A(1) -> B(2) -> B(2) = 5 seconds
        assert!((d.critical_path_secs() - 5.0).abs() < 1e-9);
    }

    #[test]
    #[should_panic(expected = "does not exist")]
    fn forward_only_edges() {
        let mut d = Dag::new("t");
        let a = d.add_type(TaskType::new("A", Resources::ZERO, 1.0, 0.0));
        d.add_task(a, SimTime(1), &[TaskId(5)]);
    }

    #[test]
    fn validate_ok() {
        assert!(tiny().validate().is_ok());
    }

    fn diamond() -> Dag {
        // a -> {b, c} -> d
        let mut d = Dag::new("diamond");
        let ty = d.add_type(TaskType::new("T", Resources::ZERO, 1.0, 0.0));
        let a = d.add_task(ty, SimTime(1), &[]);
        let b = d.add_task(ty, SimTime(2), &[a]);
        let c = d.add_task(ty, SimTime(3), &[a]);
        let _d = d.add_task(ty, SimTime(4), &[b, c]);
        d
    }

    #[test]
    fn disjoint_union_inverts_edges_on_diamond() {
        let u = Dag::disjoint_union(&[diamond(), diamond()]);
        assert_eq!(u.len(), 8);
        assert!(u.validate().is_ok());
        // both copies keep the diamond shape at their offset
        for base in [0u32, 4u32] {
            assert_eq!(
                u.successors(TaskId(base)),
                &[TaskId(base + 1), TaskId(base + 2)]
            );
            assert_eq!(u.successors(TaskId(base + 1)), &[TaskId(base + 3)]);
            assert_eq!(u.successors(TaskId(base + 2)), &[TaskId(base + 3)]);
            assert_eq!(u.preds_count(TaskId(base)), 0);
            assert_eq!(u.preds_count(TaskId(base + 3)), 2);
        }
        // no cross-instance edges: exactly the two roots
        assert_eq!(u.roots(), vec![TaskId(0), TaskId(4)]);
        // same-named types merged into one table entry
        assert_eq!(u.types.len(), 1);
        // durations carried over per copy
        assert_eq!(u.tasks[3].duration, SimTime(4));
        assert_eq!(u.tasks[7].duration, SimTime(4));
    }

    #[test]
    fn disjoint_union_merges_type_tables_by_name() {
        let mut x = Dag::new("x");
        let a = x.add_type(TaskType::new("A", Resources::ZERO, 1.0, 0.0));
        x.add_task(a, SimTime(1), &[]);
        let mut y = Dag::new("y");
        let b = y.add_type(TaskType::new("B", Resources::ZERO, 1.0, 0.0));
        let a2 = y.add_type(TaskType::new("A", Resources::ZERO, 1.0, 0.0));
        let t0 = y.add_task(b, SimTime(1), &[]);
        y.add_task(a2, SimTime(1), &[t0]);
        let u = Dag::disjoint_union(&[x, y]);
        assert_eq!(u.types.len(), 2, "A is shared, B is new");
        assert_eq!(u.type_name(TaskId(0)), "A");
        assert_eq!(u.type_name(TaskId(1)), "B");
        assert_eq!(u.type_name(TaskId(2)), "A");
        assert_eq!(u.successors(TaskId(1)), &[TaskId(2)]);
    }

    #[test]
    #[should_panic(expected = "conflicting definitions of task type 'A'")]
    fn disjoint_union_rejects_conflicting_type_definitions() {
        let mut x = Dag::new("x");
        let a = x.add_type(TaskType::new("A", Resources::new(1000, 1024), 1.0, 0.0));
        x.add_task(a, SimTime(1), &[]);
        let mut y = Dag::new("y");
        let a2 = y.add_type(TaskType::new("A", Resources::new(4000, 1024), 1.0, 0.0));
        y.add_task(a2, SimTime(1), &[]);
        Dag::disjoint_union(&[x, y]);
    }

    #[test]
    fn io_bytes_default_zero_and_survive_disjoint_union() {
        let mut d = tiny();
        assert_eq!(d.task_in_bytes(TaskId(0)), 0);
        assert_eq!(d.task_out_bytes(TaskId(0)), 0);
        d.set_io(TaskId(0), 100, 200);
        d.set_io(TaskId(3), 0, 50);
        assert_eq!(d.total_out_bytes(), 250);
        let mut e = tiny();
        e.set_io(TaskId(1), 7, 9);
        let u = Dag::disjoint_union(&[d, e]);
        assert!(u.validate().is_ok());
        // first instance at offset 0, second at offset 4
        assert_eq!(u.task_in_bytes(TaskId(0)), 100);
        assert_eq!(u.task_out_bytes(TaskId(0)), 200);
        assert_eq!(u.task_out_bytes(TaskId(3)), 50);
        assert_eq!(u.task_in_bytes(TaskId(5)), 7);
        assert_eq!(u.task_out_bytes(TaskId(5)), 9);
        // untouched tasks stay pure compute
        assert_eq!(u.task_out_bytes(TaskId(4)), 0);
    }

    #[test]
    fn disjoint_union_of_one_is_a_copy() {
        let u = Dag::disjoint_union(&[tiny()]);
        let t = tiny();
        assert_eq!(u.len(), t.len());
        for i in 0..t.len() as u32 {
            assert_eq!(u.successors(TaskId(i)), t.successors(TaskId(i)));
            assert_eq!(u.preds_count(TaskId(i)), t.preds_count(TaskId(i)));
        }
        assert!(Dag::disjoint_union(&[]).is_empty());
    }
}
