//! Workflow (de)serialization in a HyperFlow-like JSON format.
//!
//! ```json
//! {
//!   "name": "montage-4x4",
//!   "types": [{"name": "mProject", "cpu_m": 1000, "mem_mb": 1024,
//!              "median_secs": 12.0, "sigma": 0.25}],
//!   "tasks": [{"type": 0, "duration_ms": 11500, "deps": [0, 1]}]
//! }
//! ```

use super::dag::Dag;
use super::task::{TaskId, TaskType, TypeId};
use crate::k8s::resources::Resources;
use crate::sim::SimTime;
use crate::util::json::{Json, JsonError};

/// Serialize a DAG to the workflow JSON format.
pub fn to_json(dag: &Dag) -> Json {
    let types: Vec<Json> = dag
        .types
        .iter()
        .map(|t| {
            Json::obj(vec![
                ("name", Json::str(&t.name)),
                ("cpu_m", t.requests.cpu_m.into()),
                ("mem_mb", t.requests.mem_mb.into()),
                ("median_secs", t.median_secs.into()),
                ("sigma", t.sigma.into()),
            ])
        })
        .collect();
    let tasks: Vec<Json> = dag
        .tasks
        .iter()
        .map(|t| {
            // reconstruct deps from the forward edge lists
            let mut fields = vec![
                ("type", (t.ttype.0 as u64).into()),
                ("duration_ms", t.duration.as_millis().into()),
            ];
            // data-plane annotations, omitted when zero (keeps old files
            // and old readers compatible)
            let (inb, outb) = (dag.task_in_bytes(t.id), dag.task_out_bytes(t.id));
            if inb > 0 {
                fields.push(("in_b", inb.into()));
            }
            if outb > 0 {
                fields.push(("out_b", outb.into()));
            }
            Json::obj(fields)
        })
        .collect();
    // deps stored as reverse adjacency: for compactness serialize successor
    // lists once
    let succs: Vec<Json> = (0..dag.len())
        .map(|i| {
            Json::Arr(
                dag.successors(TaskId(i as u32))
                    .iter()
                    .map(|s| (s.0 as u64).into())
                    .collect(),
            )
        })
        .collect();
    Json::obj(vec![
        ("name", Json::str(dag.name())),
        ("types", Json::Arr(types)),
        ("tasks", Json::Arr(tasks)),
        ("succs", Json::Arr(succs)),
    ])
}

/// Parse a DAG from the workflow JSON format.
pub fn from_json(j: &Json) -> Result<Dag, JsonError> {
    let name = j.get("name")?.as_str()?;
    let mut dag = Dag::new(name);
    for t in j.get("types")?.as_arr()? {
        dag.add_type(TaskType::new(
            t.get("name")?.as_str()?,
            Resources::new(t.get("cpu_m")?.as_u64()?, t.get("mem_mb")?.as_u64()?),
            t.get("median_secs")?.as_f64()?,
            t.get("sigma")?.as_f64()?,
        ));
    }
    let tasks = j.get("tasks")?.as_arr()?;
    let succs = j.get("succs")?.as_arr()?;
    // invert successor lists into dependency lists
    let mut deps: Vec<Vec<TaskId>> = vec![Vec::new(); tasks.len()];
    for (i, ss) in succs.iter().enumerate() {
        for s in ss.as_arr()? {
            let si = s.as_usize()?;
            deps[si].push(TaskId(i as u32));
        }
    }
    for (i, t) in tasks.iter().enumerate() {
        let id = dag.add_task(
            TypeId(t.get("type")?.as_u64()? as u16),
            SimTime::from_millis(t.get("duration_ms")?.as_u64()?),
            &deps[i],
        );
        let inb = t.opt("in_b").map(|v| v.as_u64()).transpose()?.unwrap_or(0);
        let outb = t.opt("out_b").map(|v| v.as_u64()).transpose()?.unwrap_or(0);
        if inb > 0 || outb > 0 {
            dag.set_io(id, inb, outb);
        }
    }
    Ok(dag)
}

pub fn save(dag: &Dag, path: &str) -> std::io::Result<()> {
    std::fs::write(path, to_json(dag).to_string())
}

pub fn load(path: &str) -> anyhow::Result<Dag> {
    let text = std::fs::read_to_string(path)?;
    let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
    Ok(from_json(&j).map_err(|e| anyhow::anyhow!("{path}: {e}"))?)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workflow::montage::{generate, MontageConfig};

    #[test]
    fn round_trip_preserves_structure() {
        let dag = generate(&MontageConfig {
            grid_w: 3,
            grid_h: 3,
            diagonals: true,
            seed: 5,
        });
        let j = to_json(&dag);
        let back = from_json(&j).unwrap();
        assert_eq!(back.len(), dag.len());
        assert_eq!(back.count_by_type(), dag.count_by_type());
        assert!(back.validate().is_ok());
        for i in 0..dag.len() {
            let t = TaskId(i as u32);
            assert_eq!(back.successors(t), dag.successors(t));
            assert_eq!(back.preds_count(t), dag.preds_count(t));
            assert_eq!(back.tasks[i].duration, dag.tasks[i].duration);
            // data-plane annotations survive the round trip
            assert_eq!(back.task_in_bytes(t), dag.task_in_bytes(t));
            assert_eq!(back.task_out_bytes(t), dag.task_out_bytes(t));
        }
        assert!(dag.total_out_bytes() > 0, "montage carries size laws");
    }

    #[test]
    fn file_round_trip() {
        let dag = generate(&MontageConfig {
            grid_w: 2,
            grid_h: 2,
            diagonals: false,
            seed: 9,
        });
        let path = std::env::temp_dir().join("hfk8s_wf_test.json");
        let path = path.to_str().unwrap();
        save(&dag, path).unwrap();
        let back = load(path).unwrap();
        assert_eq!(back.len(), dag.len());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_malformed() {
        let j = Json::parse(r#"{"name": "x"}"#).unwrap();
        assert!(from_json(&j).is_err());
    }
}
