//! Montage workflow generator.
//!
//! Reproduces the structure of the astronomy mosaicking workflow the paper
//! evaluates with (§4.1): a grid of input images processed by
//!
//!   mProject (reproject every image)                — parallel stage 1
//!   mDiffFit (fit planes to overlapping pairs)      — parallel stage 2
//!   mConcatFit -> mBgModel (global background fit)  — serial bottleneck
//!   mBackground (correct every image)               — parallel stage 3
//!   mImgtbl -> mAdd -> mShrink -> mJPEG             — serial assembly
//!
//! mDiffFit becomes ready per-pair as soon as both mProjects finish, so
//! stages 1 and 2 *intertwine* — exactly the proportional-allocation
//! challenge of Table 1. With the default grid (52x52) the workflow has
//! 15,919 tasks ("a large Montage workflow with 16k tasks"), of which
//! 10,506 are 2-second mDiffFit tasks — the paper's "very short, most
//! numerous" stage.

use super::dag::Dag;
use super::task::{TaskId, TaskType};
use crate::k8s::resources::Resources;
use crate::sim::SimTime;
use crate::util::rng::Rng;

/// Generator parameters. Durations are medians of lognormal distributions
/// (seconds); resource requests follow §3.3 (types differ in requests).
#[derive(Debug, Clone)]
pub struct MontageConfig {
    pub grid_w: usize,
    pub grid_h: usize,
    /// Include diagonal overlaps in mDiffFit (Montage computes every
    /// overlapping pair; diagonal corner overlaps exist with 25% tile
    /// overlap).
    pub diagonals: bool,
    pub seed: u64,
}

impl MontageConfig {
    /// The paper's large workflow: ~16k tasks.
    pub fn paper_16k() -> Self {
        MontageConfig {
            grid_w: 52,
            grid_h: 52,
            diagonals: true,
            seed: 42,
        }
    }

    /// The "smaller workflow" used for the job-model trace in Fig. 3.
    pub fn paper_small() -> Self {
        MontageConfig {
            grid_w: 28,
            grid_h: 28,
            diagonals: true,
            seed: 42,
        }
    }

    /// Grid with the closest total task count to `total`.
    pub fn with_total_tasks(total: usize, seed: u64) -> Self {
        let mut best = (usize::MAX, 2usize);
        for g in 2..300 {
            let t = Self::total_tasks_for_grid(g, g, true);
            let d = t.abs_diff(total);
            if d < best.0 {
                best = (d, g);
            }
        }
        MontageConfig {
            grid_w: best.1,
            grid_h: best.1,
            diagonals: true,
            seed,
        }
    }

    pub fn total_tasks_for_grid(w: usize, h: usize, diagonals: bool) -> usize {
        let n = w * h;
        let mut e = w * (h - 1) + h * (w - 1);
        if diagonals {
            e += 2 * (w - 1) * (h - 1);
        }
        2 * n + e + 6 // six serial tasks: concat/bgmodel/imgtbl/add/shrink/jpeg
    }

    pub fn n_images(&self) -> usize {
        self.grid_w * self.grid_h
    }
}

/// Data-plane size laws (bytes), calibrated to 2MASS-scale Montage runs:
/// raw tiles are a few MB of FITS, reprojection roughly doubles them
/// (padded target frame), plane-fit outputs are tiny parameter files, and
/// the mosaic grows linearly with the tile count. The exact constants are
/// stand-ins (see EXPERIMENTS.md §"Data plane / storage" for provenance);
/// what matters for the model comparison is the *shape*: wide stages fan
/// many medium files through shared storage, and the assembly stage
/// gathers O(n) bytes into one task.
pub const RAW_IMAGE_BYTES: u64 = 4 << 20; // mProject external input
pub const PROJECTED_BYTES: u64 = 8 << 20; // mProject output
pub const DIFF_FIT_BYTES: u64 = 16 << 10; // mDiffFit plane-fit output
pub const CONCAT_TABLE_BYTES: u64 = 1 << 20; // mConcatFit table
pub const BG_MODEL_BYTES: u64 = 512 << 10; // mBgModel corrections
pub const CORRECTED_BYTES: u64 = 8 << 20; // mBackground output
pub const IMGTBL_BYTES: u64 = 2 << 20; // mImgtbl metadata table
pub const MOSAIC_BYTES_PER_IMAGE: u64 = 4 << 20; // mAdd output scales with n
pub const SHRINK_FACTOR: u64 = 64; // mShrink reduces the mosaic
pub const JPEG_BYTES: u64 = 1 << 20; // final preview

/// Montage task-type names in pipeline order.
pub const TYPE_NAMES: [&str; 9] = [
    "mProject",
    "mDiffFit",
    "mConcatFit",
    "mBgModel",
    "mBackground",
    "mImgtbl",
    "mAdd",
    "mShrink",
    "mJPEG",
];

/// Default pod templates + duration distributions, calibrated to the
/// paper's narrative (§4.1-4.2: mDiffFit ≈ 2 s mean; mProject and
/// mBackground short-but-longer; assembly stages serial and chunky).
pub fn default_types() -> Vec<TaskType> {
    vec![
        // cpu_used reflects typical over-provisioned requests (the VPA
        // ablation's head-room; ignored unless `AutoscalerConfig.vpa`)
        TaskType::new("mProject", Resources::new(1000, 1024), 12.0, 0.25)
            .with_cpu_used(800),
        TaskType::new("mDiffFit", Resources::new(500, 512), 2.0, 0.40)
            .with_cpu_used(300),
        TaskType::new("mConcatFit", Resources::new(1000, 2048), 40.0, 0.10),
        TaskType::new("mBgModel", Resources::new(1000, 4096), 100.0, 0.10),
        TaskType::new("mBackground", Resources::new(500, 512), 3.0, 0.30)
            .with_cpu_used(350),
        TaskType::new("mImgtbl", Resources::new(1000, 2048), 20.0, 0.10),
        TaskType::new("mAdd", Resources::new(2000, 8192), 150.0, 0.10),
        TaskType::new("mShrink", Resources::new(1000, 2048), 40.0, 0.10),
        TaskType::new("mJPEG", Resources::new(500, 1024), 15.0, 0.10),
    ]
}

/// Overlapping image pairs on the grid (right/down, plus diagonals).
pub fn overlap_pairs(w: usize, h: usize, diagonals: bool) -> Vec<(usize, usize)> {
    let idx = |r: usize, c: usize| r * w + c;
    let mut pairs = Vec::new();
    for r in 0..h {
        for c in 0..w {
            if c + 1 < w {
                pairs.push((idx(r, c), idx(r, c + 1)));
            }
            if r + 1 < h {
                pairs.push((idx(r, c), idx(r + 1, c)));
                if diagonals {
                    if c + 1 < w {
                        pairs.push((idx(r, c), idx(r + 1, c + 1)));
                    }
                    if c > 0 {
                        pairs.push((idx(r, c), idx(r + 1, c - 1)));
                    }
                }
            }
        }
    }
    pairs
}

/// Generate the Montage DAG.
pub fn generate(cfg: &MontageConfig) -> Dag {
    let mut dag = Dag::new(&format!("montage-{}x{}", cfg.grid_w, cfg.grid_h));
    let mut rng = Rng::new(cfg.seed);
    let type_ids: Vec<_> = default_types()
        .into_iter()
        .map(|t| dag.add_type(t))
        .collect();
    let [proj, diff, concat, bgmodel, backgr, imgtbl, madd, shrink, jpeg] =
        [0, 1, 2, 3, 4, 5, 6, 7, 8].map(|i| type_ids[i]);

    let sample = |dag: &Dag, idx: usize, rng: &mut Rng| {
        let t = &dag.types[idx];
        SimTime::from_secs_f64(rng.lognormal(t.median_secs, t.sigma))
    };

    // Stage 1: mProject per image (stages in its raw tile from storage).
    let n = cfg.n_images();
    let mut projects = Vec::with_capacity(n);
    for _ in 0..n {
        let d = sample(&dag, 0, &mut rng);
        let t = dag.add_task(proj, d, &[]);
        dag.set_io(t, RAW_IMAGE_BYTES, PROJECTED_BYTES);
        projects.push(t);
    }

    // Stage 2: mDiffFit per overlapping pair (intertwines with stage 1).
    let pairs = overlap_pairs(cfg.grid_w, cfg.grid_h, cfg.diagonals);
    let mut diffs = Vec::with_capacity(pairs.len());
    for &(i, j) in &pairs {
        let d = sample(&dag, 1, &mut rng);
        let t = dag.add_task(diff, d, &[projects[i], projects[j]]);
        dag.set_io(t, 0, DIFF_FIT_BYTES);
        diffs.push(t);
    }

    // Serial: mConcatFit <- all diffs; mBgModel <- concat.
    let d = sample(&dag, 2, &mut rng);
    let concat_t = dag.add_task(concat, d, &diffs);
    dag.set_io(concat_t, 0, CONCAT_TABLE_BYTES);
    let d = sample(&dag, 3, &mut rng);
    let bg_t = dag.add_task(bgmodel, d, &[concat_t]);
    dag.set_io(bg_t, 0, BG_MODEL_BYTES);

    // Stage 3: mBackground per image.
    let mut bgs = Vec::with_capacity(n);
    for &p in &projects {
        let d = sample(&dag, 4, &mut rng);
        let t = dag.add_task(backgr, d, &[bg_t, p]);
        dag.set_io(t, 0, CORRECTED_BYTES);
        bgs.push(t);
    }

    // Assembly: mImgtbl -> mAdd -> mShrink -> mJPEG. The mosaic grows
    // with the tile count (the data plane's gather hot-spot).
    let d = sample(&dag, 5, &mut rng);
    let imgtbl_t = dag.add_task(imgtbl, d, &bgs);
    dag.set_io(imgtbl_t, 0, IMGTBL_BYTES);
    let d = sample(&dag, 6, &mut rng);
    let madd_t = dag.add_task(madd, d, &[imgtbl_t]);
    let mosaic = MOSAIC_BYTES_PER_IMAGE * n as u64;
    dag.set_io(madd_t, 0, mosaic);
    let d = sample(&dag, 7, &mut rng);
    let shrink_t = dag.add_task(shrink, d, &[madd_t]);
    dag.set_io(shrink_t, 0, (mosaic / SHRINK_FACTOR).max(1));
    let d = sample(&dag, 8, &mut rng);
    let jpeg_t: TaskId = dag.add_task(jpeg, d, &[shrink_t]);
    dag.set_io(jpeg_t, 0, JPEG_BYTES);

    dag
}

/// Semantic role of a task in the Montage DAG — used by the real-compute
/// executor (rust/src/compute) to map TaskIds to artifact invocations.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Role {
    /// mProject of image `i`.
    Project(usize),
    /// mDiffFit of overlap pair `e` between images `(i, j)`.
    DiffFit(usize, (usize, usize)),
    ConcatFit,
    BgModel,
    /// mBackground of image `i`.
    Background(usize),
    Imgtbl,
    Add,
    Shrink,
    Jpeg,
}

/// TaskId -> Role mapping for a DAG produced by [`generate`] (tasks are
/// appended in a fixed order).
#[derive(Debug, Clone)]
pub struct MontageIndex {
    n: usize,
    pairs: Vec<(usize, usize)>,
}

impl MontageIndex {
    pub fn new(cfg: &MontageConfig) -> Self {
        MontageIndex {
            n: cfg.n_images(),
            pairs: overlap_pairs(cfg.grid_w, cfg.grid_h, cfg.diagonals),
        }
    }

    pub fn n_images(&self) -> usize {
        self.n
    }

    pub fn pairs(&self) -> &[(usize, usize)] {
        &self.pairs
    }

    pub fn role(&self, t: TaskId) -> Role {
        let i = t.0 as usize;
        let e = self.pairs.len();
        if i < self.n {
            Role::Project(i)
        } else if i < self.n + e {
            let k = i - self.n;
            Role::DiffFit(k, self.pairs[k])
        } else {
            match i - self.n - e {
                0 => Role::ConcatFit,
                1 => Role::BgModel,
                k if k >= 2 && k < 2 + self.n => Role::Background(k - 2),
                k if k == 2 + self.n => Role::Imgtbl,
                k if k == 3 + self.n => Role::Add,
                k if k == 4 + self.n => Role::Shrink,
                k if k == 5 + self.n => Role::Jpeg,
                k => panic!("task index {k} out of range for montage DAG"),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn index_roles_match_types() {
        let cfg = MontageConfig {
            grid_w: 3,
            grid_h: 2,
            diagonals: true,
            seed: 4,
        };
        let dag = generate(&cfg);
        let idx = MontageIndex::new(&cfg);
        for t in &dag.tasks {
            let role = idx.role(t.id);
            let tname = dag.type_name(t.id);
            let ok = match role {
                Role::Project(_) => tname == "mProject",
                Role::DiffFit(..) => tname == "mDiffFit",
                Role::ConcatFit => tname == "mConcatFit",
                Role::BgModel => tname == "mBgModel",
                Role::Background(_) => tname == "mBackground",
                Role::Imgtbl => tname == "mImgtbl",
                Role::Add => tname == "mAdd",
                Role::Shrink => tname == "mShrink",
                Role::Jpeg => tname == "mJPEG",
            };
            assert!(ok, "task {:?} type {tname} got role {role:?}", t.id);
        }
        // diff pairs map to valid image indices
        for &(a, b) in idx.pairs() {
            assert!(a < idx.n_images() && b < idx.n_images());
        }
    }

    #[test]
    fn paper_16k_size() {
        let cfg = MontageConfig::paper_16k();
        let total = MontageConfig::total_tasks_for_grid(52, 52, true);
        assert_eq!(total, 15_920); // "a large Montage workflow with 16k tasks"
        let dag = generate(&cfg);
        assert_eq!(dag.len(), total);
        assert!(dag.validate().is_ok());
    }

    #[test]
    fn stage_counts() {
        let cfg = MontageConfig {
            grid_w: 4,
            grid_h: 4,
            diagonals: true,
            seed: 1,
        };
        let dag = generate(&cfg);
        let c = dag.count_by_type();
        assert_eq!(c["mProject"], 16);
        // E = 4*3*2 + 2*9 = 24 + 18 = 42
        assert_eq!(c["mDiffFit"], 42);
        assert_eq!(c["mBackground"], 16);
        for serial in ["mConcatFit", "mBgModel", "mImgtbl", "mAdd", "mShrink", "mJPEG"] {
            assert_eq!(c[serial], 1, "{serial}");
        }
    }

    #[test]
    fn mdifffit_is_most_numerous_and_short() {
        let dag = generate(&MontageConfig::paper_16k());
        let c = dag.count_by_type();
        let max_type = c.iter().max_by_key(|(_, &v)| v).unwrap();
        assert_eq!(max_type.0, "mDiffFit");
        // average ~2s (§4.1: "very short (2s on average)")
        let w = dag.work_by_type();
        let avg = w["mDiffFit"] / c["mDiffFit"] as f64;
        assert!((1.5..3.0).contains(&avg), "avg mDiffFit duration {avg}");
    }

    #[test]
    fn dependencies_encode_intertwining() {
        let cfg = MontageConfig {
            grid_w: 3,
            grid_h: 3,
            diagonals: false,
            seed: 2,
        };
        let dag = generate(&cfg);
        // every mDiffFit depends on exactly 2 mProjects
        for t in &dag.tasks {
            if dag.types[t.ttype.0 as usize].name == "mDiffFit" {
                assert_eq!(dag.preds_count(t.id), 2);
            }
        }
        // first mDiffFit (images 0,1) can start before mProject of image 8
        // completes: it only depends on projects 0 and 1.
        let diffs: Vec<_> = dag
            .tasks
            .iter()
            .filter(|t| dag.types[t.ttype.0 as usize].name == "mDiffFit")
            .collect();
        assert!(!diffs.is_empty());
    }

    #[test]
    fn overlap_pair_count() {
        // 3x3 grid: h-pairs 6, v-pairs 6, diag 2*4=8
        assert_eq!(overlap_pairs(3, 3, false).len(), 12);
        assert_eq!(overlap_pairs(3, 3, true).len(), 20);
    }

    #[test]
    fn size_laws_annotate_every_task() {
        let cfg = MontageConfig {
            grid_w: 3,
            grid_h: 3,
            diagonals: true,
            seed: 5,
        };
        let dag = generate(&cfg);
        for t in &dag.tasks {
            let (inb, outb) = (dag.task_in_bytes(t.id), dag.task_out_bytes(t.id));
            match dag.type_name(t.id) {
                "mProject" => {
                    assert_eq!(inb, RAW_IMAGE_BYTES);
                    assert_eq!(outb, PROJECTED_BYTES);
                }
                "mDiffFit" => {
                    assert_eq!(inb, 0);
                    assert_eq!(outb, DIFF_FIT_BYTES);
                }
                "mAdd" => assert_eq!(outb, MOSAIC_BYTES_PER_IMAGE * 9),
                "mShrink" => assert_eq!(outb, MOSAIC_BYTES_PER_IMAGE * 9 / SHRINK_FACTOR),
                _ => assert!(outb > 0, "{} has no output size", dag.type_name(t.id)),
            }
        }
        // the mosaic dominates: total bytes scale with the grid
        let big = generate(&MontageConfig {
            grid_w: 6,
            grid_h: 6,
            diagonals: true,
            seed: 5,
        });
        assert!(big.total_out_bytes() > dag.total_out_bytes());
    }

    #[test]
    fn with_total_tasks_close() {
        let cfg = MontageConfig::with_total_tasks(16_000, 7);
        let total =
            MontageConfig::total_tasks_for_grid(cfg.grid_w, cfg.grid_h, cfg.diagonals);
        assert!((15_000..17_000).contains(&total), "total {total}");
    }

    #[test]
    fn deterministic_for_seed() {
        let a = generate(&MontageConfig::paper_small());
        let b = generate(&MontageConfig::paper_small());
        assert_eq!(a.len(), b.len());
        for (x, y) in a.tasks.iter().zip(b.tasks.iter()) {
            assert_eq!(x.duration, y.duration);
        }
    }

    #[test]
    fn roots_are_projects_only() {
        let dag = generate(&MontageConfig {
            grid_w: 3,
            grid_h: 2,
            diagonals: true,
            seed: 3,
        });
        let roots = dag.roots();
        assert_eq!(roots.len(), 6);
        for r in roots {
            assert_eq!(dag.type_name(r), "mProject");
        }
    }
}
