//! Workflow model: tasks, the DAG, the Montage generator, and JSON I/O.

pub mod dag;
pub mod montage;
pub mod patterns;
pub mod task;
pub mod wfjson;

pub use dag::Dag;
pub use task::{Task, TaskId, TaskType, TypeId};
