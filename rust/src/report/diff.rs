//! Rendering for `hyperflow diff`: fixed-width terminal text, a
//! self-contained HTML page, and the bench-gate verdict line. The data
//! layer lives in [`crate::obs::diff`]; nothing here recomputes a delta.

use crate::obs::diff::{BenchOutcome, SnapshotDiff};

fn signed_ms(v: i64) -> String {
    format!("{v:+} ms")
}

fn endpoint(task: Option<u64>, ty: &str) -> String {
    match task {
        Some(t) if !ty.is_empty() => format!("task {t} ({ty})"),
        Some(t) => format!("task {t}"),
        None => "path end".to_string(),
    }
}

fn or_dash(s: &str) -> &str {
    if s.is_empty() {
        "-"
    } else {
        s
    }
}

/// Terminal rendering, mirroring the fixed-width style of
/// `Attribution::render`.
pub fn render_text(d: &SnapshotDiff) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "snapshot diff: {} (seed {}) -> {} (seed {})\n",
        d.model_a, d.seed_a, d.model_b, d.seed_b
    ));
    for w in &d.warnings {
        out.push_str(&format!("  warning: {w}\n"));
    }
    out.push_str(&format!(
        "  makespan    {:>10} ms -> {:>10} ms   {}\n",
        d.makespan_a_ms,
        d.makespan_b_ms,
        signed_ms(d.makespan_delta_ms())
    ));
    if d.is_zero() {
        out.push_str("  runs are observationally identical: zero deltas everywhere\n");
        return out;
    }
    if !d.phases.is_empty() {
        out.push_str(
            "\nphase decomposition (B - A; deltas sum exactly to the makespan delta):\n",
        );
        for p in &d.phases {
            out.push_str(&format!(
                "  {:<12}{:>10} ms -> {:>10} ms   {}\n",
                p.phase,
                p.a_ms,
                p.b_ms,
                signed_ms(p.delta_ms())
            ));
        }
        out.push_str(&format!(
            "  {:<12}{:>29}   {}\n",
            "sum",
            "",
            signed_ms(d.phase_delta_sum_ms())
        ));
    }
    out.push_str(&format!(
        "\ncritical path: {} tasks -> {} tasks",
        d.path_len_a, d.path_len_b
    ));
    match &d.divergence {
        Some(v) => out.push_str(&format!(
            "; first divergence at index {}: {} vs {}\n",
            v.index,
            endpoint(v.a_task, &v.a_type),
            endpoint(v.b_task, &v.b_type)
        )),
        None => out.push_str("; identical\n"),
    }
    if !d.counters.is_empty() {
        out.push_str(&format!("\ncounters ({} changed):\n", d.counters.len()));
        for c in &d.counters {
            out.push_str(&format!(
                "  {:<28}{:>12} -> {:>12}   ({:+})\n",
                c.name,
                c.a,
                c.b,
                c.delta()
            ));
        }
    }
    if !d.gauges.is_empty() {
        out.push_str(&format!("\ngauges ({} changed):\n", d.gauges.len()));
        for g in &d.gauges {
            out.push_str(&format!(
                "  {:<28}{:>12.3} -> {:>12.3}\n",
                g.name, g.a, g.b
            ));
        }
    }
    if !d.alerts.is_empty() {
        out.push_str(&format!("\nalerts ({} changed):\n", d.alerts.len()));
        for a in &d.alerts {
            out.push_str(&format!(
                "  {:<28}fired {} -> {}, firing {} ms -> {} ms, \
                 episodes {} -> {}, state {} -> {}\n",
                a.name,
                a.fired_a,
                a.fired_b,
                a.firing_ms_a,
                a.firing_ms_b,
                a.episodes_a,
                a.episodes_b,
                or_dash(&a.state_a),
                or_dash(&a.state_b)
            ));
        }
    }
    if !d.tenants.is_empty() {
        out.push_str(&format!("\ntenants ({} changed):\n", d.tenants.len()));
        for t in &d.tenants {
            out.push_str(&format!(
                "  tenant {:<4}instances {} -> {}, queue-delay {:.2} s -> {:.2} s, \
                 makespan {:.2} s -> {:.2} s, slowdown p99 {:.2} -> {:.2}\n",
                t.tenant,
                t.instances_a,
                t.instances_b,
                t.queue_delay_mean_s_a,
                t.queue_delay_mean_s_b,
                t.makespan_mean_s_a,
                t.makespan_mean_s_b,
                t.slowdown_p99_a,
                t.slowdown_p99_b
            ));
        }
    }
    if !d.phase_tails.is_empty() {
        out.push_str(&format!(
            "\nphase tails ({} shifted, all tasks not just the critical path):\n",
            d.phase_tails.len()
        ));
        for t in &d.phase_tails {
            out.push_str(&format!(
                "  {:<12}mean {:.1} ms -> {:.1} ms, p95 {:.1} ms -> {:.1} ms\n",
                t.phase, t.mean_a_ms, t.mean_b_ms, t.p95_a_ms, t.p95_b_ms
            ));
        }
    }
    out
}

fn esc(s: &str) -> String {
    s.replace('&', "&amp;").replace('<', "&lt;").replace('>', "&gt;")
}

/// Self-contained HTML page for `hyperflow diff --html out.html` — the
/// artifact CI uploads for cross-model comparisons.
pub fn render_html(d: &SnapshotDiff) -> String {
    let mut body = String::new();
    body.push_str(&format!(
        "<h1>hyperflow-k8s run diff</h1>\
         <table class='kv'>\
         <tr><td>run A</td><td><b>{}</b> (seed {})</td></tr>\
         <tr><td>run B</td><td><b>{}</b> (seed {})</td></tr>\
         <tr><td>makespan</td><td>{} ms &rarr; {} ms ({})</td></tr>\
         <tr><td>verdict</td><td><b>{}</b></td></tr>\
         </table>",
        esc(&d.model_a),
        d.seed_a,
        esc(&d.model_b),
        d.seed_b,
        d.makespan_a_ms,
        d.makespan_b_ms,
        signed_ms(d.makespan_delta_ms()),
        if d.is_zero() {
            "runs are observationally identical"
        } else {
            "runs differ"
        }
    ));
    if !d.warnings.is_empty() {
        body.push_str("<ul>");
        for w in &d.warnings {
            body.push_str(&format!("<li>warning: {}</li>", esc(w)));
        }
        body.push_str("</ul>");
    }
    if !d.phases.is_empty() {
        body.push_str(
            "<h2>phase decomposition</h2>\
             <p>B &minus; A per critical-path phase; integer deltas sum \
             exactly to the makespan delta.</p>\
             <table class='data'>\
             <tr><th>phase</th><th>A (ms)</th><th>B (ms)</th><th>&Delta; (ms)</th></tr>",
        );
        for p in &d.phases {
            body.push_str(&format!(
                "<tr><td>{}</td><td>{}</td><td>{}</td><td>{:+}</td></tr>",
                p.phase,
                p.a_ms,
                p.b_ms,
                p.delta_ms()
            ));
        }
        body.push_str(&format!(
            "<tr><th>sum</th><th>{}</th><th>{}</th><th>{:+}</th></tr></table>",
            d.makespan_a_ms,
            d.makespan_b_ms,
            d.phase_delta_sum_ms()
        ));
    }
    body.push_str(&format!(
        "<h2>critical path</h2><p>{} tasks &rarr; {} tasks; {}</p>",
        d.path_len_a,
        d.path_len_b,
        match &d.divergence {
            Some(v) => format!(
                "first divergence at index {}: {} vs {}",
                v.index,
                esc(&endpoint(v.a_task, &v.a_type)),
                esc(&endpoint(v.b_task, &v.b_type))
            ),
            None => "identical".to_string(),
        }
    ));
    if !d.counters.is_empty() {
        body.push_str(
            "<h2>counters</h2><table class='data'>\
             <tr><th>counter</th><th>A</th><th>B</th><th>&Delta;</th></tr>",
        );
        for c in &d.counters {
            body.push_str(&format!(
                "<tr><td>{}</td><td>{}</td><td>{}</td><td>{:+}</td></tr>",
                esc(&c.name),
                c.a,
                c.b,
                c.delta()
            ));
        }
        body.push_str("</table>");
    }
    if !d.gauges.is_empty() {
        body.push_str(
            "<h2>gauges</h2><table class='data'>\
             <tr><th>gauge</th><th>A</th><th>B</th></tr>",
        );
        for g in &d.gauges {
            body.push_str(&format!(
                "<tr><td>{}</td><td>{:.3}</td><td>{:.3}</td></tr>",
                esc(&g.name),
                g.a,
                g.b
            ));
        }
        body.push_str("</table>");
    }
    if !d.alerts.is_empty() {
        body.push_str(
            "<h2>alerts</h2><table class='data'>\
             <tr><th>alert</th><th>fired</th><th>firing (ms)</th>\
             <th>episodes</th><th>final state</th></tr>",
        );
        for a in &d.alerts {
            body.push_str(&format!(
                "<tr><td>{}</td><td>{} &rarr; {}</td><td>{} &rarr; {}</td>\
                 <td>{} &rarr; {}</td><td>{} &rarr; {}</td></tr>",
                esc(&a.name),
                a.fired_a,
                a.fired_b,
                a.firing_ms_a,
                a.firing_ms_b,
                a.episodes_a,
                a.episodes_b,
                esc(or_dash(&a.state_a)),
                esc(or_dash(&a.state_b))
            ));
        }
        body.push_str("</table>");
    }
    if !d.tenants.is_empty() {
        body.push_str(
            "<h2>tenants</h2><table class='data'>\
             <tr><th>tenant</th><th>instances</th><th>queue delay (s)</th>\
             <th>makespan (s)</th><th>slowdown p99</th></tr>",
        );
        for t in &d.tenants {
            body.push_str(&format!(
                "<tr><td>{}</td><td>{} &rarr; {}</td><td>{:.2} &rarr; {:.2}</td>\
                 <td>{:.2} &rarr; {:.2}</td><td>{:.2} &rarr; {:.2}</td></tr>",
                t.tenant,
                t.instances_a,
                t.instances_b,
                t.queue_delay_mean_s_a,
                t.queue_delay_mean_s_b,
                t.makespan_mean_s_a,
                t.makespan_mean_s_b,
                t.slowdown_p99_a,
                t.slowdown_p99_b
            ));
        }
        body.push_str("</table>");
    }
    if !d.phase_tails.is_empty() {
        body.push_str(
            "<h2>phase tails (all tasks)</h2><table class='data'>\
             <tr><th>phase</th><th>mean (ms)</th><th>p95 (ms)</th></tr>",
        );
        for t in &d.phase_tails {
            body.push_str(&format!(
                "<tr><td>{}</td><td>{:.1} &rarr; {:.1}</td><td>{:.1} &rarr; {:.1}</td></tr>",
                esc(&t.phase),
                t.mean_a_ms,
                t.mean_b_ms,
                t.p95_a_ms,
                t.p95_b_ms
            ));
        }
        body.push_str("</table>");
    }
    format!(
        "<!DOCTYPE html><html><head><meta charset='utf-8'>\
         <title>hyperflow-k8s diff</title><style>\
         body{{font-family:sans-serif;max-width:900px;margin:24px auto}}\
         table.kv td{{padding:2px 10px}}\
         table.data{{border-collapse:collapse}}\
         table.data td,table.data th{{border:1px solid #999;padding:3px 10px;text-align:right}}\
         </style></head><body>{body}</body></html>"
    )
}

/// Verdict line(s) for `hyperflow diff --bench` — what CI logs before
/// deciding the exit code.
pub fn render_bench_text(base_path: &str, cur_path: &str, out: &BenchOutcome) -> String {
    match out {
        BenchOutcome::Skipped(why) => {
            format!("bench gate: SKIPPED ({base_path} vs {cur_path}): {why}\n")
        }
        BenchOutcome::Compared {
            checked,
            breaches,
            warnings,
        } => {
            let mut s = format!(
                "bench gate: {base_path} vs {cur_path}: {checked} metrics checked\n"
            );
            for w in warnings {
                s.push_str(&format!("  warning: {w}\n"));
            }
            if breaches.is_empty() {
                s.push_str("  PASS: all metrics within tolerance\n");
            } else {
                s.push_str(&format!(
                    "  FAIL: {} metric(s) out of tolerance\n",
                    breaches.len()
                ));
                for b in breaches {
                    let sign = if b.cur >= b.base { "+" } else { "-" };
                    s.push_str(&format!(
                        "    {:<40}{:>14.4} -> {:>14.4}   {sign}{:.1}% (tolerance {:.1}%)\n",
                        b.path,
                        b.base,
                        b.cur,
                        b.rel * 100.0,
                        b.tol * 100.0
                    ));
                }
            }
            s
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::diff::{BenchBreach, CounterDelta, Divergence, PhaseDelta};

    fn sample(zero: bool) -> SnapshotDiff {
        let (b_compute, b_makespan, counters, divergence) = if zero {
            (8_000, 10_000, Vec::new(), None)
        } else {
            (
                9_500,
                11_500,
                vec![CounterDelta {
                    name: "pods_created".into(),
                    a: 16,
                    b: 40,
                    in_a: true,
                    in_b: true,
                }],
                Some(Divergence {
                    index: 1,
                    a_task: Some(2),
                    a_type: "mAdd".into(),
                    b_task: Some(5),
                    b_type: "mDiffFit".into(),
                }),
            )
        };
        SnapshotDiff {
            model_a: "worker-pools".into(),
            model_b: "job".into(),
            seed_a: 7,
            seed_b: 7,
            makespan_a_ms: 10_000,
            makespan_b_ms: b_makespan,
            phases: vec![
                PhaseDelta {
                    phase: "queueing",
                    a_ms: 2_000,
                    b_ms: 2_000,
                },
                PhaseDelta {
                    phase: "compute",
                    a_ms: 8_000,
                    b_ms: b_compute,
                },
            ],
            path_len_a: 2,
            path_len_b: 2,
            divergence,
            counters,
            gauges: Vec::new(),
            alerts: Vec::new(),
            tenants: Vec::new(),
            phase_tails: Vec::new(),
            warnings: Vec::new(),
        }
    }

    #[test]
    fn zero_diff_renders_the_identical_verdict() {
        let txt = render_text(&sample(true));
        assert!(txt.contains("observationally identical"));
        assert!(!txt.contains("phase decomposition"));
    }

    #[test]
    fn nonzero_diff_renders_phases_path_and_counters() {
        let txt = render_text(&sample(false));
        assert!(txt.contains("+1500 ms"), "makespan and sum delta:\n{txt}");
        assert!(txt.contains("phase decomposition"));
        assert!(txt.contains("first divergence at index 1"));
        assert!(txt.contains("task 5 (mDiffFit)"));
        assert!(txt.contains("pods_created"));
    }

    #[test]
    fn html_is_a_complete_escaped_page() {
        let mut d = sample(false);
        d.model_b = "job<xl>".into();
        let html = render_html(&d);
        assert!(html.starts_with("<!DOCTYPE html>"));
        assert!(html.ends_with("</html>"));
        assert!(html.contains("job&lt;xl&gt;"));
        assert!(html.contains("phase decomposition"));
    }

    #[test]
    fn bench_text_covers_all_three_verdicts() {
        let skip = BenchOutcome::Skipped("placeholder".into());
        assert!(render_bench_text("a.json", "b.json", &skip).contains("SKIPPED"));
        let pass = BenchOutcome::Compared {
            checked: 12,
            breaches: Vec::new(),
            warnings: vec!["models[3]: in current only".into()],
        };
        let txt = render_bench_text("a.json", "b.json", &pass);
        assert!(txt.contains("PASS") && txt.contains("warning"));
        let fail = BenchOutcome::Compared {
            checked: 12,
            breaches: vec![BenchBreach {
                path: "models[0].ms_per_iter".into(),
                base: 100.0,
                cur: 160.0,
                rel: 0.6,
                tol: 0.3,
            }],
            warnings: Vec::new(),
        };
        let txt = render_bench_text("a.json", "b.json", &fail);
        assert!(txt.contains("FAIL") && txt.contains("+60.0% (tolerance 30.0%)"));
    }
}
