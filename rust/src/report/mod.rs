//! Execution traces and report generation (the data behind Figs. 3-6).

pub mod chrome;
pub mod diff;
pub mod figures;
pub mod html;

use crate::metrics::Registry;
use crate::sim::SimTime;
use crate::util::json::Json;
use crate::workflow::dag::Dag;
use crate::workflow::task::{TaskId, TypeId};
use std::collections::BTreeMap;

/// Per-task lifecycle record.
#[derive(Debug, Clone)]
pub struct TaskRecord {
    pub task: TaskId,
    /// Dense index into the owning [`Trace`]'s type-name table — resolve
    /// with [`Trace::type_name`]. Storing the id instead of a `String`
    /// keeps the per-ready hot path allocation-free (EXPERIMENTS.md §Perf).
    pub ttype: TypeId,
    /// Dependencies satisfied; handed to the execution model.
    pub ready_at: SimTime,
    /// Execution began in a pod.
    pub started_at: Option<SimTime>,
    pub finished_at: Option<SimTime>,
    /// Pod that executed the task.
    pub pod: Option<u64>,
}

/// The full execution trace of one simulated run.
///
/// Indexed directly by TaskId (a dense u32) — a BTreeMap index here showed
/// up in the 16k-sim profile (EXPERIMENTS.md §Perf).
#[derive(Debug, Default)]
pub struct Trace {
    pub records: Vec<TaskRecord>,
    index: Vec<u32>,
    /// Task-type names, cloned once from the DAG at kernel build; records
    /// carry only the dense [`TypeId`].
    type_names: Vec<String>,
}

const NO_RECORD: u32 = u32::MAX;

impl Trace {
    pub fn new() -> Self {
        Trace::default()
    }

    /// A trace whose records resolve type names against `names` (one entry
    /// per DAG task type, in type-id order).
    pub fn with_type_names(names: Vec<String>) -> Self {
        Trace {
            type_names: names,
            ..Trace::default()
        }
    }

    /// Resolve a record's task-type name.
    pub fn type_name(&self, r: &TaskRecord) -> &str {
        self.type_names
            .get(r.ttype.0 as usize)
            .map(String::as_str)
            .unwrap_or("?")
    }

    pub fn ready(&mut self, task: TaskId, ttype: TypeId, now: SimTime) {
        let slot = task.0 as usize;
        if slot >= self.index.len() {
            self.index.resize(slot + 1, NO_RECORD);
        }
        self.index[slot] = self.records.len() as u32;
        self.records.push(TaskRecord {
            task,
            ttype,
            ready_at: now,
            started_at: None,
            finished_at: None,
            pod: None,
        });
    }

    pub fn started(&mut self, task: TaskId, pod: u64, now: SimTime) {
        let i = self.index[task.0 as usize] as usize;
        self.records[i].started_at = Some(now);
        self.records[i].pod = Some(pod);
    }

    pub fn finished(&mut self, task: TaskId, now: SimTime) {
        let i = self.index[task.0 as usize] as usize;
        self.records[i].finished_at = Some(now);
    }

    pub fn record(&self, task: TaskId) -> Option<&TaskRecord> {
        let slot = task.0 as usize;
        if slot >= self.index.len() || self.index[slot] == NO_RECORD {
            return None;
        }
        Some(&self.records[self.index[slot] as usize])
    }

    /// Queueing delay (ready -> started) summary per type.
    ///
    /// Accumulates into a dense per-TypeId table first; each type's name
    /// is cloned exactly once when the map is assembled, instead of once
    /// per record.
    pub fn wait_times_by_type(&self) -> BTreeMap<String, crate::util::stats::Summary> {
        let n = self
            .records
            .iter()
            .map(|r| r.ttype.0 as usize + 1)
            .max()
            .unwrap_or(0)
            .max(self.type_names.len());
        let mut per_type: Vec<crate::util::stats::Summary> = vec![Default::default(); n];
        for r in &self.records {
            if let Some(s) = r.started_at {
                per_type[r.ttype.0 as usize].add((s - r.ready_at).as_secs_f64());
            }
        }
        per_type
            .into_iter()
            .enumerate()
            .filter(|(_, s)| s.len() > 0)
            .map(|(i, s)| {
                let name = self
                    .type_names
                    .get(i)
                    .cloned()
                    .unwrap_or_else(|| format!("type{i}"));
                (name, s)
            })
            .collect()
    }
}

/// Result of one simulated workflow execution.
#[derive(Debug)]
pub struct SimResult {
    pub model_name: String,
    pub makespan: SimTime,
    pub trace: Trace,
    pub metrics: Registry,
    pub pods_created: u64,
    pub api_requests: u64,
    pub sched_backoffs: u64,
    /// Successful scheduler binds (determinism fingerprint alongside
    /// `sched_backoffs`: sensitive to any event-ordering change).
    pub sched_binds: u64,
    /// Discrete events processed by the driver loop — the denominator for
    /// the events/sec throughput reported by `coordinator_hotpath`.
    pub sim_events: u64,
    /// Calendar-event arena counters (fresh slab growth vs free-list
    /// reuse); `coordinator_hotpath` reports the reuse ratio in
    /// `BENCH_driver.json`. Not part of the snapshot surface.
    pub event_arena: crate::sim::ArenaStats,
    /// Average number of concurrently running tasks over the makespan —
    /// the paper's cluster-utilization subplot metric.
    pub avg_running_tasks: f64,
    /// Average allocated CPU fraction of the cluster over the makespan.
    pub avg_cpu_utilization: f64,
    /// Resilience accounting from the chaos engine (all-zero, with
    /// `enabled == false`, on healthy runs).
    pub chaos: crate::chaos::ChaosReport,
    /// Data-plane accounting (all-zero, with `enabled == false`, when the
    /// data plane is off).
    pub data: crate::data::DataReport,
    /// Multi-tenant isolation accounting: quota throttles, placement
    /// violations and takeover blast radii (all-zero, with
    /// `enabled == false`, when isolation is off).
    pub isolation: crate::k8s::isolation::IsolationReport,
    /// Flight-recorder artifacts (spans, control-plane events,
    /// critical-path attribution). `None` unless the run opted in via
    /// `SimConfig::obs` — recording never perturbs the simulation.
    pub obs: Option<crate::obs::ObsReport>,
    /// Monitoring-stack report (alert lifecycles, final recording-rule
    /// values). `None` unless the run opted in via `SimConfig::monitor`.
    pub monitor: Option<crate::obs::monitor::MonitorReport>,
}

impl SimResult {
    /// The utilization series plotted in the paper's subplots:
    /// "the number of workflow tasks executing in parallel at any time".
    pub fn running_series(&self) -> Vec<(f64, f64)> {
        self.metrics
            .gauge("running_tasks")
            .map(|s| s.points().to_vec())
            .unwrap_or_default()
    }

    /// Per-stage running-task series (for the Gantt-like strips).
    pub fn stage_series(&self, dag: &Dag) -> Vec<(String, Vec<(f64, f64)>)> {
        let mut out = Vec::new();
        for ty in &dag.types {
            if let Some(s) = self.metrics.gauge(&format!("running::{}", ty.name)) {
                out.push((ty.name.clone(), s.points().to_vec()));
            }
        }
        out
    }

    /// Export the run as JSON (consumed by the figure benches and by
    /// downstream analysis).
    pub fn to_json(&self) -> Json {
        let series: Vec<Json> = self
            .running_series()
            .iter()
            .map(|&(t, v)| Json::Arr(vec![t.into(), v.into()]))
            .collect();
        Json::obj(vec![
            ("model", Json::str(&self.model_name)),
            ("makespan_s", self.makespan.as_secs_f64().into()),
            ("pods_created", self.pods_created.into()),
            ("api_requests", self.api_requests.into()),
            ("sched_backoffs", self.sched_backoffs.into()),
            ("sched_binds", self.sched_binds.into()),
            ("sim_events", self.sim_events.into()),
            ("avg_running_tasks", self.avg_running_tasks.into()),
            ("avg_cpu_utilization", self.avg_cpu_utilization.into()),
            ("chaos", self.chaos.to_json()),
            ("data", self.data.to_json()),
            ("isolation", self.isolation.to_json()),
            (
                "obs",
                match &self.obs {
                    Some(o) => o.to_json(),
                    None => Json::Null,
                },
            ),
            (
                "monitor",
                match &self.monitor {
                    Some(m) => m.to_json(),
                    None => Json::Null,
                },
            ),
            ("running_tasks_series", Json::Arr(series)),
        ])
    }

    /// CSV of the resampled utilization series (1 s grid).
    pub fn utilization_csv(&self) -> String {
        let mut out = String::from("t_s,running_tasks\n");
        if let Some(s) = self.metrics.gauge("running_tasks") {
            for (t, v) in s.resample(self.makespan.as_secs_f64(), 1.0) {
                out.push_str(&format!("{t:.0},{v:.0}\n"));
            }
        }
        out
    }
}

/// Write a report file under `bench_out/`, creating the directory.
pub fn write_output(name: &str, content: &str) -> std::io::Result<String> {
    let dir = std::path::Path::new("bench_out");
    std::fs::create_dir_all(dir)?;
    let path = dir.join(name);
    std::fs::write(&path, content)?;
    Ok(path.to_string_lossy().into_owned())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_lifecycle() {
        let mut tr = Trace::with_type_names(vec!["mProject".to_string()]);
        tr.ready(TaskId(0), TypeId(0), SimTime(100));
        tr.started(TaskId(0), 7, SimTime(2_000));
        tr.finished(TaskId(0), SimTime(14_000));
        let r = tr.record(TaskId(0)).unwrap();
        assert_eq!(r.ready_at, SimTime(100));
        assert_eq!(r.pod, Some(7));
        assert_eq!(r.finished_at, Some(SimTime(14_000)));
        assert_eq!(tr.type_name(r), "mProject");
    }

    #[test]
    fn wait_times_grouped_by_type() {
        let mut tr = Trace::with_type_names(vec!["A".to_string(), "B".to_string()]);
        tr.ready(TaskId(0), TypeId(0), SimTime(0));
        tr.started(TaskId(0), 1, SimTime(1_000));
        tr.ready(TaskId(1), TypeId(0), SimTime(0));
        tr.started(TaskId(1), 2, SimTime(3_000));
        // type B never started: it must not appear in the map at all
        tr.ready(TaskId(2), TypeId(1), SimTime(0));
        let w = tr.wait_times_by_type();
        assert_eq!(w.len(), 1);
        assert_eq!(w["A"].len(), 2);
        assert!((w["A"].mean() - 2.0).abs() < 1e-9);
    }
}
