//! Self-contained HTML report for a run: summary table, the utilization
//! chart (the paper's subplot), per-pool queue/replica charts, and
//! per-type latency statistics. `hyperflow run --html out.html`.

use super::SimResult;
use crate::util::svg::AreaChart;

pub fn render(res: &SimResult) -> String {
    let t_end = res.makespan.as_secs_f64();
    let mut body = String::new();

    body.push_str(&format!(
        "<h1>hyperflow-k8s run report</h1>\
         <table class='kv'>\
         <tr><td>model</td><td><b>{}</b></td></tr>\
         <tr><td>makespan</td><td>{:.0} s</td></tr>\
         <tr><td>pods created</td><td>{}</td></tr>\
         <tr><td>API requests</td><td>{}</td></tr>\
         <tr><td>scheduler back-offs</td><td>{}</td></tr>\
         <tr><td>avg parallel tasks</td><td>{:.1}</td></tr>\
         <tr><td>avg CPU utilization</td><td>{:.1}%</td></tr>\
         </table>",
        res.model_name,
        t_end,
        res.pods_created,
        res.api_requests,
        res.sched_backoffs,
        res.avg_running_tasks,
        res.avg_cpu_utilization * 100.0
    ));

    // resilience section (chaos runs only)
    if res.chaos.enabled {
        let c = &res.chaos;
        body.push_str(&format!(
            "<h2>resilience (chaos engine)</h2>\
             <table class='kv'>\
             <tr><td>faults injected</td><td>{} (pod {}, spot reclaim {}, crash {})</td></tr>\
             <tr><td>retries</td><td>{}</td></tr>\
             <tr><td>speculative copies</td><td>{} ({} lost races)</td></tr>\
             <tr><td>node blacklists</td><td>{}</td></tr>\
             <tr><td>wasted work</td><td>{:.1} s</td></tr>\
             <tr><td>goodput</td><td>{:.1}%</td></tr>\
             <tr><td>recovery latency</td><td>p50 {:.1} s &middot; p95 {:.1} s &middot; p99 {:.1} s ({} recoveries)</td></tr>\
             </table>",
            c.faults_total(),
            c.pod_failures,
            c.spot_reclaims,
            c.node_crashes,
            c.retries,
            c.speculations,
            res.metrics.counter("speculative_losses"),
            c.blacklists,
            c.wasted_ms as f64 / 1000.0,
            c.goodput() * 100.0,
            c.recovery_p50_s,
            c.recovery_p95_s,
            c.recovery_p99_s,
            c.recoveries,
        ));
    }

    // data-plane section (data runs only)
    if res.data.enabled {
        let d = &res.data;
        body.push_str(&format!(
            "<h2>data plane (storage &amp; transfers)</h2>\
             <table class='kv'>\
             <tr><td>bytes moved</td><td>{:.2} GB ({:.2} in / {:.2} out, {} transfers)</td></tr>\
             <tr><td>cache hit ratio</td><td>{:.1}% ({} hits, {} misses, {} evictions)</td></tr>\
             <tr><td>stage-in latency</td><td>p50 {:.2} s &middot; p95 {:.2} s &middot; p99 {:.2} s ({} stage-ins)</td></tr>\
             <tr><td>I/O share of task time</td><td>{:.1}%</td></tr>\
             </table>",
            d.bytes_moved() as f64 / 1e9,
            d.bytes_in as f64 / 1e9,
            d.bytes_out as f64 / 1e9,
            d.transfers,
            d.cache_hit_ratio() * 100.0,
            d.hits,
            d.misses,
            d.evictions,
            d.stage_in_p50_s,
            d.stage_in_p95_s,
            d.stage_in_p99_s,
            d.stage_ins,
            d.io_frac() * 100.0,
        ));
    }

    // critical-path attribution (flight-recorder runs only)
    if let Some(a) = res.obs.as_ref().and_then(|o| o.attribution.as_ref()) {
        let pct = |ms: u64| ms as f64 / res.makespan.as_millis().max(1) as f64 * 100.0;
        body.push_str(&format!(
            "<h2>critical-path attribution ({} tasks on the path)</h2>\
             <table class='kv'>\
             <tr><td>queueing</td><td>{:.1} s ({:.1}%)</td></tr>\
             <tr><td>scheduling</td><td>{:.1} s ({:.1}%)</td></tr>\
             <tr><td>pod start</td><td>{:.1} s ({:.1}%)</td></tr>\
             <tr><td>stage-in</td><td>{:.1} s ({:.1}%)</td></tr>\
             <tr><td>compute</td><td>{:.1} s ({:.1}%)</td></tr>\
             <tr><td>stage-out</td><td>{:.1} s ({:.1}%)</td></tr>\
             <tr><td>recovery</td><td>{:.1} s ({:.1}%)</td></tr>\
             </table>",
            a.path_tasks,
            a.queueing_ms as f64 / 1000.0,
            pct(a.queueing_ms),
            a.scheduling_ms as f64 / 1000.0,
            pct(a.scheduling_ms),
            a.pod_start_ms as f64 / 1000.0,
            pct(a.pod_start_ms),
            a.stage_in_ms as f64 / 1000.0,
            pct(a.stage_in_ms),
            a.compute_ms as f64 / 1000.0,
            pct(a.compute_ms),
            a.stage_out_ms as f64 / 1000.0,
            pct(a.stage_out_ms),
            a.recovery_ms as f64 / 1000.0,
            pct(a.recovery_ms),
        ));
    }

    // alert timeline (monitor runs only)
    if let Some(mon) = &res.monitor {
        body.push_str(&format!(
            "<h2>monitoring &amp; alerting</h2>\
             <table class='kv'>\
             <tr><td>scrapes</td><td>{} every {:.0} s</td></tr>\
             <tr><td>alert rules</td><td>{}</td></tr>\
             <tr><td>alerts fired</td><td>{}</td></tr>\
             <tr><td>time firing</td><td>{:.1} s</td></tr>\
             </table>",
            mon.ticks,
            mon.interval_ms as f64 / 1000.0,
            mon.alerts.len(),
            mon.fired_total(),
            mon.firing_ms_total() as f64 / 1000.0,
        ));
        let mut rows = String::new();
        for a in &mon.alerts {
            for ep in &a.episodes {
                rows.push_str(&format!(
                    "<tr><td>{}</td><td>{}</td><td>{:.1}</td><td>{}</td><td>{}</td><td>{:.3}</td></tr>",
                    a.name,
                    a.severity,
                    ep.pending_ms as f64 / 1000.0,
                    match ep.firing_ms {
                        Some(t) => format!("{:.1}", t as f64 / 1000.0),
                        None => "&mdash;".into(),
                    },
                    match ep.resolved_ms {
                        Some(t) => format!("{:.1}", t as f64 / 1000.0),
                        None => "open".into(),
                    },
                    ep.peak,
                ));
            }
        }
        if !rows.is_empty() {
            body.push_str(&format!(
                "<h3>alert timeline</h3>\
                 <table class='data'><tr><th>alert</th><th>severity</th>\
                 <th>pending s</th><th>firing s</th><th>resolved s</th>\
                 <th>peak</th></tr>{rows}</table>"
            ));
        }
    }

    body.push_str(
        &AreaChart {
            title: "cluster utilization: workflow tasks executing in parallel".into(),
            ..Default::default()
        }
        .render(&res.running_series(), t_end),
    );

    // per-stage series
    for name in res.metrics.gauge_names().map(str::to_string).collect::<Vec<_>>() {
        if let Some(stage) = name.strip_prefix("running::") {
            let series = res.metrics.gauge(&name).unwrap().points().to_vec();
            if series.iter().any(|&(_, v)| v > 0.0) {
                body.push_str(
                    &AreaChart {
                        title: format!("running tasks — {stage}"),
                        height: 120,
                        color: "#6a9a58".into(),
                        ..Default::default()
                    }
                    .render(&series, t_end),
                );
            }
        }
    }
    // pool queues + replicas
    for name in res.metrics.gauge_names().map(str::to_string).collect::<Vec<_>>() {
        if let Some(pool) = name.strip_prefix("queue::") {
            let series = res.metrics.gauge(&name).unwrap().points().to_vec();
            body.push_str(
                &AreaChart {
                    title: format!("queue depth — {pool}"),
                    height: 120,
                    color: "#a8783c".into(),
                    ..Default::default()
                }
                .render(&series, t_end),
            );
        }
    }

    // wait-time table
    body.push_str(
        "<h2>task wait times (ready &rarr; started)</h2>\
         <table class='data'><tr><th>type</th><th>n</th><th>mean s</th>\
         <th>p50 s</th><th>p95 s</th><th>p99 s</th><th>max s</th></tr>",
    );
    for (ty, s) in res.trace.wait_times_by_type() {
        let row = s.percentile_row();
        body.push_str(&format!(
            "<tr><td>{ty}</td><td>{}</td><td>{:.1}</td><td>{:.1}</td><td>{:.1}</td><td>{:.1}</td><td>{:.1}</td></tr>",
            s.len(),
            s.mean(),
            row.p50,
            row.p95,
            row.p99,
            s.max()
        ));
    }
    body.push_str("</table>");

    format!(
        "<!DOCTYPE html><html><head><meta charset='utf-8'>\
         <title>hyperflow-k8s report</title><style>\
         body{{font-family:sans-serif;max-width:900px;margin:24px auto}}\
         table.kv td{{padding:2px 10px}}\
         table.data{{border-collapse:collapse}}\
         table.data td,table.data th{{border:1px solid #999;padding:3px 10px;text-align:right}}\
         svg{{display:block;margin:14px 0}}\
         </style></head><body>{body}</body></html>"
    )
}

#[cfg(test)]
mod tests {
    use crate::models::{driver, ExecModel};
    use crate::workflow::montage::{generate, MontageConfig};

    #[test]
    fn report_is_complete_html() {
        let res = driver::run(
            generate(&MontageConfig {
                grid_w: 3,
                grid_h: 3,
                diagonals: true,
                seed: 1,
            }),
            ExecModel::paper_hybrid_pools(),
            driver::SimConfig::with_nodes(3),
        );
        let html = super::render(&res);
        assert!(html.starts_with("<!DOCTYPE html>"));
        assert!(html.ends_with("</body></html>"));
        assert!(html.contains("worker-pools"));
        assert!(html.contains("<svg"));
        assert!(html.contains("queue depth — mProject"));
        assert!(html.contains("task wait times"));
        assert!(html.contains("<th>p99 s</th>"), "tail-latency column");
        assert!(
            !html.contains("resilience"),
            "healthy runs carry no chaos section"
        );
        assert!(
            !html.contains("data plane"),
            "data-off runs carry no storage section"
        );
        assert!(
            !html.contains("critical-path attribution"),
            "obs-off runs carry no attribution section"
        );
        assert!(
            !html.contains("monitoring &amp; alerting"),
            "monitor-off runs carry no alert section"
        );
    }

    #[test]
    fn monitor_run_renders_the_alert_timeline() {
        // a real monitor run: builtin rules on a tightly packed cluster
        let mut cfg = driver::SimConfig::with_nodes(3);
        cfg.monitor = Some(crate::obs::monitor::MonitorConfig::default());
        let mut res = driver::run(
            generate(&MontageConfig {
                grid_w: 3,
                grid_h: 3,
                diagonals: true,
                seed: 1,
            }),
            ExecModel::paper_hybrid_pools(),
            cfg,
        );
        assert!(res.monitor.is_some(), "monitor report attached");
        // pin one episode so the timeline table renders regardless of
        // whether the healthy run tripped any builtin alert
        if let Some(mon) = res.monitor.as_mut() {
            if let Some(a) = mon.alerts.first_mut() {
                a.fired += 1;
                a.episodes.push(crate::obs::alerts::Episode {
                    pending_ms: 30_000,
                    firing_ms: Some(60_000),
                    resolved_ms: Some(90_000),
                    peak: 17.0,
                });
            }
        }
        let html = super::render(&res);
        assert!(html.contains("monitoring &amp; alerting"));
        assert!(html.contains("alerts fired"));
        assert!(html.contains("alert timeline"));
        assert!(html.contains("<th>peak</th>"));
        assert!(html.contains("<td>17.000</td>"));
    }

    #[test]
    fn obs_run_renders_the_attribution_section() {
        let res = driver::run(
            generate(&MontageConfig {
                grid_w: 3,
                grid_h: 3,
                diagonals: true,
                seed: 1,
            }),
            ExecModel::paper_hybrid_pools(),
            driver::SimConfig::with_nodes(3).obs(true),
        );
        let html = super::render(&res);
        assert!(html.contains("critical-path attribution"));
        assert!(html.contains("<td>compute</td>"));
    }

    #[test]
    fn data_run_renders_the_storage_section() {
        let mut cfg = driver::SimConfig::with_nodes(3);
        cfg.data = Some(crate::data::DataConfig::parse_spec("nfs:1,cache:4").unwrap());
        let res = driver::run(
            generate(&MontageConfig {
                grid_w: 3,
                grid_h: 3,
                diagonals: true,
                seed: 2,
            }),
            ExecModel::paper_hybrid_pools(),
            cfg,
        );
        let html = super::render(&res);
        assert!(html.contains("data plane (storage"));
        assert!(html.contains("cache hit ratio"));
        assert!(html.contains("stage-in latency"));
    }

    #[test]
    fn chaos_run_renders_the_resilience_section() {
        let mut cfg = driver::SimConfig::with_nodes(3);
        cfg.chaos =
            crate::chaos::ChaosConfig::parse_spec("pod:0.2,crash:4,straggler:0.3").unwrap();
        cfg.seed = 11;
        let res = driver::run(
            generate(&MontageConfig {
                grid_w: 4,
                grid_h: 4,
                diagonals: true,
                seed: 2,
            }),
            ExecModel::paper_hybrid_pools(),
            cfg,
        );
        let html = super::render(&res);
        assert!(html.contains("resilience (chaos engine)"));
        assert!(html.contains("goodput"));
        assert!(html.contains("recovery latency"));
    }
}
