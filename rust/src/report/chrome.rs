//! Chrome trace-event export: open the trace in `chrome://tracing` /
//! Perfetto to see the per-pod Gantt chart of a run. Each pod is a "thread"
//! and each task a complete event (`ph: "X"`).
//!
//! When the run carried a flight recorder (`SimConfig::obs`), the trace
//! grows three extra tracks:
//!
//! - pid 2 "control-plane": one instant-event lane per actor (scheduler,
//!   autoscaler, broker, chaos, data, fleet);
//! - pid 3 "counters": every gauge series as Chrome counter events
//!   (`ph: "C"`), rendered by Perfetto as stacked area charts;
//! - pid 100+node: per-node pod lanes, one complete event per pod from
//!   creation to termination.
//!
//! A `--monitor` run adds pid 4 "alerts": one lane per alert rule, with a
//! complete event per episode spanning pending→resolved (still-open
//! episodes extend to the makespan).

use super::SimResult;
use crate::obs::Actor;
use crate::util::json::Json;

/// Lane for tasks that never reached a pod (killed before dispatch).
const LOST_TID: u64 = u64::MAX;

fn process_name(pid: u64, name: &str) -> Json {
    Json::obj(vec![
        ("name", Json::str("process_name")),
        ("ph", Json::str("M")),
        ("pid", pid.into()),
        ("args", Json::obj(vec![("name", Json::str(name))])),
    ])
}

fn thread_name(pid: u64, tid: u64, name: &str) -> Json {
    Json::obj(vec![
        ("name", Json::str("thread_name")),
        ("ph", Json::str("M")),
        ("pid", pid.into()),
        ("tid", tid.into()),
        ("args", Json::obj(vec![("name", Json::str(name))])),
    ])
}

/// Build the trace-event JSON for a run.
pub fn to_chrome_trace(res: &SimResult) -> Json {
    let mut events = Vec::new();
    // process metadata
    events.push(process_name(
        1,
        &format!("hyperflow-k8s ({})", res.model_name),
    ));
    for r in &res.trace.records {
        // Unfinished or never-dispatched tasks still get a zero-duration
        // event: killed work must stay visible in the Gantt chart.
        let start = r.started_at.unwrap_or(r.ready_at);
        let end = r.finished_at.unwrap_or(start);
        let lost = r.finished_at.is_none() || r.pod.is_none();
        events.push(Json::obj(vec![
            ("name", Json::str(res.trace.type_name(r))),
            ("cat", Json::str(if lost { "lost" } else { "task" })),
            ("ph", Json::str("X")),
            ("pid", 1u64.into()),
            ("tid", r.pod.unwrap_or(LOST_TID).into()),
            // chrome traces are in microseconds
            ("ts", (start.as_millis() * 1000).into()),
            ("dur", ((end - start).as_millis() * 1000).into()),
            (
                "args",
                Json::obj(vec![
                    ("task", (r.task.0 as u64).into()),
                    ("ready_at_ms", r.ready_at.as_millis().into()),
                ]),
            ),
        ]));
    }
    if let Some(o) = &res.obs {
        push_control_plane(&mut events, o);
        push_counters(&mut events, res);
        push_node_lanes(&mut events, o);
    }
    if let Some(m) = &res.monitor {
        push_alert_track(&mut events, m);
    }
    Json::obj(vec![("traceEvents", Json::Arr(events))])
}

/// pid 4: one lane per alert rule, one complete event per episode.
fn push_alert_track(events: &mut Vec<Json>, m: &crate::obs::monitor::MonitorReport) {
    events.push(process_name(4, "alerts"));
    for (tid, a) in m.alerts.iter().enumerate() {
        let tid = tid as u64;
        events.push(thread_name(4, tid, &a.name));
        for ep in &a.episodes {
            let end = ep.resolved_ms.unwrap_or(m.makespan_ms);
            events.push(Json::obj(vec![
                ("name", Json::str(&a.name)),
                ("cat", Json::str("alert")),
                ("ph", Json::str("X")),
                ("pid", 4u64.into()),
                ("tid", tid.into()),
                ("ts", (ep.pending_ms * 1000).into()),
                ("dur", (end.saturating_sub(ep.pending_ms) * 1000).into()),
                (
                    "args",
                    Json::obj(vec![
                        ("severity", Json::str(&a.severity)),
                        (
                            "firing_ms",
                            match ep.firing_ms {
                                Some(t) => t.into(),
                                None => Json::Null,
                            },
                        ),
                        ("peak", ep.peak.into()),
                        ("resolved", ep.resolved_ms.is_some().into()),
                    ]),
                ),
            ]));
        }
    }
}

/// pid 2: one instant-event lane per control-plane actor.
fn push_control_plane(events: &mut Vec<Json>, o: &crate::obs::ObsReport) {
    events.push(process_name(2, "control-plane"));
    for a in Actor::ALL {
        events.push(thread_name(2, a.tid(), a.name()));
    }
    for e in &o.events {
        events.push(Json::obj(vec![
            ("name", Json::str(e.kind)),
            ("cat", Json::str(e.actor.name())),
            ("ph", Json::str("I")),
            ("s", Json::str("t")),
            ("pid", 2u64.into()),
            ("tid", e.actor.tid().into()),
            ("ts", (e.at.as_millis() * 1000).into()),
            (
                "args",
                Json::obj(vec![
                    ("detail", Json::str(&e.detail)),
                    ("value", e.value.into()),
                ]),
            ),
        ]));
    }
}

/// pid 3: every gauge series as Chrome counter events.
fn push_counters(events: &mut Vec<Json>, res: &SimResult) {
    events.push(process_name(3, "counters"));
    for name in res.metrics.gauge_names() {
        let Some(s) = res.metrics.gauge(name) else { continue };
        for &(t, v) in s.points() {
            events.push(Json::obj(vec![
                ("name", Json::str(name)),
                ("ph", Json::str("C")),
                ("pid", 3u64.into()),
                // gauge timestamps are in seconds
                ("ts", ((t * 1e6) as u64).into()),
                ("args", Json::obj(vec![("value", v.into())])),
            ]));
        }
    }
}

/// pid 100+node: per-node pod lanes (pool workers and job pods alike).
fn push_node_lanes(events: &mut Vec<Json>, o: &crate::obs::ObsReport) {
    let mut named = std::collections::BTreeSet::new();
    for p in &o.pods {
        let Some(node) = p.node else { continue };
        let pid = 100 + node as u64;
        if named.insert(node) {
            events.push(process_name(pid, &format!("node {node}")));
        }
        let end = p.finished.or(p.running).unwrap_or(p.created);
        events.push(Json::obj(vec![
            (
                "name",
                Json::str(p.pool.as_deref().unwrap_or("job pod")),
            ),
            ("cat", Json::str("pod")),
            ("ph", Json::str("X")),
            ("pid", pid.into()),
            ("tid", p.pod.into()),
            ("ts", (p.created.as_millis() * 1000).into()),
            (
                "dur",
                ((end.saturating_sub(p.created)).as_millis() * 1000).into(),
            ),
            (
                "args",
                Json::obj(vec![
                    (
                        "scheduled_ms",
                        match p.scheduled {
                            Some(t) => t.as_millis().into(),
                            None => Json::Null,
                        },
                    ),
                    (
                        "running_ms",
                        match p.running {
                            Some(t) => t.as_millis().into(),
                            None => Json::Null,
                        },
                    ),
                ]),
            ),
        ]));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{driver, ExecModel};
    use crate::workflow::montage::{generate, MontageConfig};

    fn dag3x3() -> crate::workflow::dag::Dag {
        generate(&MontageConfig {
            grid_w: 3,
            grid_h: 3,
            diagonals: false,
            seed: 2,
        })
    }

    #[test]
    fn trace_has_event_per_task() {
        let dag = dag3x3();
        let n = dag.len();
        let res = driver::run(dag, ExecModel::JobBased, driver::SimConfig::with_nodes(3));
        let j = to_chrome_trace(&res);
        let events = j.get("traceEvents").unwrap().as_arr().unwrap();
        // 1 metadata + n task events
        assert_eq!(events.len(), n + 1);
        let task_ev = &events[1];
        assert_eq!(task_ev.get("ph").unwrap().as_str().unwrap(), "X");
        assert!(task_ev.get("dur").unwrap().as_u64().unwrap() > 0);
        // serializes to parseable JSON
        let text = j.to_string();
        assert!(Json::parse(&text).is_ok());
    }

    #[test]
    fn unfinished_tasks_emit_zero_duration_events() {
        // Hand-build a trace where one task never started and one never
        // finished: both must still appear, flagged as "lost".
        let dag = dag3x3();
        let n = dag.len();
        let mut res = driver::run(dag, ExecModel::JobBased, driver::SimConfig::with_nodes(3));
        {
            let r = &mut res.trace.records[0];
            r.finished_at = None;
        }
        {
            let r = &mut res.trace.records[1];
            r.started_at = None;
            r.finished_at = None;
            r.pod = None;
        }
        let j = to_chrome_trace(&res);
        let events = j.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(events.len(), n + 1, "no task may be silently dropped");
        let lost: Vec<_> = events
            .iter()
            .filter(|e| e.get("cat").ok().and_then(|c| c.as_str().ok()) == Some("lost"))
            .collect();
        assert_eq!(lost.len(), 2);
        for e in &lost {
            assert_eq!(e.get("dur").unwrap().as_u64().unwrap(), 0);
        }
    }

    #[test]
    fn monitor_run_gains_an_alert_track() {
        let dag = dag3x3();
        let mut res = driver::run(dag, ExecModel::JobBased, driver::SimConfig::with_nodes(3));
        res.monitor = Some(crate::obs::monitor::MonitorReport {
            interval_ms: 30_000,
            ticks: 4,
            makespan_ms: res.makespan.as_millis(),
            alerts: vec![crate::obs::monitor::AlertReport {
                name: "BacklogSaturation".into(),
                kind: "threshold",
                severity: "page".into(),
                tenant: None,
                expr: "backlog_total > 16".into(),
                fired: 1,
                firing_ms: 30_000,
                final_state: crate::obs::alerts::AlertState::Firing,
                episodes: vec![crate::obs::alerts::Episode {
                    pending_ms: 30_000,
                    firing_ms: Some(60_000),
                    resolved_ms: None, // open: spans to makespan
                    peak: 21.0,
                }],
            }],
            records: Vec::new(),
        });
        let j = to_chrome_trace(&res);
        let events = j.get("traceEvents").unwrap().as_arr().unwrap();
        let alert: Vec<_> = events
            .iter()
            .filter(|e| e.get("cat").ok().and_then(|c| c.as_str().ok()) == Some("alert"))
            .collect();
        assert_eq!(alert.len(), 1);
        let e = alert[0];
        assert_eq!(e.get("pid").unwrap().as_u64().unwrap(), 4);
        assert_eq!(e.get("ts").unwrap().as_u64().unwrap(), 30_000_000);
        let dur = e.get("dur").unwrap().as_u64().unwrap();
        assert_eq!(dur, (res.makespan.as_millis() - 30_000) * 1000);
        // lane metadata names the rule
        assert!(events.iter().any(|m| {
            m.get("name").ok().and_then(|n| n.as_str().ok()) == Some("thread_name")
                && m.get("pid").ok().and_then(|p| p.as_u64().ok()) == Some(4)
        }));
        assert!(Json::parse(&j.to_string()).is_ok());
    }

    #[test]
    fn obs_run_gains_control_plane_counter_and_node_tracks() {
        let dag = dag3x3();
        let res = driver::run(
            dag,
            ExecModel::JobBased,
            driver::SimConfig::with_nodes(3).obs(true),
        );
        assert!(res.obs.is_some());
        let j = to_chrome_trace(&res);
        let events = j.get("traceEvents").unwrap().as_arr().unwrap();
        let pid_of = |e: &Json| e.get("pid").and_then(|p| p.as_u64()).unwrap_or(0);
        assert!(events.iter().any(|e| pid_of(e) == 2),
            "control-plane track missing");
        assert!(
            events
                .iter()
                .any(|e| pid_of(e) == 3
                    && e.get("ph").ok().and_then(|p| p.as_str().ok()) == Some("C")),
            "counter track missing"
        );
        assert!(events.iter().any(|e| pid_of(e) >= 100),
            "node pod lanes missing");
        // the whole thing round-trips through the JSON parser
        assert!(Json::parse(&j.to_string()).is_ok());
    }
}
