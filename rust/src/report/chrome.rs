//! Chrome trace-event export: open the trace in `chrome://tracing` /
//! Perfetto to see the per-pod Gantt chart of a run. Each pod is a "thread"
//! and each task a complete event (`ph: "X"`).

use super::SimResult;
use crate::util::json::Json;

/// Build the trace-event JSON for a run.
pub fn to_chrome_trace(res: &SimResult) -> Json {
    let mut events = Vec::new();
    // process metadata
    events.push(Json::obj(vec![
        ("name", Json::str("process_name")),
        ("ph", Json::str("M")),
        ("pid", 1u64.into()),
        (
            "args",
            Json::obj(vec![(
                "name",
                Json::str(format!("hyperflow-k8s ({})", res.model_name)),
            )]),
        ),
    ]));
    for r in &res.trace.records {
        let (Some(start), Some(end), Some(pod)) = (r.started_at, r.finished_at, r.pod)
        else {
            continue;
        };
        events.push(Json::obj(vec![
            ("name", Json::str(&r.type_name)),
            ("cat", Json::str("task")),
            ("ph", Json::str("X")),
            ("pid", 1u64.into()),
            ("tid", pod.into()),
            // chrome traces are in microseconds
            ("ts", (start.as_millis() * 1000).into()),
            ("dur", ((end - start).as_millis() * 1000).into()),
            (
                "args",
                Json::obj(vec![
                    ("task", (r.task.0 as u64).into()),
                    ("ready_at_ms", r.ready_at.as_millis().into()),
                ]),
            ),
        ]));
    }
    Json::obj(vec![("traceEvents", Json::Arr(events))])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{driver, ExecModel};
    use crate::workflow::montage::{generate, MontageConfig};

    #[test]
    fn trace_has_event_per_task() {
        let dag = generate(&MontageConfig {
            grid_w: 3,
            grid_h: 3,
            diagonals: false,
            seed: 2,
        });
        let n = dag.len();
        let res = driver::run(dag, ExecModel::JobBased, driver::SimConfig::with_nodes(3));
        let j = to_chrome_trace(&res);
        let events = j.get("traceEvents").unwrap().as_arr().unwrap();
        // 1 metadata + n task events
        assert_eq!(events.len(), n + 1);
        let task_ev = &events[1];
        assert_eq!(task_ev.get("ph").unwrap().as_str().unwrap(), "X");
        assert!(task_ev.get("dur").unwrap().as_u64().unwrap() > 0);
        // serializes to parseable JSON
        let text = j.to_string();
        assert!(Json::parse(&text).is_ok());
    }
}
