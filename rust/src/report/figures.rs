//! Figure/table regeneration: one function per paper artifact (Figs. 3-6
//! and the §4.4 makespan comparison). The benches in `rust/benches/` are
//! thin wrappers that call these and write `bench_out/` files.

use super::SimResult;
use crate::engine::clustering::ClusteringConfig;
use crate::models::{driver, ExecModel};
use crate::util::ascii_plot;
use crate::workflow::montage::{generate, MontageConfig};

/// Default experiment scale: the paper's 16k-task Montage on 17 nodes.
pub fn paper_sim_config() -> driver::SimConfig {
    driver::SimConfig::with_nodes(17)
}

/// Render the utilization chart + per-stage strips for a run.
pub fn render_run(title: &str, res: &SimResult, dag_cfg: &MontageConfig) -> String {
    let dag = generate(dag_cfg);
    let mut out = String::new();
    out.push_str(&format!(
        "{title}\n  makespan {:.0}s | pods {} | api reqs {} | backoffs {} | avg parallel {:.1} | cpu util {:.1}%\n\n",
        res.makespan.as_secs_f64(),
        res.pods_created,
        res.api_requests,
        res.sched_backoffs,
        res.avg_running_tasks,
        res.avg_cpu_utilization * 100.0
    ));
    out.push_str(&ascii_plot::area_chart(
        "tasks running (cluster utilization subplot)",
        &res.running_series(),
        100,
        12,
    ));
    out.push('\n');
    let stages: Vec<(String, Vec<(f64, f64)>)> = res
        .stage_series(&dag)
        .into_iter()
        .filter(|(n, _)| {
            ["mProject", "mDiffFit", "mBackground", "mAdd"].contains(&n.as_str())
        })
        .collect();
    out.push_str(&ascii_plot::stage_strips(
        "stage activity",
        &stages,
        res.makespan.as_secs_f64(),
        100,
    ));
    out
}

/// Fig. 3 — the job model on the "smaller workflow" (the 16k run was
/// infeasible in the paper; §4.2). Shows the collapse: low utilization,
/// huge back-off counts.
pub fn fig3_job_model() -> (SimResult, MontageConfig, String) {
    let wf = MontageConfig::paper_small();
    let res = driver::run(generate(&wf), ExecModel::JobBased, paper_sim_config());
    let text = render_run(
        "Fig. 3 — job-based model, smaller Montage workflow",
        &res,
        &wf,
    );
    (res, wf, text)
}

/// Fig. 4 — the job model with the paper's clustering config on the full
/// 16k workflow. Completes, but with utilization gaps from synchronized
/// back-off wake-ups.
pub fn fig4_clustering() -> (SimResult, MontageConfig, String) {
    let wf = MontageConfig::paper_16k();
    let res = driver::run(
        generate(&wf),
        ExecModel::Clustered(ClusteringConfig::paper_default()),
        paper_sim_config(),
    );
    let text = render_run(
        "Fig. 4 — job model + task clustering (paper config), 16k Montage",
        &res,
        &wf,
    );
    (res, wf, text)
}

/// Fig. 5 — clustering parameter sweep ("multiple combinations ... none
/// entirely satisfactory").
///
/// Points run in parallel via [`crate::util::sweep::run`] — each point is
/// an independent seeded simulation, and results come back in point order,
/// so the output is byte-identical to the serial loop
/// (`HF_BENCH_THREADS=1` forces the serial path).
pub fn fig5_sweep() -> Vec<(String, SimResult)> {
    let wf = MontageConfig::paper_16k();
    let configs: Vec<(String, ClusteringConfig)> = vec![
        ("paper {5,20,20}/3s".into(), ClusteringConfig::paper_default()),
        ("uniform 5/3s".into(), ClusteringConfig::uniform(5, 3000)),
        ("uniform 10/3s".into(), ClusteringConfig::uniform(10, 3000)),
        ("uniform 20/3s".into(), ClusteringConfig::uniform(20, 3000)),
        ("uniform 40/3s".into(), ClusteringConfig::uniform(40, 3000)),
        ("uniform 20/1s".into(), ClusteringConfig::uniform(20, 1000)),
        ("uniform 20/10s".into(), ClusteringConfig::uniform(20, 10_000)),
    ];
    crate::util::sweep::run(configs, |_, (label, c)| {
        let res = driver::run(
            generate(&wf),
            ExecModel::Clustered(c),
            paper_sim_config(),
        );
        (label, res)
    })
}

/// Fig. 6 — the hybrid worker-pools model on the 16k workflow: utilization
/// at cluster capacity during parallel stages.
pub fn fig6_worker_pools() -> (SimResult, MontageConfig, String) {
    let wf = MontageConfig::paper_16k();
    let res = driver::run(
        generate(&wf),
        ExecModel::paper_hybrid_pools(),
        paper_sim_config(),
    );
    let text = render_run(
        "Fig. 6 — worker-pools (hybrid) model, 16k Montage",
        &res,
        &wf,
    );
    (res, wf, text)
}

/// §4.4 headline: makespans of the three models (+ the clustering sweep's
/// best) on the 16k workflow.
pub struct MakespanRow {
    pub label: String,
    pub makespan_s: f64,
    pub pods: u64,
    pub api_requests: u64,
    pub backoffs: u64,
    pub cpu_util: f64,
    pub avg_parallel: f64,
}

pub fn makespan_table() -> Vec<MakespanRow> {
    let wf = MontageConfig::paper_16k();
    let mut rows = Vec::new();
    let runs: Vec<(String, ExecModel)> = vec![
        ("job-based".into(), ExecModel::JobBased),
        (
            "job + clustering (paper cfg)".into(),
            ExecModel::Clustered(ClusteringConfig::paper_default()),
        ),
        (
            "job + clustering (best swept)".into(),
            ExecModel::Clustered(ClusteringConfig::uniform(40, 3000)),
        ),
        ("worker-pools (hybrid)".into(), ExecModel::paper_hybrid_pools()),
    ];
    for (label, model) in runs {
        let res = driver::run(generate(&wf), model, paper_sim_config());
        rows.push(MakespanRow {
            label,
            makespan_s: res.makespan.as_secs_f64(),
            pods: res.pods_created,
            api_requests: res.api_requests,
            backoffs: res.sched_backoffs,
            cpu_util: res.avg_cpu_utilization,
            avg_parallel: res.avg_running_tasks,
        });
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    // The 16k figures are exercised by `cargo bench`; unit tests check the
    // small variant for speed and the qualitative orderings the paper
    // reports.

    #[test]
    fn small_scale_ordering_holds() {
        // needs enough scale that the job model saturates the scheduler
        // (the paper's pathologies are pressure phenomena)
        let wf = MontageConfig {
            grid_w: 20,
            grid_h: 20,
            diagonals: true,
            seed: 42,
        };
        let job = driver::run(generate(&wf), ExecModel::JobBased, paper_sim_config());
        let clu = driver::run(
            generate(&wf),
            ExecModel::Clustered(ClusteringConfig::paper_default()),
            paper_sim_config(),
        );
        let pools = driver::run(
            generate(&wf),
            ExecModel::paper_hybrid_pools(),
            paper_sim_config(),
        );
        assert!(clu.makespan < job.makespan, "clustering must beat plain jobs");
        assert!(pools.makespan < clu.makespan, "pools must beat clustering");
        assert!(pools.avg_cpu_utilization > clu.avg_cpu_utilization);
        assert!(clu.pods_created < job.pods_created);
    }

    #[test]
    fn render_run_contains_sections() {
        let wf = MontageConfig {
            grid_w: 4,
            grid_h: 4,
            diagonals: true,
            seed: 1,
        };
        let res = driver::run(generate(&wf), ExecModel::JobBased, paper_sim_config());
        let txt = render_run("t", &res, &wf);
        assert!(txt.contains("makespan"));
        assert!(txt.contains("mProject"));
        assert!(txt.contains("cluster utilization"));
    }
}
