//! Mini property-testing harness (proptest is not in the offline crate set).
//!
//! `check` runs a property over `n` generated cases from a seeded [`Rng`];
//! on failure it reports the failing case's seed so the case can be replayed
//! deterministically (`replay`). Shrinking is by seed bisection over the
//! generator's "size" parameter: generators receive (rng, size) and should
//! produce smaller cases for smaller sizes.

use super::rng::Rng;

/// Outcome of a property over one case.
pub type PropResult = Result<(), String>;

/// Run `prop` over `n` cases produced by `gen` at sizes ramping from 1 to
/// `max_size`. Panics with the failing seed/size and message on failure,
/// after trying smaller sizes with the same seed to find a smaller
/// counterexample.
pub fn check<T, G, P>(name: &str, seed: u64, n: usize, max_size: usize, gen: G, prop: P)
where
    G: Fn(&mut Rng, usize) -> T,
    P: Fn(&T) -> PropResult,
    T: std::fmt::Debug,
{
    for i in 0..n {
        let case_seed = seed.wrapping_add(i as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let size = 1 + (i * max_size) / n.max(1);
        let mut rng = Rng::new(case_seed);
        let case = gen(&mut rng, size);
        if let Err(msg) = prop(&case) {
            // try to shrink: same seed, smaller sizes
            let mut smallest = (size, msg.clone(), format!("{case:?}"));
            let mut s = size / 2;
            while s >= 1 {
                let mut rng = Rng::new(case_seed);
                let c = gen(&mut rng, s);
                match prop(&c) {
                    Err(m) => {
                        smallest = (s, m, format!("{c:?}"));
                        if s == 1 {
                            break;
                        }
                        s /= 2;
                    }
                    Ok(()) => break,
                }
            }
            panic!(
                "property '{name}' failed (replay: seed={case_seed}, size={}):\n  {}\n  case: {}",
                smallest.0, smallest.1, smallest.2
            );
        }
    }
}

/// Re-run a single failing case.
pub fn replay<T, G, P>(seed: u64, size: usize, gen: G, prop: P) -> PropResult
where
    G: Fn(&mut Rng, usize) -> T,
    P: Fn(&T) -> PropResult,
{
    let mut rng = Rng::new(seed);
    prop(&gen(&mut rng, size))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = std::cell::Cell::new(0usize);
        check(
            "sum-commutes",
            1,
            50,
            100,
            |rng, size| {
                (
                    (0..size).map(|_| rng.below(100) as i64).collect::<Vec<_>>(),
                )
            },
            |case| {
                count.set(count.get() + 1);
                let fwd: i64 = case.0.iter().sum();
                let rev: i64 = case.0.iter().rev().sum();
                if fwd == rev {
                    Ok(())
                } else {
                    Err("sum not commutative".into())
                }
            },
        );
        assert_eq!(count.get_mut(), &50);
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_panics_with_seed() {
        check(
            "always-fails",
            2,
            10,
            10,
            |rng, size| (rng.below(10), size),
            |_| Err("nope".into()),
        );
    }

    #[test]
    fn replay_reproduces() {
        let gen = |rng: &mut Rng, size: usize| (rng.below(1000), size);
        let mut r1 = Rng::new(99);
        let v1 = gen(&mut r1, 5);
        let ok = replay(99, 5, gen, |case| {
            if *case == v1 {
                Ok(())
            } else {
                Err(format!("{case:?} != {v1:?}"))
            }
        });
        assert!(ok.is_ok());
    }
}
