//! Terminal renderings of the paper's figures: utilization time series and
//! per-stage Gantt strips. The benches print these alongside CSV/JSON dumps
//! so "cargo bench" visually regenerates Figs. 3-6.

/// Render a single time series as an ASCII area chart.
///
/// `series` is (seconds, value) samples; the chart resamples onto `width`
/// columns and `height` rows.
pub fn area_chart(title: &str, series: &[(f64, f64)], width: usize, height: usize) -> String {
    if series.is_empty() {
        return format!("{title}\n(no data)\n");
    }
    let t_max = series.last().unwrap().0.max(1e-9);
    let v_max = series
        .iter()
        .map(|&(_, v)| v)
        .fold(f64::NEG_INFINITY, f64::max)
        .max(1e-9);

    // Resample: for each column take the max value in its time bucket
    // (max, not mean, so short spikes stay visible like in the paper plots).
    let mut cols = vec![0.0f64; width];
    let mut idx = 0;
    for c in 0..width {
        let t_lo = t_max * c as f64 / width as f64;
        let t_hi = t_max * (c + 1) as f64 / width as f64;
        let mut v = f64::NEG_INFINITY;
        while idx < series.len() && series[idx].0 < t_lo {
            idx += 1;
        }
        let mut j = idx;
        while j < series.len() && series[j].0 <= t_hi {
            v = v.max(series[j].1);
            j += 1;
        }
        if v == f64::NEG_INFINITY {
            // carry the previous sample forward
            v = if idx > 0 { series[idx - 1].1 } else { series[0].1 };
        }
        cols[c] = v;
    }

    let mut out = String::new();
    out.push_str(&format!("{title}  (max={v_max:.0})\n"));
    for r in (0..height).rev() {
        let thresh = v_max * (r as f64 + 0.5) / height as f64;
        let label = if r == height - 1 {
            format!("{v_max:>6.0} |")
        } else if r == 0 {
            format!("{:>6.0} |", 0.0)
        } else {
            "       |".to_string()
        };
        out.push_str(&label);
        for &v in &cols {
            out.push(if v >= thresh { '█' } else { ' ' });
        }
        out.push('\n');
    }
    out.push_str("       +");
    out.push_str(&"-".repeat(width));
    out.push('\n');
    out.push_str(&format!(
        "        0{:>width$.0}s\n",
        t_max,
        width = width.saturating_sub(1)
    ));
    out
}

/// Render per-stage activity strips (a compact Gantt): one row per stage,
/// darkness ~ number of concurrently running tasks of that stage.
pub fn stage_strips(
    title: &str,
    stages: &[(String, Vec<(f64, f64)>)],
    t_max: f64,
    width: usize,
) -> String {
    let shades = [' ', '░', '▒', '▓', '█'];
    let mut out = String::new();
    out.push_str(&format!("{title}\n"));
    let name_w = stages.iter().map(|(n, _)| n.len()).max().unwrap_or(4).max(4);
    for (name, series) in stages {
        let v_max = series
            .iter()
            .map(|&(_, v)| v)
            .fold(f64::NEG_INFINITY, f64::max)
            .max(1.0);
        let mut row = String::new();
        for c in 0..width {
            let t_lo = t_max * c as f64 / width as f64;
            let t_hi = t_max * (c + 1) as f64 / width as f64;
            let mut v: f64 = 0.0;
            let mut any = false;
            for &(t, val) in series.iter() {
                if t >= t_lo && t <= t_hi {
                    v = v.max(val);
                    any = true;
                }
                if t > t_hi {
                    break;
                }
            }
            if !any {
                // carry-forward
                let mut last = 0.0;
                for &(t, val) in series.iter() {
                    if t <= t_lo {
                        last = val;
                    } else {
                        break;
                    }
                }
                v = last;
            }
            let shade = if v <= 0.0 {
                0
            } else {
                (1 + ((v / v_max) * 3.99) as usize).min(4)
            };
            row.push(shades[shade]);
        }
        out.push_str(&format!("{name:>name_w$} |{row}|\n"));
    }
    out.push_str(&format!(
        "{:>name_w$} +0{:>w$.0}s\n",
        "",
        t_max,
        name_w = name_w,
        w = width - 1
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chart_contains_title_and_axis() {
        let s: Vec<(f64, f64)> = (0..100).map(|i| (i as f64, (i % 10) as f64)).collect();
        let out = area_chart("util", &s, 40, 8);
        assert!(out.contains("util"));
        assert!(out.lines().count() >= 10);
        assert!(out.contains('█'));
    }

    #[test]
    fn empty_series_safe() {
        let out = area_chart("x", &[], 10, 4);
        assert!(out.contains("no data"));
    }

    #[test]
    fn strip_rows_match_stages() {
        let stages = vec![
            ("mProject".to_string(), vec![(0.0, 2.0), (5.0, 0.0)]),
            ("mDiffFit".to_string(), vec![(3.0, 4.0), (8.0, 0.0)]),
        ];
        let out = stage_strips("stages", &stages, 10.0, 30);
        assert!(out.contains("mProject"));
        assert!(out.contains("mDiffFit"));
        assert_eq!(out.lines().count(), 4); // title + 2 rows + axis
    }

    #[test]
    fn chart_peak_column_is_full_height() {
        // constant max value -> top row should contain blocks
        let s: Vec<(f64, f64)> = (0..50).map(|i| (i as f64, 10.0)).collect();
        let out = area_chart("flat", &s, 20, 5);
        let top_row = out.lines().nth(1).unwrap();
        assert!(top_row.contains('█'));
    }
}
