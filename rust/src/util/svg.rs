//! Minimal SVG chart builder (no plotting deps offline). Renders step time
//! series as filled area charts — the visual style of the paper's
//! utilization subplots — for the HTML reports.

/// Build an SVG area chart from a step series [(t, v)].
pub struct AreaChart {
    pub width: u32,
    pub height: u32,
    pub title: String,
    pub color: String,
    pub x_label: String,
}

impl Default for AreaChart {
    fn default() -> Self {
        AreaChart {
            width: 860,
            height: 220,
            title: String::new(),
            color: "#4878a8".to_string(),
            x_label: "time (s)".to_string(),
        }
    }
}

const MARGIN_L: f64 = 52.0;
const MARGIN_R: f64 = 12.0;
const MARGIN_T: f64 = 26.0;
const MARGIN_B: f64 = 30.0;

impl AreaChart {
    /// Render the chart. The series is treated as a step function.
    pub fn render(&self, series: &[(f64, f64)], t_end: f64) -> String {
        let w = self.width as f64;
        let h = self.height as f64;
        let plot_w = w - MARGIN_L - MARGIN_R;
        let plot_h = h - MARGIN_T - MARGIN_B;
        let t_end = t_end.max(1e-9);
        let v_max = series
            .iter()
            .map(|&(_, v)| v)
            .fold(1e-9f64, f64::max);

        let x = |t: f64| MARGIN_L + (t / t_end) * plot_w;
        let y = |v: f64| MARGIN_T + plot_h - (v / v_max) * plot_h;

        // step-function path
        let mut d = format!("M {:.1} {:.1}", x(0.0), y(0.0));
        let mut cur = 0.0f64;
        for &(t, v) in series {
            let t = t.min(t_end);
            d.push_str(&format!(" L {:.1} {:.1}", x(t), y(cur)));
            d.push_str(&format!(" L {:.1} {:.1}", x(t), y(v)));
            cur = v;
        }
        d.push_str(&format!(" L {:.1} {:.1}", x(t_end), y(cur)));
        d.push_str(&format!(
            " L {:.1} {:.1} Z",
            x(t_end),
            y(0.0)
        ));

        let mut s = String::new();
        s.push_str(&format!(
            r#"<svg xmlns="http://www.w3.org/2000/svg" width="{}" height="{}" viewBox="0 0 {} {}" font-family="sans-serif">"#,
            self.width, self.height, self.width, self.height
        ));
        s.push_str(&format!(
            r#"<text x="{}" y="16" font-size="13" font-weight="bold">{}</text>"#,
            MARGIN_L,
            esc(&self.title)
        ));
        // axes
        s.push_str(&format!(
            r##"<line x1="{l}" y1="{t}" x2="{l}" y2="{b}" stroke="#333"/>
               <line x1="{l}" y1="{b}" x2="{r}" y2="{b}" stroke="#333"/>"##,
            l = MARGIN_L,
            t = MARGIN_T,
            b = MARGIN_T + plot_h,
            r = MARGIN_L + plot_w
        ));
        // y ticks: 0, half, max
        for (frac, label) in [(0.0, 0.0), (0.5, v_max / 2.0), (1.0, v_max)] {
            let yy = MARGIN_T + plot_h - frac * plot_h;
            s.push_str(&format!(
                r##"<text x="{:.0}" y="{:.0}" font-size="10" text-anchor="end">{:.0}</text>
                   <line x1="{:.0}" y1="{:.0}" x2="{:.0}" y2="{:.0}" stroke="#ccc" stroke-dasharray="3"/>"##,
                MARGIN_L - 6.0,
                yy + 3.0,
                label,
                MARGIN_L,
                yy,
                MARGIN_L + plot_w,
                yy
            ));
        }
        // x ticks: quarters
        for i in 0..=4 {
            let t = t_end * i as f64 / 4.0;
            s.push_str(&format!(
                r#"<text x="{:.0}" y="{:.0}" font-size="10" text-anchor="middle">{:.0}</text>"#,
                x(t),
                MARGIN_T + plot_h + 14.0,
                t
            ));
        }
        s.push_str(&format!(
            r#"<text x="{:.0}" y="{:.0}" font-size="10" text-anchor="middle">{}</text>"#,
            MARGIN_L + plot_w / 2.0,
            h - 4.0,
            esc(&self.x_label)
        ));
        // the series
        s.push_str(&format!(
            r#"<path d="{d}" fill="{c}" fill-opacity="0.55" stroke="{c}" stroke-width="1"/>"#,
            c = self.color
        ));
        s.push_str("</svg>");
        s
    }
}

fn esc(s: &str) -> String {
    s.replace('&', "&amp;").replace('<', "&lt;").replace('>', "&gt;")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_valid_svg() {
        let series = vec![(0.0, 0.0), (10.0, 5.0), (20.0, 2.0)];
        let svg = AreaChart {
            title: "util".into(),
            ..Default::default()
        }
        .render(&series, 30.0);
        assert!(svg.starts_with("<svg"));
        assert!(svg.ends_with("</svg>"));
        assert!(svg.contains("util"));
        assert!(svg.contains("<path"));
        // balanced tags
        assert_eq!(svg.matches("<svg").count(), svg.matches("</svg>").count());
    }

    #[test]
    fn escapes_title() {
        let svg = AreaChart {
            title: "a<b&c".into(),
            ..Default::default()
        }
        .render(&[(0.0, 1.0)], 1.0);
        assert!(svg.contains("a&lt;b&amp;c"));
    }

    #[test]
    fn empty_series_is_safe() {
        let svg = AreaChart::default().render(&[], 10.0);
        assert!(svg.contains("<path"));
    }
}
