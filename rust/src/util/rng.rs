//! Deterministic xoshiro256** PRNG with the distribution helpers the
//! simulator needs (uniform, normal, lognormal, exponential). No external
//! deps; every experiment is reproducible from its seed.

/// Deterministic xoshiro256** PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal from the Box-Muller pair.
    spare_normal: Option<f64>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // splitmix64 expansion of the seed into the full state
        let mut z = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut s = [0u64; 4];
        for v in s.iter_mut() {
            z = z.wrapping_add(0x9E3779B97F4A7C15);
            let mut x = z;
            x = (x ^ (x >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            x = (x ^ (x >> 27)).wrapping_mul(0x94D049BB133111EB);
            *v = x ^ (x >> 31);
        }
        Rng { s, spare_normal: None }
    }

    /// Derive an independent stream (for per-component RNGs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform integer in [0, n). Unbiased via rejection.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Standard normal (Box-Muller, cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.spare_normal.take() {
            return v;
        }
        loop {
            let u1 = self.f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let th = 2.0 * std::f64::consts::PI * u2;
            self.spare_normal = Some(r * th.sin());
            return r * th.cos();
        }
    }

    /// Lognormal with given *median* and sigma of the underlying normal.
    /// Task durations in the simulator use this (long right tail, like the
    /// real Montage task runtimes).
    pub fn lognormal(&mut self, median: f64, sigma: f64) -> f64 {
        (median.ln() + sigma * self.normal()).exp()
    }

    /// Exponential with the given mean.
    pub fn exponential(&mut self, mean: f64) -> f64 {
        let u = 1.0 - self.f64();
        -mean * u.ln()
    }

    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            v.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn below_unbiased_range() {
        let mut r = Rng::new(4);
        let mut seen = [0u32; 7];
        for _ in 0..70_000 {
            seen[r.below(7) as usize] += 1;
        }
        for &c in &seen {
            // expected 10_000 per bucket; 5% tolerance
            assert!((9_500..10_500).contains(&c), "bucket count {c}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 100_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let v = r.normal();
            sum += v;
            sq += v * v;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn lognormal_median() {
        let mut r = Rng::new(6);
        let mut v: Vec<f64> = (0..50_001).map(|_| r.lognormal(2.0, 0.5)).collect();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let med = v[25_000];
        assert!((med - 2.0).abs() < 0.1, "median {med}");
        assert!(v.iter().all(|&x| x > 0.0));
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(8);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.exponential(3.0)).sum::<f64>() / n as f64;
        assert!((mean - 3.0).abs() < 0.1, "mean {mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort();
        assert_eq!(s, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn fork_streams_independent() {
        let mut base = Rng::new(10);
        let mut a = base.fork(1);
        let mut b = base.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
