//! Small statistics helpers used by reports and benches.

/// Running summary of a set of samples.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    samples: Vec<f64>,
}

impl Summary {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, v: f64) {
        self.samples.push(v);
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.samples.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }

    pub fn stddev(&self) -> f64 {
        if self.samples.len() < 2 {
            return 0.0;
        }
        let m = self.mean();
        let var = self
            .samples
            .iter()
            .map(|v| (v - m) * (v - m))
            .sum::<f64>()
            / (self.samples.len() - 1) as f64;
        var.sqrt()
    }

    /// Percentile in [0, 100] by linear interpolation on the sorted samples.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        percentile_of_sorted(&s, p)
    }

    pub fn median(&self) -> f64 {
        self.percentile(50.0)
    }

    pub fn sum(&self) -> f64 {
        self.samples.iter().sum()
    }

    /// The standard p50/p95/p99 report row, computed with a single sort
    /// (the per-call sort in [`Summary::percentile`] sorted thrice).
    /// Every latency table in the crate — HTML wait times, chaos recovery
    /// latency, data-plane stage-ins, fleet slowdowns — assembles its row
    /// through this one helper.
    pub fn percentile_row(&self) -> PercentileRow {
        if self.samples.is_empty() {
            return PercentileRow::default();
        }
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        PercentileRow {
            p50: percentile_of_sorted(&s, 50.0),
            p95: percentile_of_sorted(&s, 95.0),
            p99: percentile_of_sorted(&s, 99.0),
        }
    }
}

/// Linear-interpolation percentile over an already-sorted slice — the one
/// definition shared by [`Summary::percentile`] and
/// [`Summary::percentile_row`], so the two can never drift apart.
fn percentile_of_sorted(s: &[f64], p: f64) -> f64 {
    let rank = (p / 100.0) * (s.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        s[lo]
    } else {
        let frac = rank - lo as f64;
        s[lo] * (1.0 - frac) + s[hi] * frac
    }
}

/// A p50/p95/p99 triple — the row shape shared by every latency/SLO table
/// in the reports.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct PercentileRow {
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

/// Integrate a step function given as (time, value) change points over
/// [t0, t1], returning the time average. Used for average cluster
/// utilization (the paper's headline metric for Figs. 3-6).
///
/// Degenerate windows return 0.0: an empty series, `t1 <= t0` (zero or
/// negative span — e.g. a zero-makespan run), and non-finite bounds
/// (`!(t1 > t0)` also catches NaN, which would otherwise slip past a
/// `t1 <= t0` check and divide by NaN below).
pub fn time_average(points: &[(f64, f64)], t0: f64, t1: f64) -> f64 {
    if !(t1 > t0) || !t0.is_finite() || !t1.is_finite() || points.is_empty() {
        return 0.0;
    }
    let mut acc = 0.0;
    let mut cur_v = 0.0;
    let mut cur_t = t0;
    for &(t, v) in points {
        if t <= t0 {
            cur_v = v;
            continue;
        }
        if t >= t1 {
            break;
        }
        acc += cur_v * (t - cur_t);
        cur_t = t;
        cur_v = v;
    }
    acc += cur_v * (t1 - cur_t);
    acc / (t1 - t0)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn filled() -> Summary {
        let mut s = Summary::new();
        for v in [1.0, 2.0, 3.0, 4.0, 5.0] {
            s.add(v);
        }
        s
    }

    #[test]
    fn mean_min_max() {
        let s = filled();
        assert_eq!(s.mean(), 3.0);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 5.0);
        assert_eq!(s.sum(), 15.0);
    }

    #[test]
    fn percentiles() {
        let s = filled();
        assert_eq!(s.median(), 3.0);
        assert_eq!(s.percentile(0.0), 1.0);
        assert_eq!(s.percentile(100.0), 5.0);
        assert!((s.percentile(25.0) - 2.0).abs() < 1e-9);
    }

    #[test]
    fn percentile_99_on_known_distribution() {
        // 101 samples 0..=100: pN lands exactly on sample index N (rank =
        // N/100 * 100), so every percentile equals its own value — the
        // reference case for the p99 column in the HTML report and the
        // fleet SLO tables.
        let mut s = Summary::new();
        for v in 0..=100 {
            s.add(v as f64);
        }
        assert!((s.percentile(99.0) - 99.0).abs() < 1e-9);
        assert!((s.percentile(95.0) - 95.0).abs() < 1e-9);
        assert!((s.percentile(50.0) - 50.0).abs() < 1e-9);
        // between-sample interpolation: p99 of 0..=9 sits between 8 and 9
        let mut t = Summary::new();
        for v in 0..=9 {
            t.add(v as f64);
        }
        assert!((t.percentile(99.0) - 8.91).abs() < 1e-9);
    }

    #[test]
    fn percentile_row_matches_individual_percentiles() {
        let mut s = Summary::new();
        for v in 0..=100 {
            s.add(v as f64);
        }
        let row = s.percentile_row();
        assert_eq!(row.p50, s.percentile(50.0));
        assert_eq!(row.p95, s.percentile(95.0));
        assert_eq!(row.p99, s.percentile(99.0));
        // interpolated case must agree bit-for-bit too
        let mut t = Summary::new();
        for v in 0..=9 {
            t.add(v as f64);
        }
        let row = t.percentile_row();
        assert_eq!(row.p99, t.percentile(99.0));
        assert_eq!(row.p50, t.median());
        // empty summaries yield the all-zero row
        assert_eq!(Summary::new().percentile_row(), PercentileRow::default());
    }

    #[test]
    fn stddev_known() {
        let s = filled();
        assert!((s.stddev() - (2.5f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn empty_summary_is_safe() {
        let s = Summary::new();
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.percentile(50.0), 0.0);
        assert!(s.is_empty());
    }

    #[test]
    fn time_average_step_function() {
        // value 0 until t=10, then 4 until t=20, then 2
        let pts = vec![(0.0, 0.0), (10.0, 4.0), (20.0, 2.0)];
        let avg = time_average(&pts, 0.0, 30.0);
        // (0*10 + 4*10 + 2*10)/30 = 2
        assert!((avg - 2.0).abs() < 1e-12);
    }

    #[test]
    fn time_average_window() {
        let pts = vec![(0.0, 1.0), (10.0, 3.0)];
        assert!((time_average(&pts, 5.0, 15.0) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn time_average_degenerate() {
        assert_eq!(time_average(&[], 0.0, 1.0), 0.0);
        assert_eq!(time_average(&[(0.0, 5.0)], 1.0, 1.0), 0.0);
        // inverted and non-finite windows must return 0.0, never NaN or
        // a garbage negative average
        assert_eq!(time_average(&[(0.0, 5.0)], 2.0, 1.0), 0.0);
        assert_eq!(time_average(&[(0.0, 5.0)], f64::NAN, 1.0), 0.0);
        assert_eq!(time_average(&[(0.0, 5.0)], 0.0, f64::NAN), 0.0);
        assert_eq!(time_average(&[(0.0, 5.0)], 0.0, f64::INFINITY), 0.0);
        assert_eq!(time_average(&[(0.0, 5.0)], f64::NEG_INFINITY, 1.0), 0.0);
    }
}
