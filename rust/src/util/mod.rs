//! Zero-dependency substrates: JSON, RNG, stats, plots, CLI, logging,
//! property testing. The offline crate set has no serde/clap/rand/proptest,
//! so these are built from scratch (see DESIGN.md §1).

pub mod ascii_plot;
pub mod cli;
pub mod env;
pub mod svg;
pub mod json;
pub mod logger;
pub mod meta;
pub mod ptest;
pub mod rng;
pub mod stats;
pub mod sweep;
