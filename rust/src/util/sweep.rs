//! Parallel sweep runner for the bench harnesses.
//!
//! Every sweep bench runs a list of *embarrassingly parallel* seeded
//! points: each point is a full, self-contained simulation whose result
//! depends only on its own config and seed, never on which worker ran it
//! or in what order. [`run`] fans those points across OS threads
//! (`std::thread::scope`, no work queue beyond an atomic cursor) and
//! returns the results **in point order**, so a bench that formats its
//! output after collection emits bytes identical to the serial run —
//! `tests/sweep.rs` and the CI `cmp` step pin exactly that.
//!
//! The contract the closure must honor: no printing, no shared mutable
//! state, no wall-clock-dependent output. Print from the collected
//! results afterwards instead. Thread count comes from
//! [`crate::util::env::bench_threads`] (`HF_BENCH_THREADS`; `1` = legacy
//! serial path, which runs the points in place without spawning).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Run `f` over every point with `HF_BENCH_THREADS` workers, returning
/// results in point order. See [`run_on`].
pub fn run<I, O, F>(points: Vec<I>, f: F) -> Vec<O>
where
    I: Send,
    O: Send,
    F: Fn(usize, I) -> O + Sync,
{
    run_on(crate::util::env::bench_threads(), points, f)
}

/// Run `f(index, point)` over every point on `threads` workers.
///
/// Results come back ordered by point index regardless of completion
/// order. `threads <= 1` (or a single point) short-circuits to a plain
/// serial loop on the calling thread — no spawn, no locks — which is the
/// reference behavior the parallel path must reproduce byte-for-byte.
///
/// Work is claimed by an atomic cursor (striding would pin the slowest
/// points to one worker; stealing by cursor keeps the load even). Each
/// point is moved out of its slot exactly once; a worker panic
/// propagates to the caller after the remaining workers finish their
/// current points.
pub fn run_on<I, O, F>(threads: usize, points: Vec<I>, f: F) -> Vec<O>
where
    I: Send,
    O: Send,
    F: Fn(usize, I) -> O + Sync,
{
    let n = points.len();
    if threads <= 1 || n <= 1 {
        return points
            .into_iter()
            .enumerate()
            .map(|(i, p)| f(i, p))
            .collect();
    }
    // One slot per point; each mutex is locked exactly once, by the
    // worker that claimed the index (the lock is how an owned `I` moves
    // across the thread boundary without `unsafe`).
    let slots: Vec<Mutex<Option<I>>> =
        points.into_iter().map(|p| Mutex::new(Some(p))).collect();
    let next = AtomicUsize::new(0);
    let workers = threads.min(n);
    let mut collected: Vec<(usize, O)> = Vec::with_capacity(n);
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..workers)
            .map(|_| {
                let (slots, next, f) = (&slots, &next, &f);
                s.spawn(move || {
                    let mut local: Vec<(usize, O)> = Vec::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        let item = slots[i]
                            .lock()
                            .unwrap()
                            .take()
                            .expect("sweep point claimed twice");
                        local.push((i, f(i, item)));
                    }
                    local
                })
            })
            .collect();
        for h in handles {
            collected.extend(h.join().expect("sweep worker panicked"));
        }
    });
    collected.sort_by_key(|&(i, _)| i);
    debug_assert_eq!(collected.len(), n, "sweep lost or duplicated points");
    collected.into_iter().map(|(_, o)| o).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_come_back_in_point_order() {
        // later points finish first: ordering must still hold
        let points: Vec<u64> = (0..16).collect();
        let out = run_on(4, points, |i, p| {
            std::thread::sleep(std::time::Duration::from_millis(16 - p));
            i as u64 * 100 + p
        });
        assert_eq!(out, (0..16).map(|p| p * 100 + p).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_matches_serial() {
        let f = |i: usize, p: u64| -> u64 { (i as u64) ^ p.wrapping_mul(0x9E37) };
        let serial = run_on(1, (0..33).collect(), f);
        for threads in [2, 3, 8, 64] {
            assert_eq!(run_on(threads, (0..33).collect(), f), serial);
        }
    }

    #[test]
    fn edge_cases_empty_and_single() {
        let out: Vec<u32> = run_on(8, Vec::<u32>::new(), |_, p| p);
        assert!(out.is_empty());
        assert_eq!(run_on(8, vec![41u32], |_, p| p + 1), vec![42]);
    }

    #[test]
    fn more_threads_than_points() {
        assert_eq!(run_on(32, vec![1u32, 2, 3], |_, p| p * 2), vec![2, 4, 6]);
    }

    #[test]
    fn owned_non_clone_points_move_into_workers() {
        // the runner must hand each owned point to exactly one worker
        struct NoClone(String);
        let points = vec![NoClone("a".into()), NoClone("b".into())];
        let out = run_on(2, points, |_, p| p.0);
        assert_eq!(out, vec!["a", "b"]);
    }
}
