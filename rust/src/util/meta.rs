//! Run/bench metadata: schema versioning, the git revision, and the
//! FNV-1a hash behind config fingerprints.
//!
//! Every `BENCH_*.json` emitter embeds a [`bench_meta`] block so the
//! regression gate (`hyperflow diff --bench`) can refuse to compare
//! apples to oranges: a baseline measured under a different config
//! fingerprint, seed, or schema version is a provenance mismatch, not a
//! performance regression. Run *snapshots* deliberately do **not**
//! include the git revision or any wall-clock stamp — they must be
//! byte-identical across same-seed reruns (`tests/diff.rs` pins this) —
//! so volatile provenance lives only in the bench artifacts.

use crate::util::json::Json;

/// Version of the `BENCH_*.json` schema. Bump on any breaking change to
/// a bench emitter's output shape; `baselines/refresh.sh` refuses to
/// install a baseline whose version does not match.
pub const BENCH_SCHEMA_VERSION: u64 = 1;

/// 64-bit FNV-1a over raw bytes. Tiny, dependency-free, and stable
/// across platforms — exactly enough for config fingerprints (this is a
/// provenance check, not a cryptographic one).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// `git describe --tags --always --dirty` of the working tree, or
/// `"unknown"` when git (or the repository) is unavailable — bench
/// artifacts must still be emitted from a tarball checkout.
pub fn git_describe() -> String {
    std::process::Command::new("git")
        .args(["describe", "--tags", "--always", "--dirty"])
        .output()
        .ok()
        .filter(|o| o.status.success())
        .and_then(|o| String::from_utf8(o.stdout).ok())
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .unwrap_or_else(|| "unknown".to_string())
}

/// The shared provenance block every bench emitter writes under the
/// `"meta"` key: model (or sweep-family label), RNG seed, git revision,
/// and the [`crate::exec::SimConfig::fingerprint`] of the swept config.
pub fn bench_meta(model: &str, seed: u64, config_fingerprint: &str) -> Json {
    Json::obj(vec![
        ("model", model.into()),
        ("seed", seed.into()),
        ("git", Json::str(git_describe())),
        ("config_fingerprint", config_fingerprint.into()),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv1a64_matches_reference_vectors() {
        // published FNV-1a test vectors
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn bench_meta_carries_all_provenance_fields() {
        let m = bench_meta("worker-pools", 42, "deadbeef00000000");
        assert_eq!(m.get("model").unwrap().as_str().unwrap(), "worker-pools");
        assert_eq!(m.get("seed").unwrap().as_u64().unwrap(), 42);
        assert!(!m.get("git").unwrap().as_str().unwrap().is_empty());
        assert_eq!(
            m.get("config_fingerprint").unwrap().as_str().unwrap(),
            "deadbeef00000000"
        );
    }

    #[test]
    fn git_describe_never_panics() {
        // value depends on the environment; the contract is non-empty
        assert!(!git_describe().is_empty());
    }
}
