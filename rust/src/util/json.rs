//! Minimal JSON parser/writer (no serde in the offline crate set).
//!
//! Used for: experiment configs, the AOT `artifacts/manifest.json`, workflow
//! (de)serialization, and trace/report output. Supports the full JSON value
//! model; numbers are kept as f64 (sufficient for every schema we read).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use `BTreeMap` for deterministic serialization.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, thiserror::Error)]
pub enum JsonError {
    #[error("unexpected end of input at byte {0}")]
    Eof(usize),
    #[error("unexpected character '{0}' at byte {1}")]
    Unexpected(char, usize),
    #[error("invalid number at byte {0}")]
    BadNumber(usize),
    #[error("invalid \\u escape at byte {0}")]
    BadEscape(usize),
    #[error("trailing garbage at byte {0}")]
    Trailing(usize),
    #[error("json type error: expected {0}")]
    Type(&'static str),
    #[error("missing key '{0}'")]
    MissingKey(String),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let b = s.as_bytes();
        let mut p = Parser { b, i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != b.len() {
            return Err(JsonError::Trailing(p.i));
        }
        Ok(v)
    }

    // -- typed accessors -------------------------------------------------
    pub fn as_f64(&self) -> Result<f64, JsonError> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => Err(JsonError::Type("number")),
        }
    }
    pub fn as_u64(&self) -> Result<u64, JsonError> {
        Ok(self.as_f64()? as u64)
    }
    pub fn as_usize(&self) -> Result<usize, JsonError> {
        Ok(self.as_f64()? as usize)
    }
    pub fn as_str(&self) -> Result<&str, JsonError> {
        match self {
            Json::Str(s) => Ok(s),
            _ => Err(JsonError::Type("string")),
        }
    }
    pub fn as_bool(&self) -> Result<bool, JsonError> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => Err(JsonError::Type("bool")),
        }
    }
    pub fn as_arr(&self) -> Result<&[Json], JsonError> {
        match self {
            Json::Arr(a) => Ok(a),
            _ => Err(JsonError::Type("array")),
        }
    }
    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>, JsonError> {
        match self {
            Json::Obj(o) => Ok(o),
            _ => Err(JsonError::Type("object")),
        }
    }
    /// Object field access: `j.get("key")?`.
    pub fn get(&self, key: &str) -> Result<&Json, JsonError> {
        self.as_obj()?
            .get(key)
            .ok_or_else(|| JsonError::MissingKey(key.to_string()))
    }
    /// Optional field access: `None` if the key is absent or null.
    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self.as_obj().ok()?.get(key) {
            None | Some(Json::Null) => None,
            Some(v) => Some(v),
        }
    }

    // -- builders ---------------------------------------------------------
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }
}

impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Self {
        Json::Num(n)
    }
}
impl From<u64> for Json {
    fn from(n: u64) -> Self {
        Json::Num(n as f64)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Self {
        Json::Num(n as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }
    fn peek(&self) -> Result<u8, JsonError> {
        self.b.get(self.i).copied().ok_or(JsonError::Eof(self.i))
    }
    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(JsonError::Unexpected(c as char, self.i)),
        }
    }
    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(JsonError::Unexpected(self.b[self.i] as char, self.i))
        }
    }
    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek()? == b'-' {
            self.i += 1;
        }
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or(JsonError::BadNumber(start))
    }
    fn string(&mut self) -> Result<String, JsonError> {
        debug_assert_eq!(self.b[self.i], b'"');
        self.i += 1;
        let mut out = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(JsonError::BadEscape(self.i));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| JsonError::BadEscape(self.i))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| JsonError::BadEscape(self.i))?;
                            self.i += 4;
                            // Surrogate pairs: only BMP needed for our schemas,
                            // but handle pairs for completeness.
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                if self.b.get(self.i) == Some(&b'\\')
                                    && self.b.get(self.i + 1) == Some(&b'u')
                                {
                                    let hex2 =
                                        std::str::from_utf8(&self.b[self.i + 2..self.i + 6])
                                            .map_err(|_| JsonError::BadEscape(self.i))?;
                                    let lo = u32::from_str_radix(hex2, 16)
                                        .map_err(|_| JsonError::BadEscape(self.i))?;
                                    self.i += 6;
                                    0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00)
                                } else {
                                    return Err(JsonError::BadEscape(self.i));
                                }
                            } else {
                                cp
                            };
                            out.push(char::from_u32(ch).ok_or(JsonError::BadEscape(self.i))?);
                        }
                        _ => return Err(JsonError::BadEscape(self.i)),
                    }
                }
                _ => {
                    // Collect the full UTF-8 sequence starting at c.
                    let len = utf8_len(c);
                    let start = self.i - 1;
                    self.i = start + len;
                    if self.i > self.b.len() {
                        return Err(JsonError::Eof(start));
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| JsonError::BadEscape(start))?,
                    );
                }
            }
        }
    }
    fn array(&mut self) -> Result<Json, JsonError> {
        self.i += 1; // '['
        let mut items = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                c => return Err(JsonError::Unexpected(c as char, self.i)),
            }
        }
    }
    fn object(&mut self) -> Result<Json, JsonError> {
        self.i += 1; // '{'
        let mut map = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.ws();
            if self.peek()? != b'"' {
                return Err(JsonError::Unexpected(self.peek()? as char, self.i));
            }
            let key = self.string()?;
            self.ws();
            if self.peek()? != b':' {
                return Err(JsonError::Unexpected(self.peek()? as char, self.i));
            }
            self.i += 1;
            self.ws();
            let v = self.value()?;
            map.insert(key, v);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                c => return Err(JsonError::Unexpected(c as char, self.i)),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(o) => {
                write!(f, "{{")?;
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert!(j.opt("d").is_none());
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str()
                .unwrap(),
            "c"
        );
    }

    #[test]
    fn parses_escapes() {
        let j = Json::parse(r#""a\n\t\"\\Aé""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "a\n\t\"\\Aé");
    }

    #[test]
    fn parses_surrogate_pair() {
        let j = Json::parse(r#""😀""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "😀");
    }

    #[test]
    fn parses_unicode_passthrough() {
        let j = Json::parse("\"żółć\"").unwrap();
        assert_eq!(j.as_str().unwrap(), "żółć");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn round_trips() {
        let src = r#"{"arr":[1,2.5,true,null,"x\"y"],"num":-7,"obj":{"k":"v"}}"#;
        let j = Json::parse(src).unwrap();
        let out = j.to_string();
        assert_eq!(Json::parse(&out).unwrap(), j);
    }

    #[test]
    fn display_is_deterministic() {
        let j = Json::obj(vec![("b", 1u64.into()), ("a", 2u64.into())]);
        assert_eq!(j.to_string(), r#"{"a":2,"b":1}"#);
    }

    #[test]
    fn real_manifest_parses() {
        // shape mirrors python/compile/aot.py manifest
        let src = r#"{"tile":128,"overlap":32,"grids":[4],
            "artifacts":{"mproject":{"file":"mproject.hlo.txt",
            "inputs":[{"shape":[128,128],"dtype":"float32"}],
            "outputs":[{"shape":[128,128],"dtype":"float32"}]}}}"#;
        let j = Json::parse(src).unwrap();
        assert_eq!(j.get("tile").unwrap().as_u64().unwrap(), 128);
        let a = j.get("artifacts").unwrap().get("mproject").unwrap();
        assert_eq!(
            a.get("inputs").unwrap().as_arr().unwrap()[0]
                .get("shape")
                .unwrap()
                .as_arr()
                .unwrap()
                .len(),
            2
        );
    }
}
