//! Minimal `log` backend: level from `HF_LOG` (error|warn|info|debug|trace),
//! writes to stderr. The simulator logs through `log::...!` macros so tests
//! stay quiet by default.

use log::{Level, LevelFilter, Metadata, Record};

struct StderrLogger;

static LOGGER: StderrLogger = StderrLogger;

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= log::max_level()
    }

    fn log(&self, record: &Record) {
        if self.enabled(record.metadata()) {
            let lvl = match record.level() {
                Level::Error => "ERROR",
                Level::Warn => "WARN ",
                Level::Info => "INFO ",
                Level::Debug => "DEBUG",
                Level::Trace => "TRACE",
            };
            eprintln!("[{lvl}] {}: {}", record.target(), record.args());
        }
    }

    fn flush(&self) {}
}

/// Install the logger once; later calls are no-ops. Level from `HF_LOG`
/// (default: warn).
pub fn init() {
    let level = match std::env::var("HF_LOG").as_deref() {
        Ok("error") => LevelFilter::Error,
        Ok("warn") => LevelFilter::Warn,
        Ok("info") => LevelFilter::Info,
        Ok("debug") => LevelFilter::Debug,
        Ok("trace") => LevelFilter::Trace,
        Ok(other) => {
            eprintln!(
                "HF_LOG: unrecognized level '{other}' — using warn \
                 (accepted: error, warn, info, debug, trace)"
            );
            LevelFilter::Warn
        }
        Err(_) => LevelFilter::Warn,
    };
    if log::set_logger(&LOGGER).is_ok() {
        log::set_max_level(level);
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::info!("logger smoke test");
    }
}
