//! Tiny CLI argument parser (clap is not in the offline crate set).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an iterator of argument strings (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Args {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.flags.insert(rest.to_string(), v);
                } else {
                    out.flags.insert(rest.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn get_bool(&self, key: &str, default: bool) -> bool {
        match self.get(key) {
            Some("true") | Some("1") | Some("yes") => true,
            Some("false") | Some("0") | Some("no") => false,
            Some(_) | None => default,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|s| s.to_string()))
    }

    #[test]
    fn positional_and_flags() {
        let a = parse(&["run", "--model", "worker-pools", "--seed=7", "--verbose"]);
        assert_eq!(a.positional, vec!["run"]);
        assert_eq!(a.get("model"), Some("worker-pools"));
        assert_eq!(a.get_u64("seed", 0), 7);
        assert!(a.has("verbose"));
        assert!(a.get_bool("verbose", false));
    }

    #[test]
    fn defaults() {
        let a = parse(&[]);
        assert_eq!(a.get_or("model", "job"), "job");
        assert_eq!(a.get_u64("seed", 42), 42);
        assert_eq!(a.get_f64("x", 1.5), 1.5);
        assert!(!a.get_bool("flag", false));
    }

    #[test]
    fn flag_before_positional() {
        let a = parse(&["--n", "3", "cmd"]);
        assert_eq!(a.get_usize("n", 0), 3);
        assert_eq!(a.positional, vec!["cmd"]);
    }

    #[test]
    fn bare_flag_at_end() {
        let a = parse(&["--dry-run"]);
        assert!(a.has("dry-run"));
    }

    #[test]
    fn negative_number_value() {
        // numbers starting with '-' (not '--') are consumed as values
        let a = parse(&["--dx", "-3.5"]);
        assert_eq!(a.get_f64("dx", 0.0), -3.5);
    }
}
