//! Environment-variable knobs for the bench harnesses (criterion is not
//! in the offline crate set, so benches are plain mains configured via
//! `HF_*` variables — see `.github/workflows/ci.yml` for the reduced CI
//! configurations). Malformed values fall back to the default, matching
//! `util::cli::Args` semantics.

/// Read `key` as a usize, falling back to `default` when unset/malformed.
pub fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

/// Read `key` as an f64, falling back to `default` when unset/malformed.
pub fn env_f64(key: &str, default: f64) -> f64 {
    std::env::var(key)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

/// Read `key` as a comma-separated f64 list (the sweep benches' rate
/// knobs), falling back to `default` when unset. Unlike the scalar
/// helpers a *malformed* entry panics with the key name — a sweep
/// silently running default rates would mislabel its output.
pub fn env_f64_list(key: &str, default: &[f64]) -> Vec<f64> {
    match std::env::var(key) {
        Err(_) => default.to_vec(),
        Ok(s) => s
            .split(',')
            .filter(|v| !v.trim().is_empty())
            .map(|v| {
                v.trim()
                    .parse()
                    .unwrap_or_else(|_| panic!("{key}: '{v}' is not a number"))
            })
            .collect(),
    }
}

/// Worker-thread count for the parallel sweep runner
/// ([`crate::util::sweep`]): `HF_BENCH_THREADS`, defaulting to the
/// machine's available parallelism. `1` selects the legacy serial path
/// (the sweep runner then executes points in place, spawning nothing).
/// `0` or a malformed value falls back to the default.
pub fn bench_threads() -> usize {
    let default = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    match env_usize("HF_BENCH_THREADS", default) {
        0 => default,
        n => n,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_when_unset_or_malformed() {
        assert_eq!(env_usize("HF_TEST_SURELY_UNSET_USIZE", 7), 7);
        assert_eq!(env_f64("HF_TEST_SURELY_UNSET_F64", 1.5), 1.5);
        std::env::set_var("HF_TEST_MALFORMED", "not-a-number");
        assert_eq!(env_usize("HF_TEST_MALFORMED", 3), 3);
        assert_eq!(env_f64("HF_TEST_MALFORMED", 2.5), 2.5);
        std::env::set_var("HF_TEST_SET", "12");
        assert_eq!(env_usize("HF_TEST_SET", 0), 12);
        assert_eq!(env_f64("HF_TEST_SET", 0.0), 12.0);
        std::env::remove_var("HF_TEST_MALFORMED");
        std::env::remove_var("HF_TEST_SET");
    }

    #[test]
    fn f64_list_parses_and_defaults() {
        assert_eq!(env_f64_list("HF_TEST_SURELY_UNSET_LIST", &[1.0, 2.0]), vec![1.0, 2.0]);
        std::env::set_var("HF_TEST_LIST", "0.5, 2,4.25,");
        assert_eq!(env_f64_list("HF_TEST_LIST", &[]), vec![0.5, 2.0, 4.25]);
        std::env::remove_var("HF_TEST_LIST");
    }

    #[test]
    fn bench_threads_is_positive() {
        // whatever the environment, the sweep runner must get >= 1 worker
        assert!(bench_threads() >= 1);
    }
}
