//! Alert lifecycle: the Prometheus-style inactive → pending → firing →
//! resolved state machine, advanced once per scrape tick.
//!
//! Semantics pinned by the fixture tests below:
//!
//! - an alert whose condition holds enters *pending* and starts its
//!   `for:` clock at that tick's timestamp;
//! - it promotes to *firing* at the first tick where the condition has
//!   held for at least the `for:` duration (a `for: 0s` alert fires the
//!   same tick it activates);
//! - a pending alert whose condition clears never fired — the episode
//!   is discarded, exactly like Prometheus;
//! - a firing alert whose condition clears resolves at that tick, and
//!   the completed episode (pending/firing/resolved timestamps plus the
//!   peak observed value) is kept for the report;
//! - an episode still firing when the run ends is kept open
//!   (`resolved_ms: None`) and its firing time is charged up to the
//!   makespan.

/// Lifecycle state of one alert rule.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AlertState {
    Inactive,
    Pending,
    Firing,
}

impl AlertState {
    pub fn name(self) -> &'static str {
        match self {
            AlertState::Inactive => "inactive",
            AlertState::Pending => "pending",
            AlertState::Firing => "firing",
        }
    }
}

/// One pending→firing(→resolved) arc of an alert. Timestamps are sim
/// milliseconds; `firing_ms` is `None` only transiently (while the
/// episode is still pending) — every episode in
/// [`AlertRuntime::episodes`] has fired.
#[derive(Debug, Clone, PartialEq)]
pub struct Episode {
    pub pending_ms: u64,
    pub firing_ms: Option<u64>,
    pub resolved_ms: Option<u64>,
    /// Largest rule value observed while the episode was active.
    pub peak: f64,
}

impl Episode {
    /// Milliseconds spent firing, charging open episodes to `end_ms`.
    pub fn firing_span_ms(&self, end_ms: u64) -> u64 {
        match self.firing_ms {
            None => 0,
            Some(f) => self.resolved_ms.unwrap_or(end_ms).saturating_sub(f),
        }
    }
}

/// Per-alert lifecycle state, fed one `(timestamp, condition, value)`
/// observation per scrape tick.
#[derive(Debug, Default)]
pub struct AlertRuntime {
    state_: Option<AlertState>,
    pending_since: u64,
    open: Option<Episode>,
    /// Completed (fired) episodes, oldest first.
    pub episodes: Vec<Episode>,
}

impl AlertRuntime {
    pub fn new() -> Self {
        AlertRuntime::default()
    }

    pub fn state(&self) -> AlertState {
        self.state_.unwrap_or(AlertState::Inactive)
    }

    /// Advance one tick. Returns `Some((from, to))` on a state
    /// transition.
    pub fn step(
        &mut self,
        now_ms: u64,
        active: bool,
        value: f64,
        for_ms: u64,
    ) -> Option<(AlertState, AlertState)> {
        let from = self.state();
        let value = if value.is_finite() { value } else { 0.0 };
        match (from, active) {
            (AlertState::Inactive, true) => {
                self.pending_since = now_ms;
                let mut ep = Episode {
                    pending_ms: now_ms,
                    firing_ms: None,
                    resolved_ms: None,
                    peak: value,
                };
                if for_ms == 0 {
                    ep.firing_ms = Some(now_ms);
                    self.state_ = Some(AlertState::Firing);
                } else {
                    self.state_ = Some(AlertState::Pending);
                }
                self.open = Some(ep);
            }
            (AlertState::Pending, true) => {
                if let Some(ep) = self.open.as_mut() {
                    ep.peak = ep.peak.max(value);
                }
                if now_ms.saturating_sub(self.pending_since) >= for_ms {
                    if let Some(ep) = self.open.as_mut() {
                        ep.firing_ms = Some(now_ms);
                    }
                    self.state_ = Some(AlertState::Firing);
                }
            }
            (AlertState::Pending, false) => {
                // never fired: the episode evaporates (Prometheus keeps
                // no record of pending-only activations either)
                self.open = None;
                self.state_ = Some(AlertState::Inactive);
            }
            (AlertState::Firing, true) => {
                if let Some(ep) = self.open.as_mut() {
                    ep.peak = ep.peak.max(value);
                }
            }
            (AlertState::Firing, false) => {
                if let Some(mut ep) = self.open.take() {
                    ep.resolved_ms = Some(now_ms);
                    self.episodes.push(ep);
                }
                self.state_ = Some(AlertState::Inactive);
            }
            (AlertState::Inactive, false) => {}
        }
        let to = self.state();
        if from != to {
            Some((from, to))
        } else {
            None
        }
    }

    /// End of run: keep a still-firing episode (open-ended), drop a
    /// still-pending one.
    pub fn finalize(&mut self) {
        if let Some(ep) = self.open.take() {
            if ep.firing_ms.is_some() {
                self.episodes.push(ep);
            }
        }
    }

    /// Number of distinct firing episodes.
    pub fn fired(&self) -> u64 {
        self.episodes.len() as u64
    }

    /// Total firing milliseconds, charging open episodes to `end_ms`.
    pub fn firing_ms(&self, end_ms: u64) -> u64 {
        self.episodes.iter().map(|e| e.firing_span_ms(end_ms)).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The pinned lifecycle fixture from the issue: a synthetic series
    /// walks one alert through every transition at exact timestamps.
    #[test]
    fn lifecycle_fixture_pins_exact_timestamps() {
        let mut rt = AlertRuntime::new();
        let for_ms = 60_000;
        // t=30s: condition false → stays inactive
        assert_eq!(rt.step(30_000, false, 0.0, for_ms), None);
        assert_eq!(rt.state(), AlertState::Inactive);
        // t=60s: condition true → pending
        assert_eq!(
            rt.step(60_000, true, 5.0, for_ms),
            Some((AlertState::Inactive, AlertState::Pending))
        );
        // t=90s: held 30s < 60s → still pending, peak tracks 7.0
        assert_eq!(rt.step(90_000, true, 7.0, for_ms), None);
        assert_eq!(rt.state(), AlertState::Pending);
        // t=120s: held 60s ≥ for → firing
        assert_eq!(
            rt.step(120_000, true, 6.0, for_ms),
            Some((AlertState::Pending, AlertState::Firing))
        );
        // t=150s: cleared → resolved
        assert_eq!(
            rt.step(150_000, false, 0.0, for_ms),
            Some((AlertState::Firing, AlertState::Inactive))
        );
        rt.finalize();
        assert_eq!(
            rt.episodes,
            vec![Episode {
                pending_ms: 60_000,
                firing_ms: Some(120_000),
                resolved_ms: Some(150_000),
                peak: 7.0,
            }]
        );
        assert_eq!(rt.fired(), 1);
        assert_eq!(rt.firing_ms(1_000_000), 30_000);
    }

    #[test]
    fn pending_that_clears_never_fired() {
        let mut rt = AlertRuntime::new();
        rt.step(10_000, true, 3.0, 60_000);
        assert_eq!(rt.state(), AlertState::Pending);
        assert_eq!(
            rt.step(20_000, false, 0.0, 60_000),
            Some((AlertState::Pending, AlertState::Inactive))
        );
        rt.finalize();
        assert!(rt.episodes.is_empty(), "pending-only episodes are discarded");
        assert_eq!(rt.fired(), 0);
    }

    #[test]
    fn for_zero_fires_immediately() {
        let mut rt = AlertRuntime::new();
        assert_eq!(
            rt.step(40_000, true, 9.0, 0),
            Some((AlertState::Inactive, AlertState::Firing))
        );
        assert_eq!(rt.episodes.len(), 0, "still open");
        rt.finalize();
        assert_eq!(rt.episodes[0].pending_ms, 40_000);
        assert_eq!(rt.episodes[0].firing_ms, Some(40_000));
        assert_eq!(rt.episodes[0].resolved_ms, None, "open at end of run");
        // open episode charged to the makespan
        assert_eq!(rt.firing_ms(100_000), 60_000);
    }

    #[test]
    fn refiring_opens_a_second_episode() {
        let mut rt = AlertRuntime::new();
        rt.step(0, true, 1.0, 0);
        rt.step(10_000, false, 0.0, 0);
        rt.step(20_000, true, 2.0, 0);
        rt.step(30_000, false, 0.0, 0);
        rt.finalize();
        assert_eq!(rt.fired(), 2);
        assert_eq!(rt.firing_ms(30_000), 20_000);
        assert_eq!(rt.episodes[1].peak, 2.0);
    }

    #[test]
    fn non_finite_values_cannot_poison_the_peak() {
        let mut rt = AlertRuntime::new();
        rt.step(0, true, f64::NAN, 0);
        rt.step(10_000, true, f64::INFINITY, 0);
        rt.step(20_000, false, 0.0, 0);
        assert_eq!(rt.episodes[0].peak, 0.0);
    }
}
