//! PromQL-lite: the rule language of the in-sim monitoring stack.
//!
//! A deliberately small subset of PromQL, evaluated once per scrape tick
//! against fixed-interval ring buffers (see [`SampleStore`]). Because the
//! scrape interval is constant, a `[90s]` range selector is just a
//! k-sample lookback (`k = round(90 / interval)`), which keeps every
//! window function O(window) with zero timestamp bookkeeping.
//!
//! Statements (one per line, `#` starts a comment):
//!
//! ```text
//! record NAME = EXPR
//! alert  NAME if EXPR CMP EXPR for DUR [severity WORD] [tenant N]
//! burnrate NAME on NUMER / DENOM slo F factor F fast DUR slow DUR
//!          [severity WORD] [tenant N]
//! ```
//!
//! Expressions support `+ - * /`, parentheses, numeric literals, metric
//! names (current sample), the window functions `rate(m[DUR])`,
//! `increase(m[DUR])`, `avg_over_time(m[DUR])`, `max_over_time(m[DUR])`,
//! `min_over_time(m[DUR])`, `changes(m[DUR])`, and the stateful
//! smoothers `ewma(m, alpha)` / `holt_winters(m, alpha, beta)` whose
//! state advances exactly once per tick (these are the forecaster inputs
//! for the predictive autoscaler, ROADMAP item 5). Division by zero
//! evaluates to 0.0 — a missing denominator must never poison an alert
//! with NaN. Durations are `30s`, `5m`, `1h`, or `500ms`.
//!
//! Recorded series are pushed back into the store under the rule's name,
//! so later rules (and kernel-side consumers via
//! [`super::monitor::MonitorState::query`]) can read them like any other
//! metric.

use std::collections::{BTreeMap, VecDeque};

// ---------------------------------------------------------------------
// sample store
// ---------------------------------------------------------------------

/// Fixed-interval ring buffers, one per metric, newest sample at the
/// back. Window functions clamp to the available history (early in a run
/// a `[10m]` window sees whatever has been scraped so far), which keeps
/// every rule total — no "no data" states to thread through alerting.
#[derive(Debug)]
pub struct SampleStore {
    interval_s: f64,
    cap: usize,
    index: BTreeMap<String, usize>,
    bufs: Vec<VecDeque<f64>>,
}

impl SampleStore {
    pub fn new(interval_s: f64, max_window_s: f64) -> Self {
        let interval_s = if interval_s > 0.0 { interval_s } else { 1.0 };
        SampleStore {
            interval_s,
            cap: Self::cap_for(interval_s, max_window_s),
            index: BTreeMap::new(),
            bufs: Vec::new(),
        }
    }

    fn cap_for(interval_s: f64, max_window_s: f64) -> usize {
        ((max_window_s.max(0.0) / interval_s).ceil() as usize + 2).max(4)
    }

    pub fn interval_s(&self) -> f64 {
        self.interval_s
    }

    /// Widen the retention to cover `max_window_s` (rules appended after
    /// construction, e.g. the per-tenant builtins, may look further back).
    pub fn grow(&mut self, max_window_s: f64) {
        self.cap = self.cap.max(Self::cap_for(self.interval_s, max_window_s));
    }

    /// Append the tick's sample for `name`. Non-finite values are
    /// recorded as 0.0: the store is the alerting substrate and must
    /// stay NaN-free.
    pub fn push(&mut self, name: &str, v: f64) {
        let i = match self.index.get(name) {
            Some(&i) => i,
            None => {
                self.bufs.push(VecDeque::new());
                let i = self.bufs.len() - 1;
                self.index.insert(name.to_string(), i);
                i
            }
        };
        let buf = &mut self.bufs[i];
        buf.push_back(if v.is_finite() { v } else { 0.0 });
        while buf.len() > self.cap {
            buf.pop_front();
        }
    }

    /// Latest sample of `name`, if it has ever been scraped.
    pub fn last(&self, name: &str) -> Option<f64> {
        let buf = self.buf(name)?;
        buf.back().copied()
    }

    fn buf(&self, name: &str) -> Option<&VecDeque<f64>> {
        self.index.get(name).map(|&i| &self.bufs[i])
    }

    /// Lookback depth for a `window_s` range: at least one sample back,
    /// clamped to the history actually present.
    fn lookback(&self, buf: &VecDeque<f64>, window_s: f64) -> usize {
        let k = ((window_s / self.interval_s).round() as usize).max(1);
        k.min(buf.len().saturating_sub(1))
    }

    /// (newest − sample `window_s` ago, covered span in seconds).
    /// `(0.0, 0.0)` until a second sample exists.
    pub fn delta(&self, name: &str, window_s: f64) -> (f64, f64) {
        let Some(buf) = self.buf(name) else {
            return (0.0, 0.0);
        };
        let k = self.lookback(buf, window_s);
        if k == 0 {
            return (0.0, 0.0);
        }
        let newest = *buf.back().unwrap();
        let oldest = buf[buf.len() - 1 - k];
        (newest - oldest, k as f64 * self.interval_s)
    }

    /// Per-second increase over the window (counter `rate()`).
    pub fn rate(&self, name: &str, window_s: f64) -> f64 {
        let (d, span) = self.delta(name, window_s);
        if span > 0.0 {
            d / span
        } else {
            0.0
        }
    }

    fn fold_window(&self, name: &str, window_s: f64, f: impl FnMut(f64, f64) -> f64, init: f64) -> f64 {
        let Some(buf) = self.buf(name) else {
            return 0.0;
        };
        if buf.is_empty() {
            return 0.0;
        }
        let k = self.lookback(buf, window_s);
        let start = buf.len() - 1 - k;
        buf.iter().skip(start).copied().fold(init, f)
    }

    pub fn avg_over(&self, name: &str, window_s: f64) -> f64 {
        let Some(buf) = self.buf(name) else {
            return 0.0;
        };
        if buf.is_empty() {
            return 0.0;
        }
        let k = self.lookback(buf, window_s);
        let n = (k + 1) as f64;
        self.fold_window(name, window_s, |acc, v| acc + v, 0.0) / n
    }

    pub fn max_over(&self, name: &str, window_s: f64) -> f64 {
        let Some(buf) = self.buf(name) else {
            return 0.0;
        };
        if buf.is_empty() {
            return 0.0;
        }
        self.fold_window(name, window_s, f64::max, f64::NEG_INFINITY)
    }

    pub fn min_over(&self, name: &str, window_s: f64) -> f64 {
        let Some(buf) = self.buf(name) else {
            return 0.0;
        };
        if buf.is_empty() {
            return 0.0;
        }
        self.fold_window(name, window_s, f64::min, f64::INFINITY)
    }

    /// Number of value changes between adjacent samples in the window.
    pub fn changes(&self, name: &str, window_s: f64) -> f64 {
        let Some(buf) = self.buf(name) else {
            return 0.0;
        };
        if buf.len() < 2 {
            return 0.0;
        }
        let k = self.lookback(buf, window_s);
        let start = buf.len() - 1 - k;
        let mut n = 0u64;
        let mut prev = buf[start];
        for i in start + 1..buf.len() {
            if buf[i] != prev {
                n += 1;
            }
            prev = buf[i];
        }
        n as f64
    }
}

// ---------------------------------------------------------------------
// expressions
// ---------------------------------------------------------------------

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OverFunc {
    Rate,
    Increase,
    Avg,
    Max,
    Min,
    Changes,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BinOp {
    Add,
    Sub,
    Mul,
    Div,
}

#[derive(Debug, Clone, PartialEq)]
pub enum Expr {
    Const(f64),
    /// Current sample of a metric (0.0 until first scraped).
    Metric(String),
    /// Window function over a range selector `m[DUR]`.
    Over {
        func: OverFunc,
        metric: String,
        window_s: f64,
    },
    /// Stateful smoother slot (index into [`RuleSet::smoothers`]).
    Smooth(usize),
    Neg(Box<Expr>),
    Bin {
        op: BinOp,
        lhs: Box<Expr>,
        rhs: Box<Expr>,
    },
}

impl Expr {
    fn max_window_s(&self) -> f64 {
        match self {
            Expr::Over { window_s, .. } => *window_s,
            Expr::Neg(e) => e.max_window_s(),
            Expr::Bin { lhs, rhs, .. } => lhs.max_window_s().max(rhs.max_window_s()),
            _ => 0.0,
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Cmp {
    Gt,
    Lt,
    Ge,
    Le,
}

impl Cmp {
    /// NaN on either side compares false: a poisoned sample can never
    /// activate an alert.
    pub fn holds(self, l: f64, r: f64) -> bool {
        match self {
            Cmp::Gt => l > r,
            Cmp::Lt => l < r,
            Cmp::Ge => l >= r,
            Cmp::Le => l <= r,
        }
    }

    pub fn symbol(self) -> &'static str {
        match self {
            Cmp::Gt => ">",
            Cmp::Lt => "<",
            Cmp::Ge => ">=",
            Cmp::Le => "<=",
        }
    }
}

/// Exponential smoothers with per-rule state, advanced exactly once per
/// scrape tick (before rule evaluation) from the latest sample of their
/// input metric.
#[derive(Debug, Clone, PartialEq)]
pub enum Smoother {
    Ewma {
        metric: String,
        alpha: f64,
        level: Option<f64>,
    },
    /// Double exponential smoothing; its value is the one-step-ahead
    /// forecast `level + trend`.
    HoltWinters {
        metric: String,
        alpha: f64,
        beta: f64,
        level: Option<f64>,
        trend: f64,
    },
}

impl Smoother {
    pub fn metric(&self) -> &str {
        match self {
            Smoother::Ewma { metric, .. } | Smoother::HoltWinters { metric, .. } => metric,
        }
    }

    pub fn update(&mut self, x: f64) {
        let x = if x.is_finite() { x } else { 0.0 };
        match self {
            Smoother::Ewma { alpha, level, .. } => {
                *level = Some(match *level {
                    None => x,
                    Some(prev) => *alpha * x + (1.0 - *alpha) * prev,
                });
            }
            Smoother::HoltWinters {
                alpha,
                beta,
                level,
                trend,
                ..
            } => match *level {
                None => {
                    *level = Some(x);
                    *trend = 0.0;
                }
                Some(prev) => {
                    let new_level = *alpha * x + (1.0 - *alpha) * (prev + *trend);
                    *trend = *beta * (new_level - prev) + (1.0 - *beta) * *trend;
                    *level = Some(new_level);
                }
            },
        }
    }

    pub fn value(&self) -> f64 {
        match self {
            Smoother::Ewma { level, .. } => level.unwrap_or(0.0),
            Smoother::HoltWinters { level, trend, .. } => {
                level.map(|l| l + trend).unwrap_or(0.0)
            }
        }
    }
}

/// Evaluate an expression against the store and the smoother table.
pub fn eval(expr: &Expr, store: &SampleStore, smoothers: &[Smoother]) -> f64 {
    match expr {
        Expr::Const(c) => *c,
        Expr::Metric(m) => store.last(m).unwrap_or(0.0),
        Expr::Over {
            func,
            metric,
            window_s,
        } => match func {
            OverFunc::Rate => store.rate(metric, *window_s),
            OverFunc::Increase => store.delta(metric, *window_s).0,
            OverFunc::Avg => store.avg_over(metric, *window_s),
            OverFunc::Max => store.max_over(metric, *window_s),
            OverFunc::Min => store.min_over(metric, *window_s),
            OverFunc::Changes => store.changes(metric, *window_s),
        },
        Expr::Smooth(i) => smoothers.get(*i).map(Smoother::value).unwrap_or(0.0),
        Expr::Neg(e) => -eval(e, store, smoothers),
        Expr::Bin { op, lhs, rhs } => {
            let l = eval(lhs, store, smoothers);
            let r = eval(rhs, store, smoothers);
            match op {
                BinOp::Add => l + r,
                BinOp::Sub => l - r,
                BinOp::Mul => l * r,
                BinOp::Div => {
                    if r == 0.0 || !r.is_finite() {
                        0.0
                    } else {
                        l / r
                    }
                }
            }
        }
    }
}

// ---------------------------------------------------------------------
// rules
// ---------------------------------------------------------------------

#[derive(Debug, Clone, PartialEq)]
pub struct RecordingRule {
    pub name: String,
    pub expr: Expr,
}

#[derive(Debug, Clone, PartialEq)]
pub struct AlertRule {
    pub name: String,
    pub lhs: Expr,
    pub cmp: Cmp,
    pub rhs: Expr,
    pub for_ms: u64,
    pub severity: String,
    pub tenant: Option<u16>,
}

/// Multi-window burn-rate alert (Google SRE style): fires while the
/// error ratio `Δnumer/Δdenom` exceeds `factor × slo` over BOTH the fast
/// and the slow window — the fast window catches the burn quickly, the
/// slow window keeps a transient spike from paging.
#[derive(Debug, Clone, PartialEq)]
pub struct BurnRateRule {
    pub name: String,
    pub numer: String,
    pub denom: String,
    pub slo: f64,
    pub factor: f64,
    pub fast_s: f64,
    pub slow_s: f64,
    pub severity: String,
    pub tenant: Option<u16>,
}

impl BurnRateRule {
    pub fn threshold(&self) -> f64 {
        self.factor * self.slo
    }

    /// Error ratio over one window. An empty denominator with a live
    /// numerator is an infinite burn — clamped to [`BURN_CLAMP`] so the
    /// value stays JSON-serializable.
    pub fn ratio(store: &SampleStore, numer: &str, denom: &str, window_s: f64) -> f64 {
        let (dn, _) = store.delta(numer, window_s);
        let (dd, _) = store.delta(denom, window_s);
        if dd > 0.0 {
            (dn / dd).min(BURN_CLAMP)
        } else if dn > 0.0 {
            BURN_CLAMP
        } else {
            0.0
        }
    }
}

/// Upper clamp for burn-rate ratios (stand-in for +inf).
pub const BURN_CLAMP: f64 = 1e9;

#[derive(Debug, Clone, PartialEq, Default)]
pub struct RuleSet {
    pub records: Vec<RecordingRule>,
    pub alerts: Vec<AlertRule>,
    pub burns: Vec<BurnRateRule>,
    pub smoothers: Vec<Smoother>,
}

impl RuleSet {
    pub fn parse(text: &str) -> Result<RuleSet, String> {
        let mut rs = RuleSet::default();
        rs.parse_append(text)?;
        Ok(rs)
    }

    /// Parse `text` and append its rules (used for the per-tenant
    /// builtins added once the fleet size is known). Smoother slots are
    /// allocated in this set, so appended rules keep their own state.
    pub fn parse_append(&mut self, text: &str) -> Result<(), String> {
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            self.parse_line(line)
                .map_err(|e| format!("rules line {}: {e}", lineno + 1))?;
        }
        Ok(())
    }

    /// Widest range selector (or burn window) in the set: the retention
    /// the sample store must keep.
    pub fn max_window_s(&self) -> f64 {
        let mut w: f64 = 0.0;
        for r in &self.records {
            w = w.max(r.expr.max_window_s());
        }
        for a in &self.alerts {
            w = w.max(a.lhs.max_window_s()).max(a.rhs.max_window_s());
        }
        for b in &self.burns {
            w = w.max(b.fast_s).max(b.slow_s);
        }
        w
    }

    fn parse_line(&mut self, line: &str) -> Result<(), String> {
        let toks = lex(line)?;
        let mut p = P {
            toks: &toks,
            i: 0,
            smoothers: &mut self.smoothers,
        };
        match p.ident_keyword()? {
            "record" => {
                let name = p.ident("recording rule name")?;
                p.expect(&Tok::Eq)?;
                let expr = p.sum()?;
                p.end()?;
                self.records.push(RecordingRule { name, expr });
            }
            "alert" => {
                let name = p.ident("alert name")?;
                p.keyword("if")?;
                let lhs = p.sum()?;
                let cmp = p.cmp()?;
                let rhs = p.sum()?;
                p.keyword("for")?;
                let for_ms = (p.duration()? * 1000.0).round() as u64;
                let (severity, tenant) = p.trailer()?;
                p.end()?;
                self.alerts.push(AlertRule {
                    name,
                    lhs,
                    cmp,
                    rhs,
                    for_ms,
                    severity,
                    tenant,
                });
            }
            "burnrate" => {
                let name = p.ident("burn-rate alert name")?;
                p.keyword("on")?;
                let numer = p.ident("numerator counter")?;
                p.expect(&Tok::Slash)?;
                let denom = p.ident("denominator counter")?;
                p.keyword("slo")?;
                let slo = p.number()?;
                p.keyword("factor")?;
                let factor = p.number()?;
                p.keyword("fast")?;
                let fast_s = p.duration()?;
                p.keyword("slow")?;
                let slow_s = p.duration()?;
                let (severity, tenant) = p.trailer()?;
                p.end()?;
                if !(slo > 0.0) {
                    return Err("slo must be > 0".to_string());
                }
                if slow_s < fast_s {
                    return Err("slow window must be >= fast window".to_string());
                }
                self.burns.push(BurnRateRule {
                    name,
                    numer,
                    denom,
                    slo,
                    factor,
                    fast_s,
                    slow_s,
                    severity,
                    tenant,
                });
            }
            kw => return Err(format!("unknown statement '{kw}' (record/alert/burnrate)")),
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------
// lexer + parser
// ---------------------------------------------------------------------

#[derive(Debug, Clone, PartialEq)]
enum Tok {
    Ident(String),
    Num(f64),
    /// Duration literal, seconds.
    Dur(f64),
    LParen,
    RParen,
    LBrack,
    RBrack,
    Comma,
    Plus,
    Minus,
    Star,
    Slash,
    Eq,
    Gt,
    Lt,
    Ge,
    Le,
}

fn lex(line: &str) -> Result<Vec<Tok>, String> {
    let b: Vec<char> = line.chars().collect();
    let mut toks = Vec::new();
    let mut i = 0;
    while i < b.len() {
        let c = b[i];
        match c {
            ' ' | '\t' => i += 1,
            '#' => break,
            '(' => {
                toks.push(Tok::LParen);
                i += 1;
            }
            ')' => {
                toks.push(Tok::RParen);
                i += 1;
            }
            '[' => {
                toks.push(Tok::LBrack);
                i += 1;
            }
            ']' => {
                toks.push(Tok::RBrack);
                i += 1;
            }
            ',' => {
                toks.push(Tok::Comma);
                i += 1;
            }
            '+' => {
                toks.push(Tok::Plus);
                i += 1;
            }
            '-' => {
                toks.push(Tok::Minus);
                i += 1;
            }
            '*' => {
                toks.push(Tok::Star);
                i += 1;
            }
            '/' => {
                toks.push(Tok::Slash);
                i += 1;
            }
            '=' => {
                toks.push(Tok::Eq);
                i += 1;
            }
            '>' => {
                if b.get(i + 1) == Some(&'=') {
                    toks.push(Tok::Ge);
                    i += 2;
                } else {
                    toks.push(Tok::Gt);
                    i += 1;
                }
            }
            '<' => {
                if b.get(i + 1) == Some(&'=') {
                    toks.push(Tok::Le);
                    i += 2;
                } else {
                    toks.push(Tok::Lt);
                    i += 1;
                }
            }
            c if c.is_ascii_digit() || c == '.' => {
                let start = i;
                while i < b.len() && (b[i].is_ascii_digit() || b[i] == '.') {
                    i += 1;
                }
                let num: String = b[start..i].iter().collect();
                let n: f64 = num
                    .parse()
                    .map_err(|_| format!("bad number '{num}'"))?;
                // unit suffix glued to the number → duration literal
                let sfx_start = i;
                while i < b.len() && b[i].is_ascii_alphabetic() {
                    i += 1;
                }
                let sfx: String = b[sfx_start..i].iter().collect();
                match sfx.as_str() {
                    "" => toks.push(Tok::Num(n)),
                    "ms" => toks.push(Tok::Dur(n / 1000.0)),
                    "s" => toks.push(Tok::Dur(n)),
                    "m" => toks.push(Tok::Dur(n * 60.0)),
                    "h" => toks.push(Tok::Dur(n * 3600.0)),
                    other => return Err(format!("bad duration unit '{other}' (ms/s/m/h)")),
                }
            }
            c if c.is_ascii_alphabetic() || c == '_' => {
                let start = i;
                while i < b.len()
                    && (b[i].is_ascii_alphanumeric() || b[i] == '_' || b[i] == ':')
                {
                    i += 1;
                }
                toks.push(Tok::Ident(b[start..i].iter().collect()));
            }
            other => return Err(format!("unexpected character '{other}'")),
        }
    }
    Ok(toks)
}

struct P<'a> {
    toks: &'a [Tok],
    i: usize,
    smoothers: &'a mut Vec<Smoother>,
}

impl<'a> P<'a> {
    fn peek(&self) -> Option<&Tok> {
        self.toks.get(self.i)
    }

    fn next(&mut self) -> Result<&Tok, String> {
        let t = self.toks.get(self.i).ok_or("unexpected end of line")?;
        self.i += 1;
        Ok(t)
    }

    fn expect(&mut self, want: &Tok) -> Result<(), String> {
        let got = self.next()?;
        if got == want {
            Ok(())
        } else {
            Err(format!("expected {want:?}, got {got:?}"))
        }
    }

    fn end(&mut self) -> Result<(), String> {
        match self.peek() {
            None => Ok(()),
            Some(t) => Err(format!("trailing token {t:?}")),
        }
    }

    fn ident_keyword(&mut self) -> Result<&'a str, String> {
        let t: &'a Tok = self.toks.get(self.i).ok_or("unexpected end of line")?;
        self.i += 1;
        match t {
            Tok::Ident(s) => Ok(s.as_str()),
            t => Err(format!("expected statement keyword, got {t:?}")),
        }
    }

    fn ident(&mut self, what: &str) -> Result<String, String> {
        match self.next()? {
            Tok::Ident(s) => Ok(s.clone()),
            t => Err(format!("expected {what}, got {t:?}")),
        }
    }

    fn keyword(&mut self, kw: &str) -> Result<(), String> {
        match self.next()? {
            Tok::Ident(s) if s == kw => Ok(()),
            t => Err(format!("expected '{kw}', got {t:?}")),
        }
    }

    fn number(&mut self) -> Result<f64, String> {
        match self.next()? {
            Tok::Num(n) => Ok(*n),
            t => Err(format!("expected number, got {t:?}")),
        }
    }

    /// Duration in seconds; a bare number is taken as seconds.
    fn duration(&mut self) -> Result<f64, String> {
        match self.next()? {
            Tok::Dur(s) => Ok(*s),
            Tok::Num(n) => Ok(*n),
            t => Err(format!("expected duration (e.g. 30s), got {t:?}")),
        }
    }

    fn cmp(&mut self) -> Result<Cmp, String> {
        match self.next()? {
            Tok::Gt => Ok(Cmp::Gt),
            Tok::Lt => Ok(Cmp::Lt),
            Tok::Ge => Ok(Cmp::Ge),
            Tok::Le => Ok(Cmp::Le),
            t => Err(format!("expected comparison (> < >= <=), got {t:?}")),
        }
    }

    /// Optional `severity WORD` and `tenant N` clauses, any order.
    fn trailer(&mut self) -> Result<(String, Option<u16>), String> {
        let mut severity = "warn".to_string();
        let mut tenant = None;
        loop {
            let kw = match self.peek() {
                Some(Tok::Ident(s)) => s.clone(),
                _ => break,
            };
            match kw.as_str() {
                "severity" => {
                    self.i += 1;
                    severity = self.ident("severity word")?;
                }
                "tenant" => {
                    self.i += 1;
                    tenant = Some(self.number()? as u16);
                }
                other => return Err(format!("unexpected clause '{other}'")),
            }
        }
        Ok((severity, tenant))
    }

    // expression grammar: sum := term (('+'|'-') term)*
    //                     term := atom (('*'|'/') atom)*
    fn sum(&mut self) -> Result<Expr, String> {
        let mut lhs = self.term()?;
        loop {
            let op = match self.peek() {
                Some(Tok::Plus) => BinOp::Add,
                Some(Tok::Minus) => BinOp::Sub,
                _ => return Ok(lhs),
            };
            self.i += 1;
            let rhs = self.term()?;
            lhs = Expr::Bin {
                op,
                lhs: Box::new(lhs),
                rhs: Box::new(rhs),
            };
        }
    }

    fn term(&mut self) -> Result<Expr, String> {
        let mut lhs = self.atom()?;
        loop {
            let op = match self.peek() {
                Some(Tok::Star) => BinOp::Mul,
                Some(Tok::Slash) => BinOp::Div,
                _ => return Ok(lhs),
            };
            self.i += 1;
            let rhs = self.atom()?;
            lhs = Expr::Bin {
                op,
                lhs: Box::new(lhs),
                rhs: Box::new(rhs),
            };
        }
    }

    fn atom(&mut self) -> Result<Expr, String> {
        match self.next()? {
            Tok::Num(n) => Ok(Expr::Const(*n)),
            Tok::Minus => Ok(Expr::Neg(Box::new(self.atom()?))),
            Tok::LParen => {
                let e = self.sum()?;
                self.expect(&Tok::RParen)?;
                Ok(e)
            }
            Tok::Ident(name) => {
                let name = name.clone();
                if self.peek() == Some(&Tok::LParen) {
                    self.i += 1;
                    self.call(&name)
                } else {
                    Ok(Expr::Metric(name))
                }
            }
            t => Err(format!("expected expression, got {t:?}")),
        }
    }

    /// Function call; `(` already consumed.
    fn call(&mut self, name: &str) -> Result<Expr, String> {
        let func = match name {
            "rate" => Some(OverFunc::Rate),
            "increase" => Some(OverFunc::Increase),
            "avg_over_time" => Some(OverFunc::Avg),
            "max_over_time" => Some(OverFunc::Max),
            "min_over_time" => Some(OverFunc::Min),
            "changes" => Some(OverFunc::Changes),
            _ => None,
        };
        if let Some(func) = func {
            let metric = self.ident("metric name")?;
            self.expect(&Tok::LBrack)?;
            let window_s = self.duration()?;
            self.expect(&Tok::RBrack)?;
            self.expect(&Tok::RParen)?;
            if !(window_s > 0.0) {
                return Err("window must be > 0".to_string());
            }
            return Ok(Expr::Over {
                func,
                metric,
                window_s,
            });
        }
        match name {
            "ewma" => {
                let metric = self.ident("metric name")?;
                self.expect(&Tok::Comma)?;
                let alpha = self.number()?;
                self.expect(&Tok::RParen)?;
                check_unit("alpha", alpha)?;
                self.smoothers.push(Smoother::Ewma {
                    metric,
                    alpha,
                    level: None,
                });
                Ok(Expr::Smooth(self.smoothers.len() - 1))
            }
            "holt_winters" => {
                let metric = self.ident("metric name")?;
                self.expect(&Tok::Comma)?;
                let alpha = self.number()?;
                self.expect(&Tok::Comma)?;
                let beta = self.number()?;
                self.expect(&Tok::RParen)?;
                check_unit("alpha", alpha)?;
                check_unit("beta", beta)?;
                self.smoothers.push(Smoother::HoltWinters {
                    metric,
                    alpha,
                    beta,
                    level: None,
                    trend: 0.0,
                });
                Ok(Expr::Smooth(self.smoothers.len() - 1))
            }
            other => Err(format!(
                "unknown function '{other}' (rate/increase/avg_over_time/max_over_time/\
                 min_over_time/changes/ewma/holt_winters)"
            )),
        }
    }
}

fn check_unit(what: &str, v: f64) -> Result<(), String> {
    if v > 0.0 && v <= 1.0 {
        Ok(())
    } else {
        Err(format!("{what} must be in (0, 1], got {v}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn store(interval_s: f64, samples: &[(&str, &[f64])]) -> SampleStore {
        let mut s = SampleStore::new(interval_s, 3600.0);
        let n = samples.iter().map(|(_, v)| v.len()).max().unwrap_or(0);
        for i in 0..n {
            for (name, vals) in samples {
                if i < vals.len() {
                    s.push(name, vals[i]);
                }
            }
        }
        s
    }

    #[test]
    fn window_functions_on_fixed_interval_samples() {
        let s = store(10.0, &[("c", &[0.0, 10.0, 40.0, 100.0])]);
        // rate over 30s: (100 - 0) / 30
        assert!((s.rate("c", 30.0) - 100.0 / 30.0).abs() < 1e-12);
        // increase over 10s: 100 - 40
        assert_eq!(s.delta("c", 10.0).0, 60.0);
        // clamped beyond history: full span
        assert_eq!(s.delta("c", 1e6).0, 100.0);
        assert_eq!(s.avg_over("c", 30.0), 37.5);
        assert_eq!(s.max_over("c", 30.0), 100.0);
        assert_eq!(s.min_over("c", 30.0), 0.0);
        assert_eq!(s.changes("c", 30.0), 3.0);
        // missing metric: every window function is 0
        assert_eq!(s.rate("nope", 30.0), 0.0);
        assert_eq!(s.avg_over("nope", 30.0), 0.0);
    }

    #[test]
    fn single_sample_windows_are_zero_rate() {
        let s = store(10.0, &[("c", &[5.0])]);
        assert_eq!(s.delta("c", 30.0), (0.0, 0.0));
        assert_eq!(s.rate("c", 30.0), 0.0);
        assert_eq!(s.avg_over("c", 30.0), 5.0);
        assert_eq!(s.changes("c", 30.0), 0.0);
    }

    #[test]
    fn ring_buffer_evicts_beyond_capacity() {
        let mut s = SampleStore::new(10.0, 20.0); // cap = 4
        for i in 0..10 {
            s.push("g", i as f64);
        }
        assert_eq!(s.last("g"), Some(9.0));
        // full-history delta only spans the retained window
        assert_eq!(s.delta("g", 1e6), (3.0, 30.0));
    }

    #[test]
    fn non_finite_samples_are_sanitized() {
        let mut s = SampleStore::new(10.0, 60.0);
        s.push("g", f64::NAN);
        s.push("g", f64::INFINITY);
        assert_eq!(s.last("g"), Some(0.0));
        assert_eq!(s.avg_over("g", 60.0), 0.0);
    }

    #[test]
    fn parses_records_alerts_and_burnrates() {
        let text = "
            # builtin-style rules
            record backlog_avg = avg_over_time(backlog_total[120s])
            record forecast = holt_winters(backlog_total, 0.5, 0.1)
            alert Saturated if avg_over_time(backlog_total[2m]) > 16 for 120s severity page
            alert TenantSlow::1 if tenant_active_age_s::1 > 1800 for 5m severity page tenant 1
            burnrate Budget on lost / done slo 0.001 factor 10 fast 120s slow 600s severity page
        ";
        let rs = RuleSet::parse(text).unwrap();
        assert_eq!(rs.records.len(), 2);
        assert_eq!(rs.alerts.len(), 2);
        assert_eq!(rs.burns.len(), 1);
        assert_eq!(rs.smoothers.len(), 1);
        assert_eq!(rs.alerts[0].for_ms, 120_000);
        assert_eq!(rs.alerts[0].cmp, Cmp::Gt);
        assert_eq!(rs.alerts[1].tenant, Some(1));
        assert_eq!(rs.alerts[1].for_ms, 300_000);
        assert_eq!(rs.alerts[1].lhs, Expr::Metric("tenant_active_age_s::1".into()));
        let b = &rs.burns[0];
        assert_eq!((b.numer.as_str(), b.denom.as_str()), ("lost", "done"));
        assert!((b.threshold() - 0.01).abs() < 1e-12);
        assert_eq!(rs.max_window_s(), 600.0);
    }

    #[test]
    fn parse_errors_name_the_line() {
        let err = RuleSet::parse("record x = rate(c[0s])").unwrap_err();
        assert!(err.contains("line 1"), "{err}");
        assert!(err.contains("window"), "{err}");
        let err = RuleSet::parse("\nfrobnicate y").unwrap_err();
        assert!(err.contains("line 2"), "{err}");
        assert!(RuleSet::parse("alert A if x > 1 for 10s extra_junk 3").is_err());
        assert!(RuleSet::parse("record z = ewma(m, 1.5)").is_err(), "alpha > 1");
        assert!(
            RuleSet::parse("burnrate B on a / b slo 0.1 factor 2 fast 10m slow 1m").is_err(),
            "slow < fast"
        );
    }

    #[test]
    fn eval_arithmetic_and_division_guard() {
        let s = store(10.0, &[("a", &[4.0]), ("b", &[0.0])]);
        let rs = RuleSet::parse("record r = (a + 2) * 3 - a / b").unwrap();
        // a/b = 4/0 → 0, so r = 18
        assert_eq!(eval(&rs.records[0].expr, &s, &rs.smoothers), 18.0);
        let rs = RuleSet::parse("record n = -a + 1").unwrap();
        assert_eq!(eval(&rs.records[0].expr, &s, &rs.smoothers), -3.0);
    }

    #[test]
    fn ewma_and_holt_winters_track_their_input() {
        let mut e = Smoother::Ewma {
            metric: "m".into(),
            alpha: 0.5,
            level: None,
        };
        e.update(10.0);
        assert_eq!(e.value(), 10.0);
        e.update(20.0);
        assert_eq!(e.value(), 15.0);

        let mut h = Smoother::HoltWinters {
            metric: "m".into(),
            alpha: 0.5,
            beta: 0.5,
            level: None,
            trend: 0.0,
        };
        // a perfect linear ramp: the one-step forecast converges ahead
        // of the input
        for x in [10.0, 20.0, 30.0, 40.0, 50.0] {
            h.update(x);
        }
        assert!(h.value() > 50.0, "forecast {} should lead the ramp", h.value());
        // fresh smoothers are 0 until the first update
        let cold = Smoother::Ewma {
            metric: "m".into(),
            alpha: 0.3,
            level: None,
        };
        assert_eq!(cold.value(), 0.0);
    }

    #[test]
    fn burn_ratio_handles_empty_denominator() {
        let s = store(
            10.0,
            &[("err", &[0.0, 5.0]), ("tot", &[0.0, 0.0]), ("ok", &[0.0, 100.0])],
        );
        // denominator moved: plain ratio
        assert_eq!(BurnRateRule::ratio(&s, "err", "ok", 10.0), 0.05);
        // denominator flat but errors present: clamped infinity
        assert_eq!(BurnRateRule::ratio(&s, "err", "tot", 10.0), BURN_CLAMP);
        // nothing moved at all: clean zero
        assert_eq!(BurnRateRule::ratio(&s, "tot", "tot", 10.0), 0.0);
    }

    #[test]
    fn cmp_is_nan_safe() {
        assert!(!Cmp::Gt.holds(f64::NAN, 0.0));
        assert!(!Cmp::Le.holds(f64::NAN, 0.0));
        assert!(Cmp::Ge.holds(1.0, 1.0));
        assert_eq!(Cmp::Ge.symbol(), ">=");
    }
}
