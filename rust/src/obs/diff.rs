//! Cross-run differential analysis: the engine behind `hyperflow diff`.
//!
//! Two layers:
//!
//! * [`diff`] compares two run snapshots ([`super::snapshot`]) and
//!   decomposes the makespan delta phase-by-phase. Because attribution
//!   telescopes in integer milliseconds on both sides (the seven phases
//!   sum *exactly* to each run's makespan), the per-phase deltas sum
//!   exactly to the makespan delta — no rounding residue, ever. On top
//!   of that it locates the first critical-path divergence point and
//!   diffs counter finals, gauge finals, alert lifecycles, per-tenant
//!   SLO rows, and the population-wide phase tails.
//! * [`compare_bench`] is the perf-regression gate: it walks two
//!   `BENCH_*.json` documents leaf-by-leaf and flags every numeric
//!   metric whose relative change exceeds its per-metric tolerance
//!   ([`Tolerances`], loaded from `baselines/tolerances.json`).
//!   Placeholder baselines (never measured — the committed state until
//!   `baselines/refresh.sh` runs on a real toolchain) disarm the gate
//!   with a notice instead of failing.
//!
//! Rendering lives in [`crate::report::diff`]; this module is pure data.

use std::collections::{BTreeMap, BTreeSet};

use super::critpath::PHASES;
use super::snapshot::SNAPSHOT_SCHEMA_VERSION;
use crate::util::json::Json;

// ---------------------------------------------------------------------
// snapshot diff
// ---------------------------------------------------------------------

/// One critical-path phase on both sides. `delta_ms` is B − A: positive
/// means run B spent longer in this phase.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PhaseDelta {
    pub phase: &'static str,
    pub a_ms: u64,
    pub b_ms: u64,
}

impl PhaseDelta {
    pub fn delta_ms(&self) -> i64 {
        self.b_ms as i64 - self.a_ms as i64
    }
}

/// First index at which the two critical paths stop agreeing, with the
/// task on each side (`None` where one path already ended).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Divergence {
    pub index: usize,
    pub a_task: Option<u64>,
    pub a_type: String,
    pub b_task: Option<u64>,
    pub b_type: String,
}

/// A counter whose final value (or presence) changed.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CounterDelta {
    pub name: String,
    pub a: u64,
    pub b: u64,
    pub in_a: bool,
    pub in_b: bool,
}

impl CounterDelta {
    pub fn delta(&self) -> i64 {
        self.b as i64 - self.a as i64
    }
}

/// A gauge whose final value changed.
#[derive(Debug, Clone, PartialEq)]
pub struct GaugeDelta {
    pub name: String,
    pub a: f64,
    pub b: f64,
}

/// An alert whose lifecycle changed between the runs (or that exists on
/// one side only).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct AlertDelta {
    pub name: String,
    pub in_a: bool,
    pub in_b: bool,
    pub fired_a: u64,
    pub fired_b: u64,
    pub firing_ms_a: u64,
    pub firing_ms_b: u64,
    pub episodes_a: u64,
    pub episodes_b: u64,
    pub state_a: String,
    pub state_b: String,
}

/// A tenant whose SLO headline numbers changed (fleet snapshots only).
#[derive(Debug, Clone, PartialEq)]
pub struct TenantDelta {
    pub tenant: u64,
    pub instances_a: u64,
    pub instances_b: u64,
    pub queue_delay_mean_s_a: f64,
    pub queue_delay_mean_s_b: f64,
    pub makespan_mean_s_a: f64,
    pub makespan_mean_s_b: f64,
    pub slowdown_p99_a: f64,
    pub slowdown_p99_b: f64,
}

/// A population-wide phase distribution that shifted (mean or p95) —
/// distinguishes a critical-path-only change from a fleet-wide one.
#[derive(Debug, Clone, PartialEq)]
pub struct PhaseTailDelta {
    pub phase: String,
    pub mean_a_ms: f64,
    pub mean_b_ms: f64,
    pub p95_a_ms: f64,
    pub p95_b_ms: f64,
}

/// Complete structured diff of two run snapshots (A → B).
#[derive(Debug, Clone, PartialEq)]
pub struct SnapshotDiff {
    pub model_a: String,
    pub model_b: String,
    pub seed_a: u64,
    pub seed_b: u64,
    pub makespan_a_ms: u64,
    pub makespan_b_ms: u64,
    /// The seven phases in [`PHASES`] order; empty when either snapshot
    /// lacks an attribution block.
    pub phases: Vec<PhaseDelta>,
    pub path_len_a: usize,
    pub path_len_b: usize,
    pub divergence: Option<Divergence>,
    /// Changed entries only — all four lists (and `phase_tails`) are
    /// empty for a self-diff.
    pub counters: Vec<CounterDelta>,
    pub gauges: Vec<GaugeDelta>,
    pub alerts: Vec<AlertDelta>,
    pub tenants: Vec<TenantDelta>,
    pub phase_tails: Vec<PhaseTailDelta>,
    /// Provenance caveats (schema/config/kind mismatches, missing
    /// attribution). Warnings never make a diff non-zero.
    pub warnings: Vec<String>,
}

impl SnapshotDiff {
    pub fn makespan_delta_ms(&self) -> i64 {
        self.makespan_b_ms as i64 - self.makespan_a_ms as i64
    }

    /// Sum of the per-phase deltas. Equal to [`Self::makespan_delta_ms`]
    /// *exactly* whenever both snapshots carry whole-run attribution —
    /// the telescoping invariant, in difference form.
    pub fn phase_delta_sum_ms(&self) -> i64 {
        self.phases.iter().map(PhaseDelta::delta_ms).sum()
    }

    /// True iff the two runs are observationally identical: zero
    /// makespan delta, zero in every phase, identical critical paths,
    /// and no counter/gauge/alert/tenant/tail change.
    pub fn is_zero(&self) -> bool {
        self.makespan_delta_ms() == 0
            && self.phases.iter().all(|p| p.delta_ms() == 0)
            && self.divergence.is_none()
            && self.path_len_a == self.path_len_b
            && self.counters.is_empty()
            && self.gauges.is_empty()
            && self.alerts.is_empty()
            && self.tenants.is_empty()
            && self.phase_tails.is_empty()
    }

    pub fn to_json(&self) -> Json {
        let phase_deltas = Json::Obj(
            self.phases
                .iter()
                .map(|p| (p.phase.to_string(), Json::Num(p.delta_ms() as f64)))
                .collect(),
        );
        let divergence = match &self.divergence {
            Some(d) => Json::obj(vec![
                ("index", d.index.into()),
                ("a_task", d.a_task.map(Json::from).unwrap_or(Json::Null)),
                ("a_type", Json::str(&d.a_type)),
                ("b_task", d.b_task.map(Json::from).unwrap_or(Json::Null)),
                ("b_type", Json::str(&d.b_type)),
            ]),
            None => Json::Null,
        };
        let counters = self
            .counters
            .iter()
            .map(|c| {
                Json::obj(vec![
                    ("name", Json::str(&c.name)),
                    ("a", c.a.into()),
                    ("b", c.b.into()),
                    ("delta", Json::Num(c.delta() as f64)),
                ])
            })
            .collect();
        let gauges = self
            .gauges
            .iter()
            .map(|g| {
                Json::obj(vec![
                    ("name", Json::str(&g.name)),
                    ("a", g.a.into()),
                    ("b", g.b.into()),
                ])
            })
            .collect();
        let alerts = self
            .alerts
            .iter()
            .map(|a| {
                Json::obj(vec![
                    ("name", Json::str(&a.name)),
                    ("fired_a", a.fired_a.into()),
                    ("fired_b", a.fired_b.into()),
                    ("firing_ms_a", a.firing_ms_a.into()),
                    ("firing_ms_b", a.firing_ms_b.into()),
                    ("episodes_a", a.episodes_a.into()),
                    ("episodes_b", a.episodes_b.into()),
                    ("state_a", Json::str(&a.state_a)),
                    ("state_b", Json::str(&a.state_b)),
                ])
            })
            .collect();
        let tenants = self
            .tenants
            .iter()
            .map(|t| {
                Json::obj(vec![
                    ("tenant", t.tenant.into()),
                    ("instances_a", t.instances_a.into()),
                    ("instances_b", t.instances_b.into()),
                    ("queue_delay_mean_s_a", t.queue_delay_mean_s_a.into()),
                    ("queue_delay_mean_s_b", t.queue_delay_mean_s_b.into()),
                    ("makespan_mean_s_a", t.makespan_mean_s_a.into()),
                    ("makespan_mean_s_b", t.makespan_mean_s_b.into()),
                    ("slowdown_p99_a", t.slowdown_p99_a.into()),
                    ("slowdown_p99_b", t.slowdown_p99_b.into()),
                ])
            })
            .collect();
        let tails = self
            .phase_tails
            .iter()
            .map(|t| {
                Json::obj(vec![
                    ("phase", Json::str(&t.phase)),
                    ("mean_a_ms", t.mean_a_ms.into()),
                    ("mean_b_ms", t.mean_b_ms.into()),
                    ("p95_a_ms", t.p95_a_ms.into()),
                    ("p95_b_ms", t.p95_b_ms.into()),
                ])
            })
            .collect();
        Json::obj(vec![
            ("model_a", Json::str(&self.model_a)),
            ("model_b", Json::str(&self.model_b)),
            ("seed_a", self.seed_a.into()),
            ("seed_b", self.seed_b.into()),
            ("makespan_a_ms", self.makespan_a_ms.into()),
            ("makespan_b_ms", self.makespan_b_ms.into()),
            ("makespan_delta_ms", Json::Num(self.makespan_delta_ms() as f64)),
            ("zero", self.is_zero().into()),
            ("phase_deltas", phase_deltas),
            (
                "phase_delta_sum_ms",
                Json::Num(self.phase_delta_sum_ms() as f64),
            ),
            ("path_len_a", self.path_len_a.into()),
            ("path_len_b", self.path_len_b.into()),
            ("divergence", divergence),
            ("counters", Json::Arr(counters)),
            ("gauges", Json::Arr(gauges)),
            ("alerts", Json::Arr(alerts)),
            ("tenants", Json::Arr(tenants)),
            ("phase_tails", Json::Arr(tails)),
            (
                "warnings",
                Json::Arr(self.warnings.iter().map(|w| Json::str(w)).collect()),
            ),
        ])
    }
}

fn req_u64(j: &Json, key: &str) -> Result<u64, String> {
    j.get(key)
        .and_then(|v| v.as_u64())
        .map_err(|e| format!("snapshot: {e}"))
}

fn req_str(j: &Json, key: &str) -> Result<String, String> {
    j.get(key)
        .and_then(|v| v.as_str())
        .map(|s| s.to_string())
        .map_err(|e| format!("snapshot: {e}"))
}

/// `(task, type)` pairs of a snapshot's critical path.
fn path_of(j: &Json) -> Vec<(u64, String)> {
    j.opt("critical_path")
        .and_then(|v| v.as_arr().ok())
        .map(|arr| {
            arr.iter()
                .map(|e| {
                    (
                        e.opt("task").and_then(|t| t.as_u64().ok()).unwrap_or(0),
                        e.opt("type")
                            .and_then(|t| t.as_str().ok())
                            .unwrap_or("")
                            .to_string(),
                    )
                })
                .collect()
        })
        .unwrap_or_default()
}

/// Flat `name → number` view of an object-valued snapshot field.
fn num_map(j: &Json, key: &str) -> BTreeMap<String, f64> {
    j.opt(key)
        .and_then(|v| v.as_obj().ok())
        .map(|o| {
            o.iter()
                .filter_map(|(k, v)| v.as_f64().ok().map(|n| (k.clone(), n)))
                .collect()
        })
        .unwrap_or_default()
}

/// `name → (fired, firing_ms, episodes, final_state)` from the monitor
/// block (empty when the run had no monitor attached).
fn alert_map(j: &Json) -> BTreeMap<String, (u64, u64, u64, String)> {
    let mut out = BTreeMap::new();
    let Some(alerts) = j
        .opt("monitor")
        .and_then(|m| m.opt("alerts"))
        .and_then(|a| a.as_arr().ok())
    else {
        return out;
    };
    for a in alerts {
        let Ok(name) = a.get("name").and_then(|n| n.as_str()) else {
            continue;
        };
        out.insert(
            name.to_string(),
            (
                a.opt("fired").and_then(|v| v.as_u64().ok()).unwrap_or(0),
                a.opt("firing_ms")
                    .and_then(|v| v.as_u64().ok())
                    .unwrap_or(0),
                a.opt("episodes")
                    .and_then(|v| v.as_arr().ok())
                    .map(|e| e.len() as u64)
                    .unwrap_or(0),
                a.opt("final_state")
                    .and_then(|v| v.as_str().ok())
                    .unwrap_or("")
                    .to_string(),
            ),
        );
    }
    out
}

/// `tenant → row` view of a fleet snapshot's tenant table.
fn tenant_map(j: &Json) -> BTreeMap<u64, &Json> {
    let mut out = BTreeMap::new();
    let Some(rows) = j.opt("tenants").and_then(|t| t.as_arr().ok()) else {
        return out;
    };
    for row in rows {
        if let Some(id) = row.opt("tenant").and_then(|t| t.as_u64().ok()) {
            out.insert(id, row);
        }
    }
    out
}

fn field_f64(row: &Json, key: &str) -> f64 {
    row.opt(key).and_then(|v| v.as_f64().ok()).unwrap_or(0.0)
}

/// Diff two parsed snapshots (A → B). Errors only on documents that are
/// not snapshots at all; provenance mismatches become warnings.
pub fn diff(a: &Json, b: &Json) -> Result<SnapshotDiff, String> {
    let mut warnings = Vec::new();
    let sv_a = req_u64(a, "schema_version")?;
    let sv_b = req_u64(b, "schema_version")?;
    if sv_a != SNAPSHOT_SCHEMA_VERSION || sv_b != SNAPSHOT_SCHEMA_VERSION {
        warnings.push(format!(
            "schema version mismatch: A v{sv_a}, B v{sv_b} \
             (this build speaks v{SNAPSHOT_SCHEMA_VERSION})"
        ));
    }
    let kind_a = req_str(a, "kind")?;
    let kind_b = req_str(b, "kind")?;
    if kind_a != kind_b {
        warnings.push(format!("comparing a '{kind_a}' run against a '{kind_b}' run"));
    }
    let fp_a = req_str(a, "config_fingerprint")?;
    let fp_b = req_str(b, "config_fingerprint")?;
    if fp_a != fp_b {
        warnings.push(format!(
            "configs differ (fingerprint {fp_a} vs {fp_b}): \
             deltas mix config and model effects"
        ));
    }

    // phase decomposition from the integer-ms attribution fields
    let phases = match (a.opt("attribution"), b.opt("attribution")) {
        (Some(at_a), Some(at_b)) => {
            let mut rows = Vec::with_capacity(PHASES.len());
            for &p in &PHASES {
                rows.push(PhaseDelta {
                    phase: p,
                    a_ms: req_u64(at_a, &format!("{p}_ms"))?,
                    b_ms: req_u64(at_b, &format!("{p}_ms"))?,
                });
            }
            rows
        }
        _ => {
            warnings.push(
                "attribution missing in at least one snapshot; \
                 phase decomposition skipped"
                    .to_string(),
            );
            Vec::new()
        }
    };

    // first critical-path divergence point
    let path_a = path_of(a);
    let path_b = path_of(b);
    let mut divergence = None;
    for i in 0..path_a.len().max(path_b.len()) {
        let ta = path_a.get(i);
        let tb = path_b.get(i);
        if let (Some(x), Some(y)) = (ta, tb) {
            if x.0 == y.0 {
                continue;
            }
        }
        divergence = Some(Divergence {
            index: i,
            a_task: ta.map(|t| t.0),
            a_type: ta.map(|t| t.1.clone()).unwrap_or_default(),
            b_task: tb.map(|t| t.0),
            b_type: tb.map(|t| t.1.clone()).unwrap_or_default(),
        });
        break;
    }

    // counter finals (changed / added / removed only)
    let ca = num_map(a, "counters");
    let cb = num_map(b, "counters");
    let names: BTreeSet<String> = ca.keys().chain(cb.keys()).cloned().collect();
    let mut counters = Vec::new();
    for name in &names {
        let (in_a, in_b) = (ca.contains_key(name), cb.contains_key(name));
        let va = ca.get(name).copied().unwrap_or(0.0) as u64;
        let vb = cb.get(name).copied().unwrap_or(0.0) as u64;
        if va != vb || in_a != in_b {
            counters.push(CounterDelta {
                name: name.clone(),
                a: va,
                b: vb,
                in_a,
                in_b,
            });
        }
    }

    // gauge finals (changed only; exact compare — same-seed runs agree
    // bit-for-bit, so any difference is real)
    let ga = num_map(a, "gauges");
    let gb = num_map(b, "gauges");
    let names: BTreeSet<String> = ga.keys().chain(gb.keys()).cloned().collect();
    let mut gauges = Vec::new();
    for name in &names {
        let va = ga.get(name).copied().unwrap_or(0.0);
        let vb = gb.get(name).copied().unwrap_or(0.0);
        if va != vb {
            gauges.push(GaugeDelta {
                name: name.clone(),
                a: va,
                b: vb,
            });
        }
    }

    // alert lifecycles (changed / added / removed only)
    let aa = alert_map(a);
    let ab = alert_map(b);
    let names: BTreeSet<String> = aa.keys().chain(ab.keys()).cloned().collect();
    let mut alerts = Vec::new();
    for name in &names {
        let (in_a, in_b) = (aa.contains_key(name), ab.contains_key(name));
        let va = aa.get(name).cloned().unwrap_or((0, 0, 0, String::new()));
        let vb = ab.get(name).cloned().unwrap_or((0, 0, 0, String::new()));
        if va != vb || in_a != in_b {
            alerts.push(AlertDelta {
                name: name.clone(),
                in_a,
                in_b,
                fired_a: va.0,
                fired_b: vb.0,
                firing_ms_a: va.1,
                firing_ms_b: vb.1,
                episodes_a: va.2,
                episodes_b: vb.2,
                state_a: va.3,
                state_b: vb.3,
            });
        }
    }

    // per-tenant SLO rows (fleet snapshots; changed only)
    let ta = tenant_map(a);
    let tb = tenant_map(b);
    let ids: BTreeSet<u64> = ta.keys().chain(tb.keys()).copied().collect();
    let mut tenants = Vec::new();
    for id in ids {
        let empty = Json::Null;
        let ra = ta.get(&id).copied().unwrap_or(&empty);
        let rb = tb.get(&id).copied().unwrap_or(&empty);
        let row = TenantDelta {
            tenant: id,
            instances_a: field_f64(ra, "instances") as u64,
            instances_b: field_f64(rb, "instances") as u64,
            queue_delay_mean_s_a: field_f64(ra, "queue_delay_mean_s"),
            queue_delay_mean_s_b: field_f64(rb, "queue_delay_mean_s"),
            makespan_mean_s_a: field_f64(ra, "makespan_mean_s"),
            makespan_mean_s_b: field_f64(rb, "makespan_mean_s"),
            slowdown_p99_a: field_f64(ra, "slowdown_p99"),
            slowdown_p99_b: field_f64(rb, "slowdown_p99"),
        };
        let changed = row.instances_a != row.instances_b
            || row.queue_delay_mean_s_a != row.queue_delay_mean_s_b
            || row.makespan_mean_s_a != row.makespan_mean_s_b
            || row.slowdown_p99_a != row.slowdown_p99_b;
        if changed {
            tenants.push(row);
        }
    }

    // population-wide phase tails (changed only)
    let rows_of = |j: &Json| -> BTreeMap<String, (f64, f64)> {
        let mut out = BTreeMap::new();
        if let Some(rows) = j.opt("phases").and_then(|p| p.as_arr().ok()) {
            for r in rows {
                if let Some(name) = r.opt("phase").and_then(|p| p.as_str().ok()) {
                    out.insert(
                        name.to_string(),
                        (field_f64(r, "mean_ms"), field_f64(r, "p95_ms")),
                    );
                }
            }
        }
        out
    };
    let pa = rows_of(a);
    let pb = rows_of(b);
    let names: BTreeSet<String> = pa.keys().chain(pb.keys()).cloned().collect();
    let mut phase_tails = Vec::new();
    for name in &names {
        let va = pa.get(name).copied().unwrap_or((0.0, 0.0));
        let vb = pb.get(name).copied().unwrap_or((0.0, 0.0));
        if va != vb {
            phase_tails.push(PhaseTailDelta {
                phase: name.clone(),
                mean_a_ms: va.0,
                mean_b_ms: vb.0,
                p95_a_ms: va.1,
                p95_b_ms: vb.1,
            });
        }
    }

    Ok(SnapshotDiff {
        model_a: req_str(a, "model")?,
        model_b: req_str(b, "model")?,
        seed_a: req_u64(a, "seed")?,
        seed_b: req_u64(b, "seed")?,
        makespan_a_ms: req_u64(a, "makespan_ms")?,
        makespan_b_ms: req_u64(b, "makespan_ms")?,
        phases,
        path_len_a: path_a.len(),
        path_len_b: path_b.len(),
        divergence,
        counters,
        gauges,
        alerts,
        tenants,
        phase_tails,
        warnings,
    })
}

// ---------------------------------------------------------------------
// bench regression gate
// ---------------------------------------------------------------------

/// Per-metric relative tolerances for the bench gate, parsed from
/// `baselines/tolerances.json`: `{"default": 0.0, "ms_per_iter": 0.30}`.
/// The lookup key is the metric's *leaf* key name (`models[2].ms_per_iter`
/// → `ms_per_iter`), so one entry covers a metric across every model and
/// sweep point. Protocol: simulation-deterministic metrics keep the
/// exact default, wall-clock metrics get explicit slack.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct Tolerances {
    /// Applied to every metric without an entry; `0.0` = exact match.
    pub default_rel: f64,
    pub per_metric: BTreeMap<String, f64>,
}

impl Tolerances {
    pub fn parse(j: &Json) -> Result<Tolerances, String> {
        let obj = j
            .as_obj()
            .map_err(|_| "tolerance file must be a JSON object".to_string())?;
        let mut t = Tolerances::default();
        for (key, v) in obj {
            let rel = v
                .as_f64()
                .map_err(|_| format!("tolerance '{key}' must be a number"))?;
            if !rel.is_finite() || rel < 0.0 {
                return Err(format!("tolerance '{key}' must be >= 0, got {rel}"));
            }
            if key == "default" {
                t.default_rel = rel;
            } else {
                t.per_metric.insert(key.clone(), rel);
            }
        }
        Ok(t)
    }

    pub fn for_key(&self, key: &str) -> f64 {
        self.per_metric.get(key).copied().unwrap_or(self.default_rel)
    }
}

/// One metric outside its tolerance band.
#[derive(Debug, Clone, PartialEq)]
pub struct BenchBreach {
    /// Dotted leaf path, e.g. `models[1].events_per_sec`.
    pub path: String,
    pub base: f64,
    pub cur: f64,
    /// Relative change `|cur − base| / max(|base|, ε)`.
    pub rel: f64,
    pub tol: f64,
}

/// Outcome of one baseline-vs-current comparison.
#[derive(Debug, Clone, PartialEq)]
pub enum BenchOutcome {
    /// Gate disarmed (placeholder baseline) — CI passes with a notice.
    Skipped(String),
    Compared {
        /// Numeric leaves compared.
        checked: usize,
        breaches: Vec<BenchBreach>,
        /// Structural drift (added/removed fields, length mismatches) —
        /// reported but non-fatal, so bench schema growth does not brick
        /// the gate.
        warnings: Vec<String>,
    },
}

impl BenchOutcome {
    /// True iff CI must fail.
    pub fn breached(&self) -> bool {
        matches!(self, BenchOutcome::Compared { breaches, .. } if !breaches.is_empty())
    }
}

/// Compare a current `BENCH_*.json` against its committed baseline.
pub fn compare_bench(base: &Json, cur: &Json, tol: &Tolerances) -> BenchOutcome {
    for (doc, which) in [(base, "baseline"), (cur, "current")] {
        if doc.opt("placeholder").and_then(|p| p.as_bool().ok()) == Some(true) {
            return BenchOutcome::Skipped(format!(
                "{which} document is a placeholder (never measured); \
                 gate disarmed until baselines/refresh.sh runs on a real toolchain"
            ));
        }
    }
    let mut w = Walk {
        checked: 0,
        breaches: Vec::new(),
        warnings: Vec::new(),
        tol,
    };
    w.walk(base, cur, "", "");
    BenchOutcome::Compared {
        checked: w.checked,
        breaches: w.breaches,
        warnings: w.warnings,
    }
}

struct Walk<'a> {
    checked: usize,
    breaches: Vec<BenchBreach>,
    warnings: Vec<String>,
    tol: &'a Tolerances,
}

impl Walk<'_> {
    /// Recursive leaf-wise comparison. `key` is the nearest object key —
    /// array elements inherit it, so `points[3].makespan_s` resolves the
    /// `makespan_s` tolerance.
    fn walk(&mut self, base: &Json, cur: &Json, path: &str, key: &str) {
        match (base, cur) {
            (Json::Obj(ob), Json::Obj(oc)) => {
                let keys: BTreeSet<&String> = ob.keys().chain(oc.keys()).collect();
                for k in keys {
                    // provenance, not performance: the meta block differs
                    // between any two commits by construction
                    if k == "meta" {
                        continue;
                    }
                    let p = if path.is_empty() {
                        k.to_string()
                    } else {
                        format!("{path}.{k}")
                    };
                    match (ob.get(k), oc.get(k)) {
                        (Some(b), Some(c)) => self.walk(b, c, &p, k),
                        (Some(_), None) => {
                            self.warnings.push(format!("{p}: in baseline only"));
                        }
                        (None, Some(_)) => {
                            self.warnings.push(format!("{p}: in current only"));
                        }
                        (None, None) => unreachable!("key from union"),
                    }
                }
            }
            (Json::Arr(ab), Json::Arr(ac)) => {
                if ab.len() != ac.len() {
                    self.warnings.push(format!(
                        "{path}: length {} vs {}",
                        ab.len(),
                        ac.len()
                    ));
                }
                for (i, (b, c)) in ab.iter().zip(ac).enumerate() {
                    self.walk(b, c, &format!("{path}[{i}]"), key);
                }
            }
            (Json::Num(nb), Json::Num(nc)) => {
                self.checked += 1;
                let tol = self.tol.for_key(key);
                let rel = if nb == nc {
                    0.0
                } else {
                    (nc - nb).abs() / nb.abs().max(1e-12)
                };
                if rel > tol + 1e-12 {
                    self.breaches.push(BenchBreach {
                        path: path.to_string(),
                        base: *nb,
                        cur: *nc,
                        rel,
                        tol,
                    });
                }
            }
            (b, c) => {
                if b != c {
                    self.warnings.push(format!("{path}: value mismatch"));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn snap(makespan: u64, compute: u64, pods: u64) -> Json {
        // minimal but schema-complete snapshot: all phases zero except
        // compute + queueing, telescoping to `makespan`
        Json::parse(&format!(
            r#"{{
              "schema_version": 1, "kind": "run", "model": "m",
              "seed": 7, "nodes": 4, "config_fingerprint": "f",
              "makespan_ms": {makespan},
              "attribution": {{
                "queueing_ms": {q}, "scheduling_ms": 0, "pod_start_ms": 0,
                "stage_in_ms": 0, "compute_ms": {compute},
                "stage_out_ms": 0, "recovery_ms": 0, "makespan_ms": {makespan}
              }},
              "critical_path": [{{"task": 0, "type": "mProject"}},
                                {{"task": 2, "type": "mAdd"}}],
              "phases": [],
              "counters": {{"pods_created": {pods}}},
              "gauges": {{}},
              "monitor": null
            }}"#,
            q = makespan - compute,
        ))
        .unwrap()
    }

    #[test]
    fn self_diff_is_zero() {
        let a = snap(10_000, 8_000, 16);
        let d = diff(&a, &a).unwrap();
        assert!(d.is_zero());
        assert_eq!(d.makespan_delta_ms(), 0);
        assert_eq!(d.phase_delta_sum_ms(), 0);
        assert!(d.divergence.is_none());
        assert!(d.counters.is_empty() && d.gauges.is_empty());
        assert!(d.to_json().get("zero").unwrap().as_bool().unwrap());
    }

    #[test]
    fn phase_deltas_telescope_to_the_makespan_delta() {
        let a = snap(10_000, 8_000, 16);
        let b = snap(13_500, 9_000, 40);
        let d = diff(&a, &b).unwrap();
        assert!(!d.is_zero());
        assert_eq!(d.makespan_delta_ms(), 3_500);
        assert_eq!(d.phase_delta_sum_ms(), 3_500, "exact, integer ms");
        assert_eq!(d.counters.len(), 1);
        assert_eq!(d.counters[0].delta(), 24);
    }

    #[test]
    fn divergence_finds_the_first_mismatch() {
        let a = snap(10_000, 8_000, 16);
        let mut b = snap(10_000, 8_000, 16);
        if let Json::Obj(o) = &mut b {
            o.insert(
                "critical_path".into(),
                Json::parse(r#"[{"task": 0, "type": "mProject"}, {"task": 5, "type": "mDiffFit"}]"#)
                    .unwrap(),
            );
        }
        let d = diff(&a, &b).unwrap();
        let div = d.divergence.expect("paths differ at index 1");
        assert_eq!(div.index, 1);
        assert_eq!(div.a_task, Some(2));
        assert_eq!(div.b_task, Some(5));
        assert_eq!(div.b_type, "mDiffFit");
        assert!(!d.is_zero());
    }

    #[test]
    fn shorter_path_diverges_at_its_end() {
        let a = snap(10_000, 8_000, 16);
        let mut b = snap(10_000, 8_000, 16);
        if let Json::Obj(o) = &mut b {
            o.insert(
                "critical_path".into(),
                Json::parse(r#"[{"task": 0, "type": "mProject"}]"#).unwrap(),
            );
        }
        let d = diff(&a, &b).unwrap();
        let div = d.divergence.expect("length mismatch is a divergence");
        assert_eq!(div.index, 1);
        assert_eq!(div.b_task, None);
    }

    #[test]
    fn provenance_mismatches_warn_but_do_not_fail() {
        let a = snap(10_000, 8_000, 16);
        let mut b = snap(10_000, 8_000, 16);
        if let Json::Obj(o) = &mut b {
            o.insert("config_fingerprint".into(), Json::str("other"));
            o.insert("schema_version".into(), Json::from(99u64));
        }
        let d = diff(&a, &b).unwrap();
        assert_eq!(d.warnings.len(), 2);
        assert!(d.is_zero(), "warnings never make a diff non-zero");
    }

    #[test]
    fn non_snapshot_documents_error() {
        let junk = Json::parse(r#"{"bench": "driver"}"#).unwrap();
        assert!(diff(&junk, &junk).is_err());
    }

    fn bench_doc(eps: f64, iter_ms: f64) -> Json {
        Json::parse(&format!(
            r#"{{"bench": "coordinator_hotpath", "schema_version": 1,
                 "meta": {{"git": "abc", "model": "all", "seed": 42}},
                 "models": [{{"model": "job", "events_per_sec": {eps},
                              "ms_per_iter": {iter_ms}}}]}}"#
        ))
        .unwrap()
    }

    #[test]
    fn bench_gate_flags_out_of_tolerance_metrics() {
        let tol = Tolerances::parse(
            &Json::parse(r#"{"default": 0.0, "ms_per_iter": 0.5}"#).unwrap(),
        )
        .unwrap();
        // within tolerance: ms_per_iter +40% < 50%, events identical
        let ok = compare_bench(&bench_doc(1e6, 100.0), &bench_doc(1e6, 140.0), &tol);
        assert!(!ok.breached());
        // breach: events_per_sec has the exact default, any drift fails
        let bad = compare_bench(&bench_doc(1e6, 100.0), &bench_doc(9e5, 100.0), &tol);
        assert!(bad.breached());
        let BenchOutcome::Compared { breaches, checked, .. } = bad else {
            panic!("not skipped");
        };
        assert!(checked >= 3);
        assert_eq!(breaches.len(), 1);
        assert_eq!(breaches[0].path, "models[0].events_per_sec");
        assert!((breaches[0].rel - 0.1).abs() < 1e-9);
    }

    #[test]
    fn bench_gate_skips_placeholders_and_ignores_meta() {
        let tol = Tolerances::default();
        let placeholder =
            Json::parse(r#"{"bench": "driver", "placeholder": true}"#).unwrap();
        let real = bench_doc(1e6, 100.0);
        assert!(matches!(
            compare_bench(&placeholder, &real, &tol),
            BenchOutcome::Skipped(_)
        ));
        // differing git hashes under meta must not trip the exact default
        let mut other = bench_doc(1e6, 100.0);
        if let Json::Obj(o) = &mut other {
            o.insert(
                "meta".into(),
                Json::parse(r#"{"git": "def-dirty", "model": "all", "seed": 42}"#).unwrap(),
            );
        }
        assert!(!compare_bench(&bench_doc(1e6, 100.0), &other, &tol).breached());
    }

    #[test]
    fn tolerances_reject_negative_and_non_numeric() {
        assert!(Tolerances::parse(&Json::parse(r#"{"x": -0.1}"#).unwrap()).is_err());
        assert!(Tolerances::parse(&Json::parse(r#"{"x": "lots"}"#).unwrap()).is_err());
        let t = Tolerances::parse(&Json::parse(r#"{"default": 0.2, "y": 0.5}"#).unwrap())
            .unwrap();
        assert_eq!(t.for_key("y"), 0.5);
        assert_eq!(t.for_key("unlisted"), 0.2);
    }
}
