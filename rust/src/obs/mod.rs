//! Flight recorder: structured span/event tracing for the execution
//! kernel.
//!
//! The paper's evaluation pipeline (§3.5) is built on observation —
//! Prometheus + Metrics Server feed the autoscaler and produce the
//! utilization figures — and its headline claim (worker pools cut
//! makespan ~20%) is an attribution statement about *where cluster time
//! goes*. This module gives the simulator the same depth of
//! instrumentation:
//!
//! * a [`FlightRecorder`] owned by the execution kernel
//!   (`exec::Kernel::obs`, an `Option` exactly like the chaos/data/fleet
//!   hooks: `None` — the default — records nothing and costs one branch
//!   per site), capturing per-task lifecycle spans
//!   (ready → dispatch → bind → pod-start → stage-in → compute →
//!   stage-out → done, plus retry / kill / speculative attempts) and
//!   instant events from every control-plane actor (scheduler binds and
//!   rejection reasons, autoscaler decisions with trigger backlog, chaos
//!   injections/remediations, data flows with achieved bandwidth, broker
//!   lane dequeues, fleet admissions, isolation quota throttles);
//! * a critical-path extractor + makespan attribution report
//!   ([`critpath`]) that decomposes the makespan into
//!   queueing / scheduling / pod-start / stage-in / compute / stage-out /
//!   recovery-wasted seconds, telescoping exactly (integer milliseconds)
//!   so the phases always sum to the makespan;
//! * a Prometheus/OpenMetrics text exposition of the metrics registry
//!   ([`prom`]);
//! * an active monitoring stack ([`monitor`]): a deterministic
//!   fixed-interval scrape loop feeding PromQL-lite recording rules
//!   ([`rules`]) and Prometheus-style alert lifecycles ([`alerts`]),
//!   including multi-window SLO burn-rate alerts.
//!
//! **Determinism contract:** recording draws no random numbers and
//! schedules no calendar events — it only *observes* state the kernel
//! already computes. With the recorder attached the simulated trace is
//! bit-identical to a run without it; only the exported artifacts differ
//! (`tests/obs.rs` pins this).

pub mod alerts;
pub mod critpath;
pub mod diff;
pub mod monitor;
pub mod prom;
pub mod rules;
pub mod snapshot;

use crate::k8s::pod::PodId;
use crate::sim::SimTime;
use crate::util::json::Json;
use crate::workflow::task::TaskId;

/// Parsed `--obs` CLI spec: which artifacts to export.
/// `trace:<file>` — extended Chrome/Perfetto trace JSON;
/// `prom:<file>` — Prometheus text exposition of all counters/gauges;
/// `crit:on|off` — print the critical-path attribution report.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ObsSpec {
    pub trace_out: Option<String>,
    pub prom_out: Option<String>,
    pub crit: bool,
}

impl ObsSpec {
    /// Parse `trace:out.json,prom:out.txt,crit:on`. Every entry is
    /// optional; an empty spec still enables recording (the attribution
    /// lands in `--json`/`--html` output).
    pub fn parse_spec(spec: &str) -> Result<ObsSpec, String> {
        let mut out = ObsSpec::default();
        for part in spec.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            match part.split_once(':') {
                Some(("trace", path)) if !path.is_empty() => {
                    out.trace_out = Some(path.to_string());
                }
                Some(("prom", path)) if !path.is_empty() => {
                    out.prom_out = Some(path.to_string());
                }
                Some(("crit", v)) => match v {
                    "on" => out.crit = true,
                    "off" => out.crit = false,
                    other => {
                        return Err(format!("--obs crit must be on|off, got '{other}'"));
                    }
                },
                _ => {
                    return Err(format!(
                        "unknown --obs entry '{part}' \
                         (expected trace:<file>, prom:<file>, crit:on|off)"
                    ));
                }
            }
        }
        Ok(out)
    }
}

/// Control-plane actor an instant event is attributed to (one Perfetto
/// "thread" per actor in the exported trace).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Actor {
    Scheduler,
    Autoscaler,
    Broker,
    Chaos,
    Data,
    Fleet,
}

impl Actor {
    pub fn name(self) -> &'static str {
        match self {
            Actor::Scheduler => "scheduler",
            Actor::Autoscaler => "autoscaler",
            Actor::Broker => "broker",
            Actor::Chaos => "chaos",
            Actor::Data => "data-plane",
            Actor::Fleet => "fleet",
        }
    }

    /// Stable Chrome-trace thread id for this actor's lane.
    pub fn tid(self) -> u64 {
        match self {
            Actor::Scheduler => 1,
            Actor::Autoscaler => 2,
            Actor::Broker => 3,
            Actor::Chaos => 4,
            Actor::Data => 5,
            Actor::Fleet => 6,
        }
    }

    pub const ALL: [Actor; 6] = [
        Actor::Scheduler,
        Actor::Autoscaler,
        Actor::Broker,
        Actor::Chaos,
        Actor::Data,
        Actor::Fleet,
    ];
}

/// One control-plane instant event.
#[derive(Debug, Clone)]
pub struct ObsEvent {
    pub at: SimTime,
    pub actor: Actor,
    /// Static event kind ("bind", "backoff", "scale_up", "fault", ...).
    pub kind: &'static str,
    /// Free-form detail (pod/node/pool/tenant identity).
    pub detail: String,
    /// Primary magnitude (backlog, replicas, Gbit/s, seconds — per kind).
    pub value: f64,
}

/// Recorded lifecycle span of one task (the *winning* attempt's
/// timestamps; failed/speculative attempts accrue into `recovery_ms`).
///
/// Timestamp chain, monotone by construction:
/// `ready ≤ pod_created (A) ≤ bound (B) ≤ running (C) ≤ exec_start (E) ≤
/// compute_end (F) ≤ finished`. For worker-pool tasks the worker pod
/// long predates the task, so A = B = C = the broker dispatch time and
/// the scheduling/pod-start phases are attributed to the pool's elastic
/// capacity (queueing) instead — exactly the asymmetry the paper's §4
/// comparison measures.
#[derive(Debug, Clone)]
pub struct TaskSpan {
    pub ready: Option<SimTime>,
    /// Pod of the attempt that completed the task.
    pub pod: Option<PodId>,
    /// A — winning pod created (job models) / task dispatched (pools).
    pub pod_created: SimTime,
    /// B — winning pod bound by the scheduler.
    pub bound: SimTime,
    /// C — winning pod running (container started).
    pub running: SimTime,
    /// E — compute began (stage-in, if any, completed).
    pub exec_start: SimTime,
    /// F — compute finished.
    pub compute_end: SimTime,
    /// Task fully done: output staged out, readiness propagated.
    pub finished: Option<SimTime>,
    /// Execution milliseconds consumed by failed / losing attempts.
    pub recovery_ms: u64,
    /// Dispatch attempts (1 = clean first-try execution).
    pub attempts: u32,
}

impl TaskSpan {
    fn empty() -> Self {
        TaskSpan {
            ready: None,
            pod: None,
            pod_created: SimTime::ZERO,
            bound: SimTime::ZERO,
            running: SimTime::ZERO,
            exec_start: SimTime::ZERO,
            compute_end: SimTime::ZERO,
            finished: None,
            recovery_ms: 0,
            attempts: 0,
        }
    }
}

/// In-flight attempt tracked per pod (a pod executes one task at a time
/// in every model, so one slot per pod suffices — speculative copies run
/// in *different* pods).
#[derive(Debug, Clone)]
struct PodCur {
    task: Option<TaskId>,
    dispatch: SimTime,
    exec_start: Option<SimTime>,
}

impl PodCur {
    fn empty() -> Self {
        PodCur {
            task: None,
            dispatch: SimTime::ZERO,
            exec_start: None,
        }
    }
}

/// The recorder. Owned by the kernel as `Option<FlightRecorder>`; every
/// call site is `if let Some(o) = k.obs.as_mut() { ... }`, so a disabled
/// run pays one branch and touches no memory.
#[derive(Debug)]
pub struct FlightRecorder {
    spans: Vec<TaskSpan>,
    pods: Vec<PodCur>,
    pub events: Vec<ObsEvent>,
}

impl FlightRecorder {
    pub fn new(n_tasks: usize) -> Self {
        FlightRecorder {
            spans: vec![TaskSpan::empty(); n_tasks],
            pods: Vec::new(),
            events: Vec::new(),
        }
    }

    fn span_mut(&mut self, t: TaskId) -> &mut TaskSpan {
        let i = t.0 as usize;
        if i >= self.spans.len() {
            self.spans.resize(i + 1, TaskSpan::empty());
        }
        &mut self.spans[i]
    }

    fn pod_mut(&mut self, p: PodId) -> &mut PodCur {
        let i = p.0 as usize;
        if i >= self.pods.len() {
            self.pods.resize(i + 1, PodCur::empty());
        }
        &mut self.pods[i]
    }

    pub fn spans(&self) -> &[TaskSpan] {
        &self.spans
    }

    pub fn span(&self, t: TaskId) -> Option<&TaskSpan> {
        self.spans.get(t.0 as usize)
    }

    /// Task became ready (dependencies satisfied / instance admitted).
    pub fn ready(&mut self, t: TaskId, now: SimTime) {
        let s = self.span_mut(t);
        if s.ready.is_none() {
            s.ready = Some(now);
        }
    }

    /// An attempt of `t` was handed to pod `p` (job pod reached its
    /// payload, or a pool worker fetched the message).
    pub fn dispatch(&mut self, p: PodId, t: TaskId, now: SimTime) {
        *self.pod_mut(p) = PodCur {
            task: Some(t),
            dispatch: now,
            exec_start: None,
        };
        self.span_mut(t).attempts += 1;
    }

    /// Compute began for the attempt running in pod `p`. A start with no
    /// prior dispatch (paths that hand work to a pod without a broker /
    /// payload step) implicitly opens the attempt at `now`.
    pub fn exec_start(&mut self, p: PodId, t: TaskId, now: SimTime) {
        let cur = self.pod_mut(p);
        if cur.task == Some(t) {
            cur.exec_start = Some(now);
        } else {
            *cur = PodCur {
                task: Some(t),
                dispatch: now,
                exec_start: Some(now),
            };
        }
    }

    /// Dispatch time of the attempt currently in pod `p` (`now` fallback
    /// for pods the recorder never saw a dispatch for).
    pub fn dispatch_of(&self, p: PodId, now: SimTime) -> SimTime {
        self.pods
            .get(p.0 as usize)
            .filter(|c| c.task.is_some())
            .map(|c| c.dispatch)
            .unwrap_or(now)
    }

    /// The attempt in pod `p` was killed (chaos fault, drain, takeover,
    /// speculative loss): its execution time so far is recovery waste.
    pub fn attempt_lost(&mut self, p: PodId, now: SimTime) {
        let i = p.0 as usize;
        if i >= self.pods.len() {
            return;
        }
        let cur = std::mem::replace(&mut self.pods[i], PodCur::empty());
        if let (Some(t), Some(start)) = (cur.task, cur.exec_start) {
            self.span_mut(t).recovery_ms += now.saturating_sub(start).as_millis();
        }
    }

    /// The attempt in pod `p` completed the task: stamp the winning
    /// attempt's chain. `a`/`b`/`c` are the pod's created/bound/running
    /// times (job models) or the dispatch time three times (pool tasks).
    pub fn complete(
        &mut self,
        p: PodId,
        t: TaskId,
        now: SimTime,
        a: SimTime,
        b: SimTime,
        c: SimTime,
    ) {
        let exec = {
            let cur = self.pod_mut(p);
            let e = if cur.task == Some(t) { cur.exec_start } else { None };
            *cur = PodCur::empty();
            e
        };
        let s = self.span_mut(t);
        s.pod = Some(p);
        s.pod_created = a;
        s.bound = b;
        s.running = c;
        s.exec_start = exec.unwrap_or(c);
        s.compute_end = now;
    }

    /// Task fully finished (stage-out landed, readiness propagated).
    pub fn finished(&mut self, t: TaskId, now: SimTime) {
        let s = self.span_mut(t);
        if s.finished.is_none() {
            s.finished = Some(now);
        }
    }

    /// Record a control-plane instant event.
    pub fn event(
        &mut self,
        at: SimTime,
        actor: Actor,
        kind: &'static str,
        detail: String,
        value: f64,
    ) {
        self.events.push(ObsEvent {
            at,
            actor,
            kind,
            detail,
            value,
        });
    }
}

/// One pod's lifetime, harvested from the kernel's pod table at the end
/// of a run (per-node lanes in the Perfetto export).
#[derive(Debug, Clone)]
pub struct PodRow {
    pub pod: u64,
    pub node: Option<u32>,
    /// Pool name for workers, `None` for job pods.
    pub pool: Option<String>,
    pub created: SimTime,
    pub scheduled: Option<SimTime>,
    pub running: Option<SimTime>,
    pub finished: Option<SimTime>,
}

/// Latency distribution of one lifecycle phase across every finished
/// task (not just the critical path): how long *typical* tasks spent
/// queueing, scheduling, staging, computing. Snapshots carry these rows
/// so `hyperflow diff` can tell a critical-path shift from a
/// distribution-wide one.
#[derive(Debug, Clone, PartialEq)]
pub struct PhaseRow {
    /// Phase name, one of [`critpath::PHASES`].
    pub phase: &'static str,
    /// Finished tasks contributing a sample.
    pub count: u64,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
}

impl PhaseRow {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("phase", self.phase.into()),
            ("count", self.count.into()),
            ("mean_ms", self.mean_ms.into()),
            ("p50_ms", self.p50_ms.into()),
            ("p95_ms", self.p95_ms.into()),
            ("p99_ms", self.p99_ms.into()),
        ])
    }
}

/// Per-phase latency distributions over every *finished* span, one row
/// per phase in [`critpath::PHASES`] order. The same clamped-monotone
/// decomposition as [`critpath::attribute`], but per task relative to
/// its own ready time, so the rows cover the whole population instead of
/// the single makespan-gating chain.
pub fn phase_rows(spans: &[TaskSpan]) -> Vec<PhaseRow> {
    use crate::util::stats::Summary;
    let mut acc: [Summary; 7] = std::array::from_fn(|_| Summary::new());
    for s in spans {
        let (Some(ready), Some(fin)) = (s.ready, s.finished) else {
            continue;
        };
        let fin = fin.as_millis();
        let ready = ready.as_millis().min(fin);
        let clamp = |v: SimTime, lo: u64| v.as_millis().clamp(lo, fin);
        let (a, b, c, e, f) = if s.pod.is_some() {
            let a = clamp(s.pod_created, ready);
            let b = clamp(s.bound, a);
            let c = clamp(s.running, b);
            let e = clamp(s.exec_start, c);
            let f = clamp(s.compute_end, e);
            (a, b, c, e, f)
        } else {
            (fin, fin, fin, fin, fin)
        };
        let recovery = s.recovery_ms.min(a - ready);
        let phases = [
            (a - ready) - recovery,
            b - a,
            c - b,
            e - c,
            f - e,
            fin - f,
            recovery,
        ];
        for (sum, ms) in acc.iter_mut().zip(phases) {
            sum.add(ms as f64);
        }
    }
    critpath::PHASES
        .iter()
        .zip(acc)
        .map(|(&phase, sum)| {
            let row = sum.percentile_row();
            PhaseRow {
                phase,
                count: sum.len() as u64,
                mean_ms: sum.mean(),
                p50_ms: row.p50,
                p95_ms: row.p95,
                p99_ms: row.p99,
            }
        })
        .collect()
}

/// Everything the recorder distills into the run result
/// (`SimResult::obs`, present only when `--obs` / `SimConfig::obs` is
/// set).
#[derive(Debug)]
pub struct ObsReport {
    /// Whole-run critical-path attribution (`None` if no task finished).
    pub attribution: Option<critpath::Attribution>,
    /// The critical path itself, start → end, as task ids.
    pub critical_path: Vec<u32>,
    /// Control-plane instant events, in emission (= time) order.
    pub events: Vec<ObsEvent>,
    /// Pod lifetimes for the per-node Perfetto lanes.
    pub pods: Vec<PodRow>,
    /// Fleet runs: per-instance attribution, aligned with the outcome
    /// vector (`None` for instances that never finished).
    pub instance_attr: Vec<Option<critpath::Attribution>>,
    /// Population-wide per-phase latency distributions ([`phase_rows`]).
    pub phase_rows: Vec<PhaseRow>,
}

impl ObsReport {
    pub fn to_json(&self) -> Json {
        let mut fields: Vec<(&str, Json)> = Vec::new();
        if let Some(a) = &self.attribution {
            fields.push(("attribution", a.to_json()));
        }
        fields.push((
            "critical_path",
            Json::Arr(
                self.critical_path
                    .iter()
                    .map(|&t| Json::from(t as u64))
                    .collect(),
            ),
        ));
        fields.push(("events", Json::from(self.events.len() as u64)));
        if !self.phase_rows.is_empty() {
            fields.push((
                "phases",
                Json::Arr(self.phase_rows.iter().map(|p| p.to_json()).collect()),
            ));
        }
        if !self.instance_attr.is_empty() {
            fields.push((
                "instance_attribution",
                Json::Arr(
                    self.instance_attr
                        .iter()
                        .map(|a| match a {
                            Some(a) => a.to_json(),
                            None => Json::obj(vec![]),
                        })
                        .collect(),
                ),
            ));
        }
        Json::obj(fields)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn obs_spec_parses_all_entries() {
        let s = ObsSpec::parse_spec("trace:out.json,prom:out.txt,crit:on").unwrap();
        assert_eq!(s.trace_out.as_deref(), Some("out.json"));
        assert_eq!(s.prom_out.as_deref(), Some("out.txt"));
        assert!(s.crit);
        let s = ObsSpec::parse_spec("crit:off").unwrap();
        assert!(!s.crit);
        assert_eq!(s.trace_out, None);
        assert_eq!(ObsSpec::parse_spec("").unwrap(), ObsSpec::default());
        assert!(ObsSpec::parse_spec("bogus:1").is_err());
        assert!(ObsSpec::parse_spec("crit:maybe").is_err());
        assert!(ObsSpec::parse_spec("trace:").is_err(), "empty path");
    }

    #[test]
    fn recorder_tracks_a_clean_attempt() {
        let mut r = FlightRecorder::new(2);
        let t = TaskId(1);
        let p = PodId(7);
        r.ready(t, SimTime(100));
        r.dispatch(p, t, SimTime(500));
        r.exec_start(p, t, SimTime(600));
        r.complete(p, t, SimTime(1_600), SimTime(200), SimTime(300), SimTime(500));
        r.finished(t, SimTime(1_700));
        let s = r.span(t).unwrap();
        assert_eq!(s.ready, Some(SimTime(100)));
        assert_eq!(s.pod, Some(p));
        assert_eq!(s.pod_created, SimTime(200));
        assert_eq!(s.bound, SimTime(300));
        assert_eq!(s.running, SimTime(500));
        assert_eq!(s.exec_start, SimTime(600));
        assert_eq!(s.compute_end, SimTime(1_600));
        assert_eq!(s.finished, Some(SimTime(1_700)));
        assert_eq!(s.attempts, 1);
        assert_eq!(s.recovery_ms, 0);
    }

    #[test]
    fn lost_attempts_accrue_recovery_and_preserve_the_winner() {
        let mut r = FlightRecorder::new(1);
        let t = TaskId(0);
        r.ready(t, SimTime(0));
        // attempt 1 dies 400 ms into compute
        r.dispatch(PodId(1), t, SimTime(100));
        r.exec_start(PodId(1), t, SimTime(200));
        r.attempt_lost(PodId(1), SimTime(600));
        // attempt 2 never reached compute before dying: no waste accrued
        r.dispatch(PodId(2), t, SimTime(700));
        r.attempt_lost(PodId(2), SimTime(800));
        // attempt 3 wins
        r.dispatch(PodId(3), t, SimTime(900));
        r.exec_start(PodId(3), t, SimTime(900));
        r.complete(
            PodId(3),
            t,
            SimTime(1_900),
            SimTime(850),
            SimTime(860),
            SimTime(900),
        );
        r.finished(t, SimTime(1_900));
        let s = r.span(t).unwrap();
        assert_eq!(s.recovery_ms, 400);
        assert_eq!(s.attempts, 3);
        assert_eq!(s.pod, Some(PodId(3)));
        // killing an unknown pod is a no-op, not a panic
        r.attempt_lost(PodId(99), SimTime(2_000));
    }

    #[test]
    fn events_record_in_order() {
        let mut r = FlightRecorder::new(0);
        r.event(SimTime(5), Actor::Scheduler, "bind", "pod 1 -> node 2".into(), 1.0);
        r.event(SimTime(9), Actor::Chaos, "fault", "spot reclaim node 0".into(), 0.0);
        assert_eq!(r.events.len(), 2);
        assert_eq!(r.events[0].actor.name(), "scheduler");
        assert_eq!(r.events[1].kind, "fault");
        assert_ne!(Actor::Scheduler.tid(), Actor::Chaos.tid());
    }
}
