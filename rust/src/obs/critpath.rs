//! Critical-path extraction + makespan attribution.
//!
//! Walk backward from the last-finishing task, at each step following
//! the predecessor that finished last (ties break to the lower task id —
//! deterministic). The resulting chain of tasks covers the makespan
//! end-to-end: each task on the path became ready exactly when its
//! chosen predecessor finished, so the per-task segments
//! `[fin_prev, fin_i]` tile `[base, makespan]` with no gaps.
//!
//! Each segment is decomposed with the span's recorded timestamps
//! (`ready ≤ A ≤ B ≤ C ≤ E ≤ F ≤ fin`, see [`crate::obs::TaskSpan`]):
//!
//! | phase        | window                         | meaning |
//! |--------------|--------------------------------|---------|
//! | queueing     | `A − fin_prev` minus recovery  | waiting for dispatch: broker queue, admission, back-off waits, batch flush |
//! | recovery     | min(wasted, `A − ready`)       | execution time consumed by failed / losing attempts |
//! | scheduling   | `B − A`                        | pod pending → bound (scheduler passes, quota throttles) |
//! | pod-start    | `C − B`                        | container creation overhead (the paper's ~2 s tax on job models) |
//! | stage-in     | `E − C`                        | input transfer (data plane; 0 without it) |
//! | compute      | `F − E`                        | task execution incl. the exec-overhead handshake |
//! | stage-out    | `fin − F`                      | output write-back gating readiness |
//!
//! All arithmetic is in integer milliseconds on clamped-monotone stamps,
//! so the seven phases sum to `makespan − base` *exactly* — the
//! attribution invariant `tests/obs.rs` checks under all four models.

use super::FlightRecorder;
use crate::sim::SimTime;
use crate::util::json::Json;
use crate::workflow::dag::Dag;
use crate::workflow::task::TaskId;

/// Makespan decomposition over the critical path (milliseconds).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct Attribution {
    /// Tasks on the critical path.
    pub path_tasks: u32,
    pub queueing_ms: u64,
    pub scheduling_ms: u64,
    pub pod_start_ms: u64,
    pub stage_in_ms: u64,
    pub compute_ms: u64,
    pub stage_out_ms: u64,
    pub recovery_ms: u64,
}

impl Attribution {
    /// Sum of all phases — equals the attributed span (makespan − base)
    /// by construction.
    pub fn total_ms(&self) -> u64 {
        self.queueing_ms
            + self.scheduling_ms
            + self.pod_start_ms
            + self.stage_in_ms
            + self.compute_ms
            + self.stage_out_ms
            + self.recovery_ms
    }

    /// Milliseconds of one phase, by its snapshot-schema name.
    pub fn phase_ms(&self, phase: &str) -> Option<u64> {
        match phase {
            "queueing" => Some(self.queueing_ms),
            "scheduling" => Some(self.scheduling_ms),
            "pod_start" => Some(self.pod_start_ms),
            "stage_in" => Some(self.stage_in_ms),
            "compute" => Some(self.compute_ms),
            "stage_out" => Some(self.stage_out_ms),
            "recovery" => Some(self.recovery_ms),
            _ => None,
        }
    }

    /// JSON view. Three families of fields:
    /// * legacy float seconds (`*_s`, kept for existing consumers),
    /// * exact integer milliseconds (`*_ms` plus `makespan_ms`, the
    ///   attributed span `makespan − base`) — what `hyperflow diff`
    ///   telescopes on,
    /// * phase fractions of the attributed span (`*_frac`, 0.0 on an
    ///   empty attribution).
    pub fn to_json(&self) -> Json {
        let total = self.total_ms();
        let frac = |ms: u64| {
            if total == 0 {
                0.0
            } else {
                ms as f64 / total as f64
            }
        };
        Json::obj(vec![
            ("path_tasks", (self.path_tasks as u64).into()),
            ("queueing_s", (self.queueing_ms as f64 / 1000.0).into()),
            ("scheduling_s", (self.scheduling_ms as f64 / 1000.0).into()),
            ("pod_start_s", (self.pod_start_ms as f64 / 1000.0).into()),
            ("stage_in_s", (self.stage_in_ms as f64 / 1000.0).into()),
            ("compute_s", (self.compute_ms as f64 / 1000.0).into()),
            ("stage_out_s", (self.stage_out_ms as f64 / 1000.0).into()),
            ("recovery_s", (self.recovery_ms as f64 / 1000.0).into()),
            ("total_s", (total as f64 / 1000.0).into()),
            ("queueing_ms", self.queueing_ms.into()),
            ("scheduling_ms", self.scheduling_ms.into()),
            ("pod_start_ms", self.pod_start_ms.into()),
            ("stage_in_ms", self.stage_in_ms.into()),
            ("compute_ms", self.compute_ms.into()),
            ("stage_out_ms", self.stage_out_ms.into()),
            ("recovery_ms", self.recovery_ms.into()),
            ("makespan_ms", total.into()),
            ("queueing_frac", frac(self.queueing_ms).into()),
            ("scheduling_frac", frac(self.scheduling_ms).into()),
            ("pod_start_frac", frac(self.pod_start_ms).into()),
            ("stage_in_frac", frac(self.stage_in_ms).into()),
            ("compute_frac", frac(self.compute_ms).into()),
            ("stage_out_frac", frac(self.stage_out_ms).into()),
            ("recovery_frac", frac(self.recovery_ms).into()),
        ])
    }

    /// Fixed-width text block (`--obs crit:on` output).
    pub fn render(&self, makespan: SimTime) -> String {
        let total = self.total_ms().max(1) as f64;
        let row = |name: &str, ms: u64| {
            format!(
                "  {name:<12} {:>10.1} s  {:>5.1}%\n",
                ms as f64 / 1000.0,
                ms as f64 * 100.0 / total
            )
        };
        let mut out = format!(
            "critical path: {} tasks, {:.1} s attributed of {:.1} s makespan\n",
            self.path_tasks,
            self.total_ms() as f64 / 1000.0,
            makespan.as_secs_f64()
        );
        out.push_str(&row("queueing", self.queueing_ms));
        out.push_str(&row("scheduling", self.scheduling_ms));
        out.push_str(&row("pod-start", self.pod_start_ms));
        out.push_str(&row("stage-in", self.stage_in_ms));
        out.push_str(&row("compute", self.compute_ms));
        out.push_str(&row("stage-out", self.stage_out_ms));
        out.push_str(&row("recovery", self.recovery_ms));
        out
    }
}

/// Snapshot-schema phase names, in telescoping order. The diff engine
/// and the per-phase percentile rows index phases by these strings.
pub const PHASES: [&str; 7] = [
    "queueing",
    "scheduling",
    "pod_start",
    "stage_in",
    "compute",
    "stage_out",
    "recovery",
];

/// Predecessor lists for every task (the DAG only stores successors).
pub fn predecessors(dag: &Dag) -> Vec<Vec<u32>> {
    let n = dag.len();
    let mut preds: Vec<Vec<u32>> = vec![Vec::new(); n];
    for p in 0..n {
        for s in dag.successors(TaskId(p as u32)) {
            preds[s.0 as usize].push(p as u32);
        }
    }
    preds
}

fn clamp(v: SimTime, lo: u64, hi: u64) -> u64 {
    v.as_millis().clamp(lo, hi)
}

/// Extract the critical path over tasks in `[lo, hi)` and attribute it.
///
/// `base` is the segment start of the path's root: `SimTime::ZERO` for a
/// whole run, the instance's admission time for one fleet instance (so
/// the first segment's queueing covers admission → first dispatch).
/// Returns `None` when no task in range finished.
pub fn attribute(
    rec: &FlightRecorder,
    preds: &[Vec<u32>],
    lo: u32,
    hi: u32,
    base: SimTime,
) -> Option<(Attribution, Vec<u32>)> {
    let spans = rec.spans();
    let fin = |t: u32| -> Option<SimTime> {
        spans.get(t as usize).and_then(|s| s.finished)
    };
    // last-finishing task in range (ties -> lowest id, deterministic)
    let mut last: Option<(u32, SimTime)> = None;
    for t in lo..hi {
        if let Some(f) = fin(t) {
            match last {
                Some((_, bf)) if f <= bf => {}
                _ => last = Some((t, f)),
            }
        }
    }
    let (mut cur, _) = last?;
    // backward walk: predecessor that finished last gates readiness
    let mut path = vec![cur];
    loop {
        let mut best: Option<(u32, SimTime)> = None;
        for &p in preds.get(cur as usize).map(|v| v.as_slice()).unwrap_or(&[]) {
            if !(lo..hi).contains(&p) {
                continue;
            }
            if let Some(f) = fin(p) {
                match best {
                    Some((_, bf)) if f <= bf => {}
                    _ => best = Some((p, f)),
                }
            }
        }
        match best {
            Some((p, _)) => {
                path.push(p);
                cur = p;
            }
            None => break,
        }
    }
    path.reverse();

    let mut attr = Attribution {
        path_tasks: path.len() as u32,
        ..Attribution::default()
    };
    let mut prev_fin = base.as_millis();
    for &t in &path {
        let s = &spans[t as usize];
        // >= prev_fin by the readiness-gating argument above; the max is
        // belt-and-braces so a malformed span cannot underflow
        let fin_ms = s.finished.expect("path tasks finished").as_millis().max(prev_fin);
        // clamp the chain monotone; a span the recorder never completed
        // (cannot happen for a finished task, but stay defensive)
        // degenerates every inner phase to zero
        let ready = clamp(s.ready.unwrap_or(SimTime::ZERO), prev_fin, fin_ms);
        let (a, b, c, e, f) = if s.pod.is_some() {
            let a = clamp(s.pod_created, ready, fin_ms);
            let b = clamp(s.bound, a, fin_ms);
            let c = clamp(s.running, b, fin_ms);
            let e = clamp(s.exec_start, c, fin_ms);
            let f = clamp(s.compute_end, e, fin_ms);
            (a, b, c, e, f)
        } else {
            (fin_ms, fin_ms, fin_ms, fin_ms, fin_ms)
        };
        // recovery happened while the task waited to re-dispatch: it can
        // never exceed the pre-bind window, so queueing stays >= 0 and
        // the segment still telescopes exactly
        let recovery = s.recovery_ms.min(a - prev_fin);
        attr.queueing_ms += (a - prev_fin) - recovery;
        attr.recovery_ms += recovery;
        attr.scheduling_ms += b - a;
        attr.pod_start_ms += c - b;
        attr.stage_in_ms += e - c;
        attr.compute_ms += f - e;
        attr.stage_out_ms += fin_ms - f;
        prev_fin = fin_ms;
    }
    Some((attr, path))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::k8s::pod::PodId;

    /// Hand-built two-task chain: 0 -> 1.
    fn recorder() -> (FlightRecorder, Vec<Vec<u32>>) {
        let mut r = FlightRecorder::new(2);
        // task 0: ready 0, pod created 100, bound 300, running 2300,
        // exec 2300, compute end 10300, finished 10300
        r.ready(TaskId(0), SimTime(0));
        r.dispatch(PodId(0), TaskId(0), SimTime(2_300));
        r.exec_start(PodId(0), TaskId(0), SimTime(2_300));
        r.complete(
            PodId(0),
            TaskId(0),
            SimTime(10_300),
            SimTime(100),
            SimTime(300),
            SimTime(2_300),
        );
        r.finished(TaskId(0), SimTime(10_300));
        // task 1 (pool-style: A=B=C=dispatch): ready at 10300, dispatched
        // 11000, stage-in to 12000, compute to 15000, stage-out to 15500
        r.ready(TaskId(1), SimTime(10_300));
        r.dispatch(PodId(1), TaskId(1), SimTime(11_000));
        r.exec_start(PodId(1), TaskId(1), SimTime(12_000));
        r.complete(
            PodId(1),
            TaskId(1),
            SimTime(15_000),
            SimTime(11_000),
            SimTime(11_000),
            SimTime(11_000),
        );
        r.finished(TaskId(1), SimTime(15_500));
        (r, vec![vec![], vec![0]])
    }

    #[test]
    fn attribution_telescopes_exactly() {
        let (r, preds) = recorder();
        let (attr, path) = attribute(&r, &preds, 0, 2, SimTime::ZERO).unwrap();
        assert_eq!(path, vec![0, 1]);
        assert_eq!(attr.path_tasks, 2);
        // task 0: queue 100, sched 200, pod-start 2000, compute 8000
        // task 1: queue 700, stage-in 1000, compute 3000, stage-out 500
        assert_eq!(attr.queueing_ms, 100 + 700);
        assert_eq!(attr.scheduling_ms, 200);
        assert_eq!(attr.pod_start_ms, 2_000);
        assert_eq!(attr.stage_in_ms, 1_000);
        assert_eq!(attr.compute_ms, 8_000 + 3_000);
        assert_eq!(attr.stage_out_ms, 500);
        assert_eq!(attr.recovery_ms, 0);
        assert_eq!(attr.total_ms(), 15_500, "sums to the last finish");
    }

    #[test]
    fn recovery_is_carved_out_of_queueing() {
        let (mut r, preds) = recorder();
        // a failed attempt of task 1 burned 400 ms before the winner
        r.span_mut(TaskId(1)).recovery_ms = 400;
        let (attr, _) = attribute(&r, &preds, 0, 2, SimTime::ZERO).unwrap();
        assert_eq!(attr.recovery_ms, 400);
        assert_eq!(attr.queueing_ms, 100 + 300);
        assert_eq!(attr.total_ms(), 15_500, "invariant survives recovery");
        // waste beyond the pre-bind window is clamped, not double-counted
        r.span_mut(TaskId(1)).recovery_ms = 10_000;
        let (attr, _) = attribute(&r, &preds, 0, 2, SimTime::ZERO).unwrap();
        assert_eq!(attr.queueing_ms, 100);
        assert_eq!(attr.recovery_ms, 700);
        assert_eq!(attr.total_ms(), 15_500);
    }

    #[test]
    fn range_and_base_select_a_sub_path() {
        let (r, preds) = recorder();
        // instance = task 1 only, admitted at its ready time
        let (attr, path) = attribute(&r, &preds, 1, 2, SimTime(10_300)).unwrap();
        assert_eq!(path, vec![1]);
        assert_eq!(attr.total_ms(), 15_500 - 10_300);
        // empty range
        assert!(attribute(&r, &preds, 2, 2, SimTime::ZERO).is_none());
    }

    #[test]
    fn unfinished_runs_yield_none() {
        let r = FlightRecorder::new(3);
        let preds = vec![vec![], vec![0], vec![1]];
        assert!(attribute(&r, &preds, 0, 3, SimTime::ZERO).is_none());
    }

    #[test]
    fn render_and_json_carry_every_phase() {
        let (r, preds) = recorder();
        let (attr, _) = attribute(&r, &preds, 0, 2, SimTime::ZERO).unwrap();
        let text = attr.render(SimTime(15_500));
        for phase in [
            "queueing", "scheduling", "pod-start", "stage-in", "compute",
            "stage-out", "recovery",
        ] {
            assert!(text.contains(phase), "missing {phase} in:\n{text}");
        }
        let j = attr.to_json().to_string();
        assert!(j.contains("\"total_s\""));
        assert!(j.contains("\"pod_start_s\""));
    }

    #[test]
    fn json_carries_exact_integer_ms_and_fractions() {
        let (r, preds) = recorder();
        let (attr, _) = attribute(&r, &preds, 0, 2, SimTime::ZERO).unwrap();
        let j = attr.to_json();
        assert_eq!(j.get("makespan_ms").unwrap().as_u64().unwrap(), 15_500);
        let mut sum = 0;
        for phase in PHASES {
            let ms = j.get(&format!("{phase}_ms")).unwrap().as_u64().unwrap();
            assert_eq!(Some(ms), attr.phase_ms(phase));
            sum += ms;
            let frac = j.get(&format!("{phase}_frac")).unwrap().as_f64().unwrap();
            assert!((frac - ms as f64 / 15_500.0).abs() < 1e-12);
        }
        assert_eq!(sum, 15_500, "integer phase ms telescope in JSON too");
        // empty attribution: fractions are 0.0, not NaN
        let empty = Attribution::default().to_json();
        assert_eq!(empty.get("compute_frac").unwrap().as_f64().unwrap(), 0.0);
    }

    /// `render()` is consumed as opaque text by scripts and compared in
    /// PR diffs — pin the exact bytes so the `to_json` extension (and
    /// future ones) cannot drift the human-facing report.
    #[test]
    fn render_output_is_byte_stable() {
        let (r, preds) = recorder();
        let (attr, _) = attribute(&r, &preds, 0, 2, SimTime::ZERO).unwrap();
        let expected = concat!(
            "critical path: 2 tasks, 15.5 s attributed of 15.5 s makespan\n",
            "  queueing            0.8 s    5.2%\n",
            "  scheduling          0.2 s    1.3%\n",
            "  pod-start           2.0 s   12.9%\n",
            "  stage-in            1.0 s    6.5%\n",
            "  compute            11.0 s   71.0%\n",
            "  stage-out           0.5 s    3.2%\n",
            "  recovery            0.0 s    0.0%\n",
        );
        assert_eq!(attr.render(SimTime(15_500)), expected);
    }
}
