//! Versioned, deterministic run snapshots (`--snapshot FILE`).
//!
//! A snapshot is the serialized observable surface of one run: makespan,
//! critical-path [`super::critpath::Attribution`], population-wide
//! per-phase percentile rows, the metrics registry's final counter and
//! gauge values, the monitoring stack's alert lifecycles, and (for fleet
//! runs) the per-tenant SLO rows. Two same-seed runs produce
//! **byte-identical** snapshot JSON — the property `tests/diff.rs` pins
//! for all four execution models — which is what makes
//! [`super::diff`] exact: every delta it reports is a real behavioral
//! difference, never serialization noise.
//!
//! Determinism sources: [`crate::util::json::Json`] objects are
//! `BTreeMap`s (sorted keys), the simulation itself is bit-deterministic
//! per seed, and the schema deliberately excludes volatile provenance
//! (git revision, wall-clock stamps — those live in the `BENCH_*.json`
//! meta block instead, see [`crate::util::meta`]).

use crate::exec::SimConfig;
use crate::fleet::report::TenantRow;
use crate::fleet::FleetResult;
use crate::report::SimResult;
use crate::util::json::Json;
use crate::workflow::task::TaskId;

/// Version of the snapshot schema. `hyperflow diff` warns on a version
/// mismatch instead of guessing at missing fields.
pub const SNAPSHOT_SCHEMA_VERSION: u64 = 1;

/// Snapshot of a single-workflow run (`hyperflow run` / `trace`).
pub fn capture(res: &SimResult, cfg: &SimConfig) -> Json {
    Json::Obj(base_fields(res, cfg, "run").into_iter().collect())
}

/// Snapshot of a fleet run (`hyperflow serve`): the single-run surface
/// plus one row per tenant.
pub fn capture_fleet(res: &FleetResult, cfg: &SimConfig) -> Json {
    let mut fields = base_fields(&res.sim, cfg, "fleet");
    let tenants = crate::fleet::report::per_tenant(res)
        .iter()
        .map(tenant_json)
        .collect();
    fields.push(("tenants".to_string(), Json::Arr(tenants)));
    Json::Obj(fields.into_iter().collect())
}

fn base_fields(res: &SimResult, cfg: &SimConfig, kind: &str) -> Vec<(String, Json)> {
    let attribution = match res.obs.as_ref().and_then(|o| o.attribution.as_ref()) {
        Some(a) => a.to_json(),
        None => Json::Null,
    };
    let critical_path = res
        .obs
        .as_ref()
        .map(|o| {
            o.critical_path
                .iter()
                .map(|&t| {
                    let mut entry = vec![("task", Json::from(t as u64))];
                    if let Some(r) = res.trace.record(TaskId(t)) {
                        entry.push(("type", Json::str(res.trace.type_name(r))));
                        if let Some(f) = r.finished_at {
                            entry.push(("finished_ms", f.as_millis().into()));
                        }
                    }
                    Json::obj(entry)
                })
                .collect()
        })
        .unwrap_or_default();
    let phases = res
        .obs
        .as_ref()
        .map(|o| o.phase_rows.iter().map(|p| p.to_json()).collect())
        .unwrap_or_default();
    let counters = Json::Obj(
        res.metrics
            .counters_sorted()
            .map(|(n, v)| (n.to_string(), Json::from(v)))
            .collect(),
    );
    let gauges = Json::Obj(
        res.metrics
            .gauge_names()
            .map(|n| (n.to_string(), Json::from(res.metrics.gauge_value(n))))
            .collect(),
    );
    let monitor = match &res.monitor {
        Some(m) => m.to_json(),
        None => Json::Null,
    };
    vec![
        ("schema_version".to_string(), SNAPSHOT_SCHEMA_VERSION.into()),
        ("kind".to_string(), Json::str(kind)),
        ("model".to_string(), Json::str(&res.model_name)),
        ("seed".to_string(), cfg.seed.into()),
        ("nodes".to_string(), cfg.nodes.into()),
        (
            "config_fingerprint".to_string(),
            Json::str(cfg.fingerprint()),
        ),
        ("makespan_ms".to_string(), res.makespan.as_millis().into()),
        (
            "totals".to_string(),
            Json::obj(vec![
                ("pods_created", res.pods_created.into()),
                ("api_requests", res.api_requests.into()),
                ("sched_backoffs", res.sched_backoffs.into()),
                ("sched_binds", res.sched_binds.into()),
                ("sim_events", res.sim_events.into()),
                ("avg_running_tasks", res.avg_running_tasks.into()),
                ("avg_cpu_utilization", res.avg_cpu_utilization.into()),
            ]),
        ),
        ("attribution".to_string(), attribution),
        ("critical_path".to_string(), Json::Arr(critical_path)),
        ("phases".to_string(), Json::Arr(phases)),
        ("counters".to_string(), counters),
        ("gauges".to_string(), gauges),
        ("monitor".to_string(), monitor),
    ]
}

/// Full (unconditional) JSON row for one tenant — snapshots keep every
/// column so two runs always diff field-by-field, even when one of them
/// ran without the chaos/data/isolation/obs subsystems attached.
fn tenant_json(r: &TenantRow) -> Json {
    Json::obj(vec![
        ("tenant", (r.tenant as u64).into()),
        ("instances", r.instances.into()),
        ("queue_delay_mean_s", r.queue_delay_mean_s.into()),
        ("makespan_mean_s", r.makespan_mean_s.into()),
        ("slowdown_mean", r.slowdown_mean.into()),
        ("slowdown_p50", r.slowdown_p50.into()),
        ("slowdown_p95", r.slowdown_p95.into()),
        ("slowdown_p99", r.slowdown_p99.into()),
        ("wasted_s", r.wasted_s.into()),
        ("retries", r.retries.into()),
        ("gb_moved", r.gb_moved.into()),
        ("quota_throttles", r.quota_throttles.into()),
        ("violations", r.violations.into()),
        ("takeover_exposed_s", r.takeover_exposed_s.into()),
        ("crit_queue_s", r.crit_queue_s.into()),
        ("crit_sched_s", r.crit_sched_s.into()),
        ("crit_pod_start_s", r.crit_pod_start_s.into()),
        ("crit_stage_in_s", r.crit_stage_in_s.into()),
        ("crit_compute_s", r.crit_compute_s.into()),
        ("crit_stage_out_s", r.crit_stage_out_s.into()),
        ("crit_recovery_s", r.crit_recovery_s.into()),
        ("alerts_fired", r.alerts_fired.into()),
        ("alert_firing_s", r.alert_firing_s.into()),
    ])
}
