//! Prometheus / OpenMetrics text exposition of the metrics registry.
//!
//! The paper runs a Prometheus + Metrics Server pipeline (§3.5); this is
//! the simulator-side equivalent: every registered counter and gauge is
//! rendered in the text exposition format, so a run's final metric state
//! can be scraped into the same dashboards the real deployment uses.
//! Wired into `hyperflow serve` and the end-of-run `--obs prom:<file>`
//! dump.
//!
//! Metric names are sanitized into the Prometheus grammar
//! (`[a-zA-Z_:][a-zA-Z0-9_:]*`): every other character becomes `_`, and
//! everything is prefixed `hf_`. Counters get the conventional `_total`
//! suffix. Output order is deterministic (sorted names).

use crate::metrics::Registry;
use std::fmt::Write;

/// Sanitize a registry name ("queue::mProject") into a Prometheus metric
/// name component ("queue_mProject").
fn sanitize(name: &str) -> String {
    let mut out = String::with_capacity(name.len());
    for ch in name.chars() {
        if ch.is_ascii_alphanumeric() || ch == '_' {
            out.push(ch);
        } else if !out.ends_with('_') {
            out.push('_');
        }
    }
    let trimmed = out.trim_matches('_');
    if trimmed.is_empty() {
        "unnamed".to_string()
    } else {
        trimmed.to_string()
    }
}

/// Render the full registry as Prometheus text exposition.
pub fn render(reg: &Registry) -> String {
    let mut out = String::new();
    for (name, value) in reg.counters_sorted() {
        let m = format!("hf_{}_total", sanitize(name));
        let _ = writeln!(out, "# HELP {m} simulator counter '{name}'");
        let _ = writeln!(out, "# TYPE {m} counter");
        let _ = writeln!(out, "{m} {value}");
    }
    // gauge_names() iterates the name index (BTreeMap): sorted, stable
    let gauges: Vec<String> = reg.gauge_names().map(str::to_string).collect();
    for name in gauges {
        let m = format!("hf_{}", sanitize(&name));
        let v = reg.gauge_value(&name);
        let _ = writeln!(out, "# HELP {m} simulator gauge '{name}' (final value)");
        let _ = writeln!(out, "# TYPE {m} gauge");
        let _ = writeln!(out, "{m} {v}");
    }
    out.push_str("# EOF\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::SimTime;

    #[test]
    fn sanitize_maps_into_the_prometheus_grammar() {
        assert_eq!(sanitize("queue::mProject"), "queue_mProject");
        assert_eq!(sanitize("pods_created"), "pods_created");
        assert_eq!(sanitize("running::mDiff-Fit"), "running_mDiff_Fit");
        assert_eq!(sanitize("::"), "unnamed");
    }

    #[test]
    fn exposition_covers_every_counter_and_gauge() {
        let mut r = Registry::new();
        r.inc("pods_created", 3);
        let _ = r.counter_id("sched_binds"); // interned, never incremented
        r.set("queue::mProject", SimTime(1_000), 7.0);
        r.set("running_tasks", SimTime(2_000), 2.5);
        let text = render(&r);
        assert!(text.contains("# TYPE hf_pods_created_total counter"));
        assert!(text.contains("hf_pods_created_total 3"));
        assert!(text.contains("hf_sched_binds_total 0"), "zero counters visible");
        assert!(text.contains("# TYPE hf_queue_mProject gauge"));
        assert!(text.contains("hf_queue_mProject 7"));
        assert!(text.contains("hf_running_tasks 2.5"));
        assert!(text.ends_with("# EOF\n"));
        // every metric line parses as "name value"
        for line in text.lines().filter(|l| !l.starts_with('#')) {
            let mut it = line.split_whitespace();
            let name = it.next().unwrap();
            assert!(name.starts_with("hf_"), "bad metric name {name}");
            assert!(it.next().unwrap().parse::<f64>().is_ok(), "bad value in {line}");
            assert_eq!(it.next(), None);
        }
    }

    #[test]
    fn empty_registry_renders_just_the_terminator() {
        assert_eq!(render(&Registry::new()), "# EOF\n");
    }
}
