//! Prometheus / OpenMetrics text exposition of the metrics registry.
//!
//! The paper runs a Prometheus + Metrics Server pipeline (§3.5); this is
//! the simulator-side equivalent: every registered counter and gauge is
//! rendered in the text exposition format, so a run's final metric state
//! can be scraped into the same dashboards the real deployment uses.
//! Wired into `hyperflow serve` and the end-of-run `--obs prom:<file>`
//! dump.
//!
//! Metric names are sanitized into the Prometheus grammar
//! (`[a-zA-Z_:][a-zA-Z0-9_:]*`): every other character becomes `_`, and
//! everything is prefixed `hf_`. Counters get the conventional `_total`
//! suffix. Output order is deterministic (sorted names).

use crate::metrics::Registry;
use crate::obs::monitor::MonitorReport;
use std::fmt::Write;

/// Sanitize a registry name ("queue::mProject") into a Prometheus metric
/// name component ("queue_mProject").
fn sanitize(name: &str) -> String {
    let mut out = String::with_capacity(name.len());
    for ch in name.chars() {
        if ch.is_ascii_alphanumeric() || ch == '_' {
            out.push(ch);
        } else if !out.ends_with('_') {
            out.push('_');
        }
    }
    let trimmed = out.trim_matches('_');
    if trimmed.is_empty() {
        "unnamed".to_string()
    } else {
        trimmed.to_string()
    }
}

/// Escape a label value per the exposition format (backslash, quote,
/// newline).
fn escape_label(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for ch in v.chars() {
        match ch {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            _ => out.push(ch),
        }
    }
    out
}

/// Render the full registry as Prometheus text exposition.
pub fn render(reg: &Registry) -> String {
    render_with_alerts(reg, None)
}

/// Render the registry plus — when a monitoring report is supplied — the
/// Prometheus-convention `ALERTS{alertname,severity,alertstate}` series
/// (final lifecycle state of every alert rule) and a per-alert
/// `hf_alerts_fired_total` counter, all before the `# EOF` terminator.
pub fn render_with_alerts(reg: &Registry, monitor: Option<&MonitorReport>) -> String {
    let mut out = String::new();
    for (name, value) in reg.counters_sorted() {
        let m = format!("hf_{}_total", sanitize(name));
        let _ = writeln!(out, "# HELP {m} simulator counter '{name}'");
        let _ = writeln!(out, "# TYPE {m} counter");
        let _ = writeln!(out, "{m} {value}");
    }
    // gauge_names() iterates the name index (BTreeMap): sorted, stable
    let gauges: Vec<String> = reg.gauge_names().map(str::to_string).collect();
    for name in gauges {
        let m = format!("hf_{}", sanitize(&name));
        let v = reg.gauge_value(&name);
        let _ = writeln!(out, "# HELP {m} simulator gauge '{name}' (final value)");
        let _ = writeln!(out, "# TYPE {m} gauge");
        let _ = writeln!(out, "{m} {v}");
    }
    if let Some(mon) = monitor {
        if !mon.alerts.is_empty() {
            let _ = writeln!(out, "# HELP ALERTS end-of-run alert rule states");
            let _ = writeln!(out, "# TYPE ALERTS gauge");
            for a in &mon.alerts {
                let _ = writeln!(
                    out,
                    "ALERTS{{alertname=\"{}\",severity=\"{}\",alertstate=\"{}\"}} 1",
                    escape_label(&a.name),
                    escape_label(&a.severity),
                    a.final_state.name(),
                );
            }
            let _ = writeln!(out, "# HELP hf_alerts_fired_total firing episodes per alert rule");
            let _ = writeln!(out, "# TYPE hf_alerts_fired_total counter");
            for a in &mon.alerts {
                let _ = writeln!(
                    out,
                    "hf_alerts_fired_total{{alertname=\"{}\"}} {}",
                    escape_label(&a.name),
                    a.fired,
                );
            }
        }
    }
    out.push_str("# EOF\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::SimTime;

    #[test]
    fn sanitize_maps_into_the_prometheus_grammar() {
        assert_eq!(sanitize("queue::mProject"), "queue_mProject");
        assert_eq!(sanitize("pods_created"), "pods_created");
        assert_eq!(sanitize("running::mDiff-Fit"), "running_mDiff_Fit");
        assert_eq!(sanitize("::"), "unnamed");
        // unicode, whitespace and symbol runs all collapse to single _
        assert_eq!(sanitize("tenant μs/op (p99)"), "tenant_s_op_p99");
        assert_eq!(sanitize("  spaced  name  "), "spaced_name");
        assert_eq!(sanitize("a//b\\c"), "a_b_c");
    }

    #[test]
    fn label_values_are_escaped() {
        assert_eq!(escape_label("plain"), "plain");
        assert_eq!(escape_label("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
    }

    #[test]
    fn alerts_exposition_lists_every_rule_before_eof() {
        use crate::obs::alerts::AlertState;
        use crate::obs::monitor::{AlertReport, MonitorReport};
        let mon = MonitorReport {
            interval_ms: 30_000,
            ticks: 5,
            makespan_ms: 150_000,
            alerts: vec![
                AlertReport {
                    name: "BacklogSaturation".into(),
                    kind: "threshold",
                    severity: "page".into(),
                    tenant: None,
                    expr: "avg_over_time(backlog_total[120s]) > 16".into(),
                    fired: 2,
                    firing_ms: 60_000,
                    final_state: AlertState::Firing,
                    episodes: Vec::new(),
                },
                AlertReport {
                    name: "TaskDisruptionBudget".into(),
                    kind: "burnrate",
                    severity: "page".into(),
                    tenant: None,
                    expr: "burn >= 10 x 0.001".into(),
                    fired: 0,
                    firing_ms: 0,
                    final_state: AlertState::Inactive,
                    episodes: Vec::new(),
                },
            ],
            records: Vec::new(),
        };
        let mut reg = Registry::new();
        reg.inc("pods_created", 1);
        let text = render_with_alerts(&reg, Some(&mon));
        assert!(text.contains(
            "ALERTS{alertname=\"BacklogSaturation\",severity=\"page\",alertstate=\"firing\"} 1"
        ));
        assert!(text.contains(
            "ALERTS{alertname=\"TaskDisruptionBudget\",severity=\"page\",alertstate=\"inactive\"} 1"
        ));
        assert!(text.contains("hf_alerts_fired_total{alertname=\"BacklogSaturation\"} 2"));
        assert!(text.ends_with("# EOF\n"));
        // alert series come after the registry metrics, before EOF
        let alerts_at = text.find("ALERTS{").unwrap();
        assert!(alerts_at > text.find("hf_pods_created_total").unwrap());
        // without a report the output is unchanged from render()
        assert_eq!(render_with_alerts(&reg, None), render(&reg));
    }

    #[test]
    fn exposition_covers_every_counter_and_gauge() {
        let mut r = Registry::new();
        r.inc("pods_created", 3);
        let _ = r.counter_id("sched_binds"); // interned, never incremented
        r.set("queue::mProject", SimTime(1_000), 7.0);
        r.set("running_tasks", SimTime(2_000), 2.5);
        let text = render(&r);
        assert!(text.contains("# TYPE hf_pods_created_total counter"));
        assert!(text.contains("hf_pods_created_total 3"));
        assert!(text.contains("hf_sched_binds_total 0"), "zero counters visible");
        assert!(text.contains("# TYPE hf_queue_mProject gauge"));
        assert!(text.contains("hf_queue_mProject 7"));
        assert!(text.contains("hf_running_tasks 2.5"));
        assert!(text.ends_with("# EOF\n"));
        // every metric line parses as "name value"
        for line in text.lines().filter(|l| !l.starts_with('#')) {
            let mut it = line.split_whitespace();
            let name = it.next().unwrap();
            assert!(name.starts_with("hf_"), "bad metric name {name}");
            assert!(it.next().unwrap().parse::<f64>().is_ok(), "bad value in {line}");
            assert_eq!(it.next(), None);
        }
    }

    #[test]
    fn empty_registry_renders_just_the_terminator() {
        assert_eq!(render(&Registry::new()), "# EOF\n");
    }
}
