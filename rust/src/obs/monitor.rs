//! In-sim monitoring stack: deterministic scrape loop, recording rules,
//! and SLO burn-rate alerting.
//!
//! A [`MonitorState`] rides on the exec kernel as an optional attachment
//! (like chaos / data / fleet): when `--monitor` is off the slot is
//! `None`, no `MonitorTick` calendar events exist, and every golden
//! trace is bit-identical to a build without this module. When on, a
//! fixed-interval RNG-free `Ev::MonitorTick` (scheduled last in
//! `build()`, the same untimed-event pattern as chaos takeovers, so
//! injector fork indices never shift) drives [`MonitorState::scrape`]:
//!
//! 1. sample every registry counter and gauge — plus synthesized series
//!    for backlog, task completions, data-plane cache traffic, quota
//!    throttles, and per-tenant instance age — into the fixed-interval
//!    ring buffers of [`rules::SampleStore`];
//! 2. advance the `ewma()` / `holt_winters()` smoother state;
//! 3. evaluate recording rules in file order, pushing each result back
//!    into the store (later rules and kernel-side consumers can read
//!    them — [`MonitorState::query`] is the forecaster interface the
//!    predictive autoscaler reads, ROADMAP item 5);
//! 4. evaluate threshold alerts and multi-window burn-rate alerts and
//!    advance each alert's inactive→pending→firing→resolved lifecycle.
//!
//! Scraping only *reads* the kernel: it draws no RNG, mutates no
//! simulation state, and schedules nothing but its own next tick — the
//! monitor-on fingerprint differs from monitor-off only by the tick
//! events themselves.

use crate::exec::kernel::Kernel;
use crate::sim::SimTime;
use crate::util::json::Json;

use super::alerts::{AlertRuntime, AlertState, Episode};
use super::rules::{eval, BurnRateRule, RuleSet, SampleStore};

/// Where the rule text comes from: the built-in set (assembled to match
/// the attached subsystems) or an inline ruleset (CLI `rules:FILE`,
/// loaded by the caller).
#[derive(Debug, Clone, PartialEq)]
pub enum RulesSource {
    Builtin,
    Inline(String),
}

/// Monitor attachment config, carried on `SimConfig`.
#[derive(Debug, Clone, PartialEq)]
pub struct MonitorConfig {
    /// Scrape interval in sim milliseconds.
    pub interval_ms: u64,
    pub rules: RulesSource,
    /// `alerts:FILE` output path (CLI convenience; the library report is
    /// always on `SimResult::monitor`).
    pub alerts_out: Option<String>,
}

impl Default for MonitorConfig {
    fn default() -> Self {
        MonitorConfig {
            interval_ms: 30_000,
            rules: RulesSource::Builtin,
            alerts_out: None,
        }
    }
}

impl MonitorConfig {
    /// Parse the `--monitor interval:S,rules:builtin|FILE,alerts:FILE`
    /// CLI spec. `rules:` paths are returned verbatim — the caller loads
    /// the file into [`RulesSource::Inline`] (the library stays
    /// filesystem-free). A bare `--monitor` ("true") takes every
    /// default.
    pub fn parse_spec(spec: &str) -> Result<(MonitorConfig, Option<String>), String> {
        let mut cfg = MonitorConfig::default();
        let mut rules_path = None;
        if spec == "true" || spec.trim().is_empty() {
            return Ok((cfg, rules_path));
        }
        for part in spec.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            match part.split_once(':') {
                Some(("interval", v)) => {
                    let secs: f64 = v
                        .parse()
                        .map_err(|_| format!("--monitor interval must be seconds, got '{v}'"))?;
                    if !(secs > 0.0) {
                        return Err(format!("--monitor interval must be > 0, got '{v}'"));
                    }
                    cfg.interval_ms = (secs * 1000.0).round() as u64;
                }
                Some(("rules", v)) if !v.is_empty() => {
                    if v != "builtin" {
                        rules_path = Some(v.to_string());
                    }
                }
                Some(("alerts", path)) if !path.is_empty() => {
                    cfg.alerts_out = Some(path.to_string());
                }
                _ => {
                    return Err(format!(
                        "unknown --monitor entry '{part}' \
                         (expected interval:<secs>, rules:builtin|<file>, alerts:<file>)"
                    ));
                }
            }
        }
        Ok((cfg, rules_path))
    }
}

// ---------------------------------------------------------------------
// builtin rules
// ---------------------------------------------------------------------

/// Instance age (oldest unfinished admission) at which a tenant counts
/// as slow for the per-tenant burn-rate budget.
const TENANT_SLOW_AGE_S: f64 = 900.0;

/// The built-in ruleset. Subsystem-specific alerts are only emitted when
/// their subsystem is attached — a cache alert on a run with no data
/// plane would fire on the 0/0 idle ratio forever.
pub fn builtin_rules(data_on: bool, isolation_on: bool) -> String {
    let mut t = String::from(
        "# hyperflow builtin monitoring rules\n\
         record backlog_avg = avg_over_time(backlog_total[120s])\n\
         record backlog_ewma = ewma(backlog_total, 0.3)\n\
         record backlog_forecast = holt_winters(backlog_total, 0.5, 0.1)\n\
         record task_throughput = rate(tasks_completed[300s])\n\
         record pod_failure_ratio = rate(pod_failures[300s]) / rate(pods_created[300s])\n\
         alert BacklogSaturation if avg_over_time(backlog_total[120s]) > 16 for 120s severity page\n\
         alert PodStartFailureRate if pod_failure_ratio > 0.05 for 300s severity ticket\n\
         alert AutoscalerFlapping if changes(replicas_total[600s]) > 8 for 0s severity ticket\n\
         burnrate TaskDisruptionBudget on tasks_lost_to_faults / tasks_completed \
         slo 0.001 factor 10 fast 120s slow 600s severity page\n",
    );
    if data_on {
        t.push_str(
            "record cache_hit_ratio = rate(data_cache_hits[300s]) / \
             (rate(data_cache_hits[300s]) + rate(data_cache_misses[300s]))\n\
             alert CacheHitCollapse if rate(data_cache_misses[300s]) - \
             3 * rate(data_cache_hits[300s]) > 0 for 300s severity ticket\n",
        );
    }
    if isolation_on {
        t.push_str(
            "alert QuotaThrottleSurge if rate(quota_throttles_total[300s]) > 0.2 \
             for 120s severity ticket\n",
        );
    }
    t
}

/// Per-tenant builtin rules, appended once the fleet plan (and thus the
/// tenant count) is known.
pub fn builtin_tenant_rules(n_tenants: usize) -> String {
    use std::fmt::Write;
    let mut t = String::new();
    for tn in 0..n_tenants {
        let _ = writeln!(
            t,
            "record tenant_age_forecast::{tn} = holt_winters(tenant_active_age_s::{tn}, 0.5, 0.1)"
        );
        let _ = writeln!(
            t,
            "alert TenantSlowdown::{tn} if tenant_active_age_s::{tn} > 1800 \
             for 300s severity page tenant {tn}"
        );
        let _ = writeln!(
            t,
            "burnrate TenantSlowdownBudget::{tn} on tenant_slow_seconds::{tn} / \
             tenant_busy_seconds::{tn} slo 0.1 factor 3 fast 300s slow 1200s \
             severity page tenant {tn}"
        );
    }
    t
}

// ---------------------------------------------------------------------
// monitor state
// ---------------------------------------------------------------------

/// The live monitoring stack, held in `Kernel::monitor`.
#[derive(Debug)]
pub struct MonitorState {
    interval_ms: u64,
    builtin: bool,
    rules: RuleSet,
    store: SampleStore,
    alert_rt: Vec<AlertRuntime>,
    burn_rt: Vec<AlertRuntime>,
    ticks: u64,
    /// Instance → tenant map (fleet runs), for the per-tenant series.
    instance_tenants: Vec<u16>,
    n_tenants: usize,
    tenant_slow_ms: Vec<u64>,
    tenant_busy_ms: Vec<u64>,
}

impl MonitorState {
    pub fn new(interval_ms: u64, rules: RuleSet, builtin: bool) -> Self {
        let interval_s = interval_ms.max(1) as f64 / 1000.0;
        let store = SampleStore::new(interval_s, rules.max_window_s());
        let alert_rt = (0..rules.alerts.len()).map(|_| AlertRuntime::new()).collect();
        let burn_rt = (0..rules.burns.len()).map(|_| AlertRuntime::new()).collect();
        MonitorState {
            interval_ms: interval_ms.max(1),
            builtin,
            rules,
            store,
            alert_rt,
            burn_rt,
            ticks: 0,
            instance_tenants: Vec::new(),
            n_tenants: 0,
            tenant_slow_ms: Vec::new(),
            tenant_busy_ms: Vec::new(),
        }
    }

    /// Build from config; resolves the builtin ruleset against the
    /// attached subsystems.
    pub fn from_config(
        cfg: &MonitorConfig,
        data_on: bool,
        isolation_on: bool,
    ) -> Result<Self, String> {
        let (text, builtin) = match &cfg.rules {
            RulesSource::Builtin => (builtin_rules(data_on, isolation_on), true),
            RulesSource::Inline(s) => (s.clone(), false),
        };
        let rules = RuleSet::parse(&text)?;
        Ok(MonitorState::new(cfg.interval_ms, rules, builtin))
    }

    pub fn interval_ms(&self) -> u64 {
        self.interval_ms
    }

    /// Fleet runs: install the instance→tenant map and (for the builtin
    /// ruleset) the per-tenant rules. Must run before the first tick.
    pub fn set_fleet(&mut self, instance_tenants: Vec<u16>) {
        self.n_tenants = instance_tenants
            .iter()
            .map(|&t| t as usize + 1)
            .max()
            .unwrap_or(0);
        self.instance_tenants = instance_tenants;
        self.tenant_slow_ms = vec![0; self.n_tenants];
        self.tenant_busy_ms = vec![0; self.n_tenants];
        if self.builtin {
            let text = builtin_tenant_rules(self.n_tenants);
            self.rules
                .parse_append(&text)
                .expect("builtin tenant rules must parse");
            while self.alert_rt.len() < self.rules.alerts.len() {
                self.alert_rt.push(AlertRuntime::new());
            }
            while self.burn_rt.len() < self.rules.burns.len() {
                self.burn_rt.push(AlertRuntime::new());
            }
            self.store.grow(self.rules.max_window_s());
        }
    }

    /// Latest value of any scraped or recorded series — the kernel-side
    /// query interface (e.g. `backlog_forecast` for a predictive
    /// autoscaler).
    pub fn query(&self, name: &str) -> Option<f64> {
        self.store.last(name)
    }

    /// One scrape tick: sample, smooth, record, alert. Read-only on the
    /// kernel.
    pub fn scrape(&mut self, now: SimTime, k: &Kernel) {
        self.ticks += 1;
        let now_ms = now.as_millis();

        // -- 1. raw samples, deterministic (sorted-name) order ----------
        let counters: Vec<(String, u64)> = k
            .metrics
            .counters_sorted()
            .map(|(n, v)| (n.to_string(), v))
            .collect();
        for (n, v) in counters {
            self.store.push(&n, v as f64);
        }
        let gauge_names: Vec<String> = k.metrics.gauge_names().map(str::to_string).collect();
        let mut queue_total = 0.0;
        let mut replicas_total = 0.0;
        for n in &gauge_names {
            let v = k.metrics.gauge_value(n);
            if n.starts_with("queue::") {
                queue_total += v;
            } else if n.starts_with("replicas::") {
                replicas_total += v;
            }
            self.store.push(n, v);
        }

        // -- synthesized series ----------------------------------------
        let done = (k.engine.dag().len() - k.engine.n_outstanding()) as f64;
        self.store.push("tasks_completed", done);
        let backlog = k.metrics.gauge_value("pending_pods") + queue_total;
        self.store.push("backlog_total", backlog);
        self.store.push("pool_queue_total", queue_total);
        self.store.push("replicas_total", replicas_total);
        if let Some(d) = &k.data {
            self.store.push("data_cache_hits", d.stats.hits as f64);
            self.store.push("data_cache_misses", d.stats.misses as f64);
        }
        if let Some(iso) = &k.isolation {
            let throttles: u64 = iso.stats.quota_throttles_by_tenant.iter().sum();
            self.store.push("quota_throttles_total", throttles as f64);
        }
        if let Some(fs) = &k.fleet {
            self.store
                .push("fleet_waiting_instances", fs.waiting.len() as f64);
            self.store.push("fleet_inflight_instances", fs.in_flight as f64);
            for tn in 0..self.n_tenants {
                // oldest unfinished admitted instance of this tenant
                let mut oldest: Option<u64> = None;
                for (i, &it) in self.instance_tenants.iter().enumerate() {
                    if it as usize != tn || fs.finished_at.get(i).copied().flatten().is_some() {
                        continue;
                    }
                    if let Some(Some(adm)) = fs.admitted_at.get(i) {
                        let a = adm.as_millis();
                        oldest = Some(oldest.map_or(a, |o| o.min(a)));
                    }
                }
                let age_s = oldest.map(|a| now_ms.saturating_sub(a) as f64 / 1000.0);
                if age_s.is_some() {
                    self.tenant_busy_ms[tn] += self.interval_ms;
                    if age_s.unwrap_or(0.0) > TENANT_SLOW_AGE_S {
                        self.tenant_slow_ms[tn] += self.interval_ms;
                    }
                }
                self.store
                    .push(&format!("tenant_active_age_s::{tn}"), age_s.unwrap_or(0.0));
                self.store.push(
                    &format!("tenant_busy_seconds::{tn}"),
                    self.tenant_busy_ms[tn] as f64 / 1000.0,
                );
                self.store.push(
                    &format!("tenant_slow_seconds::{tn}"),
                    self.tenant_slow_ms[tn] as f64 / 1000.0,
                );
            }
        }

        // -- 2. smoothers advance once per tick ------------------------
        for i in 0..self.rules.smoothers.len() {
            let metric = self.rules.smoothers[i].metric().to_string();
            let x = self.store.last(&metric).unwrap_or(0.0);
            self.rules.smoothers[i].update(x);
        }

        // -- 3. recording rules, in file order -------------------------
        for i in 0..self.rules.records.len() {
            let v = eval(&self.rules.records[i].expr, &self.store, &self.rules.smoothers);
            let name = self.rules.records[i].name.clone();
            self.store.push(&name, v);
        }

        // -- 4. alerts -------------------------------------------------
        for (i, rule) in self.rules.alerts.iter().enumerate() {
            let l = eval(&rule.lhs, &self.store, &self.rules.smoothers);
            let r = eval(&rule.rhs, &self.store, &self.rules.smoothers);
            let active = rule.cmp.holds(l, r);
            self.alert_rt[i].step(now_ms, active, l, rule.for_ms);
        }
        for (i, rule) in self.rules.burns.iter().enumerate() {
            let fast = BurnRateRule::ratio(&self.store, &rule.numer, &rule.denom, rule.fast_s);
            let slow = BurnRateRule::ratio(&self.store, &rule.numer, &rule.denom, rule.slow_s);
            let thr = rule.threshold();
            let active = fast >= thr && slow >= thr;
            self.burn_rt[i].step(now_ms, active, fast, 0);
        }
    }

    /// Fold the run into the report (end of simulation).
    pub fn into_report(mut self, makespan: SimTime) -> MonitorReport {
        for rt in self.alert_rt.iter_mut().chain(self.burn_rt.iter_mut()) {
            rt.finalize();
        }
        let mut alerts = Vec::new();
        for (rule, rt) in self.rules.alerts.iter().zip(&self.alert_rt) {
            alerts.push(AlertReport {
                name: rule.name.clone(),
                kind: "threshold",
                severity: rule.severity.clone(),
                tenant: rule.tenant,
                expr: format!(
                    "value {} threshold for {}ms",
                    rule.cmp.symbol(),
                    rule.for_ms
                ),
                fired: rt.fired(),
                firing_ms: rt.firing_ms(makespan.as_millis()),
                final_state: rt.state(),
                episodes: rt.episodes.clone(),
            });
        }
        for (rule, rt) in self.rules.burns.iter().zip(&self.burn_rt) {
            alerts.push(AlertReport {
                name: rule.name.clone(),
                kind: "burnrate",
                severity: rule.severity.clone(),
                tenant: rule.tenant,
                expr: format!(
                    "{}/{} burn >= {:.4} over {}s and {}s",
                    rule.numer,
                    rule.denom,
                    rule.threshold(),
                    rule.fast_s,
                    rule.slow_s
                ),
                fired: rt.fired(),
                firing_ms: rt.firing_ms(makespan.as_millis()),
                final_state: rt.state(),
                episodes: rt.episodes.clone(),
            });
        }
        let records = self
            .rules
            .records
            .iter()
            .map(|r| (r.name.clone(), self.store.last(&r.name).unwrap_or(0.0)))
            .collect();
        MonitorReport {
            interval_ms: self.interval_ms,
            ticks: self.ticks,
            makespan_ms: makespan.as_millis(),
            alerts,
            records,
        }
    }
}

// ---------------------------------------------------------------------
// report
// ---------------------------------------------------------------------

/// Final state of one alert rule after the run.
#[derive(Debug, Clone, PartialEq)]
pub struct AlertReport {
    pub name: String,
    /// "threshold" or "burnrate".
    pub kind: &'static str,
    pub severity: String,
    pub tenant: Option<u16>,
    /// Human-readable condition summary.
    pub expr: String,
    pub fired: u64,
    pub firing_ms: u64,
    pub final_state: AlertState,
    pub episodes: Vec<Episode>,
}

/// End-of-run monitoring report, attached to `SimResult::monitor`.
#[derive(Debug, Clone, PartialEq)]
pub struct MonitorReport {
    pub interval_ms: u64,
    pub ticks: u64,
    pub makespan_ms: u64,
    /// Threshold alerts in rule order, then burn-rate alerts in rule
    /// order.
    pub alerts: Vec<AlertReport>,
    /// Recording rules and their final values, in rule order.
    pub records: Vec<(String, f64)>,
}

impl MonitorReport {
    pub fn fired_total(&self) -> u64 {
        self.alerts.iter().map(|a| a.fired).sum()
    }

    pub fn firing_ms_total(&self) -> u64 {
        self.alerts.iter().map(|a| a.firing_ms).sum()
    }

    /// Alerts-fired count attributed to one tenant (tenant-scoped rules
    /// only).
    pub fn tenant_fired(&self, tenant: u16) -> u64 {
        self.alerts
            .iter()
            .filter(|a| a.tenant == Some(tenant))
            .map(|a| a.fired)
            .sum()
    }

    /// Time-in-firing (ms) attributed to one tenant.
    pub fn tenant_firing_ms(&self, tenant: u16) -> u64 {
        self.alerts
            .iter()
            .filter(|a| a.tenant == Some(tenant))
            .map(|a| a.firing_ms)
            .sum()
    }

    /// Chronological `(time_ms, line)` alert timeline for the text
    /// report: one entry per lifecycle edge of every fired episode.
    pub fn timeline(&self) -> Vec<(u64, String)> {
        let mut out: Vec<(u64, String)> = Vec::new();
        for a in &self.alerts {
            for ep in &a.episodes {
                out.push((
                    ep.pending_ms,
                    format!("{} pending ({})", a.name, a.severity),
                ));
                if let Some(f) = ep.firing_ms {
                    out.push((f, format!("{} FIRING (peak {:.3})", a.name, ep.peak)));
                }
                match ep.resolved_ms {
                    Some(r) => out.push((r, format!("{} resolved", a.name))),
                    None => out.push((
                        self.makespan_ms,
                        format!("{} still firing at end of run", a.name),
                    )),
                }
            }
        }
        out.sort_by(|a, b| a.0.cmp(&b.0).then_with(|| a.1.cmp(&b.1)));
        out
    }

    pub fn to_json(&self) -> Json {
        let alerts = self
            .alerts
            .iter()
            .map(|a| {
                let episodes = a
                    .episodes
                    .iter()
                    .map(|e| {
                        Json::obj(vec![
                            ("pending_ms", e.pending_ms.into()),
                            (
                                "firing_ms",
                                e.firing_ms.map(Json::from).unwrap_or(Json::Null),
                            ),
                            (
                                "resolved_ms",
                                e.resolved_ms.map(Json::from).unwrap_or(Json::Null),
                            ),
                            ("peak", e.peak.into()),
                        ])
                    })
                    .collect();
                Json::obj(vec![
                    ("name", Json::str(&a.name)),
                    ("kind", Json::str(a.kind)),
                    ("severity", Json::str(&a.severity)),
                    (
                        "tenant",
                        a.tenant.map(|t| Json::from(t as u64)).unwrap_or(Json::Null),
                    ),
                    ("expr", Json::str(&a.expr)),
                    ("fired", a.fired.into()),
                    ("firing_ms", a.firing_ms.into()),
                    ("final_state", Json::str(a.final_state.name())),
                    ("episodes", Json::Arr(episodes)),
                ])
            })
            .collect();
        let records = self
            .records
            .iter()
            .map(|(n, v)| Json::obj(vec![("name", Json::str(n)), ("value", (*v).into())]))
            .collect();
        Json::obj(vec![
            ("interval_ms", self.interval_ms.into()),
            ("ticks", self.ticks.into()),
            ("makespan_ms", self.makespan_ms.into()),
            ("alerts_fired", self.fired_total().into()),
            ("firing_ms_total", self.firing_ms_total().into()),
            ("alerts", Json::Arr(alerts)),
            ("records", Json::Arr(records)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_rules_parse_for_every_subsystem_combination() {
        for data_on in [false, true] {
            for iso_on in [false, true] {
                let text = builtin_rules(data_on, iso_on);
                let rs = RuleSet::parse(&text)
                    .unwrap_or_else(|e| panic!("builtin({data_on},{iso_on}): {e}"));
                assert!(rs.alerts.iter().any(|a| a.name == "BacklogSaturation"));
                assert!(rs.burns.iter().any(|b| b.name == "TaskDisruptionBudget"));
                assert_eq!(
                    rs.alerts.iter().any(|a| a.name == "CacheHitCollapse"),
                    data_on
                );
                assert_eq!(
                    rs.alerts.iter().any(|a| a.name == "QuotaThrottleSurge"),
                    iso_on
                );
            }
        }
        let tenant_text = builtin_tenant_rules(3);
        let rs = RuleSet::parse(&tenant_text).unwrap();
        assert_eq!(rs.alerts.len(), 3);
        assert_eq!(rs.burns.len(), 3);
        assert_eq!(rs.alerts[2].tenant, Some(2));
        assert_eq!(rs.burns[1].numer, "tenant_slow_seconds::1");
    }

    #[test]
    fn parse_spec_accepts_the_documented_grammar() {
        let (cfg, path) = MonitorConfig::parse_spec("true").unwrap();
        assert_eq!(cfg, MonitorConfig::default());
        assert_eq!(path, None);

        let (cfg, path) =
            MonitorConfig::parse_spec("interval:15,rules:builtin,alerts:out.json").unwrap();
        assert_eq!(cfg.interval_ms, 15_000);
        assert_eq!(cfg.alerts_out.as_deref(), Some("out.json"));
        assert_eq!(path, None);

        let (cfg, path) = MonitorConfig::parse_spec("interval:0.5,rules:my_rules.txt").unwrap();
        assert_eq!(cfg.interval_ms, 500);
        assert_eq!(path.as_deref(), Some("my_rules.txt"));

        assert!(MonitorConfig::parse_spec("interval:0").is_err());
        assert!(MonitorConfig::parse_spec("interval:nope").is_err());
        assert!(MonitorConfig::parse_spec("bogus:1").is_err());
    }

    #[test]
    fn report_json_is_deterministic_and_complete() {
        let rules = RuleSet::parse(
            "record r = x\n\
             alert A if x > 1 for 0s severity page\n\
             burnrate B on e / t slo 0.01 factor 2 fast 60s slow 120s tenant 1",
        )
        .unwrap();
        let mut m = MonitorState::new(30_000, rules, false);
        // drive the store directly (no kernel needed for report shape)
        m.store.push("x", 2.0);
        m.store.push("e", 0.0);
        m.store.push("t", 0.0);
        m.alert_rt[0].step(30_000, true, 2.0, 0);
        m.burn_rt[0].step(30_000, false, 0.0, 0);
        m.ticks = 1;
        let rep = m.into_report(SimTime::from_millis(90_000));
        assert_eq!(rep.alerts.len(), 2);
        assert_eq!(rep.alerts[0].kind, "threshold");
        assert_eq!(rep.alerts[1].kind, "burnrate");
        assert_eq!(rep.alerts[1].tenant, Some(1));
        assert_eq!(rep.fired_total(), 1);
        assert_eq!(rep.firing_ms_total(), 60_000, "open episode runs to makespan");
        assert_eq!(rep.tenant_fired(1), 0);
        let j = rep.to_json().to_string();
        assert_eq!(j, rep.to_json().to_string(), "serialization is stable");
        assert!(j.contains("\"alerts_fired\""));
        assert!(j.contains("\"final_state\":\"firing\""));
        let tl = rep.timeline();
        assert_eq!(tl.len(), 3, "pending + firing + still-firing edges");
        assert!(tl[2].1.contains("still firing"));
    }
}
