//! Real Montage compute: synthetic sky generation, the task-type executors
//! that call the PJRT runtime, and end-to-end mosaic verification.
//!
//! This is the payload worker pods execute in real-time mode
//! ([`crate::realtime`]): actual image reprojection / plane fitting /
//! background solving / coaddition on synthetic sky tiles, through the
//! AOT-compiled JAX+Pallas artifacts — not sleeps.

pub mod sky;
pub mod store;

use crate::runtime::{Runtime, Tensor};
use crate::workflow::montage::{MontageConfig, MontageIndex, Role};
use anyhow::Result;
use std::sync::Arc;
use store::Store;

/// Geometry + ground truth for one real Montage run.
#[derive(Debug)]
pub struct MontageCompute {
    pub g: usize,
    pub tile: usize,
    pub overlap: usize,
    pub index: MontageIndex,
    pub store: Arc<Store>,
    /// True per-image background offsets (mean-free), for verification.
    pub true_offsets: Vec<f32>,
}

impl MontageCompute {
    /// Prepare raw inputs for a g x g run: sky tiles with per-image
    /// constant background errors and (optionally) sub-pixel pointing
    /// offsets that exercise the reprojection kernel.
    pub fn prepare(g: usize, tile: usize, overlap: usize, seed: u64, warp: bool) -> Self {
        let cfg = MontageConfig {
            grid_w: g,
            grid_h: g,
            diagonals: false, // 4-neighbourhood matches the mbgmodel artifact
            seed,
        };
        let index = MontageIndex::new(&cfg);
        let store = Arc::new(Store::new());
        let mut rng = crate::util::rng::Rng::new(seed);
        let step = tile - overlap;
        let n = g * g;
        let mut offs = Vec::with_capacity(n);
        for i in 0..n {
            let (r, c) = (i / g, i % g);
            let (oy, ox) = ((r * step) as f64, (c * step) as f64);
            let off = rng.normal() as f32 * 2.0;
            let (dx, dy) = if warp {
                (rng.range_f64(-0.5, 0.5), rng.range_f64(-0.5, 0.5))
            } else {
                (0.0, 0.0)
            };
            // raw tile sampled on the shifted grid; mProject's inverse warp
            // with params (1,0,0,1,dx,dy) maps it back onto the canonical
            // grid
            let mut raw = vec![0f32; tile * tile];
            for rr in 0..tile {
                for cc in 0..tile {
                    let gx = ox + cc as f64 + dx;
                    let gy = oy + rr as f64 + dy;
                    raw[rr * tile + cc] = sky::sky(gy, gx) + off;
                }
            }
            store.put(&format!("raw/{i}"), Tensor::new(raw, &[tile, tile]));
            store.put(
                &format!("params/{i}"),
                Tensor::new(
                    vec![1.0, 0.0, 0.0, 1.0, dx as f32, dy as f32],
                    &[6],
                ),
            );
            offs.push(off);
        }
        let mean = offs.iter().sum::<f32>() / n as f32;
        let true_offsets = offs.iter().map(|o| o - mean).collect();
        MontageCompute {
            g,
            tile,
            overlap,
            index,
            store,
            true_offsets,
        }
    }

    /// Artifact names a worker for `type_name` needs loaded.
    pub fn artifacts_for(&self, type_name: &str) -> Vec<String> {
        match type_name {
            "mProject" => vec!["mproject".into()],
            "mDiffFit" => vec!["mdifffit".into()],
            "mBackground" => vec!["mbackground".into()],
            "mBgModel" => vec![format!("mbgmodel_g{}", self.g)],
            "mAdd" => vec![format!("madd_g{}", self.g)],
            "mShrink" => vec![format!("mshrink_g{}", self.g)],
            _ => vec![], // bookkeeping tasks: no artifact
        }
    }

    /// Execute one task (by role) against the runtime. Inputs/outputs move
    /// through the shared [`Store`] (the cluster's shared filesystem in the
    /// paper's setup).
    pub fn execute(&self, rt: &Runtime, role: Role) -> Result<()> {
        let (t, v) = (self.tile, self.overlap);
        let step = t - v;
        let g = self.g;
        match role {
            Role::Project(i) => {
                let raw = self.store.get(&format!("raw/{i}"))?;
                let params = self.store.get(&format!("params/{i}"))?;
                let out = rt.execute("mproject", &[(*raw).clone(), (*params).clone()])?;
                let mut it = out.into_iter();
                self.store.put(&format!("proj/{i}"), it.next().unwrap());
                self.store.put(&format!("w/{i}"), it.next().unwrap());
            }
            Role::DiffFit(e, (i, j)) => {
                let pi = self.store.get(&format!("proj/{i}"))?;
                let pj = self.store.get(&format!("proj/{j}"))?;
                let wi = self.store.get(&format!("w/{i}"))?;
                let wj = self.store.get(&format!("w/{j}"))?;
                let horizontal = j == i + 1;
                let (p1, p2, w12) = if horizontal {
                    (
                        slice_cols(&pi, t, step, t),
                        slice_cols(&pj, t, 0, v),
                        mul(&slice_cols(&wi, t, step, t), &slice_cols(&wj, t, 0, v)),
                    )
                } else {
                    // vertical neighbour: bottom strip of i vs top of j,
                    // transposed into the (T, V) artifact shape
                    (
                        transpose(&slice_rows(&pi, t, step, t), v, t),
                        transpose(&slice_rows(&pj, t, 0, v), v, t),
                        transpose(
                            &mul(&slice_rows(&wi, t, step, t), &slice_rows(&wj, t, 0, v)),
                            v,
                            t,
                        ),
                    )
                };
                let out = rt.execute(
                    "mdifffit",
                    &[
                        Tensor::new(p1, &[t, v]),
                        Tensor::new(p2, &[t, v]),
                        Tensor::new(w12, &[t, v]),
                    ],
                )?;
                self.store
                    .put(&format!("diff/{e}"), out.into_iter().next().unwrap());
            }
            Role::ConcatFit => {
                // gather the constant terms of every pair fit
                let e = self.index.pairs().len();
                let mut d = Vec::with_capacity(e);
                for k in 0..e {
                    d.push(self.store.get(&format!("diff/{k}"))?.data[0]);
                }
                self.store.put("fits", Tensor::new(d, &[e]));
            }
            Role::BgModel => {
                let fits = self.store.get("fits")?;
                let pairs = self.index.pairs();
                let src: Vec<i32> = pairs.iter().map(|&(i, _)| i as i32).collect();
                let dst: Vec<i32> = pairs.iter().map(|&(_, j)| j as i32).collect();
                let ew = vec![1.0f32; pairs.len()];
                let out = rt.execute(
                    &format!("mbgmodel_g{g}"),
                    &[
                        Tensor::from_i32(&src, &[src.len()]),
                        Tensor::from_i32(&dst, &[dst.len()]),
                        (*fits).clone(),
                        Tensor::new(ew, &[pairs.len()]),
                    ],
                )?;
                self.store.put("offsets", out.into_iter().next().unwrap());
            }
            Role::Background(i) => {
                let proj = self.store.get(&format!("proj/{i}"))?;
                let w = self.store.get(&format!("w/{i}"))?;
                let offsets = self.store.get("offsets")?;
                let out = rt.execute(
                    "mbackground",
                    &[
                        (*proj).clone(),
                        (*w).clone(),
                        Tensor::new(vec![offsets.data[i]], &[1]),
                    ],
                )?;
                self.store
                    .put(&format!("corr/{i}"), out.into_iter().next().unwrap());
            }
            Role::Imgtbl => {
                // metadata pass: verify all corrected tiles exist
                for i in 0..g * g {
                    self.store.get(&format!("corr/{i}"))?;
                }
            }
            Role::Add => {
                let n = g * g;
                let mut imgs = Vec::with_capacity(n * t * t);
                let mut ws = Vec::with_capacity(n * t * t);
                let mut oy = Vec::with_capacity(n);
                let mut ox = Vec::with_capacity(n);
                for i in 0..n {
                    imgs.extend_from_slice(&self.store.get(&format!("corr/{i}"))?.data);
                    ws.extend_from_slice(&self.store.get(&format!("w/{i}"))?.data);
                    oy.push(((i / g) * step) as i32);
                    ox.push(((i % g) * step) as i32);
                }
                let out = rt.execute(
                    &format!("madd_g{g}"),
                    &[
                        Tensor::new(imgs, &[n, t, t]),
                        Tensor::new(ws, &[n, t, t]),
                        Tensor::from_i32(&oy, &[n]),
                        Tensor::from_i32(&ox, &[n]),
                    ],
                )?;
                let mut it = out.into_iter();
                let _acc = it.next().unwrap();
                self.store.put("wmap", it.next().unwrap());
                self.store.put("mosaic", it.next().unwrap());
            }
            Role::Shrink => {
                let mosaic = self.store.get("mosaic")?;
                let out = rt.execute(&format!("mshrink_g{g}"), &[(*mosaic).clone()])?;
                self.store.put("shrunk", out.into_iter().next().unwrap());
            }
            Role::Jpeg => {
                let shrunk = self.store.get("shrunk")?;
                self.store.put("preview", pgm_normalize(&shrunk));
            }
        }
        Ok(())
    }

    /// Verify the finished mosaic against the analytic sky (up to the
    /// unobservable global DC offset) and the recovered offsets against the
    /// ground truth.
    pub fn verify(&self) -> Result<VerifyReport> {
        let mosaic = self.store.get("mosaic")?;
        let wmap = self.store.get("wmap")?;
        let offsets = self.store.get("offsets")?;
        let cs = (self.g - 1) * (self.tile - self.overlap) + self.tile;
        // residual vs true sky where covered
        let mut resid = Vec::new();
        for r in 0..cs {
            for c in 0..cs {
                if wmap.data[r * cs + c] > 0.0 {
                    resid.push(mosaic.data[r * cs + c] - sky::sky(r as f64, c as f64));
                }
            }
        }
        let mean = resid.iter().sum::<f32>() / resid.len() as f32;
        let max_resid = resid
            .iter()
            .map(|v| (v - mean).abs())
            .fold(0f32, f32::max);
        let max_offset_err = offsets
            .data
            .iter()
            .zip(self.true_offsets.iter())
            .map(|(a, b)| (a - b).abs())
            .fold(0f32, f32::max);
        let covered = resid.len();
        Ok(VerifyReport {
            max_mosaic_residual: max_resid,
            max_offset_error: max_offset_err,
            covered_pixels: covered,
            canvas_pixels: cs * cs,
        })
    }
}

/// Outcome of [`MontageCompute::verify`].
#[derive(Debug)]
pub struct VerifyReport {
    /// Max |mosaic - sky| over covered pixels, after removing the global DC.
    pub max_mosaic_residual: f32,
    /// Max |recovered - true| background offset.
    pub max_offset_error: f32,
    pub covered_pixels: usize,
    pub canvas_pixels: usize,
}

impl VerifyReport {
    pub fn ok(&self, tol: f32) -> bool {
        self.max_mosaic_residual < tol && self.max_offset_error < tol
    }
}

// -- small dense helpers (row-major) ---------------------------------------

fn slice_cols(t: &Tensor, width: usize, c0: usize, c1: usize) -> Vec<f32> {
    let rows = t.data.len() / width;
    let mut out = Vec::with_capacity(rows * (c1 - c0));
    for r in 0..rows {
        out.extend_from_slice(&t.data[r * width + c0..r * width + c1]);
    }
    out
}

fn slice_rows(t: &Tensor, width: usize, r0: usize, r1: usize) -> Vec<f32> {
    t.data[r0 * width..r1 * width].to_vec()
}

fn mul(a: &[f32], b: &[f32]) -> Vec<f32> {
    a.iter().zip(b.iter()).map(|(x, y)| x * y).collect()
}

/// Transpose an (r x c) row-major matrix into (c x r).
fn transpose(m: &[f32], rows: usize, cols: usize) -> Vec<f32> {
    let mut out = vec![0f32; m.len()];
    for r in 0..rows {
        for c in 0..cols {
            out[c * rows + r] = m[r * cols + c];
        }
    }
    out
}

/// Normalize to 0..255 for the mJPEG preview output.
fn pgm_normalize(t: &Tensor) -> Tensor {
    let (lo, hi) = t
        .data
        .iter()
        .fold((f32::INFINITY, f32::NEG_INFINITY), |(l, h), &v| {
            (l.min(v), h.max(v))
        });
    let scale = if hi > lo { 255.0 / (hi - lo) } else { 0.0 };
    Tensor::new(
        t.data.iter().map(|&v| ((v - lo) * scale).round()).collect(),
        &t.shape,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transpose_round_trip() {
        let m = vec![1., 2., 3., 4., 5., 6.]; // 2x3
        let t = transpose(&m, 2, 3); // 3x2
        assert_eq!(t, vec![1., 4., 2., 5., 3., 6.]);
        assert_eq!(transpose(&t, 3, 2), m);
    }

    #[test]
    fn slicing() {
        // 3x4 matrix
        let t = Tensor::new((0..12).map(|v| v as f32).collect(), &[3, 4]);
        assert_eq!(slice_cols(&t, 4, 2, 4), vec![2., 3., 6., 7., 10., 11.]);
        assert_eq!(slice_rows(&t, 4, 1, 2), vec![4., 5., 6., 7.]);
    }

    #[test]
    fn pgm_normalize_range() {
        let t = Tensor::new(vec![-1.0, 0.0, 3.0], &[3]);
        let n = pgm_normalize(&t);
        assert_eq!(n.data[0], 0.0);
        assert_eq!(n.data[2], 255.0);
    }

    #[test]
    fn prepare_builds_all_inputs() {
        let mc = MontageCompute::prepare(2, 128, 32, 7, false);
        for i in 0..4 {
            assert!(mc.store.get(&format!("raw/{i}")).is_ok());
            assert!(mc.store.get(&format!("params/{i}")).is_ok());
        }
        assert_eq!(mc.true_offsets.len(), 4);
        let s: f32 = mc.true_offsets.iter().sum();
        assert!(s.abs() < 1e-5, "offsets not mean-free: {s}");
        assert_eq!(mc.index.pairs().len(), 4); // 2x2 grid, 4-neighbourhood
    }

    #[test]
    fn artifacts_for_pool_subsets() {
        let mc = MontageCompute::prepare(2, 128, 32, 7, false);
        assert_eq!(mc.artifacts_for("mProject"), vec!["mproject"]);
        assert_eq!(mc.artifacts_for("mBgModel"), vec!["mbgmodel_g2"]);
        assert!(mc.artifacts_for("mImgtbl").is_empty());
    }
}
