//! Shared data store: the in-memory stand-in for the cluster's shared
//! filesystem (the paper's setup stages Montage files on a shared volume).
//! Thread-safe: worker-pod threads read inputs and publish outputs here.

use crate::runtime::Tensor;
use anyhow::{anyhow, Result};
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

#[derive(Debug, Default)]
pub struct Store {
    inner: Mutex<HashMap<String, Arc<Tensor>>>,
}

impl Store {
    pub fn new() -> Self {
        Store::default()
    }

    pub fn put(&self, key: &str, t: Tensor) {
        self.inner
            .lock()
            .unwrap()
            .insert(key.to_string(), Arc::new(t));
    }

    /// Fetch a tensor; error mentions the key (missing data = dependency
    /// bug, the tests rely on the message).
    pub fn get(&self, key: &str) -> Result<Arc<Tensor>> {
        self.inner
            .lock()
            .unwrap()
            .get(key)
            .cloned()
            .ok_or_else(|| anyhow!("store: key '{key}' not present"))
    }

    pub fn contains(&self, key: &str) -> bool {
        self.inner.lock().unwrap().contains_key(key)
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Approximate resident bytes (for the e2e report).
    pub fn bytes(&self) -> usize {
        self.inner
            .lock()
            .unwrap()
            .values()
            .map(|t| t.data.len() * 4)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn put_get_cycle() {
        let s = Store::new();
        s.put("a", Tensor::new(vec![1.0, 2.0], &[2]));
        let t = s.get("a").unwrap();
        assert_eq!(t.data, vec![1.0, 2.0]);
        assert!(s.contains("a"));
        assert_eq!(s.len(), 1);
        assert_eq!(s.bytes(), 8);
    }

    #[test]
    fn missing_key_names_it() {
        let s = Store::new();
        let e = s.get("proj/3").unwrap_err();
        assert!(format!("{e}").contains("proj/3"));
    }

    #[test]
    fn concurrent_access() {
        let s = Arc::new(Store::new());
        let mut handles = Vec::new();
        for i in 0..8 {
            let s = s.clone();
            handles.push(std::thread::spawn(move || {
                s.put(&format!("k{i}"), Tensor::new(vec![i as f32], &[1]));
                s.get(&format!("k{i}")).unwrap();
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(s.len(), 8);
    }
}
