//! Shared data store: the in-memory stand-in for the cluster's shared
//! filesystem (the paper's setup stages Montage files on a shared volume).
//! Thread-safe: worker-pod threads read inputs and publish outputs here.
//!
//! Byte accounting mirrors the simulated data plane ([`crate::data`]):
//! `put` records each tensor's byte length, so the realtime e2e path can
//! report actual bytes moved alongside the simulator's modeled transfers.

use crate::runtime::Tensor;
use anyhow::{anyhow, Result};
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

#[derive(Debug, Default)]
struct Inner {
    map: HashMap<String, Arc<Tensor>>,
    bytes: usize,
}

#[derive(Debug, Default)]
pub struct Store {
    inner: Mutex<Inner>,
}

/// Byte length of a stored tensor (f32 payload).
fn tensor_bytes(t: &Tensor) -> usize {
    t.data.len() * 4
}

impl Store {
    pub fn new() -> Self {
        Store::default()
    }

    /// Insert (or replace) a tensor, keeping the byte total exact across
    /// overwrites.
    pub fn put(&self, key: &str, t: Tensor) {
        let sz = tensor_bytes(&t);
        let mut inner = self.inner.lock().unwrap();
        if let Some(old) = inner.map.insert(key.to_string(), Arc::new(t)) {
            inner.bytes -= tensor_bytes(&old);
        }
        inner.bytes += sz;
    }

    /// Fetch a tensor; error mentions the key (missing data = dependency
    /// bug, the tests rely on the message).
    pub fn get(&self, key: &str) -> Result<Arc<Tensor>> {
        self.inner
            .lock()
            .unwrap()
            .map
            .get(key)
            .cloned()
            .ok_or_else(|| anyhow!("store: key '{key}' not present"))
    }

    pub fn contains(&self, key: &str) -> bool {
        self.inner.lock().unwrap().map.contains_key(key)
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total resident bytes, maintained incrementally on `put` (O(1), not
    /// a scan — the e2e report polls this per stage).
    pub fn bytes_total(&self) -> usize {
        self.inner.lock().unwrap().bytes
    }

    /// Byte size of one key's tensor, if present.
    pub fn bytes_of(&self, key: &str) -> Option<usize> {
        self.inner
            .lock()
            .unwrap()
            .map
            .get(key)
            .map(|t| tensor_bytes(t))
    }

    /// Resident bytes (kept for older call sites; same as
    /// [`Store::bytes_total`]).
    pub fn bytes(&self) -> usize {
        self.bytes_total()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn put_get_cycle() {
        let s = Store::new();
        s.put("a", Tensor::new(vec![1.0, 2.0], &[2]));
        let t = s.get("a").unwrap();
        assert_eq!(t.data, vec![1.0, 2.0]);
        assert!(s.contains("a"));
        assert_eq!(s.len(), 1);
        assert_eq!(s.bytes(), 8);
        assert_eq!(s.bytes_total(), 8);
        assert_eq!(s.bytes_of("a"), Some(8));
        assert_eq!(s.bytes_of("b"), None);
    }

    #[test]
    fn overwrite_keeps_byte_total_exact() {
        let s = Store::new();
        s.put("k", Tensor::new(vec![0.0; 8], &[8]));
        assert_eq!(s.bytes_total(), 32);
        // replacing with a smaller tensor must not leak the old size
        s.put("k", Tensor::new(vec![0.0; 2], &[2]));
        assert_eq!(s.bytes_total(), 8);
        assert_eq!(s.bytes_of("k"), Some(8));
        s.put("j", Tensor::new(vec![0.0; 4], &[4]));
        assert_eq!(s.bytes_total(), 24);
    }

    #[test]
    fn missing_key_names_it() {
        let s = Store::new();
        let e = s.get("proj/3").unwrap_err();
        assert!(format!("{e}").contains("proj/3"));
    }

    #[test]
    fn concurrent_access() {
        let s = Arc::new(Store::new());
        let mut handles = Vec::new();
        for i in 0..8 {
            let s = s.clone();
            handles.push(std::thread::spawn(move || {
                s.put(&format!("k{i}"), Tensor::new(vec![i as f32], &[1]));
                s.get(&format!("k{i}")).unwrap();
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(s.len(), 8);
        assert_eq!(s.bytes_total(), 32, "8 single-f32 tensors");
    }
}
