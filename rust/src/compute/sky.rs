//! The synthetic sky: a deterministic smooth function over global mosaic
//! coordinates. Matches the function used by the python test-suite
//! (python/tests/test_model.py::sky) so both sides validate the same
//! ground truth.

/// Sky surface brightness at global pixel (y, x).
pub fn sky(y: f64, x: f64) -> f32 {
    ((x / 37.0).sin() + (y / 29.0).cos() + 0.002 * x + 0.001 * y) as f32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_smooth() {
        assert_eq!(sky(10.0, 20.0), sky(10.0, 20.0));
        // smooth: neighbouring pixels differ by < 0.1
        for y in 0..50 {
            for x in 0..50 {
                let d = (sky(y as f64, x as f64 + 1.0) - sky(y as f64, x as f64)).abs();
                assert!(d < 0.1, "gradient too steep at ({y},{x})");
            }
        }
    }

    #[test]
    fn known_value_at_origin() {
        // sin(0) + cos(0) + 0 + 0 = 1
        assert!((sky(0.0, 0.0) - 1.0).abs() < 1e-6);
    }
}
