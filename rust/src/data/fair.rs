//! Max-min fair bandwidth allocation (progressive filling).
//!
//! A transfer ("flow") crosses one or more capacitated resources — here a
//! node's NIC and, for the shared-NFS backend, the file server's aggregate
//! link — and may additionally be limited by a per-flow cap (the object
//! store's per-stream bandwidth). The **max-min fair** allocation is the
//! unique rate vector in which no flow's rate can be increased without
//! decreasing the rate of a flow that is no better off: water-fill all
//! flows together, freezing a flow when it hits its own cap or when one of
//! its resources saturates, until every flow is frozen.
//!
//! The driver recomputes the allocation whenever a transfer starts or
//! finishes (rates are piecewise-constant between such events), so the
//! whole transfer timeline is a deterministic function of the event order
//! — identical seed + config stays bit-reproducible.

/// Relative numerical slack for saturation checks.
const EPS: f64 = 1e-9;

/// One flow's constraint set: the resources it crosses (indices into the
/// capacity vector) and an optional per-flow rate cap.
#[derive(Debug, Clone)]
pub struct FlowReq {
    pub links: Vec<usize>,
    /// Per-flow rate cap (`f64::INFINITY` = resource-limited only).
    pub cap: f64,
}

impl FlowReq {
    /// A flow limited only by the resources it crosses.
    pub fn through(links: Vec<usize>) -> Self {
        FlowReq {
            links,
            cap: f64::INFINITY,
        }
    }

    /// Add a per-flow rate cap (object-store per-stream bandwidth).
    pub fn with_cap(mut self, cap: f64) -> Self {
        self.cap = cap;
        self
    }
}

/// Reusable scratch for repeated max-min computations — the data plane
/// recomputes shares on every transfer start/finish, and the driver's
/// hot-path discipline is zero steady-state allocation (EXPERIMENTS.md
/// §Perf), so the working vectors live here across calls.
#[derive(Debug, Default)]
pub struct Workspace {
    alloc: Vec<f64>,
    rem: Vec<f64>,
    active: Vec<bool>,
    count: Vec<usize>,
}

impl Workspace {
    /// Compute the max-min fair share of every flow given per-resource
    /// capacities, into the workspace's reusable buffers; the returned
    /// slice is valid until the next call. Units are arbitrary but must
    /// be consistent (the data plane uses bytes/ms). Every flow must
    /// cross at least one resource or carry a finite cap — otherwise its
    /// fair share would be unbounded.
    pub fn shares(&mut self, capacity: &[f64], flows: &[FlowReq]) -> &[f64] {
        let n = flows.len();
        self.alloc.clear();
        self.alloc.resize(n, 0.0);
        if n == 0 {
            return &self.alloc;
        }
        for f in flows {
            assert!(
                !f.links.is_empty() || f.cap.is_finite(),
                "unconstrained flow has no max-min share"
            );
            debug_assert!(f.links.iter().all(|&r| r < capacity.len()));
        }
        self.rem.clear();
        self.rem.extend_from_slice(capacity);
        self.active.clear();
        self.active.resize(n, true);
        self.count.clear();
        self.count.resize(capacity.len(), 0);
        let mut n_active = n;
        // Each round saturates at least one resource or flow cap, so the
        // loop runs at most n + |capacity| rounds; the bound guards FP
        // corner cases.
        for _ in 0..(n + capacity.len() + 1) {
            if n_active == 0 {
                break;
            }
            self.count.fill(0);
            for (i, f) in flows.iter().enumerate() {
                if self.active[i] {
                    for &r in &f.links {
                        self.count[r] += 1;
                    }
                }
            }
            // the water level rises by the smallest per-flow headroom
            let mut delta = f64::INFINITY;
            for (r, &c) in self.count.iter().enumerate() {
                if c > 0 {
                    delta = delta.min(self.rem[r] / c as f64);
                }
            }
            for (i, f) in flows.iter().enumerate() {
                if self.active[i] {
                    delta = delta.min(f.cap - self.alloc[i]);
                }
            }
            if !delta.is_finite() {
                break; // cannot happen with the constraint assert above
            }
            let delta = delta.max(0.0);
            if delta > 0.0 {
                for (i, f) in flows.iter().enumerate() {
                    if self.active[i] {
                        self.alloc[i] += delta;
                        for &r in &f.links {
                            self.rem[r] = (self.rem[r] - delta).max(0.0);
                        }
                    }
                }
            }
            // freeze flows at their cap or on a saturated resource
            for (i, f) in flows.iter().enumerate() {
                if !self.active[i] {
                    continue;
                }
                let capped = f.cap.is_finite() && self.alloc[i] + EPS * f.cap.max(1.0) >= f.cap;
                let saturated = f
                    .links
                    .iter()
                    .any(|&r| self.rem[r] <= EPS * capacity[r].max(1.0));
                if capped || saturated {
                    self.active[i] = false;
                    n_active -= 1;
                }
            }
        }
        &self.alloc
    }
}

/// One-shot convenience wrapper over [`Workspace::shares`] (tests and
/// cold paths).
pub fn max_min_shares(capacity: &[f64], flows: &[FlowReq]) -> Vec<f64> {
    Workspace::default().shares(capacity, flows).to_vec()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::ptest;
    use crate::util::rng::Rng;

    fn assert_close(a: f64, b: f64) {
        assert!((a - b).abs() < 1e-6, "{a} != {b}");
    }

    #[test]
    fn single_flow_gets_its_bottleneck() {
        let s = max_min_shares(&[10.0, 4.0], &[FlowReq::through(vec![0, 1])]);
        assert_close(s[0], 4.0);
    }

    #[test]
    fn equal_flows_split_one_resource_evenly() {
        let flows: Vec<FlowReq> = (0..4).map(|_| FlowReq::through(vec![0])).collect();
        let s = max_min_shares(&[8.0], &flows);
        for &v in &s {
            assert_close(v, 2.0);
        }
    }

    #[test]
    fn bottleneck_constrained_mix_hand_computed() {
        // resources: A = 10, B = 4
        // f0 crosses A only; f1 crosses A and B; f2 crosses B only.
        // Water-filling: B saturates at level 2 (freezes f1, f2); f0
        // continues alone on A up to 10 - 2 = 8.
        let flows = vec![
            FlowReq::through(vec![0]),
            FlowReq::through(vec![0, 1]),
            FlowReq::through(vec![1]),
        ];
        let s = max_min_shares(&[10.0, 4.0], &flows);
        assert_close(s[0], 8.0);
        assert_close(s[1], 2.0);
        assert_close(s[2], 2.0);
    }

    #[test]
    fn per_flow_cap_frees_headroom_for_the_rest() {
        // the capped stream stops at 1; the other takes the remaining 9
        let flows = vec![
            FlowReq::through(vec![0]).with_cap(1.0),
            FlowReq::through(vec![0]),
        ];
        let s = max_min_shares(&[10.0], &flows);
        assert_close(s[0], 1.0);
        assert_close(s[1], 9.0);
    }

    #[test]
    fn cap_only_flow_needs_no_resource() {
        let s = max_min_shares(&[], &[FlowReq { links: vec![], cap: 3.0 }]);
        assert_close(s[0], 3.0);
    }

    #[test]
    fn zero_capacity_resource_starves_its_flows() {
        let flows = vec![FlowReq::through(vec![0]), FlowReq::through(vec![1])];
        let s = max_min_shares(&[0.0, 5.0], &flows);
        assert_close(s[0], 0.0);
        assert_close(s[1], 5.0);
    }

    #[test]
    #[should_panic(expected = "unconstrained flow")]
    fn unconstrained_flow_is_rejected() {
        max_min_shares(&[1.0], &[FlowReq::through(vec![])]);
    }

    #[test]
    fn empty_problem() {
        assert!(max_min_shares(&[3.0], &[]).is_empty());
    }

    /// Random problem generator: up to `size` flows over up to 6 resources,
    /// each flow crossing 1-2 distinct resources, ~25% carrying a cap.
    fn gen_problem(rng: &mut Rng, size: usize) -> (Vec<f64>, Vec<FlowReq>) {
        let n_res = 1 + rng.below(6) as usize;
        let caps: Vec<f64> = (0..n_res).map(|_| 1.0 + rng.f64() * 99.0).collect();
        let n_flows = 1 + rng.below(size.max(1) as u64) as usize;
        let flows: Vec<FlowReq> = (0..n_flows)
            .map(|_| {
                let a = rng.below(n_res as u64) as usize;
                let mut links = vec![a];
                if rng.below(2) == 1 && n_res > 1 {
                    let b = rng.below(n_res as u64) as usize;
                    if b != a {
                        links.push(b);
                    }
                }
                let mut f = FlowReq::through(links);
                if rng.below(4) == 0 {
                    f = f.with_cap(0.5 + rng.f64() * 20.0);
                }
                f
            })
            .collect();
        (caps, flows)
    }

    #[test]
    fn prop_allocations_respect_capacity_and_are_maximal() {
        ptest::check(
            "max-min feasible + maximal",
            0xFA17,
            60,
            24,
            gen_problem,
            |(caps, flows)| {
                let s = max_min_shares(caps, flows);
                let tol = 1e-6;
                // feasibility: per-resource sums within capacity
                for (r, &cap) in caps.iter().enumerate() {
                    let used: f64 = flows
                        .iter()
                        .zip(&s)
                        .filter(|(f, _)| f.links.contains(&r))
                        .map(|(_, &v)| v)
                        .sum();
                    if used > cap + tol * cap.max(1.0) {
                        return Err(format!("resource {r} over capacity: {used} > {cap}"));
                    }
                }
                // maximality: every flow is at its cap or on a saturated link
                for (i, f) in flows.iter().enumerate() {
                    let at_cap = f.cap.is_finite() && s[i] >= f.cap - tol * f.cap.max(1.0);
                    let on_saturated = f.links.iter().any(|&r| {
                        let used: f64 = flows
                            .iter()
                            .zip(&s)
                            .filter(|(g, _)| g.links.contains(&r))
                            .map(|(_, &v)| v)
                            .sum();
                        used >= caps[r] - tol * caps[r].max(1.0)
                    });
                    if !at_cap && !on_saturated {
                        return Err(format!("flow {i} could still grow: {}", s[i]));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_allocation_is_order_independent() {
        ptest::check(
            "max-min order independent",
            0xFA18,
            40,
            16,
            gen_problem,
            |(caps, flows)| {
                let fwd = max_min_shares(caps, flows);
                // reverse the flow order and compare the mapped-back shares
                let rev_flows: Vec<FlowReq> = flows.iter().rev().cloned().collect();
                let rev = max_min_shares(caps, &rev_flows);
                for (i, &v) in fwd.iter().enumerate() {
                    let w = rev[flows.len() - 1 - i];
                    if (v - w).abs() > 1e-6 * v.max(1.0) {
                        return Err(format!("flow {i}: {v} (fwd) vs {w} (rev)"));
                    }
                }
                Ok(())
            },
        );
    }
}
