//! Data plane: shared-storage and transfer modeling.
//!
//! The paper runs Montage against a shared NFS volume, but the simulator
//! modeled tasks as pure compute — bytes never moved, so storage
//! contention (a first-order effect on data-intensive workflows, and a
//! core concern of workflow containerization in KubeAdaptor,
//! arXiv:2207.01222) was invisible. This module makes data movement a
//! deterministic, seeded part of every run:
//!
//! * **Workflow annotation** — each task declares external input bytes and
//!   output bytes on the [`crate::workflow::dag::Dag`]; a task's inputs
//!   are its predecessors' outputs plus its external stage-in. File ids
//!   are task-scoped, so [`Dag::disjoint_union`] keeps fleet instances'
//!   files disjoint for free.
//! * **Backends** ([`Backend`]) — shared NFS with a bounded aggregate
//!   server bandwidth, or an object store with per-request latency and a
//!   per-stream bandwidth cap. Every node additionally owns a
//!   [`NIC_GBPS`] network link.
//! * **Transfers** — stage-in before execution and stage-out after, one
//!   coalesced flow per task per direction, rated by max-min fair sharing
//!   ([`fair`]) over the node links and the NFS server, recomputed on
//!   every transfer start/finish. All events ride the calendar
//!   [`crate::sim::EventQueue`], so identical seed + config is
//!   bit-reproducible.
//! * **Node-local ephemeral cache** — fetched inputs and produced outputs
//!   land in an LRU cache on the pod's node, *owned by the pod* (emptyDir
//!   semantics): entries die with their pod. Long-lived pool workers
//!   therefore accumulate warm caches, while job pods start cold every
//!   time — the central asymmetry `benches/data_locality.rs` measures.
//!   Chaos kills take the cache with the pod (crash-loses-cache).
//! * **Locality** — with `locality:on`, the scheduler prefers nodes
//!   already caching a pending pod's input bytes (see
//!   [`crate::k8s::scheduler::DataLocality`]); off, placement is
//!   bit-identical to a build without the data plane.
//!
//! CLI spec: `--data nfs:1,cache:8,locality:on` (see
//! [`DataConfig::parse_spec`]).

pub mod fair;
pub mod report;

pub use report::{DataReport, DataStats};

use crate::k8s::node::Node;
use crate::k8s::pod::{Payload, PodId};
use crate::k8s::scheduler::DataLocality;
use crate::sim::SimTime;
use crate::workflow::dag::Dag;
use crate::workflow::task::TaskId;
use fair::FlowReq;
use std::collections::BTreeMap;

/// Per-node NIC bandwidth (Gbit/s) shared by that node's transfers.
pub const NIC_GBPS: f64 = 10.0;

/// Default per-node cache capacity (decimal GB) when the spec omits
/// `cache:`.
pub const DEFAULT_CACHE_GB: f64 = 8.0;

#[inline]
fn gbps_to_bytes_per_ms(gbps: f64) -> f64 {
    gbps * 1e9 / 8.0 / 1000.0
}

/// Storage backend the workflow's files live on.
#[derive(Debug, Clone, PartialEq)]
pub enum Backend {
    /// Shared NFS server: one aggregate link of `gbps` Gbit/s that every
    /// transfer (in either direction) crosses.
    Nfs { gbps: f64 },
    /// Object store: per-request latency plus a per-stream bandwidth cap;
    /// aggregate backend bandwidth is unbounded (nodes' NICs still limit).
    ObjectStore { latency_ms: u64, stream_gbps: f64 },
}

/// Complete data-plane description for a run.
#[derive(Debug, Clone, PartialEq)]
pub struct DataConfig {
    pub backend: Backend,
    /// Node-local ephemeral cache capacity in bytes (0 disables caching).
    pub cache_bytes: u64,
    /// Locality-aware scheduling: prefer nodes caching the pod's inputs.
    pub locality: bool,
}

impl DataConfig {
    /// Shared-NFS config with the default cache and locality off.
    pub fn nfs(gbps: f64) -> Self {
        DataConfig {
            backend: Backend::Nfs { gbps },
            cache_bytes: (DEFAULT_CACHE_GB * 1e9) as u64,
            locality: false,
        }
    }

    /// Parse the CLI/JSON data spec: comma-separated `kind:value` entries.
    ///
    /// | kind       | value                          | meaning |
    /// |------------|--------------------------------|---------|
    /// | `nfs`      | aggregate Gbit/s               | shared NFS backend |
    /// | `s3`       | `<latency_ms>x<gbit/s>`        | object-store backend |
    /// | `cache`    | decimal GB per node            | ephemeral cache size |
    /// | `locality` | `on` / `off`                   | locality-aware placement |
    ///
    /// Exactly one backend entry is required.
    /// Example: `nfs:1,cache:8,locality:on` or `s3:30x1.5,cache:4`.
    pub fn parse_spec(spec: &str) -> Result<DataConfig, String> {
        let mut backend: Option<Backend> = None;
        let mut cache_bytes = (DEFAULT_CACHE_GB * 1e9) as u64;
        let mut locality = false;
        for entry in spec.split(',') {
            let entry = entry.trim();
            if entry.is_empty() {
                continue;
            }
            let (kind, value) = entry
                .split_once(':')
                .ok_or_else(|| format!("data entry '{entry}' is not kind:value"))?;
            let value = value.trim();
            match kind.trim() {
                "nfs" => {
                    let g: f64 = value
                        .parse()
                        .map_err(|_| format!("data entry '{entry}': '{value}' is not a number"))?;
                    if !g.is_finite() || g <= 0.0 {
                        return Err(format!("data entry '{entry}': bandwidth must be > 0"));
                    }
                    if backend.is_some() {
                        return Err("data spec lists more than one backend".into());
                    }
                    backend = Some(Backend::Nfs { gbps: g });
                }
                "s3" => {
                    let (lat, bw) = value.split_once('x').ok_or_else(|| {
                        format!("data entry '{entry}': s3 value is <latency_ms>x<gbit/s>")
                    })?;
                    let latency_ms: u64 = lat
                        .trim()
                        .parse()
                        .map_err(|_| format!("data entry '{entry}': '{lat}' is not a number"))?;
                    let stream_gbps: f64 = bw
                        .trim()
                        .parse()
                        .map_err(|_| format!("data entry '{entry}': '{bw}' is not a number"))?;
                    if !stream_gbps.is_finite() || stream_gbps <= 0.0 {
                        return Err(format!(
                            "data entry '{entry}': per-stream bandwidth must be > 0"
                        ));
                    }
                    if backend.is_some() {
                        return Err("data spec lists more than one backend".into());
                    }
                    backend = Some(Backend::ObjectStore {
                        latency_ms,
                        stream_gbps,
                    });
                }
                "cache" => {
                    let gb: f64 = value
                        .parse()
                        .map_err(|_| format!("data entry '{entry}': '{value}' is not a number"))?;
                    if !gb.is_finite() || gb < 0.0 {
                        return Err(format!("data entry '{entry}': cache size must be >= 0"));
                    }
                    cache_bytes = (gb * 1e9) as u64;
                }
                "locality" => {
                    locality = match value {
                        "on" => true,
                        "off" => false,
                        other => {
                            return Err(format!(
                                "data entry '{entry}': locality is on|off, not '{other}'"
                            ))
                        }
                    };
                }
                other => {
                    return Err(format!(
                        "unknown data entry '{other}' (expected nfs, s3, cache, locality)"
                    ))
                }
            }
        }
        let backend = backend.ok_or_else(|| {
            "data spec needs a backend: nfs:<gbit/s> or s3:<latency_ms>x<gbit/s>".to_string()
        })?;
        Ok(DataConfig {
            backend,
            cache_bytes,
            locality,
        })
    }
}

/// Transfer direction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Dir {
    In,
    Out,
}

/// One coalesced transfer (all of a task's missing input bytes, or its
/// output) between the backend and a node.
#[derive(Debug)]
struct Flow {
    pod: PodId,
    task: TaskId,
    node: usize,
    tenant: usize,
    dir: Dir,
    /// Total bytes this flow moves (for accounting).
    total: u64,
    /// Bytes still to move (advanced by `rate` between recomputes).
    remaining: f64,
    /// Current max-min fair rate, bytes/ms (0 while inactive).
    rate: f64,
    /// Still pending (false once completed or canceled).
    live: bool,
    /// Participates in fair sharing (object-store request latency defers
    /// activation).
    active: bool,
    /// Completion-event generation; stale `FlowDone` events are dropped.
    gen: u32,
    begun_at: SimTime,
    /// Absolute ms of the currently scheduled completion (`u64::MAX` none).
    sched_at: u64,
    /// Files to insert into the node cache when the flow completes.
    files: Vec<u32>,
}

#[derive(Debug)]
struct CacheEntry {
    bytes: u64,
    owner: PodId,
    stamp: u64,
}

/// Node-local ephemeral cache: LRU over file ids, entries owned by the
/// pod that fetched/produced them (emptyDir semantics — they die with it).
#[derive(Debug, Default)]
struct NodeCache {
    used: u64,
    entries: BTreeMap<u32, CacheEntry>,
}

/// Scheduling instruction the data plane hands back to the driver.
#[derive(Debug, Clone, Copy)]
pub struct FlowEvent {
    pub flow: u32,
    pub gen: u32,
    pub at: SimTime,
    /// true: schedule an activation (object-store request latency);
    /// false: schedule a completion check.
    pub activate: bool,
}

/// Outcome of starting a stage: the data is already local (`Ready`) or a
/// transfer was launched (`Wait` — the driver resumes on `FlowDone`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StageStart {
    Ready,
    Wait,
}

/// A completed flow, as reported by [`DataPlane::flow_done`].
#[derive(Debug, Clone, Copy)]
pub struct FlowDone {
    pub pod: PodId,
    pub task: TaskId,
    pub inbound: bool,
    /// Bytes the flow moved (achieved-bandwidth reporting).
    pub bytes: u64,
    /// Wall time the transfer took.
    pub dur: SimTime,
}

const NO_FLOW: u32 = u32::MAX;

/// Runtime state of the data plane for one simulated run.
#[derive(Debug)]
pub struct DataPlane {
    cfg: DataConfig,
    /// Per task: input file ids (predecessor outputs, then the external
    /// input if any). File id `t` = output of task `t`; `n_tasks + t` =
    /// external input of task `t`.
    inputs: Vec<Vec<u32>>,
    file_bytes: Vec<u64>,
    caches: Vec<NodeCache>,
    flows: Vec<Flow>,
    /// Flows currently sharing bandwidth, in activation order.
    active: Vec<u32>,
    /// The live flow of each pod (`NO_FLOW` = none); a pod stages at most
    /// one transfer at a time.
    pod_flow: Vec<u32>,
    /// Cache entries owned by each pod (fast-path skip for cancel).
    pod_owned: Vec<u32>,
    /// Last time flow progress was advanced (ms).
    last_ms: u64,
    /// LRU clock.
    touch: u64,
    /// Reusable fair-share workspace + problem buffers — the recompute
    /// runs on every transfer start/finish (§Perf: no per-event allocs).
    ws: fair::Workspace,
    caps_buf: Vec<f64>,
    reqs_buf: Vec<FlowReq>,
    pub stats: DataStats,
}

impl DataPlane {
    pub fn new(cfg: DataConfig, dag: &Dag, n_nodes: usize) -> Self {
        let n = dag.len();
        let mut inputs: Vec<Vec<u32>> = vec![Vec::new(); n];
        for p in 0..n {
            for s in dag.successors(TaskId(p as u32)) {
                inputs[s.0 as usize].push(p as u32);
            }
        }
        let mut file_bytes = vec![0u64; 2 * n];
        for t in 0..n {
            let id = TaskId(t as u32);
            file_bytes[t] = dag.task_out_bytes(id);
            let ext = dag.task_in_bytes(id);
            file_bytes[n + t] = ext;
            if ext > 0 {
                inputs[t].push((n + t) as u32);
            }
        }
        DataPlane {
            cfg,
            inputs,
            file_bytes,
            caches: (0..n_nodes).map(|_| NodeCache::default()).collect(),
            flows: Vec::new(),
            active: Vec::new(),
            pod_flow: Vec::new(),
            pod_owned: Vec::new(),
            last_ms: 0,
            touch: 0,
            ws: fair::Workspace::default(),
            caps_buf: Vec::new(),
            reqs_buf: Vec::new(),
            stats: DataStats {
                enabled: true,
                ..Default::default()
            },
        }
    }

    pub fn cfg(&self) -> &DataConfig {
        &self.cfg
    }

    fn ensure_pod(&mut self, pod: PodId) {
        let i = pod.0 as usize;
        if i >= self.pod_flow.len() {
            self.pod_flow.resize(i + 1, NO_FLOW);
            self.pod_owned.resize(i + 1, 0);
        }
    }

    /// Is `file` currently cached on `node` (read-only; no LRU touch)?
    fn cached(&self, node: usize, file: u32) -> bool {
        self.caches[node].entries.contains_key(&file)
    }

    /// Total bytes of `task`'s inputs currently cached on `node`.
    fn cached_input_bytes_of(&self, task: TaskId, node: usize) -> u64 {
        self.inputs[task.0 as usize]
            .iter()
            .filter(|&&f| self.cached(node, f))
            .map(|&f| self.file_bytes[f as usize])
            .sum()
    }

    /// Insert `file` into `node`'s cache, owned by `pod`, evicting LRU
    /// entries as needed. Files larger than the cache are skipped.
    fn cache_insert(&mut self, node: usize, file: u32, pod: PodId) {
        let bytes = self.file_bytes[file as usize];
        if bytes == 0 || bytes > self.cfg.cache_bytes {
            return;
        }
        self.touch += 1;
        let stamp = self.touch;
        let cache = &mut self.caches[node];
        if let Some(e) = cache.entries.get_mut(&file) {
            e.stamp = stamp; // refresh; keep the original owner
            return;
        }
        while cache.used + bytes > self.cfg.cache_bytes {
            // evict the least-recently-used entry (deterministic: BTreeMap
            // iteration order breaks stamp ties by file id, and stamps are
            // unique anyway)
            let victim = cache
                .entries
                .iter()
                .min_by_key(|(_, e)| e.stamp)
                .map(|(&f, _)| f);
            match victim {
                Some(f) => {
                    let e = cache.entries.remove(&f).expect("victim exists");
                    cache.used -= e.bytes;
                    let o = e.owner.0 as usize;
                    if o < self.pod_owned.len() && self.pod_owned[o] > 0 {
                        self.pod_owned[o] -= 1;
                    }
                    self.stats.evictions += 1;
                }
                None => return, // cannot happen: bytes <= cache_bytes
            }
        }
        cache.used += bytes;
        cache.entries.insert(
            file,
            CacheEntry {
                bytes,
                owner: pod,
                stamp,
            },
        );
        self.ensure_pod(pod);
        self.pod_owned[pod.0 as usize] += 1;
    }

    /// Begin staging `task`'s inputs onto `pod` (bound to `node`).
    /// Returns `Ready` when every input byte is already local; otherwise
    /// launches one coalesced transfer and returns `Wait`.
    pub fn begin_stage_in(
        &mut self,
        now: SimTime,
        pod: PodId,
        node: usize,
        task: TaskId,
        tenant: usize,
        out: &mut Vec<FlowEvent>,
    ) -> StageStart {
        let mut need = 0u64;
        let mut files: Vec<u32> = Vec::new();
        let input_ids = std::mem::take(&mut self.inputs[task.0 as usize]);
        for &f in &input_ids {
            let bytes = self.file_bytes[f as usize];
            if bytes == 0 {
                continue;
            }
            if self.cached(node, f) {
                self.stats.hits += 1;
                self.stats.bytes_hit += bytes;
                self.touch += 1;
                let stamp = self.touch;
                if let Some(e) = self.caches[node].entries.get_mut(&f) {
                    e.stamp = stamp;
                }
            } else {
                self.stats.misses += 1;
                need += bytes;
                files.push(f);
            }
        }
        self.inputs[task.0 as usize] = input_ids;
        if need == 0 {
            self.stats.stage_in.add(0.0);
            return StageStart::Ready;
        }
        self.launch(now, pod, node, task, tenant, Dir::In, need, files, out);
        StageStart::Wait
    }

    /// Begin writing `task`'s output back to the backend. Returns `Ready`
    /// for zero-byte outputs.
    pub fn begin_stage_out(
        &mut self,
        now: SimTime,
        pod: PodId,
        node: usize,
        task: TaskId,
        tenant: usize,
        out: &mut Vec<FlowEvent>,
    ) -> StageStart {
        let bytes = self.file_bytes[task.0 as usize];
        if bytes == 0 {
            self.stats.stage_out.add(0.0);
            return StageStart::Ready;
        }
        let files = vec![task.0];
        self.launch(now, pod, node, task, tenant, Dir::Out, bytes, files, out);
        StageStart::Wait
    }

    #[allow(clippy::too_many_arguments)]
    fn launch(
        &mut self,
        now: SimTime,
        pod: PodId,
        node: usize,
        task: TaskId,
        tenant: usize,
        dir: Dir,
        bytes: u64,
        files: Vec<u32>,
        out: &mut Vec<FlowEvent>,
    ) {
        let id = self.flows.len() as u32;
        self.flows.push(Flow {
            pod,
            task,
            node,
            tenant,
            dir,
            total: bytes,
            remaining: bytes as f64,
            rate: 0.0,
            live: true,
            active: false,
            gen: 0,
            begun_at: now,
            sched_at: u64::MAX,
            files,
        });
        self.ensure_pod(pod);
        debug_assert_eq!(self.pod_flow[pod.0 as usize], NO_FLOW, "one stage at a time");
        self.pod_flow[pod.0 as usize] = id;
        match self.cfg.backend {
            Backend::ObjectStore { latency_ms, .. } if latency_ms > 0 => {
                // the request round-trip runs before any byte moves
                out.push(FlowEvent {
                    flow: id,
                    gen: 0,
                    at: now + SimTime::from_millis(latency_ms),
                    activate: true,
                });
            }
            _ => self.activate_flow(now, id, out),
        }
    }

    /// An object-store request's latency elapsed: the flow joins fair
    /// sharing (no-op if the pod died in the meantime).
    pub fn activate(&mut self, now: SimTime, flow: u32, gen: u32, out: &mut Vec<FlowEvent>) {
        let f = &self.flows[flow as usize];
        if !f.live || f.active || f.gen != gen {
            return;
        }
        self.activate_flow(now, flow, out);
    }

    fn activate_flow(&mut self, now: SimTime, flow: u32, out: &mut Vec<FlowEvent>) {
        self.flows[flow as usize].active = true;
        self.active.push(flow);
        self.recompute(now, out);
    }

    /// Advance every active flow's progress to `now` at its current rate.
    fn advance_all(&mut self, now: SimTime) {
        let now_ms = now.as_millis();
        let dt = now_ms.saturating_sub(self.last_ms) as f64;
        if dt > 0.0 {
            for &id in &self.active {
                let f = &mut self.flows[id as usize];
                f.remaining = (f.remaining - f.rate * dt).max(0.0);
            }
        }
        self.last_ms = now_ms;
    }

    /// Recompute max-min fair rates for every active flow and (re)schedule
    /// completion checks whose times moved.
    fn recompute(&mut self, now: SimTime, out: &mut Vec<FlowEvent>) {
        self.advance_all(now);
        if self.active.is_empty() {
            return;
        }
        let n_nodes = self.caches.len();
        let nic = gbps_to_bytes_per_ms(NIC_GBPS);
        self.caps_buf.clear();
        self.caps_buf.resize(n_nodes, nic);
        let (server, stream_cap) = match self.cfg.backend {
            Backend::Nfs { gbps } => {
                self.caps_buf.push(gbps_to_bytes_per_ms(gbps));
                (Some(n_nodes), f64::INFINITY)
            }
            Backend::ObjectStore { stream_gbps, .. } => {
                (None, gbps_to_bytes_per_ms(stream_gbps))
            }
        };
        while self.reqs_buf.len() < self.active.len() {
            self.reqs_buf.push(FlowReq {
                links: Vec::with_capacity(2),
                cap: f64::INFINITY,
            });
        }
        for (k, &id) in self.active.iter().enumerate() {
            let node = self.flows[id as usize].node;
            let r = &mut self.reqs_buf[k];
            r.links.clear();
            r.links.push(node);
            if let Some(s) = server {
                r.links.push(s);
            }
            r.cap = stream_cap;
        }
        let shares = self
            .ws
            .shares(&self.caps_buf, &self.reqs_buf[..self.active.len()]);
        let now_ms = now.as_millis();
        for (k, &id) in self.active.iter().enumerate() {
            let f = &mut self.flows[id as usize];
            f.rate = shares[k];
            debug_assert!(f.rate > 0.0, "active flow with zero rate");
            let eta = if f.rate > 0.0 {
                (f.remaining / f.rate).ceil() as u64
            } else {
                0
            };
            let at = now_ms + eta.max(1);
            if at != f.sched_at {
                f.gen += 1;
                f.sched_at = at;
                out.push(FlowEvent {
                    flow: id,
                    gen: f.gen,
                    at: SimTime::from_millis(at),
                    activate: false,
                });
            }
        }
    }

    /// A scheduled completion check fired. Returns the completed flow's
    /// identity if it genuinely finished (stale generations and canceled
    /// flows return `None`); pushes any rate-change reschedules to `out`.
    pub fn flow_done(
        &mut self,
        now: SimTime,
        flow: u32,
        gen: u32,
        out: &mut Vec<FlowEvent>,
    ) -> Option<FlowDone> {
        {
            let f = &self.flows[flow as usize];
            if !f.live || !f.active || f.gen != gen {
                return None;
            }
        }
        self.advance_all(now);
        let f = &mut self.flows[flow as usize];
        if f.remaining > 0.5 {
            // rounding drift: not actually done — reschedule
            let eta = (f.remaining / f.rate).ceil() as u64;
            f.gen += 1;
            f.sched_at = now.as_millis() + eta.max(1);
            out.push(FlowEvent {
                flow,
                gen: f.gen,
                at: SimTime::from_millis(f.sched_at),
                activate: false,
            });
            return None;
        }
        f.live = false;
        f.active = false;
        let pod = f.pod;
        let task = f.task;
        let node = f.node;
        let tenant = f.tenant;
        let dir = f.dir;
        let total = f.total;
        let dur = now.saturating_sub(f.begun_at);
        let files = std::mem::take(&mut f.files);
        self.active.retain(|&id| id != flow);
        self.pod_flow[pod.0 as usize] = NO_FLOW;
        self.stats.transfers += 1;
        self.stats.io_ms += dur.as_millis();
        self.stats.add_tenant_bytes(tenant, total);
        match dir {
            Dir::In => {
                self.stats.bytes_in += total;
                self.stats.stage_in.add(dur.as_secs_f64());
            }
            Dir::Out => {
                self.stats.bytes_out += total;
                self.stats.stage_out.add(dur.as_secs_f64());
            }
        }
        for fid in files {
            self.cache_insert(node, fid, pod);
        }
        self.recompute(now, out);
        Some(FlowDone {
            pod,
            task,
            inbound: dir == Dir::In,
            bytes: total,
            dur,
        })
    }

    /// A pod terminated (normal completion, scale-down, or chaos kill):
    /// cancel its in-flight transfer and drop its cache entries — the
    /// ephemeral scratch dies with the pod.
    pub fn cancel_pod(
        &mut self,
        now: SimTime,
        pod: PodId,
        node: Option<usize>,
        out: &mut Vec<FlowEvent>,
    ) {
        let i = pod.0 as usize;
        if i >= self.pod_flow.len() {
            return;
        }
        let flow = self.pod_flow[i];
        if flow != NO_FLOW {
            self.pod_flow[i] = NO_FLOW;
            let f = &mut self.flows[flow as usize];
            f.live = false;
            if f.active {
                f.active = false;
                self.active.retain(|&id| id != flow);
                self.recompute(now, out);
            }
        }
        if self.pod_owned[i] > 0 {
            if let Some(n) = node {
                let cache = &mut self.caches[n];
                let mut freed = 0u64;
                cache.entries.retain(|_, e| {
                    if e.owner == pod {
                        freed += e.bytes;
                        false
                    } else {
                        true
                    }
                });
                cache.used -= freed;
            }
            self.pod_owned[i] = 0;
        }
    }

    /// Freeze the run's accounting.
    pub fn report(&self) -> DataReport {
        self.stats.report()
    }
}

impl DataLocality for DataPlane {
    fn cached_input_bytes(&self, payload: &Payload, node: &Node) -> u64 {
        match payload {
            Payload::JobBatch { tasks } => tasks
                .iter()
                .map(|&t| self.cached_input_bytes_of(t, node.id.0))
                .sum(),
            // worker pods carry no tasks at placement time
            Payload::Worker { .. } => 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::k8s::resources::Resources;
    use crate::workflow::task::TaskType;

    #[test]
    fn parses_full_specs() {
        let c = DataConfig::parse_spec("nfs:2,cache:4,locality:on").unwrap();
        assert_eq!(c.backend, Backend::Nfs { gbps: 2.0 });
        assert_eq!(c.cache_bytes, 4_000_000_000);
        assert!(c.locality);
        let c = DataConfig::parse_spec("s3:30x1.5").unwrap();
        assert_eq!(
            c.backend,
            Backend::ObjectStore {
                latency_ms: 30,
                stream_gbps: 1.5
            }
        );
        assert!(!c.locality);
        assert_eq!(c.cache_bytes, (DEFAULT_CACHE_GB * 1e9) as u64);
    }

    #[test]
    fn rejects_malformed_specs() {
        for bad in [
            "",                  // no backend
            "cache:4",           // no backend either
            "nfs",               // no value
            "nfs:x",             // not a number
            "nfs:0",             // zero bandwidth
            "nfs:-1",            // negative
            "nfs:1,s3:10x1",     // two backends
            "s3:10",             // missing stream bandwidth
            "s3:ax1",            // bad latency
            "cache:-2,nfs:1",    // negative cache
            "locality:maybe,nfs:1",
            "flux:9",            // unknown kind
        ] {
            assert!(DataConfig::parse_spec(bad).is_err(), "accepted: {bad}");
        }
    }

    fn two_task_dag(out0: u64, ext0: u64, out1: u64) -> Dag {
        let mut d = Dag::new("d");
        let ty = d.add_type(TaskType::new("T", Resources::new(500, 512), 1.0, 0.0));
        let a = d.add_task(ty, SimTime(1000), &[]);
        d.set_io(a, ext0, out0);
        let b = d.add_task(ty, SimTime(1000), &[a]);
        d.set_io(b, 0, out1);
        d
    }

    #[test]
    fn inputs_are_pred_outputs_plus_external() {
        let dag = two_task_dag(100, 40, 7);
        let dp = DataPlane::new(DataConfig::nfs(1.0), &dag, 2);
        // task 0: external input only (file id n_tasks + 0 = 2)
        assert_eq!(dp.inputs[0], vec![2]);
        assert_eq!(dp.file_bytes[2], 40);
        // task 1: task 0's output
        assert_eq!(dp.inputs[1], vec![0]);
        assert_eq!(dp.file_bytes[0], 100);
    }

    #[test]
    fn stage_in_flows_complete_and_populate_the_cache() {
        let dag = two_task_dag(1_000_000, 500_000, 2_000);
        let mut dp = DataPlane::new(DataConfig::nfs(1.0), &dag, 1);
        let mut out = Vec::new();
        let pod = PodId(0);
        // task 0 stages its 500 kB external input
        let s = dp.begin_stage_in(SimTime::ZERO, pod, 0, TaskId(0), 0, &mut out);
        assert_eq!(s, StageStart::Wait);
        assert_eq!(out.len(), 1);
        let ev = out[0];
        assert!(!ev.activate);
        // 500 kB over 1 Gbit/s = 4 ms
        assert_eq!(ev.at, SimTime(4));
        out.clear();
        let done = dp.flow_done(ev.at, ev.flow, ev.gen, &mut out).unwrap();
        assert!(done.inbound);
        assert_eq!(done.task, TaskId(0));
        assert_eq!(dp.stats.bytes_in, 500_000);
        assert!(dp.cached(0, 2), "fetched input cached on the node");
        // stage-out of task 0's 1 MB output
        out.clear();
        let s = dp.begin_stage_out(SimTime(10), pod, 0, TaskId(0), 0, &mut out);
        assert_eq!(s, StageStart::Wait);
        let ev = out[0];
        out.clear();
        let done = dp.flow_done(ev.at, ev.flow, ev.gen, &mut out).unwrap();
        assert!(!done.inbound);
        assert_eq!(dp.stats.bytes_out, 1_000_000);
        assert!(dp.cached(0, 0), "produced output cached on the node");
        // task 1 on the same node: its input (task 0's output) is a hit
        out.clear();
        let s = dp.begin_stage_in(SimTime(20), PodId(0), 0, TaskId(1), 0, &mut out);
        assert_eq!(s, StageStart::Ready, "warm cache serves the input");
        assert_eq!(dp.stats.bytes_hit, 1_000_000);
        assert_eq!(dp.stats.hits, 1);
    }

    #[test]
    fn concurrent_flows_share_the_nfs_link_fairly() {
        // two 1 MB stage-ins on different nodes share a 1 Gbit/s server:
        // each gets 500 Mbit/s -> 16 ms instead of 8
        let mut d = Dag::new("d");
        let ty = d.add_type(TaskType::new("T", Resources::new(500, 512), 1.0, 0.0));
        for _ in 0..2 {
            let t = d.add_task(ty, SimTime(1000), &[]);
            d.set_io(t, 1_000_000, 0);
        }
        let mut dp = DataPlane::new(DataConfig::nfs(1.0), &d, 2);
        let mut out = Vec::new();
        dp.begin_stage_in(SimTime::ZERO, PodId(0), 0, TaskId(0), 0, &mut out);
        assert_eq!(out.last().unwrap().at, SimTime(8), "alone: full bandwidth");
        out.clear();
        dp.begin_stage_in(SimTime::ZERO, PodId(1), 1, TaskId(1), 0, &mut out);
        // both flows rescheduled at the halved rate
        let times: Vec<u64> = out.iter().map(|e| e.at.as_millis()).collect();
        assert_eq!(times, vec![16, 16]);
    }

    #[test]
    fn canceling_a_pod_drops_its_flow_and_cache_entries() {
        let dag = two_task_dag(1_000_000, 500_000, 2_000);
        let mut dp = DataPlane::new(DataConfig::nfs(1.0), &dag, 1);
        let mut out = Vec::new();
        dp.begin_stage_in(SimTime::ZERO, PodId(0), 0, TaskId(0), 0, &mut out);
        let ev = out[0];
        out.clear();
        dp.cancel_pod(SimTime(2), PodId(0), Some(0), &mut out);
        // the scheduled completion is now stale
        assert!(dp.flow_done(ev.at, ev.flow, ev.gen, &mut out).is_none());
        assert_eq!(dp.stats.bytes_in, 0, "canceled transfers move nothing");
        // a pod that cached entries loses them on termination
        let mut dp = DataPlane::new(DataConfig::nfs(1.0), &dag, 1);
        out.clear();
        dp.begin_stage_in(SimTime::ZERO, PodId(0), 0, TaskId(0), 0, &mut out);
        let ev = out[0];
        out.clear();
        dp.flow_done(ev.at, ev.flow, ev.gen, &mut out).unwrap();
        assert!(dp.cached(0, 2));
        dp.cancel_pod(SimTime(10), PodId(0), Some(0), &mut out);
        assert!(!dp.cached(0, 2), "emptyDir dies with the pod");
        assert_eq!(dp.caches[0].used, 0);
    }

    #[test]
    fn lru_eviction_respects_capacity() {
        let mut d = Dag::new("d");
        let ty = d.add_type(TaskType::new("T", Resources::ZERO, 1.0, 0.0));
        for _ in 0..3 {
            let t = d.add_task(ty, SimTime(1), &[]);
            d.set_io(t, 0, 600);
        }
        let mut cfg = DataConfig::nfs(1.0);
        cfg.cache_bytes = 1_000; // fits one 600-byte file
        let mut dp = DataPlane::new(cfg, &d, 1);
        dp.cache_insert(0, 0, PodId(0));
        dp.cache_insert(0, 1, PodId(0));
        assert!(!dp.cached(0, 0), "LRU evicted the older file");
        assert!(dp.cached(0, 1));
        assert_eq!(dp.stats.evictions, 1);
        assert!(dp.caches[0].used <= 1_000);
    }

    #[test]
    fn object_store_defers_activation_by_the_request_latency() {
        let dag = two_task_dag(0, 1_000_000, 0);
        let cfg = DataConfig::parse_spec("s3:25x1").unwrap();
        let mut dp = DataPlane::new(cfg, &dag, 1);
        let mut out = Vec::new();
        dp.begin_stage_in(SimTime::ZERO, PodId(0), 0, TaskId(0), 0, &mut out);
        assert_eq!(out.len(), 1);
        assert!(out[0].activate);
        assert_eq!(out[0].at, SimTime(25));
        let act = out[0];
        out.clear();
        dp.activate(act.at, act.flow, act.gen, &mut out);
        // 1 MB at 1 Gbit/s per-stream = 8 ms after the 25 ms request
        assert_eq!(out[0].at, SimTime(33));
    }
}
