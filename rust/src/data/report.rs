//! Data-plane accounting: bytes moved, cache effectiveness, stage-in/out
//! latency percentiles, and the compute-vs-I/O breakdown.
//!
//! Definitions (EXPERIMENTS.md §"Data plane / storage"):
//!
//! * **bytes in / out** — bytes actually moved over the network by
//!   stage-in (backend -> node) and stage-out (node -> backend) transfers.
//!   Cache hits move nothing and are counted separately.
//! * **cache hit ratio** — `hit_bytes / (hit_bytes + bytes_in)`: the
//!   fraction of input bytes served from a node-local ephemeral cache.
//! * **stage-in p50/95/99** — per-task stage-in durations (seconds),
//!   including the zero-duration fully-cached case — a warm cache shows up
//!   directly as a collapsed stage-in tail.
//! * **I/O fraction** — `io_ms / (io_ms + compute_ms)` where `io_ms` sums
//!   every task's serial stage-in + stage-out time and `compute_ms` sums
//!   execution time. This is per-task serial time, not wall-clock overlap.

use crate::util::json::Json;
use crate::util::stats::Summary;

/// Mutable accumulator the driver and [`super::DataPlane`] update.
#[derive(Debug, Default)]
pub struct DataStats {
    pub enabled: bool,
    /// Bytes fetched over the network by stage-in transfers.
    pub bytes_in: u64,
    /// Bytes written back by stage-out transfers.
    pub bytes_out: u64,
    /// Input bytes served from a node-local cache (no transfer).
    pub bytes_hit: u64,
    /// Input-file cache hits / misses (file-granularity counts).
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    /// Completed network transfers (stage-in + stage-out flows).
    pub transfers: u64,
    /// Per-task stage-in durations, seconds (0.0 when fully cached).
    pub stage_in: Summary,
    /// Per-task stage-out durations, seconds.
    pub stage_out: Summary,
    /// Sum of task execution time (net of executor overhead), ms.
    pub compute_ms: u64,
    /// Sum of per-task serial stage-in + stage-out time, ms.
    pub io_ms: u64,
    /// Bytes moved per tenant lane (stage-in + stage-out; fleet runs).
    pub bytes_by_tenant: Vec<u64>,
}

impl DataStats {
    /// Size the per-tenant lanes (fleet runs; single runs keep one lane).
    pub fn set_tenants(&mut self, n: usize) {
        self.bytes_by_tenant.resize(n.max(1), 0);
    }

    pub fn add_tenant_bytes(&mut self, tenant: usize, bytes: u64) {
        if self.bytes_by_tenant.is_empty() {
            self.set_tenants(1);
        }
        let lane = tenant.min(self.bytes_by_tenant.len() - 1);
        self.bytes_by_tenant[lane] += bytes;
    }

    /// Freeze the accumulator into the report attached to a `SimResult`.
    pub fn report(&self) -> DataReport {
        let stage_in = self.stage_in.percentile_row();
        DataReport {
            enabled: self.enabled,
            bytes_in: self.bytes_in,
            bytes_out: self.bytes_out,
            bytes_hit: self.bytes_hit,
            hits: self.hits,
            misses: self.misses,
            evictions: self.evictions,
            transfers: self.transfers,
            stage_ins: self.stage_in.len(),
            stage_in_mean_s: self.stage_in.mean(),
            stage_in_p50_s: stage_in.p50,
            stage_in_p95_s: stage_in.p95,
            stage_in_p99_s: stage_in.p99,
            stage_out_p95_s: self.stage_out.percentile(95.0),
            compute_ms: self.compute_ms,
            io_ms: self.io_ms,
            bytes_by_tenant: self.bytes_by_tenant.clone(),
        }
    }
}

/// Immutable data-plane summary of one run (all-zero with
/// `enabled == false` when the data plane is off).
#[derive(Debug, Clone, Default)]
pub struct DataReport {
    pub enabled: bool,
    pub bytes_in: u64,
    pub bytes_out: u64,
    pub bytes_hit: u64,
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    pub transfers: u64,
    pub stage_ins: usize,
    pub stage_in_mean_s: f64,
    pub stage_in_p50_s: f64,
    pub stage_in_p95_s: f64,
    pub stage_in_p99_s: f64,
    pub stage_out_p95_s: f64,
    pub compute_ms: u64,
    pub io_ms: u64,
    pub bytes_by_tenant: Vec<u64>,
}

impl DataReport {
    /// Total bytes moved over the network in either direction.
    pub fn bytes_moved(&self) -> u64 {
        self.bytes_in + self.bytes_out
    }

    /// Fraction of input bytes served from cache; 1.0 when every input
    /// byte was cached (or nothing was read).
    pub fn cache_hit_ratio(&self) -> f64 {
        let total = self.bytes_hit + self.bytes_in;
        if total == 0 {
            return 1.0;
        }
        self.bytes_hit as f64 / total as f64
    }

    /// Fraction of per-task serial time spent in I/O rather than compute.
    pub fn io_frac(&self) -> f64 {
        let total = self.io_ms + self.compute_ms;
        if total == 0 {
            return 0.0;
        }
        self.io_ms as f64 / total as f64
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("enabled", self.enabled.into()),
            ("bytes_in", self.bytes_in.into()),
            ("bytes_out", self.bytes_out.into()),
            ("bytes_moved", self.bytes_moved().into()),
            ("bytes_hit", self.bytes_hit.into()),
            ("cache_hit_ratio", self.cache_hit_ratio().into()),
            ("hits", self.hits.into()),
            ("misses", self.misses.into()),
            ("evictions", self.evictions.into()),
            ("transfers", self.transfers.into()),
            ("stage_ins", self.stage_ins.into()),
            ("stage_in_mean_s", self.stage_in_mean_s.into()),
            ("stage_in_p50_s", self.stage_in_p50_s.into()),
            ("stage_in_p95_s", self.stage_in_p95_s.into()),
            ("stage_in_p99_s", self.stage_in_p99_s.into()),
            ("stage_out_p95_s", self.stage_out_p95_s.into()),
            ("compute_ms", self.compute_ms.into()),
            ("io_ms", self.io_ms.into()),
            ("io_frac", self.io_frac().into()),
            (
                "bytes_by_tenant",
                Json::Arr(self.bytes_by_tenant.iter().map(|&v| v.into()).collect()),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_report_is_inert() {
        let r = DataStats::default().report();
        assert!(!r.enabled);
        assert_eq!(r.bytes_moved(), 0);
        assert_eq!(r.cache_hit_ratio(), 1.0);
        assert_eq!(r.io_frac(), 0.0);
    }

    #[test]
    fn ratios_from_known_counters() {
        let mut s = DataStats {
            enabled: true,
            ..Default::default()
        };
        s.bytes_in = 750;
        s.bytes_hit = 250;
        s.bytes_out = 100;
        s.compute_ms = 900;
        s.io_ms = 100;
        let r = s.report();
        assert!((r.cache_hit_ratio() - 0.25).abs() < 1e-12);
        assert_eq!(r.bytes_moved(), 850);
        assert!((r.io_frac() - 0.1).abs() < 1e-12);
    }

    #[test]
    fn stage_in_percentiles_survive_the_report() {
        let mut s = DataStats::default();
        for v in 0..=100 {
            s.stage_in.add(v as f64);
        }
        let r = s.report();
        assert_eq!(r.stage_ins, 101);
        assert!((r.stage_in_p50_s - 50.0).abs() < 1e-9);
        assert!((r.stage_in_p95_s - 95.0).abs() < 1e-9);
        assert!((r.stage_in_p99_s - 99.0).abs() < 1e-9);
        let j = r.to_json().to_string();
        assert!(j.contains("stage_in_p99_s"));
        assert!(j.contains("cache_hit_ratio"));
    }

    #[test]
    fn tenant_lanes_clamp_like_the_chaos_lanes() {
        let mut s = DataStats::default();
        s.set_tenants(2);
        s.add_tenant_bytes(0, 10);
        s.add_tenant_bytes(1, 20);
        s.add_tenant_bytes(9, 5); // clamps to the last lane
        assert_eq!(s.bytes_by_tenant, vec![10, 25]);
        // unsized lanes auto-size to one
        let mut t = DataStats::default();
        t.add_tenant_bytes(0, 7);
        assert_eq!(t.bytes_by_tenant, vec![7]);
    }
}
