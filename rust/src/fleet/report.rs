//! Per-tenant SLO reporting for fleet runs.
//!
//! Definitions (all per instance, aggregated per tenant):
//!
//! * **queueing delay** — `admitted - arrival`: time spent waiting for an
//!   admission slot under the cap (0 without a cap);
//! * **makespan** — `finished - admitted`: execution span on the shared
//!   cluster;
//! * **slowdown** — `(finished - arrival) / ideal`, where `ideal` is the
//!   instance's critical-path length in isolation. Slowdown is the
//!   standard open-loop service metric: 1.0 is the physical optimum, and
//!   it diverges as the arrival rate crosses the saturation knee.
//!
//! Percentiles come from [`crate::util::stats::Summary`] (p50/p95/p99 —
//! the p99 column is what an operator would put an SLO on).

use super::FleetResult;
use crate::util::json::Json;
use crate::util::stats::Summary;

/// Aggregated statistics for one tenant.
#[derive(Debug, Clone)]
pub struct TenantRow {
    pub tenant: u16,
    pub instances: usize,
    pub queue_delay_mean_s: f64,
    pub makespan_mean_s: f64,
    pub slowdown_mean: f64,
    pub slowdown_p50: f64,
    pub slowdown_p95: f64,
    pub slowdown_p99: f64,
    /// Resilience SLO columns under churn (chaos runs; 0 otherwise):
    /// compute-seconds this tenant lost to faults, and how many of its
    /// tasks/batches were re-dispatched.
    pub wasted_s: f64,
    pub retries: u64,
    /// Data-plane SLO column (data runs; 0 otherwise): decimal GB this
    /// tenant moved over the network (stage-in + stage-out).
    pub gb_moved: f64,
    /// Isolation SLO columns (isolation runs; 0 otherwise): admissions
    /// this tenant had throttled at its ResourceQuota, placement
    /// violations it suffered (its tasks executing on foreign-owned
    /// nodes), and compute-seconds of *this* tenant's in-flight work
    /// caught in another tenant's takeover blast radius.
    pub quota_throttles: u64,
    pub violations: u64,
    pub takeover_exposed_s: f64,
    /// Critical-path attribution columns (flight-recorder runs only; all
    /// zero otherwise): mean seconds per phase over this tenant's
    /// instances, from each instance's own critical path.
    pub crit_queue_s: f64,
    pub crit_sched_s: f64,
    pub crit_pod_start_s: f64,
    pub crit_stage_in_s: f64,
    pub crit_compute_s: f64,
    pub crit_stage_out_s: f64,
    pub crit_recovery_s: f64,
    /// Monitoring-stack SLO columns (`--monitor` runs only; zero
    /// otherwise): firing episodes of this tenant's scoped alerts
    /// (slowdown age + burn-rate budget), and total seconds those alerts
    /// spent firing.
    pub alerts_fired: u64,
    pub alert_firing_s: f64,
}

/// Fleet-wide headline numbers (one saturation-sweep point).
#[derive(Debug, Clone)]
pub struct FleetSummary {
    pub instances: usize,
    /// End of the run: last instance completion (seconds).
    pub span_s: f64,
    /// Completed-instance throughput over the whole run.
    pub completed_per_hour: f64,
    pub mean_queue_delay_s: f64,
    pub mean_slowdown: f64,
    pub slowdown_p99: f64,
    /// Average allocated-CPU fraction of the cluster over the run.
    pub utilization: f64,
}

/// Per-tenant accumulators over the outcome/meta pairs.
fn tenant_summaries(res: &FleetResult) -> Vec<(Summary, Summary, Summary)> {
    let mut acc: Vec<(Summary, Summary, Summary)> = (0..res.n_tenants)
        .map(|_| (Summary::new(), Summary::new(), Summary::new()))
        .collect();
    for (o, m) in res.outcomes.iter().zip(&res.metas) {
        let (delay, makespan, slowdown) = &mut acc[o.tenant as usize];
        delay.add((o.admitted - o.arrival).as_secs_f64());
        makespan.add((o.finished - o.admitted).as_secs_f64());
        slowdown.add((o.finished - o.arrival).as_secs_f64() / m.ideal_s.max(1e-9));
    }
    acc
}

/// Per-tenant mean attribution seconds (7 phases), from the flight
/// recorder's per-instance critical paths. All zero when obs is off.
fn tenant_crit_means(res: &FleetResult) -> Vec<[f64; 7]> {
    let mut sums: Vec<([f64; 7], usize)> = vec![([0.0; 7], 0); res.n_tenants];
    if let Some(o) = &res.sim.obs {
        for (m, a) in res.metas.iter().zip(&o.instance_attr) {
            let Some(a) = a else { continue };
            let (s, n) = &mut sums[m.tenant as usize];
            for (slot, ms) in s.iter_mut().zip([
                a.queueing_ms,
                a.scheduling_ms,
                a.pod_start_ms,
                a.stage_in_ms,
                a.compute_ms,
                a.stage_out_ms,
                a.recovery_ms,
            ]) {
                *slot += ms as f64 / 1000.0;
            }
            *n += 1;
        }
    }
    sums.into_iter()
        .map(|(s, n)| {
            if n == 0 {
                [0.0; 7]
            } else {
                s.map(|v| v / n as f64)
            }
        })
        .collect()
}

/// Per-tenant SLO rows (every tenant, including ones with no arrivals).
pub fn per_tenant(res: &FleetResult) -> Vec<TenantRow> {
    let chaos = &res.sim.chaos;
    let data = &res.sim.data;
    let iso = &res.sim.isolation;
    let mon = res.sim.monitor.as_ref();
    let crit = tenant_crit_means(res);
    tenant_summaries(res)
        .into_iter()
        .enumerate()
        .map(|(t, (delay, makespan, slowdown))| {
            let row = slowdown.percentile_row();
            TenantRow {
                tenant: t as u16,
                instances: slowdown.len(),
                queue_delay_mean_s: delay.mean(),
                makespan_mean_s: makespan.mean(),
                slowdown_mean: slowdown.mean(),
                slowdown_p50: row.p50,
                slowdown_p95: row.p95,
                slowdown_p99: row.p99,
                wasted_s: chaos.wasted_ms_by_tenant.get(t).copied().unwrap_or(0) as f64 / 1000.0,
                retries: chaos.retries_by_tenant.get(t).copied().unwrap_or(0),
                gb_moved: data.bytes_by_tenant.get(t).copied().unwrap_or(0) as f64 / 1e9,
                quota_throttles: iso.quota_throttles_by_tenant.get(t).copied().unwrap_or(0),
                violations: iso.violations_by_tenant.get(t).copied().unwrap_or(0),
                takeover_exposed_s: iso
                    .takeover_exposed_ms_by_tenant
                    .get(t)
                    .copied()
                    .unwrap_or(0) as f64
                    / 1000.0,
                crit_queue_s: crit[t][0],
                crit_sched_s: crit[t][1],
                crit_pod_start_s: crit[t][2],
                crit_stage_in_s: crit[t][3],
                crit_compute_s: crit[t][4],
                crit_stage_out_s: crit[t][5],
                crit_recovery_s: crit[t][6],
                alerts_fired: mon.map(|m| m.tenant_fired(t as u16)).unwrap_or(0),
                alert_firing_s: mon.map(|m| m.tenant_firing_ms(t as u16)).unwrap_or(0) as f64
                    / 1000.0,
            }
        })
        .collect()
}

/// Fleet-wide aggregate (the numbers `BENCH_fleet.json` tracks per
/// arrival-rate point).
pub fn aggregate(res: &FleetResult) -> FleetSummary {
    let mut delay = Summary::new();
    let mut slowdown = Summary::new();
    for (o, m) in res.outcomes.iter().zip(&res.metas) {
        delay.add((o.admitted - o.arrival).as_secs_f64());
        slowdown.add((o.finished - o.arrival).as_secs_f64() / m.ideal_s.max(1e-9));
    }
    let span_s = res.sim.makespan.as_secs_f64();
    let completed_per_hour = if span_s > 0.0 {
        res.outcomes.len() as f64 * 3600.0 / span_s
    } else {
        0.0
    };
    FleetSummary {
        instances: res.outcomes.len(),
        span_s,
        completed_per_hour,
        mean_queue_delay_s: delay.mean(),
        mean_slowdown: slowdown.mean(),
        slowdown_p99: slowdown.percentile(99.0),
        utilization: res.sim.avg_cpu_utilization,
    }
}

/// Deterministic fixed-width text table (the `hyperflow serve` output).
/// Flight-recorder runs gain seven `crit-*` attribution columns.
pub fn render_table(res: &FleetResult) -> String {
    let with_crit = res.sim.obs.is_some();
    let with_mon = res.sim.monitor.is_some();
    let mut out = String::from(
        "tenant  instances  qdelay-mean-s  makespan-mean-s  \
         slowdown-mean  slowdown-p50  slowdown-p95  slowdown-p99  \
         wasted-s  retries  gb-moved  quota-thr  iso-viol  tko-exposed-s",
    );
    if with_crit {
        out.push_str(
            "  crit-queue-s  crit-sched-s  crit-podstart-s  \
             crit-stagein-s  crit-compute-s  crit-stageout-s  crit-recovery-s",
        );
    }
    if with_mon {
        out.push_str("  alerts-fired  alert-firing-s");
    }
    out.push('\n');
    for r in per_tenant(res) {
        out.push_str(&format!(
            "{:>6}  {:>9}  {:>13.1}  {:>15.1}  {:>13.2}  {:>12.2}  {:>12.2}  {:>12.2}  {:>8.1}  {:>7}  {:>8.2}  {:>9}  {:>8}  {:>13.1}",
            r.tenant,
            r.instances,
            r.queue_delay_mean_s,
            r.makespan_mean_s,
            r.slowdown_mean,
            r.slowdown_p50,
            r.slowdown_p95,
            r.slowdown_p99,
            r.wasted_s,
            r.retries,
            r.gb_moved,
            r.quota_throttles,
            r.violations,
            r.takeover_exposed_s,
        ));
        if with_crit {
            out.push_str(&format!(
                "  {:>12.1}  {:>12.1}  {:>15.1}  {:>14.1}  {:>14.1}  {:>15.1}  {:>15.1}",
                r.crit_queue_s,
                r.crit_sched_s,
                r.crit_pod_start_s,
                r.crit_stage_in_s,
                r.crit_compute_s,
                r.crit_stage_out_s,
                r.crit_recovery_s,
            ));
        }
        if with_mon {
            out.push_str(&format!(
                "  {:>12}  {:>14.1}",
                r.alerts_fired, r.alert_firing_s,
            ));
        }
        out.push('\n');
    }
    out
}

/// JSON export of the fleet report (`hyperflow serve --json`).
pub fn to_json(res: &FleetResult) -> Json {
    let agg = aggregate(res);
    let with_crit = res.sim.obs.is_some();
    let with_mon = res.sim.monitor.is_some();
    let tenants: Vec<Json> = per_tenant(res)
        .into_iter()
        .map(|r| {
            let mut fields = vec![
                ("tenant", (r.tenant as u64).into()),
                ("instances", r.instances.into()),
                ("queue_delay_mean_s", r.queue_delay_mean_s.into()),
                ("makespan_mean_s", r.makespan_mean_s.into()),
                ("slowdown_mean", r.slowdown_mean.into()),
                ("slowdown_p50", r.slowdown_p50.into()),
                ("slowdown_p95", r.slowdown_p95.into()),
                ("slowdown_p99", r.slowdown_p99.into()),
                ("wasted_s", r.wasted_s.into()),
                ("retries", r.retries.into()),
                ("gb_moved", r.gb_moved.into()),
                ("quota_throttles", r.quota_throttles.into()),
                ("violations", r.violations.into()),
                ("takeover_exposed_s", r.takeover_exposed_s.into()),
            ];
            if with_crit {
                fields.extend([
                    ("crit_queue_s", r.crit_queue_s.into()),
                    ("crit_sched_s", r.crit_sched_s.into()),
                    ("crit_pod_start_s", r.crit_pod_start_s.into()),
                    ("crit_stage_in_s", r.crit_stage_in_s.into()),
                    ("crit_compute_s", r.crit_compute_s.into()),
                    ("crit_stage_out_s", r.crit_stage_out_s.into()),
                    ("crit_recovery_s", r.crit_recovery_s.into()),
                ]);
            }
            if with_mon {
                fields.extend([
                    ("alerts_fired", r.alerts_fired.into()),
                    ("alert_firing_s", r.alert_firing_s.into()),
                ]);
            }
            Json::obj(fields)
        })
        .collect();
    Json::obj(vec![
        ("model", Json::str(&res.sim.model_name)),
        ("duration_s", res.duration_s.into()),
        ("instances", agg.instances.into()),
        ("span_s", agg.span_s.into()),
        ("instances_per_hour", agg.completed_per_hour.into()),
        ("mean_queue_delay_s", agg.mean_queue_delay_s.into()),
        ("mean_slowdown", agg.mean_slowdown.into()),
        ("slowdown_p99", agg.slowdown_p99.into()),
        ("utilization", agg.utilization.into()),
        ("chaos", res.sim.chaos.to_json()),
        ("data", res.sim.data.to_json()),
        ("isolation", res.sim.isolation.to_json()),
        (
            "monitor",
            match &res.sim.monitor {
                Some(m) => m.to_json(),
                None => Json::Null,
            },
        ),
        ("tenants", Json::Arr(tenants)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fleet::{InstanceMeta, InstanceOutcome};
    use crate::metrics::Registry;
    use crate::report::{SimResult, Trace};
    use crate::sim::SimTime;

    fn fake_result() -> FleetResult {
        let sim = SimResult {
            model_name: "fleet/worker-pools".into(),
            makespan: SimTime(200_000),
            trace: Trace::new(),
            metrics: Registry::new(),
            pods_created: 0,
            api_requests: 0,
            sched_backoffs: 0,
            sched_binds: 0,
            sim_events: 0,
            event_arena: crate::sim::ArenaStats::default(),
            avg_running_tasks: 0.0,
            avg_cpu_utilization: 0.5,
            chaos: crate::chaos::ChaosReport::default(),
            data: crate::data::DataReport::default(),
            isolation: crate::k8s::isolation::IsolationReport::default(),
            obs: None,
            monitor: None,
        };
        let outcomes = vec![
            InstanceOutcome {
                tenant: 0,
                arrival: SimTime(0),
                admitted: SimTime(10_000),
                finished: SimTime(110_000),
                n_tasks: 10,
            },
            InstanceOutcome {
                tenant: 1,
                arrival: SimTime(0),
                admitted: SimTime(0),
                finished: SimTime(50_000),
                n_tasks: 10,
            },
        ];
        let metas = vec![
            InstanceMeta {
                tenant: 0,
                grid: 3,
                n_tasks: 10,
                ideal_s: 50.0,
            },
            InstanceMeta {
                tenant: 1,
                grid: 3,
                n_tasks: 10,
                ideal_s: 50.0,
            },
        ];
        FleetResult {
            sim,
            outcomes,
            metas,
            duration_s: 100.0,
            n_tenants: 2,
        }
    }

    #[test]
    fn per_tenant_rows_compute_the_defined_metrics() {
        let rows = per_tenant(&fake_result());
        assert_eq!(rows.len(), 2);
        // tenant 0: response 110 s over ideal 50 s => slowdown 2.2
        assert!((rows[0].slowdown_mean - 2.2).abs() < 1e-9);
        assert!((rows[0].queue_delay_mean_s - 10.0).abs() < 1e-9);
        assert!((rows[0].makespan_mean_s - 100.0).abs() < 1e-9);
        // tenant 1: response == ideal => slowdown 1.0, no queueing
        assert!((rows[1].slowdown_mean - 1.0).abs() < 1e-9);
        assert_eq!(rows[1].queue_delay_mean_s, 0.0);
        // single sample: every percentile equals it
        assert_eq!(rows[0].slowdown_p50, rows[0].slowdown_p99);
    }

    #[test]
    fn aggregate_throughput_over_span() {
        let a = aggregate(&fake_result());
        assert_eq!(a.instances, 2);
        // span 200 s => 2 instances = 36/h
        assert!((a.completed_per_hour - 36.0).abs() < 1e-9);
        assert!((a.mean_slowdown - 1.6).abs() < 1e-9);
        assert!((a.mean_queue_delay_s - 5.0).abs() < 1e-9);
        assert_eq!(a.utilization, 0.5);
    }

    #[test]
    fn table_and_json_are_deterministic_and_complete() {
        let r = fake_result();
        assert_eq!(render_table(&r), render_table(&r));
        let t = render_table(&r);
        assert!(t.contains("slowdown-p99"));
        assert!(t.contains("wasted-s"), "resilience columns present");
        assert!(t.contains("gb-moved"), "data-plane column present");
        assert!(t.contains("quota-thr"), "isolation columns present");
        assert!(t.contains("tko-exposed-s"), "isolation columns present");
        assert_eq!(t.lines().count(), 3, "header + one row per tenant");
        let j = to_json(&r).to_string();
        assert!(j.contains("instances_per_hour"));
        assert!(j.contains("slowdown_p99"));
        assert!(j.contains("\"chaos\""), "resilience block exported");
        assert!(j.contains("wasted_s"));
        assert!(j.contains("\"data\""), "data-plane block exported");
        assert!(j.contains("gb_moved"));
        assert!(j.contains("\"isolation\""), "isolation block exported");
        assert!(j.contains("quota_throttles"));
        assert!(j.contains("takeover_exposed_s"));
    }

    #[test]
    fn per_tenant_bytes_column_follows_the_data_report() {
        let mut r = fake_result();
        r.sim.data.enabled = true;
        r.sim.data.bytes_by_tenant = vec![2_000_000_000, 0];
        let rows = per_tenant(&r);
        assert!((rows[0].gb_moved - 2.0).abs() < 1e-9);
        assert_eq!(rows[1].gb_moved, 0.0);
    }

    #[test]
    fn per_tenant_resilience_columns_follow_the_chaos_report() {
        let mut r = fake_result();
        r.sim.chaos.enabled = true;
        r.sim.chaos.wasted_ms_by_tenant = vec![1_500, 0];
        r.sim.chaos.retries_by_tenant = vec![3, 0];
        let rows = per_tenant(&r);
        assert!((rows[0].wasted_s - 1.5).abs() < 1e-9);
        assert_eq!(rows[0].retries, 3);
        assert_eq!(rows[1].retries, 0);
        assert_eq!(rows[1].wasted_s, 0.0);
    }

    #[test]
    fn crit_columns_appear_only_on_flight_recorder_runs() {
        let mut r = fake_result();
        assert!(!render_table(&r).contains("crit-queue-s"));
        assert!(!to_json(&r).to_string().contains("crit_queue_s"));
        // attach a recorder report: instance 0 (tenant 0) attributed,
        // instance 1 (tenant 1) unattributed
        r.sim.obs = Some(crate::obs::ObsReport {
            attribution: None,
            critical_path: Vec::new(),
            events: Vec::new(),
            pods: Vec::new(),
            instance_attr: vec![
                Some(crate::obs::critpath::Attribution {
                    path_tasks: 2,
                    queueing_ms: 1_500,
                    compute_ms: 4_000,
                    ..Default::default()
                }),
                None,
            ],
            phase_rows: Vec::new(),
        });
        let t = render_table(&r);
        assert!(t.contains("crit-queue-s"));
        assert!(t.contains("crit-recovery-s"));
        let rows = per_tenant(&r);
        assert!((rows[0].crit_queue_s - 1.5).abs() < 1e-9);
        assert!((rows[0].crit_compute_s - 4.0).abs() < 1e-9);
        assert_eq!(rows[1].crit_queue_s, 0.0);
        assert!(to_json(&r).to_string().contains("crit_compute_s"));
    }

    #[test]
    fn alert_columns_appear_only_on_monitor_runs() {
        let mut r = fake_result();
        assert!(!render_table(&r).contains("alerts-fired"));
        assert!(!to_json(&r).to_string().contains("alerts_fired"));
        // attach a monitor report with one tenant-1-scoped alert that
        // fired twice for 30 s total
        r.sim.monitor = Some(crate::obs::monitor::MonitorReport {
            interval_ms: 30_000,
            ticks: 7,
            makespan_ms: 200_000,
            alerts: vec![crate::obs::monitor::AlertReport {
                name: "TenantSlowdown::1".into(),
                kind: "threshold",
                severity: "page".into(),
                tenant: Some(1),
                expr: "tenant_active_age_s::1 > 1800".into(),
                fired: 2,
                firing_ms: 30_000,
                final_state: crate::obs::alerts::AlertState::Inactive,
                episodes: Vec::new(),
            }],
            records: Vec::new(),
        });
        let t = render_table(&r);
        assert!(t.contains("alerts-fired"));
        assert!(t.contains("alert-firing-s"));
        let rows = per_tenant(&r);
        assert_eq!(rows[0].alerts_fired, 0, "tenant-0 untouched");
        assert_eq!(rows[1].alerts_fired, 2);
        assert!((rows[1].alert_firing_s - 30.0).abs() < 1e-9);
        let j = to_json(&r).to_string();
        assert!(j.contains("\"monitor\""), "monitor block exported");
        assert!(j.contains("alert_firing_s"));
    }

    #[test]
    fn per_tenant_isolation_columns_follow_the_report() {
        let mut r = fake_result();
        r.sim.isolation.enabled = true;
        r.sim.isolation.quota_throttles_by_tenant = vec![4, 0];
        r.sim.isolation.violations_by_tenant = vec![0, 2];
        r.sim.isolation.takeover_exposed_ms_by_tenant = vec![0, 2_500];
        let rows = per_tenant(&r);
        assert_eq!(rows[0].quota_throttles, 4);
        assert_eq!(rows[0].violations, 0);
        assert_eq!(rows[0].takeover_exposed_s, 0.0);
        assert_eq!(rows[1].quota_throttles, 0);
        assert_eq!(rows[1].violations, 2);
        assert!((rows[1].takeover_exposed_s - 2.5).abs() < 1e-9);
    }
}
