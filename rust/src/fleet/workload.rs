//! Workload generation for the fleet service: turn a [`FleetConfig`] into
//! a concrete, fully deterministic [`FleetPlan`].
//!
//! The pipeline is: sample the aggregate arrival times, assign each
//! arrival a tenant (uniform thinning, so each tenant sees an open-loop
//! stream), draw the instance's Montage grid size from that tenant's mix,
//! generate the instance DAG with a derived seed, and merge everything
//! with [`Dag::disjoint_union`] — instance `i` occupies the contiguous
//! task range starting at the sum of earlier instance lengths, which is
//! how the driver maps tasks back to instances and tenants.

use super::{FleetConfig, FleetPlan, InstanceSpec};
use crate::util::rng::Rng;
use crate::workflow::dag::Dag;
use crate::workflow::montage::{generate, MontageConfig};

/// Static description of one generated instance (index-aligned with
/// [`FleetPlan::instances`] and, after the run, with the outcomes).
#[derive(Debug, Clone)]
pub struct InstanceMeta {
    pub tenant: u16,
    /// Montage grid size (the instance is `grid x grid`).
    pub grid: usize,
    pub n_tasks: u32,
    /// Critical-path seconds of the instance in isolation — the lower
    /// bound on its response time, and the denominator of its slowdown.
    pub ideal_s: f64,
}

/// Build the union DAG, the fleet plan, and the per-instance metadata for
/// a fleet configuration. Fully deterministic in `cfg.seed`.
pub fn build_plan(cfg: &FleetConfig) -> (Dag, FleetPlan, Vec<InstanceMeta>) {
    assert!(!cfg.tenants.is_empty(), "at least one tenant");
    let n_tenants = cfg.tenants.len();
    let mut master = Rng::new(cfg.seed ^ 0xF1EE7);
    let mut arr_rng = master.fork(1);
    let mut tenant_rng = master.fork(2);
    let mut gen_rng = master.fork(3);

    let times = cfg.arrival.schedule(cfg.duration_s, &mut arr_rng);
    let mut dags: Vec<Dag> = Vec::with_capacity(times.len());
    let mut metas: Vec<InstanceMeta> = Vec::with_capacity(times.len());
    let mut instances: Vec<InstanceSpec> = Vec::with_capacity(times.len());
    let mut first_task = 0u32;
    for &arrival_ms in &times {
        let tenant = tenant_rng.below(n_tenants as u64) as u16;
        let grids = &cfg.tenants[tenant as usize].grids;
        let grid = grids[gen_rng.below(grids.len() as u64) as usize];
        let dag = generate(&MontageConfig {
            grid_w: grid,
            grid_h: grid,
            diagonals: true,
            seed: gen_rng.next_u64(),
        });
        let n_tasks = dag.len() as u32;
        metas.push(InstanceMeta {
            tenant,
            grid,
            n_tasks,
            ideal_s: dag.critical_path_secs(),
        });
        instances.push(InstanceSpec {
            tenant,
            arrival_ms,
            first_task,
            n_tasks,
        });
        first_task += n_tasks;
        dags.push(dag);
    }
    let union = Dag::disjoint_union(&dags);
    let plan = FleetPlan {
        instances,
        tenant_weights: cfg.tenants.iter().map(|t| t.weight).collect(),
        max_in_flight: cfg.max_in_flight,
    };
    (union, plan, metas)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fleet::{default_tenants, ArrivalProcess};

    fn cfg() -> FleetConfig {
        FleetConfig {
            arrival: ArrivalProcess::Burst {
                every_s: 120.0,
                size: 2,
            },
            duration_s: 600.0,
            tenants: default_tenants(3, &[3, 4, 5]),
            seed: 9,
            max_in_flight: None,
        }
    }

    #[test]
    fn plan_covers_the_union_dag_contiguously() {
        let (dag, plan, metas) = build_plan(&cfg());
        assert_eq!(plan.instances.len(), 10); // 5 bursts x 2
        assert_eq!(plan.instances.len(), metas.len());
        let mut expect = 0u32;
        for (s, m) in plan.instances.iter().zip(&metas) {
            assert_eq!(s.first_task, expect);
            assert_eq!(s.n_tasks, m.n_tasks);
            assert_eq!(s.tenant, m.tenant);
            assert!((s.tenant as usize) < plan.tenant_weights.len());
            assert!(m.ideal_s > 0.0);
            expect += s.n_tasks;
        }
        assert_eq!(expect as usize, dag.len());
        assert!(dag.validate().is_ok());
        // arrivals are sorted (burst schedule)
        assert!(plan
            .instances
            .windows(2)
            .all(|w| w[0].arrival_ms <= w[1].arrival_ms));
    }

    #[test]
    fn same_seed_same_plan_different_seed_differs() {
        let (_, p1, m1) = build_plan(&cfg());
        let (_, p2, m2) = build_plan(&cfg());
        assert_eq!(p1.instances.len(), p2.instances.len());
        for (a, b) in p1.instances.iter().zip(&p2.instances) {
            assert_eq!(a.arrival_ms, b.arrival_ms);
            assert_eq!(a.tenant, b.tenant);
            assert_eq!(a.n_tasks, b.n_tasks);
        }
        for (a, b) in m1.iter().zip(&m2) {
            assert_eq!(a.grid, b.grid);
            assert_eq!(a.ideal_s, b.ideal_s);
        }
        let mut other = cfg();
        other.seed = 10;
        let (_, _, m3) = build_plan(&other);
        assert!(
            m1.iter().zip(&m3).any(|(a, b)| a.grid != b.grid
                || a.tenant != b.tenant
                || a.ideal_s != b.ideal_s),
            "different seed should reshuffle the workload"
        );
    }

    #[test]
    fn tenant_sizes_come_from_their_mix() {
        let (_, plan, metas) = build_plan(&cfg());
        let tenants = default_tenants(3, &[3, 4, 5]);
        for (s, m) in plan.instances.iter().zip(&metas) {
            assert!(
                tenants[s.tenant as usize].grids.contains(&m.grid),
                "tenant {} drew grid {} outside its mix",
                s.tenant,
                m.grid
            );
        }
    }
}
