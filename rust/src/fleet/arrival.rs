//! Open-loop arrival processes for the fleet service.
//!
//! "Open-loop" means arrivals do not wait for the system: instances keep
//! coming at the configured rate whether or not the cluster keeps up —
//! exactly the regime that exposes a service's saturation knee (once
//! offered load exceeds capacity, queues and slowdown grow without bound).
//! All processes are generated from a caller-supplied
//! [`crate::util::rng::Rng`], so a fleet run is reproducible from its seed.

use crate::util::rng::Rng;

/// How workflow instances arrive over the window `[0, duration_s)`.
#[derive(Debug, Clone)]
pub enum ArrivalProcess {
    /// Memoryless arrivals at `per_hour` instances/hour (exponential
    /// interarrival times) — the classic open-loop workload model.
    Poisson { per_hour: f64 },
    /// Periodic bursts: `size` simultaneous arrivals every `every_s`
    /// seconds, first burst at t=0. A deterministic stand-in for
    /// trace-style on/off submission patterns (nightly pipelines, course
    /// deadlines).
    Burst { every_s: f64, size: usize },
    /// Explicit arrival times in milliseconds (trace-driven replay).
    /// Times at or beyond the window are dropped.
    Trace { times_ms: Vec<u64> },
}

impl ArrivalProcess {
    /// Materialize the arrival times (ms, sorted ascending) within
    /// `[0, duration_s)`.
    pub fn schedule(&self, duration_s: f64, rng: &mut Rng) -> Vec<u64> {
        let horizon_ms = (duration_s * 1000.0).round() as u64;
        match self {
            ArrivalProcess::Poisson { per_hour } => {
                assert!(*per_hour > 0.0, "arrival rate must be positive");
                let mean_s = 3600.0 / per_hour;
                let mut out = Vec::new();
                let mut t_s = 0.0f64;
                loop {
                    t_s += rng.exponential(mean_s);
                    let ms = (t_s * 1000.0).round() as u64;
                    if ms >= horizon_ms {
                        break;
                    }
                    out.push(ms);
                }
                out
            }
            ArrivalProcess::Burst { every_s, size } => {
                let step_ms = (every_s * 1000.0).round() as u64;
                assert!(step_ms > 0, "burst period must be positive");
                assert!(*size > 0, "burst size must be positive");
                let mut out = Vec::new();
                let mut t = 0u64;
                while t < horizon_ms {
                    for _ in 0..*size {
                        out.push(t);
                    }
                    t += step_ms;
                }
                out
            }
            ArrivalProcess::Trace { times_ms } => {
                let mut v: Vec<u64> = times_ms
                    .iter()
                    .copied()
                    .filter(|&ms| ms < horizon_ms)
                    .collect();
                v.sort_unstable();
                v
            }
        }
    }

    /// Human-readable label for reports.
    pub fn label(&self) -> String {
        match self {
            ArrivalProcess::Poisson { per_hour } => format!("poisson({per_hour}/h)"),
            ArrivalProcess::Burst { every_s, size } => {
                format!("burst({size} every {every_s}s)")
            }
            ArrivalProcess::Trace { times_ms } => format!("trace({} arrivals)", times_ms.len()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_matches_rate_and_is_deterministic() {
        let p = ArrivalProcess::Poisson { per_hour: 3600.0 }; // 1/s mean
        let a = p.schedule(10_000.0, &mut Rng::new(7));
        let b = p.schedule(10_000.0, &mut Rng::new(7));
        assert_eq!(a, b, "same seed, same schedule");
        // ~10_000 expected arrivals; 10 sigma tolerance
        assert!((9_000..11_000).contains(&a.len()), "got {}", a.len());
        assert!(a.windows(2).all(|w| w[0] <= w[1]), "sorted");
        assert!(a.iter().all(|&ms| ms < 10_000_000), "inside the window");
        // a different seed shifts the schedule
        let c = p.schedule(10_000.0, &mut Rng::new(8));
        assert_ne!(a, c);
    }

    #[test]
    fn burst_is_periodic_and_exact() {
        let t = ArrivalProcess::Burst {
            every_s: 100.0,
            size: 2,
        }
        .schedule(350.0, &mut Rng::new(1));
        assert_eq!(
            t,
            vec![0, 0, 100_000, 100_000, 200_000, 200_000, 300_000, 300_000]
        );
    }

    #[test]
    fn trace_filters_and_sorts() {
        let t = ArrivalProcess::Trace {
            times_ms: vec![5_000, 1_000, 99_000, 10_000],
        }
        .schedule(50.0, &mut Rng::new(1));
        assert_eq!(t, vec![1_000, 5_000, 10_000]);
    }

    #[test]
    fn labels_are_descriptive() {
        assert_eq!(
            ArrivalProcess::Poisson { per_hour: 6.0 }.label(),
            "poisson(6/h)"
        );
        assert!(ArrivalProcess::Burst {
            every_s: 60.0,
            size: 3
        }
        .label()
        .contains("burst"));
    }
}
