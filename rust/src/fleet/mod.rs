//! Fleet service: open-loop multi-tenant workflow arrivals on one shared
//! cluster.
//!
//! The paper evaluates one workflow at a time, but its worker-pools model
//! exists precisely because "multiple instances of different workflows can
//! intertwine" (§3.4) — and a production deployment is a long-running
//! *service* absorbing a stream of submissions, not a one-shot experiment
//! harness (cf. KubeAdaptor's containerized workflow injection,
//! arXiv:2207.01222, and multi-tenant resource sharing in Mao et al.,
//! arXiv:2010.10350). This module provides that service layer on top of
//! the simulator:
//!
//! * [`arrival`] — open-loop arrival processes (Poisson, periodic bursts,
//!   explicit traces), seeded via [`crate::util::rng`];
//! * [`workload`] — turns a [`FleetConfig`] into a concrete [`FleetPlan`]:
//!   per-arrival Montage instances with per-tenant size mixes, merged into
//!   one task space with [`crate::workflow::dag::Dag::disjoint_union`];
//! * [`crate::exec::run_fleet`] — the multi-instance engine:
//!   instances are admitted (optionally under a concurrency cap), their
//!   tasks flow through tenant-aware broker lanes with weighted fair-share
//!   dequeue, and the autoscaler sees the aggregate backlog;
//! * [`report`] — per-tenant SLO statistics: queueing delay, makespan and
//!   slowdown percentiles (p50/p95/p99) from [`crate::util::stats::Summary`].
//!
//! The CLI front-end is `hyperflow serve`; the saturation sweep lives in
//! `benches/fleet_saturation.rs` (writes `BENCH_fleet.json`).

pub mod arrival;
pub mod report;
pub mod workload;

pub use arrival::ArrivalProcess;
pub use workload::InstanceMeta;

use crate::exec::{self as driver, ConfigError, ExecModel, SimConfig};
use crate::report::SimResult;
use crate::sim::SimTime;

/// One workflow instance inside a fleet plan: a contiguous task range
/// `[first_task, first_task + n_tasks)` of the disjoint-union DAG, owned
/// by a tenant, arriving at `arrival_ms`.
#[derive(Debug, Clone)]
pub struct InstanceSpec {
    pub tenant: u16,
    pub arrival_ms: u64,
    pub first_task: u32,
    pub n_tasks: u32,
}

/// A fully-resolved fleet workload, ready for
/// [`crate::exec::run_fleet`].
#[derive(Debug, Clone)]
pub struct FleetPlan {
    /// Instances in arrival order; task ranges are contiguous and cover
    /// the union DAG.
    pub instances: Vec<InstanceSpec>,
    /// Fair-share weight per tenant (broker dequeue shares).
    pub tenant_weights: Vec<u64>,
    /// Admission-control cap: max concurrently running instances
    /// (`None` = admit on arrival).
    pub max_in_flight: Option<usize>,
}

impl FleetPlan {
    /// Structural validation against a union DAG of `n_tasks` tasks:
    /// contiguous instance ranges covering the DAG, every instance tenant
    /// weighted, a usable admission cap. Named errors instead of the
    /// assorted mid-run panics these used to be.
    pub fn validate(&self, n_tasks: u32) -> Result<(), ConfigError> {
        if self.tenant_weights.is_empty() {
            return Err(ConfigError::NoTenants);
        }
        if self.max_in_flight == Some(0) {
            return Err(ConfigError::ZeroAdmissionCap);
        }
        let mut expect = 0u32;
        for s in &self.instances {
            if s.first_task != expect {
                // gap/overlap: the next range must start where the last ended
                return Err(ConfigError::BadInstanceRanges {
                    expected: expect,
                    found: s.first_task,
                });
            }
            if s.n_tasks == 0 {
                return Err(ConfigError::EmptyInstance);
            }
            if (s.tenant as usize) >= self.tenant_weights.len() {
                return Err(ConfigError::TenantWeightArity {
                    tenant: s.tenant,
                    weights: self.tenant_weights.len(),
                });
            }
            expect += s.n_tasks;
        }
        if expect != n_tasks {
            return Err(ConfigError::BadInstanceRanges {
                expected: n_tasks,
                found: expect,
            });
        }
        Ok(())
    }
}

/// Lifecycle of one instance after the run: arrival (open-loop),
/// admission (possibly delayed by the cap), completion.
#[derive(Debug, Clone)]
pub struct InstanceOutcome {
    pub tenant: u16,
    pub arrival: SimTime,
    pub admitted: SimTime,
    pub finished: SimTime,
    pub n_tasks: u32,
}

/// One tenant's workload profile: fair-share weight and the Montage grid
/// sizes it submits (drawn uniformly per arrival).
#[derive(Debug, Clone)]
pub struct TenantSpec {
    pub weight: u64,
    pub grids: Vec<usize>,
}

/// Default tenant profiles: equal weights, with each tenant drawing from
/// a two-size slice of the global grid mix (rotated by tenant index), so
/// tenants submit genuinely different size distributions.
pub fn default_tenants(n: usize, grids: &[usize]) -> Vec<TenantSpec> {
    assert!(n > 0, "at least one tenant");
    assert!(!grids.is_empty(), "at least one grid size");
    (0..n)
        .map(|k| TenantSpec {
            weight: 1,
            grids: vec![grids[k % grids.len()], grids[(k + 1) % grids.len()]],
        })
        .collect()
}

/// Parameters of a fleet simulation.
#[derive(Debug, Clone)]
pub struct FleetConfig {
    /// Aggregate arrival process over the whole tenant population; each
    /// arrival is assigned a tenant uniformly at random (thinning), so
    /// every tenant receives an open-loop stream of rate `R / K`.
    pub arrival: ArrivalProcess,
    /// Length of the arrival window in simulated seconds. The run itself
    /// continues until the backlog drains.
    pub duration_s: f64,
    pub tenants: Vec<TenantSpec>,
    /// Master seed: arrival times, tenant assignment, instance sizes and
    /// task durations all derive from it deterministically.
    pub seed: u64,
    /// Admission-control cap (see [`FleetPlan::max_in_flight`]).
    pub max_in_flight: Option<usize>,
}

/// Everything a fleet run produced: the aggregate simulation result plus
/// per-instance lifecycles and workload metadata (index-aligned with
/// `outcomes`).
#[derive(Debug)]
pub struct FleetResult {
    pub sim: SimResult,
    pub outcomes: Vec<InstanceOutcome>,
    pub metas: Vec<InstanceMeta>,
    pub duration_s: f64,
    pub n_tenants: usize,
}

/// Generate the workload for `cfg` and run it under `model` on the
/// simulated cluster. Deterministic: the same `(cfg, model, sim_cfg)`
/// produces an identical result, per-tenant slowdown table included.
pub fn run(model: ExecModel, mut sim_cfg: SimConfig, cfg: &FleetConfig) -> FleetResult {
    let (dag, plan, metas) = workload::build_plan(cfg);
    // A sweep point whose arrival process yields nothing (rate far below
    // 1/duration) is a legitimate empty measurement, not an error — the
    // pooled-model driver cannot run an empty DAG, so report it directly.
    if plan.instances.is_empty() {
        return FleetResult {
            sim: SimResult {
                model_name: format!("fleet/{}", model.name()),
                makespan: crate::sim::SimTime::ZERO,
                trace: crate::report::Trace::new(),
                metrics: crate::metrics::Registry::new(),
                pods_created: 0,
                api_requests: 0,
                sched_backoffs: 0,
                sched_binds: 0,
                sim_events: 0,
                event_arena: crate::sim::ArenaStats::default(),
                avg_running_tasks: 0.0,
                avg_cpu_utilization: 0.0,
                chaos: crate::chaos::ChaosReport::default(),
                data: crate::data::DataReport::default(),
                isolation: crate::k8s::isolation::IsolationReport::default(),
                obs: None,
                monitor: None,
            },
            outcomes: Vec::new(),
            metas,
            duration_s: cfg.duration_s,
            n_tenants: cfg.tenants.len(),
        };
    }
    // The open-loop backlog must drain after arrivals stop: widen the
    // livelock guard past the *offered work*, not just the arrival
    // window — an over-saturated sweep point legitimately drains for far
    // longer than the window, and must finish rather than trip the
    // driver's deadlock assertion. Fully-serial execution of every task
    // is the worst case; 4x that plus a day covers per-task overheads
    // and scheduler back-off pathologies.
    let total_task_s: f64 = dag.tasks.iter().map(|t| t.duration.as_secs_f64()).sum();
    sim_cfg.max_sim_s = sim_cfg
        .max_sim_s
        .max(cfg.duration_s * 50.0 + 86_400.0)
        .max(cfg.duration_s + total_task_s * 4.0 + 86_400.0);
    let (sim, outcomes) = driver::run_fleet(dag, model, sim_cfg, &plan);
    FleetResult {
        sim,
        outcomes,
        metas,
        duration_s: cfg.duration_s,
        n_tenants: cfg.tenants.len(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg(seed: u64) -> FleetConfig {
        FleetConfig {
            arrival: ArrivalProcess::Poisson { per_hour: 90.0 },
            duration_s: 400.0,
            tenants: default_tenants(2, &[3, 4]),
            seed,
            max_in_flight: None,
        }
    }

    #[test]
    fn fleet_run_completes_and_is_consistent() {
        let res = run(
            ExecModel::paper_hybrid_pools(),
            SimConfig::with_nodes(4),
            &small_cfg(1),
        );
        assert!(!res.outcomes.is_empty());
        assert_eq!(res.outcomes.len(), res.metas.len());
        let traced = res.sim.trace.records.len() as u32;
        let total: u32 = res.metas.iter().map(|m| m.n_tasks).sum();
        assert_eq!(traced, total, "every task of every instance traced");
        for (o, m) in res.outcomes.iter().zip(&res.metas) {
            assert_eq!(o.tenant, m.tenant);
            assert!(o.finished > o.admitted);
            assert!(o.admitted >= o.arrival);
            // response time can never beat the critical path
            assert!((o.finished - o.arrival).as_secs_f64() > m.ideal_s);
        }
    }

    #[test]
    fn fleet_run_is_deterministic_for_seed() {
        let mk = || {
            run(
                ExecModel::paper_hybrid_pools(),
                SimConfig::with_nodes(4),
                &small_cfg(7),
            )
        };
        let (a, b) = (mk(), mk());
        assert_eq!(a.sim.makespan, b.sim.makespan);
        assert_eq!(a.outcomes.len(), b.outcomes.len());
        for (x, y) in a.outcomes.iter().zip(&b.outcomes) {
            assert_eq!(x.tenant, y.tenant);
            assert_eq!(x.arrival, y.arrival);
            assert_eq!(x.admitted, y.admitted);
            assert_eq!(x.finished, y.finished);
        }
        assert_eq!(report::render_table(&a), report::render_table(&b));
    }

    #[test]
    fn admission_cap_defers_but_completes() {
        let mut cfg = small_cfg(3);
        cfg.max_in_flight = Some(1);
        let res = run(ExecModel::paper_hybrid_pools(), SimConfig::with_nodes(4), &cfg);
        // serialized: no two instances overlap
        let mut sorted: Vec<_> = res.outcomes.iter().collect();
        sorted.sort_by_key(|o| o.admitted);
        for w in sorted.windows(2) {
            assert!(w[1].admitted >= w[0].finished, "cap 1 must serialize");
        }
    }

    #[test]
    fn zero_arrivals_yield_an_empty_result_not_a_panic() {
        let mut cfg = small_cfg(1);
        // an empty trace: guaranteed zero arrivals in the window
        cfg.arrival = ArrivalProcess::Trace { times_ms: vec![] };
        let res = run(
            ExecModel::paper_hybrid_pools(),
            SimConfig::with_nodes(4),
            &cfg,
        );
        assert!(res.outcomes.is_empty());
        assert_eq!(res.sim.makespan, crate::sim::SimTime::ZERO);
        let agg = report::aggregate(&res);
        assert_eq!(agg.instances, 0);
        assert_eq!(agg.completed_per_hour, 0.0);
        assert_eq!(report::per_tenant(&res).len(), 2);
    }

    #[test]
    fn fleet_plan_validation_names_each_failure_mode() {
        let spec = |tenant, first, n| InstanceSpec {
            tenant,
            arrival_ms: 0,
            first_task: first,
            n_tasks: n,
        };
        let ok = FleetPlan {
            instances: vec![spec(0, 0, 3), spec(1, 3, 2)],
            tenant_weights: vec![1, 1],
            max_in_flight: None,
        };
        assert!(ok.validate(5).is_ok());
        let mut bad = ok.clone();
        bad.tenant_weights.clear();
        assert_eq!(bad.validate(5), Err(ConfigError::NoTenants));
        let mut bad = ok.clone();
        bad.max_in_flight = Some(0);
        assert_eq!(bad.validate(5), Err(ConfigError::ZeroAdmissionCap));
        let mut bad = ok.clone();
        bad.instances[1].tenant = 7;
        assert_eq!(
            bad.validate(5),
            Err(ConfigError::TenantWeightArity {
                tenant: 7,
                weights: 2
            })
        );
        let mut bad = ok.clone();
        bad.instances[1].first_task = 4; // gap
        assert!(matches!(
            bad.validate(5),
            Err(ConfigError::BadInstanceRanges { .. })
        ));
        let mut bad = ok.clone();
        bad.instances[1].n_tasks = 0;
        assert_eq!(bad.validate(5), Err(ConfigError::EmptyInstance));
        // ranges that do not cover the DAG
        assert!(matches!(
            ok.validate(9),
            Err(ConfigError::BadInstanceRanges { .. })
        ));
    }

    #[test]
    fn default_tenants_rotate_grid_mixes() {
        let t = default_tenants(3, &[4, 5, 6]);
        assert_eq!(t.len(), 3);
        assert_eq!(t[0].grids, vec![4, 5]);
        assert_eq!(t[1].grids, vec![5, 6]);
        assert_eq!(t[2].grids, vec![6, 4]);
        assert!(t.iter().all(|s| s.weight == 1));
    }
}
