//! Experiment configuration: typed, JSON-loadable descriptions of a full
//! run (workflow + execution model + cluster/sim parameters), so every
//! experiment in EXPERIMENTS.md is a shippable config file (see
//! `configs/*.json`).

use crate::engine::clustering::ClusteringConfig;
use crate::exec::{ExecModel, SimConfig};
use crate::util::json::{Json, JsonError};
use crate::workflow::dag::Dag;
use crate::workflow::montage::{generate, MontageConfig};
use anyhow::{anyhow, Result};

/// Which workflow to run.
#[derive(Debug, Clone)]
pub enum WorkflowSpec {
    /// Montage on a g x g grid.
    MontageGrid {
        grid: usize,
        diagonals: bool,
        seed: u64,
    },
    /// Montage sized to approximately `total` tasks.
    MontageTotal { total: usize, seed: u64 },
    /// Load a DAG from a workflow JSON file.
    File { path: String },
}

impl WorkflowSpec {
    pub fn build(&self) -> Result<Dag> {
        match self {
            WorkflowSpec::MontageGrid {
                grid,
                diagonals,
                seed,
            } => Ok(generate(&MontageConfig {
                grid_w: *grid,
                grid_h: *grid,
                diagonals: *diagonals,
                seed: *seed,
            })),
            WorkflowSpec::MontageTotal { total, seed } => {
                Ok(generate(&MontageConfig::with_total_tasks(*total, *seed)))
            }
            WorkflowSpec::File { path } => crate::workflow::wfjson::load(path),
        }
    }
}

/// A complete experiment description.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    pub name: String,
    pub workflow: WorkflowSpec,
    pub model: ExecModel,
    pub sim: SimConfig,
}

fn parse_workflow(j: &Json) -> Result<WorkflowSpec> {
    let ty = j.get("type").map_err(je)?.as_str().map_err(je)?;
    Ok(match ty {
        "montage" => {
            if let Some(total) = j.opt("total_tasks") {
                WorkflowSpec::MontageTotal {
                    total: total.as_usize().map_err(je)?,
                    seed: j.opt("seed").map(|s| s.as_u64()).transpose().map_err(je)?.unwrap_or(42),
                }
            } else {
                WorkflowSpec::MontageGrid {
                    grid: j.get("grid").map_err(je)?.as_usize().map_err(je)?,
                    diagonals: j
                        .opt("diagonals")
                        .map(|d| d.as_bool())
                        .transpose()
                        .map_err(je)?
                        .unwrap_or(true),
                    seed: j.opt("seed").map(|s| s.as_u64()).transpose().map_err(je)?.unwrap_or(42),
                }
            }
        }
        "file" => WorkflowSpec::File {
            path: j.get("path").map_err(je)?.as_str().map_err(je)?.to_string(),
        },
        other => return Err(anyhow!("unknown workflow type '{other}'")),
    })
}

fn parse_model(j: &Json) -> Result<ExecModel> {
    let ty = j.get("type").map_err(je)?.as_str().map_err(je)?;
    Ok(match ty {
        "job" | "job-based" => ExecModel::JobBased,
        "clustered" => {
            let rules = match j.opt("rules") {
                Some(r) => ClusteringConfig::from_json(r).map_err(je)?,
                None => ClusteringConfig::paper_default(),
            };
            ExecModel::Clustered(rules)
        }
        "pools" | "worker-pools" => {
            let pooled = match j.opt("pooled") {
                Some(p) => p
                    .as_arr()
                    .map_err(je)?
                    .iter()
                    .map(|s| s.as_str().map(str::to_string))
                    .collect::<std::result::Result<Vec<_>, _>>()
                    .map_err(je)?,
                None => vec![
                    "mProject".to_string(),
                    "mDiffFit".to_string(),
                    "mBackground".to_string(),
                ],
            };
            ExecModel::WorkerPools {
                pooled_types: pooled,
            }
        }
        "generic-pool" => ExecModel::GenericPool,
        other => return Err(anyhow!("unknown model type '{other}'")),
    })
}

fn parse_sim(j: Option<&Json>, nodes_default: usize) -> Result<SimConfig> {
    let mut sim = SimConfig::with_nodes(nodes_default);
    let Some(j) = j else { return Ok(sim) };
    let u = |key: &str, d: u64| -> Result<u64> {
        Ok(j.opt(key).map(|v| v.as_u64()).transpose().map_err(je)?.unwrap_or(d))
    };
    if let Some(n) = j.opt("nodes") {
        sim = SimConfig::with_nodes(n.as_usize().map_err(je)?);
    }
    sim.pod_start_ms = u("pod_start_ms", sim.pod_start_ms)?;
    sim.exec_overhead_ms = u("exec_overhead_ms", sim.exec_overhead_ms)?;
    sim.job_controller_ms = u("job_controller_ms", sim.job_controller_ms)?;
    sim.sched.backoff_initial_ms = u("backoff_initial_ms", sim.sched.backoff_initial_ms)?;
    sim.sched.backoff_max_ms = u("backoff_max_ms", sim.sched.backoff_max_ms)?;
    sim.autoscale.poll_ms = u("autoscale_poll_ms", sim.autoscale.poll_ms)?;
    sim.autoscale.stabilization_ms = u("stabilization_ms", sim.autoscale.stabilization_ms)?;
    sim.autoscale.min_replicas = u("min_replicas", sim.autoscale.min_replicas as u64)? as usize;
    sim.seed = u("seed", sim.seed)?;
    if let Some(p) = j.opt("pod_failure_prob") {
        // deprecated: kept working, folded onto the chaos PodFailure
        // injector at build time (models/driver.rs)
        sim.pod_failure_prob = p.as_f64().map_err(je)?;
    }
    if let Some(c) = j.opt("chaos") {
        sim.chaos = crate::chaos::ChaosConfig::parse_spec(c.as_str().map_err(je)?)
            .map_err(|e| anyhow!("chaos spec: {e}"))?;
    }
    if let Some(d) = j.opt("data") {
        sim.data = Some(
            crate::data::DataConfig::parse_spec(d.as_str().map_err(je)?)
                .map_err(|e| anyhow!("data spec: {e}"))?,
        );
    }
    if let Some(cap) = j.opt("max_pending_pods") {
        sim.max_pending_pods = Some(cap.as_usize().map_err(je)?);
    }
    if let Some(evs) = j.opt("node_events") {
        for e in evs.as_arr().map_err(je)? {
            let a = e.as_arr().map_err(je)?;
            if a.len() != 3 {
                return Err(anyhow!("node_events entries are [ms, node, up]"));
            }
            sim.node_events.push((
                a[0].as_u64().map_err(je)?,
                a[1].as_usize().map_err(je)?,
                a[2].as_bool().map_err(je)?,
            ));
        }
    }
    Ok(sim)
}

fn je(e: JsonError) -> anyhow::Error {
    anyhow!("{e}")
}

impl ExperimentConfig {
    pub fn from_json(j: &Json) -> Result<Self> {
        let name = j
            .opt("name")
            .map(|n| n.as_str())
            .transpose()
            .map_err(je)?
            .unwrap_or("experiment")
            .to_string();
        let workflow = parse_workflow(j.get("workflow").map_err(je)?)?;
        let model = parse_model(j.get("model").map_err(je)?)?;
        let sim = parse_sim(j.opt("sim"), 17)?;
        let cfg = ExperimentConfig {
            name,
            workflow,
            model,
            sim,
        };
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn load(path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("reading config {path}: {e}"))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("parsing {path}: {e}"))?;
        Self::from_json(&j)
    }

    pub fn validate(&self) -> Result<()> {
        // named ConfigError variants from the exec layer (zero nodes, bad
        // node events, out-of-range pod_failure_prob, zero cluster sizes,
        // empty/duplicate pool sets)
        self.sim.validate().map_err(|e| anyhow!("{e}"))?;
        self.model.validate().map_err(|e| anyhow!("{e}"))?;
        Ok(())
    }

    /// Build the workflow and run the experiment.
    pub fn run(&self) -> Result<crate::report::SimResult> {
        let dag = self.workflow.build()?;
        self.model
            .validate_against(&dag)
            .map_err(|e| anyhow!("{e}"))?;
        Ok(crate::exec::run(dag, self.model.clone(), self.sim.clone()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_config() {
        let src = r#"{
            "name": "fig4-repro",
            "workflow": {"type": "montage", "grid": 5, "seed": 7},
            "model": {"type": "clustered", "rules": [
                {"matchTask": ["mProject"], "size": 5, "timeoutMs": 3000}
            ]},
            "sim": {"nodes": 4, "pod_start_ms": 1500, "max_pending_pods": 16,
                    "node_events": [[30000, 1, false]]}
        }"#;
        let cfg = ExperimentConfig::from_json(&Json::parse(src).unwrap()).unwrap();
        assert_eq!(cfg.name, "fig4-repro");
        assert_eq!(cfg.sim.nodes, 4);
        assert_eq!(cfg.sim.pod_start_ms, 1500);
        assert_eq!(cfg.sim.max_pending_pods, Some(16));
        assert_eq!(cfg.sim.node_events, vec![(30000, 1, false)]);
        assert!(matches!(cfg.model, ExecModel::Clustered(_)));
    }

    #[test]
    fn defaults_fill_in() {
        let src = r#"{
            "workflow": {"type": "montage", "grid": 3},
            "model": {"type": "pools"}
        }"#;
        let cfg = ExperimentConfig::from_json(&Json::parse(src).unwrap()).unwrap();
        assert_eq!(cfg.sim.nodes, 17);
        if let ExecModel::WorkerPools { pooled_types } = &cfg.model {
            assert_eq!(pooled_types.len(), 3);
        } else {
            panic!();
        }
    }

    #[test]
    fn rejects_bad_configs() {
        for bad in [
            r#"{"workflow": {"type": "unknown"}, "model": {"type": "job"}}"#,
            r#"{"workflow": {"type": "montage", "grid": 3},
                "model": {"type": "nope"}}"#,
            r#"{"workflow": {"type": "montage", "grid": 3},
                "model": {"type": "job"}, "sim": {"pod_failure_prob": 2.0}}"#,
            r#"{"workflow": {"type": "montage", "grid": 3},
                "model": {"type": "job"},
                "sim": {"nodes": 2, "node_events": [[1000, 5, false]]}}"#,
        ] {
            assert!(
                ExperimentConfig::from_json(&Json::parse(bad).unwrap()).is_err(),
                "accepted: {bad}"
            );
        }
    }

    #[test]
    fn chaos_spec_parses_and_legacy_pod_failure_keeps_working() {
        let src = r#"{
            "workflow": {"type": "montage", "grid": 3},
            "model": {"type": "pools"},
            "sim": {"nodes": 4, "chaos": "spot:0.2,straggler:0.25",
                    "pod_failure_prob": 0.05}
        }"#;
        let cfg = ExperimentConfig::from_json(&Json::parse(src).unwrap()).unwrap();
        assert_eq!(cfg.sim.chaos.injectors.len(), 2);
        assert!(cfg.sim.chaos.is_enabled());
        // the deprecated knob still parses and still takes effect (the
        // driver folds it into the chaos PodFailure injector)
        assert!((cfg.sim.pod_failure_prob - 0.05).abs() < 1e-12);

        let bad = r#"{
            "workflow": {"type": "montage", "grid": 3},
            "model": {"type": "pools"},
            "sim": {"chaos": "meteor:1"}
        }"#;
        assert!(ExperimentConfig::from_json(&Json::parse(bad).unwrap()).is_err());
    }

    #[test]
    fn data_spec_parses_and_bad_specs_are_rejected() {
        let src = r#"{
            "workflow": {"type": "montage", "grid": 3},
            "model": {"type": "pools"},
            "sim": {"nodes": 4, "data": "nfs:1,cache:4,locality:on"}
        }"#;
        let cfg = ExperimentConfig::from_json(&Json::parse(src).unwrap()).unwrap();
        let data = cfg.sim.data.expect("data plane configured");
        assert!(data.locality);
        assert_eq!(data.cache_bytes, 4_000_000_000);

        let bad = r#"{
            "workflow": {"type": "montage", "grid": 3},
            "model": {"type": "pools"},
            "sim": {"data": "cache:4"}
        }"#;
        assert!(ExperimentConfig::from_json(&Json::parse(bad).unwrap()).is_err());
    }

    #[test]
    fn config_runs_end_to_end() {
        let src = r#"{
            "workflow": {"type": "montage", "grid": 3, "seed": 1},
            "model": {"type": "job"},
            "sim": {"nodes": 3}
        }"#;
        let cfg = ExperimentConfig::from_json(&Json::parse(src).unwrap()).unwrap();
        let res = cfg.run().unwrap();
        assert!(res.makespan.as_secs_f64() > 0.0);
    }

    #[test]
    fn total_tasks_variant() {
        let src = r#"{
            "workflow": {"type": "montage", "total_tasks": 500, "seed": 3},
            "model": {"type": "generic-pool"}
        }"#;
        let cfg = ExperimentConfig::from_json(&Json::parse(src).unwrap()).unwrap();
        let dag = cfg.workflow.build().unwrap();
        assert!((300..800).contains(&dag.len()), "{}", dag.len());
    }
}
