//! Parse `artifacts/manifest.json` written by `python/compile/aot.py`:
//! the shape/dtype contract between the AOT-compiled HLO artifacts and the
//! Rust runtime.

use crate::util::json::Json;
use anyhow::{anyhow, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

#[derive(Debug, Clone, PartialEq)]
pub struct IoSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl IoSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub tile: usize,
    pub overlap: usize,
    pub grids: Vec<usize>,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
    pub dir: PathBuf,
}

fn parse_io(j: &Json) -> Result<IoSpec> {
    let shape = j
        .get("shape")
        .map_err(|e| anyhow!("{e}"))?
        .as_arr()
        .map_err(|e| anyhow!("{e}"))?
        .iter()
        .map(|v| v.as_usize().map_err(|e| anyhow!("{e}")))
        .collect::<Result<Vec<_>>>()?;
    let dtype = j
        .get("dtype")
        .and_then(|d| d.as_str())
        .map_err(|e| anyhow!("{e}"))?
        .to_string();
    Ok(IoSpec { shape, dtype })
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("parse {path:?}: {e}"))?;
        let mut artifacts = BTreeMap::new();
        for (name, a) in j.get("artifacts").map_err(|e| anyhow!("{e}"))?.as_obj().map_err(|e| anyhow!("{e}"))? {
            let file = dir.join(
                a.get("file")
                    .and_then(|f| f.as_str())
                    .map_err(|e| anyhow!("{e}"))?,
            );
            let inputs = a
                .get("inputs")
                .map_err(|e| anyhow!("{e}"))?
                .as_arr()
                .map_err(|e| anyhow!("{e}"))?
                .iter()
                .map(parse_io)
                .collect::<Result<Vec<_>>>()?;
            let outputs = a
                .get("outputs")
                .map_err(|e| anyhow!("{e}"))?
                .as_arr()
                .map_err(|e| anyhow!("{e}"))?
                .iter()
                .map(parse_io)
                .collect::<Result<Vec<_>>>()?;
            artifacts.insert(
                name.clone(),
                ArtifactSpec {
                    name: name.clone(),
                    file,
                    inputs,
                    outputs,
                },
            );
        }
        Ok(Manifest {
            tile: j.get("tile").and_then(|v| v.as_u64()).map_err(|e| anyhow!("{e}"))? as usize,
            overlap: j.get("overlap").and_then(|v| v.as_u64()).map_err(|e| anyhow!("{e}"))?
                as usize,
            grids: j
                .get("grids")
                .map_err(|e| anyhow!("{e}"))?
                .as_arr()
                .map_err(|e| anyhow!("{e}"))?
                .iter()
                .map(|v| v.as_usize().map_err(|e| anyhow!("{e}")))
                .collect::<Result<Vec<_>>>()?,
            artifacts,
            dir,
        })
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow!("artifact '{name}' not in manifest (have: {:?})",
                self.artifacts.keys().collect::<Vec<_>>()))
    }

    /// Canvas edge length for grid `g` (matches python model.canvas_size).
    pub fn canvas_size(&self, g: usize) -> usize {
        (g - 1) * (self.tile - self.overlap) + self.tile
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_fixture(dir: &Path) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"tile":128,"overlap":32,"grids":[4],"artifacts":{
                "mproject":{"file":"mproject.hlo.txt",
                  "inputs":[{"shape":[128,128],"dtype":"float32"},
                            {"shape":[6],"dtype":"float32"}],
                  "outputs":[{"shape":[128,128],"dtype":"float32"},
                             {"shape":[128,128],"dtype":"float32"}]}}}"#,
        )
        .unwrap();
    }

    #[test]
    fn loads_fixture() {
        let dir = std::env::temp_dir().join("hfk8s_manifest_test");
        write_fixture(&dir);
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.tile, 128);
        assert_eq!(m.canvas_size(4), 416);
        let a = m.get("mproject").unwrap();
        assert_eq!(a.inputs.len(), 2);
        assert_eq!(a.inputs[0].elements(), 128 * 128);
        assert!(m.get("nope").is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_dir_is_helpful() {
        let err = Manifest::load("/nonexistent/path").unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }

    #[test]
    fn real_artifacts_manifest_if_present() {
        // integration-lite: if `make artifacts` has run, the real manifest
        // must satisfy the contract the runtime relies on.
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        for name in ["mproject", "mdifffit", "mbackground"] {
            let a = m.get(name).unwrap();
            assert!(a.file.exists(), "{:?} missing", a.file);
            assert!(!a.outputs.is_empty());
        }
        assert_eq!(m.tile, 128);
        assert_eq!(m.overlap, 32);
    }
}
