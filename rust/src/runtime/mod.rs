//! PJRT runtime: load the AOT-compiled HLO artifacts and execute them.
//!
//! The interchange format is HLO *text* (see python/compile/aot.py and
//! /opt/xla-example/README.md): `HloModuleProto::from_text_file` reassigns
//! instruction ids, so jax >= 0.5 modules load cleanly into the
//! xla_extension 0.5.1 that the published `xla` crate links.
//!
//! `PjRtClient` is `Rc`-based and not `Send`: each worker-pod thread in the
//! real-time runner owns its own `Runtime` (which also models the real
//! system, where every pod has its own process + loaded binaries).

pub mod manifest;

use anyhow::{anyhow, Context, Result};
use manifest::{ArtifactSpec, Manifest};
use std::collections::BTreeMap;
use std::path::Path;

/// A loaded set of executables.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    execs: BTreeMap<String, xla::PjRtLoadedExecutable>,
}

impl std::fmt::Debug for Runtime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Runtime")
            .field("artifacts", &self.execs.keys().collect::<Vec<_>>())
            .finish()
    }
}

/// Host-side tensor: f32 data + shape (the only runtime dtype besides the
/// i32 index inputs, which use [`Tensor::from_i32`]).
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub data: Vec<f32>,
    pub shape: Vec<usize>,
    /// True if this tensor should be fed as i32 (index inputs).
    pub is_i32: bool,
}

impl Tensor {
    pub fn new(data: Vec<f32>, shape: &[usize]) -> Self {
        debug_assert_eq!(data.len(), shape.iter().product::<usize>());
        Tensor {
            data,
            shape: shape.to_vec(),
            is_i32: false,
        }
    }

    pub fn from_i32(data: &[i32], shape: &[usize]) -> Self {
        Tensor {
            data: data.iter().map(|&v| v as f32).collect(),
            shape: shape.to_vec(),
            is_i32: true,
        }
    }

    pub fn zeros(shape: &[usize]) -> Self {
        Tensor::new(vec![0.0; shape.iter().product()], shape)
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        let lit = if self.is_i32 {
            let ints: Vec<i32> = self.data.iter().map(|&v| v as i32).collect();
            xla::Literal::vec1(&ints)
        } else {
            xla::Literal::vec1(&self.data)
        };
        Ok(lit.reshape(&dims)?)
    }
}

impl Runtime {
    /// Load + compile every artifact in the manifest.
    pub fn load(dir: impl AsRef<Path>) -> Result<Runtime> {
        let manifest = Manifest::load(&dir)?;
        let names: Vec<String> = manifest.artifacts.keys().cloned().collect();
        Self::load_subset_of(manifest, &names)
    }

    /// Load only the named artifacts (worker pods for one task type only
    /// need that type's executable — the "separate container image per
    /// pool" of §3.3).
    pub fn load_subset(dir: impl AsRef<Path>, names: &[&str]) -> Result<Runtime> {
        let manifest = Manifest::load(&dir)?;
        let names: Vec<String> = names.iter().map(|s| s.to_string()).collect();
        Self::load_subset_of(manifest, &names)
    }

    fn load_subset_of(manifest: Manifest, names: &[String]) -> Result<Runtime> {
        // silence the xla_extension client lifecycle chatter
        if std::env::var_os("TF_CPP_MIN_LOG_LEVEL").is_none() {
            std::env::set_var("TF_CPP_MIN_LOG_LEVEL", "2");
        }
        let client = xla::PjRtClient::cpu()?;
        let mut execs = BTreeMap::new();
        for name in names {
            let spec = manifest.get(name)?;
            let proto = xla::HloModuleProto::from_text_file(&spec.file)
                .with_context(|| format!("loading {:?}", spec.file))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .with_context(|| format!("compiling {name}"))?;
            execs.insert(name.clone(), exe);
        }
        Ok(Runtime {
            client,
            manifest,
            execs,
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn has(&self, name: &str) -> bool {
        self.execs.contains_key(name)
    }

    fn spec(&self, name: &str) -> Result<&ArtifactSpec> {
        self.manifest.get(name)
    }

    /// Execute `name` with the given inputs; returns one Tensor per output
    /// (the artifacts are lowered with `return_tuple=True`).
    pub fn execute(&self, name: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let spec = self.spec(name)?.clone();
        let exe = self
            .execs
            .get(name)
            .ok_or_else(|| anyhow!("artifact '{name}' not loaded in this runtime"))?;
        if inputs.len() != spec.inputs.len() {
            return Err(anyhow!(
                "{name}: expected {} inputs, got {}",
                spec.inputs.len(),
                inputs.len()
            ));
        }
        for (i, (t, s)) in inputs.iter().zip(spec.inputs.iter()).enumerate() {
            if t.shape != s.shape {
                return Err(anyhow!(
                    "{name}: input {i} shape {:?} != manifest {:?}",
                    t.shape,
                    s.shape
                ));
            }
        }
        let lits: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<_>>()?;
        let result = exe.execute::<xla::Literal>(&lits)?[0][0].to_literal_sync()?;
        let parts = result.to_tuple()?;
        if parts.len() != spec.outputs.len() {
            return Err(anyhow!(
                "{name}: got {} outputs, manifest says {}",
                parts.len(),
                spec.outputs.len()
            ));
        }
        let mut out = Vec::with_capacity(parts.len());
        for (lit, os) in parts.into_iter().zip(spec.outputs.iter()) {
            let data: Vec<f32> = lit.to_vec()?;
            out.push(Tensor::new(data, &os.shape));
        }
        Ok(out)
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<std::path::PathBuf> {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.json").exists().then_some(dir)
    }

    #[test]
    fn tensor_shape_checks() {
        let t = Tensor::zeros(&[2, 3]);
        assert_eq!(t.data.len(), 6);
        let i = Tensor::from_i32(&[1, 2], &[2]);
        assert!(i.is_i32);
    }

    // The following tests need `make artifacts` to have run; they are the
    // core AOT round-trip checks (python lowers, rust executes).

    #[test]
    fn mproject_identity_roundtrip() {
        let Some(dir) = artifacts_dir() else { return };
        let rt = Runtime::load_subset(&dir, &["mproject"]).unwrap();
        let t = rt.manifest().tile;
        let img: Vec<f32> = (0..t * t).map(|i| (i % 97) as f32 * 0.1).collect();
        let params = Tensor::new(vec![1.0, 0.0, 0.0, 1.0, 0.0, 0.0], &[6]);
        let out = rt
            .execute("mproject", &[Tensor::new(img.clone(), &[t, t]), params])
            .unwrap();
        assert_eq!(out.len(), 2);
        // identity warp: interior pixels match exactly, border weight 0
        let (proj, w) = (&out[0], &out[1]);
        for r in 0..t - 1 {
            for c in 0..t - 1 {
                assert_eq!(proj.data[r * t + c], img[r * t + c]);
                assert_eq!(w.data[r * t + c], 1.0);
            }
        }
        assert_eq!(w.data[t * t - 1], 0.0);
    }

    #[test]
    fn mdifffit_recovers_constant_offset() {
        let Some(dir) = artifacts_dir() else { return };
        let rt = Runtime::load_subset(&dir, &["mdifffit"]).unwrap();
        let (t, v) = (rt.manifest().tile, rt.manifest().overlap);
        let p2: Vec<f32> = (0..t * v).map(|i| (i % 13) as f32).collect();
        let p1: Vec<f32> = p2.iter().map(|x| x + 2.5).collect();
        let w = vec![1.0f32; t * v];
        let out = rt
            .execute(
                "mdifffit",
                &[
                    Tensor::new(p1, &[t, v]),
                    Tensor::new(p2, &[t, v]),
                    Tensor::new(w, &[t, v]),
                ],
            )
            .unwrap();
        let coeffs = &out[0];
        assert!((coeffs.data[0] - 2.5).abs() < 1e-2, "a = {}", coeffs.data[0]);
        assert!(coeffs.data[1].abs() < 1e-3);
        assert!(coeffs.data[2].abs() < 1e-3);
    }

    #[test]
    fn wrong_shape_rejected() {
        let Some(dir) = artifacts_dir() else { return };
        let rt = Runtime::load_subset(&dir, &["mbackground"]).unwrap();
        let bad = Tensor::zeros(&[2, 2]);
        let err = rt
            .execute("mbackground", &[bad.clone(), bad.clone(), bad])
            .unwrap_err();
        assert!(format!("{err}").contains("shape"));
    }

    #[test]
    fn unloaded_artifact_rejected() {
        let Some(dir) = artifacts_dir() else { return };
        let rt = Runtime::load_subset(&dir, &["mproject"]).unwrap();
        assert!(rt.has("mproject"));
        assert!(!rt.has("mdifffit"));
    }
}
