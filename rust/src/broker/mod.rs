//! Message broker substrate: RabbitMQ-like named work queues.
//!
//! The worker-pools model (§3.5) publishes each ready task to the queue of
//! its task type; worker pods consume with prefetch 1 and ack on
//! completion. Queue *lengths* are the autoscaler's primary metric, exactly
//! as in the paper ("The length of these queues is the main metric used to
//! make decision about scaling the worker pools").
//!
//! Queue names are interned at declaration into dense [`PoolId`] indices:
//! the simulation hot path (publish/fetch/ack per task, backlog reads per
//! autoscale tick) indexes a `Vec` instead of hashing/cloning `String`
//! keys, which together with the driver's pool tables removed every
//! per-event string allocation (EXPERIMENTS.md §Perf). Names remain
//! available through [`Broker::name`] for metrics labels and reports.
//!
//! ## Multi-tenancy (fleet service)
//!
//! Every queue is internally a set of per-[`TenantId`] FIFO *lanes* served
//! by weighted fair-share (stride) scheduling: each lane carries a virtual
//! "pass" that advances by `STRIDE_SCALE / weight` per delivery, and
//! [`Broker::fetch`] always serves the non-empty lane with the lowest
//! pass. A lane that was idle re-enters at the queue's current virtual
//! time, so a bursty tenant can neither bank credit while idle nor starve
//! steady tenants. With a single tenant (the default — every classic
//! single-workflow simulation) there is exactly one lane and the queue
//! degenerates to the original plain FIFO, bit for bit.

use crate::workflow::task::TaskId;
use std::collections::VecDeque;

/// Dense handle for a declared pool/queue. Shared vocabulary between the
/// [`Broker`], the autoscaler's pool specs, worker-pod payloads, and the
/// driver's deployment/idle tables — all of which index `Vec`s by it.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct PoolId(pub u16);

impl PoolId {
    #[inline]
    pub fn idx(self) -> usize {
        self.0 as usize
    }
}

/// Dense tenant handle for multi-tenant fleet runs. Tenant 0 is the
/// default lane used by every single-workflow simulation.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct TenantId(pub u16);

impl TenantId {
    #[inline]
    pub fn idx(self) -> usize {
        self.0 as usize
    }
}

/// Stride-scheduling scale: a lane of weight `w` advances its pass by
/// `STRIDE_SCALE / w` per delivery, so relative service rates are
/// proportional to weights.
const STRIDE_SCALE: u64 = 1 << 32;

/// Upper bound on tenant weights: keeps every stride >= 2^12, so a lane's
/// pass always advances and proportionality stays exact (a weight large
/// enough to truncate its stride to 0 would turn fair share into absolute
/// priority).
const MAX_WEIGHT: u64 = 1 << 20;

/// One named work queue: per-tenant FIFO lanes + fair-share dequeue state.
#[derive(Debug)]
pub struct Queue {
    /// Ready messages per tenant lane.
    lanes: Vec<VecDeque<TaskId>>,
    /// Stride pass per lane (virtual service time consumed).
    pass: Vec<u64>,
    /// Virtual time of the queue: pass of the most recently served lane.
    /// Idle lanes re-enter at this value (no banked credit).
    vtime: u64,
    /// Delivered but not yet acked (prefetch window).
    unacked: usize,
    // counters
    pub published_total: u64,
    pub acked_total: u64,
}

impl Queue {
    fn with_tenants(n: usize) -> Self {
        Queue {
            lanes: (0..n).map(|_| VecDeque::new()).collect(),
            pass: vec![0; n],
            vtime: 0,
            unacked: 0,
            published_total: 0,
            acked_total: 0,
        }
    }

    /// Messages waiting for a consumer (all lanes).
    pub fn depth(&self) -> usize {
        self.lanes.iter().map(|l| l.len()).sum()
    }

    /// Messages a given tenant has waiting.
    pub fn depth_for(&self, tenant: TenantId) -> usize {
        self.lanes[tenant.idx()].len()
    }

    /// Depth + unacked: the autoscaler's "workload" for this queue. This
    /// is the *aggregate* over all tenant lanes — the autoscaler sizes the
    /// shared pool, while fairness is enforced at dequeue time.
    pub fn backlog(&self) -> usize {
        self.depth() + self.unacked
    }

    pub fn unacked(&self) -> usize {
        self.unacked
    }
}

impl Default for Queue {
    fn default() -> Self {
        Queue::with_tenants(1)
    }
}

/// The broker: a set of queues, dense-indexed by [`PoolId`].
#[derive(Debug)]
pub struct Broker {
    queues: Vec<Queue>,
    names: Vec<String>,
    /// Per-tenant stride (`STRIDE_SCALE / weight`); length = tenant count.
    strides: Vec<u64>,
}

impl Default for Broker {
    fn default() -> Self {
        Broker {
            queues: Vec::new(),
            names: Vec::new(),
            strides: vec![STRIDE_SCALE],
        }
    }
}

impl Broker {
    pub fn new() -> Self {
        Broker::default()
    }

    /// Configure the tenant lanes and their fair-share weights (a weight-2
    /// tenant is served twice as often as a weight-1 tenant when both have
    /// backlog). Must be called before any message is published; existing
    /// declared queues are re-laned.
    pub fn set_tenant_weights(&mut self, weights: &[u64]) {
        assert!(!weights.is_empty(), "at least one tenant is required");
        assert!(
            weights.iter().all(|&w| (1..=MAX_WEIGHT).contains(&w)),
            "tenant weights must be in 1..={MAX_WEIGHT}"
        );
        self.strides = weights.iter().map(|&w| STRIDE_SCALE / w).collect();
        for q in &mut self.queues {
            assert!(
                q.backlog() == 0,
                "tenant weights must be set before publishing"
            );
            *q = Queue::with_tenants(self.strides.len());
        }
    }

    /// Number of configured tenant lanes.
    pub fn n_tenants(&self) -> usize {
        self.strides.len()
    }

    /// Declare a queue, interning its name (idempotent: re-declaring an
    /// existing name returns the original id).
    pub fn declare(&mut self, name: &str) -> PoolId {
        if let Some(i) = self.names.iter().position(|n| n == name) {
            return PoolId(i as u16);
        }
        assert!(self.names.len() < u16::MAX as usize, "pool id space exhausted");
        self.names.push(name.to_string());
        self.queues.push(Queue::with_tenants(self.strides.len()));
        PoolId((self.queues.len() - 1) as u16)
    }

    /// Look up a declared queue by name (cold path: config/reports only).
    pub fn resolve(&self, name: &str) -> Option<PoolId> {
        self.names
            .iter()
            .position(|n| n == name)
            .map(|i| PoolId(i as u16))
    }

    /// The interned name of a queue.
    pub fn name(&self, id: PoolId) -> &str {
        &self.names[id.idx()]
    }

    /// Number of declared queues (valid `PoolId`s are `0..len`).
    pub fn len(&self) -> usize {
        self.queues.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queues.is_empty()
    }

    pub fn queue(&self, id: PoolId) -> &Queue {
        &self.queues[id.idx()]
    }

    pub fn queue_names(&self) -> impl Iterator<Item = &str> {
        self.names.iter().map(|s| s.as_str())
    }

    /// Publish a task on the default tenant lane (single-workflow runs).
    pub fn publish(&mut self, id: PoolId, task: TaskId) {
        self.publish_for(id, task, TenantId(0));
    }

    /// Publish a task on a tenant's lane of a queue.
    pub fn publish_for(&mut self, id: PoolId, task: TaskId, tenant: TenantId) {
        let q = &mut self.queues[id.idx()];
        let lane = tenant.idx();
        assert!(
            lane < q.lanes.len(),
            "tenant {lane} beyond the configured lane count {}",
            q.lanes.len()
        );
        if q.lanes[lane].is_empty() {
            // lane (re)activation: join at the queue's virtual time so an
            // idle tenant cannot burst ahead of continuously-active ones
            q.pass[lane] = q.pass[lane].max(q.vtime);
        }
        q.lanes[lane].push_back(task);
        q.published_total += 1;
    }

    /// Deliver one message to a consumer (prefetch 1): weighted fair-share
    /// pick across tenant lanes, then FIFO within the lane; moves the
    /// message to the unacked window. Ties resolve to the lowest tenant id
    /// (deterministic).
    pub fn fetch(&mut self, id: PoolId) -> Option<TaskId> {
        let q = &mut self.queues[id.idx()];
        let mut best: Option<usize> = None;
        for (lane, dq) in q.lanes.iter().enumerate() {
            if dq.is_empty() {
                continue;
            }
            match best {
                Some(b) if q.pass[lane] >= q.pass[b] => {}
                _ => best = Some(lane),
            }
        }
        let lane = best?;
        let t = q.lanes[lane].pop_front().expect("non-empty lane");
        q.vtime = q.pass[lane];
        q.pass[lane] = q.pass[lane].wrapping_add(self.strides[lane]);
        q.unacked += 1;
        Some(t)
    }

    /// Deliver one message from a *single* tenant's lane (isolation:
    /// a worker running on a node owned by one tenant may only consume
    /// that tenant's work). FIFO within the lane, with exactly
    /// [`Broker::fetch`]'s stride bookkeeping so interleaving constrained
    /// and unconstrained consumers keeps fair-share accounting coherent.
    /// `None` when the lane is unconfigured or empty.
    pub fn fetch_from(&mut self, id: PoolId, tenant: TenantId) -> Option<TaskId> {
        let q = &mut self.queues[id.idx()];
        let lane = tenant.idx();
        if lane >= q.lanes.len() || q.lanes[lane].is_empty() {
            return None;
        }
        let t = q.lanes[lane].pop_front().expect("non-empty lane");
        q.vtime = q.pass[lane];
        q.pass[lane] = q.pass[lane].wrapping_add(self.strides[lane]);
        q.unacked += 1;
        Some(t)
    }

    /// Ack a previously fetched message.
    pub fn ack(&mut self, id: PoolId) {
        let q = &mut self.queues[id.idx()];
        assert!(
            q.unacked > 0,
            "ack without outstanding delivery on '{}'",
            self.names[id.idx()]
        );
        q.unacked -= 1;
        q.acked_total += 1;
    }

    /// Requeue an unacked message (consumer died — failure injection) at
    /// the front of its tenant's lane, so it is redelivered first.
    pub fn nack_requeue(&mut self, id: PoolId, task: TaskId, tenant: TenantId) {
        let q = &mut self.queues[id.idx()];
        assert!(
            q.unacked > 0,
            "nack without outstanding delivery on '{}'",
            self.names[id.idx()]
        );
        q.unacked -= 1;
        let lane = tenant.idx();
        if q.lanes[lane].is_empty() {
            // same reactivation rule as publish: while the lane sat empty
            // (its only message was in flight) other lanes advanced vtime,
            // and a stale pass would let this tenant bank credit
            q.pass[lane] = q.pass[lane].max(q.vtime);
        }
        q.lanes[lane].push_front(task);
    }

    /// Drop an unacked delivery without redelivering it (chaos recovery:
    /// the consumer died and the *recovery policy* owns the message now —
    /// it will be re-published after its retry back-off, so the broker
    /// must not also requeue it).
    pub fn nack_drop(&mut self, id: PoolId) {
        let q = &mut self.queues[id.idx()];
        assert!(
            q.unacked > 0,
            "nack_drop without outstanding delivery on '{}'",
            self.names[id.idx()]
        );
        q.unacked -= 1;
    }

    /// Total backlog across all queues (for reports).
    pub fn total_backlog(&self) -> usize {
        self.queues.iter().map(|q| q.backlog()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn publish_fetch_ack_cycle() {
        let mut b = Broker::new();
        let q = b.declare("mProject");
        b.publish(q, TaskId(1));
        b.publish(q, TaskId(2));
        assert_eq!(b.queue(q).depth(), 2);

        let t = b.fetch(q).unwrap();
        assert_eq!(t, TaskId(1)); // FIFO
        assert_eq!(b.queue(q).depth(), 1);
        assert_eq!(b.queue(q).backlog(), 2); // 1 ready + 1 unacked

        b.ack(q);
        assert_eq!(b.queue(q).backlog(), 1);
        assert_eq!(b.queue(q).acked_total, 1);
    }

    #[test]
    fn declare_interns_and_is_idempotent() {
        let mut b = Broker::new();
        let a = b.declare("a");
        let c = b.declare("b");
        assert_eq!(b.declare("a"), a);
        assert_ne!(a, c);
        assert_eq!(b.name(a), "a");
        assert_eq!(b.resolve("b"), Some(c));
        assert_eq!(b.resolve("missing"), None);
        assert_eq!(b.len(), 2);
        let names: Vec<&str> = b.queue_names().collect();
        assert_eq!(names, vec!["a", "b"]);
    }

    #[test]
    fn fetch_empty_returns_none() {
        let mut b = Broker::new();
        let q = b.declare("q");
        assert_eq!(b.fetch(q), None);
    }

    #[test]
    #[should_panic(expected = "index out of bounds")]
    fn undeclared_id_panics() {
        let mut b = Broker::new();
        b.publish(PoolId(0), TaskId(0));
    }

    #[test]
    fn nack_requeues_at_front() {
        let mut b = Broker::new();
        let q = b.declare("q");
        b.publish(q, TaskId(1));
        b.publish(q, TaskId(2));
        let t = b.fetch(q).unwrap();
        b.nack_requeue(q, t, TenantId(0));
        assert_eq!(b.fetch(q), Some(TaskId(1))); // redelivered first
    }

    #[test]
    fn queues_are_independent() {
        let mut b = Broker::new();
        let a = b.declare("a");
        let c = b.declare("b");
        b.publish(a, TaskId(1));
        assert_eq!(b.queue(a).depth(), 1);
        assert_eq!(b.queue(c).depth(), 0);
        assert_eq!(b.total_backlog(), 1);
    }

    #[test]
    #[should_panic(expected = "ack without outstanding")]
    fn double_ack_panics() {
        let mut b = Broker::new();
        let q = b.declare("q");
        b.publish(q, TaskId(1));
        b.fetch(q);
        b.ack(q);
        b.ack(q);
    }

    #[test]
    fn nack_drop_consumes_the_delivery() {
        let mut b = Broker::new();
        let q = b.declare("q");
        b.publish(q, TaskId(1));
        b.publish(q, TaskId(2));
        let t = b.fetch(q).unwrap();
        assert_eq!(t, TaskId(1));
        assert_eq!(b.queue(q).backlog(), 2);
        b.nack_drop(q);
        // the message is gone from the broker (the recovery policy will
        // re-publish it later); only task 2 remains
        assert_eq!(b.queue(q).backlog(), 1);
        assert_eq!(b.queue(q).unacked(), 0);
        assert_eq!(b.fetch(q), Some(TaskId(2)));
        // re-publication is an ordinary publish
        b.publish(q, TaskId(1));
        assert_eq!(b.queue(q).depth(), 1);
    }

    #[test]
    #[should_panic(expected = "nack_drop without outstanding")]
    fn nack_drop_without_delivery_panics() {
        let mut b = Broker::new();
        let q = b.declare("q");
        b.nack_drop(q);
    }

    // -- multi-tenant fair-share coverage --------------------------------

    #[test]
    fn equal_weights_round_robin() {
        let mut b = Broker::new();
        b.set_tenant_weights(&[1, 1]);
        let q = b.declare("q");
        for i in 0..3 {
            b.publish_for(q, TaskId(i), TenantId(0));
        }
        for i in 10..13 {
            b.publish_for(q, TaskId(i), TenantId(1));
        }
        let order: Vec<u32> = (0..6).map(|_| b.fetch(q).unwrap().0).collect();
        assert_eq!(order, vec![0, 10, 1, 11, 2, 12]);
    }

    #[test]
    fn weighted_fair_share_serves_proportionally() {
        let mut b = Broker::new();
        b.set_tenant_weights(&[2, 1]);
        let q = b.declare("q");
        for i in 0..6 {
            b.publish_for(q, TaskId(i), TenantId(0));
        }
        for i in 10..16 {
            b.publish_for(q, TaskId(i), TenantId(1));
        }
        // 2:1 service ratio — tenant 0's six tasks all leave within the
        // first nine deliveries
        let first9: Vec<u32> = (0..9).map(|_| b.fetch(q).unwrap().0).collect();
        assert_eq!(first9.iter().filter(|&&t| t < 10).count(), 6, "{first9:?}");
        assert_eq!(first9.iter().filter(|&&t| t >= 10).count(), 3);
        // remainder drains tenant 1 FIFO
        let rest: Vec<u32> = (0..3).map(|_| b.fetch(q).unwrap().0).collect();
        assert_eq!(rest, vec![13, 14, 15]);
    }

    #[test]
    fn idle_tenant_cannot_burst_ahead() {
        let mut b = Broker::new();
        b.set_tenant_weights(&[1, 1]);
        let q = b.declare("q");
        for i in 0..4 {
            b.publish_for(q, TaskId(i), TenantId(0));
        }
        // tenant 0 served twice while tenant 1 is idle
        assert_eq!(b.fetch(q), Some(TaskId(0)));
        assert_eq!(b.fetch(q), Some(TaskId(1)));
        // tenant 1 activates late: joins at the current virtual time and
        // service alternates — it does not drain first to "catch up"
        for i in 10..14 {
            b.publish_for(q, TaskId(i), TenantId(1));
        }
        let next: Vec<u32> = (0..4).map(|_| b.fetch(q).unwrap().0).collect();
        assert_eq!(next, vec![10, 2, 11, 3]);
    }

    #[test]
    fn per_tenant_depth_and_aggregate_backlog() {
        let mut b = Broker::new();
        b.set_tenant_weights(&[1, 1, 1]);
        let q = b.declare("q");
        b.publish_for(q, TaskId(1), TenantId(0));
        b.publish_for(q, TaskId(2), TenantId(2));
        b.publish_for(q, TaskId(3), TenantId(2));
        assert_eq!(b.queue(q).depth(), 3);
        assert_eq!(b.queue(q).depth_for(TenantId(0)), 1);
        assert_eq!(b.queue(q).depth_for(TenantId(1)), 0);
        assert_eq!(b.queue(q).depth_for(TenantId(2)), 2);
        b.fetch(q);
        assert_eq!(b.queue(q).backlog(), 3, "unacked still counts");
    }

    #[test]
    fn tenant_nack_redelivers_on_same_lane_first() {
        let mut b = Broker::new();
        b.set_tenant_weights(&[1, 1]);
        let q = b.declare("q");
        b.publish_for(q, TaskId(1), TenantId(1));
        b.publish_for(q, TaskId(2), TenantId(1));
        let t = b.fetch(q).unwrap();
        assert_eq!(t, TaskId(1));
        b.nack_requeue(q, t, TenantId(1));
        assert_eq!(b.fetch(q), Some(TaskId(1)));
        assert_eq!(b.fetch(q), Some(TaskId(2)));
    }

    #[test]
    fn nack_on_empty_lane_cannot_bank_credit() {
        let mut b = Broker::new();
        b.set_tenant_weights(&[1, 1]);
        let q = b.declare("q");
        // tenant 1's only task goes in flight; its lane sits empty while
        // tenant 0 is served four times (vtime advances without it)
        b.publish_for(q, TaskId(20), TenantId(1));
        let inflight = b.fetch(q).unwrap();
        assert_eq!(inflight, TaskId(20));
        for i in 0..4 {
            b.publish_for(q, TaskId(i), TenantId(0));
        }
        for i in 0..4 {
            assert_eq!(b.fetch(q), Some(TaskId(i)));
        }
        // the consumer dies: redelivery must re-enter at current vtime,
        // not at tenant 1's stale pass
        b.nack_requeue(q, inflight, TenantId(1));
        b.publish_for(q, TaskId(4), TenantId(0));
        b.publish_for(q, TaskId(21), TenantId(1));
        let order: Vec<u32> = (0..3).map(|_| b.fetch(q).unwrap().0).collect();
        // alternating service, not [20, 21, 4] (banked credit)
        assert_eq!(order, vec![20, 4, 21]);
    }

    #[test]
    fn fetch_from_serves_only_the_named_lane() {
        let mut b = Broker::new();
        b.set_tenant_weights(&[1, 1]);
        let q = b.declare("q");
        b.publish_for(q, TaskId(1), TenantId(0));
        b.publish_for(q, TaskId(10), TenantId(1));
        b.publish_for(q, TaskId(11), TenantId(1));
        // a tenant-1 worker never sees tenant 0's message
        assert_eq!(b.fetch_from(q, TenantId(1)), Some(TaskId(10)));
        assert_eq!(b.fetch_from(q, TenantId(1)), Some(TaskId(11)));
        assert_eq!(b.fetch_from(q, TenantId(1)), None);
        assert_eq!(b.queue(q).depth_for(TenantId(0)), 1);
        // out-of-range lanes are a clean miss, not a panic
        assert_eq!(b.fetch_from(q, TenantId(7)), None);
        assert_eq!(b.queue(q).unacked(), 2);
    }

    #[test]
    fn fetch_from_keeps_stride_accounting_coherent_with_fetch() {
        // two brokers, same traffic: one drains a lane via fetch_from, the
        // other via fetch with the competing lane empty — the fair-share
        // state they leave behind must be identical, which we observe
        // through identical subsequent service order
        let mut a = Broker::new();
        let mut b = Broker::new();
        for br in [&mut a, &mut b] {
            br.set_tenant_weights(&[1, 1]);
            let q = br.declare("q");
            br.publish_for(q, TaskId(10), TenantId(1));
            br.publish_for(q, TaskId(11), TenantId(1));
        }
        let q = PoolId(0);
        assert_eq!(a.fetch_from(q, TenantId(1)), Some(TaskId(10)));
        assert_eq!(a.fetch_from(q, TenantId(1)), Some(TaskId(11)));
        assert_eq!(b.fetch(q), Some(TaskId(10)));
        assert_eq!(b.fetch(q), Some(TaskId(11)));
        for br in [&mut a, &mut b] {
            br.publish_for(q, TaskId(0), TenantId(0));
            br.publish_for(q, TaskId(12), TenantId(1));
        }
        assert_eq!(a.fetch(q), b.fetch(q));
        assert_eq!(a.fetch(q), b.fetch(q));
    }

    #[test]
    #[should_panic(expected = "tenant weights must be in")]
    fn oversized_weight_is_rejected() {
        let mut b = Broker::new();
        b.set_tenant_weights(&[1 << 21, 1]);
    }

    #[test]
    #[should_panic(expected = "beyond the configured lane count")]
    fn publish_for_unconfigured_tenant_panics() {
        let mut b = Broker::new();
        let q = b.declare("q");
        b.publish_for(q, TaskId(0), TenantId(1));
    }

    #[test]
    #[should_panic(expected = "before publishing")]
    fn late_weight_change_panics() {
        let mut b = Broker::new();
        let q = b.declare("q");
        b.publish(q, TaskId(0));
        b.set_tenant_weights(&[1, 1]);
    }
}
