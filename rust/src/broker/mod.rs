//! Message broker substrate: RabbitMQ-like named work queues.
//!
//! The worker-pools model (§3.5) publishes each ready task to the queue of
//! its task type; worker pods consume with prefetch 1 and ack on
//! completion. Queue *lengths* are the autoscaler's primary metric, exactly
//! as in the paper ("The length of these queues is the main metric used to
//! make decision about scaling the worker pools").

use crate::workflow::task::TaskId;
use std::collections::{BTreeMap, VecDeque};

/// One named work queue.
#[derive(Debug, Default)]
pub struct Queue {
    ready: VecDeque<TaskId>,
    /// Delivered but not yet acked (prefetch window).
    unacked: usize,
    // counters
    pub published_total: u64,
    pub acked_total: u64,
}

impl Queue {
    /// Messages waiting for a consumer.
    pub fn depth(&self) -> usize {
        self.ready.len()
    }

    /// Depth + unacked: the autoscaler's "workload" for this queue.
    pub fn backlog(&self) -> usize {
        self.ready.len() + self.unacked
    }

    pub fn unacked(&self) -> usize {
        self.unacked
    }
}

/// The broker: a set of named queues.
#[derive(Debug, Default)]
pub struct Broker {
    queues: BTreeMap<String, Queue>,
}

impl Broker {
    pub fn new() -> Self {
        Broker::default()
    }

    /// Declare a queue (idempotent).
    pub fn declare(&mut self, name: &str) {
        self.queues.entry(name.to_string()).or_default();
    }

    pub fn queue(&self, name: &str) -> Option<&Queue> {
        self.queues.get(name)
    }

    pub fn queue_names(&self) -> impl Iterator<Item = &str> {
        self.queues.keys().map(|s| s.as_str())
    }

    /// Publish a task to a queue. The queue must have been declared.
    pub fn publish(&mut self, name: &str, task: TaskId) {
        let q = self
            .queues
            .get_mut(name)
            .unwrap_or_else(|| panic!("publish to undeclared queue '{name}'"));
        q.ready.push_back(task);
        q.published_total += 1;
    }

    /// Deliver one message to a consumer (prefetch 1): moves it to the
    /// unacked window.
    pub fn fetch(&mut self, name: &str) -> Option<TaskId> {
        let q = self.queues.get_mut(name)?;
        let t = q.ready.pop_front()?;
        q.unacked += 1;
        Some(t)
    }

    /// Ack a previously fetched message.
    pub fn ack(&mut self, name: &str) {
        let q = self
            .queues
            .get_mut(name)
            .unwrap_or_else(|| panic!("ack on undeclared queue '{name}'"));
        assert!(q.unacked > 0, "ack without outstanding delivery on '{name}'");
        q.unacked -= 1;
        q.acked_total += 1;
    }

    /// Requeue an unacked message (consumer died — failure injection).
    pub fn nack_requeue(&mut self, name: &str, task: TaskId) {
        let q = self
            .queues
            .get_mut(name)
            .unwrap_or_else(|| panic!("nack on undeclared queue '{name}'"));
        assert!(q.unacked > 0);
        q.unacked -= 1;
        q.ready.push_front(task);
    }

    /// Total backlog across all queues (for reports).
    pub fn total_backlog(&self) -> usize {
        self.queues.values().map(|q| q.backlog()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn publish_fetch_ack_cycle() {
        let mut b = Broker::new();
        b.declare("mProject");
        b.publish("mProject", TaskId(1));
        b.publish("mProject", TaskId(2));
        assert_eq!(b.queue("mProject").unwrap().depth(), 2);

        let t = b.fetch("mProject").unwrap();
        assert_eq!(t, TaskId(1)); // FIFO
        assert_eq!(b.queue("mProject").unwrap().depth(), 1);
        assert_eq!(b.queue("mProject").unwrap().backlog(), 2); // 1 ready + 1 unacked

        b.ack("mProject");
        assert_eq!(b.queue("mProject").unwrap().backlog(), 1);
        assert_eq!(b.queue("mProject").unwrap().acked_total, 1);
    }

    #[test]
    fn fetch_empty_returns_none() {
        let mut b = Broker::new();
        b.declare("q");
        assert_eq!(b.fetch("q"), None);
        assert_eq!(b.fetch("missing"), None);
    }

    #[test]
    #[should_panic(expected = "undeclared queue")]
    fn publish_undeclared_panics() {
        let mut b = Broker::new();
        b.publish("nope", TaskId(0));
    }

    #[test]
    fn nack_requeues_at_front() {
        let mut b = Broker::new();
        b.declare("q");
        b.publish("q", TaskId(1));
        b.publish("q", TaskId(2));
        let t = b.fetch("q").unwrap();
        b.nack_requeue("q", t);
        assert_eq!(b.fetch("q"), Some(TaskId(1))); // redelivered first
    }

    #[test]
    fn queues_are_independent() {
        let mut b = Broker::new();
        b.declare("a");
        b.declare("b");
        b.publish("a", TaskId(1));
        assert_eq!(b.queue("a").unwrap().depth(), 1);
        assert_eq!(b.queue("b").unwrap().depth(), 0);
        assert_eq!(b.total_backlog(), 1);
    }

    #[test]
    #[should_panic(expected = "ack without outstanding")]
    fn double_ack_panics() {
        let mut b = Broker::new();
        b.declare("q");
        b.publish("q", TaskId(1));
        b.fetch("q");
        b.ack("q");
        b.ack("q");
    }
}
