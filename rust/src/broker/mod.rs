//! Message broker substrate: RabbitMQ-like named work queues.
//!
//! The worker-pools model (§3.5) publishes each ready task to the queue of
//! its task type; worker pods consume with prefetch 1 and ack on
//! completion. Queue *lengths* are the autoscaler's primary metric, exactly
//! as in the paper ("The length of these queues is the main metric used to
//! make decision about scaling the worker pools").
//!
//! Queue names are interned at declaration into dense [`PoolId`] indices:
//! the simulation hot path (publish/fetch/ack per task, backlog reads per
//! autoscale tick) indexes a `Vec` instead of hashing/cloning `String`
//! keys, which together with the driver's pool tables removed every
//! per-event string allocation (EXPERIMENTS.md §Perf). Names remain
//! available through [`Broker::name`] for metrics labels and reports.

use crate::workflow::task::TaskId;
use std::collections::VecDeque;

/// Dense handle for a declared pool/queue. Shared vocabulary between the
/// [`Broker`], the autoscaler's pool specs, worker-pod payloads, and the
/// driver's deployment/idle tables — all of which index `Vec`s by it.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct PoolId(pub u16);

impl PoolId {
    #[inline]
    pub fn idx(self) -> usize {
        self.0 as usize
    }
}

/// One named work queue.
#[derive(Debug, Default)]
pub struct Queue {
    ready: VecDeque<TaskId>,
    /// Delivered but not yet acked (prefetch window).
    unacked: usize,
    // counters
    pub published_total: u64,
    pub acked_total: u64,
}

impl Queue {
    /// Messages waiting for a consumer.
    pub fn depth(&self) -> usize {
        self.ready.len()
    }

    /// Depth + unacked: the autoscaler's "workload" for this queue.
    pub fn backlog(&self) -> usize {
        self.ready.len() + self.unacked
    }

    pub fn unacked(&self) -> usize {
        self.unacked
    }
}

/// The broker: a set of queues, dense-indexed by [`PoolId`].
#[derive(Debug, Default)]
pub struct Broker {
    queues: Vec<Queue>,
    names: Vec<String>,
}

impl Broker {
    pub fn new() -> Self {
        Broker::default()
    }

    /// Declare a queue, interning its name (idempotent: re-declaring an
    /// existing name returns the original id).
    pub fn declare(&mut self, name: &str) -> PoolId {
        if let Some(i) = self.names.iter().position(|n| n == name) {
            return PoolId(i as u16);
        }
        assert!(self.names.len() < u16::MAX as usize, "pool id space exhausted");
        self.names.push(name.to_string());
        self.queues.push(Queue::default());
        PoolId((self.queues.len() - 1) as u16)
    }

    /// Look up a declared queue by name (cold path: config/reports only).
    pub fn resolve(&self, name: &str) -> Option<PoolId> {
        self.names
            .iter()
            .position(|n| n == name)
            .map(|i| PoolId(i as u16))
    }

    /// The interned name of a queue.
    pub fn name(&self, id: PoolId) -> &str {
        &self.names[id.idx()]
    }

    /// Number of declared queues (valid `PoolId`s are `0..len`).
    pub fn len(&self) -> usize {
        self.queues.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queues.is_empty()
    }

    pub fn queue(&self, id: PoolId) -> &Queue {
        &self.queues[id.idx()]
    }

    pub fn queue_names(&self) -> impl Iterator<Item = &str> {
        self.names.iter().map(|s| s.as_str())
    }

    /// Publish a task to a queue.
    pub fn publish(&mut self, id: PoolId, task: TaskId) {
        let q = &mut self.queues[id.idx()];
        q.ready.push_back(task);
        q.published_total += 1;
    }

    /// Deliver one message to a consumer (prefetch 1): moves it to the
    /// unacked window.
    pub fn fetch(&mut self, id: PoolId) -> Option<TaskId> {
        let q = &mut self.queues[id.idx()];
        let t = q.ready.pop_front()?;
        q.unacked += 1;
        Some(t)
    }

    /// Ack a previously fetched message.
    pub fn ack(&mut self, id: PoolId) {
        let q = &mut self.queues[id.idx()];
        assert!(
            q.unacked > 0,
            "ack without outstanding delivery on '{}'",
            self.names[id.idx()]
        );
        q.unacked -= 1;
        q.acked_total += 1;
    }

    /// Requeue an unacked message (consumer died — failure injection).
    pub fn nack_requeue(&mut self, id: PoolId, task: TaskId) {
        let q = &mut self.queues[id.idx()];
        assert!(
            q.unacked > 0,
            "nack without outstanding delivery on '{}'",
            self.names[id.idx()]
        );
        q.unacked -= 1;
        q.ready.push_front(task);
    }

    /// Total backlog across all queues (for reports).
    pub fn total_backlog(&self) -> usize {
        self.queues.iter().map(|q| q.backlog()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn publish_fetch_ack_cycle() {
        let mut b = Broker::new();
        let q = b.declare("mProject");
        b.publish(q, TaskId(1));
        b.publish(q, TaskId(2));
        assert_eq!(b.queue(q).depth(), 2);

        let t = b.fetch(q).unwrap();
        assert_eq!(t, TaskId(1)); // FIFO
        assert_eq!(b.queue(q).depth(), 1);
        assert_eq!(b.queue(q).backlog(), 2); // 1 ready + 1 unacked

        b.ack(q);
        assert_eq!(b.queue(q).backlog(), 1);
        assert_eq!(b.queue(q).acked_total, 1);
    }

    #[test]
    fn declare_interns_and_is_idempotent() {
        let mut b = Broker::new();
        let a = b.declare("a");
        let c = b.declare("b");
        assert_eq!(b.declare("a"), a);
        assert_ne!(a, c);
        assert_eq!(b.name(a), "a");
        assert_eq!(b.resolve("b"), Some(c));
        assert_eq!(b.resolve("missing"), None);
        assert_eq!(b.len(), 2);
        let names: Vec<&str> = b.queue_names().collect();
        assert_eq!(names, vec!["a", "b"]);
    }

    #[test]
    fn fetch_empty_returns_none() {
        let mut b = Broker::new();
        let q = b.declare("q");
        assert_eq!(b.fetch(q), None);
    }

    #[test]
    #[should_panic(expected = "index out of bounds")]
    fn undeclared_id_panics() {
        let mut b = Broker::new();
        b.publish(PoolId(0), TaskId(0));
    }

    #[test]
    fn nack_requeues_at_front() {
        let mut b = Broker::new();
        let q = b.declare("q");
        b.publish(q, TaskId(1));
        b.publish(q, TaskId(2));
        let t = b.fetch(q).unwrap();
        b.nack_requeue(q, t);
        assert_eq!(b.fetch(q), Some(TaskId(1))); // redelivered first
    }

    #[test]
    fn queues_are_independent() {
        let mut b = Broker::new();
        let a = b.declare("a");
        let c = b.declare("b");
        b.publish(a, TaskId(1));
        assert_eq!(b.queue(a).depth(), 1);
        assert_eq!(b.queue(c).depth(), 0);
        assert_eq!(b.total_backlog(), 1);
    }

    #[test]
    #[should_panic(expected = "ack without outstanding")]
    fn double_ack_panics() {
        let mut b = Broker::new();
        let q = b.declare("q");
        b.publish(q, TaskId(1));
        b.fetch(q);
        b.ack(q);
        b.ack(q);
    }
}
