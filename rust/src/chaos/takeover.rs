//! Tenant takeover: the blast-radius model behind the deterministic
//! `takeover:<tenant>@<t>` chaos injector.
//!
//! A takeover assumes the worst about one tenant at a fixed instant:
//! every container it has running executes attacker code. What that
//! attacker can *reach* is governed by a minimal RBAC/privilege model
//! derived from the isolation policy
//! ([`crate::k8s::isolation::IsolationPolicy`]):
//!
//! | policy    | node escape | co-resident pods      | storage surfaces          |
//! |-----------|-------------|-----------------------|---------------------------|
//! | shared    | yes         | every pod on reached nodes | node caches + shared backend |
//! | dedicated | yes         | same-tenant only (by placement) | own-pool caches + shared backend |
//! | sandboxed | no          | none                  | shared backend only       |
//!
//! The **blast radius** is computed from the live placement at takeover
//! time — nodes hosting the victim's pods, every pod co-resident on
//! those nodes, and the data-plane surfaces an escaped container could
//! touch. Remediation (in `exec/hooks.rs`) then cordons and drains the
//! reachable nodes with the PR 3 cordon/incarnation machinery (sandboxed
//! runtimes deny the escape, so only the victim's own pods are killed).
//! The whole scenario is RNG-free: the injector fires at a fixed
//! calendar time and the radius is a pure function of simulator state,
//! so identical seed+spec reruns are bit-identical.
//!
//! Grounded in KubeSec-style privilege reachability analysis and the
//! shared-vs-dedicated trade of cluster-of-clusters deployments
//! (PAPERS.md).

use crate::k8s::isolation::IsolationPolicy;
use crate::k8s::node::NodeId;
use crate::k8s::pod::PodTable;

/// Cordon-and-drain window granted to blast-radius nodes before they are
/// reclaimed for re-imaging (mirrors the spot-reclaim warning shape).
pub const TAKEOVER_DRAIN_MS: u64 = 60_000;

/// Re-image/replace time for a reclaimed blast-radius node before its
/// capacity returns (fresh incarnation).
pub const TAKEOVER_REIMAGE_MS: u64 = 240_000;

/// What a compromised container is allowed to reach — the minimal
/// RBAC/privilege model the isolation policy implies.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PrivilegeModel {
    /// Container-to-node escape (hostPath/privileged/kernel surface).
    pub can_reach_node: bool,
    /// From a reached node, co-resident pods are reachable.
    pub can_reach_co_resident: bool,
    /// Node-local caches on reached nodes are readable.
    pub can_reach_node_cache: bool,
    /// The shared storage backend is reachable over the network even
    /// from inside a sandbox.
    pub can_reach_shared_storage: bool,
}

impl PrivilegeModel {
    pub fn for_policy(policy: IsolationPolicy) -> PrivilegeModel {
        let escape = policy.can_reach_node();
        PrivilegeModel {
            can_reach_node: escape,
            can_reach_co_resident: escape,
            can_reach_node_cache: escape,
            can_reach_shared_storage: true,
        }
    }
}

/// The computed reach of one takeover, at the instant it fires.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct BlastRadius {
    /// Nodes the attacker can escape onto (sorted ascending; empty under
    /// a sandboxed runtime).
    pub nodes: Vec<NodeId>,
    /// Pods inside the radius: co-residents of reached nodes, or just
    /// the victim's own pods when escape is denied.
    pub pods: u64,
    /// Radius pods currently embodying *another* tenant's work — the
    /// pods whose loss shows up in innocent tenants' SLOs.
    pub innocent_pods: u64,
    /// Data-plane surfaces reachable: node-local caches on reached nodes
    /// plus the shared backend (0 when the data plane is off).
    pub storage_surfaces: u64,
}

/// Compute the blast radius of `victim` from live placement.
///
/// `effective_tenant` maps a pod *index* to the tenant whose work it
/// currently embodies (`None` for idle infrastructure) — see
/// [`crate::k8s::isolation::IsolationState::effective_tenant`]. Indices
/// keep the two scans below on the SoA [`PodTable`] columns (`phase`,
/// `node`) without materializing pod rows.
pub fn compute_blast_radius(
    victim: u16,
    privilege: &PrivilegeModel,
    pods: &PodTable,
    n_nodes: usize,
    node_failed: impl Fn(NodeId) -> bool,
    effective_tenant: impl Fn(usize) -> Option<u16>,
    data_plane_on: bool,
) -> BlastRadius {
    let mut br = BlastRadius::default();
    let mut on_node = vec![false; n_nodes];
    let mut victim_pods = 0u64;
    for i in 0..pods.len() {
        if pods.is_terminal(i) || effective_tenant(i) != Some(victim) {
            continue;
        }
        victim_pods += 1;
        if let Some(nid) = pods.node[i] {
            if !node_failed(nid) {
                on_node[nid.0] = true;
            }
        }
    }
    if privilege.can_reach_node {
        br.nodes = (0..n_nodes)
            .filter(|&i| on_node[i])
            .map(NodeId)
            .collect();
        for i in 0..pods.len() {
            let Some(nid) = pods.node[i] else { continue };
            if pods.is_terminal(i) || !on_node[nid.0] {
                continue;
            }
            br.pods += 1;
            if privilege.can_reach_co_resident {
                if let Some(t) = effective_tenant(i) {
                    if t != victim {
                        br.innocent_pods += 1;
                    }
                }
            }
        }
    } else {
        br.pods = victim_pods;
    }
    if data_plane_on {
        if privilege.can_reach_node_cache {
            br.storage_surfaces += br.nodes.len() as u64;
        }
        if privilege.can_reach_shared_storage {
            br.storage_surfaces += 1;
        }
    }
    br
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::k8s::pod::{Payload, Pod, PodId, PodPhase};
    use crate::k8s::resources::Resources;
    use crate::sim::SimTime;
    use crate::workflow::task::TaskId;

    /// pods: (id, node, effective tenant, running?)
    fn mkpods(spec: &[(u64, Option<usize>, Option<u16>, bool)]) -> (PodTable, Vec<Option<u16>>) {
        let mut pods = PodTable::new();
        let mut eff = Vec::new();
        for &(id, node, tenant, running) in spec {
            let mut p = Pod::new(
                PodId(id),
                Payload::JobBatch { tasks: vec![TaskId(0)] },
                Resources::new(500, 512),
                SimTime::ZERO,
            );
            p.node = node.map(NodeId);
            p.phase = if running { PodPhase::Running } else { PodPhase::Succeeded };
            pods.push(p);
            eff.push(tenant);
        }
        (pods, eff)
    }

    fn radius(
        victim: u16,
        policy: IsolationPolicy,
        spec: &[(u64, Option<usize>, Option<u16>, bool)],
        data_on: bool,
    ) -> BlastRadius {
        let (pods, eff) = mkpods(spec);
        compute_blast_radius(
            victim,
            &PrivilegeModel::for_policy(policy),
            &pods,
            4,
            |_| false,
            |i: usize| eff[i],
            data_on,
        )
    }

    const MIXED: &[(u64, Option<usize>, Option<u16>, bool)] = &[
        (0, Some(0), Some(0), true),  // victim on node 0
        (1, Some(0), Some(1), true),  // innocent co-resident on node 0
        (2, Some(1), Some(1), true),  // innocent alone on node 1
        (3, Some(2), Some(0), true),  // victim on node 2
        (4, Some(2), None, true),     // idle infra on node 2
        (5, None, Some(0), true),     // victim still pending (no node)
        (6, Some(3), Some(0), false), // terminal victim: out of scope
    ];

    #[test]
    fn shared_radius_reaches_co_residents_and_caches() {
        let br = radius(0, IsolationPolicy::Shared, MIXED, true);
        assert_eq!(br.nodes, vec![NodeId(0), NodeId(2)]);
        // pods on nodes 0+2: victim x2, innocent x1, idle infra x1
        assert_eq!(br.pods, 4);
        assert_eq!(br.innocent_pods, 1);
        // 2 node caches + 1 shared backend
        assert_eq!(br.storage_surfaces, 3);
    }

    #[test]
    fn sandboxed_radius_is_only_the_victims_pods() {
        let br = radius(0, IsolationPolicy::Sandboxed, MIXED, true);
        assert!(br.nodes.is_empty());
        assert_eq!(br.pods, 3, "victim's own non-terminal pods");
        assert_eq!(br.innocent_pods, 0);
        assert_eq!(br.storage_surfaces, 1, "shared backend only");
    }

    #[test]
    fn dedicated_placement_yields_no_innocents() {
        // under a dedicated partition the victim's pods sit only on its
        // own nodes; co-residents are same-tenant or idle infra
        let spec: &[(u64, Option<usize>, Option<u16>, bool)] = &[
            (0, Some(0), Some(0), true),
            (1, Some(0), Some(0), true),
            (2, Some(0), None, true),
            (3, Some(2), Some(1), true), // other tenant's pool: unreached
        ];
        let br = radius(0, IsolationPolicy::Dedicated, spec, false);
        assert_eq!(br.nodes, vec![NodeId(0)]);
        assert_eq!(br.pods, 3);
        assert_eq!(br.innocent_pods, 0);
        assert_eq!(br.storage_surfaces, 0, "data plane off");
    }

    #[test]
    fn failed_nodes_are_outside_the_radius() {
        let (pods, eff) = mkpods(MIXED);
        let br = compute_blast_radius(
            0,
            &PrivilegeModel::for_policy(IsolationPolicy::Shared),
            &pods,
            4,
            |n| n == NodeId(0),
            |i: usize| eff[i],
            false,
        );
        assert_eq!(br.nodes, vec![NodeId(2)]);
    }

    #[test]
    fn privilege_model_follows_policy() {
        let sh = PrivilegeModel::for_policy(IsolationPolicy::Shared);
        assert!(sh.can_reach_node && sh.can_reach_co_resident);
        let sb = PrivilegeModel::for_policy(IsolationPolicy::Sandboxed);
        assert!(!sb.can_reach_node && !sb.can_reach_node_cache);
        assert!(sb.can_reach_shared_storage, "network storage survives the sandbox");
    }
}
