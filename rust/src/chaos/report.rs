//! Resilience accounting: what the faults cost and how fast the platform
//! recovered.
//!
//! Definitions (EXPERIMENTS.md §"Resilience / chaos"):
//!
//! * **wasted work** — wall-clock compute-ms burned by executions that did
//!   not produce a completion: the elapsed execution time of a task killed
//!   by a fault (minus the checkpoint-restored fraction), the full run of
//!   a losing speculative copy, and the startup time of a pod that crashed
//!   at container start.
//! * **useful work** — elapsed execution ms of every *winning* run.
//! * **goodput** — `useful / (useful + wasted)`; 1.0 on a healthy run.
//! * **recovery latency** — fault time -> the time the affected task is
//!   executing again (p50/p95/p99 over all recoveries).

use crate::util::json::Json;
use crate::util::stats::Summary;

/// Mutable accumulator the driver updates during a run.
#[derive(Debug, Default)]
pub struct ChaosStats {
    /// Whether the chaos subsystem was active for this run.
    pub enabled: bool,
    pub pod_failures: u64,
    pub spot_warnings: u64,
    pub spot_reclaims: u64,
    pub node_crashes: u64,
    /// Speculative copies launched for straggling tasks.
    pub speculations: u64,
    /// Re-dispatches scheduled by the recovery policy.
    pub retries: u64,
    pub blacklists: u64,
    /// Events dropped because they referenced a dead node incarnation.
    pub stale_drops: u64,
    pub wasted_ms: u64,
    pub useful_ms: u64,
    /// Fault -> re-execution latency samples (seconds).
    pub recovery_latency: Summary,
    /// Per-tenant splits (fleet runs; single runs use lane 0).
    pub wasted_ms_by_tenant: Vec<u64>,
    pub retries_by_tenant: Vec<u64>,
}

impl ChaosStats {
    /// Size the per-tenant lanes (fleet runs call this with the tenant
    /// count; single runs keep one lane).
    pub fn set_tenants(&mut self, n: usize) {
        self.wasted_ms_by_tenant.resize(n.max(1), 0);
        self.retries_by_tenant.resize(n.max(1), 0);
    }

    pub fn add_waste(&mut self, tenant: usize, ms: u64) {
        self.wasted_ms += ms;
        if self.wasted_ms_by_tenant.is_empty() {
            self.set_tenants(1);
        }
        let lane = tenant.min(self.wasted_ms_by_tenant.len() - 1);
        self.wasted_ms_by_tenant[lane] += ms;
    }

    /// Waste with no task owner (e.g. a shared pool worker crashing at
    /// container start): counts toward the total but toward no tenant's
    /// lane — the lanes report *task-attributable* waste, and may
    /// therefore sum to less than `wasted_ms`.
    pub fn add_waste_shared(&mut self, ms: u64) {
        self.wasted_ms += ms;
    }

    pub fn add_retry(&mut self, tenant: usize) {
        self.retries += 1;
        if self.retries_by_tenant.is_empty() {
            self.set_tenants(1);
        }
        let lane = tenant.min(self.retries_by_tenant.len() - 1);
        self.retries_by_tenant[lane] += 1;
    }

    /// Freeze the accumulator into the report attached to a `SimResult`.
    pub fn report(&self) -> ChaosReport {
        let recovery = self.recovery_latency.percentile_row();
        ChaosReport {
            enabled: self.enabled,
            pod_failures: self.pod_failures,
            spot_warnings: self.spot_warnings,
            spot_reclaims: self.spot_reclaims,
            node_crashes: self.node_crashes,
            speculations: self.speculations,
            retries: self.retries,
            blacklists: self.blacklists,
            stale_drops: self.stale_drops,
            wasted_ms: self.wasted_ms,
            useful_ms: self.useful_ms,
            recoveries: self.recovery_latency.len(),
            recovery_p50_s: recovery.p50,
            recovery_p95_s: recovery.p95,
            recovery_p99_s: recovery.p99,
            wasted_ms_by_tenant: self.wasted_ms_by_tenant.clone(),
            retries_by_tenant: self.retries_by_tenant.clone(),
        }
    }
}

/// Immutable resilience summary of one run.
#[derive(Debug, Clone, Default)]
pub struct ChaosReport {
    pub enabled: bool,
    pub pod_failures: u64,
    pub spot_warnings: u64,
    pub spot_reclaims: u64,
    pub node_crashes: u64,
    pub speculations: u64,
    pub retries: u64,
    pub blacklists: u64,
    pub stale_drops: u64,
    pub wasted_ms: u64,
    pub useful_ms: u64,
    pub recoveries: usize,
    pub recovery_p50_s: f64,
    pub recovery_p95_s: f64,
    pub recovery_p99_s: f64,
    pub wasted_ms_by_tenant: Vec<u64>,
    pub retries_by_tenant: Vec<u64>,
}

impl ChaosReport {
    /// Total faults injected across every source.
    pub fn faults_total(&self) -> u64 {
        self.pod_failures + self.spot_reclaims + self.node_crashes
    }

    /// `useful / (useful + wasted)`; 1.0 when nothing ran or nothing was
    /// lost.
    pub fn goodput(&self) -> f64 {
        let total = self.useful_ms + self.wasted_ms;
        if total == 0 {
            return 1.0;
        }
        self.useful_ms as f64 / total as f64
    }

    /// Fraction of all executed compute that was wasted.
    pub fn wasted_frac(&self) -> f64 {
        1.0 - self.goodput()
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("enabled", self.enabled.into()),
            ("faults_total", self.faults_total().into()),
            ("pod_failures", self.pod_failures.into()),
            ("spot_warnings", self.spot_warnings.into()),
            ("spot_reclaims", self.spot_reclaims.into()),
            ("node_crashes", self.node_crashes.into()),
            ("speculations", self.speculations.into()),
            ("retries", self.retries.into()),
            ("blacklists", self.blacklists.into()),
            ("stale_drops", self.stale_drops.into()),
            ("wasted_ms", self.wasted_ms.into()),
            ("useful_ms", self.useful_ms.into()),
            ("goodput", self.goodput().into()),
            ("recoveries", self.recoveries.into()),
            ("recovery_p50_s", self.recovery_p50_s.into()),
            ("recovery_p95_s", self.recovery_p95_s.into()),
            ("recovery_p99_s", self.recovery_p99_s.into()),
            (
                "wasted_ms_by_tenant",
                Json::Arr(self.wasted_ms_by_tenant.iter().map(|&v| v.into()).collect()),
            ),
            (
                "retries_by_tenant",
                Json::Arr(self.retries_by_tenant.iter().map(|&v| v.into()).collect()),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn goodput_and_waste_fraction() {
        let mut s = ChaosStats {
            enabled: true,
            ..Default::default()
        };
        s.useful_ms = 900;
        s.add_waste(0, 100);
        let r = s.report();
        assert!((r.goodput() - 0.9).abs() < 1e-12);
        assert!((r.wasted_frac() - 0.1).abs() < 1e-12);
        assert_eq!(r.wasted_ms_by_tenant, vec![100]);
    }

    #[test]
    fn empty_run_has_unit_goodput() {
        let r = ChaosStats::default().report();
        assert_eq!(r.goodput(), 1.0);
        assert_eq!(r.wasted_frac(), 0.0);
        assert_eq!(r.faults_total(), 0);
        assert!(!r.enabled);
    }

    #[test]
    fn per_tenant_lanes_split_waste_and_retries() {
        let mut s = ChaosStats::default();
        s.set_tenants(3);
        s.add_waste(0, 10);
        s.add_waste(2, 30);
        s.add_retry(2);
        s.add_retry(2);
        // out-of-range tenants clamp to the last lane instead of panicking
        s.add_waste(9, 5);
        let r = s.report();
        assert_eq!(r.wasted_ms, 45);
        assert_eq!(r.wasted_ms_by_tenant, vec![10, 0, 35]);
        assert_eq!(r.retries_by_tenant, vec![0, 0, 2]);
        assert_eq!(r.retries, 2);
    }

    #[test]
    fn shared_waste_counts_in_the_total_but_no_lane() {
        let mut s = ChaosStats::default();
        s.set_tenants(2);
        s.add_waste(1, 40);
        s.add_waste_shared(60);
        let r = s.report();
        assert_eq!(r.wasted_ms, 100);
        assert_eq!(r.wasted_ms_by_tenant, vec![0, 40]);
        assert!(r.wasted_ms_by_tenant.iter().sum::<u64>() <= r.wasted_ms);
    }

    #[test]
    fn recovery_percentiles_survive_the_report() {
        let mut s = ChaosStats::default();
        for v in 0..=100 {
            s.recovery_latency.add(v as f64);
        }
        let r = s.report();
        assert_eq!(r.recoveries, 101);
        assert!((r.recovery_p50_s - 50.0).abs() < 1e-9);
        assert!((r.recovery_p99_s - 99.0).abs() < 1e-9);
        let j = r.to_json().to_string();
        assert!(j.contains("recovery_p99_s"));
        assert!(j.contains("goodput"));
    }
}
