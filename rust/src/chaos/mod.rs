//! Chaos engine: deterministic failure injection, recovery policies and
//! resilience accounting for every execution model.
//!
//! The paper (§4) evaluates the job-based, clustered and worker-pool
//! models on a *healthy* cluster, but the environments the models target —
//! spot/preemptible node pools, autoscaled multi-tenant clusters — are
//! defined by churn: reclaims with a two-minute warning, node crashes,
//! flaky container starts, and stragglers (cf. KubeAdaptor's task
//! rescheduling, arXiv:2207.01222, and preemptible capacity as the
//! dominant cost lever in the Docker/K8s resource-management survey,
//! arXiv:2010.10350). This module makes failure a first-class, seeded,
//! *reproducible* input to the simulator:
//!
//! * [`inject`] — fault injectors: per-pod start failure (the successor of
//!   the legacy `sim.pod_failure_prob` knob), spot reclaim with a drain
//!   warning, whole-node crash, and per-node straggler slowdown. Timed
//!   injectors are seeded Poisson processes driven off the calendar
//!   [`crate::sim::EventQueue`], so identical seed + chaos spec gives a
//!   bit-identical run — including under `run_fleet`.
//! * [`recover`] — recovery policies, pluggable per execution strategy
//!   ([`crate::exec::strategy::ExecStrategy::default_recovery`]): retry
//!   with exponential back-off and a
//!   delay cap, node blacklisting after K failures, checkpoint-restart
//!   (a re-run resumes at a configurable fraction of the lost progress),
//!   and speculative re-execution for straggling pool tasks.
//! * [`report`] — resilience accounting: wasted work (compute-ms lost to
//!   faults), retry counts, recovery-latency percentiles via
//!   [`crate::util::stats::Summary`], and goodput — surfaced in the text,
//!   JSON and HTML reports and, per tenant, in the fleet SLO table.
//! * [`takeover`] — the tenant-takeover scenario: a minimal privilege
//!   model per [`crate::k8s::isolation::IsolationPolicy`] and the
//!   blast-radius computation behind the RNG-free `takeover:<tenant>@<t>`
//!   injector.
//!
//! The CLI spec grammar (`hyperflow run --chaos spot:0.1,straggler:0.25`)
//! is parsed by [`ChaosConfig::parse_spec`]; `benches/chaos_resilience.rs`
//! sweeps reclaim rates across all four models into `BENCH_chaos.json`.

pub mod inject;
pub mod recover;
pub mod report;
pub mod takeover;

pub use inject::Injector;
pub use recover::RecoveryPolicy;
pub use report::{ChaosReport, ChaosStats};

/// Complete chaos description for a run: which faults to inject and
/// (optionally) how to recover from them. An empty injector list disables
/// the subsystem entirely — the driver then schedules no chaos events and
/// stays bit-identical with pre-chaos builds.
#[derive(Debug, Clone, Default)]
pub struct ChaosConfig {
    pub injectors: Vec<Injector>,
    /// Recovery policy override; `None` selects the execution strategy's
    /// default ([`crate::exec::strategy::ExecStrategy::default_recovery`])
    /// at build time.
    pub recovery: Option<RecoveryPolicy>,
}

impl ChaosConfig {
    /// Whether any fault source is configured.
    pub fn is_enabled(&self) -> bool {
        !self.injectors.is_empty()
    }

    /// Parse the CLI/JSON chaos spec: a comma-separated list of
    /// `kind:value` entries.
    ///
    /// | kind        | value                         | injector |
    /// |-------------|-------------------------------|----------|
    /// | `pod`       | crash probability per start   | [`Injector::PodFailure`] |
    /// | `spot`      | reclaims per node per hour    | [`Injector::SpotReclaim`] (2 min warning) |
    /// | `crash`     | crashes per node per hour     | [`Injector::NodeCrash`] |
    /// | `straggler` | fraction of nodes that are slow | [`Injector::Straggler`] (3x slowdown) |
    /// | `takeover`  | `<tenant>@<t_seconds>`          | [`Injector::Takeover`] (fixed instant) |
    ///
    /// Example: `spot:0.2,crash:0.1,pod:0.02,straggler:0.25,takeover:1@600`.
    pub fn parse_spec(spec: &str) -> Result<ChaosConfig, String> {
        let mut cfg = ChaosConfig::default();
        for entry in spec.split(',') {
            let entry = entry.trim();
            if entry.is_empty() {
                continue;
            }
            let (kind, value) = entry
                .split_once(':')
                .ok_or_else(|| format!("chaos entry '{entry}' is not kind:value"))?;
            // takeover takes `<tenant>@<t_seconds>`, not a plain number —
            // handled before the generic numeric-value parse below
            if kind.trim() == "takeover" {
                let (tenant, at) = value.trim().split_once('@').ok_or_else(|| {
                    format!("chaos entry '{entry}': expected takeover:<tenant>@<t_seconds>")
                })?;
                let tenant: u16 = tenant.trim().parse().map_err(|_| {
                    format!("chaos entry '{entry}': '{tenant}' is not a tenant id")
                })?;
                let at_s: f64 = at.trim().parse().map_err(|_| {
                    format!("chaos entry '{entry}': '{at}' is not a time in seconds")
                })?;
                if !at_s.is_finite() || at_s < 0.0 {
                    return Err(format!("chaos entry '{entry}': time must be >= 0"));
                }
                cfg.injectors.push(Injector::Takeover {
                    tenant,
                    at_ms: (at_s * 1000.0).round() as u64,
                });
                continue;
            }
            let v: f64 = value
                .trim()
                .parse()
                .map_err(|_| format!("chaos entry '{entry}': '{value}' is not a number"))?;
            if !v.is_finite() || v < 0.0 {
                return Err(format!("chaos entry '{entry}': value must be >= 0"));
            }
            let injector = match kind.trim() {
                "pod" => {
                    if v > 1.0 {
                        return Err(format!("chaos entry '{entry}': probability must be <= 1"));
                    }
                    Injector::PodFailure { prob: v }
                }
                "spot" => Injector::SpotReclaim {
                    per_node_per_hour: v,
                    warning_ms: inject::SPOT_WARNING_MS,
                    replace_ms: inject::SPOT_REPLACE_MS,
                },
                "crash" => Injector::NodeCrash {
                    per_node_per_hour: v,
                    repair_ms: inject::CRASH_REPAIR_MS,
                },
                "straggler" => {
                    if v > 1.0 {
                        return Err(format!("chaos entry '{entry}': fraction must be <= 1"));
                    }
                    Injector::Straggler {
                        frac_nodes: v,
                        factor: inject::STRAGGLER_FACTOR,
                    }
                }
                other => {
                    return Err(format!(
                        "unknown chaos injector '{other}' \
                         (expected pod, spot, crash, straggler, takeover)"
                    ))
                }
            };
            cfg.injectors.push(injector);
        }
        Ok(cfg)
    }

    /// Combined per-start crash probability over every
    /// [`Injector::PodFailure`] entry (independent sources compose as
    /// `1 - prod(1 - p)`).
    pub fn pod_failure_prob(&self) -> f64 {
        let survive: f64 = self
            .injectors
            .iter()
            .filter_map(|i| match i {
                Injector::PodFailure { prob } => Some(1.0 - prob),
                _ => None,
            })
            .product();
        1.0 - survive
    }

    /// The straggler injector's `(fraction, factor)`, if configured.
    pub fn straggler(&self) -> Option<(f64, f64)> {
        self.injectors.iter().find_map(|i| match i {
            Injector::Straggler { frac_nodes, factor } => Some((*frac_nodes, *factor)),
            _ => None,
        })
    }

    /// Scheduled takeovers as `(tenant, at_ms)`, in spec order.
    pub fn takeovers(&self) -> impl Iterator<Item = (u16, u64)> + '_ {
        self.injectors.iter().filter_map(|i| match i {
            Injector::Takeover { tenant, at_ms } => Some((*tenant, *at_ms)),
            _ => None,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_spec() {
        let c = ChaosConfig::parse_spec("spot:0.2,crash:0.1,pod:0.02,straggler:0.25").unwrap();
        assert_eq!(c.injectors.len(), 4);
        assert!(c.is_enabled());
        assert!((c.pod_failure_prob() - 0.02).abs() < 1e-12);
        assert_eq!(c.straggler(), Some((0.25, inject::STRAGGLER_FACTOR)));
        match &c.injectors[0] {
            Injector::SpotReclaim {
                per_node_per_hour,
                warning_ms,
                ..
            } => {
                assert!((per_node_per_hour - 0.2).abs() < 1e-12);
                assert_eq!(*warning_ms, 120_000, "the ISSUE's 2-minute warning");
            }
            other => panic!("expected spot injector, got {other:?}"),
        }
    }

    #[test]
    fn empty_spec_is_disabled() {
        let c = ChaosConfig::parse_spec("").unwrap();
        assert!(!c.is_enabled());
        assert_eq!(c.pod_failure_prob(), 0.0);
        assert_eq!(c.straggler(), None);
    }

    #[test]
    fn rejects_malformed_specs() {
        for bad in [
            "spot",           // no value
            "spot:x",         // not a number
            "spot:-1",        // negative
            "pod:1.5",        // probability > 1
            "straggler:2",    // fraction > 1
            "meteor:0.5",     // unknown kind
            "takeover:1",     // missing @time
            "takeover:x@600", // tenant not a number
            "takeover:1@soon", // time not a number
            "takeover:1@-5",  // negative time
        ] {
            assert!(ChaosConfig::parse_spec(bad).is_err(), "accepted: {bad}");
        }
    }

    #[test]
    fn parses_takeover_entries() {
        let c = ChaosConfig::parse_spec("takeover:1@600,spot:0.5,takeover:0@1800.5").unwrap();
        assert!(c.is_enabled());
        let t: Vec<(u16, u64)> = c.takeovers().collect();
        assert_eq!(t, vec![(1, 600_000), (0, 1_800_500)]);
        // takeover-only spec still counts as enabled chaos
        let only = ChaosConfig::parse_spec("takeover:2@0").unwrap();
        assert!(only.is_enabled());
        assert_eq!(only.pod_failure_prob(), 0.0);
    }

    #[test]
    fn pod_failure_probs_compose() {
        let c = ChaosConfig::parse_spec("pod:0.5,pod:0.5").unwrap();
        assert!((c.pod_failure_prob() - 0.75).abs() < 1e-12);
    }
}
