//! Recovery policies: what the platform does *after* a fault, pluggable
//! per execution model. Each strategy module supplies its own default via
//! [`crate::exec::strategy::ExecStrategy::default_recovery`] (pool models
//! add speculation; job models cannot split a pod and lean on
//! checkpoint-restart + retry alone); an explicit policy on the
//! [`crate::chaos::ChaosConfig`] overrides it.
//!
//! Four mechanisms (all knobs on one [`RecoveryPolicy`]):
//!
//! * **retry with exponential back-off + cap** — a task (or job batch)
//!   lost to a fault is re-dispatched after `initial x factor^attempt`
//!   milliseconds, capped at `retry_max_ms`; tasks always retry until they
//!   complete (the workflow contract), only the *delay* is capped.
//! * **node blacklisting** — after `blacklist_after` pod-start failures on
//!   one node, the node is cordoned for `blacklist_ms` (blacklist-aware
//!   placement: the scheduler skips cordoned nodes).
//! * **checkpoint-restart** — a re-run resumes at `checkpoint_frac` of the
//!   work the failed run had completed, so only `1 - checkpoint_frac` of
//!   the elapsed compute is wasted.
//! * **speculative re-execution** — a pool task still running after
//!   `spec_factor x` its nominal duration (a straggler) gets a second,
//!   concurrently-executing copy; the first completion wins and the loser
//!   is dropped as stale. At most one copy per task. Pool models only —
//!   job batches execute inside a single pod and cannot be split.

use crate::sim::SimTime;

#[derive(Debug, Clone, PartialEq)]
pub struct RecoveryPolicy {
    /// First retry delay after a fault (ms).
    pub retry_initial_ms: u64,
    /// Back-off multiplier per attempt.
    pub retry_factor: f64,
    /// Cap on the retry delay (ms) — retries themselves are unlimited.
    pub retry_max_ms: u64,
    /// Pod-start failures on one node before it is blacklisted (0 = off).
    pub blacklist_after: u32,
    /// How long a blacklisted node stays cordoned (ms).
    pub blacklist_ms: u64,
    /// Fraction of a failed run's completed work restored on re-run
    /// (0.0 = restart from scratch, 1.0 = perfect checkpointing).
    pub checkpoint_frac: f64,
    /// Drain worker pods during a spot-reclaim warning (graceful: finish
    /// the current task, take no new work). Without it workers keep
    /// consuming until the node dies.
    pub drain_on_warning: bool,
    /// Launch speculative copies of straggling pool tasks.
    pub speculative: bool,
    /// Straggler threshold: speculate once a task has run for
    /// `spec_factor x` its nominal duration.
    pub spec_factor: f64,
}

impl Default for RecoveryPolicy {
    fn default() -> Self {
        RecoveryPolicy {
            retry_initial_ms: 1_000,
            retry_factor: 2.0,
            retry_max_ms: 60_000,
            blacklist_after: 3,
            blacklist_ms: 120_000,
            checkpoint_frac: 0.5,
            drain_on_warning: true,
            speculative: false,
            spec_factor: 2.0,
        }
    }
}

impl RecoveryPolicy {
    /// Retry delay for the given attempt number (0-based), capped.
    pub fn backoff(&self, attempt: u32) -> SimTime {
        let exp = self.retry_initial_ms as f64 * self.retry_factor.powi(attempt.min(63) as i32);
        SimTime::from_millis((exp as u64).min(self.retry_max_ms))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backoff_grows_and_caps() {
        let p = RecoveryPolicy {
            retry_initial_ms: 1_000,
            retry_factor: 2.0,
            retry_max_ms: 8_000,
            ..Default::default()
        };
        let delays: Vec<u64> = (0..6).map(|a| p.backoff(a).as_millis()).collect();
        assert_eq!(delays, vec![1_000, 2_000, 4_000, 8_000, 8_000, 8_000]);
        // huge attempt counts saturate instead of overflowing
        assert_eq!(p.backoff(u32::MAX).as_millis(), 8_000);
    }

    #[test]
    fn default_policy_has_blacklisting_but_no_speculation() {
        // the per-model speculation split now lives with the strategies
        // (see exec::strategy tests); the base policy stays conservative
        let p = RecoveryPolicy::default();
        assert!(!p.speculative);
        assert!(p.blacklist_after > 0, "blacklisting on by default");
        assert!(p.checkpoint_frac > 0.0 && p.checkpoint_frac < 1.0);
        assert!(p.drain_on_warning);
    }
}
