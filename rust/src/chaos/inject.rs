//! Fault injectors: seeded processes that decide *when* and *where*
//! failures strike.
//!
//! Timed injectors (spot reclaim, node crash) are Poisson processes over
//! the node population: the aggregate cluster rate is `per_node_per_hour x
//! n_nodes`, inter-fault gaps are exponential, and the victim node is
//! drawn uniformly. Each injector owns a forked [`Rng`] stream and samples
//! lazily — the driver schedules the next fault event only when the
//! previous one fires, so draws happen in deterministic event order and
//! identical seed + spec reproduces the exact fault timeline.
//!
//! [`Injector::PodFailure`] and [`Injector::Straggler`] are not timed:
//! pod failures are sampled at each container start, and straggler
//! slowness is a per-node duration multiplier sampled at cluster build
//! (and re-sampled when a reclaimed node's replacement arrives).

use crate::sim::SimTime;
use crate::util::rng::Rng;

/// Spot reclaim warning: the cloud's "2-minute notice" (ISSUE/tentpole).
pub const SPOT_WARNING_MS: u64 = 120_000;
/// Time until replacement capacity for a reclaimed node is provisioned.
pub const SPOT_REPLACE_MS: u64 = 180_000;
/// Repair time for a crashed node.
pub const CRASH_REPAIR_MS: u64 = 300_000;
/// Default duration multiplier for straggler nodes.
pub const STRAGGLER_FACTOR: f64 = 3.0;

/// One fault source.
#[derive(Debug, Clone, PartialEq)]
pub enum Injector {
    /// A pod crashes at container start with probability `prob` (image
    /// pull error, OOM on start, node flake). Generalizes the legacy
    /// `sim.pod_failure_prob` knob.
    PodFailure { prob: f64 },
    /// Spot/preemptible reclaim: each node is reclaimed at
    /// `per_node_per_hour` (Poisson). The node is cordoned and drained for
    /// `warning_ms`, then goes down; replacement capacity arrives after
    /// `replace_ms`.
    SpotReclaim {
        per_node_per_hour: f64,
        warning_ms: u64,
        replace_ms: u64,
    },
    /// Hard node crash: no warning; everything on the node dies. The node
    /// is repaired after `repair_ms`.
    NodeCrash {
        per_node_per_hour: f64,
        repair_ms: u64,
    },
    /// Straggler slowdown: `frac_nodes` of the cluster runs every task
    /// `factor`x slower (degraded disk/net/noisy neighbor).
    Straggler { frac_nodes: f64, factor: f64 },
    /// Tenant takeover at a fixed instant: `tenant` is assumed fully
    /// compromised at `at_ms` and its blast radius
    /// ([`crate::chaos::takeover`]) is remediated. RNG-free — the event
    /// is placed on the calendar at build time, so adding or removing a
    /// takeover never shifts the other injectors' RNG streams.
    Takeover { tenant: u16, at_ms: u64 },
}

impl Injector {
    /// Whether this injector emits scheduled fault events (vs. being
    /// sampled inline at pod start / cluster build).
    pub fn is_timed(&self) -> bool {
        matches!(
            self,
            Injector::SpotReclaim { .. } | Injector::NodeCrash { .. }
        )
    }

    fn rate_per_node_per_hour(&self) -> f64 {
        match self {
            Injector::SpotReclaim {
                per_node_per_hour, ..
            }
            | Injector::NodeCrash {
                per_node_per_hour, ..
            } => *per_node_per_hour,
            _ => 0.0,
        }
    }
}

/// A timed injector bound to its private RNG stream.
#[derive(Debug)]
pub struct FaultProcess {
    pub injector: Injector,
    rng: Rng,
}

impl FaultProcess {
    pub fn new(injector: Injector, rng: Rng) -> Self {
        FaultProcess { injector, rng }
    }

    /// Sample the next fault of this process over `n_nodes` nodes:
    /// `(delay from now, victim node index)`. `None` when the injector is
    /// inert (rate 0 or not timed) — no event is ever scheduled for it.
    pub fn next_fault(&mut self, n_nodes: usize) -> Option<(SimTime, usize)> {
        let rate = self.injector.rate_per_node_per_hour();
        if rate <= 0.0 || n_nodes == 0 {
            return None;
        }
        let mean_ms = 3_600_000.0 / (rate * n_nodes as f64);
        let delay = self.rng.exponential(mean_ms).round() as u64;
        let victim = self.rng.below(n_nodes as u64) as usize;
        Some((SimTime::from_millis(delay), victim))
    }
}

/// Sample the per-node straggler slowdown table: `factor` with probability
/// `frac`, else 1.0. One draw per node, in node order (deterministic).
pub fn sample_node_slowdowns(n_nodes: usize, frac: f64, factor: f64, rng: &mut Rng) -> Vec<f64> {
    (0..n_nodes)
        .map(|_| if rng.f64() < frac { factor } else { 1.0 })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timed_injectors_sample_deterministically() {
        let inj = Injector::SpotReclaim {
            per_node_per_hour: 1.0,
            warning_ms: SPOT_WARNING_MS,
            replace_ms: SPOT_REPLACE_MS,
        };
        let mut a = FaultProcess::new(inj.clone(), Rng::new(7));
        let mut b = FaultProcess::new(inj, Rng::new(7));
        for _ in 0..100 {
            assert_eq!(a.next_fault(4), b.next_fault(4));
        }
    }

    #[test]
    fn fault_rate_scales_with_cluster_size() {
        // 1/h/node over 4 nodes => mean gap ~15 min
        let mut p = FaultProcess::new(
            Injector::NodeCrash {
                per_node_per_hour: 1.0,
                repair_ms: CRASH_REPAIR_MS,
            },
            Rng::new(3),
        );
        let n = 20_000;
        let mut sum_ms = 0u64;
        for _ in 0..n {
            let (d, victim) = p.next_fault(4).unwrap();
            assert!(victim < 4);
            sum_ms += d.as_millis();
        }
        let mean_min = sum_ms as f64 / n as f64 / 60_000.0;
        assert!((mean_min - 15.0).abs() < 0.5, "mean gap {mean_min} min");
    }

    #[test]
    fn inert_injectors_emit_nothing() {
        let mut zero = FaultProcess::new(
            Injector::SpotReclaim {
                per_node_per_hour: 0.0,
                warning_ms: 1,
                replace_ms: 1,
            },
            Rng::new(1),
        );
        assert_eq!(zero.next_fault(4), None);
        let mut untimed = FaultProcess::new(Injector::PodFailure { prob: 0.5 }, Rng::new(1));
        assert_eq!(untimed.next_fault(4), None);
        assert!(!Injector::PodFailure { prob: 0.5 }.is_timed());
        assert!(Injector::NodeCrash {
            per_node_per_hour: 1.0,
            repair_ms: 1
        }
        .is_timed());
    }

    #[test]
    fn takeover_is_untimed_and_rate_free() {
        // a takeover must never join the timed-process list: it is
        // scheduled at a fixed calendar time and consumes no RNG, so its
        // presence cannot shift the other injectors' fork indices
        let t = Injector::Takeover { tenant: 1, at_ms: 600_000 };
        assert!(!t.is_timed());
        let mut p = FaultProcess::new(t, Rng::new(5));
        assert_eq!(p.next_fault(4), None);
    }

    #[test]
    fn straggler_table_matches_fraction() {
        let mut rng = Rng::new(9);
        let slow = sample_node_slowdowns(10_000, 0.25, 3.0, &mut rng);
        let n_slow = slow.iter().filter(|&&f| f == 3.0).count();
        assert!(slow.iter().all(|&f| f == 1.0 || f == 3.0));
        assert!((2_200..2_800).contains(&n_slow), "{n_slow} slow of 10k");
        // deterministic
        let mut rng2 = Rng::new(9);
        assert_eq!(slow, sample_node_slowdowns(10_000, 0.25, 3.0, &mut rng2));
    }
}
