//! Prometheus-like metrics: counters, gauges, and step time series.
//!
//! The worker-pools architecture uses a metrics pipeline (Prometheus +
//! Metrics Server in the paper, §3.5) to feed queue lengths to the
//! autoscaler and to record the utilization series plotted in Figs. 3-6.

use crate::sim::SimTime;
use std::collections::BTreeMap;

/// A step time series: (t, value) change points; value holds until next
/// point.
#[derive(Debug, Default, Clone)]
pub struct Series {
    points: Vec<(f64, f64)>,
}

impl Series {
    /// Record `value` at time `t` (seconds). Consecutive duplicates are
    /// collapsed.
    pub fn record(&mut self, t: f64, value: f64) {
        if let Some(&(lt, lv)) = self.points.last() {
            if lv == value {
                return;
            }
            debug_assert!(t >= lt, "series time went backwards");
        }
        self.points.push((t, value));
    }

    pub fn points(&self) -> &[(f64, f64)] {
        &self.points
    }

    pub fn last_value(&self) -> f64 {
        self.points.last().map(|&(_, v)| v).unwrap_or(0.0)
    }

    pub fn max_value(&self) -> f64 {
        self.points
            .iter()
            .map(|&(_, v)| v)
            .fold(f64::NEG_INFINITY, f64::max)
    }

    /// Time average over [t0, t1] (see util::stats::time_average).
    pub fn time_average(&self, t0: f64, t1: f64) -> f64 {
        crate::util::stats::time_average(&self.points, t0, t1)
    }

    /// Resample onto a uniform grid with `dt` seconds (for CSV export).
    ///
    /// A non-positive (or NaN) `dt` would loop forever on the grid walk
    /// and a negative `t_end` has no valid grid at all — both return an
    /// empty vector instead of hanging or panicking in release builds.
    pub fn resample(&self, t_end: f64, dt: f64) -> Vec<(f64, f64)> {
        if !(dt > 0.0) || t_end < 0.0 {
            return Vec::new();
        }
        let mut out = Vec::new();
        let mut idx = 0;
        let mut cur = 0.0;
        let mut t = 0.0;
        while t <= t_end + 1e-9 {
            while idx < self.points.len() && self.points[idx].0 <= t {
                cur = self.points[idx].1;
                idx += 1;
            }
            out.push((t, cur));
            t += dt;
        }
        out
    }
}

/// Pre-resolved handle to a gauge: hot paths resolve the name once and
/// then update by index (string-keyed lookups were ~15% of the 16k sim,
/// see EXPERIMENTS.md §Perf).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GaugeId(usize);

/// Pre-resolved handle to a counter, mirroring [`GaugeId`]: the name is
/// interned once (cold path) and every increment after that is a plain
/// `Vec` index instead of a string-keyed BTreeMap lookup that allocates
/// on first touch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CounterId(usize);

/// Metrics registry: named counters and gauges (with history).
#[derive(Debug, Default)]
pub struct Registry {
    counters: Vec<u64>,
    counter_names: BTreeMap<String, usize>,
    gauges: Vec<Series>,
    names: BTreeMap<String, usize>,
}

impl Registry {
    pub fn new() -> Self {
        Registry::default()
    }

    /// Resolve (or create) a counter handle. Interned counters exist with
    /// value 0 from this point on, so reports and the Prometheus
    /// exposition see every registered counter even before its first
    /// increment.
    pub fn counter_id(&mut self, name: &str) -> CounterId {
        if let Some(&i) = self.counter_names.get(name) {
            return CounterId(i);
        }
        self.counters.push(0);
        let i = self.counters.len() - 1;
        self.counter_names.insert(name.to_string(), i);
        CounterId(i)
    }

    /// Increment a counter by handle (hot path).
    #[inline]
    pub fn inc_id(&mut self, id: CounterId, by: u64) {
        self.counters[id.0] += by;
    }

    /// Read a counter by handle.
    #[inline]
    pub fn counter_by_id(&self, id: CounterId) -> u64 {
        self.counters[id.0]
    }

    /// Name-resolving increment (cold paths and tests).
    pub fn inc(&mut self, name: &str, by: u64) {
        let id = self.counter_id(name);
        self.inc_id(id, by);
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counter_names
            .get(name)
            .map(|&i| self.counters[i])
            .unwrap_or(0)
    }

    /// All counters, in deterministic (sorted-name) order — the
    /// Prometheus/OpenMetrics exposition walks this.
    pub fn counters_sorted(&self) -> impl Iterator<Item = (&str, u64)> {
        self.counter_names
            .iter()
            .map(move |(n, &i)| (n.as_str(), self.counters[i]))
    }

    /// Resolve (or create) a gauge handle.
    pub fn gauge_id(&mut self, name: &str) -> GaugeId {
        if let Some(&i) = self.names.get(name) {
            return GaugeId(i);
        }
        self.gauges.push(Series::default());
        let i = self.gauges.len() - 1;
        self.names.insert(name.to_string(), i);
        GaugeId(i)
    }

    /// Set a gauge by handle (hot path).
    pub fn set_id(&mut self, id: GaugeId, now: SimTime, value: f64) {
        self.gauges[id.0].record(now.as_secs_f64(), value);
    }

    /// Add a delta to a gauge by handle (hot path).
    pub fn add_id(&mut self, id: GaugeId, now: SimTime, delta: f64) {
        let cur = self.gauges[id.0].last_value();
        self.gauges[id.0].record(now.as_secs_f64(), cur + delta);
    }

    pub fn gauge_by_id(&self, id: GaugeId) -> &Series {
        &self.gauges[id.0]
    }

    /// Set a gauge at simulated time `now` (name-resolving convenience).
    pub fn set(&mut self, name: &str, now: SimTime, value: f64) {
        let id = self.gauge_id(name);
        self.set_id(id, now, value);
    }

    /// Add a delta to a gauge at time `now`.
    pub fn add(&mut self, name: &str, now: SimTime, delta: f64) {
        let id = self.gauge_id(name);
        self.add_id(id, now, delta);
    }

    pub fn gauge(&self, name: &str) -> Option<&Series> {
        self.names.get(name).map(|&i| &self.gauges[i])
    }

    pub fn gauge_value(&self, name: &str) -> f64 {
        self.gauge(name).map(|s| s.last_value()).unwrap_or(0.0)
    }

    pub fn gauge_names(&self) -> impl Iterator<Item = &str> {
        self.names.keys().map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let mut r = Registry::new();
        r.inc("pods_created", 1);
        r.inc("pods_created", 2);
        assert_eq!(r.counter("pods_created"), 3);
        assert_eq!(r.counter("missing"), 0);
    }

    #[test]
    fn gauges_record_history() {
        let mut r = Registry::new();
        r.set("queue", SimTime(0), 5.0);
        r.set("queue", SimTime(1000), 8.0);
        r.set("queue", SimTime(2000), 8.0); // dedup
        let s = r.gauge("queue").unwrap();
        assert_eq!(s.points().len(), 2);
        assert_eq!(s.last_value(), 8.0);
        assert_eq!(s.max_value(), 8.0);
    }

    #[test]
    fn gauge_add_deltas() {
        let mut r = Registry::new();
        r.add("running", SimTime(0), 1.0);
        r.add("running", SimTime(500), 1.0);
        r.add("running", SimTime(1000), -2.0);
        assert_eq!(r.gauge_value("running"), 0.0);
        assert_eq!(r.gauge("running").unwrap().max_value(), 2.0);
    }

    #[test]
    fn series_time_average() {
        let mut s = Series::default();
        s.record(0.0, 0.0);
        s.record(10.0, 4.0);
        s.record(20.0, 2.0);
        assert!((s.time_average(0.0, 30.0) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn series_time_average_degenerate_windows_are_zero() {
        // empty series and collapsed/inverted/NaN windows: a
        // zero-makespan run must report 0.0 utilization, not NaN
        let empty = Series::default();
        assert_eq!(empty.time_average(0.0, 10.0), 0.0);
        let mut s = Series::default();
        s.record(0.0, 5.0);
        assert_eq!(s.time_average(0.0, 0.0), 0.0);
        assert_eq!(s.time_average(10.0, 5.0), 0.0);
        let nan = s.time_average(0.0, f64::NAN);
        assert_eq!(nan, 0.0, "NaN window must not poison the average");
    }

    #[test]
    fn series_resample_uniform() {
        let mut s = Series::default();
        s.record(0.0, 1.0);
        s.record(2.5, 3.0);
        let r = s.resample(5.0, 1.0);
        assert_eq!(r.len(), 6);
        assert_eq!(r[0], (0.0, 1.0));
        assert_eq!(r[2], (2.0, 1.0));
        assert_eq!(r[3], (3.0, 3.0));
    }

    #[test]
    fn monotone_guard_allows_equal_times() {
        let mut s = Series::default();
        s.record(1.0, 1.0);
        s.record(1.0, 2.0); // same instant, new value — allowed
        assert_eq!(s.points().len(), 2);
    }

    #[test]
    fn counter_ids_are_interned_and_fast_path_equivalent() {
        let mut r = Registry::new();
        let a = r.counter_id("pods_created");
        let b = r.counter_id("pods_created");
        assert_eq!(a, b, "re-resolving a name yields the same handle");
        r.inc_id(a, 2);
        r.inc("pods_created", 1); // name path hits the same slot
        assert_eq!(r.counter("pods_created"), 3);
        assert_eq!(r.counter_by_id(a), 3);
        // interned-but-untouched counters are visible with value 0
        let z = r.counter_id("stale_node_events_dropped");
        assert_eq!(r.counter_by_id(z), 0);
        assert_eq!(r.counter("stale_node_events_dropped"), 0);
    }

    #[test]
    fn counters_sorted_is_deterministic_and_complete() {
        let mut r = Registry::new();
        r.inc("zeta", 1);
        r.inc("alpha", 2);
        let _ = r.counter_id("mid");
        let all: Vec<(String, u64)> = r
            .counters_sorted()
            .map(|(n, v)| (n.to_string(), v))
            .collect();
        assert_eq!(
            all,
            vec![
                ("alpha".to_string(), 2),
                ("mid".to_string(), 0),
                ("zeta".to_string(), 1)
            ]
        );
    }

    #[test]
    fn resample_guards_degenerate_grids() {
        let mut s = Series::default();
        s.record(0.0, 1.0);
        assert!(s.resample(5.0, 0.0).is_empty(), "dt = 0 would never advance");
        assert!(s.resample(5.0, -1.0).is_empty(), "negative dt");
        assert!(s.resample(5.0, f64::NAN).is_empty(), "NaN dt");
        assert!(s.resample(-1.0, 1.0).is_empty(), "negative horizon");
        // boundary: a zero-length horizon still samples the t=0 point
        let r = s.resample(0.0, 1.0);
        assert_eq!(r, vec![(0.0, 1.0)]);
    }
}
