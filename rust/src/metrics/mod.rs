//! Prometheus-like metrics: counters, gauges, and step time series.
//!
//! The worker-pools architecture uses a metrics pipeline (Prometheus +
//! Metrics Server in the paper, §3.5) to feed queue lengths to the
//! autoscaler and to record the utilization series plotted in Figs. 3-6.

use crate::sim::SimTime;
use std::collections::BTreeMap;

/// A step time series: (t, value) change points; value holds until next
/// point.
#[derive(Debug, Default, Clone)]
pub struct Series {
    points: Vec<(f64, f64)>,
}

impl Series {
    /// Record `value` at time `t` (seconds). Consecutive duplicates are
    /// collapsed.
    pub fn record(&mut self, t: f64, value: f64) {
        if let Some(&(lt, lv)) = self.points.last() {
            if lv == value {
                return;
            }
            debug_assert!(t >= lt, "series time went backwards");
        }
        self.points.push((t, value));
    }

    pub fn points(&self) -> &[(f64, f64)] {
        &self.points
    }

    pub fn last_value(&self) -> f64 {
        self.points.last().map(|&(_, v)| v).unwrap_or(0.0)
    }

    pub fn max_value(&self) -> f64 {
        self.points
            .iter()
            .map(|&(_, v)| v)
            .fold(f64::NEG_INFINITY, f64::max)
    }

    /// Time average over [t0, t1] (see util::stats::time_average).
    pub fn time_average(&self, t0: f64, t1: f64) -> f64 {
        crate::util::stats::time_average(&self.points, t0, t1)
    }

    /// Resample onto a uniform grid with `dt` seconds (for CSV export).
    pub fn resample(&self, t_end: f64, dt: f64) -> Vec<(f64, f64)> {
        let mut out = Vec::new();
        let mut idx = 0;
        let mut cur = 0.0;
        let mut t = 0.0;
        while t <= t_end + 1e-9 {
            while idx < self.points.len() && self.points[idx].0 <= t {
                cur = self.points[idx].1;
                idx += 1;
            }
            out.push((t, cur));
            t += dt;
        }
        out
    }
}

/// Pre-resolved handle to a gauge: hot paths resolve the name once and
/// then update by index (string-keyed lookups were ~15% of the 16k sim,
/// see EXPERIMENTS.md §Perf).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GaugeId(usize);

/// Metrics registry: named counters and gauges (with history).
#[derive(Debug, Default)]
pub struct Registry {
    counters: BTreeMap<String, u64>,
    gauges: Vec<Series>,
    names: BTreeMap<String, usize>,
}

impl Registry {
    pub fn new() -> Self {
        Registry::default()
    }

    pub fn inc(&mut self, name: &str, by: u64) {
        *self.counters.entry(name.to_string()).or_insert(0) += by;
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    /// Resolve (or create) a gauge handle.
    pub fn gauge_id(&mut self, name: &str) -> GaugeId {
        if let Some(&i) = self.names.get(name) {
            return GaugeId(i);
        }
        self.gauges.push(Series::default());
        let i = self.gauges.len() - 1;
        self.names.insert(name.to_string(), i);
        GaugeId(i)
    }

    /// Set a gauge by handle (hot path).
    pub fn set_id(&mut self, id: GaugeId, now: SimTime, value: f64) {
        self.gauges[id.0].record(now.as_secs_f64(), value);
    }

    /// Add a delta to a gauge by handle (hot path).
    pub fn add_id(&mut self, id: GaugeId, now: SimTime, delta: f64) {
        let cur = self.gauges[id.0].last_value();
        self.gauges[id.0].record(now.as_secs_f64(), cur + delta);
    }

    pub fn gauge_by_id(&self, id: GaugeId) -> &Series {
        &self.gauges[id.0]
    }

    /// Set a gauge at simulated time `now` (name-resolving convenience).
    pub fn set(&mut self, name: &str, now: SimTime, value: f64) {
        let id = self.gauge_id(name);
        self.set_id(id, now, value);
    }

    /// Add a delta to a gauge at time `now`.
    pub fn add(&mut self, name: &str, now: SimTime, delta: f64) {
        let id = self.gauge_id(name);
        self.add_id(id, now, delta);
    }

    pub fn gauge(&self, name: &str) -> Option<&Series> {
        self.names.get(name).map(|&i| &self.gauges[i])
    }

    pub fn gauge_value(&self, name: &str) -> f64 {
        self.gauge(name).map(|s| s.last_value()).unwrap_or(0.0)
    }

    pub fn gauge_names(&self) -> impl Iterator<Item = &str> {
        self.names.keys().map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let mut r = Registry::new();
        r.inc("pods_created", 1);
        r.inc("pods_created", 2);
        assert_eq!(r.counter("pods_created"), 3);
        assert_eq!(r.counter("missing"), 0);
    }

    #[test]
    fn gauges_record_history() {
        let mut r = Registry::new();
        r.set("queue", SimTime(0), 5.0);
        r.set("queue", SimTime(1000), 8.0);
        r.set("queue", SimTime(2000), 8.0); // dedup
        let s = r.gauge("queue").unwrap();
        assert_eq!(s.points().len(), 2);
        assert_eq!(s.last_value(), 8.0);
        assert_eq!(s.max_value(), 8.0);
    }

    #[test]
    fn gauge_add_deltas() {
        let mut r = Registry::new();
        r.add("running", SimTime(0), 1.0);
        r.add("running", SimTime(500), 1.0);
        r.add("running", SimTime(1000), -2.0);
        assert_eq!(r.gauge_value("running"), 0.0);
        assert_eq!(r.gauge("running").unwrap().max_value(), 2.0);
    }

    #[test]
    fn series_time_average() {
        let mut s = Series::default();
        s.record(0.0, 0.0);
        s.record(10.0, 4.0);
        s.record(20.0, 2.0);
        assert!((s.time_average(0.0, 30.0) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn series_resample_uniform() {
        let mut s = Series::default();
        s.record(0.0, 1.0);
        s.record(2.5, 3.0);
        let r = s.resample(5.0, 1.0);
        assert_eq!(r.len(), 6);
        assert_eq!(r[0], (0.0, 1.0));
        assert_eq!(r[2], (2.0, 1.0));
        assert_eq!(r[3], (3.0, 3.0));
    }

    #[test]
    fn monotone_guard_allows_equal_times() {
        let mut s = Series::default();
        s.record(1.0, 1.0);
        s.record(1.0, 2.0); // same instant, new value — allowed
        assert_eq!(s.points().len(), 2);
    }
}
