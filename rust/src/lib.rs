//! # hyperflow-k8s
//!
//! Reproduction of **"Towards cloud-native scientific workflow management"**
//! (Orzechowski, Baliś, Janecki, 2024): alternative execution models for
//! scientific workflows on Kubernetes, evaluated with a 16k-task Montage
//! workflow.
//!
//! The crate provides:
//! * a discrete-event **Kubernetes cluster simulator** ([`k8s`], [`sim`]) —
//!   scheduler with exponential back-off, API-server queueing, pod
//!   lifecycle latencies;
//! * the **HyperFlow engine** ([`engine`]) with task clustering;
//! * the layered **execution subsystem** ([`exec`]): an event-loop kernel
//!   with pluggable model strategies — job-based, job-based with
//!   clustering, typed worker pools and the generic pool (KEDA-style
//!   autoscaler with proportional quota allocation, [`autoscale`], over an
//!   AMQP-like [`broker`]); [`models`] re-exports the model enum and the
//!   legacy driver entry points;
//! * the **chaos engine** ([`chaos`]): deterministic fault injection
//!   (pod failures, spot reclaims, node crashes, stragglers), pluggable
//!   recovery policies (retry back-off, blacklisting, checkpoint-restart,
//!   speculative re-execution) and resilience accounting (wasted work,
//!   goodput, recovery latency);
//! * the **fleet service** ([`fleet`]): open-loop multi-tenant workflow
//!   arrivals on one shared cluster, with weighted fair-share dequeue,
//!   admission control, and per-tenant slowdown/SLO reporting
//!   (`hyperflow serve`);
//! * the **data plane** ([`data`]): shared-storage and transfer modeling —
//!   per-task input/output files, pluggable backends (shared NFS, object
//!   store) with max-min fair bandwidth sharing, node-local ephemeral
//!   caches, and locality-aware scheduling (`--data nfs:1,cache:8`);
//! * the **flight recorder** ([`obs`]): zero-cost-when-disabled span and
//!   control-plane event tracing with critical-path makespan attribution,
//!   a full Chrome/Perfetto export, and a Prometheus text exposition
//!   (`--obs trace:out.json,prom:out.txt,crit:on`);
//! * the **in-sim monitoring stack** ([`obs::monitor`], [`obs::rules`],
//!   [`obs::alerts`]): a deterministic fixed-interval scrape loop
//!   evaluating PromQL-lite recording rules and alert rules — threshold
//!   alerts with `for:` holds, multi-window SLO burn-rate alerts, the
//!   full inactive→pending→firing→resolved lifecycle — plus
//!   `ewma`/`holt_winters` forecasters queryable from kernel hooks
//!   (`--monitor interval:30,rules:builtin,alerts:alerts.json`);
//! * **differential run analysis** ([`obs::snapshot`], [`obs::diff`]):
//!   byte-deterministic versioned run snapshots (`--snapshot out.json`)
//!   and a diff engine that decomposes a makespan delta phase-by-phase
//!   (integer-ms deltas summing exactly to the makespan delta), locates
//!   the first critical-path divergence, and doubles as the CI
//!   perf-regression gate (`hyperflow diff --bench` with per-metric
//!   tolerances against `baselines/`);
//! * the **Montage workflow generator** ([`workflow`]);
//! * a **PJRT runtime** ([`runtime`]) executing the real Montage numerics
//!   (JAX + Pallas, AOT-compiled to HLO) inside worker pods ([`compute`],
//!   [`realtime`]);
//! * reports and figure regeneration ([`report`], [`metrics`]).
//!
//! See DESIGN.md for the system inventory and EXPERIMENTS.md for
//! paper-vs-measured results.

pub mod autoscale;
pub mod broker;
pub mod chaos;
pub mod compute;
pub mod config;
pub mod data;
pub mod engine;
pub mod exec;
pub mod fleet;
pub mod k8s;
pub mod metrics;
pub mod models;
pub mod obs;
pub mod realtime;
pub mod report;
pub mod runtime;
pub mod sim;
pub mod util;
pub mod workflow;
