//! The discrete-event simulation driver: binds an [`ExecModel`] to the
//! Kubernetes substrate and the HyperFlow engine and runs a workflow to
//! completion, producing a [`SimResult`] trace.
//!
//! Two entry points share the same event machinery:
//!
//! * [`run`] — the paper's experiment harness: one workflow, dispatched at
//!   t=0, simulated to completion.
//! * [`run_fleet`] — the fleet service: many workflow *instances* (one
//!   [`Dag::disjoint_union`] task space, each instance a contiguous id
//!   range) arriving over simulated time, tagged with tenants, admitted
//!   under an optional concurrency cap, and executed concurrently on the
//!   shared cluster. Instance roots are held back until admission;
//!   readiness propagation, pools, autoscaling and scheduling are exactly
//!   the single-run code paths — the autoscaler simply sees the aggregate
//!   backlog of all in-flight instances, and the broker's per-tenant lanes
//!   enforce weighted fair-share at dequeue time.
//!
//! Event flow (job path):          Event flow (pool path):
//!   task ready                       task ready
//!   -> batcher (maybe buffer)        -> publish to type queue
//!   -> API: create Job               -> wake idle worker / autoscaler
//!   -> API: create Pod               ...
//!   -> scheduler (may back off!)     autoscale tick: desired replicas
//!   -> pod start (~2 s)              -> API: create/delete worker pods
//!   -> execute batch sequentially    -> scheduler -> pod start
//!   -> pod terminates, free node     -> worker loop: fetch/execute/ack
//!
//! Hot-path design (EXPERIMENTS.md §Perf): pools are interned to dense
//! [`PoolId`] indices at startup, so deployments, idle-worker queues,
//! queue-depth gauges and per-type routing are all `Vec` lookups; the
//! steady-state event loop performs no string hashing, no map walks and no
//! per-event heap allocation (readiness, scheduler passes and batch
//! hand-offs reuse scratch buffers or move payloads instead of cloning).

use super::ExecModel;
use crate::autoscale::{Autoscaler, AutoscalerConfig, PoolSpec};
use crate::broker::{Broker, PoolId, TenantId};
use crate::chaos::inject::{sample_node_slowdowns, FaultProcess};
use crate::chaos::{ChaosConfig, ChaosStats, Injector, RecoveryPolicy};
use crate::data::{DataConfig, DataPlane, FlowEvent, StageStart};
use crate::engine::clustering::{BatchAction, Batcher, ClusteringConfig};
use crate::engine::{Engine, TaskState};
use crate::fleet::{FleetPlan, InstanceOutcome};
use crate::k8s::api_server::{ApiServer, ApiServerConfig};
use crate::k8s::node::{paper_cluster, Node, NodeId};
use crate::k8s::pod::{Payload, Pod, PodId, PodPhase};
use crate::k8s::resources::Resources;
use crate::k8s::scheduler::{DataLocality, SchedulePass, Scheduler, SchedulerConfig};
use crate::metrics::{GaugeId, Registry};
use crate::report::{SimResult, Trace};
use crate::sim::{EventQueue, SimTime};
use crate::workflow::dag::Dag;
use crate::workflow::task::{TaskId, TypeId};
use std::collections::VecDeque;

/// Cluster / runtime parameters (defaults follow DESIGN.md §5).
#[derive(Debug, Clone)]
pub struct SimConfig {
    /// Number of worker nodes (paper: up to 17).
    pub nodes: usize,
    /// Pod container startup latency (paper: "typically about 2s").
    pub pod_start_ms: u64,
    /// Per-task executor overhead inside a pod (HyperFlow job-executor
    /// fetch + spawn).
    pub exec_overhead_ms: u64,
    /// Job-controller reconcile delay (Job object -> Pod object).
    pub job_controller_ms: u64,
    /// Message fetch latency from a pool queue.
    pub fetch_ms: u64,
    pub sched: SchedulerConfig,
    pub api: ApiServerConfig,
    pub autoscale: AutoscalerConfig,
    /// Hard wall-clock cap on the simulation (guards against livelock in
    /// pathological configurations). Simulated seconds.
    pub max_sim_s: f64,
    /// **Deprecated** — legacy knob, kept working for old configs: at
    /// build time a non-zero value is folded into the chaos subsystem as
    /// an [`Injector::PodFailure`]. Prefer `chaos` with a `pod:<p>` spec.
    pub pod_failure_prob: f64,
    /// Seed for the chaos/failure-injection RNG streams.
    pub seed: u64,
    /// Chaos engine: fault injectors + recovery policy (see
    /// [`crate::chaos`]). Empty = disabled, zero overhead, bit-identical
    /// behavior to pre-chaos builds.
    pub chaos: ChaosConfig,
    /// Future-work (§5): throttled job submission — cap on pods that may
    /// sit in the Pending/creation pipeline at once; further batches wait
    /// in the engine. `None` reproduces the paper's unthrottled behaviour.
    pub max_pending_pods: Option<usize>,
    /// Failure injection: scheduled node up/down events (ms, node index,
    /// up?). Down kills all pods on the node (jobs recreated, worker tasks
    /// requeued); up restores capacity.
    pub node_events: Vec<(u64, usize, bool)>,
    /// Data plane: shared-storage/transfer modeling (see [`crate::data`]).
    /// `None` (the default) disables it entirely — no stage events are
    /// ever scheduled and runs are bit-identical to pre-data builds.
    pub data: Option<DataConfig>,
}

impl Default for SimConfig {
    fn default() -> Self {
        let nodes = 17;
        SimConfig {
            nodes,
            pod_start_ms: 2_000,
            exec_overhead_ms: 100,
            job_controller_ms: 500,
            fetch_ms: 10,
            sched: SchedulerConfig::default(),
            api: ApiServerConfig::default(),
            autoscale: AutoscalerConfig {
                quota_cpu_m: nodes as u64 * 4_000,
                ..Default::default()
            },
            max_sim_s: 6.0 * 3600.0,
            pod_failure_prob: 0.0,
            seed: 42,
            chaos: ChaosConfig::default(),
            max_pending_pods: None,
            node_events: Vec::new(),
            data: None,
        }
    }
}

impl SimConfig {
    pub fn with_nodes(nodes: usize) -> Self {
        SimConfig {
            nodes,
            autoscale: AutoscalerConfig {
                quota_cpu_m: nodes as u64 * 4_000,
                ..Default::default()
            },
            ..Default::default()
        }
    }
}

/// Simulation events.
#[derive(Debug, Clone, PartialEq, Eq)]
enum Ev {
    /// API processed the Job creation; the Job controller will now create
    /// the pod object.
    JobAdmitted { pod: PodId },
    /// Pod object exists; enters the scheduler.
    PodCreated { pod: PodId },
    /// Container started; payload begins.
    PodStarted { pod: PodId },
    /// Current task inside the pod finished.
    TaskDone { pod: PodId, task: TaskId },
    /// A pod's scheduling back-off expired; retry.
    BackoffExpire { pod: PodId },
    /// Clustering partial-batch timeout.
    FlushTimer { type_idx: u16, deadline: SimTime },
    /// Autoscaler poll.
    AutoscaleTick,
    /// A worker finished fetching a message from its queue.
    WorkerFetched { pod: PodId, task: TaskId },
    /// Failure injection: a node goes down (kills its pods) or comes back.
    NodeEvent { node: usize, up: bool },
    /// Fleet service: workflow instance `inst` arrives (open-loop).
    InstanceArrive { inst: u32 },
    /// Chaos: timed injector `proc_idx` strikes `node` (spot warning or
    /// crash); the handler samples and schedules the process's next fault.
    ChaosFault { proc_idx: u8, node: usize },
    /// Chaos: a spot-reclaim warning expired — the node goes down now;
    /// replacement capacity arrives `replace_ms` later.
    ChaosReclaim { node: usize, replace_ms: u64 },
    /// Chaos: a reclaimed/crashed node's replacement capacity arrives
    /// (fresh incarnation).
    ChaosRestore { node: usize },
    /// Chaos: a blacklisted node's cordon expires.
    ChaosUncordon { node: usize },
    /// Chaos recovery: a failed pool task's retry back-off expired.
    ChaosRetryTask { task: TaskId },
    /// Chaos recovery: a failed job batch's retry back-off expired.
    ChaosRetryBatch { tasks: Vec<TaskId> },
    /// Chaos recovery: straggler watch — if `task` is still running in
    /// `pod`, launch a speculative copy.
    SpecCheck { pod: PodId, task: TaskId },
    /// Data plane: a transfer's scheduled completion check (stale
    /// generations are dropped by [`DataPlane::flow_done`]).
    FlowDone { flow: u32, gen: u32 },
    /// Data plane: an object-store request's latency elapsed — the flow
    /// joins fair bandwidth sharing.
    FlowActivate { flow: u32, gen: u32 },
}

/// Where a pod is in the stage-in -> compute -> stage-out cycle of its
/// current task (always `Idle` between tasks; stage phases only occur
/// with the data plane enabled).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum IoPhase {
    Idle,
    StageIn,
    Compute,
    StageOut,
}

/// What a pod will do next, extracted from its payload without cloning it
/// (the owned `Vec<TaskId>` is *moved* out of job payloads).
enum PodWork {
    Batch(Vec<TaskId>),
    Pool(PoolId),
}

/// Sentinel for "no pending fault" in the per-task fault-time table.
const NO_FAULT: u64 = u64::MAX;

/// Runtime state of the chaos engine for one run (`None` = disabled: no
/// chaos events are ever scheduled and the hot path is untouched).
struct ChaosRuntime {
    /// Timed injectors (spot reclaim, node crash), each with its own
    /// forked RNG stream.
    processes: Vec<FaultProcess>,
    /// Combined per-start crash probability over all PodFailure injectors
    /// (includes the migrated legacy `pod_failure_prob`).
    pod_fail_prob: f64,
    /// Stream for pod-start crash sampling.
    pod_rng: crate::util::rng::Rng,
    /// Stream for straggler (re)sampling on node replacement.
    node_rng: crate::util::rng::Rng,
    /// Straggler injector params: (fraction of slow nodes, slow factor).
    straggler: Option<(f64, f64)>,
    /// Recovery policy in force (explicit or per-model default).
    policy: RecoveryPolicy,
    /// Quota the autoscaler was configured with at build (re-scaled to
    /// surviving capacity on node churn).
    base_quota: u64,
}

impl ChaosRuntime {
    /// Build the runtime from a config, folding the deprecated
    /// `pod_failure_prob` knob in as one more PodFailure injector.
    /// Returns `None` when no fault source is configured.
    fn build(
        cfg: &ChaosConfig,
        legacy_pod_failure_prob: f64,
        model: &ExecModel,
        seed: u64,
        base_quota: u64,
    ) -> Option<ChaosRuntime> {
        let mut spec = cfg.clone();
        if legacy_pod_failure_prob > 0.0 {
            log::warn!(
                "sim.pod_failure_prob is deprecated: folding it into the chaos \
                 subsystem as a PodFailure injector (use chaos spec 'pod:{legacy_pod_failure_prob}')"
            );
            spec.injectors.push(Injector::PodFailure {
                prob: legacy_pod_failure_prob,
            });
        }
        if !spec.is_enabled() {
            return None;
        }
        let policy = spec
            .recovery
            .clone()
            .unwrap_or_else(|| RecoveryPolicy::for_model(model));
        // Fixed fork order => the fault timeline is a pure function of
        // (seed, chaos spec), independent of everything else in the run.
        // The pod-failure stream keeps the legacy `seed ^ 0xFA11` seeding
        // of the old inline pod_failure_prob branch, so configs that only
        // set the deprecated knob reproduce their historical failure
        // pattern (one draw per pod start, same order until the first
        // fault diverges the timeline).
        let mut master = crate::util::rng::Rng::new(seed ^ 0xC4A0_5EED);
        let pod_rng = crate::util::rng::Rng::new(seed ^ 0xFA11);
        let node_rng = master.fork(2);
        let processes: Vec<FaultProcess> = spec
            .injectors
            .iter()
            .filter(|i| i.is_timed())
            .enumerate()
            .map(|(k, i)| FaultProcess::new(i.clone(), master.fork(16 + k as u64)))
            .collect();
        assert!(processes.len() <= u8::MAX as usize, "too many timed injectors");
        Some(ChaosRuntime {
            processes,
            pod_fail_prob: spec.pod_failure_prob(),
            pod_rng,
            node_rng,
            straggler: spec.straggler(),
            policy,
            base_quota,
        })
    }
}

/// Runtime state of a fleet run (see [`run_fleet`]): per-instance
/// admission and completion tracking over the disjoint-union task space.
struct FleetState {
    /// Unfinished task count per instance; 0 = the instance completed.
    outstanding: Vec<u32>,
    /// Each instance's initially-ready tasks, dispatched at admission
    /// (taken out once — an instance is admitted exactly once).
    roots: Vec<Vec<TaskId>>,
    admitted_at: Vec<Option<SimTime>>,
    finished_at: Vec<Option<SimTime>>,
    /// Arrived instances waiting for an admission slot (FIFO).
    waiting: VecDeque<u32>,
    /// Instances admitted but not yet finished.
    in_flight: usize,
    /// Admission-control cap on concurrently running instances.
    max_in_flight: Option<usize>,
}

struct World {
    cfg: SimConfig,
    q: EventQueue<Ev>,
    pods: Vec<Pod>,
    nodes: Vec<Node>,
    sched: Scheduler,
    api: ApiServer,
    engine: Engine,
    batcher: Batcher,
    broker: Broker,
    scaler: Option<Autoscaler>,
    /// Worker deployment state per pool: live pod set, kept sorted by
    /// `PodId` (ids are assigned monotonically, so insertion is a push;
    /// this preserves the old `BTreeSet` iteration order for scale-down).
    deployments: Vec<Vec<PodId>>,
    /// Idle running workers per pool (FIFO).
    idle_workers: Vec<VecDeque<PodId>>,
    /// The task type backing each pool (`None` for the generic pool).
    pool_type: Vec<Option<TypeId>>,
    /// Routing table: which pool (if any) a ready task of each type goes
    /// to. Replaces per-task string compares/clones in dispatch.
    pool_of_type: Vec<Option<PoolId>>,
    /// Pools in name order — the autoscale reconciliation applies desired
    /// counts in this order to stay bit-identical with the pre-interning
    /// code, which iterated a `BTreeMap<String, usize>`.
    pools_by_name: Vec<PoolId>,
    /// Remaining batch tasks per pod (job path), front = current.
    batch_queue: Vec<VecDeque<TaskId>>,
    /// Task currently executing in each pod (for node-failure recovery).
    current_task: Vec<Option<TaskId>>,
    /// Job batches deferred by the pending-pod throttle (§5 future work).
    throttle_wait: VecDeque<Vec<TaskId>>,
    /// Pods created but not yet bound (throttle accounting).
    jobs_in_flight: usize,
    /// Pod template for the generic-pool model (max over all types).
    generic_requests: Resources,
    metrics: Registry,
    trace: Trace,
    running_tasks: i64,
    /// Incremental count of pods in the Pending phase (perf: a full scan
    /// here was 70% of the 16k job-model sim, see EXPERIMENTS.md §Perf).
    pending_count: usize,
    /// Completed tasks per TypeId (feeds the VPA usage estimator).
    completed_by_type: Vec<u64>,
    // pre-resolved gauge handles (string-keyed lookups were hot; §Perf)
    g_running: GaugeId,
    g_cpu: GaugeId,
    g_pending: GaugeId,
    /// running::<type> gauge per TypeId.
    g_by_type: Vec<GaugeId>,
    /// queue::<pool> gauge per PoolId.
    g_queue: Vec<GaugeId>,
    /// replicas::<pool> gauge per PoolId.
    g_replicas: Vec<GaugeId>,
    // -- chaos engine (None for healthy runs; see crate::chaos) ----------
    chaos: Option<ChaosRuntime>,
    /// Resilience accounting (always present; all-zero without chaos).
    chaos_stats: ChaosStats,
    /// Per-node task-duration multiplier (straggler injector; all 1.0
    /// otherwise). Resampled when a node's replacement arrives.
    node_slow: Vec<f64>,
    /// Node incarnation counters: bumped when replacement capacity for a
    /// reclaimed/crashed node arrives, so events bound to the previous
    /// hardware are recognizably stale.
    node_incarnation: Vec<u32>,
    /// Pod-start failures charged to each node (blacklisting evidence).
    node_fault_counts: Vec<u32>,
    /// Spot warning in progress for the node (drain pending).
    drain_pending: Vec<bool>,
    /// Blacklist expiry per node (ZERO = not blacklisted).
    blacklist_until: Vec<SimTime>,
    /// Incarnation of the node each pod was bound to (stale-event guard).
    pod_bound_inc: Vec<u32>,
    /// When the task currently in each pod started (waste accounting).
    pod_task_started_at: Vec<SimTime>,
    /// Remaining work per task (checkpoint-restart shrinks it on re-runs;
    /// initialized to the DAG durations).
    task_work_left: Vec<SimTime>,
    /// Fault-driven re-dispatch count per task (retry back-off input).
    task_attempts: Vec<u32>,
    /// When the task was last lost to a fault (`NO_FAULT` = none pending);
    /// cleared into the recovery-latency summary when it re-starts.
    task_fault_at: Vec<u64>,
    /// A speculative copy was already launched for the task (at most one).
    spec_launched: Vec<bool>,
    /// Live executions per task (1 normally; 2 while a speculative copy
    /// races the original). Gates retries — a task with a copy still
    /// running must not be re-dispatched — and keeps the trace record on
    /// the first copy's timestamps.
    task_running: Vec<u8>,
    // -- data plane (None = pure-compute tasks, the pre-data behavior) ---
    data: Option<DataPlane>,
    /// Stage cycle position per pod (all `Idle`/`Compute` without data).
    pod_io: Vec<IoPhase>,
    /// Execution ms of the task a pod is currently staging out — success
    /// accounting (useful work, completed-by-type, compute time) is
    /// deferred until the write lands, so a kill mid-write re-runs the
    /// task without double counting.
    pod_exec_ms: Vec<u64>,
    /// Task has a stage-out in flight (its completion is not yet visible
    /// to successors); sized only when the data plane is on.
    task_out_pending: Vec<bool>,
    /// Scratch buffer for transfer (re)schedules.
    flow_buf: Vec<FlowEvent>,
    // -- fleet service (None for classic single-workflow runs) ----------
    fleet: Option<FleetState>,
    /// Instance index of each task (fleet runs; empty otherwise).
    task_instance: Vec<u32>,
    /// Tenant lane of each task (fleet runs; empty = all tenant 0).
    task_tenant: Vec<u16>,
    // -- reusable scratch buffers (zero steady-state allocation, §Perf) --
    /// Newly-ready tasks from `Engine::complete_into`.
    ready_buf: Vec<TaskId>,
    /// Scheduler pass output.
    pass_buf: SchedulePass,
    /// Pod-id snapshots (scale-down members, node-failure victims).
    members_buf: Vec<PodId>,
    /// Idle-worker snapshot for scale-down.
    idle_buf: Vec<PodId>,
    /// Autoscale tick: backlog / current / desired per pool.
    backlog_buf: Vec<usize>,
    current_buf: Vec<usize>,
    desired_buf: Vec<usize>,
}

/// Queue name of the single pool in the generic-pool model.
const GENERIC_POOL: &str = "__generic__";

impl World {
    fn now(&self) -> SimTime {
        self.q.now()
    }

    // ---------------------------------------------------------------
    // helpers
    // ---------------------------------------------------------------
    fn new_pod(&mut self, payload: Payload) -> PodId {
        let requests = match &payload {
            Payload::Worker { pool } => match self.pool_type[pool.idx()] {
                None => self.generic_requests,
                Some(ty) => {
                    let t = &self.engine.dag().types[ty.0 as usize];
                    // §5 VPA: once enough of this type has run, right-size
                    // new workers to the observed CPU usage
                    if self.cfg.autoscale.vpa
                        && self.completed_by_type[ty.0 as usize]
                            >= self.cfg.autoscale.vpa_min_samples
                    {
                        Resources::new(t.cpu_used_m, t.requests.mem_mb)
                    } else {
                        t.requests
                    }
                }
            },
            Payload::JobBatch { tasks } => self.engine.dag().type_of(tasks[0]).requests,
        };
        let id = PodId(self.pods.len() as u64);
        let pod = Pod::new(id, payload, requests, self.now());
        self.pods.push(pod);
        self.batch_queue.push(VecDeque::new());
        self.current_task.push(None);
        self.pod_bound_inc.push(0);
        self.pod_task_started_at.push(SimTime::ZERO);
        self.pod_io.push(IoPhase::Idle);
        self.pod_exec_ms.push(0);
        self.pending_count += 1;
        self.metrics.inc("pods_created", 1);
        id
    }

    /// Job path: create a Job for a batch of same-type tasks, honouring the
    /// pending-pod throttle (§5 future work) when configured.
    fn create_job(&mut self, tasks: Vec<TaskId>) {
        debug_assert!(!tasks.is_empty());
        if let Some(cap) = self.cfg.max_pending_pods {
            if self.jobs_in_flight >= cap {
                self.throttle_wait.push_back(tasks);
                self.metrics.inc("throttled_batches", 1);
                return;
            }
        }
        self.create_job_now(tasks);
    }

    fn create_job_now(&mut self, tasks: Vec<TaskId>) {
        let pid = self.new_pod(Payload::JobBatch { tasks });
        self.jobs_in_flight += 1;
        self.metrics.inc("jobs_created", 1);
        // API round-trip for the Job object
        let done = self.api.admit(self.now());
        self.q.schedule_at(done, Ev::JobAdmitted { pod: pid });
    }

    /// A job pod left the pending pipeline: admit deferred batches.
    fn job_unblocked(&mut self) {
        debug_assert!(self.jobs_in_flight > 0);
        self.jobs_in_flight -= 1;
        if let Some(cap) = self.cfg.max_pending_pods {
            while self.jobs_in_flight < cap {
                match self.throttle_wait.pop_front() {
                    Some(batch) => self.create_job_now(batch),
                    None => break,
                }
            }
        }
    }

    /// Pool path: create a worker pod for a deployment scale-up.
    fn create_worker(&mut self, pool: PoolId) {
        let pid = self.new_pod(Payload::Worker { pool });
        let dep = &mut self.deployments[pool.idx()];
        if let Some(&last) = dep.last() {
            debug_assert!(last < pid, "pod ids must be monotone");
        }
        dep.push(pid);
        let done = self.api.admit(self.now());
        self.q.schedule_at(done, Ev::PodCreated { pod: pid });
    }

    fn run_scheduler(&mut self) {
        let now = self.now();
        let mut pass = std::mem::take(&mut self.pass_buf);
        // locality-aware placement only when the data plane asks for it;
        // otherwise the oracle-free path is taken (bit-identical to the
        // pre-data scheduler)
        let data = self.data.take();
        let locality: Option<&dyn DataLocality> = match &data {
            Some(d) if d.cfg().locality => Some(d),
            _ => None,
        };
        self.sched
            .pass_into(now, &mut self.pods, &mut self.nodes, &mut pass, locality);
        self.data = data;
        if !pass.bound.is_empty() {
            self.record_cpu();
        }
        for &(pid, node, bind_done) in &pass.bound {
            self.pending_count -= 1;
            self.pod_bound_inc[pid.0 as usize] = self.node_incarnation[node.0];
            if matches!(self.pods[pid.0 as usize].payload, Payload::JobBatch { .. }) {
                self.job_unblocked();
            }
            self.q.schedule_at(
                bind_done + SimTime::from_millis(self.cfg.pod_start_ms),
                Ev::PodStarted { pod: pid },
            );
        }
        for &(pid, until) in &pass.backed_off {
            self.q.schedule_at(until, Ev::BackoffExpire { pod: pid });
        }
        self.pass_buf = pass;
        self.metrics
            .set_id(self.g_pending, now, self.pending_count as f64);
    }

    fn record_cpu(&mut self) {
        let now = self.now();
        let alloc: u64 = self.nodes.iter().map(|n| n.allocated.cpu_m).sum();
        self.metrics.set_id(self.g_cpu, now, alloc as f64);
    }

    fn record_running(&mut self, ttype: TypeId, delta: i64) {
        let now = self.now();
        self.running_tasks += delta;
        self.metrics
            .set_id(self.g_running, now, self.running_tasks as f64);
        self.metrics
            .add_id(self.g_by_type[ttype.0 as usize], now, delta as f64);
    }

    /// Record the current depth of a pool's queue.
    fn record_queue_depth(&mut self, pool: PoolId) {
        let now = self.now();
        let depth = self.broker.queue(pool).depth();
        self.metrics
            .set_id(self.g_queue[pool.idx()], now, depth as f64);
    }

    /// Start executing `task` inside `pod` at the current time.
    ///
    /// Chaos hooks (all inert on healthy runs): the remaining work may be
    /// less than the DAG duration (checkpoint-restart), a straggler node
    /// stretches it by its slowdown factor, a pending fault timestamp is
    /// folded into the recovery-latency summary, and straggling pool
    /// tasks get a speculation watch.
    fn start_task(&mut self, pod: PodId, task: TaskId) {
        let now = self.now();
        let nominal = self.task_work_left[task.0 as usize];
        let ttype = self.engine.dag().tasks[task.0 as usize].ttype;
        let slow = match self.pods[pod.0 as usize].node {
            Some(nid) => self.node_slow[nid.0],
            None => 1.0,
        };
        let dur = if slow != 1.0 {
            SimTime::from_millis((nominal.as_millis() as f64 * slow).round() as u64)
        } else {
            nominal
        };
        // a speculative copy racing the original must not overwrite the
        // task's trace record — queueing delay is ready -> *first* start
        if self.task_running[task.0 as usize] == 0 {
            self.trace.started(task, pod.0, now);
        }
        self.task_running[task.0 as usize] += 1;
        self.record_running(ttype, 1);
        self.pods[pod.0 as usize].executed += 1;
        self.current_task[pod.0 as usize] = Some(task);
        self.pod_io[pod.0 as usize] = IoPhase::Compute;
        self.pod_task_started_at[pod.0 as usize] = now;
        if self.chaos.is_some() {
            let fault_at = self.task_fault_at[task.0 as usize];
            if fault_at != NO_FAULT {
                self.task_fault_at[task.0 as usize] = NO_FAULT;
                self.chaos_stats
                    .recovery_latency
                    .add((now - SimTime::from_millis(fault_at)).as_secs_f64());
            }
        }
        self.q.schedule_at(
            now + SimTime::from_millis(self.cfg.exec_overhead_ms) + dur,
            Ev::TaskDone { pod, task },
        );
        // straggler watch: if the task is still running after spec_factor
        // x its nominal time, a speculative copy is launched (pools only)
        if let Some(ch) = &self.chaos {
            if ch.policy.speculative
                && ch.straggler.is_some()
                && !self.spec_launched[task.0 as usize]
                && self.pods[pod.0 as usize].pool_id().is_some()
            {
                let watch = SimTime::from_millis(
                    self.cfg.exec_overhead_ms
                        + (nominal.as_millis() as f64 * ch.policy.spec_factor).round() as u64,
                );
                self.q.schedule_at(now + watch, Ev::SpecCheck { pod, task });
            }
        }
    }

    // ---------------------------------------------------------------
    // data plane: the stage-in -> compute -> stage-out task cycle
    // ---------------------------------------------------------------

    /// Drain the data plane's (re)schedules into the event queue.
    fn schedule_flow_events(&mut self, mut buf: Vec<FlowEvent>) {
        for ev in buf.drain(..) {
            let e = if ev.activate {
                Ev::FlowActivate {
                    flow: ev.flow,
                    gen: ev.gen,
                }
            } else {
                Ev::FlowDone {
                    flow: ev.flow,
                    gen: ev.gen,
                }
            };
            self.q.schedule_at(ev.at, e);
        }
        self.flow_buf = buf;
    }

    /// Hand `task` to `pod`: with the data plane on, stage its inputs
    /// first (execution starts when the transfer completes); without it,
    /// execution starts immediately — the exact pre-data path.
    fn begin_task(&mut self, pod: PodId, task: TaskId) {
        if self.data.is_none() {
            self.start_task(pod, task);
            return;
        }
        let now = self.now();
        let node = self.pods[pod.0 as usize].node.expect("running pod is bound").0;
        let tenant = self.tenant_of(task).idx();
        self.current_task[pod.0 as usize] = Some(task);
        self.pod_io[pod.0 as usize] = IoPhase::StageIn;
        let mut buf = std::mem::take(&mut self.flow_buf);
        let start = self
            .data
            .as_mut()
            .expect("data plane")
            .begin_stage_in(now, pod, node, task, tenant, &mut buf);
        self.schedule_flow_events(buf);
        if start == StageStart::Ready {
            // every input byte is already node-local (warm cache)
            self.start_task(pod, task);
        }
    }

    /// The task's compute finished: write its output back to the backend.
    /// Successors become ready only when the write lands (write-through
    /// shared storage, like the paper's NFS volume).
    fn begin_stage_out_for(&mut self, pod: PodId, task: TaskId) {
        let now = self.now();
        let node = self.pods[pod.0 as usize].node.expect("running pod is bound").0;
        let tenant = self.tenant_of(task).idx();
        self.pod_io[pod.0 as usize] = IoPhase::StageOut;
        self.task_out_pending[task.0 as usize] = true;
        let mut buf = std::mem::take(&mut self.flow_buf);
        let start = self
            .data
            .as_mut()
            .expect("data plane")
            .begin_stage_out(now, pod, node, task, tenant, &mut buf);
        self.schedule_flow_events(buf);
        if start == StageStart::Ready {
            self.finish_task(pod, task);
        }
    }

    /// Stage-out landed (or the task had no output bytes): the task's
    /// completion becomes visible — trace it, propagate readiness, and
    /// advance the pod to its next unit of work. Data-plane runs only.
    fn finish_task(&mut self, pod: PodId, task: TaskId) {
        let now = self.now();
        self.current_task[pod.0 as usize] = None;
        self.pod_io[pod.0 as usize] = IoPhase::Idle;
        self.task_out_pending[task.0 as usize] = false;
        // a speculative twin cannot have completed it (the loser is caught
        // at TaskDone), but guard anyway: completing twice would corrupt
        // the engine's outstanding count
        if self.engine.state(task) != TaskState::Done {
            // success accounting deferred from TaskDone: only an execution
            // whose output landed counts as useful/completed
            let ttype = self.engine.dag().tasks[task.0 as usize].ttype;
            let exec_ms = self.pod_exec_ms[pod.0 as usize];
            self.completed_by_type[ttype.0 as usize] += 1;
            if self.chaos.is_some() {
                self.chaos_stats.useful_ms += exec_ms;
            }
            self.data.as_mut().expect("data plane").stats.compute_ms += exec_ms;
            self.trace.finished(task, now);
            let mut ready = std::mem::take(&mut self.ready_buf);
            ready.clear();
            self.engine.complete_into(task, &mut ready);
            self.dispatch_ready(&ready);
            self.ready_buf = ready;
            if self.fleet.is_some() {
                self.instance_task_done(task);
            }
        }
        match self.pods[pod.0 as usize].pool_id() {
            None => {
                self.batch_queue[pod.0 as usize].pop_front();
                if let Some(&next) = self.batch_queue[pod.0 as usize].front() {
                    self.begin_task(pod, next);
                } else {
                    self.terminate_pod(pod, PodPhase::Succeeded);
                }
            }
            Some(pool) => self.advance_worker(pod, pool),
        }
    }

    /// Node failure: kill every pod on the node; recover their work.
    /// Job batches are recreated by the job controller; a worker's
    /// in-flight task is redelivered to its queue (the broker's unacked
    /// window, like a RabbitMQ consumer dying).
    fn fail_node(&mut self, node: usize) {
        self.fail_node_inner(node, false);
    }

    /// Shared kill path for scheduled `node_events` (`chaos = false`:
    /// instant redelivery, the pre-chaos semantics) and the chaos engine
    /// (`chaos = true`: wasted-work accounting, checkpoint-restart credit,
    /// and policy-driven retry back-off instead of instant redelivery).
    fn fail_node_inner(&mut self, node: usize, chaos: bool) {
        self.nodes[node].failed = true;
        self.metrics.inc("node_failures", 1);
        let mut victims = std::mem::take(&mut self.members_buf);
        victims.clear();
        victims.extend(
            self.pods
                .iter()
                .filter(|p| p.node == Some(NodeId(node)) && !p.is_terminal())
                .map(|p| p.id),
        );
        for &pid in &victims {
            // roll back the running-task accounting for the in-flight task
            let in_flight = self.current_task[pid.0 as usize].take();
            let phase = self.pod_io[pid.0 as usize];
            if let Some(task) = in_flight {
                if phase != IoPhase::Compute {
                    // killed while staging data: nothing executed yet
                    // (stage-in) or the output write was lost (stage-out —
                    // the task must re-run, its completion never became
                    // visible). The requeue below handles both; only the
                    // running-task accounting is skipped.
                    if phase == IoPhase::StageOut {
                        self.task_out_pending[task.0 as usize] = false;
                        if chaos {
                            // the finished execution died with its output:
                            // its compute (plus the partial write) never
                            // counted as useful — charge it as waste and
                            // stamp the fault for recovery latency
                            let now = self.now();
                            let elapsed = now
                                .saturating_sub(self.pod_task_started_at[pid.0 as usize])
                                .as_millis();
                            let wasted =
                                elapsed.saturating_sub(self.cfg.exec_overhead_ms.min(elapsed));
                            self.chaos_stats
                                .add_waste(self.tenant_of(task).idx(), wasted);
                            self.task_fault_at[task.0 as usize] = now.as_millis();
                            self.metrics.inc("tasks_lost_to_faults", 1);
                        }
                    }
                } else {
                    let ttype = self.engine.dag().tasks[task.0 as usize].ttype;
                    self.record_running(ttype, -1);
                    self.task_running[task.0 as usize] -= 1;
                    if chaos {
                        if self.engine.state(task) == TaskState::Done {
                            // losing speculative copy killed after its twin
                            // already won: the whole run is waste, there is
                            // nothing to checkpoint or recover
                            let elapsed = self
                                .now()
                                .saturating_sub(self.pod_task_started_at[pid.0 as usize])
                                .as_millis();
                            let exec_ms =
                                elapsed.saturating_sub(self.cfg.exec_overhead_ms.min(elapsed));
                            self.chaos_stats
                                .add_waste(self.tenant_of(task).idx(), exec_ms);
                            self.metrics.inc("speculative_losses", 1);
                        } else {
                            self.account_lost_work(pid, task, node);
                        }
                    }
                }
            }
            let work = match &self.pods[pid.0 as usize].payload {
                Payload::JobBatch { tasks } => {
                    // job controller recreates the pod with the unfinished
                    // remainder of the batch (current task included)
                    let remaining: Vec<TaskId> = if self.batch_queue[pid.0 as usize].is_empty() {
                        tasks.clone() // killed while Pending/Starting
                    } else {
                        self.batch_queue[pid.0 as usize].iter().copied().collect()
                    };
                    PodWork::Batch(remaining)
                }
                Payload::Worker { pool } => PodWork::Pool(*pool),
            };
            self.terminate_pod(pid, PodPhase::Deleted);
            match work {
                PodWork::Batch(remaining) => {
                    if !remaining.is_empty() {
                        if chaos {
                            self.schedule_batch_retry(remaining);
                        } else {
                            self.create_job(remaining);
                        }
                    }
                }
                PodWork::Pool(pool) => {
                    if let Some(task) = in_flight {
                        if chaos {
                            // the recovery policy owns the message now: it
                            // re-enters the queue after its retry back-off
                            // (unless the task already completed elsewhere)
                            self.broker.nack_drop(pool);
                            self.record_queue_depth(pool);
                            if self.engine.state(task) != TaskState::Done {
                                self.schedule_task_retry(task);
                            }
                        } else {
                            // the unacked delivery is redelivered at once
                            self.broker.nack_requeue(pool, task, self.tenant_of(task));
                            self.wake_idle_worker(pool);
                        }
                    }
                }
            }
        }
        self.members_buf = victims;
        if chaos {
            self.update_chaos_quota();
        }
    }

    // ---------------------------------------------------------------
    // chaos engine: fault application, recovery, accounting
    // ---------------------------------------------------------------

    /// Sample + schedule the next fault of timed injector `i` (no-op for
    /// inert processes).
    fn schedule_next_fault(&mut self, i: usize) {
        let n = self.nodes.len();
        let Some(ch) = &mut self.chaos else { return };
        if let Some((delay, victim)) = ch.processes[i].next_fault(n) {
            self.q.schedule_in(
                delay,
                Ev::ChaosFault {
                    proc_idx: i as u8,
                    node: victim,
                },
            );
        }
    }

    /// A timed fault strikes `node`.
    fn apply_fault(&mut self, proc_idx: usize, node: usize) {
        let injector = match &self.chaos {
            Some(ch) => ch.processes[proc_idx].injector.clone(),
            None => return,
        };
        match injector {
            Injector::SpotReclaim {
                warning_ms,
                replace_ms,
                ..
            } => self.spot_warning(node, warning_ms, replace_ms),
            Injector::NodeCrash { repair_ms, .. } => {
                if self.nodes[node].failed {
                    return; // already down
                }
                self.chaos_stats.node_crashes += 1;
                self.metrics.inc("node_crashes", 1);
                self.fail_node_inner(node, true);
                self.q
                    .schedule_in(SimTime::from_millis(repair_ms), Ev::ChaosRestore { node });
            }
            _ => unreachable!("only timed injectors emit ChaosFault"),
        }
    }

    /// Spot reclaim, phase 1: the provider's warning. The node is cordoned
    /// (no new placements) and — under a graceful policy — its workers
    /// drain: idle workers terminate immediately (the autoscaler replaces
    /// them on surviving nodes), busy workers finish their current task
    /// and exit. Job pods run on; whatever is still alive when the warning
    /// expires dies with the node.
    fn spot_warning(&mut self, node: usize, warning_ms: u64, replace_ms: u64) {
        if self.nodes[node].failed || self.drain_pending[node] {
            return; // already dying
        }
        self.drain_pending[node] = true;
        self.nodes[node].cordoned = true;
        self.chaos_stats.spot_warnings += 1;
        self.metrics.inc("spot_warnings", 1);
        let drain = self
            .chaos
            .as_ref()
            .map(|c| c.policy.drain_on_warning)
            .unwrap_or(false);
        if drain {
            let mut victims = std::mem::take(&mut self.members_buf);
            victims.clear();
            victims.extend(
                self.pods
                    .iter()
                    .filter(|p| {
                        p.node == Some(NodeId(node))
                            && !p.is_terminal()
                            && p.pool_id().is_some()
                    })
                    .map(|p| p.id),
            );
            for &pid in &victims {
                match self.pods[pid.0 as usize].phase {
                    PodPhase::Running if self.current_task[pid.0 as usize].is_none() => {
                        // idle worker: release it now so the deployment
                        // re-creates it on a surviving node
                        self.terminate_pod(pid, PodPhase::Succeeded);
                    }
                    PodPhase::Running => {
                        self.pods[pid.0 as usize].phase = PodPhase::Draining;
                    }
                    // Starting workers are abandoned before doing work
                    PodPhase::Starting => self.terminate_pod(pid, PodPhase::Deleted),
                    _ => {}
                }
            }
            self.members_buf = victims;
        }
        self.q.schedule_in(
            SimTime::from_millis(warning_ms),
            Ev::ChaosReclaim { node, replace_ms },
        );
    }

    /// Charge the compute a killed in-flight task burned, minus the
    /// checkpoint-restored fraction, and shrink the task's remaining work
    /// accordingly. `node` is where it ran (for de-slowing straggler time
    /// into work units).
    fn account_lost_work(&mut self, pod: PodId, task: TaskId, node: usize) {
        let now = self.now();
        let elapsed = now
            .saturating_sub(self.pod_task_started_at[pod.0 as usize])
            .as_millis();
        let exec_ms = elapsed.saturating_sub(self.cfg.exec_overhead_ms.min(elapsed));
        let frac = self
            .chaos
            .as_ref()
            .map(|c| c.policy.checkpoint_frac)
            .unwrap_or(0.0);
        // progress in work units (a straggler burns `slow` wall-ms per
        // work-ms), of which `frac` survives in the checkpoint
        let slow = self.node_slow[node].max(1.0);
        let work_done = (exec_ms as f64 / slow) as u64;
        let left = self.task_work_left[task.0 as usize].as_millis();
        let credit = ((work_done as f64 * frac) as u64).min(left.saturating_sub(1));
        self.task_work_left[task.0 as usize] = SimTime::from_millis(left - credit);
        let wasted = exec_ms.saturating_sub(credit);
        self.chaos_stats
            .add_waste(self.tenant_of(task).idx(), wasted);
        self.task_fault_at[task.0 as usize] = now.as_millis();
        self.metrics.inc("tasks_lost_to_faults", 1);
    }

    /// Schedule a pool task's policy-driven re-dispatch — unless another
    /// copy of it is still executing (speculation): the live copy carries
    /// the work, and if that copy dies too, *its* kill path schedules the
    /// retry. Keeps the at-most-one-extra-copy contract.
    fn schedule_task_retry(&mut self, task: TaskId) {
        if self.task_running[task.0 as usize] > 0 {
            return;
        }
        let attempt = self.task_attempts[task.0 as usize];
        self.task_attempts[task.0 as usize] = attempt.saturating_add(1);
        let delay = self
            .chaos
            .as_ref()
            .map(|c| c.policy.backoff(attempt))
            .unwrap_or(SimTime::ZERO);
        self.chaos_stats.add_retry(self.tenant_of(task).idx());
        self.metrics.inc("chaos_retries", 1);
        self.q.schedule_in(delay, Ev::ChaosRetryTask { task });
    }

    /// Schedule a job batch's policy-driven re-creation (attempt count
    /// keyed on the batch's first task).
    fn schedule_batch_retry(&mut self, tasks: Vec<TaskId>) {
        debug_assert!(!tasks.is_empty());
        let key = tasks[0];
        let attempt = self.task_attempts[key.0 as usize];
        self.task_attempts[key.0 as usize] = attempt.saturating_add(1);
        let delay = self
            .chaos
            .as_ref()
            .map(|c| c.policy.backoff(attempt))
            .unwrap_or(SimTime::ZERO);
        self.chaos_stats.add_retry(self.tenant_of(key).idx());
        self.metrics.inc("chaos_retries", 1);
        self.q.schedule_in(delay, Ev::ChaosRetryBatch { tasks });
    }

    /// A pod crashed at container start (PodFailure injector, successor of
    /// the legacy inline `pod_failure_prob` branch): the startup time is
    /// wasted, the node collects blacklisting evidence, and the payload is
    /// recovered by policy — batches after a retry back-off, workers by
    /// the deployment controller on the next autoscale tick.
    fn pod_start_failure(&mut self, pod: PodId) {
        self.metrics.inc("pod_failures", 1);
        self.chaos_stats.pod_failures += 1;
        // the container-start latency was burned for nothing; a batch pod
        // charges its owning tenant, a shared pool worker charges no lane
        // (it serves every tenant)
        match &self.pods[pod.0 as usize].payload {
            Payload::JobBatch { tasks } => {
                let tenant = self.tenant_of(tasks[0]).idx();
                self.chaos_stats.add_waste(tenant, self.cfg.pod_start_ms);
            }
            Payload::Worker { .. } => {
                self.chaos_stats.add_waste_shared(self.cfg.pod_start_ms);
            }
        }
        if let Some(nid) = self.pods[pod.0 as usize].node {
            self.note_node_fault(nid.0);
        }
        let retry = match &mut self.pods[pod.0 as usize].payload {
            Payload::JobBatch { tasks } => Some(std::mem::take(tasks)),
            Payload::Worker { .. } => None,
        };
        self.terminate_pod(pod, PodPhase::Deleted);
        if let Some(tasks) = retry {
            self.schedule_batch_retry(tasks);
        }
    }

    /// Blacklisting: a node that keeps failing pod starts is cordoned for
    /// the policy's blacklist window.
    fn note_node_fault(&mut self, node: usize) {
        self.node_fault_counts[node] += 1;
        let Some(ch) = &self.chaos else { return };
        let k = ch.policy.blacklist_after;
        let window = ch.policy.blacklist_ms;
        if k == 0 || self.node_fault_counts[node] < k {
            return;
        }
        if self.nodes[node].failed || self.nodes[node].cordoned {
            return; // already out of rotation
        }
        let now = self.now();
        self.nodes[node].cordoned = true;
        self.blacklist_until[node] = now + SimTime::from_millis(window);
        self.node_fault_counts[node] = 0;
        self.chaos_stats.blacklists += 1;
        self.metrics.inc("node_blacklists", 1);
        self.q
            .schedule_in(SimTime::from_millis(window), Ev::ChaosUncordon { node });
    }

    /// Rescale the pool quota to the surviving node capacity (chaos runs
    /// only — legacy `node_events` keep the original quota semantics).
    fn update_chaos_quota(&mut self) {
        let Some(ch) = &self.chaos else { return };
        let base = ch.base_quota;
        if self.scaler.is_none() {
            return;
        }
        let total: u64 = self.nodes.iter().map(|n| n.capacity.cpu_m).sum();
        let live: u64 = self
            .nodes
            .iter()
            .filter(|n| !n.failed)
            .map(|n| n.capacity.cpu_m)
            .sum();
        let quota = ((base as u128 * live as u128) / total.max(1) as u128) as u64;
        self.scaler.as_mut().unwrap().set_quota(quota);
    }

    /// A scheduled pod event is stale when the pod's node was reclaimed
    /// and its replacement (same index, new incarnation) arrived in the
    /// meantime. Defense-in-depth: chaos kills are synchronous, so pods
    /// die with their node — but any completion that slips through must
    /// not be credited against the new hardware.
    fn stale_node_event(&mut self, pod: PodId) -> bool {
        let Some(nid) = self.pods[pod.0 as usize].node else {
            return false;
        };
        if self.pod_bound_inc[pod.0 as usize] != self.node_incarnation[nid.0] {
            self.chaos_stats.stale_drops += 1;
            self.metrics.inc("stale_node_events_dropped", 1);
            return true;
        }
        false
    }

    /// Post-completion advance of a pool worker: ack the delivery, then
    /// drain, fetch the next message, or go idle. Shared by the normal
    /// completion path and the speculative-loser path.
    fn advance_worker(&mut self, pod: PodId, pool: PoolId) {
        let now = self.now();
        self.broker.ack(pool);
        self.record_queue_depth(pool);
        if self.pods[pod.0 as usize].phase == PodPhase::Draining {
            self.terminate_pod(pod, PodPhase::Succeeded);
        } else if let Some(next) = self.broker.fetch(pool) {
            self.q.schedule_at(
                now + SimTime::from_millis(self.cfg.fetch_ms),
                Ev::WorkerFetched { pod, task: next },
            );
        } else {
            self.idle_workers[pool.idx()].push_back(pod);
        }
    }

    /// Tenant lane of a task: its instance's tenant in fleet runs, the
    /// default lane otherwise.
    fn tenant_of(&self, t: TaskId) -> TenantId {
        TenantId(self.task_tenant.get(t.0 as usize).copied().unwrap_or(0))
    }

    /// Route newly-ready tasks to the execution model.
    fn dispatch_ready(&mut self, ready: &[TaskId]) {
        let now = self.now();
        for &t in ready {
            let ttype = self.engine.dag().tasks[t.0 as usize].ttype;
            self.trace.ready(t, self.engine.dag().type_name(t), now);
            match self.pool_of_type[ttype.0 as usize] {
                Some(pool) => {
                    self.broker.publish_for(pool, t, self.tenant_of(t));
                    self.record_queue_depth(pool);
                    self.wake_idle_worker(pool);
                }
                None => {
                    // job path (with or without clustering)
                    let action = self.batcher.push(
                        now,
                        &self.engine.dag().types[ttype.0 as usize].name,
                        t,
                    );
                    match action {
                        BatchAction::Flush(batch) => self.create_job(batch),
                        BatchAction::ArmTimer(deadline) => self.q.schedule_at(
                            deadline,
                            Ev::FlushTimer {
                                type_idx: ttype.0,
                                deadline,
                            },
                        ),
                        BatchAction::Buffered => {}
                    }
                }
            }
        }
    }

    /// Give an idle worker of `pool` a task, if any is queued.
    fn wake_idle_worker(&mut self, pool: PoolId) {
        while let Some(&pid) = self.idle_workers[pool.idx()].front() {
            // skip workers that were deleted while idle
            if self.pods[pid.0 as usize].phase != PodPhase::Running {
                self.idle_workers[pool.idx()].pop_front();
                continue;
            }
            if let Some(task) = self.broker.fetch(pool) {
                self.idle_workers[pool.idx()].pop_front();
                let now = self.now();
                self.q.schedule_at(
                    now + SimTime::from_millis(self.cfg.fetch_ms),
                    Ev::WorkerFetched { pod: pid, task },
                );
            }
            return;
        }
    }

    /// Terminate a pod and free its node resources.
    fn terminate_pod(&mut self, pid: PodId, phase: PodPhase) {
        let now = self.now();
        if self.pods[pid.0 as usize].phase == PodPhase::Pending {
            self.pending_count -= 1;
        }
        // data plane: the pod's in-flight transfer is torn down and its
        // ephemeral cache entries die with it (crash-loses-cache)
        if self.data.is_some() {
            let node = self.pods[pid.0 as usize].node.map(|n| n.0);
            let mut buf = std::mem::take(&mut self.flow_buf);
            self.data
                .as_mut()
                .expect("data plane")
                .cancel_pod(now, pid, node, &mut buf);
            self.schedule_flow_events(buf);
            self.pod_io[pid.0 as usize] = IoPhase::Idle;
        }
        let pod = &mut self.pods[pid.0 as usize];
        debug_assert!(!pod.is_terminal());
        let had_node = pod.node;
        pod.phase = phase;
        pod.finished_at = Some(now);
        if let Some(nid) = had_node {
            let req = pod.requests;
            self.nodes[nid.0].release(req);
            self.record_cpu();
        }
        if let Some(pool) = self.pods[pid.0 as usize].pool_id() {
            let dep = &mut self.deployments[pool.idx()];
            if let Ok(i) = dep.binary_search(&pid) {
                dep.remove(i);
            }
        }
        self.sched.forget(pid);
        // pod deletion is an API request too
        self.api.admit(now);
        // freed resources: pods in the *active* queue can retry now; pods in
        // back-off keep sleeping (the paper's §4.2/4.3 pathology).
        self.run_scheduler();
    }

    // ---------------------------------------------------------------
    // fleet service: instance arrival / admission / completion
    // ---------------------------------------------------------------

    /// An instance arrives (open-loop): admit immediately if a slot is
    /// free, otherwise join the admission queue (FIFO).
    fn instance_arrive(&mut self, inst: usize) {
        let admit = {
            let fs = self.fleet.as_mut().expect("fleet mode");
            match fs.max_in_flight {
                Some(cap) if fs.in_flight >= cap => {
                    fs.waiting.push_back(inst as u32);
                    false
                }
                _ => true,
            }
        };
        if admit {
            self.admit_instance(inst);
        }
    }

    /// Admit an instance: dispatch its root tasks into the shared cluster.
    fn admit_instance(&mut self, inst: usize) {
        let now = self.now();
        let roots = {
            let fs = self.fleet.as_mut().expect("fleet mode");
            fs.in_flight += 1;
            debug_assert!(fs.admitted_at[inst].is_none(), "double admission");
            fs.admitted_at[inst] = Some(now);
            std::mem::take(&mut fs.roots[inst])
        };
        self.metrics.inc("instances_admitted", 1);
        self.dispatch_ready(&roots);
    }

    /// Per-instance completion bookkeeping after a task finished; frees an
    /// admission slot (and admits the next waiting instance) when the
    /// task was its instance's last.
    fn instance_task_done(&mut self, task: TaskId) {
        let now = self.now();
        let inst = self.task_instance[task.0 as usize] as usize;
        let next = {
            let fs = self.fleet.as_mut().expect("fleet mode");
            debug_assert!(fs.outstanding[inst] > 0);
            fs.outstanding[inst] -= 1;
            if fs.outstanding[inst] > 0 {
                return;
            }
            fs.finished_at[inst] = Some(now);
            fs.in_flight -= 1;
            fs.waiting.pop_front()
        };
        self.metrics.inc("instances_completed", 1);
        if let Some(next) = next {
            self.admit_instance(next as usize);
        }
    }

    // ---------------------------------------------------------------
    // autoscaler reconciliation
    // ---------------------------------------------------------------
    fn autoscale(&mut self) {
        let now = self.now();
        // VPA: publish right-sized pod templates to the scaler once a
        // type's usage estimate is trustworthy
        if self.cfg.autoscale.vpa {
            if let Some(s) = &mut self.scaler {
                for pool in 0..self.pool_type.len() {
                    let Some(ty) = self.pool_type[pool] else { continue };
                    let t = &self.engine.dag().types[ty.0 as usize];
                    if self.completed_by_type[ty.0 as usize] >= self.cfg.autoscale.vpa_min_samples
                        && t.cpu_used_m != t.requests.cpu_m
                    {
                        s.set_pool_requests(pool, Resources::new(t.cpu_used_m, t.requests.mem_mb));
                    }
                }
            }
        }
        if self.scaler.is_none() {
            return;
        }
        let n_pools = self.deployments.len();
        let mut backlogs = std::mem::take(&mut self.backlog_buf);
        let mut current = std::mem::take(&mut self.current_buf);
        let mut desired = std::mem::take(&mut self.desired_buf);
        backlogs.clear();
        current.clear();
        for pool in 0..n_pools {
            backlogs.push(self.broker.queue(PoolId(pool as u16)).backlog());
            let have = self.deployments[pool].len();
            current.push(have);
            self.metrics.set_id(self.g_replicas[pool], now, have as f64);
        }
        self.scaler
            .as_mut()
            .unwrap()
            .poll_into(now, &backlogs, &current, &mut desired);
        let pools_by_name = std::mem::take(&mut self.pools_by_name);
        for &pool in &pools_by_name {
            let want = desired[pool.idx()];
            let have = self.deployments[pool.idx()].len();
            if want > have {
                for _ in 0..(want - have) {
                    self.create_worker(pool);
                }
            } else if want < have {
                self.scale_down(pool, have - want);
            }
        }
        self.pools_by_name = pools_by_name;
        self.backlog_buf = backlogs;
        self.current_buf = current;
        self.desired_buf = desired;
        self.run_scheduler();
    }

    /// Remove `n` workers from a pool: pending pods first, then idle
    /// running workers, then mark busy workers Draining.
    fn scale_down(&mut self, pool: PoolId, n: usize) {
        let mut members = std::mem::take(&mut self.members_buf);
        members.clear();
        members.extend_from_slice(&self.deployments[pool.idx()]);
        let mut idle = std::mem::take(&mut self.idle_buf);
        idle.clear();
        idle.extend(self.idle_workers[pool.idx()].iter().copied());
        self.scale_down_phases(pool, n, &members, &idle);
        self.members_buf = members;
        self.idle_buf = idle;
    }

    fn scale_down_phases(&mut self, pool: PoolId, n: usize, members: &[PodId], idle: &[PodId]) {
        let mut remaining = n;
        // 1. pending (never scheduled) pods
        for &pid in members {
            if remaining == 0 {
                return;
            }
            if self.pods[pid.0 as usize].phase == PodPhase::Pending {
                self.terminate_pod(pid, PodPhase::Deleted);
                remaining -= 1;
            }
        }
        // also starting pods that haven't begun work
        for &pid in members {
            if remaining == 0 {
                return;
            }
            if self.pods[pid.0 as usize].phase == PodPhase::Starting {
                self.terminate_pod(pid, PodPhase::Deleted);
                remaining -= 1;
            }
        }
        // 2. idle running workers
        for &pid in idle {
            if remaining == 0 {
                return;
            }
            if self.pods[pid.0 as usize].phase == PodPhase::Running {
                self.idle_workers[pool.idx()].retain(|&p| p != pid);
                self.terminate_pod(pid, PodPhase::Deleted);
                remaining -= 1;
            }
        }
        // 3. drain busy workers (terminate after current task)
        for &pid in members {
            if remaining == 0 {
                return;
            }
            let pod = &mut self.pods[pid.0 as usize];
            if pod.phase == PodPhase::Running {
                pod.phase = PodPhase::Draining;
                remaining -= 1;
            }
        }
    }

    // ---------------------------------------------------------------
    // event handlers
    // ---------------------------------------------------------------
    fn handle(&mut self, ev: Ev) {
        match ev {
            Ev::JobAdmitted { pod } => {
                // job controller creates the pod object after its reconcile
                let done = self.api.admit(self.now())
                    + SimTime::from_millis(self.cfg.job_controller_ms);
                self.q.schedule_at(done, Ev::PodCreated { pod });
            }
            Ev::PodCreated { pod } => {
                if self.pods[pod.0 as usize].phase == PodPhase::Pending {
                    self.sched.enqueue(pod);
                    self.run_scheduler();
                }
            }
            Ev::BackoffExpire { pod } => {
                if self.pods[pod.0 as usize].phase == PodPhase::Pending
                    && self.sched.is_sleeping(pod)
                {
                    self.sched.enqueue(pod);
                    self.run_scheduler();
                }
            }
            Ev::PodStarted { pod } => {
                let now = self.now();
                if self.pods[pod.0 as usize].is_terminal() {
                    return; // deleted while starting
                }
                if self.stale_node_event(pod) {
                    return; // bound to a node incarnation that no longer exists
                }
                // chaos: crash at container start (PodFailure injector —
                // the migrated sim.pod_failure_prob knob included)
                let crash = match &mut self.chaos {
                    Some(ch) if ch.pod_fail_prob > 0.0 => ch.pod_rng.f64() < ch.pod_fail_prob,
                    _ => false,
                };
                if crash {
                    self.pod_start_failure(pod);
                    return;
                }
                let work = {
                    let p = &mut self.pods[pod.0 as usize];
                    p.phase = PodPhase::Running;
                    p.running_at = Some(now);
                    match &mut p.payload {
                        // move the batch into the execution queue — the
                        // remainder lives in `batch_queue` from here on
                        Payload::JobBatch { tasks } => PodWork::Batch(std::mem::take(tasks)),
                        Payload::Worker { pool } => PodWork::Pool(*pool),
                    }
                };
                match work {
                    PodWork::Batch(tasks) => {
                        self.batch_queue[pod.0 as usize] = tasks.into();
                        let first = self.batch_queue[pod.0 as usize]
                            .front()
                            .copied()
                            .expect("non-empty batch");
                        self.begin_task(pod, first);
                    }
                    PodWork::Pool(pool) => {
                        if let Some(task) = self.broker.fetch(pool) {
                            self.q.schedule_at(
                                now + SimTime::from_millis(self.cfg.fetch_ms),
                                Ev::WorkerFetched { pod, task },
                            );
                        } else {
                            self.idle_workers[pool.idx()].push_back(pod);
                        }
                    }
                }
            }
            Ev::WorkerFetched { pod, task } => {
                if self.pods[pod.0 as usize].is_terminal() {
                    // worker deleted between fetch and start: requeue on
                    // the pod's own pool (its payload outlives deletion)
                    if let Some(pool) = self.pods[pod.0 as usize].pool_id() {
                        self.broker.nack_requeue(pool, task, self.tenant_of(task));
                        self.wake_idle_worker(pool);
                    }
                    return;
                }
                // chaos/speculation: the task already completed elsewhere
                // (its other copy won, or it was requeued after a fault
                // and then finished) — drop the stale delivery
                if self.engine.state(task) == TaskState::Done {
                    if let Some(pool) = self.pods[pod.0 as usize].pool_id() {
                        self.advance_worker(pod, pool);
                    }
                    return;
                }
                self.begin_task(pod, task);
            }
            Ev::TaskDone { pod, task } => {
                if self.pods[pod.0 as usize].is_terminal()
                    || self.current_task[pod.0 as usize] != Some(task)
                {
                    return; // pod was killed; the task was requeued/recreated
                }
                if self.stale_node_event(pod) {
                    return; // completion from a node incarnation that is gone
                }
                let now = self.now();
                let ttype = self.engine.dag().tasks[task.0 as usize].ttype;
                // execution time of this run, net of the fixed executor
                // overhead — same definition as account_lost_work, so
                // goodput's numerator and denominator are commensurate
                let elapsed = now
                    .saturating_sub(self.pod_task_started_at[pod.0 as usize])
                    .as_millis();
                let exec_ms = elapsed.saturating_sub(self.cfg.exec_overhead_ms.min(elapsed));
                // speculative duplicate that lost the race: the task
                // already completed in its other copy (or, with the data
                // plane, its twin's stage-out is already in flight) — the
                // whole run is wasted work, and the worker simply moves on
                if self.engine.state(task) == TaskState::Done
                    || (self.data.is_some() && self.task_out_pending[task.0 as usize])
                {
                    self.current_task[pod.0 as usize] = None;
                    self.pod_io[pod.0 as usize] = IoPhase::Idle;
                    self.record_running(ttype, -1);
                    self.task_running[task.0 as usize] -= 1;
                    self.chaos_stats
                        .add_waste(self.tenant_of(task).idx(), exec_ms);
                    self.metrics.inc("speculative_losses", 1);
                    if let Some(pool) = self.pods[pod.0 as usize].pool_id() {
                        self.advance_worker(pod, pool);
                    }
                    return;
                }
                if self.data.is_some() {
                    // the execution is done but the output write is not:
                    // successors wait for the stage-out (write-through
                    // shared storage). `current_task` stays set so a kill
                    // during the write re-runs the task — and ALL success
                    // accounting (useful work, completed-by-type, compute
                    // time) waits for the write to land in finish_task,
                    // or the re-run would be counted twice.
                    self.record_running(ttype, -1);
                    self.task_running[task.0 as usize] -= 1;
                    self.pod_exec_ms[pod.0 as usize] = exec_ms;
                    self.begin_stage_out_for(pod, task);
                    return;
                }
                if self.chaos.is_some() {
                    self.chaos_stats.useful_ms += exec_ms;
                }
                self.current_task[pod.0 as usize] = None;
                self.pod_io[pod.0 as usize] = IoPhase::Idle;
                self.trace.finished(task, now);
                self.record_running(ttype, -1);
                self.task_running[task.0 as usize] -= 1;
                self.completed_by_type[ttype.0 as usize] += 1;
                // readiness propagation through the reusable scratch buffer
                let mut ready = std::mem::take(&mut self.ready_buf);
                ready.clear();
                self.engine.complete_into(task, &mut ready);
                self.dispatch_ready(&ready);
                self.ready_buf = ready;
                // fleet: per-instance completion + admission-slot release
                if self.fleet.is_some() {
                    self.instance_task_done(task);
                }
                // advance the pod
                match self.pods[pod.0 as usize].pool_id() {
                    None => {
                        self.batch_queue[pod.0 as usize].pop_front();
                        if let Some(&next) = self.batch_queue[pod.0 as usize].front() {
                            self.start_task(pod, next);
                        } else {
                            self.terminate_pod(pod, PodPhase::Succeeded);
                        }
                    }
                    Some(pool) => self.advance_worker(pod, pool),
                }
            }
            Ev::FlushTimer { type_idx, deadline } => {
                let batch = self
                    .batcher
                    .timer_fired(&self.engine.dag().types[type_idx as usize].name, deadline);
                if let Some(batch) = batch {
                    self.create_job(batch);
                }
            }
            Ev::NodeEvent { node, up } => {
                if up {
                    self.nodes[node].failed = false;
                    self.run_scheduler(); // capacity restored
                } else {
                    self.fail_node(node);
                }
            }
            Ev::InstanceArrive { inst } => {
                self.instance_arrive(inst as usize);
            }
            Ev::ChaosFault { proc_idx, node } => {
                self.apply_fault(proc_idx as usize, node);
                // lazy Poisson process: draw + schedule the next strike
                self.schedule_next_fault(proc_idx as usize);
            }
            Ev::ChaosReclaim { node, replace_ms } => {
                self.drain_pending[node] = false;
                if !self.nodes[node].failed {
                    self.chaos_stats.spot_reclaims += 1;
                    self.metrics.inc("spot_reclaims", 1);
                    self.fail_node_inner(node, true);
                    self.q
                        .schedule_in(SimTime::from_millis(replace_ms), Ev::ChaosRestore { node });
                }
                // if a crash beat the warning to it, the crash's own
                // restore will bring the replacement up
            }
            Ev::ChaosRestore { node } => {
                // replacement capacity: same slot, fresh incarnation
                self.node_incarnation[node] += 1;
                self.nodes[node].failed = false;
                self.nodes[node].cordoned = false;
                self.drain_pending[node] = false;
                self.blacklist_until[node] = SimTime::ZERO;
                self.node_fault_counts[node] = 0;
                // replacement hardware rolls the straggler dice again
                let resample = self.chaos.as_mut().and_then(|ch| {
                    ch.straggler
                        .map(|(frac, factor)| if ch.node_rng.f64() < frac { factor } else { 1.0 })
                });
                if let Some(slow) = resample {
                    self.node_slow[node] = slow;
                }
                self.update_chaos_quota();
                self.metrics.inc("nodes_restored", 1);
                self.run_scheduler();
            }
            Ev::ChaosUncordon { node } => {
                let now = self.now();
                if !self.nodes[node].failed
                    && !self.drain_pending[node]
                    && self.blacklist_until[node] <= now
                    && self.nodes[node].cordoned
                {
                    self.nodes[node].cordoned = false;
                    self.run_scheduler();
                }
            }
            Ev::ChaosRetryTask { task } => {
                if self.engine.state(task) == TaskState::Done {
                    return; // a speculative copy landed it in the meantime
                }
                if self.task_running[task.0 as usize] > 0 {
                    return; // a copy started while the back-off ran; it owns the work
                }
                let ttype = self.engine.dag().tasks[task.0 as usize].ttype;
                match self.pool_of_type[ttype.0 as usize] {
                    Some(pool) => {
                        self.broker.publish_for(pool, task, self.tenant_of(task));
                        self.record_queue_depth(pool);
                        self.wake_idle_worker(pool);
                    }
                    // defensive: a task of an unpooled type re-enters as a
                    // single-task job
                    None => self.create_job(vec![task]),
                }
            }
            Ev::ChaosRetryBatch { tasks } => {
                self.create_job(tasks);
            }
            Ev::SpecCheck { pod, task } => {
                // still running in this pod after spec_factor x nominal?
                if self.pods[pod.0 as usize].is_terminal()
                    || self.current_task[pod.0 as usize] != Some(task)
                    || self.engine.state(task) == TaskState::Done
                    || self.spec_launched[task.0 as usize]
                {
                    return;
                }
                self.spec_launched[task.0 as usize] = true;
                self.chaos_stats.speculations += 1;
                self.metrics.inc("speculative_copies", 1);
                let ttype = self.engine.dag().tasks[task.0 as usize].ttype;
                if let Some(pool) = self.pool_of_type[ttype.0 as usize] {
                    self.broker.publish_for(pool, task, self.tenant_of(task));
                    self.record_queue_depth(pool);
                    self.wake_idle_worker(pool);
                }
            }
            Ev::FlowActivate { flow, gen } => {
                let now = self.now();
                let mut buf = std::mem::take(&mut self.flow_buf);
                if let Some(dp) = &mut self.data {
                    dp.activate(now, flow, gen, &mut buf);
                }
                self.schedule_flow_events(buf);
            }
            Ev::FlowDone { flow, gen } => {
                let now = self.now();
                let mut buf = std::mem::take(&mut self.flow_buf);
                let done = self
                    .data
                    .as_mut()
                    .and_then(|dp| dp.flow_done(now, flow, gen, &mut buf));
                self.schedule_flow_events(buf);
                let Some(d) = done else { return };
                // a completing flow implies a live pod (kills cancel their
                // flows synchronously) — but stay defensive
                if self.pods[d.pod.0 as usize].is_terminal()
                    || self.current_task[d.pod.0 as usize] != Some(d.task)
                {
                    return;
                }
                if d.inbound {
                    self.start_task(d.pod, d.task);
                } else {
                    self.finish_task(d.pod, d.task);
                }
            }
            Ev::AutoscaleTick => {
                self.autoscale();
                if !self.engine.is_done() {
                    let poll = self
                        .scaler
                        .as_ref()
                        .map(|s| s.cfg.poll_ms)
                        .unwrap_or(15_000);
                    self.q
                        .schedule_in(SimTime::from_millis(poll), Ev::AutoscaleTick);
                }
            }
        }
    }
}

/// Construct the simulated world (cluster, control plane, pools, gauges)
/// for a workflow + execution model, returning the initially-ready tasks
/// for the caller to dispatch — at t=0 ([`run`]) or per instance arrival
/// ([`run_fleet`]).
fn build(dag: Dag, model: &ExecModel, cfg: SimConfig) -> (World, Vec<TaskId>) {
    let (engine, initial_ready) = Engine::new(dag);

    let batcher = match model {
        ExecModel::Clustered(c) => Batcher::new(c.clone()),
        _ => Batcher::new(ClusteringConfig::none()),
    };

    let n_types = engine.dag().types.len();
    // generic-pool pod template: max requests over every task type (§3.3's
    // "universal image" problem, resource-wise)
    let generic_requests = engine
        .dag()
        .types
        .iter()
        .fold(Resources::ZERO, |acc, t| Resources {
            cpu_m: acc.cpu_m.max(t.requests.cpu_m),
            mem_mb: acc.mem_mb.max(t.requests.mem_mb),
        });

    // Intern every pool up front: PoolId = declaration order, aligned with
    // the autoscaler's spec indices and the broker's queue indices.
    let mut broker = Broker::new();
    let mut pool_type: Vec<Option<TypeId>> = Vec::new();
    let mut pool_of_type: Vec<Option<PoolId>> = vec![None; n_types];
    let mut specs: Vec<PoolSpec> = Vec::new();
    match model {
        ExecModel::WorkerPools { pooled_types } => {
            for t in pooled_types {
                let ty = engine
                    .dag()
                    .type_id(t)
                    .unwrap_or_else(|| panic!("pooled type '{t}' not in workflow"));
                let id = broker.declare(t);
                assert_eq!(id.idx(), pool_type.len(), "duplicate pooled type '{t}'");
                pool_type.push(Some(ty));
                pool_of_type[ty.0 as usize] = Some(id);
                specs.push(PoolSpec {
                    name: t.clone(),
                    requests: engine.dag().types[ty.0 as usize].requests,
                });
            }
        }
        ExecModel::GenericPool => {
            let id = broker.declare(GENERIC_POOL);
            pool_type.push(None);
            for slot in pool_of_type.iter_mut() {
                *slot = Some(id);
            }
            specs.push(PoolSpec {
                name: GENERIC_POOL.to_string(),
                requests: generic_requests,
            });
        }
        _ => {}
    }
    let n_pools = pool_type.len();
    let scaler = (n_pools > 0).then(|| Autoscaler::new(cfg.autoscale.clone(), specs));

    let mut pools_by_name: Vec<PoolId> = (0..n_pools).map(|i| PoolId(i as u16)).collect();
    pools_by_name.sort_by(|a, b| broker.name(*a).cmp(broker.name(*b)));

    // pre-resolve the hot gauges (see §Perf)
    let mut metrics = Registry::new();
    let g_running = metrics.gauge_id("running_tasks");
    let g_cpu = metrics.gauge_id("cpu_allocated_m");
    let g_pending = metrics.gauge_id("pending_pods");
    let g_by_type: Vec<GaugeId> = engine
        .dag()
        .types
        .iter()
        .map(|t| metrics.gauge_id(&format!("running::{}", t.name)))
        .collect();
    let g_queue: Vec<GaugeId> = (0..n_pools)
        .map(|i| metrics.gauge_id(&format!("queue::{}", broker.name(PoolId(i as u16)))))
        .collect();
    let g_replicas: Vec<GaugeId> = (0..n_pools)
        .map(|i| metrics.gauge_id(&format!("replicas::{}", broker.name(PoolId(i as u16)))))
        .collect();

    let n_tasks = engine.dag().len();
    let chaos = ChaosRuntime::build(
        &cfg.chaos,
        cfg.pod_failure_prob,
        model,
        cfg.seed,
        cfg.autoscale.quota_cpu_m,
    );
    let chaos_enabled = chaos.is_some();
    // data plane: file tables + caches derived from the DAG's annotations
    let data = cfg
        .data
        .as_ref()
        .map(|dc| DataPlane::new(dc.clone(), engine.dag(), cfg.nodes));
    let task_out_pending = if data.is_some() {
        vec![false; n_tasks]
    } else {
        Vec::new()
    };
    // per-task chaos tables (healthy runs read work_left in start_task too,
    // so it always mirrors the DAG durations)
    let task_work_left: Vec<SimTime> = engine.dag().tasks.iter().map(|t| t.duration).collect();

    let mut world = World {
        chaos,
        chaos_stats: ChaosStats {
            enabled: chaos_enabled,
            ..Default::default()
        },
        node_slow: vec![1.0; cfg.nodes],
        node_incarnation: vec![0; cfg.nodes],
        node_fault_counts: vec![0; cfg.nodes],
        drain_pending: vec![false; cfg.nodes],
        blacklist_until: vec![SimTime::ZERO; cfg.nodes],
        pod_bound_inc: Vec::new(),
        pod_task_started_at: Vec::new(),
        task_work_left,
        task_attempts: vec![0; n_tasks],
        task_fault_at: vec![NO_FAULT; n_tasks],
        spec_launched: vec![false; n_tasks],
        task_running: vec![0; n_tasks],
        nodes: paper_cluster(cfg.nodes),
        sched: Scheduler::new(cfg.sched.clone()),
        api: ApiServer::new(cfg.api.clone()),
        engine,
        batcher,
        broker,
        scaler,
        deployments: vec![Vec::new(); n_pools],
        idle_workers: vec![VecDeque::new(); n_pools],
        pool_type,
        pool_of_type,
        pools_by_name,
        batch_queue: Vec::new(),
        current_task: Vec::new(),
        throttle_wait: VecDeque::new(),
        jobs_in_flight: 0,
        generic_requests,
        metrics,
        trace: Trace::new(),
        running_tasks: 0,
        pending_count: 0,
        completed_by_type: vec![0; n_types],
        data,
        pod_io: Vec::new(),
        pod_exec_ms: Vec::new(),
        task_out_pending,
        flow_buf: Vec::new(),
        fleet: None,
        task_instance: Vec::new(),
        task_tenant: Vec::new(),
        g_running,
        g_cpu,
        g_pending,
        g_by_type,
        g_queue,
        g_replicas,
        q: EventQueue::new(),
        pods: Vec::new(),
        ready_buf: Vec::new(),
        pass_buf: SchedulePass::default(),
        members_buf: Vec::new(),
        idle_buf: Vec::new(),
        backlog_buf: Vec::new(),
        current_buf: Vec::new(),
        desired_buf: Vec::new(),
        cfg,
    };

    world.metrics.set_id(world.g_running, SimTime::ZERO, 0.0);
    // schedule the configured node failures (moved out and back rather
    // than cloning the whole Vec per run)
    let node_events = std::mem::take(&mut world.cfg.node_events);
    for &(at_ms, node, up) in &node_events {
        assert!(node < world.nodes.len(), "node event for unknown node {node}");
        world
            .q
            .schedule_at(SimTime::from_millis(at_ms), Ev::NodeEvent { node, up });
    }
    world.cfg.node_events = node_events;
    // chaos: sample the straggler table and arm every timed injector
    let straggler = world.chaos.as_ref().and_then(|c| c.straggler);
    if let Some((frac, factor)) = straggler {
        let n = world.nodes.len();
        let slow = {
            let ch = world.chaos.as_mut().expect("chaos runtime");
            sample_node_slowdowns(n, frac, factor, &mut ch.node_rng)
        };
        world.node_slow = slow;
    }
    let n_processes = world.chaos.as_ref().map(|c| c.processes.len()).unwrap_or(0);
    for i in 0..n_processes {
        world.schedule_next_fault(i);
    }
    (world, initial_ready)
}

/// Pump the event loop until every workflow task completed (or the wall
/// cap fires); returns the makespan and the processed event count.
fn drive(world: &mut World) -> (SimTime, u64) {
    let max_ms = (world.cfg.max_sim_s * 1000.0) as u64;
    let mut makespan = SimTime::ZERO;
    let mut sim_events: u64 = 0;
    while let Some((t, ev)) = world.q.pop() {
        if t.as_millis() > max_ms {
            log::warn!(
                "simulation wall cap hit at {t} with {} tasks outstanding",
                world.engine.n_outstanding()
            );
            break;
        }
        sim_events += 1;
        world.handle(ev);
        if world.engine.is_done() {
            makespan = world.q.now();
            break;
        }
    }
    assert!(
        world.engine.is_done(),
        "simulation ended with {} of {} tasks incomplete (deadlock?)",
        world.engine.n_outstanding(),
        world.engine.dag().len()
    );
    (makespan, sim_events)
}

/// Fold the finished world into a [`SimResult`].
fn summarize(world: World, model_name: String, makespan: SimTime, sim_events: u64) -> SimResult {
    let t_end = makespan.as_secs_f64();
    let avg_running = world
        .metrics
        .gauge("running_tasks")
        .map(|s| s.time_average(0.0, t_end))
        .unwrap_or(0.0);
    let total_cpu = world.cfg.nodes as f64 * 4_000.0;
    let avg_cpu = world
        .metrics
        .gauge("cpu_allocated_m")
        .map(|s| s.time_average(0.0, t_end) / total_cpu)
        .unwrap_or(0.0);

    SimResult {
        model_name,
        makespan,
        data: world
            .data
            .as_ref()
            .map(|d| d.report())
            .unwrap_or_default(),
        pods_created: world.metrics.counter("pods_created"),
        api_requests: world.api.requests_total,
        sched_backoffs: world.sched.backoffs_total,
        sched_binds: world.sched.binds_total,
        sim_events,
        avg_running_tasks: avg_running,
        avg_cpu_utilization: avg_cpu,
        chaos: world.chaos_stats.report(),
        trace: world.trace,
        metrics: world.metrics,
    }
}

/// Run a workflow under an execution model on the simulated cluster.
pub fn run(dag: Dag, model: ExecModel, cfg: SimConfig) -> SimResult {
    let model_name = model.name().to_string();
    let (mut world, initial_ready) = build(dag, &model, cfg);
    world.dispatch_ready(&initial_ready);
    if world.scaler.is_some() {
        // first poll fires quickly so pools can start warming up
        world
            .q
            .schedule_in(SimTime::from_millis(1_000), Ev::AutoscaleTick);
    }
    let (makespan, sim_events) = drive(&mut world);
    summarize(world, model_name, makespan, sim_events)
}

/// Run an open-loop fleet of workflow instances on one shared cluster.
///
/// `dag` is the [`Dag::disjoint_union`] of every instance; `plan` maps
/// each instance to its contiguous task range, tenant, and arrival time,
/// and carries the tenant fair-share weights plus the admission cap. Each
/// instance's root tasks are dispatched when the instance is *admitted*
/// (at arrival, or when a slot frees under the cap); everything downstream
/// — readiness, batching, pools, autoscaling — is the single-run
/// machinery operating on the aggregate workload. Returns the overall
/// [`SimResult`] plus one [`InstanceOutcome`] per instance (same order as
/// `plan.instances`), from which per-tenant SLO statistics are derived by
/// [`crate::fleet::report`].
pub fn run_fleet(
    dag: Dag,
    model: ExecModel,
    cfg: SimConfig,
    plan: &FleetPlan,
) -> (SimResult, Vec<InstanceOutcome>) {
    let model_name = format!("fleet/{}", model.name());
    let n_tasks = dag.len();
    // validate the plan: contiguous instance ranges covering the union DAG
    assert!(!plan.tenant_weights.is_empty(), "at least one tenant");
    assert!(
        plan.max_in_flight != Some(0),
        "admission cap of 0 would never admit an instance"
    );
    let mut expect = 0u32;
    for s in &plan.instances {
        assert_eq!(s.first_task, expect, "instance ranges must be contiguous");
        assert!(s.n_tasks > 0, "empty workflow instance");
        assert!(
            (s.tenant as usize) < plan.tenant_weights.len(),
            "instance tenant {} has no weight entry",
            s.tenant
        );
        expect += s.n_tasks;
    }
    assert_eq!(expect as usize, n_tasks, "instance ranges must cover the DAG");

    let (mut world, initial_ready) = build(dag, &model, cfg);
    world.broker.set_tenant_weights(&plan.tenant_weights);
    // per-tenant resilience accounting (wasted work / retries per lane)
    world.chaos_stats.set_tenants(plan.tenant_weights.len());
    // per-tenant bytes-moved lanes for the data plane, when enabled
    if let Some(dp) = &mut world.data {
        dp.stats.set_tenants(plan.tenant_weights.len());
    }

    // per-task instance/tenant tables (the disjoint-union offset scheme)
    let mut task_instance = vec![0u32; n_tasks];
    let mut task_tenant = vec![0u16; n_tasks];
    for (i, s) in plan.instances.iter().enumerate() {
        let range = s.first_task as usize..(s.first_task + s.n_tasks) as usize;
        task_instance[range.clone()].fill(i as u32);
        task_tenant[range].fill(s.tenant);
    }
    // hold each instance's roots back until it is admitted
    let mut roots: Vec<Vec<TaskId>> = vec![Vec::new(); plan.instances.len()];
    for &t in &initial_ready {
        roots[task_instance[t.0 as usize] as usize].push(t);
    }
    world.task_instance = task_instance;
    world.task_tenant = task_tenant;
    world.fleet = Some(FleetState {
        outstanding: plan.instances.iter().map(|s| s.n_tasks).collect(),
        roots,
        admitted_at: vec![None; plan.instances.len()],
        finished_at: vec![None; plan.instances.len()],
        waiting: VecDeque::new(),
        in_flight: 0,
        max_in_flight: plan.max_in_flight,
    });
    for (i, s) in plan.instances.iter().enumerate() {
        world.q.schedule_at(
            SimTime::from_millis(s.arrival_ms),
            Ev::InstanceArrive { inst: i as u32 },
        );
    }
    if world.scaler.is_some() {
        world
            .q
            .schedule_in(SimTime::from_millis(1_000), Ev::AutoscaleTick);
    }

    let (makespan, sim_events) = drive(&mut world);

    let fs = world.fleet.take().expect("fleet state");
    debug_assert!(fs.waiting.is_empty() && fs.in_flight == 0);
    let outcomes = plan
        .instances
        .iter()
        .enumerate()
        .map(|(i, s)| InstanceOutcome {
            tenant: s.tenant,
            arrival: SimTime::from_millis(s.arrival_ms),
            admitted: fs.admitted_at[i].expect("instance never admitted"),
            finished: fs.finished_at[i].expect("instance never finished"),
            n_tasks: s.n_tasks,
        })
        .collect();
    (summarize(world, model_name, makespan, sim_events), outcomes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workflow::montage::{generate, MontageConfig};

    fn small_dag() -> Dag {
        generate(&MontageConfig {
            grid_w: 3,
            grid_h: 3,
            diagonals: true,
            seed: 1,
        })
    }

    #[test]
    fn job_based_completes_small_workflow() {
        let res = run(small_dag(), ExecModel::JobBased, SimConfig::with_nodes(4));
        assert!(res.makespan > SimTime::ZERO);
        // every task got its own pod
        assert_eq!(res.pods_created as usize, small_dag().len());
        assert!(res.avg_running_tasks > 0.0);
        assert!(res.sim_events > 0);
    }

    #[test]
    fn clustered_uses_fewer_pods() {
        let dag = small_dag();
        let n = dag.len();
        let res = run(
            dag,
            ExecModel::Clustered(ClusteringConfig::paper_default()),
            SimConfig::with_nodes(4),
        );
        assert!(
            (res.pods_created as usize) < n,
            "clustering must reduce pod count: {} vs {n}",
            res.pods_created
        );
    }

    #[test]
    fn worker_pools_completes() {
        let res = run(
            small_dag(),
            ExecModel::paper_hybrid_pools(),
            SimConfig::with_nodes(4),
        );
        assert!(res.makespan > SimTime::ZERO);
        assert!(res.avg_running_tasks > 0.0);
    }

    #[test]
    fn all_tasks_traced_exactly_once() {
        for model in [
            ExecModel::JobBased,
            ExecModel::Clustered(ClusteringConfig::paper_default()),
            ExecModel::paper_hybrid_pools(),
        ] {
            let dag = small_dag();
            let n = dag.len();
            let res = run(dag, model, SimConfig::with_nodes(4));
            assert_eq!(res.trace.records.len(), n);
            for r in &res.trace.records {
                assert!(r.started_at.is_some(), "{:?} never started", r.task);
                assert!(r.finished_at.is_some(), "{:?} never finished", r.task);
                assert!(r.started_at.unwrap() >= r.ready_at);
                assert!(r.finished_at.unwrap() > r.started_at.unwrap());
            }
        }
    }

    #[test]
    fn dependencies_respected_in_trace() {
        let dag = small_dag();
        let succs: Vec<(TaskId, Vec<TaskId>)> = (0..dag.len())
            .map(|i| {
                let t = TaskId(i as u32);
                (t, dag.successors(t).to_vec())
            })
            .collect();
        let res = run(dag, ExecModel::JobBased, SimConfig::with_nodes(4));
        for (t, ss) in succs {
            let t_fin = res.trace.record(t).unwrap().finished_at.unwrap();
            for s in ss {
                let s_start = res.trace.record(s).unwrap().started_at.unwrap();
                assert!(
                    s_start >= t_fin,
                    "dependency violated: {s:?} started before {t:?} finished"
                );
            }
        }
    }

    #[test]
    fn pools_beat_plain_jobs_on_parallel_stage_heavy_workflow() {
        let mk = || {
            generate(&MontageConfig {
                grid_w: 6,
                grid_h: 6,
                diagonals: true,
                seed: 2,
            })
        };
        let jobs = run(mk(), ExecModel::JobBased, SimConfig::with_nodes(4));
        let pools = run(mk(), ExecModel::paper_hybrid_pools(), SimConfig::with_nodes(4));
        assert!(
            pools.makespan < jobs.makespan,
            "pools {} vs jobs {}",
            pools.makespan,
            jobs.makespan
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let a = run(small_dag(), ExecModel::JobBased, SimConfig::with_nodes(4));
        let b = run(small_dag(), ExecModel::JobBased, SimConfig::with_nodes(4));
        assert_eq!(a.makespan, b.makespan);
        assert_eq!(a.pods_created, b.pods_created);
        assert_eq!(a.api_requests, b.api_requests);
    }

    #[test]
    fn generic_pool_completes_but_wastes_resources() {
        // wide parallel stages: the generic pod template (max requests over
        // all types = mAdd's 2000m) halves the worker slots (§3.3)
        let mk = || {
            generate(&MontageConfig {
                grid_w: 10,
                grid_h: 10,
                diagonals: true,
                seed: 4,
            })
        };
        let dag = mk();
        let n = dag.len();
        let generic = run(dag, ExecModel::GenericPool, SimConfig::with_nodes(4));
        assert_eq!(generic.trace.records.len(), n);
        let typed = run(
            mk(),
            ExecModel::WorkerPools {
                pooled_types: crate::workflow::montage::TYPE_NAMES
                    .iter()
                    .map(|s| s.to_string())
                    .collect(),
            },
            SimConfig::with_nodes(4),
        );
        assert!(
            typed.makespan < generic.makespan,
            "typed {} vs generic {}",
            typed.makespan,
            generic.makespan
        );
    }

    #[test]
    fn job_throttle_cuts_backoffs_and_makespan() {
        // §5 future work: "improvement of the job queuing mechanism in the
        // job-based model to reduce the number of requested Pods, thus
        // mitigating the main flaw of the model" — confirmed.
        let mk = || {
            generate(&MontageConfig {
                grid_w: 8,
                grid_h: 8,
                diagonals: true,
                seed: 4,
            })
        };
        let mut throttled_cfg = SimConfig::with_nodes(4);
        throttled_cfg.max_pending_pods = Some(8);
        let throttled = run(mk(), ExecModel::JobBased, throttled_cfg);
        let unthrottled = run(mk(), ExecModel::JobBased, SimConfig::with_nodes(4));
        assert_eq!(throttled.trace.records.len(), mk().len());
        assert!(
            throttled.sched_backoffs < unthrottled.sched_backoffs / 2,
            "throttle should slash back-offs: {} vs {}",
            throttled.sched_backoffs,
            unthrottled.sched_backoffs
        );
        assert!(
            throttled.makespan <= unthrottled.makespan,
            "throttle should not slow the run: {} vs {}",
            throttled.makespan,
            unthrottled.makespan
        );
        assert!(throttled.metrics.counter("throttled_batches") > 0);
    }

    #[test]
    fn vpa_rightsizing_speeds_up_pools() {
        // §5 future work: with VPA, workers request observed usage
        // (mDiffFit 300m vs 500m requested) -> more fit per node
        let mk = || {
            generate(&MontageConfig {
                grid_w: 14,
                grid_h: 14,
                diagonals: true,
                seed: 6,
            })
        };
        let mut vpa_cfg = SimConfig::with_nodes(4);
        vpa_cfg.autoscale.vpa = true;
        let with_vpa = run(mk(), ExecModel::paper_hybrid_pools(), vpa_cfg);
        let without = run(mk(), ExecModel::paper_hybrid_pools(), SimConfig::with_nodes(4));
        assert_eq!(with_vpa.trace.records.len(), mk().len());
        assert!(
            with_vpa.makespan < without.makespan,
            "VPA {} vs {}",
            with_vpa.makespan,
            without.makespan
        );
        // capacity still never exceeded
        let cap = 4.0 * 4000.0;
        for &(_, v) in with_vpa.metrics.gauge("cpu_allocated_m").unwrap().points() {
            assert!(v <= cap + 1e-9);
        }
    }

    #[test]
    fn node_failure_recovers_all_tasks() {
        for model in [
            ExecModel::JobBased,
            ExecModel::Clustered(ClusteringConfig::paper_default()),
            ExecModel::paper_hybrid_pools(),
        ] {
            let dag = small_dag();
            let n = dag.len();
            let mut cfg = SimConfig::with_nodes(4);
            // node 0 dies mid-run, comes back much later
            cfg.node_events = vec![(30_000, 0, false), (200_000, 0, true)];
            let res = run(dag, model.clone(), cfg);
            assert_eq!(res.trace.records.len(), n, "{}", model.name());
            assert!(res.metrics.counter("node_failures") == 1);
            for r in &res.trace.records {
                assert!(r.finished_at.is_some(), "{:?} lost", r.task);
            }
        }
    }

    fn two_instance_plan(n_a: u32, n_b: u32, arrival_b_ms: u64, cap: Option<usize>) -> FleetPlan {
        FleetPlan {
            instances: vec![
                crate::fleet::InstanceSpec {
                    tenant: 0,
                    arrival_ms: 0,
                    first_task: 0,
                    n_tasks: n_a,
                },
                crate::fleet::InstanceSpec {
                    tenant: 1,
                    arrival_ms: arrival_b_ms,
                    first_task: n_a,
                    n_tasks: n_b,
                },
            ],
            tenant_weights: vec![1, 1],
            max_in_flight: cap,
        }
    }

    #[test]
    fn fleet_two_instances_complete_concurrently() {
        let (a, b) = (small_dag(), small_dag());
        let (n_a, n_b) = (a.len() as u32, b.len() as u32);
        let union = Dag::disjoint_union(&[a, b]);
        let plan = two_instance_plan(n_a, n_b, 30_000, None);
        let (res, outcomes) = run_fleet(
            union,
            ExecModel::paper_hybrid_pools(),
            SimConfig::with_nodes(4),
            &plan,
        );
        assert_eq!(res.trace.records.len(), (n_a + n_b) as usize);
        assert_eq!(outcomes.len(), 2);
        for o in &outcomes {
            assert!(o.admitted >= o.arrival, "admitted before arrival");
            assert!(o.finished > o.admitted, "finished before admitted");
        }
        // no cap: admission is immediate at arrival
        assert_eq!(outcomes[0].admitted, SimTime::ZERO);
        assert_eq!(outcomes[1].admitted, SimTime::from_millis(30_000));
        // the second instance overlaps the first (shared cluster, not serial)
        assert!(outcomes[1].admitted < outcomes[0].finished);
    }

    #[test]
    fn fleet_admission_cap_serializes_instances() {
        let (a, b) = (small_dag(), small_dag());
        let (n_a, n_b) = (a.len() as u32, b.len() as u32);
        let union = Dag::disjoint_union(&[a, b]);
        let plan = two_instance_plan(n_a, n_b, 30_000, Some(1));
        let (res, outcomes) = run_fleet(
            union,
            ExecModel::paper_hybrid_pools(),
            SimConfig::with_nodes(4),
            &plan,
        );
        assert_eq!(res.trace.records.len(), (n_a + n_b) as usize);
        // cap 1: the second instance waits for the first to finish
        assert!(outcomes[1].admitted >= outcomes[0].finished);
        assert!(outcomes[1].admitted > outcomes[1].arrival, "queued at the cap");
        assert_eq!(res.metrics.counter("instances_admitted"), 2);
        assert_eq!(res.metrics.counter("instances_completed"), 2);
    }

    #[test]
    fn fleet_works_under_every_model() {
        for model in [
            ExecModel::JobBased,
            ExecModel::Clustered(ClusteringConfig::paper_default()),
            ExecModel::paper_hybrid_pools(),
            ExecModel::GenericPool,
        ] {
            let (a, b) = (small_dag(), small_dag());
            let (n_a, n_b) = (a.len() as u32, b.len() as u32);
            let union = Dag::disjoint_union(&[a, b]);
            let plan = two_instance_plan(n_a, n_b, 10_000, None);
            let (res, outcomes) =
                run_fleet(union, model.clone(), SimConfig::with_nodes(4), &plan);
            assert_eq!(
                res.trace.records.len(),
                (n_a + n_b) as usize,
                "{}",
                model.name()
            );
            assert!(outcomes.iter().all(|o| o.finished > o.admitted));
        }
    }

    #[test]
    fn chaos_every_model_completes_under_heavy_churn() {
        // spot reclaims, crashes, flaky pod starts and stragglers all at
        // once: every model must still finish every task exactly once,
        // and the accounting must show the faults actually happened.
        for model in [
            ExecModel::JobBased,
            ExecModel::Clustered(ClusteringConfig::paper_default()),
            ExecModel::paper_hybrid_pools(),
            ExecModel::GenericPool,
        ] {
            let dag = generate(&MontageConfig {
                grid_w: 5,
                grid_h: 5,
                diagonals: true,
                seed: 3,
            });
            let n = dag.len();
            let mut cfg = SimConfig::with_nodes(4);
            cfg.seed = 9;
            cfg.chaos =
                crate::chaos::ChaosConfig::parse_spec("spot:4,crash:2,pod:0.25,straggler:0.3")
                    .unwrap();
            let res = run(dag, model.clone(), cfg);
            let name = model.name();
            assert_eq!(res.trace.records.len(), n, "{name}: records");
            for r in &res.trace.records {
                assert!(r.finished_at.is_some(), "{name}: {:?} lost", r.task);
            }
            assert!(res.chaos.enabled, "{name}");
            assert!(res.chaos.faults_total() > 0, "{name}: no faults injected");
            assert!(res.chaos.wasted_ms > 0, "{name}: no waste accounted");
            assert!(res.chaos.goodput() < 1.0, "{name}: goodput must dip");
            assert!(res.chaos.goodput() > 0.0, "{name}");
        }
    }

    #[test]
    fn chaos_spot_churn_inflates_makespan() {
        let mk = || {
            generate(&MontageConfig {
                grid_w: 6,
                grid_h: 6,
                diagonals: true,
                seed: 2,
            })
        };
        let healthy = run(mk(), ExecModel::paper_hybrid_pools(), SimConfig::with_nodes(4));
        let mut cfg = SimConfig::with_nodes(4);
        cfg.seed = 5;
        cfg.chaos = crate::chaos::ChaosConfig::parse_spec("spot:6,crash:3").unwrap();
        let churned = run(mk(), ExecModel::paper_hybrid_pools(), cfg);
        assert!(
            churned.makespan > healthy.makespan,
            "churn {} vs healthy {}",
            churned.makespan,
            healthy.makespan
        );
        assert!(healthy.chaos.wasted_ms == 0 && !healthy.chaos.enabled);
    }

    #[test]
    fn legacy_pod_failure_prob_is_migrated_onto_the_chaos_engine() {
        // the deprecated knob must keep injecting failures — now routed
        // through the PodFailure injector with waste + retry accounting
        let dag = small_dag();
        let n = dag.len();
        let mut cfg = SimConfig::with_nodes(4);
        cfg.pod_failure_prob = 0.3;
        cfg.seed = 13;
        let res = run(dag, ExecModel::JobBased, cfg);
        assert_eq!(res.trace.records.len(), n);
        assert!(res.metrics.counter("pod_failures") > 0);
        assert!(res.chaos.enabled, "legacy knob must enable the subsystem");
        assert_eq!(
            res.chaos.pod_failures,
            res.metrics.counter("pod_failures"),
            "chaos accounting mirrors the metric"
        );
        assert!(res.chaos.retries > 0, "failed batches are retried");
        assert!(res.chaos.wasted_ms > 0, "burned pod starts are waste");
    }

    #[test]
    fn fleet_under_chaos_drains_and_stamps_every_instance() {
        // regression (fleet accounting under retries): per-instance
        // outstanding counters must not drift when tasks fail and re-enter
        // the queue — a faulty fleet run still drains, and every instance
        // gets admission + completion stamps. (run_fleet panics on any
        // unstamped instance.)
        let (a, b) = (small_dag(), small_dag());
        let (n_a, n_b) = (a.len() as u32, b.len() as u32);
        let union = Dag::disjoint_union(&[a, b]);
        let plan = two_instance_plan(n_a, n_b, 20_000, None);
        let mut cfg = SimConfig::with_nodes(4);
        cfg.seed = 21;
        cfg.chaos =
            crate::chaos::ChaosConfig::parse_spec("pod:0.25,crash:6,straggler:0.5").unwrap();
        let (res, outcomes) = run_fleet(union, ExecModel::paper_hybrid_pools(), cfg, &plan);
        assert_eq!(outcomes.len(), 2);
        for o in &outcomes {
            assert!(o.finished > o.admitted);
        }
        assert_eq!(res.metrics.counter("instances_completed"), 2);
        assert_eq!(res.trace.records.len(), (n_a + n_b) as usize);
        assert!(res.chaos.faults_total() > 0, "churn must actually occur");
        // per-tenant resilience lanes are sized; task-attributable waste
        // lands in them, shared worker-crash waste only in the total
        assert_eq!(res.chaos.wasted_ms_by_tenant.len(), 2);
        assert!(
            res.chaos.wasted_ms_by_tenant.iter().sum::<u64>() <= res.chaos.wasted_ms,
            "lanes cannot exceed the total"
        );
    }

    fn data_cfg(nodes: usize, spec: &str) -> SimConfig {
        let mut cfg = SimConfig::with_nodes(nodes);
        cfg.data = Some(crate::data::DataConfig::parse_spec(spec).unwrap());
        cfg
    }

    #[test]
    fn data_plane_every_model_completes_and_accounts_bytes() {
        for model in [
            ExecModel::JobBased,
            ExecModel::Clustered(ClusteringConfig::paper_default()),
            ExecModel::paper_hybrid_pools(),
            ExecModel::GenericPool,
        ] {
            let dag = small_dag();
            let n = dag.len();
            let res = run(dag, model.clone(), data_cfg(4, "nfs:1,cache:4"));
            let name = model.name();
            assert_eq!(res.trace.records.len(), n, "{name}: records");
            for r in &res.trace.records {
                assert!(r.finished_at.is_some(), "{name}: {:?} lost", r.task);
                assert!(r.started_at.unwrap() >= r.ready_at, "{name}");
                assert!(r.finished_at.unwrap() > r.started_at.unwrap(), "{name}");
            }
            assert!(res.data.enabled, "{name}");
            assert!(res.data.bytes_in > 0, "{name}: no stage-in traffic");
            assert!(res.data.bytes_out > 0, "{name}: no stage-out traffic");
            assert!(res.data.transfers > 0, "{name}");
            assert!(res.data.compute_ms > 0, "{name}");
            assert!(res.data.io_ms > 0, "{name}: transfers must take time");
            // every task stages in exactly once on a healthy run
            assert_eq!(res.data.stage_ins, n, "{name}");
        }
    }

    #[test]
    fn data_plane_slows_the_run_and_the_default_stays_inert() {
        let base = SimConfig::with_nodes(4);
        assert!(base.data.is_none(), "data plane must be opt-in");
        let plain = run(small_dag(), ExecModel::paper_hybrid_pools(), base);
        assert!(!plain.data.enabled);
        assert_eq!(plain.data.bytes_in, 0);
        // a constrained shared link must cost wall-clock time
        let with_data = run(
            small_dag(),
            ExecModel::paper_hybrid_pools(),
            data_cfg(4, "nfs:0.5,cache:4"),
        );
        assert!(
            with_data.makespan > plain.makespan,
            "I/O pressure must show up: {} vs {}",
            with_data.makespan,
            plain.makespan
        );
    }

    #[test]
    fn warm_pool_caches_beat_cold_job_pods_on_bytes_and_stage_in() {
        // the ISSUE's acceptance asymmetry: long-lived workers keep their
        // node-local caches across tasks, job pods always start cold — at
        // constrained NFS bandwidth pools move fewer bytes and collapse
        // the stage-in tail.
        let mk = || {
            generate(&MontageConfig {
                grid_w: 6,
                grid_h: 6,
                diagonals: true,
                seed: 2,
            })
        };
        let jobs = run(mk(), ExecModel::JobBased, data_cfg(4, "nfs:0.5,cache:8"));
        let pools = run(
            mk(),
            ExecModel::paper_hybrid_pools(),
            data_cfg(4, "nfs:0.5,cache:8"),
        );
        assert!(
            pools.data.bytes_in < jobs.data.bytes_in,
            "pools {} vs jobs {} bytes in",
            pools.data.bytes_in,
            jobs.data.bytes_in
        );
        assert!(
            pools.data.cache_hit_ratio() > jobs.data.cache_hit_ratio(),
            "pools {:.3} vs jobs {:.3} hit ratio",
            pools.data.cache_hit_ratio(),
            jobs.data.cache_hit_ratio()
        );
        assert!(
            pools.data.stage_in_p95_s <= jobs.data.stage_in_p95_s,
            "pools {:.2}s vs jobs {:.2}s stage-in p95",
            pools.data.stage_in_p95_s,
            jobs.data.stage_in_p95_s
        );
    }

    #[test]
    fn locality_scheduling_completes_and_reproduces() {
        // clustered batches are the placement-sensitive case: producers
        // may still be alive when consumers schedule
        let mk = || {
            let mut cfg = data_cfg(4, "nfs:1,cache:8,locality:on");
            cfg.seed = 3;
            run(
                generate(&MontageConfig {
                    grid_w: 5,
                    grid_h: 5,
                    diagonals: true,
                    seed: 3,
                }),
                ExecModel::Clustered(ClusteringConfig::paper_default()),
                cfg,
            )
        };
        let (a, b) = (mk(), mk());
        assert_eq!(a.trace.records.len(), b.trace.records.len());
        assert_eq!(a.makespan, b.makespan, "locality run must reproduce");
        assert_eq!(a.data.bytes_in, b.data.bytes_in);
        assert_eq!(a.sched_binds, b.sched_binds);
        for r in &a.trace.records {
            assert!(r.finished_at.is_some(), "{:?} lost under locality", r.task);
        }
    }

    #[test]
    fn data_plane_survives_chaos_churn() {
        // node crashes kill in-flight transfers and wipe node caches
        // (crash-loses-cache); every task must still complete exactly once
        for model in [ExecModel::paper_hybrid_pools(), ExecModel::JobBased] {
            let dag = generate(&MontageConfig {
                grid_w: 5,
                grid_h: 5,
                diagonals: true,
                seed: 4,
            });
            let n = dag.len();
            let mut cfg = data_cfg(4, "nfs:1,cache:4");
            cfg.seed = 9;
            cfg.chaos =
                crate::chaos::ChaosConfig::parse_spec("crash:4,pod:0.15").unwrap();
            let res = run(dag, model.clone(), cfg);
            let name = model.name();
            assert_eq!(res.trace.records.len(), n, "{name}");
            for r in &res.trace.records {
                assert!(r.finished_at.is_some(), "{name}: {:?} lost", r.task);
            }
            assert!(res.chaos.faults_total() > 0, "{name}: churn must occur");
            assert!(res.data.bytes_in > 0, "{name}");
            // interrupted stage-ins re-run, so there can be more stage-in
            // samples than tasks — never fewer
            assert!(res.data.stage_ins >= n, "{name}");
        }
    }

    #[test]
    fn fleet_with_data_fills_tenant_byte_lanes() {
        let (a, b) = (small_dag(), small_dag());
        let (n_a, n_b) = (a.len() as u32, b.len() as u32);
        let union = Dag::disjoint_union(&[a, b]);
        let plan = two_instance_plan(n_a, n_b, 20_000, None);
        let (res, outcomes) = run_fleet(
            union,
            ExecModel::paper_hybrid_pools(),
            data_cfg(4, "nfs:1,cache:4"),
            &plan,
        );
        assert_eq!(outcomes.len(), 2);
        for o in &outcomes {
            assert!(o.finished > o.admitted);
        }
        assert_eq!(res.data.bytes_by_tenant.len(), 2);
        assert!(res.data.bytes_by_tenant.iter().all(|&b| b > 0));
        // every moved byte belongs to some tenant's instance
        assert_eq!(
            res.data.bytes_by_tenant.iter().sum::<u64>(),
            res.data.bytes_in + res.data.bytes_out
        );
    }

    #[test]
    fn nodes_never_overcommitted() {
        // run and assert the cpu_allocated series never exceeds capacity
        let res = run(
            small_dag(),
            ExecModel::paper_hybrid_pools(),
            SimConfig::with_nodes(3),
        );
        let cap = 3.0 * 4000.0;
        let s = res.metrics.gauge("cpu_allocated_m").unwrap();
        for &(_, v) in s.points() {
            assert!(v <= cap + 1e-9, "allocated {v} exceeds capacity {cap}");
        }
    }
}
