//! Back-compat shim: the 2.8k-line simulation driver that used to live
//! here was decomposed into the layered [`crate::exec`] subsystem —
//! kernel ([`crate::exec::kernel`]), pluggable model strategies
//! ([`crate::exec::strategy`] + one module per paper model), and
//! subsystem hooks ([`crate::exec::hooks`]). The public entry points are
//! re-exported so every existing `models::driver::{run, run_fleet,
//! SimConfig}` call site (tests, benches, examples, configs) keeps
//! working unchanged.

pub use crate::exec::{run, run_fleet, ConfigError, SimConfig, SimConfigBuilder};
