//! Execution models for scientific workflows on Kubernetes (§3).
//!
//! * [`ExecModel::JobBased`] — every task is a Kubernetes Job (§3.2).
//! * [`ExecModel::Clustered`] — jobs with HyperFlow task clustering (§3.2/3.5).
//! * [`ExecModel::WorkerPools`] — auto-scalable per-type worker pools fed by
//!   queues (§3.3/3.5). The paper's experiments use the *hybrid* variant
//!   (pools for the three parallel stages, jobs for the serial tail), which
//!   is the default here.
//!
//! [`driver`] hosts the discrete-event simulation binding an execution
//! model to the Kubernetes substrate (scheduler + API server + autoscaler +
//! broker) and the HyperFlow engine.

pub mod driver;
pub mod multicloud;

use crate::engine::clustering::ClusteringConfig;

/// Which execution model a run uses.
#[derive(Debug, Clone)]
pub enum ExecModel {
    /// §3.2: one task -> one Kubernetes Job -> one Pod.
    JobBased,
    /// §3.2 + clustering: batches of same-type tasks per pod.
    Clustered(ClusteringConfig),
    /// §3.3: worker pools for `pooled_types`; other types run as jobs
    /// (the paper's hybrid setup). Set `pooled_types` to all types for the
    /// pure pool model.
    WorkerPools { pooled_types: Vec<String> },
    /// §3.3's rejected alternative: a single generic worker pool for ALL
    /// task types. "Inferior both conceptually and technically": the pod
    /// template must request the max resources over every type (degrading
    /// scheduling quality) and implies one universal container image.
    /// Implemented to quantify exactly that degradation.
    GenericPool,
}

impl ExecModel {
    pub fn name(&self) -> &'static str {
        match self {
            ExecModel::JobBased => "job-based",
            ExecModel::Clustered(_) => "job-clustered",
            ExecModel::WorkerPools { .. } => "worker-pools",
            ExecModel::GenericPool => "generic-pool",
        }
    }

    /// The hybrid worker-pools setup used in §4.4: pools for the three
    /// parallel stages, jobs for everything else.
    pub fn paper_hybrid_pools() -> Self {
        ExecModel::WorkerPools {
            pooled_types: vec![
                "mProject".to_string(),
                "mDiffFit".to_string(),
                "mBackground".to_string(),
            ],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names() {
        assert_eq!(ExecModel::JobBased.name(), "job-based");
        assert_eq!(
            ExecModel::Clustered(ClusteringConfig::paper_default()).name(),
            "job-clustered"
        );
        assert_eq!(ExecModel::paper_hybrid_pools().name(), "worker-pools");
    }

    #[test]
    fn hybrid_pools_cover_parallel_stages() {
        if let ExecModel::WorkerPools { pooled_types } = ExecModel::paper_hybrid_pools() {
            assert_eq!(pooled_types.len(), 3);
            assert!(pooled_types.contains(&"mDiffFit".to_string()));
        } else {
            panic!("wrong variant");
        }
    }
}
