//! Execution models for scientific workflows on Kubernetes (§3).
//!
//! * [`ExecModel::JobBased`] — every task is a Kubernetes Job (§3.2).
//! * [`ExecModel::Clustered`] — jobs with HyperFlow task clustering (§3.2/3.5).
//! * [`ExecModel::WorkerPools`] — auto-scalable per-type worker pools fed by
//!   queues (§3.3/3.5). The paper's experiments use the *hybrid* variant
//!   (pools for the three parallel stages, jobs for the serial tail), which
//!   is the default here.
//! * [`ExecModel::GenericPool`] — §3.3's rejected single generic pool.
//!
//! This module is a facade: the model enum and the simulation live in the
//! layered [`crate::exec`] subsystem (kernel / strategies / hooks), with
//! [`driver`] kept as a re-export shim for the old entry-point paths.
//! [`multicloud`] hosts the §5 multi-cluster extension, a compact
//! standalone DES.

pub mod driver;
pub mod multicloud;

pub use crate::exec::ExecModel;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::clustering::ClusteringConfig;

    #[test]
    fn names() {
        assert_eq!(ExecModel::JobBased.name(), "job-based");
        assert_eq!(
            ExecModel::Clustered(ClusteringConfig::paper_default()).name(),
            "job-clustered"
        );
        assert_eq!(ExecModel::paper_hybrid_pools().name(), "worker-pools");
    }

    #[test]
    fn hybrid_pools_cover_parallel_stages() {
        if let ExecModel::WorkerPools { pooled_types } = ExecModel::paper_hybrid_pools() {
            assert_eq!(pooled_types.len(), 3);
            assert!(pooled_types.contains(&"mDiffFit".to_string()));
        } else {
            panic!("wrong variant");
        }
    }
}
