//! Multi-cloud execution (§5 future work): "evaluating the execution
//! models in a multi-cloud setting involving multiple Kubernetes clusters".
//!
//! A compact DES over K independent clusters, each with its own node pool,
//! scheduler and API server (separate control planes), executing one
//! workflow cooperatively:
//!
//! * **worker-pools mode**: per-type queues are *global* (the engine's
//!   broker spans clouds); every cluster runs its own per-type pools whose
//!   autoscaler sees the global backlog scaled by the cluster's share of
//!   total capacity (the paper's proportional rule, federated).
//! * **job mode**: each job is placed on the cluster with the fewest
//!   pending pods (least-loaded dispatch).
//!
//! Cross-cloud data movement is the first-order cost: a task whose
//! dependency outputs live on a different cluster pays
//! `transfer_ms_per_dep` per remote input before executing. The
//! `multicloud` rows in EXPERIMENTS.md §Extensions sweep 1x17 vs 2x9 vs
//! 4x4+1 node splits.
//!
//! Pools here are the same interned [`PoolId`] space the single-cluster
//! execution kernel ([`crate::exec`]) uses: an index into `pooled_types`,
//! shared by the global queues, the per-(cloud, pool) idle/worker tables,
//! and worker payloads. This module stays a standalone DES rather than a
//! [`crate::exec::strategy::ExecStrategy`] because it owns K control
//! planes, not one.

use crate::broker::PoolId;
use crate::engine::Engine;
use crate::k8s::api_server::{ApiServer, ApiServerConfig};
use crate::k8s::node::{paper_cluster, Node};
use crate::k8s::pod::{Payload, Pod, PodId, PodPhase};
use crate::k8s::scheduler::{Scheduler, SchedulerConfig};
use crate::sim::{EventQueue, SimTime};
use crate::workflow::dag::Dag;
use crate::workflow::task::TaskId;
use std::collections::VecDeque;

/// One cloud: nodes + control plane.
struct Cloud {
    nodes: Vec<Node>,
    sched: Scheduler,
    api: ApiServer,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum McMode {
    /// One Kubernetes Job per task, least-loaded cluster placement.
    Jobs,
    /// Global queues + per-cloud worker pools (federated §3.3).
    Pools,
}

#[derive(Debug, Clone)]
pub struct McConfig {
    /// Nodes per cluster, e.g. [17] or [9, 8] or [5, 4, 4, 4].
    pub clusters: Vec<usize>,
    pub mode: McMode,
    /// Latency to move one dependency's outputs across clouds.
    pub transfer_ms_per_dep: u64,
    pub pod_start_ms: u64,
    pub exec_overhead_ms: u64,
    /// Autoscaler poll (pools mode).
    pub poll_ms: u64,
}

impl Default for McConfig {
    fn default() -> Self {
        McConfig {
            clusters: vec![9, 8],
            mode: McMode::Pools,
            transfer_ms_per_dep: 500,
            pod_start_ms: 2_000,
            exec_overhead_ms: 100,
            poll_ms: 15_000,
        }
    }
}

#[derive(Debug, Clone, PartialEq, Eq)]
enum Ev {
    PodCreated { pod: PodId },
    BackoffExpire { cloud: usize, pod: PodId },
    PodStarted { pod: PodId },
    TaskDone { pod: PodId, task: TaskId },
    ScaleTick,
}

/// What a started pod runs next, extracted without cloning the payload
/// (mirrors the driver's `PodWork`; job pods here are always singletons).
enum PodWork {
    Job(TaskId),
    Worker(PoolId),
}

/// Result of a multi-cloud run.
#[derive(Debug)]
pub struct McResult {
    pub makespan: SimTime,
    pub pods_created: u64,
    /// Total cross-cloud dependency transfers paid.
    pub transfers: u64,
    /// Tasks executed per cloud.
    pub tasks_per_cloud: Vec<usize>,
}

struct McWorld {
    cfg: McConfig,
    q: EventQueue<Ev>,
    clouds: Vec<Cloud>,
    pods: Vec<Pod>,
    pod_cloud: Vec<usize>,
    engine: Engine,
    /// Global ready queue per pool (pools mode), indexed by PoolId.
    queues: Vec<VecDeque<TaskId>>,
    /// Idle workers per (cloud, pool), indexed `cloud * n_pools + pool`.
    idle: Vec<VecDeque<PodId>>,
    /// Cloud on which each completed task ran (for transfer costs).
    task_cloud: Vec<Option<usize>>,
    current_task: Vec<Option<TaskId>>,
    /// Live worker count per (cloud, pool), same indexing as `idle`.
    workers: Vec<usize>,
    pods_created: u64,
    transfers: u64,
    tasks_per_cloud: Vec<usize>,
    /// Pool names (PoolId = index) and per-pool pod-template requests.
    pooled_types: Vec<String>,
    pool_requests: Vec<crate::k8s::resources::Resources>,
    /// Per-type routing: which pool a ready task joins (pools mode).
    pool_of_type: Vec<Option<PoolId>>,
    /// Scratch buffer for readiness propagation (reused across events).
    ready_buf: Vec<TaskId>,
}

impl McWorld {
    fn now(&self) -> SimTime {
        self.q.now()
    }

    fn slot(&self, cloud: usize, pool: PoolId) -> usize {
        cloud * self.pooled_types.len() + pool.idx()
    }

    fn new_pod(&mut self, cloud: usize, payload: Payload) -> PodId {
        let requests = match &payload {
            Payload::Worker { pool } => self.pool_requests[pool.idx()],
            Payload::JobBatch { tasks } => self.engine.dag().type_of(tasks[0]).requests,
        };
        let id = PodId(self.pods.len() as u64);
        self.pods.push(Pod::new(id, payload, requests, self.now()));
        self.pod_cloud.push(cloud);
        self.current_task.push(None);
        self.pods_created += 1;
        let now = self.now();
        let done = self.clouds[cloud].api.admit(now);
        self.q.schedule_at(done, Ev::PodCreated { pod: id });
        id
    }

    fn run_scheduler(&mut self, cloud: usize) {
        let now = self.now();
        let c = &mut self.clouds[cloud];
        let pass = c.sched.pass(now, &mut self.pods, &mut c.nodes);
        for (pid, _n, bind_done) in pass.bound {
            self.q.schedule_at(
                bind_done + SimTime::from_millis(self.cfg.pod_start_ms),
                Ev::PodStarted { pod: pid },
            );
        }
        for (pid, until) in pass.backed_off {
            self.q
                .schedule_at(until, Ev::BackoffExpire { cloud, pod: pid });
        }
    }

    /// Cross-cloud input transfer cost for running `task` on `cloud`.
    fn transfer_cost(&mut self, task: TaskId, cloud: usize) -> SimTime {
        let dag = self.engine.dag();
        // dependencies = predecessors: walk successor lists is wrong way;
        // count remote parents via task_cloud of *predecessors*. The DAG
        // stores forward edges, so predecessors were recorded at dispatch.
        let mut remote = 0u64;
        for p in 0..task.0 {
            // cheap check: only tasks whose successor list contains `task`
            // — bounded work because montage succs lists are short except
            // the join nodes, where the cost is genuinely real.
            if dag.successors(TaskId(p)).contains(&task) {
                if let Some(pc) = self.task_cloud[p as usize] {
                    if pc != cloud {
                        remote += 1;
                    }
                }
            }
        }
        self.transfers += remote;
        SimTime::from_millis(remote * self.cfg.transfer_ms_per_dep)
    }

    fn start_task(&mut self, pod: PodId, task: TaskId) {
        let cloud = self.pod_cloud[pod.0 as usize];
        let dur = self.engine.dag().tasks[task.0 as usize].duration;
        let xfer = self.transfer_cost(task, cloud);
        self.current_task[pod.0 as usize] = Some(task);
        let at = self.now()
            + xfer
            + SimTime::from_millis(self.cfg.exec_overhead_ms)
            + dur;
        self.q.schedule_at(at, Ev::TaskDone { pod, task });
    }

    fn least_loaded_cloud(&self) -> usize {
        (0..self.clouds.len())
            .min_by_key(|&c| self.clouds[c].sched.queue_len() + self.clouds[c].sched.sleeping_len())
            .unwrap()
    }

    fn dispatch(&mut self, ready: &[TaskId]) {
        for &t in ready {
            let ttype = self.engine.dag().tasks[t.0 as usize].ttype;
            let pooled = if self.cfg.mode == McMode::Pools {
                self.pool_of_type[ttype.0 as usize]
            } else {
                None
            };
            if let Some(pool) = pooled {
                self.queues[pool.idx()].push_back(t);
                self.wake_idle(pool);
            } else {
                let cloud = self.least_loaded_cloud();
                self.new_pod(cloud, Payload::JobBatch { tasks: vec![t] });
            }
        }
    }

    fn wake_idle(&mut self, pool: PoolId) {
        for c in 0..self.clouds.len() {
            let key = self.slot(c, pool);
            while let Some(&pid) = self.idle[key].front() {
                if self.pods[pid.0 as usize].phase != PodPhase::Running {
                    self.idle[key].pop_front();
                    continue;
                }
                if let Some(t) = self.queues[pool.idx()].pop_front() {
                    self.idle[key].pop_front();
                    self.start_task(pid, t);
                } else {
                    return;
                }
            }
        }
    }

    /// Federated autoscale: each cloud's desired worker count per type is
    /// the global backlog split proportionally to cluster capacity.
    fn scale(&mut self) {
        let total_cpu: u64 = self
            .clouds
            .iter()
            .map(|c| c.nodes.iter().map(|n| n.capacity.cpu_m).sum::<u64>())
            .sum();
        for pi in 0..self.pooled_types.len() {
            let pool = PoolId(pi as u16);
            let backlog = self.queues[pi].len();
            let req = self.pool_requests[pi].cpu_m;
            for c in 0..self.clouds.len() {
                let cloud_cpu: u64 =
                    self.clouds[c].nodes.iter().map(|n| n.capacity.cpu_m).sum();
                let mut share =
                    ((backlog as u64 * cloud_cpu) / total_cpu.max(1)) as usize;
                // never strand a non-empty queue: cloud 0 guarantees one
                if backlog > 0 && c == 0 {
                    share = share.max(1);
                }
                let cap = (cloud_cpu / req.max(1)) as usize;
                let want = share.min(cap.max(1));
                let key = self.slot(c, pool);
                let have = self.workers[key];
                if want > have {
                    for _ in 0..(want - have) {
                        self.new_pod(c, Payload::Worker { pool });
                    }
                    self.workers[key] += want - have;
                } else if want < have {
                    // scale down: terminate idle workers (and pending ones)
                    // so other pools can claim the capacity
                    let mut to_kill = have - want;
                    let idle: Vec<PodId> = self.idle[key].iter().copied().collect();
                    for pid in idle {
                        if to_kill == 0 {
                            break;
                        }
                        if self.pods[pid.0 as usize].phase == PodPhase::Running {
                            self.idle[key].retain(|&p| p != pid);
                            self.terminate(pid);
                            self.workers[key] -= 1;
                            to_kill -= 1;
                        }
                    }
                    // pending workers of this pool can also be deleted
                    if to_kill > 0 {
                        let pending: Vec<PodId> = self
                            .pods
                            .iter()
                            .filter(|p| {
                                p.phase == PodPhase::Pending
                                    && self.pod_cloud[p.id.0 as usize] == c
                                    && p.pool_id() == Some(pool)
                            })
                            .map(|p| p.id)
                            .collect();
                        for pid in pending {
                            if to_kill == 0 {
                                break;
                            }
                            self.pods[pid.0 as usize].phase = PodPhase::Deleted;
                            self.clouds[c].sched.forget(pid);
                            self.workers[key] -= 1;
                            to_kill -= 1;
                        }
                    }
                }
            }
        }
    }

    fn terminate(&mut self, pid: PodId) {
        let cloud = self.pod_cloud[pid.0 as usize];
        let req = self.pods[pid.0 as usize].requests;
        if let Some(n) = self.pods[pid.0 as usize].node {
            self.clouds[cloud].nodes[n.0].release(req);
        }
        self.pods[pid.0 as usize].phase = PodPhase::Succeeded;
        self.clouds[cloud].sched.forget(pid);
        self.run_scheduler(cloud);
    }

    fn handle(&mut self, ev: Ev) {
        match ev {
            Ev::PodCreated { pod } => {
                let cloud = self.pod_cloud[pod.0 as usize];
                self.clouds[cloud].sched.enqueue(pod);
                self.run_scheduler(cloud);
            }
            Ev::BackoffExpire { cloud, pod } => {
                if self.pods[pod.0 as usize].phase == PodPhase::Pending {
                    self.clouds[cloud].sched.enqueue(pod);
                    self.run_scheduler(cloud);
                }
            }
            Ev::PodStarted { pod } => {
                if self.pods[pod.0 as usize].is_terminal() {
                    return;
                }
                self.pods[pod.0 as usize].phase = PodPhase::Running;
                let work = match &self.pods[pod.0 as usize].payload {
                    Payload::JobBatch { tasks } => PodWork::Job(tasks[0]),
                    Payload::Worker { pool } => PodWork::Worker(*pool),
                };
                match work {
                    PodWork::Job(task) => self.start_task(pod, task),
                    PodWork::Worker(pool) => {
                        if let Some(t) = self.queues[pool.idx()].pop_front() {
                            self.start_task(pod, t);
                        } else {
                            let c = self.pod_cloud[pod.0 as usize];
                            let key = self.slot(c, pool);
                            self.idle[key].push_back(pod);
                        }
                    }
                }
            }
            Ev::TaskDone { pod, task } => {
                let cloud = self.pod_cloud[pod.0 as usize];
                self.current_task[pod.0 as usize] = None;
                self.task_cloud[task.0 as usize] = Some(cloud);
                self.tasks_per_cloud[cloud] += 1;
                let mut ready = std::mem::take(&mut self.ready_buf);
                ready.clear();
                self.engine.complete_into(task, &mut ready);
                self.dispatch(&ready);
                self.ready_buf = ready;
                match self.pods[pod.0 as usize].pool_id() {
                    None => self.terminate(pod),
                    Some(pool) => {
                        if let Some(t) = self.queues[pool.idx()].pop_front() {
                            self.start_task(pod, t);
                        } else {
                            let key = self.slot(cloud, pool);
                            self.idle[key].push_back(pod);
                        }
                    }
                }
            }
            Ev::ScaleTick => {
                self.scale();
                if !self.engine.is_done() {
                    self.q
                        .schedule_in(SimTime::from_millis(self.cfg.poll_ms), Ev::ScaleTick);
                }
            }
        }
    }
}

/// Run a workflow across multiple clouds.
pub fn run(dag: Dag, cfg: McConfig) -> McResult {
    let n_tasks = dag.len();
    let n_types = dag.types.len();
    let (engine, initial) = Engine::new(dag);
    let pooled_types: Vec<String> = ["mProject", "mDiffFit", "mBackground"]
        .iter()
        .filter(|t| engine.dag().type_id(t).is_some())
        .map(|s| s.to_string())
        .collect();
    let mut pool_of_type: Vec<Option<PoolId>> = vec![None; n_types];
    let mut pool_requests = Vec::with_capacity(pooled_types.len());
    for (pi, name) in pooled_types.iter().enumerate() {
        let ty = engine.dag().type_id(name).unwrap();
        pool_of_type[ty.0 as usize] = Some(PoolId(pi as u16));
        pool_requests.push(engine.dag().types[ty.0 as usize].requests);
    }
    let clouds: Vec<Cloud> = cfg
        .clusters
        .iter()
        .map(|&n| Cloud {
            nodes: paper_cluster(n),
            sched: Scheduler::new(SchedulerConfig::default()),
            api: ApiServer::new(ApiServerConfig::default()),
        })
        .collect();
    let n_clouds = clouds.len();
    let n_pools = pooled_types.len();
    let mut w = McWorld {
        q: EventQueue::new(),
        clouds,
        pods: Vec::new(),
        pod_cloud: Vec::new(),
        engine,
        queues: (0..n_pools).map(|_| VecDeque::new()).collect(),
        idle: (0..n_clouds * n_pools).map(|_| VecDeque::new()).collect(),
        task_cloud: vec![None; n_tasks],
        current_task: Vec::new(),
        workers: vec![0; n_clouds * n_pools],
        pods_created: 0,
        transfers: 0,
        tasks_per_cloud: vec![0; n_clouds],
        pooled_types,
        pool_requests,
        pool_of_type,
        ready_buf: Vec::new(),
        cfg,
    };
    if w.cfg.mode == McMode::Pools {
        w.q.schedule_in(SimTime::from_millis(1000), Ev::ScaleTick);
    }
    w.dispatch(&initial);
    let mut makespan = SimTime::ZERO;
    let cap = SimTime::from_secs_f64(24.0 * 3600.0); // livelock guard
    while let Some((t, ev)) = w.q.pop() {
        assert!(
            t <= cap,
            "multicloud run exceeded 24h simulated with {} tasks outstanding",
            w.engine.n_outstanding()
        );
        w.handle(ev);
        if w.engine.is_done() {
            makespan = w.q.now();
            break;
        }
    }
    assert!(
        w.engine.is_done(),
        "multicloud run deadlocked with {} outstanding",
        w.engine.n_outstanding()
    );
    McResult {
        makespan,
        pods_created: w.pods_created,
        transfers: w.transfers,
        tasks_per_cloud: w.tasks_per_cloud,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workflow::montage::{generate, MontageConfig};

    fn wf(g: usize) -> Dag {
        generate(&MontageConfig {
            grid_w: g,
            grid_h: g,
            diagonals: true,
            seed: 5,
        })
    }

    #[test]
    fn single_cloud_completes() {
        let r = run(
            wf(5),
            McConfig {
                clusters: vec![4],
                mode: McMode::Pools,
                ..Default::default()
            },
        );
        assert!(r.makespan > SimTime::ZERO);
        assert_eq!(r.transfers, 0, "no cross-cloud transfers with one cloud");
        assert_eq!(r.tasks_per_cloud.iter().sum::<usize>(), wf(5).len());
    }

    #[test]
    fn split_cloud_pays_transfers() {
        let r = run(
            wf(5),
            McConfig {
                clusters: vec![2, 2],
                mode: McMode::Pools,
                ..Default::default()
            },
        );
        assert!(r.transfers > 0, "expected cross-cloud dependency traffic");
        assert!(r.tasks_per_cloud.iter().all(|&n| n > 0), "both clouds used");
    }

    #[test]
    fn same_capacity_split_is_slower_with_transfer_cost() {
        let single = run(
            wf(6),
            McConfig {
                clusters: vec![4],
                mode: McMode::Pools,
                transfer_ms_per_dep: 2_000,
                ..Default::default()
            },
        );
        let split = run(
            wf(6),
            McConfig {
                clusters: vec![2, 2],
                mode: McMode::Pools,
                transfer_ms_per_dep: 2_000,
                ..Default::default()
            },
        );
        assert!(
            split.makespan > single.makespan,
            "split {} vs single {}",
            split.makespan,
            single.makespan
        );
    }

    #[test]
    fn free_transfers_make_split_competitive() {
        let single = run(
            wf(6),
            McConfig {
                clusters: vec![4],
                mode: McMode::Pools,
                transfer_ms_per_dep: 0,
                ..Default::default()
            },
        );
        let split = run(
            wf(6),
            McConfig {
                clusters: vec![2, 2],
                mode: McMode::Pools,
                transfer_ms_per_dep: 0,
                ..Default::default()
            },
        );
        let ratio = split.makespan.as_secs_f64() / single.makespan.as_secs_f64();
        assert!(ratio < 1.4, "free-transfer split should be close: {ratio}");
    }

    #[test]
    fn jobs_mode_works_across_clouds() {
        let r = run(
            wf(4),
            McConfig {
                clusters: vec![2, 1, 1],
                mode: McMode::Jobs,
                ..Default::default()
            },
        );
        assert_eq!(r.tasks_per_cloud.iter().sum::<usize>(), wf(4).len());
        assert!(r.tasks_per_cloud.iter().filter(|&&n| n > 0).count() >= 2);
    }
}
