//! The HyperFlow workflow engine: signal-counting readiness propagation.
//!
//! HyperFlow's model of computation fires a task when all of its input
//! signals have arrived [Balis 2016]. For DAG workflows this reduces to
//! predecessor counting: `complete(t)` decrements the remaining-dependency
//! counter of every successor and returns the tasks that just became ready.
//! The engine is execution-model agnostic — the driver decides whether a
//! ready task becomes a Kubernetes Job, joins a clustered batch, or is
//! published to a worker-pool queue.

pub mod clustering;

use crate::workflow::dag::Dag;
use crate::workflow::task::TaskId;

#[derive(Debug)]
pub struct Engine {
    dag: Dag,
    remaining: Vec<u32>,
    state: Vec<TaskState>,
    n_done: usize,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TaskState {
    /// Dependencies outstanding.
    Waiting,
    /// Ready, handed to the execution model.
    Dispatched,
    /// Completed.
    Done,
}

impl Engine {
    /// Build the engine; returns it plus the initially-ready tasks.
    pub fn new(dag: Dag) -> (Self, Vec<TaskId>) {
        let remaining: Vec<u32> = (0..dag.len())
            .map(|i| dag.preds_count(TaskId(i as u32)))
            .collect();
        let state = vec![TaskState::Waiting; dag.len()];
        let mut eng = Engine {
            dag,
            remaining,
            state,
            n_done: 0,
        };
        let roots = eng.dag.roots();
        for &r in &roots {
            eng.state[r.0 as usize] = TaskState::Dispatched;
        }
        (eng, roots)
    }

    pub fn dag(&self) -> &Dag {
        &self.dag
    }

    /// Record completion of `t`; returns newly-ready tasks (marked
    /// Dispatched). Panics on double-completion — the paper's executor
    /// protocol guarantees exactly-once completion signals.
    pub fn complete(&mut self, t: TaskId) -> Vec<TaskId> {
        let mut ready = Vec::new();
        self.complete_into(t, &mut ready);
        ready
    }

    /// Allocation-free variant of [`Engine::complete`]: appends newly-ready
    /// tasks to `out`, letting the simulation driver reuse one scratch
    /// buffer across all 16k completions (EXPERIMENTS.md §Perf).
    pub fn complete_into(&mut self, t: TaskId, out: &mut Vec<TaskId>) {
        let i = t.0 as usize;
        assert_eq!(
            self.state[i],
            TaskState::Dispatched,
            "task {t:?} completed in state {:?}",
            self.state[i]
        );
        self.state[i] = TaskState::Done;
        self.n_done += 1;
        for &s in self.dag.successors(t) {
            let j = s.0 as usize;
            debug_assert!(self.remaining[j] > 0);
            self.remaining[j] -= 1;
            if self.remaining[j] == 0 {
                debug_assert_eq!(self.state[j], TaskState::Waiting);
                self.state[j] = TaskState::Dispatched;
                out.push(s);
            }
        }
    }

    pub fn is_done(&self) -> bool {
        self.n_done == self.dag.len()
    }

    pub fn n_done(&self) -> usize {
        self.n_done
    }

    pub fn n_outstanding(&self) -> usize {
        self.dag.len() - self.n_done
    }

    pub fn state(&self, t: TaskId) -> TaskState {
        self.state[t.0 as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::k8s::resources::Resources;
    use crate::sim::SimTime;
    use crate::workflow::montage::{generate, MontageConfig};
    use crate::workflow::task::TaskType;

    fn diamond() -> Dag {
        // 0 -> {1, 2} -> 3
        let mut d = Dag::new("diamond");
        let ty = d.add_type(TaskType::new("T", Resources::ZERO, 1.0, 0.0));
        let t0 = d.add_task(ty, SimTime(1), &[]);
        let t1 = d.add_task(ty, SimTime(1), &[t0]);
        let t2 = d.add_task(ty, SimTime(1), &[t0]);
        let _ = d.add_task(ty, SimTime(1), &[t1, t2]);
        d
    }

    #[test]
    fn roots_dispatch_first() {
        let (eng, ready) = Engine::new(diamond());
        assert_eq!(ready, vec![TaskId(0)]);
        assert_eq!(eng.state(TaskId(0)), TaskState::Dispatched);
        assert_eq!(eng.state(TaskId(1)), TaskState::Waiting);
    }

    #[test]
    fn diamond_readiness_order() {
        let (mut eng, _) = Engine::new(diamond());
        let r = eng.complete(TaskId(0));
        assert_eq!(r, vec![TaskId(1), TaskId(2)]);
        assert!(eng.complete(TaskId(1)).is_empty()); // join not ready yet
        let r = eng.complete(TaskId(2));
        assert_eq!(r, vec![TaskId(3)]);
        assert!(!eng.is_done());
        eng.complete(TaskId(3));
        assert!(eng.is_done());
        assert_eq!(eng.n_done(), 4);
    }

    #[test]
    #[should_panic(expected = "completed in state")]
    fn double_complete_panics() {
        let (mut eng, _) = Engine::new(diamond());
        eng.complete(TaskId(0));
        eng.complete(TaskId(0));
    }

    #[test]
    #[should_panic(expected = "completed in state")]
    fn complete_waiting_panics() {
        let (mut eng, _) = Engine::new(diamond());
        eng.complete(TaskId(3));
    }

    #[test]
    fn complete_into_appends_without_clearing() {
        let (mut eng, _) = Engine::new(diamond());
        let mut buf = vec![TaskId(99)]; // pre-existing content survives
        eng.complete_into(TaskId(0), &mut buf);
        assert_eq!(buf, vec![TaskId(99), TaskId(1), TaskId(2)]);
        buf.clear();
        eng.complete_into(TaskId(1), &mut buf);
        assert!(buf.is_empty()); // join not ready yet
        eng.complete_into(TaskId(2), &mut buf);
        assert_eq!(buf, vec![TaskId(3)]);
    }

    #[test]
    fn full_montage_drains() {
        // property: completing tasks in any ready order drains the DAG
        let dag = generate(&MontageConfig {
            grid_w: 4,
            grid_h: 4,
            diagonals: true,
            seed: 3,
        });
        let total = dag.len();
        let (mut eng, mut ready) = Engine::new(dag);
        let mut processed = 0;
        while let Some(t) = ready.pop() {
            processed += 1;
            let mut newly = eng.complete(t);
            ready.append(&mut newly);
        }
        assert_eq!(processed, total);
        assert!(eng.is_done());
    }

    #[test]
    fn readiness_never_exceeds_dependencies() {
        // each task becomes ready exactly once
        let dag = generate(&MontageConfig {
            grid_w: 3,
            grid_h: 3,
            diagonals: true,
            seed: 8,
        });
        let (mut eng, ready) = Engine::new(dag);
        let mut seen = std::collections::BTreeSet::new();
        let mut stack = ready;
        for t in &stack {
            assert!(seen.insert(*t));
        }
        while let Some(t) = stack.pop() {
            for n in eng.complete(t) {
                assert!(seen.insert(n), "task {n:?} became ready twice");
                stack.push(n);
            }
        }
    }
}
