//! HyperFlow task clustering (agglomeration), §3.5.
//!
//! Tasks of matching types are buffered into batches of `size`; if a full
//! batch does not form within `timeout_ms`, the partial batch is flushed.
//! Clustering is *horizontal* (§3.2): only same-type tasks cluster, and the
//! batch executes sequentially in one pod so the pod's resource requests
//! stay valid.
//!
//! The paper's example configuration:
//! ```json
//! [{"matchTask": ["mProject"],  "size": 5,  "timeoutMs": 3000},
//!  {"matchTask": ["mDiffFit"],  "size": 20, "timeoutMs": 3000}]
//! ```

use crate::sim::SimTime;
use crate::util::json::{Json, JsonError};
use crate::workflow::task::{TaskId, TypeId};

/// One clustering rule.
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterRule {
    pub match_task: Vec<String>,
    pub size: usize,
    pub timeout_ms: u64,
}

/// The clustering configuration: an ordered rule list; first match wins.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ClusteringConfig {
    pub rules: Vec<ClusterRule>,
}

impl ClusteringConfig {
    /// No clustering: every task is its own batch (the plain job model).
    pub fn none() -> Self {
        ClusteringConfig::default()
    }

    /// The configuration shown in the paper (§3.5), extended with the
    /// mBackground rule the experiments imply (Fig. 4 discusses batched
    /// mBackground execution).
    pub fn paper_default() -> Self {
        ClusteringConfig {
            rules: vec![
                ClusterRule {
                    match_task: vec!["mProject".into()],
                    size: 5,
                    timeout_ms: 3000,
                },
                ClusterRule {
                    match_task: vec!["mDiffFit".into()],
                    size: 20,
                    timeout_ms: 3000,
                },
                ClusterRule {
                    match_task: vec!["mBackground".into()],
                    size: 20,
                    timeout_ms: 3000,
                },
            ],
        }
    }

    /// Uniform clustering of the three parallel stages (for the Fig. 5
    /// parameter sweep).
    pub fn uniform(size: usize, timeout_ms: u64) -> Self {
        ClusteringConfig {
            rules: ["mProject", "mDiffFit", "mBackground"]
                .iter()
                .map(|t| ClusterRule {
                    match_task: vec![t.to_string()],
                    size,
                    timeout_ms,
                })
                .collect(),
        }
    }

    pub fn rule_for(&self, type_name: &str) -> Option<&ClusterRule> {
        self.rules
            .iter()
            .find(|r| r.match_task.iter().any(|m| m == type_name))
    }

    /// Parse the HyperFlow JSON rule format shown in §3.5.
    pub fn from_json(j: &Json) -> Result<Self, JsonError> {
        let mut rules = Vec::new();
        for r in j.as_arr()? {
            rules.push(ClusterRule {
                match_task: r
                    .get("matchTask")?
                    .as_arr()?
                    .iter()
                    .map(|s| s.as_str().map(str::to_string))
                    .collect::<Result<_, _>>()?,
                size: r.get("size")?.as_usize()?,
                timeout_ms: r.get("timeoutMs")?.as_u64()?,
            });
        }
        Ok(ClusteringConfig { rules })
    }
}

/// What the batcher wants done after a push/flush.
#[derive(Debug, PartialEq)]
pub enum BatchAction {
    /// Dispatch this batch now.
    Flush(Vec<TaskId>),
    /// Batch incomplete: arm a flush timer for this deadline (only emitted
    /// when the buffer transitions empty -> non-empty).
    ArmTimer(SimTime),
    /// Task buffered; a timer is already armed.
    Buffered,
}

/// Per-type batch buffers with deadline bookkeeping.
///
/// Buffers are a dense `Vec` indexed by [`TypeId`] — the per-push
/// `BTreeMap<String, _>` lookup (plus a rule clone with its `match_task`
/// strings) showed up in the 16k-sim profile (EXPERIMENTS.md §Perf). Name
/// matching against the rule list happens once per type, on the first
/// push of that type, and is cached as a copyable `(size, timeout)` pair.
#[derive(Debug)]
pub struct Batcher {
    cfg: ClusteringConfig,
    buffers: Vec<Buffer>,
    rule_cache: Vec<CachedRule>,
    pub batches_emitted: u64,
    pub partial_flushes: u64,
}

#[derive(Debug, Default)]
struct Buffer {
    tasks: Vec<TaskId>,
    deadline: Option<SimTime>,
}

/// Result of matching one task type against the rule list.
#[derive(Debug, Clone, Copy)]
enum CachedRule {
    Unresolved,
    NoRule,
    Rule { size: usize, timeout_ms: u64 },
}

impl Batcher {
    pub fn new(cfg: ClusteringConfig) -> Self {
        Batcher {
            cfg,
            buffers: Vec::new(),
            rule_cache: Vec::new(),
            batches_emitted: 0,
            partial_flushes: 0,
        }
    }

    pub fn cfg(&self) -> &ClusteringConfig {
        &self.cfg
    }

    /// Offer a ready task. Tasks of types without a rule flush immediately
    /// as singleton batches. `type_name` is only consulted the first time
    /// a type id is seen, to resolve its rule.
    pub fn push(
        &mut self,
        now: SimTime,
        ttype: TypeId,
        type_name: &str,
        task: TaskId,
    ) -> BatchAction {
        let i = ttype.0 as usize;
        if i >= self.buffers.len() {
            self.buffers.resize_with(i + 1, Buffer::default);
            self.rule_cache.resize(i + 1, CachedRule::Unresolved);
        }
        if matches!(self.rule_cache[i], CachedRule::Unresolved) {
            self.rule_cache[i] = match self.cfg.rule_for(type_name) {
                None => CachedRule::NoRule,
                Some(r) => CachedRule::Rule {
                    size: r.size,
                    timeout_ms: r.timeout_ms,
                },
            };
        }
        let (size, timeout_ms) = match self.rule_cache[i] {
            CachedRule::Rule { size, timeout_ms } if size > 1 => (size, timeout_ms),
            _ => {
                self.batches_emitted += 1;
                return BatchAction::Flush(vec![task]);
            }
        };
        let buf = &mut self.buffers[i];
        buf.tasks.push(task);
        if buf.tasks.len() >= size {
            buf.deadline = None;
            self.batches_emitted += 1;
            return BatchAction::Flush(std::mem::take(&mut buf.tasks));
        }
        if buf.deadline.is_none() {
            let dl = now + SimTime::from_millis(timeout_ms);
            buf.deadline = Some(dl);
            BatchAction::ArmTimer(dl)
        } else {
            BatchAction::Buffered
        }
    }

    /// Timer fired for `ttype` with deadline `dl`. Returns the partial
    /// batch if the deadline is still current (it is cleared when a full
    /// batch flushed in the meantime).
    pub fn timer_fired(&mut self, ttype: TypeId, dl: SimTime) -> Option<Vec<TaskId>> {
        let buf = self.buffers.get_mut(ttype.0 as usize)?;
        if buf.deadline != Some(dl) || buf.tasks.is_empty() {
            return None;
        }
        buf.deadline = None;
        self.batches_emitted += 1;
        self.partial_flushes += 1;
        Some(std::mem::take(&mut buf.tasks))
    }

    /// Flush everything (end-of-workflow drain), in type-id order.
    pub fn drain(&mut self) -> Vec<(TypeId, Vec<TaskId>)> {
        let mut out = Vec::new();
        for (i, buf) in self.buffers.iter_mut().enumerate() {
            if !buf.tasks.is_empty() {
                buf.deadline = None;
                self.batches_emitted += 1;
                out.push((TypeId(i as u16), std::mem::take(&mut buf.tasks)));
            }
        }
        out
    }

    pub fn buffered(&self, ttype: TypeId) -> usize {
        self.buffers
            .get(ttype.0 as usize)
            .map(|b| b.tasks.len())
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(i: u32) -> TaskId {
        TaskId(i)
    }

    #[test]
    fn paper_config_rules() {
        let c = ClusteringConfig::paper_default();
        assert_eq!(c.rule_for("mProject").unwrap().size, 5);
        assert_eq!(c.rule_for("mDiffFit").unwrap().size, 20);
        assert!(c.rule_for("mAdd").is_none());
    }

    #[test]
    fn json_round_trip_of_paper_listing() {
        let src = r#"[
            {"matchTask": ["mProject"], "size": 5, "timeoutMs": 3000},
            {"matchTask": ["mDiffFit"], "size": 20, "timeoutMs": 3000}
        ]"#;
        let cfg = ClusteringConfig::from_json(&Json::parse(src).unwrap()).unwrap();
        assert_eq!(cfg.rules.len(), 2);
        assert_eq!(cfg.rule_for("mDiffFit").unwrap().timeout_ms, 3000);
    }

    const TX: TypeId = TypeId(0);

    #[test]
    fn full_batch_flushes_immediately() {
        let mut b = Batcher::new(ClusteringConfig {
            rules: vec![ClusterRule {
                match_task: vec!["X".into()],
                size: 3,
                timeout_ms: 1000,
            }],
        });
        assert_eq!(
            b.push(SimTime(0), TX, "X", t(0)),
            BatchAction::ArmTimer(SimTime(1000))
        );
        assert_eq!(b.push(SimTime(10), TX, "X", t(1)), BatchAction::Buffered);
        assert_eq!(
            b.push(SimTime(20), TX, "X", t(2)),
            BatchAction::Flush(vec![t(0), t(1), t(2)])
        );
        assert_eq!(b.buffered(TX), 0);
    }

    #[test]
    fn partial_batch_flushes_on_timeout() {
        let mut b = Batcher::new(ClusteringConfig {
            rules: vec![ClusterRule {
                match_task: vec!["X".into()],
                size: 5,
                timeout_ms: 3000,
            }],
        });
        let dl = match b.push(SimTime(0), TX, "X", t(0)) {
            BatchAction::ArmTimer(dl) => dl,
            o => panic!("{o:?}"),
        };
        b.push(SimTime(100), TX, "X", t(1));
        assert_eq!(b.timer_fired(TX, dl), Some(vec![t(0), t(1)]));
        assert_eq!(b.partial_flushes, 1);
    }

    #[test]
    fn stale_timer_ignored_after_full_flush() {
        let mut b = Batcher::new(ClusteringConfig {
            rules: vec![ClusterRule {
                match_task: vec!["X".into()],
                size: 2,
                timeout_ms: 3000,
            }],
        });
        let dl = match b.push(SimTime(0), TX, "X", t(0)) {
            BatchAction::ArmTimer(dl) => dl,
            o => panic!("{o:?}"),
        };
        b.push(SimTime(1), TX, "X", t(1)); // full flush
        assert_eq!(b.timer_fired(TX, dl), None);
    }

    #[test]
    fn timer_for_unseen_type_is_ignored() {
        let mut b = Batcher::new(ClusteringConfig::paper_default());
        assert_eq!(b.timer_fired(TypeId(40), SimTime(1000)), None);
    }

    #[test]
    fn new_batch_rearms_timer() {
        let mut b = Batcher::new(ClusteringConfig {
            rules: vec![ClusterRule {
                match_task: vec!["X".into()],
                size: 2,
                timeout_ms: 1000,
            }],
        });
        b.push(SimTime(0), TX, "X", t(0));
        b.push(SimTime(5), TX, "X", t(1)); // flush
        match b.push(SimTime(50), TX, "X", t(2)) {
            BatchAction::ArmTimer(dl) => assert_eq!(dl, SimTime(1050)),
            o => panic!("{o:?}"),
        }
    }

    #[test]
    fn unmatched_type_is_singleton() {
        let mut b = Batcher::new(ClusteringConfig::paper_default());
        assert_eq!(
            b.push(SimTime(0), TypeId(7), "mAdd", t(7)),
            BatchAction::Flush(vec![t(7)])
        );
    }

    #[test]
    fn size_one_rule_is_singleton() {
        let mut b = Batcher::new(ClusteringConfig::uniform(1, 3000));
        assert_eq!(
            b.push(SimTime(0), TX, "mProject", t(1)),
            BatchAction::Flush(vec![t(1)])
        );
    }

    #[test]
    fn drain_flushes_all_buffers_in_type_id_order() {
        let mut b = Batcher::new(ClusteringConfig::paper_default());
        // push in reverse type-id order; drain must come back dense/sorted
        b.push(SimTime(0), TypeId(1), "mDiffFit", t(1));
        b.push(SimTime(0), TypeId(0), "mProject", t(0));
        let drained = b.drain();
        assert_eq!(drained.len(), 2);
        assert_eq!(drained[0], (TypeId(0), vec![t(0)]));
        assert_eq!(drained[1], (TypeId(1), vec![t(1)]));
    }

    #[test]
    fn rule_is_resolved_once_per_type() {
        // the cached resolution must win even if a later push lies about
        // the name — the TypeId is the identity, the name a resolution key
        let mut b = Batcher::new(ClusteringConfig::paper_default());
        assert!(matches!(
            b.push(SimTime(0), TX, "mProject", t(0)),
            BatchAction::ArmTimer(_)
        ));
        assert_eq!(b.push(SimTime(1), TX, "mAdd", t(1)), BatchAction::Buffered);
        assert_eq!(b.buffered(TX), 2);
    }

    #[test]
    fn no_task_lost_property() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(21);
        for _ in 0..30 {
            let size = 2 + rng.below(10) as usize;
            let mut b = Batcher::new(ClusteringConfig {
                rules: vec![ClusterRule {
                    match_task: vec!["X".into()],
                    size,
                    timeout_ms: 500,
                }],
            });
            let n = 1 + rng.below(100);
            let mut out = 0usize;
            let mut timers: Vec<SimTime> = Vec::new();
            for i in 0..n {
                let now = SimTime(i * 10);
                // fire due timers first
                timers.retain(|&dl| {
                    if dl <= now {
                        if let Some(batch) = b.timer_fired(TX, dl) {
                            out += batch.len();
                        }
                        false
                    } else {
                        true
                    }
                });
                match b.push(now, TX, "X", t(i as u32)) {
                    BatchAction::Flush(v) => out += v.len(),
                    BatchAction::ArmTimer(dl) => timers.push(dl),
                    BatchAction::Buffered => {}
                }
            }
            for (_, v) in b.drain() {
                out += v.len();
            }
            assert_eq!(out as u64, n, "tasks lost or duplicated");
        }
    }
}
