//! Generic discrete-event queue.
//!
//! Implemented as a **hierarchical calendar (bucket) queue** rather than a
//! binary heap: simulated time is integer milliseconds, so events within a
//! ~65 s horizon live in one-millisecond buckets indexed directly by time,
//! with a three-level occupancy bitmap (64² × 16 bits) locating the next
//! non-empty bucket in a handful of `trailing_zeros` instructions. Events
//! beyond the horizon wait in a sorted overflow map and are swept into the
//! wheel in one batch when the wheel drains — each event pays at most one
//! overflow insert over its lifetime, so push/pop are amortized O(1) for
//! the dense event streams the 16k-task models generate (the heap's
//! O(log n) per operation was the top simulator cost after the allocation
//! fixes; EXPERIMENTS.md §Perf).
//!
//! Storage is a **slab arena**: every event lives in a slot of one grown
//! `Vec`, and buckets are intrusive FIFO linked lists threaded through
//! the slots (`head`/`tail` per bucket, `next` per slot). Popped slots go
//! onto a free list and are recycled, so the steady-state loop allocates
//! nothing per event — the old per-bucket `VecDeque`s paid a buffer
//! allocation per overflow key and per warmup bucket. A rebase moves
//! whole lists by retargeting two indices per timestamp, never touching
//! the events themselves. [`EventQueue::arena_stats`] reports fresh
//! slot allocations vs free-list reuses; `BENCH_driver.json` records the
//! reuse ratio.
//!
//! Determinism contract (unchanged from the heap version, which used a
//! monotone sequence number): events pop in (time, schedule order). Every
//! bucket holds exactly one timestamp, past events clamp to `now`, and
//! overflow sweeps preserve per-timestamp list order — so plain FIFO
//! insertion order within a bucket IS schedule order, and runs are
//! bit-reproducible without storing a per-event counter. Slot *indices*
//! carry no ordering: FIFO order lives in the list links alone, so
//! free-list recycling cannot reorder same-timestamp ties (pinned by the
//! `free_list_reuse_*` tests below and `tests/sweep.rs`).

use super::time::SimTime;
use std::collections::BTreeMap;

/// log2 of the wheel size: 2^16 one-millisecond buckets ≈ 65 s horizon.
const WHEEL_BITS: u32 = 16;
const WHEEL: usize = 1 << WHEEL_BITS;
const L0_WORDS: usize = WHEEL / 64;
const L1_WORDS: usize = L0_WORDS / 64;

/// Null link for the intrusive lists (slot indices are dense u32s).
const NIL: u32 = u32::MAX;

/// `word` with all bits below `bit` cleared (0 when `bit >= 64`).
#[inline]
fn bits_from(word: u64, bit: u32) -> u64 {
    if bit >= 64 {
        0
    } else {
        word & (u64::MAX << bit)
    }
}

/// One arena slot: the event payload (`None` while on the free list) and
/// the intrusive link to the next slot in the same bucket / free list.
#[derive(Debug)]
struct Slot<E> {
    ev: Option<E>,
    next: u32,
}

/// Fresh-allocation vs free-list-reuse counters of the event arena.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ArenaStats {
    /// Slots created by growing the slab.
    pub allocs: u64,
    /// Slots recycled from the free list.
    pub reuses: u64,
}

impl ArenaStats {
    /// Fraction of event schedules served from the free list.
    pub fn reuse_ratio(&self) -> f64 {
        let total = self.allocs + self.reuses;
        if total == 0 {
            0.0
        } else {
            self.reuses as f64 / total as f64
        }
    }
}

/// Priority queue of scheduled events (calendar queue over a slab arena).
#[derive(Debug)]
pub struct EventQueue<E> {
    /// The arena. Grows to the peak concurrent event count, then stops.
    slots: Vec<Slot<E>>,
    /// Head of the LIFO free list of recycled slots.
    free: u32,
    /// Per-bucket FIFO list heads/tails covering `[base_ms, base_ms +
    /// WHEEL)`; each bucket holds exactly one timestamp.
    head: Vec<u32>,
    tail: Vec<u32>,
    /// Occupancy bitmaps: one bit per bucket / per l0 word / per l1 word.
    occ_l0: Vec<u64>,
    occ_l1: Vec<u64>,
    occ_l2: u64,
    /// Absolute time (ms) of bucket 0.
    base_ms: u64,
    /// Lowest bucket index that may still be occupied.
    cursor: usize,
    /// Events beyond the wheel horizon: absolute ms -> (head, tail) of a
    /// FIFO slot list, preserving schedule order for the tie-break.
    overflow: BTreeMap<u64, (u32, u32)>,
    len: usize,
    now: SimTime,
    stats: ArenaStats,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        EventQueue {
            slots: Vec::new(),
            free: NIL,
            head: vec![NIL; WHEEL],
            tail: vec![NIL; WHEEL],
            occ_l0: vec![0; L0_WORDS],
            occ_l1: vec![0; L1_WORDS],
            occ_l2: 0,
            base_ms: 0,
            cursor: 0,
            overflow: BTreeMap::new(),
            len: 0,
            now: SimTime::ZERO,
            stats: ArenaStats::default(),
        }
    }

    /// Current simulated time (time of the last popped event).
    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Arena counters: fresh slab growth vs free-list reuse.
    pub fn arena_stats(&self) -> ArenaStats {
        self.stats
    }

    /// Take a slot for `event`: recycle from the free list, else grow.
    #[inline]
    fn alloc_slot(&mut self, event: E) -> u32 {
        if self.free != NIL {
            let i = self.free;
            let slot = &mut self.slots[i as usize];
            self.free = slot.next;
            slot.ev = Some(event);
            slot.next = NIL;
            self.stats.reuses += 1;
            i
        } else {
            let i = self.slots.len() as u32;
            debug_assert!(i < NIL, "event arena exhausted u32 index space");
            self.slots.push(Slot {
                ev: Some(event),
                next: NIL,
            });
            self.stats.allocs += 1;
            i
        }
    }

    /// Return a drained slot to the free list (LIFO: warm slots first).
    #[inline]
    fn free_slot(&mut self, i: u32) {
        let slot = &mut self.slots[i as usize];
        debug_assert!(slot.ev.is_none(), "freeing an occupied slot");
        slot.next = self.free;
        self.free = i;
    }

    /// Append slot `i` to a bucket's FIFO list.
    #[inline]
    fn list_push(head: &mut u32, tail: &mut u32, slots: &mut [Slot<E>], i: u32) {
        if *head == NIL {
            *head = i;
        } else {
            slots[*tail as usize].next = i;
        }
        *tail = i;
    }

    #[inline]
    fn mark(&mut self, idx: usize) {
        self.occ_l0[idx >> 6] |= 1 << (idx & 63);
        self.occ_l1[idx >> 12] |= 1 << ((idx >> 6) & 63);
        self.occ_l2 |= 1 << (idx >> 12);
    }

    #[inline]
    fn unmark(&mut self, idx: usize) {
        let w0 = idx >> 6;
        self.occ_l0[w0] &= !(1 << (idx & 63));
        if self.occ_l0[w0] == 0 {
            let w1 = w0 >> 6;
            self.occ_l1[w1] &= !(1 << (w0 & 63));
            if self.occ_l1[w1] == 0 {
                self.occ_l2 &= !(1 << w1);
            }
        }
    }

    /// Lowest occupied bucket index `>= from`, via the bitmap hierarchy.
    fn next_occupied(&self, from: usize) -> Option<usize> {
        if from >= WHEEL {
            return None;
        }
        let w0 = from >> 6;
        let b0 = bits_from(self.occ_l0[w0], (from & 63) as u32);
        if b0 != 0 {
            return Some((w0 << 6) | b0.trailing_zeros() as usize);
        }
        let w1 = w0 >> 6;
        let b1 = bits_from(self.occ_l1[w1], (w0 & 63) as u32 + 1);
        if b1 != 0 {
            let w0n = (w1 << 6) | b1.trailing_zeros() as usize;
            return Some((w0n << 6) | self.occ_l0[w0n].trailing_zeros() as usize);
        }
        let b2 = bits_from(self.occ_l2, w1 as u32 + 1);
        if b2 != 0 {
            let w1n = b2.trailing_zeros() as usize;
            let w0n = (w1n << 6) | self.occ_l1[w1n].trailing_zeros() as usize;
            return Some((w0n << 6) | self.occ_l0[w0n].trailing_zeros() as usize);
        }
        None
    }

    /// The wheel drained: slide the window to the earliest overflow event
    /// and sweep everything inside the new horizon into buckets. With
    /// intrusive lists a sweep retargets two indices per timestamp — the
    /// events themselves never move.
    fn rebase(&mut self) {
        let &new_base = self
            .overflow
            .keys()
            .next()
            .expect("rebase with empty overflow");
        let beyond = self.overflow.split_off(&(new_base + WHEEL as u64));
        let window = std::mem::replace(&mut self.overflow, beyond);
        self.base_ms = new_base;
        self.cursor = 0;
        for (ms, (h, t)) in window {
            let idx = (ms - new_base) as usize;
            debug_assert_eq!(self.head[idx], NIL);
            self.head[idx] = h;
            self.tail[idx] = t;
            self.mark(idx);
        }
    }

    /// Schedule `event` at absolute time `at`. Events scheduled in the past
    /// are clamped to `now` (fire next).
    pub fn schedule_at(&mut self, at: SimTime, event: E) {
        let at = at.max(self.now);
        self.len += 1;
        let ms = at.as_millis();
        debug_assert!(ms >= self.base_ms);
        let i = self.alloc_slot(event);
        if ms - self.base_ms < WHEEL as u64 {
            let idx = (ms - self.base_ms) as usize;
            let (head, tail) = (&mut self.head[idx], &mut self.tail[idx]);
            Self::list_push(head, tail, &mut self.slots, i);
            self.mark(idx);
        } else {
            let (head, tail) = self.overflow.entry(ms).or_insert((NIL, NIL));
            Self::list_push(head, tail, &mut self.slots, i);
        }
    }

    /// Schedule `event` after a delay from now.
    pub fn schedule_in(&mut self, delay: SimTime, event: E) {
        self.schedule_at(self.now + delay, event);
    }

    /// Pop the next event, advancing `now`.
    pub fn pop(&mut self) -> Option<(SimTime, E)> {
        if self.len == 0 {
            return None;
        }
        loop {
            if let Some(idx) = self.next_occupied(self.cursor) {
                self.cursor = idx;
                let i = self.head[idx];
                debug_assert_ne!(i, NIL, "occupied bucket is empty");
                let slot = &mut self.slots[i as usize];
                let event = slot.ev.take().expect("bucket slot is empty");
                self.head[idx] = slot.next;
                if self.head[idx] == NIL {
                    self.tail[idx] = NIL;
                    self.unmark(idx);
                }
                self.free_slot(i);
                self.len -= 1;
                let at = SimTime::from_millis(self.base_ms + idx as u64);
                debug_assert!(at >= self.now, "time went backwards");
                self.now = at;
                return Some((at, event));
            }
            self.rebase();
        }
    }

    pub fn peek_time(&self) -> Option<SimTime> {
        if self.len == 0 {
            return None;
        }
        if let Some(idx) = self.next_occupied(self.cursor) {
            return Some(SimTime::from_millis(self.base_ms + idx as u64));
        }
        self.overflow
            .keys()
            .next()
            .map(|&ms| SimTime::from_millis(ms))
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q: EventQueue<u32> = EventQueue::new();
        q.schedule_at(SimTime(30), 3);
        q.schedule_at(SimTime(10), 1);
        q.schedule_at(SimTime(20), 2);
        let order: Vec<u32> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, vec![1, 2, 3]);
    }

    #[test]
    fn same_time_fifo() {
        let mut q: EventQueue<u32> = EventQueue::new();
        for i in 0..10 {
            q.schedule_at(SimTime(5), i);
        }
        let order: Vec<u32> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn now_advances() {
        let mut q: EventQueue<u32> = EventQueue::new();
        q.schedule_at(SimTime(100), 1);
        assert_eq!(q.now(), SimTime::ZERO);
        q.pop();
        assert_eq!(q.now(), SimTime(100));
    }

    #[test]
    fn schedule_in_is_relative() {
        let mut q: EventQueue<u32> = EventQueue::new();
        q.schedule_at(SimTime(50), 1);
        q.pop();
        q.schedule_in(SimTime(25), 2);
        let (t, e) = q.pop().unwrap();
        assert_eq!((t, e), (SimTime(75), 2));
    }

    #[test]
    fn past_events_clamped_to_now() {
        let mut q: EventQueue<u32> = EventQueue::new();
        q.schedule_at(SimTime(100), 1);
        q.pop();
        q.schedule_at(SimTime(10), 2); // in the past
        let (t, _) = q.pop().unwrap();
        assert_eq!(t, SimTime(100));
    }

    #[test]
    fn peek_and_len() {
        let mut q: EventQueue<u32> = EventQueue::new();
        assert!(q.is_empty());
        q.schedule_at(SimTime(5), 1);
        assert_eq!(q.peek_time(), Some(SimTime(5)));
        assert_eq!(q.len(), 1);
    }

    // -- calendar-specific coverage (horizon crossing, rebase, FIFO) ------

    const HORIZON: u64 = super::WHEEL as u64;

    #[test]
    fn events_beyond_horizon_pop_in_order() {
        let mut q: EventQueue<u32> = EventQueue::new();
        // in-wheel, overflow, and far-overflow events, scheduled shuffled
        q.schedule_at(SimTime(3 * HORIZON + 7), 4);
        q.schedule_at(SimTime(5), 1);
        q.schedule_at(SimTime(HORIZON + 2), 3);
        q.schedule_at(SimTime(HORIZON - 1), 2);
        let popped: Vec<(u64, u32)> = std::iter::from_fn(|| q.pop())
            .map(|(t, e)| (t.as_millis(), e))
            .collect();
        assert_eq!(
            popped,
            vec![
                (5, 1),
                (HORIZON - 1, 2),
                (HORIZON + 2, 3),
                (3 * HORIZON + 7, 4)
            ]
        );
    }

    #[test]
    fn peek_sees_overflow_when_wheel_empty() {
        let mut q: EventQueue<u32> = EventQueue::new();
        q.schedule_at(SimTime(2 * HORIZON), 9);
        assert_eq!(q.peek_time(), Some(SimTime(2 * HORIZON)));
        assert_eq!(q.pop(), Some((SimTime(2 * HORIZON), 9)));
        assert_eq!(q.peek_time(), None);
    }

    #[test]
    fn same_time_fifo_across_rebase() {
        let mut q: EventQueue<u32> = EventQueue::new();
        let t = SimTime(HORIZON + 500);
        for i in 0..8 {
            q.schedule_at(t, i);
        }
        // draining an earlier event forces the later ones through a rebase
        q.schedule_at(SimTime(1), 100);
        assert_eq!(q.pop(), Some((SimTime(1), 100)));
        let order: Vec<u32> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, (0..8).collect::<Vec<_>>());
    }

    #[test]
    fn event_exactly_at_the_horizon_goes_through_overflow_in_order() {
        // base_ms = 0: the wheel covers [0, WHEEL); an event at exactly
        // base_ms + WHEEL must take the overflow path, and FIFO order at
        // that timestamp must survive the later sweep into the wheel.
        let mut q: EventQueue<u32> = EventQueue::new();
        q.schedule_at(SimTime(HORIZON), 10);
        q.schedule_at(SimTime(HORIZON), 11);
        q.schedule_at(SimTime(HORIZON - 1), 0); // last in-wheel slot
        assert_eq!(q.len(), 3);
        assert_eq!(q.pop(), Some((SimTime(HORIZON - 1), 0)));
        // wheel drained -> rebase to HORIZON; the boundary events arrive
        // in schedule order
        assert_eq!(q.pop(), Some((SimTime(HORIZON), 10)));
        assert_eq!(q.pop(), Some((SimTime(HORIZON), 11)));
        // after the rebase the window is [HORIZON, 2*HORIZON): the new
        // boundary is 2*HORIZON and the same contract holds there
        q.schedule_at(SimTime(2 * HORIZON), 20);
        q.schedule_at(SimTime(2 * HORIZON - 1), 19);
        q.schedule_at(SimTime(2 * HORIZON), 21);
        assert_eq!(q.pop(), Some((SimTime(2 * HORIZON - 1), 19)));
        assert_eq!(q.pop(), Some((SimTime(2 * HORIZON), 20)));
        assert_eq!(q.pop(), Some((SimTime(2 * HORIZON), 21)));
        assert!(q.is_empty());
    }

    #[test]
    fn past_events_clamp_to_now_with_fifo_surviving_the_overflow_sweep() {
        let mut q: EventQueue<u32> = EventQueue::new();
        // two overflow events at one far timestamp (FIFO pair), plus an
        // early event to drain the wheel first
        q.schedule_at(SimTime(HORIZON + 500), 1);
        q.schedule_at(SimTime(HORIZON + 500), 2);
        q.schedule_at(SimTime(10), 0);
        assert_eq!(q.pop(), Some((SimTime(10), 0)));
        // popping the first overflow event forces the rebase sweep and
        // advances now to HORIZON + 500
        assert_eq!(q.pop(), Some((SimTime(HORIZON + 500), 1)));
        assert_eq!(q.now(), SimTime(HORIZON + 500));
        // events scheduled in the past (and exactly at now) clamp to now
        // and join the *back* of the current bucket — behind the swept
        // event 2 that is already there, in schedule order
        q.schedule_at(SimTime(3), 90);
        q.schedule_at(q.now(), 91);
        q.schedule_at(SimTime::ZERO, 92);
        let order: Vec<(u64, u32)> = std::iter::from_fn(|| q.pop())
            .map(|(t, e)| (t.as_millis(), e))
            .collect();
        assert_eq!(
            order,
            vec![
                (HORIZON + 500, 2),
                (HORIZON + 500, 90),
                (HORIZON + 500, 91),
                (HORIZON + 500, 92),
            ]
        );
    }

    #[test]
    fn interleaved_schedule_pop_keeps_window_sliding() {
        // march far past several horizons with short relative delays
        let mut q: EventQueue<u64> = EventQueue::new();
        q.schedule_at(SimTime(0), 0);
        let mut last = SimTime::ZERO;
        for i in 1..5_000u64 {
            let (t, _) = q.pop().unwrap();
            assert!(t >= last, "time went backwards");
            last = t;
            // delays straddle the horizon boundary
            let delay = if i % 7 == 0 { HORIZON + 13 } else { 40 * i % 900 };
            q.schedule_in(SimTime(delay), i);
        }
    }

    #[test]
    fn matches_reference_heap_on_random_workload() {
        use crate::util::rng::Rng;
        use std::cmp::Reverse;
        use std::collections::BinaryHeap;

        let mut rng = Rng::new(0xE7E47);
        for _ in 0..20 {
            let mut q: EventQueue<u32> = EventQueue::new();
            let mut reference: BinaryHeap<Reverse<(u64, u64, u32)>> = BinaryHeap::new();
            let mut ref_now = 0u64;
            let mut seq = 0u64;
            let mut pending = 0usize;
            for step in 0..2_000u32 {
                if pending == 0 || rng.below(3) > 0 {
                    // schedule: mostly near-term, sometimes past-horizon
                    let delay = match rng.below(10) {
                        0 => HORIZON + rng.below(3 * HORIZON),
                        1..=3 => rng.below(30_000),
                        _ => rng.below(400),
                    };
                    let at = ref_now + delay;
                    q.schedule_at(SimTime(at), step);
                    seq += 1;
                    reference.push(Reverse((at.max(ref_now), seq, step)));
                    pending += 1;
                } else {
                    let got = q.pop().unwrap();
                    let Reverse((t, _, e)) = reference.pop().unwrap();
                    ref_now = t;
                    assert_eq!(got, (SimTime(t), e));
                    pending -= 1;
                }
            }
            // drain both completely
            while let Some(got) = q.pop() {
                let Reverse((t, _, e)) = reference.pop().unwrap();
                assert_eq!(got, (SimTime(t), e));
            }
            assert!(reference.is_empty());
        }
    }

    // -- slab-arena coverage (free-list reuse, FIFO under recycling) ------

    #[test]
    fn arena_reuses_slots_in_steady_state() {
        let mut q: EventQueue<u32> = EventQueue::new();
        // ping-pong one event: 1 fresh slot, then pure reuse
        q.schedule_at(SimTime(0), 0);
        for i in 1..1_000u32 {
            let (_, e) = q.pop().unwrap();
            q.schedule_in(SimTime(7), e + i);
        }
        let s = q.arena_stats();
        assert_eq!(s.allocs, 1, "steady state must not grow the slab");
        assert_eq!(s.reuses, 999);
        assert!(s.reuse_ratio() > 0.99);
    }

    #[test]
    fn free_list_reuse_never_reorders_fifo_ties() {
        // Recycled slot indices arrive LIFO — lower indices can be handed
        // out *after* higher ones. Schedule ties at one timestamp through
        // a heavily recycled arena and require pure schedule order back.
        let mut q: EventQueue<u32> = EventQueue::new();
        for i in 0..64 {
            q.schedule_at(SimTime(1), i);
        }
        while q.pop().is_some() {}
        // the free list now holds 64 slots in LIFO order; these ties all
        // recycle slots whose indices are NOT in schedule order
        for i in 0..64 {
            q.schedule_at(SimTime(2), 100 + i);
        }
        assert_eq!(q.arena_stats().allocs, 64);
        assert_eq!(q.arena_stats().reuses, 64);
        let order: Vec<u32> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, (100..164).collect::<Vec<_>>());
    }

    #[test]
    fn free_list_reuse_property_random_interleave() {
        // Property: under random schedule/pop interleaving with many
        // same-timestamp ties (maximizing recycling), pop order matches a
        // (time, seq) reference heap exactly.
        use crate::util::rng::Rng;
        use std::cmp::Reverse;
        use std::collections::BinaryHeap;

        let mut rng = Rng::new(0x51AB);
        for _ in 0..10 {
            let mut q: EventQueue<u32> = EventQueue::new();
            let mut reference: BinaryHeap<Reverse<(u64, u64, u32)>> = BinaryHeap::new();
            let mut ref_now = 0u64;
            let mut seq = 0u64;
            let mut pending = 0usize;
            for step in 0..3_000u32 {
                if pending == 0 || rng.below(3) > 0 {
                    // only 4 distinct delays -> dense timestamp collisions
                    let delay = 10 * rng.below(4);
                    let at = ref_now + delay;
                    q.schedule_at(SimTime(at), step);
                    seq += 1;
                    reference.push(Reverse((at.max(ref_now), seq, step)));
                    pending += 1;
                } else {
                    let got = q.pop().unwrap();
                    let Reverse((t, _, e)) = reference.pop().unwrap();
                    ref_now = t;
                    assert_eq!(got, (SimTime(t), e));
                    pending -= 1;
                }
            }
            while let Some(got) = q.pop() {
                let Reverse((t, _, e)) = reference.pop().unwrap();
                assert_eq!(got, (SimTime(t), e));
            }
            let s = q.arena_stats();
            assert!(
                s.reuses > s.allocs,
                "interleaved workload must recycle: {s:?}"
            );
        }
    }
}
