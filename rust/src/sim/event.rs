//! Generic discrete-event queue: a binary heap of (time, seq, event) with a
//! monotone sequence number so same-time events pop in scheduling order
//! (deterministic runs).

use super::time::SimTime;
use std::cmp::Reverse;
use std::collections::BinaryHeap;

#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
struct Entry<E: Ord> {
    at: SimTime,
    seq: u64,
    event: E,
}

/// Priority queue of scheduled events.
#[derive(Debug)]
pub struct EventQueue<E: Ord> {
    heap: BinaryHeap<Reverse<Entry<E>>>,
    seq: u64,
    now: SimTime,
}

impl<E: Ord> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E: Ord> EventQueue<E> {
    pub fn new() -> Self {
        EventQueue {
            heap: BinaryHeap::new(),
            seq: 0,
            now: SimTime::ZERO,
        }
    }

    /// Current simulated time (time of the last popped event).
    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Schedule `event` at absolute time `at`. Events scheduled in the past
    /// are clamped to `now` (fire next).
    pub fn schedule_at(&mut self, at: SimTime, event: E) {
        let at = at.max(self.now);
        self.seq += 1;
        self.heap.push(Reverse(Entry {
            at,
            seq: self.seq,
            event,
        }));
    }

    /// Schedule `event` after a delay from now.
    pub fn schedule_in(&mut self, delay: SimTime, event: E) {
        self.schedule_at(self.now + delay, event);
    }

    /// Pop the next event, advancing `now`.
    pub fn pop(&mut self) -> Option<(SimTime, E)> {
        self.heap.pop().map(|Reverse(e)| {
            debug_assert!(e.at >= self.now, "time went backwards");
            self.now = e.at;
            (e.at, e.event)
        })
    }

    pub fn peek_time(&self) -> Option<SimTime> {
        self.heap.peek().map(|Reverse(e)| e.at)
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q: EventQueue<u32> = EventQueue::new();
        q.schedule_at(SimTime(30), 3);
        q.schedule_at(SimTime(10), 1);
        q.schedule_at(SimTime(20), 2);
        let order: Vec<u32> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, vec![1, 2, 3]);
    }

    #[test]
    fn same_time_fifo() {
        let mut q: EventQueue<u32> = EventQueue::new();
        for i in 0..10 {
            q.schedule_at(SimTime(5), i);
        }
        let order: Vec<u32> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn now_advances() {
        let mut q: EventQueue<u32> = EventQueue::new();
        q.schedule_at(SimTime(100), 1);
        assert_eq!(q.now(), SimTime::ZERO);
        q.pop();
        assert_eq!(q.now(), SimTime(100));
    }

    #[test]
    fn schedule_in_is_relative() {
        let mut q: EventQueue<u32> = EventQueue::new();
        q.schedule_at(SimTime(50), 1);
        q.pop();
        q.schedule_in(SimTime(25), 2);
        let (t, e) = q.pop().unwrap();
        assert_eq!((t, e), (SimTime(75), 2));
    }

    #[test]
    fn past_events_clamped_to_now() {
        let mut q: EventQueue<u32> = EventQueue::new();
        q.schedule_at(SimTime(100), 1);
        q.pop();
        q.schedule_at(SimTime(10), 2); // in the past
        let (t, _) = q.pop().unwrap();
        assert_eq!(t, SimTime(100));
    }

    #[test]
    fn peek_and_len() {
        let mut q: EventQueue<u32> = EventQueue::new();
        assert!(q.is_empty());
        q.schedule_at(SimTime(5), 1);
        assert_eq!(q.peek_time(), Some(SimTime(5)));
        assert_eq!(q.len(), 1);
    }
}
