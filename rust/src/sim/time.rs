//! Simulated time in integer milliseconds.
//!
//! All simulator latencies (pod startup, API round-trips, back-off delays,
//! task durations) are expressed in `SimTime`. Integer millis keep event
//! ordering exact and reproducible.

use std::fmt;
use std::ops::{Add, AddAssign, Sub};

/// A point in simulated time (milliseconds since simulation start).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct SimTime(pub u64);

impl SimTime {
    pub const ZERO: SimTime = SimTime(0);

    pub fn from_millis(ms: u64) -> Self {
        SimTime(ms)
    }

    pub fn from_secs_f64(s: f64) -> Self {
        SimTime((s.max(0.0) * 1000.0).round() as u64)
    }

    pub fn as_millis(self) -> u64 {
        self.0
    }

    pub fn as_secs_f64(self) -> f64 {
        self.0 as f64 / 1000.0
    }

    pub fn saturating_sub(self, other: SimTime) -> SimTime {
        SimTime(self.0.saturating_sub(other.0))
    }
}

impl Add for SimTime {
    type Output = SimTime;
    fn add(self, rhs: SimTime) -> SimTime {
        SimTime(self.0 + rhs.0)
    }
}

impl AddAssign for SimTime {
    fn add_assign(&mut self, rhs: SimTime) {
        self.0 += rhs.0;
    }
}

impl Sub for SimTime {
    type Output = SimTime;
    fn sub(self, rhs: SimTime) -> SimTime {
        SimTime(self.0 - rhs.0)
    }
}

impl fmt::Display for SimTime {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{:.3}s", self.as_secs_f64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conversions() {
        assert_eq!(SimTime::from_secs_f64(1.5).as_millis(), 1500);
        assert_eq!(SimTime::from_millis(2500).as_secs_f64(), 2.5);
        assert_eq!(SimTime::from_secs_f64(-3.0), SimTime::ZERO);
    }

    #[test]
    fn arithmetic() {
        let a = SimTime(1000);
        let b = SimTime(400);
        assert_eq!(a + b, SimTime(1400));
        assert_eq!(a - b, SimTime(600));
        assert_eq!(b.saturating_sub(a), SimTime::ZERO);
        let mut c = a;
        c += b;
        assert_eq!(c, SimTime(1400));
    }

    #[test]
    fn ordering() {
        assert!(SimTime(1) < SimTime(2));
        assert_eq!(format!("{}", SimTime(1234)), "1.234s");
    }
}
