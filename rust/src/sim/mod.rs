//! Discrete-event simulation core: simulated time and the event queue.

pub mod event;
pub mod time;

pub use event::{ArenaStats, EventQueue};
pub use time::SimTime;
